# EfQAT build entry points.
#
# `make artifacts` needs the L1/L2 python toolchain (jax + pallas); the
# default rust build and tests do not — they run on the native backend.

ARTIFACTS ?= artifacts

.PHONY: build test doc artifacts bundle bench-quick

build:
	cargo build --release

test:
	cargo test -q

doc:
	cargo doc --no-deps

# Compile every step function to HLO + per-artifact manifests, then write
# the schema-versioned bundle inventory (RFC 0001) the PJRT backend
# verifies against.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../$(ARTIFACTS)
	cargo run --release -- bundle --artifacts $(ARTIFACTS)

# Re-inventory an existing artifacts directory without rebuilding it.
bundle:
	cargo run --release -- bundle --artifacts $(ARTIFACTS)

bench-quick:
	cargo bench --bench table5_backward_runtime
