#!/usr/bin/env python3
"""Compare two BENCH_*.json artifacts and fail on regressions.

The benches (table5, workspace_alloc, serve_throughput, serve_latency)
all emit JSON documents of numeric leaves, possibly nested (e.g.
serve_latency's per-cell grid, its two_model per-model percentiles, and
swap_latency_ms).  This script walks both documents, pairs leaves by
path, classifies each metric by its key name, and exits non-zero if any
metric regressed by more than the threshold (default 15%), printing a
table of offenders.

Classification by key suffix/substring (case-insensitive):
  higher-is-worse:  *_ms, *_us, *_s, *_seconds, *_bytes*, *_time*
  lower-is-worse:   *_per_s, *speedup*, *throughput*, *_qps,
                    bwd_layers_skipped (table5's truncation depth — a
                    shrinking boundary means the backward does more work)
  ignored:          iters, meta keys (bench/backend/bits/models list),
                    and anything non-numeric

Usage:
  python3 python/bench_compare.py BASE.json CANDIDATE.json [--threshold 15]

Exit status: 0 = no regression beyond threshold, 1 = regression found,
2 = usage / parse error / no comparable metrics.
"""

import json
import sys

IGNORED_KEYS = {"iters", "bench", "backend", "bits", "schema", "version"}
HIGHER_IS_WORSE = ("_ms", "_us", "_ns", "_s", "seconds", "bytes", "time", "latency")


def classify(key):
    """'up' if a larger value is worse, 'down' if smaller is worse, None to skip."""
    k = key.lower()
    if k in IGNORED_KEYS:
        return None
    # suffix match for unit-like patterns ("per_s" must not catch
    # "bytes_per_step"); substring for the descriptive ones
    if k.endswith(("per_s", "qps")) or "speedup" in k or "throughput" in k:
        return "down"
    if k == "bwd_layers_skipped":
        return "down"
    for pat in HIGHER_IS_WORSE:
        if k.endswith(pat) or pat in k:
            return "up"
    return None


def leaves(doc, path=()):
    """Yield (path_tuple, number) for every numeric leaf."""
    if isinstance(doc, dict):
        for k, v in doc.items():
            yield from leaves(v, path + (k,))
    elif isinstance(doc, list):
        for i, v in enumerate(doc):
            yield from leaves(v, path + (str(i),))
    elif isinstance(doc, (int, float)) and not isinstance(doc, bool):
        yield path, float(doc)


def compare(base, cand, threshold_pct):
    base_leaves = dict(leaves(base))
    cand_leaves = dict(leaves(cand))
    regressions = []
    compared = 0
    for path, b in sorted(base_leaves.items()):
        if not path:
            # a bare-scalar document root has no key to classify; skip it
            # so the "no comparable metrics" exit (2) fires instead of an
            # IndexError
            continue
        direction = classify(path[-1])
        if direction is None or path not in cand_leaves:
            continue
        c = cand_leaves[path]
        compared += 1
        if b == 0:
            continue  # nothing meaningful to ratio against
        delta_pct = (c - b) / abs(b) * 100.0
        worse = delta_pct if direction == "up" else -delta_pct
        if worse > threshold_pct:
            regressions.append((".".join(path), b, c, delta_pct, direction))
    return compared, regressions


def main(argv):
    args = []
    threshold = 15.0
    it = iter(argv[1:])
    for a in it:
        if a == "--threshold":
            try:
                threshold = float(next(it))
            except (StopIteration, ValueError):
                print("bench_compare: --threshold wants a number", file=sys.stderr)
                return 2
        elif a.startswith("--"):
            print(f"bench_compare: unknown flag {a}", file=sys.stderr)
            return 2
        else:
            args.append(a)
    if len(args) != 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    try:
        with open(args[0]) as f:
            base = json.load(f)
        with open(args[1]) as f:
            cand = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_compare: {e}", file=sys.stderr)
        return 2

    compared, regressions = compare(base, cand, threshold)
    print(f"bench_compare: {args[0]} -> {args[1]}: "
          f"{compared} metrics compared, threshold {threshold:.0f}%")
    if not compared:
        print("bench_compare: no comparable metrics found "
              "(different benches, or schema drift?)", file=sys.stderr)
        return 2
    if regressions:
        width = max(len(p) for p, *_ in regressions)
        print(f"\n{'metric'.ljust(width)}  {'base':>12}  {'candidate':>12}  {'delta':>8}")
        for path, b, c, delta, direction in regressions:
            arrow = "slower" if direction == "up" else "lower"
            print(f"{path.ljust(width)}  {b:12.3f}  {c:12.3f}  {delta:+7.1f}%  ({arrow})")
        print(f"\nbench_compare: FAIL: {len(regressions)} metric(s) "
              f"regressed beyond {threshold:.0f}%")
        return 1
    print("bench_compare: OK — no regression beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
