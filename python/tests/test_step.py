"""Step-builder (AOT ABI) tests: the exact functions that get lowered to
HLO are executed here with concrete inputs."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import models as zoo
from compile import step as step_mod
from compile.quantization import QuantCfg
from compile.specs import wsites

from .test_models import init_params, init_qparams, init_states, make_batch

RNG = np.random.default_rng(9)


def pack_inputs(model, inputs, P, Q, S, B, sel_vals=None):
    args = []
    for s in inputs:
        if s.role == "param":
            args.append(P[s.name])
        elif s.role.startswith("qparam"):
            v = Q[s.name]
            args.append(v.reshape(s.shape) if s.role != "qparam_sw" else v)
        elif s.role == "state":
            args.append(S[s.name])
        elif s.role == "data":
            args.append(B[s.name])
        elif s.role in ("index", "flag"):
            args.append(sel_vals[s.name])
        else:
            raise KeyError(s.role)
    return args


def out_map(outputs, vals):
    return {s.name: v for s, v in zip(outputs, vals)}


class TestTrainStep:
    def setup_method(self, _):
        self.model = zoo.build("resnet8")
        self.bs = 8
        self.qc = QuantCfg(8, 8, mode="ref")
        self.P = init_params(self.model)
        self.Q = init_qparams(self.model, self.P)
        self.S = init_states(self.model)
        self.B = make_batch(self.model, self.bs)

    def test_qat_loss_decreases_with_sgd(self):
        fn, ins, outs = step_mod.build_train(self.model, self.qc, "ratio", 1.0, self.bs)
        jfn = jax.jit(fn)
        P = dict(self.P)
        S = dict(self.S)
        losses = []
        for _ in range(8):
            args = pack_inputs(self.model, ins, P, self.Q, S, self.B)
            vals = out_map(outs, jfn(*args))
            losses.append(float(vals["loss"][0]))
            for o in outs:
                if o.role == "grad" and not o.of.startswith(("sw:", "sx:", "zx:")):
                    P[o.of] = P[o.of] - 0.05 * vals[o.name]
                elif o.role == "state":
                    S[o.of] = vals[o.name]
        assert losses[-1] < losses[0], losses

    def test_ratio_grads_are_rows_of_qat_grads(self):
        fn_full, ins_f, outs_f = step_mod.build_train(
            self.model, self.qc, "ratio", 1.0, self.bs
        )
        fn_r, ins_r, outs_r = step_mod.build_train(
            self.model, self.qc, "ratio", 0.25, self.bs
        )
        sites = wsites(self.model.params)
        sel_vals = {}
        for s in ins_r:
            if s.role == "index":
                c_out = next(p.c_out for p in sites if p.name == s.of)
                sel_vals[s.name] = jnp.array(
                    RNG.choice(c_out, size=s.shape[0], replace=False).astype(np.int32)
                )
        vf = out_map(outs_f, fn_full(*pack_inputs(self.model, ins_f, self.P, self.Q, self.S, self.B)))
        vr = out_map(outs_r, fn_r(*pack_inputs(self.model, ins_r, self.P, self.Q, self.S, self.B, sel_vals)))
        np.testing.assert_allclose(vf["loss"], vr["loss"], rtol=1e-5)
        for p in sites:
            idx = np.asarray(sel_vals[f"id:{p.name}"])
            np.testing.assert_allclose(
                vr[f"d:{p.name}"], np.asarray(vf[f"d:{p.name}"])[idx],
                rtol=1e-4, atol=1e-4, err_msg=p.name,
            )

    def test_r0_has_no_weight_grads_but_trains_qparams(self):
        fn, ins, outs = step_mod.build_train(self.model, self.qc, "ratio", 0.0, self.bs)
        roles = {o.of for o in outs if o.role == "grad"}
        sites = wsites(self.model.params)
        for p in sites:
            assert p.name not in roles
            assert f"sx:{p.name}" in roles and f"zx:{p.name}" in roles
        # biases + norm still train (the paper's "0%" column)
        assert "fc.b" in roles and "stem.conv.bn.g" in roles

    def test_lwpn_flags_gate_grads(self):
        fn, ins, outs = step_mod.build_train(self.model, self.qc, "lwpn", 1.0, self.bs)
        sites = wsites(self.model.params)
        sel_vals = {f"flag:{p.name}": jnp.array([i % 2], jnp.int32) for i, p in enumerate(sites)}
        vals = out_map(outs, fn(*pack_inputs(self.model, ins, self.P, self.Q, self.S, self.B, sel_vals)))
        for i, p in enumerate(sites):
            mx = float(jnp.abs(vals[f"d:{p.name}"]).max())
            assert (mx == 0.0) == (i % 2 == 0), p.name

    def test_fp_train_has_all_param_grads(self):
        fn, ins, outs = step_mod.build_train(self.model, self.qc, "fp", 1.0, self.bs)
        grad_of = {o.of for o in outs if o.role == "grad"}
        for p in self.model.params:
            assert p.name in grad_of, p.name
        assert not any(s.role.startswith("qparam") for s in ins)


def test_fwd_step_eval_mode():
    model = zoo.build("resnet8")
    qc = QuantCfg(8, 8, mode="ref")
    P, S = init_params(model), init_states(model)
    Q = init_qparams(model, P)
    B = make_batch(model, 8)
    fn, ins, outs = step_mod.build_fwd(model, qc, 8)
    vals = out_map(outs, fn(*pack_inputs(model, ins, P, Q, S, B)))
    assert vals["logits"].shape == (8, 10)
    assert 0 <= int(vals["correct"][0]) <= 8


def test_calib_step_minmax():
    model = zoo.build("resnet8")
    P, S = init_params(model), init_states(model)
    B = make_batch(model, 8)
    fn, ins, outs = step_mod.build_calib(model, 8)
    args = []
    for s in ins:
        if s.role == "param":
            args.append(P[s.name])
        elif s.role == "state":
            args.append(S[s.name])
        else:
            args.append(B["x"])
    vals = out_map(outs, fn(*args))
    # first conv sees the raw input, so its minmax must bound the batch
    mm = vals["mm:stem.conv"]
    assert float(mm[0]) <= float(jnp.min(B["x"])) + 1e-6
    assert float(mm[1]) >= float(jnp.max(B["x"])) - 1e-6
    for o in outs:
        assert float(vals[o.name][0]) <= float(vals[o.name][1])


def test_bert_train_step_runs():
    model = zoo.build("bert_tiny")
    qc = QuantCfg(4, 8, mode="ref")
    P, S = init_params(model), init_states(model)
    Q = init_qparams(model, P)
    B = make_batch(model, 4)
    fn, ins, outs = step_mod.build_train(model, qc, "ratio", 0.1, 4)
    sites = wsites(model.params)
    sel_vals = {}
    for s in ins:
        if s.role == "index":
            sel_vals[s.name] = jnp.arange(s.shape[0], dtype=jnp.int32)
    vals = out_map(outs, fn(*pack_inputs(model, ins, P, Q, S, B, sel_vals)))
    assert np.isfinite(float(vals["loss"][0]))
    # embeddings are frozen in EfQAT mode
    assert "d:emb.tok" not in vals
    for p in sites:
        k = step_mod.site_k(p.c_out, 0.1)
        assert vals[f"d:{p.name}"].shape[0] == k
