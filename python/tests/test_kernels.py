"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes, dtypes, bit-widths and scale magnitudes; every
kernel must match its ref.py oracle to fp32 tolerance.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

S = settings(max_examples=10, deadline=None)


def farr(rng, shape, scale=1.0):
    return jnp.array((rng.standard_normal(shape) * scale).astype(np.float32))


@S
@given(
    rows=st.integers(1, 40),
    feat=st.integers(1, 65),
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fq_sym_perrow_matches_ref(rows, feat, bits, seed):
    rng = np.random.default_rng(seed)
    w = farr(rng, (rows, feat))
    s = jnp.array(rng.uniform(1e-3, 0.5, rows).astype(np.float32))
    got = kernels.fq_sym_perrow(w, s, bits)
    want = ref.fq_sym_perrow_ref(w, s, bits)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@S
@given(
    ndim=st.integers(1, 4),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fq_asym_pertensor_matches_ref(ndim, bits, seed):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(1, 6, ndim))
    x = farr(rng, shape, scale=2.0)
    s = jnp.float32(rng.uniform(1e-3, 0.3))
    z = jnp.float32(rng.uniform(-10, 200))
    got = kernels.fq_asym_pertensor(x, s, z, bits)
    want = ref.fq_asym_pertensor_ref(x, s, z, bits)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@S
@given(
    b=st.integers(1, 17),
    c_out=st.integers(1, 50),
    c_in=st.integers(1, 33),
    seed=st.integers(0, 2**31 - 1),
)
def test_partial_dw_matches_ref(b, c_out, c_in, seed):
    rng = np.random.default_rng(seed)
    dy = farr(rng, (b, c_out))
    x = farr(rng, (b, c_in))
    k = int(rng.integers(1, c_out + 1))
    idx = jnp.array(rng.choice(c_out, size=k, replace=False).astype(np.int32))
    got = kernels.partial_dw(dy, x, idx)
    want = ref.partial_dw_ref(dy, x, idx)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_partial_dw_never_materializes_frozen_rows():
    # output shape is [k, C_in] — the frozen rows simply do not exist
    rng = np.random.default_rng(0)
    dy, x = farr(rng, (8, 64)), farr(rng, (8, 32))
    idx = jnp.array([5, 2], dtype=jnp.int32)
    assert kernels.partial_dw(dy, x, idx).shape == (2, 32)


@S
@given(
    rows=st.integers(1, 30),
    ndim=st.integers(2, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_row_abs_mean_matches_ref(rows, ndim, seed):
    rng = np.random.default_rng(seed)
    shape = (rows,) + tuple(rng.integers(1, 5, ndim - 1))
    w = farr(rng, shape)
    np.testing.assert_allclose(
        kernels.row_abs_mean(w), ref.row_abs_mean_ref(w), rtol=1e-6
    )


@S
@given(
    b=st.integers(1, 9),
    c_in=st.integers(1, 20),
    c_out=st.integers(1, 20),
    seed=st.integers(0, 2**31 - 1),
)
def test_int8_matmul_matches_ref(b, c_in, c_out, seed):
    rng = np.random.default_rng(seed)
    xq = jnp.array(rng.integers(0, 256, (b, c_in)), dtype=jnp.int32)
    wq = jnp.array(rng.integers(-127, 128, (c_out, c_in)), dtype=jnp.int32)
    sx = jnp.float32(rng.uniform(1e-3, 0.1))
    zx = jnp.float32(rng.integers(0, 255))
    sw = jnp.array(rng.uniform(1e-3, 0.1, c_out).astype(np.float32))
    got = kernels.int8_matmul(xq, wq, sx, zx, sw)
    want = ref.int8_matmul_ref(xq, wq, sx, zx, sw)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_int8_matmul_equals_fakequant_matmul():
    """Integer arithmetic == fake-quant fp32 arithmetic (train/deploy gap)."""
    rng = np.random.default_rng(7)
    b, c_in, c_out, bits = 4, 16, 8, 8
    x = farr(rng, (b, c_in))
    w = farr(rng, (c_out, c_in))
    sx = jnp.float32(0.05)
    zx = jnp.float32(round(float(rng.uniform(50, 200))))
    sw = jnp.array(rng.uniform(0.01, 0.05, c_out).astype(np.float32))
    # quantize to codes
    xq = jnp.clip(jnp.round(x / sx) + zx, 0, 255)
    wq = jnp.clip(jnp.round(w / sw[:, None]), -127, 127)
    y_int = kernels.int8_matmul(xq, wq, sx, zx, sw)
    xh = ref.fq_asym_pertensor_ref(x, sx, zx, bits)
    wh = ref.fq_sym_perrow_ref(w, sw, bits)
    y_fq = xh @ wh.T
    np.testing.assert_allclose(y_int, y_fq, rtol=1e-4, atol=1e-4)
