"""Fake-quantizer backward rules vs jax.grad of the STE reference."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.quantization import (
    QuantCfg,
    fq_act_bwd,
    fq_act_fwd,
    fq_act_ste,
    fq_weight_bwd,
    fq_weight_fwd,
    fq_weight_ste,
)

S = settings(max_examples=10, deadline=None)


@S
@given(
    rows=st.integers(1, 20),
    feat=st.integers(1, 40),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_weight_bwd_matches_ste_grad(rows, feat, bits, seed):
    rng = np.random.default_rng(seed)
    qc = QuantCfg(bits, 8, mode="ref")
    w = jnp.array(rng.standard_normal((rows, feat)).astype(np.float32))
    s = jnp.array(rng.uniform(0.01, 0.2, rows).astype(np.float32))
    dout = jnp.array(rng.standard_normal((rows, feat)).astype(np.float32))

    # forward values agree between ref and STE construction
    np.testing.assert_allclose(
        fq_weight_fwd(w, s, qc), fq_weight_ste(w, s, bits), atol=0
    )
    _, vjp = jax.vjp(lambda w, s: fq_weight_ste(w, s, bits), w, s)
    dw_ref, ds_ref = vjp(dout)
    dw, ds = fq_weight_bwd(w, s, dout, qc)
    np.testing.assert_allclose(dw, dw_ref, atol=1e-5)
    np.testing.assert_allclose(ds, ds_ref, rtol=1e-3, atol=1e-3)


@S
@given(
    n=st.integers(1, 200),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_act_bwd_matches_ste_grad(n, bits, seed):
    rng = np.random.default_rng(seed)
    qc = QuantCfg(8, bits, mode="ref")
    x = jnp.array((rng.standard_normal(n) * 2).astype(np.float32))
    s = jnp.float32(rng.uniform(0.01, 0.2))
    z = jnp.float32(rng.uniform(0, 2**bits - 1))
    dout = jnp.array(rng.standard_normal(n).astype(np.float32))

    np.testing.assert_allclose(fq_act_fwd(x, s, z, qc), fq_act_ste(x, s, z, bits))
    _, vjp = jax.vjp(lambda x, s, z: fq_act_ste(x, s, z, bits), x, s, z)
    dx_ref, ds_ref, dz_ref = vjp(dout)
    dx, ds, dz = fq_act_bwd(x, s, z, dout, qc)
    np.testing.assert_allclose(dx, dx_ref, atol=1e-5)
    np.testing.assert_allclose(ds, ds_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(dz, dz_ref, rtol=1e-3, atol=1e-3)


def test_weight_quant_is_symmetric():
    qc = QuantCfg(8, 8, mode="ref")
    w = jnp.array([[-1.0, 1.0], [0.5, -0.5]], jnp.float32)
    s = jnp.array([0.01, 0.01], jnp.float32)
    wh = fq_weight_fwd(w, s, qc)
    np.testing.assert_allclose(wh, -fq_weight_fwd(-w, s, qc))


def test_act_clip_range_respected():
    qc = QuantCfg(8, 4, mode="ref")  # 4-bit activations: codes 0..15
    x = jnp.linspace(-10, 10, 101, dtype=jnp.float32)
    s, z = jnp.float32(0.1), jnp.float32(8.0)
    xh = fq_act_fwd(x, s, z, qc)
    codes = np.round(np.asarray(xh) / 0.1) + 8
    assert codes.min() >= 0 and codes.max() <= 15
