"""AOT pipeline tests: manifest/HLO emission and ABI invariants."""

import json
import os

import pytest

from compile import aot, models as zoo, step as step_mod
from compile.quantization import QuantCfg
from compile.specs import wsites


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.compile_model("resnet8", ["w8a8"], [25], out, force=False, use_pallas=True)
    return out


def test_manifest_and_hlo_emitted(tiny_artifacts):
    names = [
        "resnet8_fp_train",
        "resnet8_fp_fwd",
        "resnet8_calib",
        "resnet8_w8a8_fwd",
        "resnet8_w8a8_train_r25",
        "resnet8_w8a8_train_lwpn",
    ]
    for n in names:
        assert os.path.exists(os.path.join(tiny_artifacts, n + ".hlo.txt")), n
        man = json.load(open(os.path.join(tiny_artifacts, n + ".manifest.json")))
        assert man["name"] == n
        assert man["inputs"] and man["outputs"]


def test_hlo_parameter_count_matches_manifest(tiny_artifacts):
    """keep_unused=True: the HLO entry computation must declare exactly the
    manifest's inputs — XLA DCE of unused params would break the rust ABI."""
    for n in ["resnet8_calib", "resnet8_w8a8_train_r25"]:
        man = json.load(open(os.path.join(tiny_artifacts, n + ".manifest.json")))
        hlo = open(os.path.join(tiny_artifacts, n + ".hlo.txt")).read()
        entry = hlo.split("ENTRY")[1]
        n_params = entry.count("parameter(")
        assert n_params == len(man["inputs"]), n


def test_train_manifest_roles(tiny_artifacts):
    man = json.load(open(os.path.join(tiny_artifacts, "resnet8_w8a8_train_r25.manifest.json")))
    roles = {i["role"] for i in man["inputs"]}
    assert {"param", "qparam_sw", "qparam_sx", "qparam_zx", "state", "data", "index"} <= roles
    out_roles = {o["role"] for o in man["outputs"]}
    assert {"loss", "metric", "grad", "state"} <= out_roles
    # index slot counts match site_k
    for i in man["inputs"]:
        if i["role"] == "index":
            site = next(w for w in man["wsites"] if w["name"] == i["of"])
            assert i["shape"][0] == step_mod.site_k(site["c_out"], 0.25)


def test_grad_outputs_restricted_to_k_rows(tiny_artifacts):
    man = json.load(open(os.path.join(tiny_artifacts, "resnet8_w8a8_train_r25.manifest.json")))
    for o in man["outputs"]:
        if o["role"] == "grad" and not o["of"].startswith(("sw:", "sx:", "zx:")):
            site = next((w for w in man["wsites"] if w["name"] == o["of"]), None)
            if site is not None:  # weight site — partial grad
                assert o["shape"][0] == step_mod.site_k(site["c_out"], 0.25), o["of"]


def test_lwpn_has_flags_and_full_grads(tiny_artifacts):
    man = json.load(open(os.path.join(tiny_artifacts, "resnet8_w8a8_train_lwpn.manifest.json")))
    flags = [i for i in man["inputs"] if i["role"] == "flag"]
    assert len(flags) == len(man["wsites"])
    for o in man["outputs"]:
        if o["role"] == "grad":
            site = next((w for w in man["wsites"] if w["name"] == o["of"]), None)
            if site is not None:
                assert o["shape"][0] == site["c_out"]


def test_site_k_rule():
    assert step_mod.site_k(16, 0.05) == 1
    assert step_mod.site_k(64, 0.25) == 16
    assert step_mod.site_k(64, 1.0) == 64


def test_skip_existing_artifacts(tiny_artifacts, capsys):
    aot.compile_model("resnet8", ["w8a8"], [25], tiny_artifacts, force=False, use_pallas=True)
    out = capsys.readouterr().out
    assert "[skip]" in out and "[ok]" not in out
