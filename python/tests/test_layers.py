"""Manual layer VJPs vs jax.vjp of STE-differentiable forwards."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import layers as L
from compile.quantization import QuantCfg, fq_act_ste, fq_weight_ste

QC = QuantCfg(8, 8, mode="ref")
RNG = np.random.default_rng(42)


def f32(*shape, scale=1.0):
    return jnp.array((RNG.standard_normal(shape) * scale).astype(np.float32))


def ste_linear(x, w, b, sx, zx, sw, qc):
    xh = fq_act_ste(x, sx, zx, qc.a_bits)
    wh = fq_weight_ste(w, sw, qc.w_bits)
    y = xh @ wh.T
    return y + b[None, :] if b is not None else y


class TestQLinear:
    def setup_method(self, _):
        self.x = f32(6, 10)
        self.w = f32(7, 10)
        self.b = f32(7)
        self.sx, self.zx = jnp.float32(0.033), jnp.float32(4.7)
        self.sw = jnp.array(RNG.uniform(0.01, 0.05, 7).astype(np.float32))
        self.dy = f32(6, 7)
        self.ref = jax.vjp(
            lambda x, w, b, sx, zx, sw: ste_linear(x, w, b, sx, zx, sw, QC),
            self.x, self.w, self.b, self.sx, self.zx, self.sw,
        )[1](self.dy)
        _, self.cache = L.qlinear_fwd(
            self.x, self.w, self.b, self.sx, self.zx, self.sw, QC
        )

    def test_forward_matches_ste_value(self):
        y, _ = L.qlinear_fwd(self.x, self.w, self.b, self.sx, self.zx, self.sw, QC)
        yr = ste_linear(self.x, self.w, self.b, self.sx, self.zx, self.sw, QC)
        np.testing.assert_allclose(y, yr, atol=1e-6)

    def test_full_backward(self):
        dx, g = L.qlinear_bwd(self.dy, self.cache, L.Sel.all(), QC)
        dx_r, dw_r, db_r, dsx_r, dzx_r, dsw_r = self.ref
        np.testing.assert_allclose(dx, dx_r, atol=1e-5)
        np.testing.assert_allclose(g.dw, dw_r, atol=1e-5)
        np.testing.assert_allclose(g.db, db_r, atol=1e-5)
        np.testing.assert_allclose(g.dsw, dsw_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(g.dsx, dsx_r, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(g.dzx, dzx_r, rtol=1e-4, atol=1e-3)

    def test_idx_backward_is_rows_of_full(self):
        idx = jnp.array([2, 5, 0], dtype=jnp.int32)
        _, g = L.qlinear_bwd(self.dy, self.cache, L.Sel("idx", idx=idx), QC)
        _, dw_r, _, _, _, dsw_r = self.ref
        np.testing.assert_allclose(g.dw, np.asarray(dw_r)[np.asarray(idx)], atol=1e-5)
        np.testing.assert_allclose(
            g.dsw, np.asarray(dsw_r)[np.asarray(idx)], rtol=1e-4, atol=1e-4
        )

    def test_idx_backward_shape_is_k(self):
        idx = jnp.array([4], dtype=jnp.int32)
        _, g = L.qlinear_bwd(self.dy, self.cache, L.Sel("idx", idx=idx), QC)
        assert g.dw.shape == (1, 10) and g.dsw.shape == (1,)

    def test_none_sel_produces_no_weight_grad(self):
        _, g = L.qlinear_bwd(self.dy, self.cache, L.Sel.none(), QC)
        assert g.dw is None and g.dsw is None
        assert g.dsx is not None  # activation qparams still train at r=0

    def test_flag_backward(self):
        _, g1 = L.qlinear_bwd(self.dy, self.cache, L.Sel("flag", flag=jnp.int32(1)), QC)
        _, g0 = L.qlinear_bwd(self.dy, self.cache, L.Sel("flag", flag=jnp.int32(0)), QC)
        _, dw_r, *_ = self.ref
        np.testing.assert_allclose(g1.dw, dw_r, atol=1e-5)
        assert float(jnp.abs(g0.dw).max()) == 0.0

    def test_3d_input(self):
        x3 = f32(2, 5, 10)
        y, cache = L.qlinear_fwd(x3, self.w, self.b, self.sx, self.zx, self.sw, QC)
        assert y.shape == (2, 5, 7)
        dx, g = L.qlinear_bwd(f32(2, 5, 7), cache, L.Sel.all(), QC)
        assert dx.shape == x3.shape and g.dw.shape == self.w.shape


@pytest.mark.parametrize("stride,pad,k", [(1, 1, 3), (2, 1, 3), (1, 0, 1), (2, 0, 1)])
def test_qconv_backward(stride, pad, k):
    x = f32(3, 4, 8, 8)
    w = f32(5, 4, k, k)
    sx, zx = jnp.float32(0.04), jnp.float32(6.0)
    sw = jnp.array(RNG.uniform(0.01, 0.05, 5).astype(np.float32))

    def ste_conv(x, w, sx, zx, sw):
        xh = fq_act_ste(x, sx, zx, QC.a_bits)
        wh = fq_weight_ste(w, sw, QC.w_bits)
        return L._conv(xh, wh, stride, pad)

    y, vjp = jax.vjp(ste_conv, x, w, sx, zx, sw)
    dy = f32(*y.shape)
    dx_r, dw_r, dsx_r, dzx_r, dsw_r = vjp(dy)

    y2, cache = L.qconv_fwd(x, w, sx, zx, sw, QC, stride=stride, pad=pad)
    np.testing.assert_allclose(y2, y, atol=1e-5)
    dx, g = L.qconv_bwd(dy, cache, L.Sel.all(), QC)
    np.testing.assert_allclose(dx, dx_r, atol=1e-4)
    np.testing.assert_allclose(g.dw, dw_r, atol=1e-4)
    np.testing.assert_allclose(g.dsw, dsw_r, rtol=1e-3, atol=5e-4)

    idx = jnp.array([4, 1], dtype=jnp.int32)
    _, gi = L.qconv_bwd(dy, cache, L.Sel("idx", idx=idx), QC)
    np.testing.assert_allclose(gi.dw, np.asarray(dw_r)[np.asarray(idx)], atol=1e-4)

    _, g0 = L.qconv_bwd(dy, cache, L.Sel("flag", flag=jnp.int32(0)), QC)
    assert float(jnp.abs(g0.dw).max()) == 0.0


def _check_simple(fwd, bwd, args, dy_shape, n_grads, atol=1e-4):
    y, vjp = jax.vjp(fwd, *args)
    dy = f32(*dy_shape)
    refs = vjp(dy)[:n_grads]
    outs = bwd(dy)
    if not isinstance(outs, tuple):
        outs = (outs,)
    for o, r in zip(outs, refs):
        np.testing.assert_allclose(o, r, atol=atol)


def test_bn_backward():
    x, g, b = f32(4, 3, 5, 5), f32(3), f32(3)
    rm, rv = jnp.zeros(3), jnp.ones(3)
    _, cache, _, _ = L.bn_fwd(x, g, b, rm, rv)
    _check_simple(
        lambda x, g, b: L.bn_fwd(x, g, b, rm, rv)[0],
        lambda dy: L.bn_bwd(dy, cache),
        (x, g, b),
        (4, 3, 5, 5),
        3,
    )


def test_bn_running_stats_update():
    x = f32(4, 3, 5, 5) + 2.0
    rm, rv = jnp.zeros(3), jnp.ones(3)
    _, _, nrm, nrv = L.bn_fwd(x, jnp.ones(3), jnp.zeros(3), rm, rv, momentum=0.1)
    np.testing.assert_allclose(nrm, 0.1 * jnp.mean(x, axis=(0, 2, 3)), rtol=1e-5)
    # eval mode uses running stats and leaves them unchanged
    _, _, erm, erv = L.bn_fwd(x, jnp.ones(3), jnp.zeros(3), nrm, nrv, train=False)
    np.testing.assert_allclose(erm, nrm)


def test_ln_backward():
    x, g, b = f32(4, 6, 12), f32(12), f32(12)
    _, cache = L.ln_fwd(x, g, b)
    _check_simple(
        lambda x, g, b: L.ln_fwd(x, g, b)[0],
        lambda dy: L.ln_bwd(dy, cache),
        (x, g, b),
        (4, 6, 12),
        3,
    )


def test_relu_gelu_backward():
    x = f32(5, 9)
    _, c = L.relu_fwd(x)
    _check_simple(lambda x: L.relu_fwd(x)[0], lambda dy: L.relu_bwd(dy, c), (x,), (5, 9), 1)
    _, cg = L.gelu_fwd(x)
    _check_simple(lambda x: L.gelu_fwd(x)[0], lambda dy: L.gelu_bwd(dy, cg), (x,), (5, 9), 1, atol=1e-5)


def test_pool_softmax_ce_embedding_backward():
    x = f32(2, 3, 4, 4)
    _, shape = L.global_avg_pool_fwd(x)
    _check_simple(
        lambda x: L.global_avg_pool_fwd(x)[0],
        lambda dy: L.global_avg_pool_bwd(dy, shape),
        (x,),
        (2, 3),
        1,
    )
    s = f32(3, 7)
    _, p = L.softmax_fwd(s)
    _check_simple(
        lambda s: L.softmax_fwd(s)[0], lambda dy: L.softmax_bwd(dy, p), (s,), (3, 7), 1
    )
    logits = f32(6, 10)
    labels = jnp.array(RNG.integers(0, 10, 6), dtype=jnp.int32)
    loss, correct, cache = L.ce_loss_fwd(logits, labels)

    def ce(lg):
        m = jnp.max(lg, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1)) + m[:, 0]
        return jnp.mean(lse - jnp.take_along_axis(lg, labels[:, None], 1)[:, 0])

    _, vjp = jax.vjp(ce, logits)
    np.testing.assert_allclose(L.ce_loss_bwd(cache), vjp(jnp.float32(1))[0], atol=1e-6)

    table = f32(11, 5)
    ids = jnp.array(RNG.integers(0, 11, (3, 4)), dtype=jnp.int32)
    _, ce2 = L.embedding_fwd(table, ids)
    _check_simple(
        lambda t: L.embedding_fwd(t, ids)[0],
        lambda dy: L.embedding_bwd(dy, ce2),
        (table,),
        (3, 4, 5),
        1,
    )
