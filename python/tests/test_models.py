"""Whole-model gradient checks: manual backward vs jax.grad of the
STE-differentiable model (mode='ste'), for every registered model, in
both quantized and FP configurations."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import models as zoo
from compile.layers import Sel
from compile.quantization import QuantCfg
from compile.specs import wsites

RNG = np.random.default_rng(3)


def init_params(model):
    P = {}
    for p in model.params:
        kind = p.init[0]
        if kind in ("he_conv", "he_lin"):
            std = float(np.sqrt(2.0 / p.init[1]))
            P[p.name] = jnp.array((RNG.standard_normal(p.shape) * std).astype(np.float32))
        elif kind == "normal":
            P[p.name] = jnp.array((RNG.standard_normal(p.shape) * p.init[1]).astype(np.float32))
        elif kind == "zeros":
            P[p.name] = jnp.zeros(p.shape, jnp.float32)
        elif kind == "ones":
            P[p.name] = jnp.ones(p.shape, jnp.float32)
        else:
            raise KeyError(kind)
    return P


def init_states(model):
    return {
        s.name: jnp.zeros(s.shape) if s.init == "zeros" else jnp.ones(s.shape)
        for s in model.states
    }


def init_qparams(model, P):
    Q = {}
    for p in wsites(model.params):
        w = P[p.name].reshape(p.c_out, -1)
        # 1.02 factor keeps the row-max strictly inside the clip range:
        # exactly ON the boundary, jax.grad of clip() splits ties 0.5/0.5
        # while the STE backward uses inclusive masks — a measure-zero
        # convention difference that would otherwise trip the comparison.
        Q[f"sw:{p.name}"] = jnp.maximum(jnp.max(jnp.abs(w), axis=1) / 127.0, 1e-4) * 1.02
        Q[f"sx:{p.name}"] = jnp.float32(0.05)
        Q[f"zx:{p.name}"] = jnp.float32(64.0)
    return Q


def make_batch(model, bs=4):
    B = {}
    for b in model.batch_specs(bs):
        if b.dtype == "f32":
            B[b.name] = jnp.array(RNG.standard_normal(b.shape).astype(np.float32))
        else:
            hi = 10
            if b.name == "x":  # token ids
                hi = getattr(model, "vocab", 10)
            elif b.name in ("y_start", "y_end"):
                hi = model.seq_len
            elif b.name == "y" and hasattr(model, "vocab"):
                hi = model.vocab
            B[b.name] = jnp.array(RNG.integers(0, hi, b.shape), dtype=jnp.int32)
    return B


@pytest.mark.parametrize("name", ["resnet8", "resnet11b", "bert_tiny", "gpt_mini"])
@pytest.mark.parametrize("fp", [False, True])
def test_manual_backward_matches_ste_autodiff(name, fp):
    model = zoo.build(name)
    qc = QuantCfg(0, 0) if fp else QuantCfg(8, 8, mode="ste")
    P = init_params(model)
    S = init_states(model)
    Q = {} if fp else init_qparams(model, P)
    B = make_batch(model)
    sels = {p.name: Sel.all() for p in wsites(model.params)}

    def loss_fn(P, Q):
        loss, _, _, _ = model.forward(P, Q, S, B, True, qc)
        return loss

    gP_ref, gQ_ref = jax.grad(loss_fn, argnums=(0, 1))(P, Q)

    _, _, caches, _ = model.forward(P, Q, S, B, True, qc)
    grads = model.backward(P, Q, caches, sels, qc)

    checked = 0
    for k, ref in gP_ref.items():
        if k not in grads:
            # embeddings receive no grads in quantized mode (paper §4)
            assert not fp and k.startswith("emb."), k
            continue
        np.testing.assert_allclose(
            grads[k], ref, rtol=1e-3, atol=2e-3, err_msg=f"param {k}"
        )
        checked += 1
    for k, ref in gQ_ref.items():
        np.testing.assert_allclose(
            grads[k], ref, rtol=1e-3, atol=2e-3, err_msg=f"qparam {k}"
        )
        checked += 1
    assert checked >= len(grads) * 0.9


def test_idx_selection_matches_full_rows_resnet():
    """EfQAT partial grads == the corresponding rows of the QAT full grads."""
    model = zoo.build("resnet8")
    qc = QuantCfg(8, 8, mode="ref")
    P, S = init_params(model), init_states(model)
    Q = init_qparams(model, P)
    B = make_batch(model)
    sites = wsites(model.params)

    _, _, caches, _ = model.forward(P, Q, S, B, True, qc)
    full = model.backward(P, Q, caches, {p.name: Sel.all() for p in sites}, qc)

    idxs = {
        p.name: jnp.array(
            RNG.choice(p.c_out, size=max(1, p.c_out // 4), replace=False).astype(np.int32)
        )
        for p in sites
    }
    _, _, caches, _ = model.forward(P, Q, S, B, True, qc)
    part = model.backward(
        P, Q, caches, {n: Sel("idx", idx=i) for n, i in idxs.items()}, qc
    )
    for p in sites:
        sel = np.asarray(idxs[p.name])
        np.testing.assert_allclose(
            part[p.name], np.asarray(full[p.name])[sel], rtol=1e-4, atol=1e-4
        )
        np.testing.assert_allclose(
            part[f"sw:{p.name}"], np.asarray(full[f"sw:{p.name}"])[sel],
            rtol=1e-3, atol=1e-3,
        )


def test_lwpn_flags_zero_frozen_layers():
    model = zoo.build("resnet8")
    qc = QuantCfg(8, 8, mode="ref")
    P, S = init_params(model), init_states(model)
    Q = init_qparams(model, P)
    B = make_batch(model)
    sites = wsites(model.params)
    flags = {p.name: jnp.int32(i % 2) for i, p in enumerate(sites)}

    _, _, caches, _ = model.forward(P, Q, S, B, True, qc)
    grads = model.backward(
        P, Q, caches, {n: Sel("flag", flag=f) for n, f in flags.items()}, qc
    )
    for p in sites:
        mx = float(jnp.abs(grads[p.name]).max())
        if int(flags[p.name]) == 0:
            assert mx == 0.0, p.name
        else:
            assert mx > 0.0, p.name


def test_bert_span_loss_is_mean_of_start_end():
    model = zoo.build("bert_tiny")
    qc = QuantCfg(0, 0)
    P, S = init_params(model), init_states(model)
    B = make_batch(model)
    loss, metrics, _, _ = model.forward(P, {}, S, B, True, qc)
    assert loss.shape == () and metrics["logits"].shape == (4, model.seq_len, 2)


def test_gpt_causality():
    """Future tokens must not influence past logits."""
    model = zoo.build("gpt_mini")
    qc = QuantCfg(0, 0)
    P, S = init_params(model), init_states(model)
    B = make_batch(model)
    _, m1, _, _ = model.forward(P, {}, S, B, False, qc)
    B2 = dict(B)
    x2 = np.asarray(B["x"]).copy()
    x2[:, -1] = (x2[:, -1] + 1) % model.vocab  # perturb ONLY the last token
    B2["x"] = jnp.array(x2)
    _, m2, _, _ = model.forward(P, {}, S, B2, False, qc)
    np.testing.assert_allclose(
        m1["logits"][:, :-1], m2["logits"][:, :-1], atol=1e-5
    )
