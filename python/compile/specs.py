"""Model/parameter/state specs shared by models, aot.py and the manifest.

Everything the rust coordinator needs to own the training state is
declared here: parameter names, shapes, initializer recipes, which
parameters are quantized weights (and therefore freezable channel-wise),
and the per-model list of weight sites in a stable order.  aot.py
serializes these into the artifact manifest; rust binds literals by
manifest order, so the specs are the single source of truth for the
cross-language ABI.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One trainable tensor.

    kind:
      weight     conv/linear weight — quantized (per-row S_w), freezable
      bias       linear bias — always trained during EfQAT
      norm       BN/LN gamma+beta — always trained during EfQAT
      embed      embedding table — trained only in FP mode (paper §4)
    init: ("he_conv", fan_in) | ("he_lin", fan_in) | ("normal", std)
          | ("zeros",) | ("ones",) | ("uniform", lo, hi)
    """

    name: str
    shape: tuple[int, ...]
    init: tuple
    kind: str

    @property
    def c_out(self) -> int:
        return self.shape[0]

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


@dataclasses.dataclass(frozen=True)
class StateSpec:
    """Non-trainable state threaded through the train step (BN stats)."""

    name: str
    shape: tuple[int, ...]
    init: str  # 'zeros' | 'ones'


@dataclasses.dataclass(frozen=True)
class BatchSpec:
    """One data input of the step function."""

    name: str
    shape: tuple[int, ...]
    dtype: str  # 'f32' | 'i32'


def wsites(params: list[ParamSpec]) -> list[ParamSpec]:
    """Quantized/freezable weight sites in declaration order."""
    return [p for p in params if p.kind == "weight"]
