"""Manual forward/backward layers for the EfQAT training graph.

Why manual?  `jax.grad` always materializes the *full* weight gradient.
EfQAT's contribution (paper Section 3.2, Fig. 1 right) is that the weight
gradient matmul is only evaluated for the unfrozen output channels:

    dX     = dY · Ŵ                      (always full — needed to propagate)
    dW[id] = gather(dY, id)ᵀ · X̂          (only k = ⌈r·C_out⌉ rows)

so every layer here exposes an explicit `*_fwd` (returning a residual
cache) and `*_bwd` (consuming the cache plus a `Sel` describing which
rows are unfrozen).  Each hand-written VJP is verified against `jax.vjp`
of the same forward in python/tests/test_layers.py.

Selection (`Sel`) variants map to the paper's modes:
    all    — QAT baseline / FP training: full dW
    idx    — EfQAT-CWPL / CWPN: static-k row indices (AOT shape)
    flag   — EfQAT-LWPN: per-layer lax.cond; XLA `conditional` is lazy, so
             a frozen layer's dW matmul is skipped *at runtime*
    none   — the 0% case: no dW at all (only qparams/bias/norm train)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import kernels
from .kernels import ref
from .quantization import QuantCfg, fq_act_bwd, fq_act_fwd, fq_weight_bwd, fq_weight_fwd


@dataclasses.dataclass
class Sel:
    """Per-layer weight-gradient selection."""

    kind: str  # 'all' | 'idx' | 'flag' | 'none'
    idx: Optional[jnp.ndarray] = None  # [k] int32, kind == 'idx'
    flag: Optional[jnp.ndarray] = None  # scalar int32, kind == 'flag'

    @staticmethod
    def all() -> "Sel":
        return Sel("all")

    @staticmethod
    def none() -> "Sel":
        return Sel("none")


@dataclasses.dataclass
class QGrads:
    """Gradients produced by one quantized layer's backward."""

    dw: Optional[jnp.ndarray] = None  # [k,...] ('idx') or full ('all'/'flag')
    dsw: Optional[jnp.ndarray] = None  # [k] or [C_out]
    db: Optional[jnp.ndarray] = None  # [C_out]
    dsx: Optional[jnp.ndarray] = None  # scalar
    dzx: Optional[jnp.ndarray] = None  # scalar


# ---------------------------------------------------------------------------
# Quantized linear:  y = x̂ ŵᵀ + b
# ---------------------------------------------------------------------------


def qlinear_fwd(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray],
    sx: jnp.ndarray,
    zx: jnp.ndarray,
    sw: jnp.ndarray,
    qc: QuantCfg,
) -> tuple[jnp.ndarray, Any]:
    """x: [..., C_in], w: [C_out, C_in].  Leading dims are flattened."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if qc.enabled:
        xh = fq_act_fwd(x2, sx, zx, qc)
        wh = fq_weight_fwd(w, sw, qc)
    else:
        xh, wh = x2, w
    y2 = xh @ wh.T
    if b is not None:
        y2 = y2 + b[None, :]
    y = y2.reshape(lead + (w.shape[0],))
    cache = (x2, xh, w, wh, sx, zx, sw, b is not None, lead)
    return y, cache


def _linear_dwhat(dy2, xh, sel):
    """dŴ restricted by `sel`.  Returns (dwhat, row_params_extractor)."""
    if sel.kind == "all":
        return dy2.T @ xh, lambda a: a
    if sel.kind == "idx":
        dwp = kernels.partial_dw(dy2, xh, sel.idx)
        return dwp, lambda a: jnp.take(a, sel.idx, axis=0)
    if sel.kind == "flag":
        dwhat = lax.cond(
            sel.flag > 0,
            lambda: dy2.T @ xh,
            lambda: jnp.zeros((xh.shape[1], dy2.shape[1]), jnp.float32).T,
        )
        return dwhat, lambda a: a
    return None, None


def qlinear_bwd(
    dy: jnp.ndarray, cache: Any, sel: Sel, qc: QuantCfg
) -> tuple[jnp.ndarray, QGrads]:
    x2, xh, w, wh, sx, zx, sw, has_b, lead = cache
    dy2 = dy.reshape(-1, dy.shape[-1])
    g = QGrads()
    if has_b:
        g.db = jnp.sum(dy2, axis=0)

    dxh = dy2 @ wh  # full input gradient — same as QAT (Eq. 5 first matmul)

    if qc.enabled:
        dwhat, take_rows = _linear_dwhat(dy2, xh, sel)
        if dwhat is not None:
            g.dw, g.dsw = fq_weight_bwd(take_rows(w), take_rows(sw), dwhat, qc)
        dx2, g.dsx, g.dzx = fq_act_bwd(x2, sx, zx, dxh, qc)
    else:
        if sel.kind != "none":
            g.dw = dy2.T @ xh
        dx2 = dxh
    return dx2.reshape(lead + (x2.shape[-1],)), g


# ---------------------------------------------------------------------------
# Quantized conv2d (NCHW / OIHW), stride s, symmetric padding p
# ---------------------------------------------------------------------------


def _conv(x, w, stride, pad):
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def _conv_dx(dy, wh, x_shape, stride, pad):
    """Full input gradient via the VJP of the forward conv (exact, and
    XLA CSEs the re-traced forward with the original one)."""
    _, vjp = jax.vjp(lambda t: _conv(t, wh, stride, pad), jnp.zeros(x_shape, dy.dtype))
    return vjp(dy)[0]


def _conv_dw(x, dy, kh, stride, pad):
    """Weight gradient as a conv: dW[o,i,u,v] = Σ_{n,p,q} dy[n,o,p,q]·
    x[n,i,u+p·s-pad,v+q·s-pad].  `dy` may be channel-gathered (EfQAT):
    its channel count determines the produced rows."""
    h = x.shape[2]
    ho = dy.shape[2]
    pad_hi = kh - h - pad + (ho - 1) * stride
    return lax.conv_general_dilated(
        x,
        dy,
        window_strides=(1, 1),
        padding=((pad, pad_hi), (pad, pad_hi)),
        rhs_dilation=(stride, stride),
        dimension_numbers=("CNHW", "IOHW", "CNHW"),
    )


def qconv_fwd(
    x: jnp.ndarray,
    w: jnp.ndarray,
    sx: jnp.ndarray,
    zx: jnp.ndarray,
    sw: jnp.ndarray,
    qc: QuantCfg,
    stride: int = 1,
    pad: int = 1,
) -> tuple[jnp.ndarray, Any]:
    """x: [N, C_in, H, W], w: [C_out, C_in, kh, kw].  Bias-free (BN follows)."""
    if qc.enabled:
        xh = fq_act_fwd(x, sx, zx, qc)
        wh = fq_weight_fwd(w, sw, qc)
    else:
        xh, wh = x, w
    y = _conv(xh, wh, stride, pad)
    cache = (x, xh, w, wh, sx, zx, sw, stride, pad)
    return y, cache


def qconv_bwd(
    dy: jnp.ndarray, cache: Any, sel: Sel, qc: QuantCfg
) -> tuple[jnp.ndarray, QGrads]:
    x, xh, w, wh, sx, zx, sw, stride, pad = cache
    kh = w.shape[2]
    g = QGrads()

    dxh = _conv_dx(dy, wh, x.shape, stride, pad)

    def full_dwhat():
        return _conv_dw(xh, dy, kh, stride, pad)

    if qc.enabled:
        if sel.kind == "all":
            g.dw, g.dsw = fq_weight_bwd(w, sw, full_dwhat(), qc)
        elif sel.kind == "idx":
            dy_g = jnp.take(dy, sel.idx, axis=1)
            dwhat = _conv_dw(xh, dy_g, kh, stride, pad)
            w_g = jnp.take(w, sel.idx, axis=0)
            s_g = jnp.take(sw, sel.idx, axis=0)
            g.dw, g.dsw = fq_weight_bwd(w_g, s_g, dwhat, qc)
        elif sel.kind == "flag":
            zero = lambda: jnp.zeros(w.shape, jnp.float32)
            dwhat = lax.cond(sel.flag > 0, full_dwhat, zero)
            g.dw, g.dsw = fq_weight_bwd(w, sw, dwhat, qc)
        dx, g.dsx, g.dzx = fq_act_bwd(x, sx, zx, dxh, qc)
    else:
        if sel.kind != "none":
            g.dw = full_dwhat()
        dx = dxh
    return dx, g


# ---------------------------------------------------------------------------
# BatchNorm2d (training mode, running-stat state threaded through)
# ---------------------------------------------------------------------------

BN_EPS = 1e-5


def bn_fwd(x, gamma, beta, rmean, rvar, momentum=0.1, train=True):
    """x: [N, C, H, W].  Returns (y, cache, new_rmean, new_rvar)."""
    if train:
        mu = jnp.mean(x, axis=(0, 2, 3))
        var = jnp.var(x, axis=(0, 2, 3))
        new_rmean = (1 - momentum) * rmean + momentum * mu
        new_rvar = (1 - momentum) * rvar + momentum * var
    else:
        mu, var = rmean, rvar
        new_rmean, new_rvar = rmean, rvar
    inv = 1.0 / jnp.sqrt(var + BN_EPS)
    xhat = (x - mu[None, :, None, None]) * inv[None, :, None, None]
    y = gamma[None, :, None, None] * xhat + beta[None, :, None, None]
    return y, (xhat, gamma, inv, x.shape), new_rmean, new_rvar


def bn_bwd(dy, cache):
    xhat, gamma, inv, shape = cache
    n = shape[0] * shape[2] * shape[3]
    dgamma = jnp.sum(dy * xhat, axis=(0, 2, 3))
    dbeta = jnp.sum(dy, axis=(0, 2, 3))
    gi = (gamma * inv)[None, :, None, None]
    dx = gi * (
        dy
        - (dbeta / n)[None, :, None, None]
        - xhat * (dgamma / n)[None, :, None, None]
    )
    return dx, dgamma, dbeta


# ---------------------------------------------------------------------------
# LayerNorm (last axis)
# ---------------------------------------------------------------------------

LN_EPS = 1e-5


def ln_fwd(x, gamma, beta):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    inv = 1.0 / jnp.sqrt(var + LN_EPS)
    xhat = (x - mu) * inv
    return gamma * xhat + beta, (xhat, gamma, inv)


def ln_bwd(dy, cache):
    xhat, gamma, inv = cache
    d = xhat.shape[-1]
    dgamma = jnp.sum(dy * xhat, axis=tuple(range(dy.ndim - 1)))
    dbeta = jnp.sum(dy, axis=tuple(range(dy.ndim - 1)))
    dxhat = dy * gamma
    dx = inv * (
        dxhat
        - jnp.mean(dxhat, axis=-1, keepdims=True)
        - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True)
    )
    return dx, dgamma, dbeta


# ---------------------------------------------------------------------------
# Elementwise activations
# ---------------------------------------------------------------------------


def relu_fwd(x):
    return jnp.maximum(x, 0.0), (x > 0)


def relu_bwd(dy, cache):
    return dy * cache


_GELU_C = 0.7978845608028654  # sqrt(2/pi)


def gelu_fwd(x):
    inner = _GELU_C * (x + 0.044715 * x**3)
    t = jnp.tanh(inner)
    return 0.5 * x * (1.0 + t), (x, t)


def gelu_bwd(dy, cache):
    x, t = cache
    dinner = _GELU_C * (1.0 + 3 * 0.044715 * x**2)
    dydx = 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t**2) * dinner
    return dy * dydx


# ---------------------------------------------------------------------------
# Pooling / softmax / losses
# ---------------------------------------------------------------------------


def global_avg_pool_fwd(x):
    """[N, C, H, W] → [N, C]"""
    return jnp.mean(x, axis=(2, 3)), x.shape


def global_avg_pool_bwd(dy, shape):
    n, c, h, w = shape
    return jnp.broadcast_to(dy[:, :, None, None], shape) / (h * w)


def softmax_fwd(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    return p, p


def softmax_bwd(dy, p):
    return p * (dy - jnp.sum(dy * p, axis=-1, keepdims=True))


def ce_loss_fwd(logits, labels):
    """Mean softmax cross-entropy.  logits: [B, C], labels: [B] int32.
    Returns (loss, correct_count, cache)."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    sh = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(sh), axis=-1)) + m[:, 0]
    nll = lse - jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    loss = jnp.mean(nll)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.int32))
    return loss, correct, (logits, labels, lse)


def ce_loss_bwd(cache, scale=1.0):
    logits, labels, lse = cache
    b, c = logits.shape
    p = jnp.exp(logits - lse[:, None])
    onehot = jax.nn.one_hot(labels, c, dtype=logits.dtype)
    return (p - onehot) * (scale / b)


def embedding_fwd(table, ids):
    """table: [V, D], ids: [...] int32 → [..., D]"""
    return jnp.take(table, ids, axis=0), (table.shape, ids)


def embedding_bwd(dy, cache):
    shape, ids = cache
    flat_ids = ids.reshape(-1)
    flat_dy = dy.reshape(-1, shape[1])
    return jnp.zeros(shape, dy.dtype).at[flat_ids].add(flat_dy)
