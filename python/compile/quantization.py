"""Fake-quantization forward/backward with STE + LSQ-style gradients.

This module implements the differentiable quantizers the paper trains
with (Section 3.1 + Section 4 "we use STE to approximate the gradient of
the rounding function"):

  weights      symmetric, per-output-channel scale S_w (Eq. 3/4), Z_w = 0
  activations  asymmetric, per-tensor scale S_x and zero point Z_x (Eq. 1/2)

Backward rules (w.r.t. a downstream gradient g = ∂L/∂x̂):

  STE on round():     ∂x̂/∂x = 1 inside the clip range, 0 outside
  LSQ scale grad:     ∂x̂/∂s = round(x/s) - x/s   (in range)
                              clip boundary code  (out of range)
  LSQ+ zero point:    ∂x̂/∂z = 0 (in range) / -s (out of range)

The *forward* dequantized values come from the Pallas kernels
(kernels.fq_sym_perrow / fq_asym_pertensor) when `QuantCfg.use_pallas`
is set, otherwise from the pure-jnp oracle; both are bit-identical (see
python/tests/test_kernels.py). Backward formulas are plain jnp — they
are cheap elementwise ops fused by XLA into the surrounding graph.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from . import kernels
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class QuantCfg:
    """Static quantization configuration for a model build.

    w_bits/a_bits of 0 disable quantization entirely (the FP path used to
    pretrain baselines).
    """

    w_bits: int = 8
    a_bits: int = 8
    # forward quantizer implementation: 'kernel' (Pallas), 'ref' (pure jnp),
    # 'ste' (stop_gradient construction — differentiable, used as the
    # jax.grad oracle in tests)
    mode: str = "kernel"

    @property
    def enabled(self) -> bool:
        return self.w_bits > 0

    @property
    def tag(self) -> str:
        return "fp" if not self.enabled else f"w{self.w_bits}a{self.a_bits}"


# ---------------------------------------------------------------------------
# STE-differentiable reference quantizers (test oracles).
#
# jax.vjp of the plain forward is useless as an oracle: round() has zero
# gradient a.e.  These encode the STE/LSQ rules via stop_gradient so that
# jax.vjp of *these* yields exactly the gradients the manual backward
# (fq_weight_bwd / fq_act_bwd) must produce.  Used only by tests.
# ---------------------------------------------------------------------------


def fq_weight_ste(w: jnp.ndarray, s: jnp.ndarray, bits: int) -> jnp.ndarray:
    from jax import lax

    qmin, qmax = ref.qrange_sym(bits)
    sb = s.reshape((w.shape[0],) + (1,) * (w.ndim - 1))
    v = w / sb
    vb = jnp.clip(v, qmin, qmax)
    q = vb + lax.stop_gradient(jnp.round(vb) - vb)
    return q * sb


def fq_act_ste(
    x: jnp.ndarray, s: jnp.ndarray, z: jnp.ndarray, bits: int
) -> jnp.ndarray:
    from jax import lax

    qmin, qmax = ref.qrange_asym(bits)
    v = x / s
    zr = z + lax.stop_gradient(jnp.round(z) - z)
    t = jnp.clip(v + zr, qmin, qmax) - zr
    c = jnp.clip(jnp.round(v) + jnp.round(z), qmin, qmax)
    return s * (t + lax.stop_gradient((c - jnp.round(z)) - t))


# ---------------------------------------------------------------------------
# Weights: symmetric per-row
# ---------------------------------------------------------------------------


def fq_weight_fwd(w: jnp.ndarray, s: jnp.ndarray, qc: QuantCfg) -> jnp.ndarray:
    """ŵ = clip(round(w/s))·s per output row. w: [C_out, ...], s: [C_out]."""
    if qc.mode == "kernel":
        return kernels.fq_sym_perrow(w, s, qc.w_bits)
    if qc.mode == "ste":
        return fq_weight_ste(w, s, qc.w_bits)
    return ref.fq_sym_perrow_ref(w, s, qc.w_bits)


def fq_weight_bwd(
    w: jnp.ndarray, s: jnp.ndarray, dwhat: jnp.ndarray, qc: QuantCfg
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Backward of the weight fake-quantizer for *the given rows only*.

    `w`, `s`, `dwhat` must already be restricted to the unfrozen rows
    (shape [k, ...] / [k]); dW and dS_w never exist for frozen rows, which
    is exactly the EfQAT compute saving.
    Returns (dw [k, ...], ds [k]).
    """
    qmin, qmax = ref.qrange_sym(qc.w_bits)
    sb = s.reshape((w.shape[0],) + (1,) * (w.ndim - 1))
    v = w / sb
    q = jnp.clip(jnp.round(v), qmin, qmax)
    in_range = (v >= qmin) & (v <= qmax)
    dw = dwhat * in_range
    # LSQ: ∂ŵ/∂s = q - v in range, q (= clip boundary) outside.
    ds_elem = dwhat * jnp.where(in_range, q - v, q)
    ds = jnp.sum(ds_elem.reshape(w.shape[0], -1), axis=1)
    return dw, ds


# ---------------------------------------------------------------------------
# Activations: asymmetric per-tensor
# ---------------------------------------------------------------------------


def fq_act_fwd(
    x: jnp.ndarray, s: jnp.ndarray, z: jnp.ndarray, qc: QuantCfg
) -> jnp.ndarray:
    """x̂ = (clip(round(x/s)+round(z), 0, 2^b-1) - round(z))·s."""
    if qc.mode == "kernel":
        return kernels.fq_asym_pertensor(x, s, z, qc.a_bits)
    if qc.mode == "ste":
        return fq_act_ste(x, s, z, qc.a_bits)
    return ref.fq_asym_pertensor_ref(x, s, z, qc.a_bits)


def fq_act_bwd(
    x: jnp.ndarray,
    s: jnp.ndarray,
    z: jnp.ndarray,
    dxhat: jnp.ndarray,
    qc: QuantCfg,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Backward of the activation fake-quantizer.

    Returns (dx [like x], ds scalar, dz scalar).
    """
    qmin, qmax = ref.qrange_asym(qc.a_bits)
    v = x / s
    zr = jnp.round(z)
    # LSQ+ convention: the pass-through mask is evaluated on the
    # *continuous* code v + z, not the rounded one.
    in_range = (v + zr >= qmin) & (v + zr <= qmax)
    c = jnp.clip(jnp.round(v) + zr, qmin, qmax)
    dx = dxhat * in_range
    # in range: ∂x̂/∂s = (c - z) - v,  ∂x̂/∂z = 0
    # clipped:  ∂x̂/∂s = (c - z),      ∂x̂/∂z = -s
    ds = jnp.sum(dxhat * ((c - zr) - jnp.where(in_range, v, 0.0)))
    dz = jnp.sum(dxhat * jnp.where(in_range, 0.0, -s))
    return dx, ds, dz
