"""AOT compiler: lower every step function to HLO text + JSON manifest.

Interchange is HLO *text*, not a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 (the
version behind the published `xla` rust crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts per (model, bits):
    <m>_fp_train                 FP baseline train step (bits-independent)
    <m>_<bits>_fwd               eval forward
    <m>_<bits>_calib             PTQ MinMax calibration forward
    <m>_<bits>_train_r{0,5,10,25,50}   EfQAT ratio artifacts (static k)
    <m>_<bits>_train_r100        the QAT baseline (full dW)
    <m>_<bits>_train_lwpn        per-layer lax.cond flags (fully dynamic)

Usage:  python -m compile.aot --out-dir ../artifacts \
            [--models resnet20,bert_tiny] [--bits w8a8,w4a8] \
            [--ratios 0,5,10,25,50,100] [--force] [--no-pallas]

Existing artifacts are skipped unless --force, so `make artifacts` is an
incremental no-op when nothing changed.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import models as model_zoo
from . import step as step_mod
from .quantization import QuantCfg
from .specs import wsites

DEFAULT_BITS = {
    "resnet8": ["w8a8", "w4a8"],
    "resnet20": ["w8a8", "w4a8", "w4a4"],
    "resnet11b": ["w8a8", "w4a8", "w4a4"],
    "bert_tiny": ["w8a8", "w4a8"],
    "gpt_mini": ["w8a8", "w4a8"],
}
DEFAULT_RATIOS = [0, 5, 10, 25, 50, 100]


def parse_bits(tag: str) -> QuantCfg:
    # 'w4a8' -> QuantCfg(4, 8)
    w, a = tag[1:].split("a")
    return QuantCfg(int(w), int(a))


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _abstract_args(inputs):
    return [
        jax.ShapeDtypeStruct(s.shape, jnp.float32 if s.dtype == "f32" else jnp.int32)
        for s in inputs
    ]


def write_artifact(out_dir, name, fn, inputs, outputs, meta, force=False):
    hlo_path = os.path.join(out_dir, name + ".hlo.txt")
    man_path = os.path.join(out_dir, name + ".manifest.json")
    if not force and os.path.exists(hlo_path) and os.path.exists(man_path):
        print(f"  [skip] {name}")
        return False
    t0 = time.time()
    # keep_unused=True: manifest order IS the ABI — XLA must not DCE inputs
    # that don't reach an output (e.g. fc.w in the calib artifact).
    lowered = jax.jit(fn, keep_unused=True).lower(*_abstract_args(inputs))
    text = to_hlo_text(lowered)
    with open(hlo_path, "w") as f:
        f.write(text)
    manifest = dict(meta)
    manifest["name"] = name
    manifest["inputs"] = [s.to_json() for s in inputs]
    manifest["outputs"] = [s.to_json() for s in outputs]
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  [ok]   {name}  ({len(text)//1024} KiB HLO, {time.time()-t0:.1f}s)")
    return True


def model_meta(model, batch_size, qc: QuantCfg | None, extra=None):
    meta = {
        "model": model.name,
        "batch_size": batch_size,
        "w_bits": qc.w_bits if qc else 0,
        "a_bits": qc.a_bits if qc else 0,
        "params": [
            {
                "name": p.name,
                "shape": list(p.shape),
                "init": list(p.init),
                "kind": p.kind,
            }
            for p in model.params
        ],
        "states": [
            {"name": s.name, "shape": list(s.shape), "init": s.init}
            for s in model.states
        ],
        "wsites": [{"name": p.name, "c_out": p.c_out, "size": p.size} for p in wsites(model.params)],
    }
    if extra:
        meta.update(extra)
    return meta


def compile_model(model_name, bits_tags, ratios, out_dir, force, use_pallas):
    model = model_zoo.build(model_name)
    bs = model_zoo.BATCH_SIZES[model_name]
    mode = "kernel" if use_pallas else "ref"
    print(f"[{model_name}] batch={bs} sites={len(wsites(model.params))} "
          f"params={sum(p.size for p in model.params)}")

    # FP train (baseline pretraining / FP+1) — bits-independent
    qc_fp = QuantCfg(0, 0, mode=mode)
    fn, ins, outs = step_mod.build_train(model, qc_fp, "fp", 1.0, bs)
    write_artifact(
        out_dir,
        f"{model_name}_fp_train",
        fn,
        ins,
        outs,
        model_meta(model, bs, None, {"kind": "train", "sel_mode": "fp", "ratio": 1.0}),
        force,
    )
    # FP eval
    fn, ins, outs = step_mod.build_fwd(model, qc_fp, bs)
    write_artifact(
        out_dir,
        f"{model_name}_fp_fwd",
        fn,
        ins,
        outs,
        model_meta(model, bs, None, {"kind": "fwd", "sel_mode": "fp"}),
        force,
    )
    # calibration (FP forward + MinMax taps)
    fn, ins, outs = step_mod.build_calib(model, bs)
    write_artifact(
        out_dir,
        f"{model_name}_calib",
        fn,
        ins,
        outs,
        model_meta(model, bs, None, {"kind": "calib"}),
        force,
    )

    for tag in bits_tags:
        qc = parse_bits(tag)
        qc = QuantCfg(qc.w_bits, qc.a_bits, mode=mode)
        fn, ins, outs = step_mod.build_fwd(model, qc, bs)
        write_artifact(
            out_dir,
            f"{model_name}_{tag}_fwd",
            fn,
            ins,
            outs,
            model_meta(model, bs, qc, {"kind": "fwd"}),
            force,
        )
        for r in ratios:
            fn, ins, outs = step_mod.build_train(model, qc, "ratio", r / 100.0, bs)
            write_artifact(
                out_dir,
                f"{model_name}_{tag}_train_r{r}",
                fn,
                ins,
                outs,
                model_meta(
                    model, bs, qc,
                    {"kind": "train", "sel_mode": "ratio", "ratio": r / 100.0},
                ),
                force,
            )
        fn, ins, outs = step_mod.build_train(model, qc, "lwpn", 1.0, bs)
        write_artifact(
            out_dir,
            f"{model_name}_{tag}_train_lwpn",
            fn,
            ins,
            outs,
            model_meta(model, bs, qc, {"kind": "train", "sel_mode": "lwpn", "ratio": 1.0}),
            force,
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="resnet8,resnet20,resnet11b,bert_tiny,gpt_mini")
    ap.add_argument("--bits", default="")
    ap.add_argument("--ratios", default=",".join(str(r) for r in DEFAULT_RATIOS))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-pallas", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    ratios = [int(r) for r in args.ratios.split(",") if r != ""]
    t0 = time.time()
    for m in args.models.split(","):
        bits = args.bits.split(",") if args.bits else DEFAULT_BITS[m]
        compile_model(m, bits, ratios, args.out_dir, args.force, not args.no_pallas)
    print(f"total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
