"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: `python/tests/test_kernels.py`
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels
(interpret=True) match these references exactly (fp32 tolerance).

The quantization math follows the paper's Eq. (1)-(4):
  weights:     symmetric, per-channel (per output row), zero point = 0
  activations: asymmetric, per-tensor, zero point Z_x
"""

from __future__ import annotations

import jax.numpy as jnp


def qrange_sym(bits: int) -> tuple[int, int]:
    """Symmetric signed integer range [-(2^{b-1}-1), 2^{b-1}-1] (Eq. 3)."""
    m = 2 ** (bits - 1) - 1
    return -m, m


def qrange_asym(bits: int) -> tuple[int, int]:
    """Asymmetric unsigned range [0, 2^b - 1] (Eq. 1)."""
    return 0, 2**bits - 1


def fq_sym_perrow_ref(w: jnp.ndarray, s: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Fake-quantize weights symmetrically per output row (Eq. 3).

    w: [C_out, ...] (row = leading axis), s: [C_out].
    Returns dequantized ŵ = clip(round(w/s), qmin, qmax) * s.
    """
    qmin, qmax = qrange_sym(bits)
    s = s.reshape((w.shape[0],) + (1,) * (w.ndim - 1))
    q = jnp.clip(jnp.round(w / s), qmin, qmax)
    return q * s


def fq_asym_pertensor_ref(
    x: jnp.ndarray, s: jnp.ndarray, z: jnp.ndarray, bits: int
) -> jnp.ndarray:
    """Fake-quantize activations asymmetrically per tensor (Eq. 1).

    x̂ = (clip(round(x/s) + round(z), 0, 2^b-1) - round(z)) * s
    """
    qmin, qmax = qrange_asym(bits)
    zr = jnp.round(z)
    c = jnp.clip(jnp.round(x / s) + zr, qmin, qmax)
    return (c - zr) * s


def partial_dw_ref(dy: jnp.ndarray, x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """The paper's Fig. 1 (right) backward op for a linear layer.

    dy: [B, C_out] output gradient, x: [B, C_in] (quantized) input,
    idx: [k] int32 unfrozen row ids.  Returns dW[idx] = dy[:, idx]^T @ x,
    shape [k, C_in] — only the unfrozen rows are ever materialized.
    """
    return jnp.take(dy, idx, axis=1).T @ x


def row_abs_mean_ref(w: jnp.ndarray) -> jnp.ndarray:
    """Channel importance I_B = mean |w| over each output row (Eq. 6)."""
    return jnp.mean(jnp.abs(w.reshape(w.shape[0], -1)), axis=1)


def int8_matmul_ref(
    xq: jnp.ndarray,
    wq: jnp.ndarray,
    s_x: jnp.ndarray,
    z_x: jnp.ndarray,
    s_w: jnp.ndarray,
) -> jnp.ndarray:
    """Integer forward path: y = (xq - z_x) @ wq^T scaled back to fp32.

    xq: [B, C_in] unsigned-domain codes, wq: [C_out, C_in] signed codes,
    s_w: [C_out].  Accumulation in int32, dequantization in fp32 — this is
    what real int8 inference hardware computes; used to verify that the
    fake-quant training graph matches integer arithmetic bit-for-bit.
    """
    acc = (xq.astype(jnp.int32) - z_x.astype(jnp.int32)) @ wq.astype(jnp.int32).T
    return acc.astype(jnp.float32) * (s_x * s_w)[None, :]
