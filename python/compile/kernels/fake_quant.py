"""Pallas fake-quantization kernels (paper Eq. 1-4).

Two kernels:
  * fq_sym_perrow      — symmetric per-output-channel weight fake-quant
  * fq_asym_pertensor  — asymmetric per-tensor activation fake-quant

Both are tiled over row blocks so each grid step works on a
[ROW_BLOCK, features] tile that fits VMEM on a real TPU; on this testbed
they run via interpret=True, which lowers them to plain HLO the CPU PJRT
client can execute (Mosaic custom-calls cannot run on CPU).

TPU mapping (see DESIGN.md §2): the tile is a pure VPU elementwise job —
one HBM→VMEM stream in, one out, no MXU involvement; ROW_BLOCK is chosen
so tile_bytes = ROW_BLOCK * F * 4 ≤ 4 MiB, leaving VMEM headroom for
double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import qrange_asym, qrange_sym

# Default row tile: 8 rows keeps the tile < 4 MiB for feature dims up to
# 128k, and divides every channel count used by the bundled models.
ROW_BLOCK = 8


def _fq_sym_kernel(w_ref, s_ref, o_ref, *, qmin: int, qmax: int):
    w = w_ref[...]
    s = s_ref[...][:, None]
    q = jnp.clip(jnp.round(w / s), qmin, qmax)
    o_ref[...] = q * s


def fq_sym_perrow(w: jnp.ndarray, s: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Fake-quantize weights per output row: ŵ = clip(round(w/s))·s.

    w: [C_out, ...] any trailing shape, s: [C_out].  Rows are processed in
    ROW_BLOCK tiles; C_out is padded up to a multiple internally.
    """
    qmin, qmax = qrange_sym(bits)
    orig_shape = w.shape
    c_out = orig_shape[0]
    w2 = w.reshape(c_out, -1)
    feat = w2.shape[1]

    pad = (-c_out) % ROW_BLOCK
    if pad:
        w2 = jnp.pad(w2, ((0, pad), (0, 0)))
        s = jnp.pad(s, (0, pad), constant_values=1.0)
    rows = c_out + pad

    out = pl.pallas_call(
        functools.partial(_fq_sym_kernel, qmin=qmin, qmax=qmax),
        grid=(rows // ROW_BLOCK,),
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, feat), lambda i: (i, 0)),
            pl.BlockSpec((ROW_BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, feat), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, feat), w.dtype),
        interpret=True,
    )(w2, s)
    return out[:c_out].reshape(orig_shape)


def _fq_asym_kernel(x_ref, s_ref, z_ref, o_ref, *, qmin: int, qmax: int):
    x = x_ref[...]
    s = s_ref[0]
    zr = jnp.round(z_ref[0])
    c = jnp.clip(jnp.round(x / s) + zr, qmin, qmax)
    o_ref[...] = (c - zr) * s


def fq_asym_pertensor(
    x: jnp.ndarray, s: jnp.ndarray, z: jnp.ndarray, bits: int
) -> jnp.ndarray:
    """Fake-quantize activations per tensor (asymmetric, Eq. 1).

    x: any shape, s/z: scalars (or shape-[1] arrays).
    """
    qmin, qmax = qrange_asym(bits)
    orig_shape = x.shape
    flat = x.reshape(1, -1)
    n = flat.shape[1]
    s1 = jnp.asarray(s, jnp.float32).reshape(1)
    z1 = jnp.asarray(z, jnp.float32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_fq_asym_kernel, qmin=qmin, qmax=qmax),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), x.dtype),
        interpret=True,
    )(flat, s1, z1)
    return out.reshape(orig_shape)
