"""Pallas kernel for channel importance (paper Eq. 6).

    I_B = (1/n) Σ_{w ∈ B} |w|

where a block B is one output channel (conv) / one row (linear). The
coordinator recomputes importances every `f` samples (the paper's
freezing frequency); at the rust layer the same reduction is implemented
host-side — this kernel is the in-graph variant used by the importance
artifact and by tests to cross-check the rust implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8


def _row_abs_mean_kernel(w_ref, o_ref):
    w = w_ref[...]
    o_ref[...] = jnp.mean(jnp.abs(w), axis=1)


def row_abs_mean(w: jnp.ndarray) -> jnp.ndarray:
    """Per-row mean absolute value. w: [C_out, ...] → [C_out] f32."""
    c_out = w.shape[0]
    w2 = w.reshape(c_out, -1).astype(jnp.float32)
    feat = w2.shape[1]
    pad = (-c_out) % ROW_BLOCK
    if pad:
        w2 = jnp.pad(w2, ((0, pad), (0, 0)))
    rows = c_out + pad

    out = pl.pallas_call(
        _row_abs_mean_kernel,
        grid=(rows // ROW_BLOCK,),
        in_specs=[pl.BlockSpec((ROW_BLOCK, feat), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROW_BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=True,
    )(w2)
    return out[:c_out]
