"""Pallas kernel for the paper's core backward op (Fig. 1 right).

EfQAT only computes the weight gradient for the unfrozen output channels:

    dW[id] = dY[:, id]^T @ X̂          (linear layer, Eq. 5 restricted)

The kernel fuses the column gather of dY with the matmul so the frozen
columns of dY are never copied: each grid step loads a ROW_BLOCK-wide
slice of the *index* vector, gathers those columns of dY into a
[B, ROW_BLOCK] tile, and contracts with the full X̂ tile on the MXU.

TPU mapping (DESIGN.md §2): dY and X̂ stream HBM→VMEM once; the gathered
[B, ROW_BLOCK] tile plus an [ROW_BLOCK, C_in] accumulator live in VMEM
(< 2 MiB at BERT-base scale: B=16·seq=128 ⇒ 2048×16×4B + 16×768×4B).
The contraction is a bf16-able [ROW_BLOCK, B] × [B, C_in] MXU matmul.
On this testbed it runs via interpret=True.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of dW produced per grid step. 16 gathered columns per step keeps
# the gather loop short while the [16, B]x[B, C_in] matmul saturates the
# MXU for C_in >= 128.
ROW_BLOCK = 16


def _partial_dw_kernel(idx_ref, dy_ref, x_ref, o_ref):
    dy = dy_ref[...]  # [B, C_out]
    x = x_ref[...]  # [B, C_in]
    # Gather ROW_BLOCK columns of dY by dynamic index. The python loop
    # unrolls at trace time into ROW_BLOCK dynamic slices.
    cols = [dy[:, idx_ref[i]] for i in range(ROW_BLOCK)]
    g = jnp.stack(cols, axis=0)  # [ROW_BLOCK, B]
    o_ref[...] = g @ x  # [ROW_BLOCK, C_in]


def partial_dw(dy: jnp.ndarray, x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """dW[idx] = dy[:, idx]^T @ x, computed without materializing full dW.

    dy: [B, C_out], x: [B, C_in], idx: [k] int32 → [k, C_in].
    idx is padded internally to a multiple of ROW_BLOCK (padded rows are
    computed redundantly and sliced off; the FLOP overhead is < ROW_BLOCK
    rows).
    """
    b, c_out = dy.shape
    _, c_in = x.shape
    k = idx.shape[0]
    pad = (-k) % ROW_BLOCK
    if pad:
        idx = jnp.concatenate([idx, jnp.broadcast_to(idx[-1:], (pad,))])
    kp = k + pad

    out = pl.pallas_call(
        _partial_dw_kernel,
        grid=(kp // ROW_BLOCK,),
        in_specs=[
            pl.BlockSpec((ROW_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((b, c_out), lambda i: (0, 0)),
            pl.BlockSpec((b, c_in), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, c_in), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((kp, c_in), jnp.float32),
        interpret=True,
    )(idx.astype(jnp.int32), dy.astype(jnp.float32), x.astype(jnp.float32))
    return out[:k]
