"""Pallas integer-arithmetic forward matmul.

Computes y = ((xq - z_x) @ wq^T) * (s_x * s_w) with int32 accumulation,
i.e. exactly what an int8 MAC array evaluates at inference time. Tests
assert this matches the fake-quant fp32 training graph bit-for-bit (both
are exact in fp32 for b ≤ 8), closing the train/deploy gap.

TPU mapping: xq/wq tiles in VMEM as int8, MXU int8 mode, int32
accumulator tile, dequant on the VPU as the tile leaves.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _int8_matmul_kernel(xq_ref, wq_ref, sx_ref, zx_ref, sw_ref, o_ref):
    xq = xq_ref[...].astype(jnp.int32)
    wq = wq_ref[...].astype(jnp.int32)
    zx = zx_ref[0].astype(jnp.int32)
    acc = (xq - zx) @ wq.T
    o_ref[...] = acc.astype(jnp.float32) * (sx_ref[0] * sw_ref[...])[None, :]


def int8_matmul(
    xq: jnp.ndarray,
    wq: jnp.ndarray,
    s_x: jnp.ndarray,
    z_x: jnp.ndarray,
    s_w: jnp.ndarray,
) -> jnp.ndarray:
    """Integer matmul + dequant. xq: [B, C_in], wq: [C_out, C_in] → [B, C_out]."""
    b, c_in = xq.shape
    c_out = wq.shape[0]
    out = pl.pallas_call(
        _int8_matmul_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((b, c_in), lambda i: (0, 0)),
            pl.BlockSpec((c_out, c_in), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((c_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b, c_out), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c_out), jnp.float32),
        interpret=True,
    )(
        xq.astype(jnp.int32),
        wq.astype(jnp.int32),
        jnp.asarray(s_x, jnp.float32).reshape(1),
        jnp.asarray(z_x, jnp.float32).reshape(1),
        s_w.astype(jnp.float32),
    )
    return out
