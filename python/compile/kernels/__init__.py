"""Layer-1 Pallas kernels (interpret=True on CPU) + pure-jnp oracles."""

from .fake_quant import fq_asym_pertensor, fq_sym_perrow
from .importance import row_abs_mean
from .partial_dw import partial_dw
from .qmatmul import int8_matmul

__all__ = [
    "fq_sym_perrow",
    "fq_asym_pertensor",
    "partial_dw",
    "row_abs_mean",
    "int8_matmul",
]
