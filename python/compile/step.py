"""Step-function builders: the cross-language ABI of the system.

Each builder returns a pure jax function plus the ordered input/output
`IOSpec` lists that aot.py serializes into the artifact manifest.  The
rust coordinator packs literals in manifest order, executes the compiled
HLO, and unpacks outputs by manifest order — these lists ARE the
contract.

Step kinds:
  train  — one EfQAT/QAT/FP training step: forward + manual backward.
           Selection plumbing per weight site:
             fp     no quantization, full dW everywhere (baseline pretraining)
             ratio  r=0: no dW; 0<r<1: per-site index vector id[k];
                    r=1: full dW (the QAT baseline)
             lwpn   per-site i32 flag, lax.cond skips the dW matmul at runtime
  fwd    — evaluation forward (BN in inference mode), returns loss/metric/logits
  calib  — FP forward that records per-site activation (min,max) for PTQ
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp

from .layers import Sel
from .quantization import QuantCfg
from .specs import ParamSpec, wsites


@dataclasses.dataclass(frozen=True)
class IOSpec:
    name: str
    shape: tuple[int, ...]
    dtype: str  # 'f32' | 'i32'
    role: str
    of: Optional[str] = None  # grad/state/calib target

    def to_json(self) -> dict:
        d = {
            "name": self.name,
            "shape": list(self.shape),
            "dtype": self.dtype,
            "role": self.role,
        }
        if self.of is not None:
            d["of"] = self.of
        return d


def site_k(c_out: int, ratio: float) -> int:
    """Static gradient-slot count per site: k = max(1, ⌊r·C_out⌋) (Eq. 7/8;
    the max(1,·) keeps tiny layers trainable at r=5%, see DESIGN.md §3)."""
    if ratio >= 1.0:
        return c_out
    return max(1, int(ratio * c_out))


def _np_dtype(d):
    return jnp.float32 if d == "f32" else jnp.int32


def _param_inputs(model) -> list[IOSpec]:
    return [IOSpec(p.name, p.shape, "f32", "param") for p in model.params]


def _qparam_inputs(model) -> list[IOSpec]:
    out = []
    for p in wsites(model.params):
        out.append(IOSpec(f"sw:{p.name}", (p.c_out,), "f32", "qparam_sw", of=p.name))
        out.append(IOSpec(f"sx:{p.name}", (1,), "f32", "qparam_sx", of=p.name))
        out.append(IOSpec(f"zx:{p.name}", (1,), "f32", "qparam_zx", of=p.name))
    return out


def _state_inputs(model) -> list[IOSpec]:
    return [IOSpec(s.name, s.shape, "f32", "state") for s in model.states]


def _data_inputs(model, batch_size) -> list[IOSpec]:
    return [
        IOSpec(b.name, b.shape, b.dtype, "data") for b in model.batch_specs(batch_size)
    ]


def _unpack(args, specs_groups):
    """Split the flat positional args tuple by spec groups into dicts."""
    out = []
    i = 0
    for specs in specs_groups:
        d = {}
        for s in specs:
            d[s.name] = args[i]
            i += 1
        out.append(d)
    assert i == len(args)
    return out


def build_train(
    model, qc: QuantCfg, sel_mode: str, ratio: float, batch_size: int
) -> tuple[Callable, list[IOSpec], list[IOSpec]]:
    """sel_mode: 'fp' | 'ratio' | 'lwpn'."""
    sites = wsites(model.params)
    fp = sel_mode == "fp"
    if fp:
        qc = QuantCfg(0, 0, mode=qc.mode)

    in_params = _param_inputs(model)
    in_qp = [] if fp else _qparam_inputs(model)
    in_state = _state_inputs(model)
    in_data = _data_inputs(model, batch_size)
    in_sel: list[IOSpec] = []
    if sel_mode == "ratio" and 0.0 < ratio < 1.0:
        for p in sites:
            k = site_k(p.c_out, ratio)
            in_sel.append(IOSpec(f"id:{p.name}", (k,), "i32", "index", of=p.name))
    elif sel_mode == "lwpn":
        for p in sites:
            in_sel.append(IOSpec(f"flag:{p.name}", (1,), "i32", "flag", of=p.name))
    inputs = in_params + in_qp + in_state + in_data + in_sel

    # ---- probe the model once (abstractly at lower time) to learn which
    # grads/outputs exist; outputs are then fixed in manifest order.
    def make_sels(sel_args):
        sels = {}
        for p in sites:
            if fp or (sel_mode == "ratio" and ratio >= 1.0):
                sels[p.name] = Sel.all()
            elif sel_mode == "ratio" and ratio <= 0.0:
                sels[p.name] = Sel.none()
            elif sel_mode == "ratio":
                sels[p.name] = Sel("idx", idx=sel_args[f"id:{p.name}"])
            else:
                sels[p.name] = Sel("flag", flag=sel_args[f"flag:{p.name}"][0])
        return sels

    def run(args):
        P, Q, S, B, SA = _unpack(args, [in_params, in_qp, in_state, in_data, in_sel])
        Q = {k: (v if k.startswith("sw:") else v[0]) for k, v in Q.items()}
        loss, metrics, caches, newS = model.forward(P, Q, S, B, True, qc)
        grads = model.backward(P, Q, caches, make_sels(SA), qc)
        return loss, metrics, grads, newS

    # figure out output presence with a cheap abstract evaluation
    import jax

    probe_args = [
        jnp.zeros(s.shape, _np_dtype(s.dtype))
        if s.dtype == "f32"
        else jnp.zeros(s.shape, jnp.int32)
        for s in inputs
    ]
    # scales must be nonzero to avoid div-by-zero during probing
    probe_args = [
        jnp.ones(s.shape, jnp.float32) if s.role in ("qparam_sw", "qparam_sx") else a
        for s, a in zip(inputs, probe_args)
    ]
    probe = jax.eval_shape(lambda *a: run(a), *probe_args)
    _, _, probe_grads, probe_state = probe

    outputs: list[IOSpec] = [
        IOSpec("loss", (1,), "f32", "loss"),
        IOSpec("correct", (1,), "i32", "metric"),
    ]
    grad_order: list[str] = []
    for p in model.params:
        if p.name in probe_grads:
            outputs.append(
                IOSpec(f"d:{p.name}", tuple(probe_grads[p.name].shape), "f32", "grad", of=p.name)
            )
            grad_order.append(p.name)
    if not fp:
        for p in sites:
            for pref in ("sw:", "sx:", "zx:"):
                key = f"{pref}{p.name}"
                if key in probe_grads:
                    shp = tuple(probe_grads[key].shape) or (1,)
                    outputs.append(IOSpec(f"d:{key}", shp, "f32", "grad", of=key))
                    grad_order.append(key)
    state_order = [s.name for s in model.states]
    for s in model.states:
        outputs.append(IOSpec(f"new:{s.name}", s.shape, "f32", "state", of=s.name))

    def fn(*args):
        loss, metrics, grads, newS = run(args)
        outs = [loss.reshape(1), metrics["correct"].reshape(1).astype(jnp.int32)]
        for name in grad_order:
            g = grads[name]
            outs.append(g.reshape((1,)) if g.ndim == 0 else g)
        for name in state_order:
            outs.append(newS[name])
        return tuple(outs)

    return fn, inputs, outputs


def build_fwd(
    model, qc: QuantCfg, batch_size: int
) -> tuple[Callable, list[IOSpec], list[IOSpec]]:
    """Evaluation forward (BN inference mode). Also used for QAT-mode eval."""
    fp = not qc.enabled
    in_params = _param_inputs(model)
    in_qp = [] if fp else _qparam_inputs(model)
    in_state = _state_inputs(model)
    in_data = _data_inputs(model, batch_size)
    inputs = in_params + in_qp + in_state + in_data

    import jax

    def run(args):
        P, Q, S, B = _unpack(args, [in_params, in_qp, in_state, in_data])
        Q = {k: (v if k.startswith("sw:") else v[0]) for k, v in Q.items()}
        loss, metrics, _, _ = model.forward(P, Q, S, B, False, qc)
        return loss, metrics

    probe_args = [
        jnp.ones(s.shape, jnp.float32)
        if s.dtype == "f32"
        else jnp.zeros(s.shape, jnp.int32)
        for s in inputs
    ]
    probe_loss, probe_metrics = jax.eval_shape(lambda *a: run(a), *probe_args)
    outputs = [
        IOSpec("loss", (1,), "f32", "loss"),
        IOSpec("correct", (1,), "i32", "metric"),
        IOSpec("logits", tuple(probe_metrics["logits"].shape), "f32", "logits"),
    ]

    def fn(*args):
        loss, metrics = run(args)
        return (
            loss.reshape(1),
            metrics["correct"].reshape(1).astype(jnp.int32),
            metrics["logits"],
        )

    return fn, inputs, outputs


def build_calib(
    model, batch_size: int
) -> tuple[Callable, list[IOSpec], list[IOSpec]]:
    """FP forward recording per-site activation (min,max) — the MinMax
    observer of the paper's PTQ baseline, evaluated on the calibration set."""
    sites = wsites(model.params)
    in_params = _param_inputs(model)
    in_state = _state_inputs(model)
    in_data = [s for s in _data_inputs(model, batch_size) if s.name == "x"]
    inputs = in_params + in_state + in_data
    qc = QuantCfg(0, 0)

    label_specs = [b for b in model.batch_specs(batch_size) if b.name != "x"]

    outputs = [
        IOSpec(f"mm:{p.name}", (2,), "f32", "calib", of=p.name) for p in sites
    ]

    def fn(*args):
        P, S, B = _unpack(args, [in_params, in_state, in_data])
        for ls in label_specs:  # dummy labels, unused by the taps
            B[ls.name] = jnp.zeros(ls.shape, jnp.int32)
        mm = {}

        def tap(site, x):
            mm[site] = jnp.stack([jnp.min(x), jnp.max(x)])

        model.forward(P, {}, S, B, False, qc, tap=tap)
        return tuple(mm[p.name] for p in sites)

    return fn, inputs, outputs
