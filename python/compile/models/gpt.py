"""GPT-mini: pre-LN decoder-only LM used by the end-to-end example.

Causal transformer (GPT-2 style):
    x = x + MHA(LN(x), causal);  x = x + FFN(LN(x));  logits = head(LN(x))
Next-token cross-entropy loss over [B, T].  The output head is a
quantized, channel-freezable weight site like every other linear layer;
embeddings are fp32 (trained only in FP mode).
"""

from __future__ import annotations

import jax.numpy as jnp

from .. import layers as L
from ..quantization import QuantCfg
from ..specs import BatchSpec, ParamSpec, StateSpec
from . import transformer_common as T


class GptMini:
    def __init__(
        self,
        name: str = "gpt_mini",
        n_layers: int = 4,
        d_model: int = 256,
        n_heads: int = 4,
        d_ff: int = 1024,
        vocab: int = 512,
        seq_len: int = 128,
    ):
        self.name = name
        self.n_layers = n_layers
        self.d_model = d_model
        self.n_heads = n_heads
        self.d_ff = d_ff
        self.vocab = vocab
        self.seq_len = seq_len
        self.params, self.states = self._build_specs()

    def _build_specs(self):
        d, ff = self.d_model, self.d_ff
        params: list[ParamSpec] = [
            ParamSpec("emb.tok", (self.vocab, d), ("normal", 0.02), "embed"),
            ParamSpec("emb.pos", (self.seq_len, d), ("normal", 0.02), "embed"),
        ]
        for i in range(self.n_layers):
            pre = f"l{i}"
            params += T.ln_specs(f"{pre}.ln1", d)
            for proj in ("q", "k", "v", "o"):
                params += T.lin_specs(f"{pre}.att.{proj}", d, d)
            params += T.ln_specs(f"{pre}.ln2", d)
            params += T.lin_specs(f"{pre}.ff1", ff, d)
            params += T.lin_specs(f"{pre}.ff2", d, ff)
        params += T.ln_specs("lnf", d)
        params += T.lin_specs("head", self.vocab, d)
        return params, []

    def batch_specs(self, batch_size: int) -> list[BatchSpec]:
        return [
            BatchSpec("x", (batch_size, self.seq_len), "i32"),
            BatchSpec("y", (batch_size, self.seq_len), "i32"),
        ]

    def forward(self, P, Q, S, batch, train, qc: QuantCfg, tap=None):
        caches: dict = {}
        ctx = (P, Q, qc, caches, tap)
        ids = batch["x"]
        b, t = ids.shape

        tok, ce = L.embedding_fwd(P["emb.tok"], ids)
        caches["emb"] = ce
        h = tok + P["emb.pos"][None, :t]

        for i in range(self.n_layers):
            pre = f"l{i}"
            n1 = T.ln_fwd(ctx, f"{pre}.ln1", h)
            a = T.mha_fwd(ctx, f"{pre}.att", n1, self.n_heads, causal=True)
            h = h + a
            n2 = T.ln_fwd(ctx, f"{pre}.ln2", h)
            f1 = T.qlin_fwd(ctx, f"{pre}.ff1", n2)
            g, cg = L.gelu_fwd(f1)
            caches[f"{pre}.gelu"] = cg
            f2 = T.qlin_fwd(ctx, f"{pre}.ff2", g)
            h = h + f2

        hf = T.ln_fwd(ctx, "lnf", h)
        logits = T.qlin_fwd(ctx, "head", hf)  # [B, T, V]
        flat = logits.reshape(b * t, self.vocab)
        labels = batch["y"].reshape(b * t)
        loss, correct, cce = L.ce_loss_fwd(flat, labels)
        caches["ce"] = cce
        caches["bt"] = (b, t)
        return loss, {"correct": correct, "logits": logits}, caches, dict(S)

    def backward(self, P, Q, caches, sels, qc: QuantCfg):
        grads: dict = {}
        bctx = (P, Q, sels, qc, caches, grads)
        b, t = caches["bt"]
        dflat = L.ce_loss_bwd(caches["ce"])
        dlogits = dflat.reshape(b, t, self.vocab)

        dhf = T.qlin_bwd(bctx, "head", dlogits)
        dh = T.ln_bwd(bctx, "lnf", dhf)
        for i in reversed(range(self.n_layers)):
            pre = f"l{i}"
            df2 = T.qlin_bwd(bctx, f"{pre}.ff2", dh)
            dg = L.gelu_bwd(df2, caches[f"{pre}.gelu"])
            dn2 = T.qlin_bwd(bctx, f"{pre}.ff1", dg)
            dh = dh + T.ln_bwd(bctx, f"{pre}.ln2", dn2)
            da = T.mha_bwd(bctx, f"{pre}.att", dh)
            dh = dh + T.ln_bwd(bctx, f"{pre}.ln1", da)
        if not qc.enabled:
            grads["emb.tok"] = L.embedding_bwd(dh, caches["emb"])
            grads["emb.pos"] = jnp.sum(dh, axis=0)
        return grads
