"""CIFAR-style ResNets with manual forward/backward (He et al., 2016).

Two families, matching the paper's CNN benchmarks:

  * basic-block ResNet (resnet8 / resnet20): 3 stages of n blocks,
    widths (16, 32, 64), depth = 6n+2 — the paper's CIFAR-10 network.
  * bottleneck ResNet (resnet11b): 1x1 → 3x3 → 1x1(×4) blocks — the
    stand-in for the paper's ResNet-50/ImageNet experiment (see
    DESIGN.md §3 substitutions).

Every conv (stem, both/all block convs, and the 1x1 shortcut convs) is a
quantized weight site, as in the paper ("we quantize all convolutions
and linear layers, including the input, output, and shortcut layers").
BN and the final FC bias always train during EfQAT.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

from .. import layers as L
from ..quantization import QuantCfg
from ..specs import BatchSpec, ParamSpec, StateSpec


def _he_conv(name, c_out, c_in, k):
    fan = c_in * k * k
    return ParamSpec(name, (c_out, c_in, k, k), ("he_conv", fan), "weight")


class ResNet:
    """Manual-backprop ResNet.

    blocks: tuple of per-stage block counts; widths: per-stage output
    channels (pre-expansion); bottleneck: use 1-3-1 bottleneck blocks
    with expansion 4.
    """

    def __init__(
        self,
        name: str,
        blocks=(3, 3, 3),
        widths=(16, 32, 64),
        num_classes: int = 10,
        image_hw: int = 32,
        bottleneck: bool = False,
    ):
        self.name = name
        self.blocks = blocks
        self.widths = widths
        self.num_classes = num_classes
        self.image_hw = image_hw
        self.bottleneck = bottleneck
        self.expansion = 4 if bottleneck else 1
        self.params, self.states = self._build_specs()

    # -- specs ---------------------------------------------------------

    def _bn_specs(self, name, c):
        return (
            [
                ParamSpec(f"{name}.g", (c,), ("ones",), "norm"),
                ParamSpec(f"{name}.b", (c,), ("zeros",), "norm"),
            ],
            [
                StateSpec(f"{name}.rm", (c,), "zeros"),
                StateSpec(f"{name}.rv", (c,), "ones"),
            ],
        )

    def _build_specs(self):
        params: list[ParamSpec] = []
        states: list[StateSpec] = []
        w0 = self.widths[0]
        params.append(_he_conv("stem.conv", w0, 3, 3))
        p, s = self._bn_specs("stem.conv.bn", w0)
        params += p
        states += s

        c_in = w0
        for si, (n, w) in enumerate(zip(self.blocks, self.widths)):
            c_out = w * self.expansion
            for bi in range(n):
                pre = f"s{si}.b{bi}"
                stride = 2 if (si > 0 and bi == 0) else 1
                if self.bottleneck:
                    convs = [
                        (f"{pre}.c1", w, c_in, 1),
                        (f"{pre}.c2", w, w, 3),
                        (f"{pre}.c3", c_out, w, 1),
                    ]
                else:
                    convs = [
                        (f"{pre}.c1", w, c_in, 3),
                        (f"{pre}.c2", c_out, w, 3),
                    ]
                for cname, co, ci, k in convs:
                    params.append(_he_conv(cname, co, ci, k))
                    p, s = self._bn_specs(cname + ".bn", co)
                    params += p
                    states += s
                if stride != 1 or c_in != c_out:
                    params.append(_he_conv(f"{pre}.sc", c_out, c_in, 1))
                    p, s = self._bn_specs(f"{pre}.sc.bn", c_out)
                    params += p
                    states += s
                c_in = c_out

        params.append(
            ParamSpec("fc.w", (self.num_classes, c_in), ("he_lin", c_in), "weight")
        )
        params.append(ParamSpec("fc.b", (self.num_classes,), ("zeros",), "bias"))
        return params, states

    def batch_specs(self, batch_size: int) -> list[BatchSpec]:
        hw = self.image_hw
        return [
            BatchSpec("x", (batch_size, 3, hw, hw), "f32"),
            BatchSpec("y", (batch_size,), "i32"),
        ]

    # -- forward/backward ----------------------------------------------

    def _conv_bn_relu(
        self, ctx, name, x, stride, pad, train, relu=True
    ) -> jnp.ndarray:
        P, Q, S, qc, caches, newS, tap = ctx
        if tap:
            tap(name, x)
        if qc.enabled:
            y, cc = L.qconv_fwd(
                x,
                P[name],
                Q[f"sx:{name}"],
                Q[f"zx:{name}"],
                Q[f"sw:{name}"],
                qc,
                stride=stride,
                pad=pad,
            )
        else:
            y = L._conv(x, P[name], stride, pad)
            cc = (x, x, P[name], P[name], None, None, None, stride, pad)
        bn = name + ".bn"
        y, cb, nrm, nrv = L.bn_fwd(y, P[bn + ".g"], P[bn + ".b"], S[bn + ".rm"], S[bn + ".rv"], train=train)
        newS[bn + ".rm"], newS[bn + ".rv"] = nrm, nrv
        mask = None
        if relu:
            y, mask = L.relu_fwd(y)
        caches[name] = (cc, cb, mask)
        return y

    def _conv_bn_bwd(self, ctx, name, dy, relu=True):
        P, Q, sels, qc, caches, grads = ctx
        cc, cb, mask = caches[name]
        if relu:
            dy = L.relu_bwd(dy, mask)
        dy, dg, db = L.bn_bwd(dy, cb)
        bn = name + ".bn"
        grads[bn + ".g"], grads[bn + ".b"] = dg, db
        if qc.enabled:
            dx, g = L.qconv_bwd(dy, cc, sels[name], qc)
            if g.dw is not None:
                grads[name], grads[f"sw:{name}"] = g.dw, g.dsw
            grads[f"sx:{name}"], grads[f"zx:{name}"] = g.dsx, g.dzx
        else:
            x, xh, w, wh, _, _, _, stride, pad = cc
            dx = L._conv_dx(dy, wh, x.shape, stride, pad)
            if sels[name].kind != "none":
                grads[name] = L._conv_dw(xh, dy, w.shape[2], stride, pad)
        return dx

    def forward(self, P, Q, S, batch, train, qc: QuantCfg, tap=None):
        """Returns (loss, metrics, caches, new_state)."""
        caches: dict = {}
        newS: dict = dict(S)
        ctx = (P, Q, S, qc, caches, newS, tap)
        x = batch["x"]

        h = self._conv_bn_relu(ctx, "stem.conv", x, 1, 1, train)
        c_in = self.widths[0]
        for si, (n, w) in enumerate(zip(self.blocks, self.widths)):
            c_out = w * self.expansion
            for bi in range(n):
                pre = f"s{si}.b{bi}"
                stride = 2 if (si > 0 and bi == 0) else 1
                ident = h
                if self.bottleneck:
                    h1 = self._conv_bn_relu(ctx, f"{pre}.c1", h, 1, 0, train)
                    h2 = self._conv_bn_relu(ctx, f"{pre}.c2", h1, stride, 1, train)
                    h3 = self._conv_bn_relu(ctx, f"{pre}.c3", h2, 1, 0, train, relu=False)
                else:
                    h1 = self._conv_bn_relu(ctx, f"{pre}.c1", h, stride, 1, train)
                    h3 = self._conv_bn_relu(ctx, f"{pre}.c2", h1, 1, 1, train, relu=False)
                if stride != 1 or c_in != c_out:
                    sc = self._conv_bn_relu(ctx, f"{pre}.sc", ident, stride, 0, train, relu=False)
                else:
                    sc = ident
                    caches[f"{pre}.nosc"] = True
                h, rmask = L.relu_fwd(h3 + sc)
                caches[f"{pre}.relu"] = rmask
                c_in = c_out

        pooled, pshape = L.global_avg_pool_fwd(h)
        caches["pool"] = pshape
        if tap:
            tap("fc.w", pooled)
        if qc.enabled:
            logits, cfc = L.qlinear_fwd(
                pooled, P["fc.w"], P["fc.b"], Q["sx:fc.w"], Q["zx:fc.w"], Q["sw:fc.w"], qc
            )
        else:
            logits = pooled @ P["fc.w"].T + P["fc.b"][None, :]
            cfc = (pooled, pooled)
        caches["fc"] = cfc
        loss, correct, cce = L.ce_loss_fwd(logits, batch["y"])
        caches["ce"] = cce
        return loss, {"correct": correct, "logits": logits}, caches, newS

    def backward(self, P, Q, caches, sels, qc: QuantCfg):
        grads: dict = {}
        ctx = (P, Q, sels, qc, caches, grads)
        dlogits = L.ce_loss_bwd(caches["ce"])

        if qc.enabled:
            dpool, g = L.qlinear_bwd(dlogits, caches["fc"], sels["fc.w"], qc)
            if g.dw is not None:
                grads["fc.w"], grads["sw:fc.w"] = g.dw, g.dsw
            grads["fc.b"] = g.db
            grads["sx:fc.w"], grads["zx:fc.w"] = g.dsx, g.dzx
        else:
            pooled, _ = caches["fc"]
            dpool = dlogits @ P["fc.w"]
            if sels["fc.w"].kind != "none":
                grads["fc.w"] = dlogits.T @ pooled
            grads["fc.b"] = jnp.sum(dlogits, axis=0)

        dh = L.global_avg_pool_bwd(dpool, caches["pool"])

        c_outs = []
        c_in = self.widths[0]
        plan = []
        for si, (n, w) in enumerate(zip(self.blocks, self.widths)):
            c_out = w * self.expansion
            for bi in range(n):
                stride = 2 if (si > 0 and bi == 0) else 1
                plan.append((si, bi, stride, c_in, c_out))
                c_in = c_out

        for si, bi, stride, ci, co in reversed(plan):
            pre = f"s{si}.b{bi}"
            dh = L.relu_bwd(dh, caches[f"{pre}.relu"])
            if f"{pre}.nosc" in caches:
                dident = dh
            else:
                dident = self._conv_bn_bwd(ctx, f"{pre}.sc", dh, relu=False)
            if self.bottleneck:
                d3 = self._conv_bn_bwd(ctx, f"{pre}.c3", dh, relu=False)
                d2 = self._conv_bn_bwd(ctx, f"{pre}.c2", d3)
                dmain = self._conv_bn_bwd(ctx, f"{pre}.c1", d2)
            else:
                d2 = self._conv_bn_bwd(ctx, f"{pre}.c2", dh, relu=False)
                dmain = self._conv_bn_bwd(ctx, f"{pre}.c1", d2)
            dh = dmain + dident

        self._conv_bn_bwd(ctx, "stem.conv", dh)
        return grads
