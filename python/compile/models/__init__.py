"""Model registry.

Maps the paper's benchmarks to this testbed (DESIGN.md §3):
  resnet20   — CIFAR-10 / ResNet-20 (faithful architecture)
  resnet8    — quickstart-scale variant of the same family
  resnet11b  — bottleneck net on a 100-class task (ResNet-50/ImageNet stand-in)
  bert_tiny  — span-QA encoder (BERT-base/SQuAD stand-in)
  gpt_mini   — decoder LM for the end-to-end example
"""

from __future__ import annotations

from .bert import BertTiny
from .gpt import GptMini
from .resnet import ResNet

# Per-model training batch size baked into the AOT artifacts.
BATCH_SIZES = {
    "resnet8": 32,
    "resnet20": 32,
    "resnet11b": 16,
    "bert_tiny": 16,
    "gpt_mini": 8,
}


def build(name: str):
    if name == "resnet8":
        return ResNet("resnet8", blocks=(1, 1, 1))
    if name == "resnet20":
        return ResNet("resnet20", blocks=(3, 3, 3))
    if name == "resnet11b":
        return ResNet(
            "resnet11b",
            blocks=(1, 1, 1),
            widths=(32, 64, 128),
            num_classes=100,
            bottleneck=True,
        )
    if name == "bert_tiny":
        return BertTiny()
    if name == "gpt_mini":
        return GptMini()
    raise KeyError(f"unknown model {name!r}")


ALL_MODELS = ["resnet8", "resnet20", "resnet11b", "bert_tiny", "gpt_mini"]
