"""BERT-tiny encoder for span-extraction QA (the paper's SQuAD stand-in).

Post-LN encoder (Devlin et al.): per layer
    h = LN(x + MHA(x));  y = LN(h + FFN(h))
with a 2-output QA head producing start/end logits.  Loss is the mean of
start- and end-position cross-entropy, exactly the SQuAD v1.1 training
objective; the rust coordinator computes token-overlap F1 from the
logits (paper's metric).

Embeddings (token + position) are fp32 and receive gradients only in FP
mode — the paper does not update them during EfQAT.
"""

from __future__ import annotations

import jax.numpy as jnp

from .. import layers as L
from ..quantization import QuantCfg
from ..specs import BatchSpec, ParamSpec, StateSpec
from . import transformer_common as T


class BertTiny:
    def __init__(
        self,
        name: str = "bert_tiny",
        n_layers: int = 4,
        d_model: int = 128,
        n_heads: int = 4,
        d_ff: int = 512,
        vocab: int = 1024,
        seq_len: int = 64,
    ):
        self.name = name
        self.n_layers = n_layers
        self.d_model = d_model
        self.n_heads = n_heads
        self.d_ff = d_ff
        self.vocab = vocab
        self.seq_len = seq_len
        self.params, self.states = self._build_specs()

    def _build_specs(self):
        d, ff = self.d_model, self.d_ff
        params: list[ParamSpec] = [
            ParamSpec("emb.tok", (self.vocab, d), ("normal", 0.02), "embed"),
            ParamSpec("emb.pos", (self.seq_len, d), ("normal", 0.02), "embed"),
        ]
        params += T.ln_specs("emb.ln", d)
        for i in range(self.n_layers):
            pre = f"l{i}"
            for proj in ("q", "k", "v", "o"):
                params += T.lin_specs(f"{pre}.att.{proj}", d, d)
            params += T.ln_specs(f"{pre}.ln1", d)
            params += T.lin_specs(f"{pre}.ff1", ff, d)
            params += T.lin_specs(f"{pre}.ff2", d, ff)
            params += T.ln_specs(f"{pre}.ln2", d)
        params += T.lin_specs("qa", 2, d)
        return params, []

    def batch_specs(self, batch_size: int) -> list[BatchSpec]:
        return [
            BatchSpec("x", (batch_size, self.seq_len), "i32"),
            BatchSpec("y_start", (batch_size,), "i32"),
            BatchSpec("y_end", (batch_size,), "i32"),
        ]

    def forward(self, P, Q, S, batch, train, qc: QuantCfg, tap=None):
        caches: dict = {}
        ctx = (P, Q, qc, caches, tap)
        ids = batch["x"]
        b, t = ids.shape

        tok, ce = L.embedding_fwd(P["emb.tok"], ids)
        caches["emb"] = ce
        h = tok + P["emb.pos"][None, :t]
        h = T.ln_fwd(ctx, "emb.ln", h)

        for i in range(self.n_layers):
            pre = f"l{i}"
            a = T.mha_fwd(ctx, f"{pre}.att", h, self.n_heads, causal=False)
            h = T.ln_fwd(ctx, f"{pre}.ln1", h + a)
            f1 = T.qlin_fwd(ctx, f"{pre}.ff1", h)
            g, cg = L.gelu_fwd(f1)
            caches[f"{pre}.gelu"] = cg
            f2 = T.qlin_fwd(ctx, f"{pre}.ff2", g)
            h = T.ln_fwd(ctx, f"{pre}.ln2", h + f2)

        logits = T.qlin_fwd(ctx, "qa", h)  # [B, T, 2]
        start_logits = logits[:, :, 0]
        end_logits = logits[:, :, 1]
        loss_s, corr_s, cs = L.ce_loss_fwd(start_logits, batch["y_start"])
        loss_e, corr_e, cend = L.ce_loss_fwd(end_logits, batch["y_end"])
        caches["ce"] = (cs, cend)
        loss = 0.5 * (loss_s + loss_e)
        em = jnp.sum(
            (jnp.argmax(start_logits, -1) == batch["y_start"])
            & (jnp.argmax(end_logits, -1) == batch["y_end"])
        ).astype(jnp.int32)
        return loss, {"correct": em, "logits": logits}, caches, dict(S)

    def backward(self, P, Q, caches, sels, qc: QuantCfg):
        grads: dict = {}
        bctx = (P, Q, sels, qc, caches, grads)
        cs, cend = caches["ce"]
        dls = L.ce_loss_bwd(cs, scale=0.5)
        dle = L.ce_loss_bwd(cend, scale=0.5)
        dlogits = jnp.stack([dls, dle], axis=-1)  # [B, T, 2]

        dh = T.qlin_bwd(bctx, "qa", dlogits)
        for i in reversed(range(self.n_layers)):
            pre = f"l{i}"
            dh = T.ln_bwd(bctx, f"{pre}.ln2", dh)
            df2 = T.qlin_bwd(bctx, f"{pre}.ff2", dh)
            dg = L.gelu_bwd(df2, caches[f"{pre}.gelu"])
            dh = dh + T.qlin_bwd(bctx, f"{pre}.ff1", dg)
            dh = T.ln_bwd(bctx, f"{pre}.ln1", dh)
            da = T.mha_bwd(bctx, f"{pre}.att", dh)
            dh = dh + da
        dh = T.ln_bwd(bctx, "emb.ln", dh)
        if not qc.enabled:  # FP pretraining also trains the embeddings
            grads["emb.tok"] = L.embedding_bwd(dh, caches["emb"])
            grads["emb.pos"] = jnp.sum(dh, axis=0)
        return grads
