"""Shared manual-backprop transformer pieces (BERT-tiny / GPT-mini).

All linear projections (Q, K, V, O, FFN up/down, heads) are quantized
weight sites — matching the paper's BERT setup where every linear layer
is quantized and channel-freezable, while embeddings stay fp32 and are
not updated during EfQAT.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from .. import layers as L
from ..quantization import QuantCfg
from ..specs import ParamSpec, StateSpec


def lin_specs(name: str, d_out: int, d_in: int) -> list[ParamSpec]:
    return [
        ParamSpec(f"{name}.w", (d_out, d_in), ("he_lin", d_in), "weight"),
        ParamSpec(f"{name}.b", (d_out,), ("zeros",), "bias"),
    ]


def ln_specs(name: str, d: int) -> list[ParamSpec]:
    return [
        ParamSpec(f"{name}.g", (d,), ("ones",), "norm"),
        ParamSpec(f"{name}.b", (d,), ("zeros",), "norm"),
    ]


# ctx = (P, Q, qc, caches, tap)  for fwd
# bctx = (P, Q, sels, qc, caches, grads)  for bwd


def qlin_fwd(ctx, name, x):
    P, Q, qc, caches, tap = ctx
    w, b = P[f"{name}.w"], P[f"{name}.b"]
    if tap:
        tap(f"{name}.w", x)
    if qc.enabled:
        y, cc = L.qlinear_fwd(
            x, w, b, Q[f"sx:{name}.w"], Q[f"zx:{name}.w"], Q[f"sw:{name}.w"], qc
        )
    else:
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        y = (x2 @ w.T + b[None, :]).reshape(lead + (w.shape[0],))
        cc = (x2, x2, w, w, None, None, None, True, lead)
    caches[name] = cc
    return y


def qlin_bwd(bctx, name, dy):
    P, Q, sels, qc, caches, grads = bctx
    cc = caches[name]
    pname = f"{name}.w"
    if qc.enabled:
        dx, g = L.qlinear_bwd(dy, cc, sels[pname], qc)
        if g.dw is not None:
            grads[pname], grads[f"sw:{pname}"] = g.dw, g.dsw
        grads[f"{name}.b"] = g.db
        grads[f"sx:{pname}"], grads[f"zx:{pname}"] = g.dsx, g.dzx
    else:
        x2, xh, w, wh, _, _, _, _, lead = cc
        dy2 = dy.reshape(-1, dy.shape[-1])
        dx = (dy2 @ w).reshape(lead + (x2.shape[-1],))
        if sels[pname].kind != "none":
            grads[pname] = dy2.T @ x2
        grads[f"{name}.b"] = jnp.sum(dy2, axis=0)
    return dx


def ln_fwd(ctx, name, x):
    P, Q, qc, caches, tap = ctx
    y, c = L.ln_fwd(x, P[f"{name}.g"], P[f"{name}.b"])
    caches[name] = c
    return y


def ln_bwd(bctx, name, dy):
    P, Q, sels, qc, caches, grads = bctx
    dx, dg, db = L.ln_bwd(dy, caches[name])
    grads[f"{name}.g"], grads[f"{name}.b"] = dg, db
    return dx


def mha_fwd(ctx, name, x, n_heads: int, causal: bool):
    """Multi-head self-attention.  x: [B, T, D]."""
    P, Q, qc, caches, tap = ctx
    b, t, d = x.shape
    dh = d // n_heads
    alpha = 1.0 / math.sqrt(dh)

    q = qlin_fwd(ctx, f"{name}.q", x)
    k = qlin_fwd(ctx, f"{name}.k", x)
    v = qlin_fwd(ctx, f"{name}.v", x)

    def split(a):  # [B,T,D] -> [B,H,T,dh]
        return a.reshape(b, t, n_heads, dh).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    s = jnp.einsum("bhtd,bhsd->bhts", qh, kh) * alpha
    if causal:
        mask = jnp.tril(jnp.ones((t, t), bool))
        s = jnp.where(mask[None, None], s, -1e9)
    p, _ = L.softmax_fwd(s)
    o = jnp.einsum("bhts,bhsd->bhtd", p, vh)  # [B,H,T,dh]
    om = o.transpose(0, 2, 1, 3).reshape(b, t, d)
    out = qlin_fwd(ctx, f"{name}.o", om)
    caches[f"{name}.attn"] = (qh, kh, vh, p, alpha, (b, t, d, n_heads, dh))
    return out


def mha_bwd(bctx, name, dout):
    P, Q, sels, qc, caches, grads = bctx
    qh, kh, vh, p, alpha, (b, t, d, n_heads, dh) = caches[f"{name}.attn"]

    dom = qlin_bwd(bctx, f"{name}.o", dout)
    do = dom.reshape(b, t, n_heads, dh).transpose(0, 2, 1, 3)
    dp = jnp.einsum("bhtd,bhsd->bhts", do, vh)
    dv = jnp.einsum("bhts,bhtd->bhsd", p, do)
    ds = L.softmax_bwd(dp, p) * alpha
    dq = jnp.einsum("bhts,bhsd->bhtd", ds, kh)
    dk = jnp.einsum("bhts,bhtd->bhsd", ds, qh)

    def merge(a):  # [B,H,T,dh] -> [B,T,D]
        return a.transpose(0, 2, 1, 3).reshape(b, t, d)

    dx = qlin_bwd(bctx, f"{name}.q", merge(dq))
    dx = dx + qlin_bwd(bctx, f"{name}.k", merge(dk))
    dx = dx + qlin_bwd(bctx, f"{name}.v", merge(dv))
    return dx
