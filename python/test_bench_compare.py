"""Unit tests for bench_compare.py's exit contract (stdlib only).

Run from the repo root:

  python3 -m unittest discover -s python -p "test_*.py"

The contract under test (see bench_compare.py's docstring): exit 0 when
no classified metric regressed beyond the threshold, 1 when one did,
and 2 for usage errors, unparseable input, or documents with no
comparable metrics — including documents whose root is a bare scalar
and documents that are missing a whole top-level section, neither of
which may crash.
"""

import io
import json
import os
import tempfile
import unittest
from contextlib import redirect_stderr, redirect_stdout

import bench_compare


class BenchCompareExitContract(unittest.TestCase):
    def setUp(self):
        self._tmp = tempfile.TemporaryDirectory(prefix="bench_compare_test_")
        self.addCleanup(self._tmp.cleanup)

    def _write(self, name, doc):
        path = os.path.join(self._tmp.name, name)
        with open(path, "w") as f:
            json.dump(doc, f)
        return path

    def _run(self, *argv):
        out, err = io.StringIO(), io.StringIO()
        with redirect_stdout(out), redirect_stderr(err):
            code = bench_compare.main(["bench_compare.py", *argv])
        return code, out.getvalue(), err.getvalue()

    def test_self_diff_exits_zero(self):
        doc = {"bench": "t", "fwd_ms": 1.25, "grid": [{"p95_ms": 3.0}]}
        path = self._write("base.json", doc)
        code, out, _ = self._run(path, path)
        self.assertEqual(code, 0)
        self.assertIn("no regression", out)

    def test_regression_exits_one(self):
        base = self._write("base.json", {"fwd_ms": 1.0})
        cand = self._write("cand.json", {"fwd_ms": 2.0})
        code, out, _ = self._run(base, cand)
        self.assertEqual(code, 1)
        self.assertIn("fwd_ms", out)

    def test_improvement_and_within_threshold_exit_zero(self):
        base = self._write("base.json", {"fwd_ms": 1.0, "tput_per_s": 100.0})
        cand = self._write("cand.json", {"fwd_ms": 1.05, "tput_per_s": 140.0})
        code, _, _ = self._run(base, cand, "--threshold", "15")
        self.assertEqual(code, 0)

    def test_lower_is_worse_direction(self):
        base = self._write("base.json", {"tput_per_s": 100.0})
        cand = self._write("cand.json", {"tput_per_s": 50.0})
        code, _, _ = self._run(base, cand)
        self.assertEqual(code, 1)

    def test_missing_whole_section_exits_two(self):
        # candidate lacks the only top-level section the base has metrics
        # under: zero comparable metrics must be reported, not a crash
        base = self._write("base.json", {"two_model": {"mlp": {"p95_ms": 3.0}}})
        cand = self._write("cand.json", {"swap": {"swap_latency_ms": 1.0}})
        code, _, err = self._run(base, cand)
        self.assertEqual(code, 2)
        self.assertIn("no comparable metrics", err)

    def test_scalar_root_documents_exit_two(self):
        # regression guard: a bare numeric root produces a leaf with an
        # empty path, which used to IndexError inside classify(path[-1])
        base = self._write("base.json", 42.0)
        cand = self._write("cand.json", 42.0)
        code, _, err = self._run(base, cand)
        self.assertEqual(code, 2)
        self.assertIn("no comparable metrics", err)

    def test_unclassified_keys_only_exits_two(self):
        doc = {"bench": "t", "iters": 3, "label": "x"}
        path = self._write("base.json", doc)
        code, _, _ = self._run(path, path)
        self.assertEqual(code, 2)

    def test_parse_error_exits_two(self):
        bad = os.path.join(self._tmp.name, "bad.json")
        with open(bad, "w") as f:
            f.write("{not json")
        good = self._write("good.json", {"fwd_ms": 1.0})
        self.assertEqual(self._run(bad, good)[0], 2)
        self.assertEqual(self._run(good, os.path.join(self._tmp.name, "absent.json"))[0], 2)

    def test_usage_errors_exit_two(self):
        path = self._write("base.json", {"fwd_ms": 1.0})
        self.assertEqual(self._run(path)[0], 2)
        self.assertEqual(self._run(path, path, "--bogus")[0], 2)
        self.assertEqual(self._run(path, path, "--threshold", "nope")[0], 2)

    def test_classify_directions(self):
        self.assertEqual(bench_compare.classify("p95_ms"), "up")
        self.assertEqual(bench_compare.classify("queue_p95_us"), "up")
        self.assertEqual(bench_compare.classify("bytes_per_step"), "up")
        self.assertEqual(bench_compare.classify("tput_per_s"), "down")
        self.assertEqual(bench_compare.classify("speedup_vs_float"), "down")
        # table5's dispatch/truncation axes
        self.assertEqual(bench_compare.classify("scalar_bwd_ms"), "up")
        self.assertEqual(bench_compare.classify("lwpn_r25_trunc_on_ms"), "up")
        self.assertEqual(bench_compare.classify("dispatch_speedup"), "down")
        self.assertEqual(bench_compare.classify("bwd_layers_skipped"), "down")
        self.assertIsNone(bench_compare.classify("iters"))
        self.assertIsNone(bench_compare.classify("bench"))

    def test_truncation_depth_shrinking_is_a_regression(self):
        base = self._write("base.json", {"mlp": {"bwd_layers_skipped": 2}})
        cand = self._write("cand.json", {"mlp": {"bwd_layers_skipped": 1}})
        code, out, _ = self._run(base, cand)
        self.assertEqual(code, 1)
        self.assertIn("bwd_layers_skipped", out)


if __name__ == "__main__":
    unittest.main()
