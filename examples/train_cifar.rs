//! Full CIFAR-style pipeline on resnet20 — reproduces one Table 4 cell.
//!
//!   cargo run --release --example train_cifar -- \
//!       --bits w4a8 --mode cwpn --ratio 25 --train.freq 4096
//!
//! Accepts every config key the `efqat` CLI accepts.

use std::collections::BTreeMap;

use efqat::cfg::Config;
use efqat::cli::Args;
use efqat::coordinator::pipeline::{ensure_fp_checkpoint, run_efqat_pipeline};
use efqat::coordinator::Session;
use efqat::error::Result;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = Config::empty();
    if !argv.is_empty() {
        let mut padded = vec!["run".to_string()];
        padded.extend(argv);
        let args = Args::parse(&padded)?;
        let over: BTreeMap<String, String> = args.options;
        cfg.override_with(&over);
    }
    let model = cfg.str("model", "resnet20");
    let bits = cfg.str("bits", "w4a8");
    let mode = cfg.str("mode", "cwpn");
    let ratio = cfg.usize("ratio", 25);

    // resnet models need the PJRT artifacts: `make artifacts`, then
    // `--backend pjrt`; `--model convnet` runs the same pipeline on the
    // native conv graph with no artifacts at all
    let session = Session::from_cfg(&cfg)?;
    ensure_fp_checkpoint(&session, &cfg, &model, cfg.usize("train.epochs", 6))?;
    let summary = run_efqat_pipeline(&session, &cfg, &model, &bits, &mode, ratio)?;
    println!("{}", summary.render());
    Ok(())
}
