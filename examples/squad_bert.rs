//! Span-extraction QA with bert_tiny — the paper's SQuAD/BERT experiment.
//!
//!   cargo run --release --example squad_bert -- [--bits w8a8] [--ratio 25]
//!
//! Fine-tunes the FP encoder on synthetic span QA, quantizes with PTQ,
//! then runs EfQAT modes and reports F1 (exactly Table 4's BERT block at
//! repro scale).  Embeddings stay frozen during EfQAT, as in the paper.

use efqat::cfg::Config;
use efqat::coordinator::pipeline::{ensure_fp_checkpoint, run_efqat_pipeline};
use efqat::coordinator::Session;
use efqat::error::Result;
use efqat::harness::Table;

fn main() -> Result<()> {
    let mut cfg = Config::empty();
    cfg.set("train.lr_w", "0.003");
    cfg.set("train.lr_q", "1e-6");
    for c in std::env::args().skip(1).collect::<Vec<_>>().chunks(2) {
        if let (Some(k), Some(v)) = (c[0].strip_prefix("--"), c.get(1)) {
            cfg.set(k, v);
        }
    }
    let bits = cfg.str("bits", "w8a8");
    let ratio = cfg.usize("ratio", 25);

    // bert_tiny needs the PJRT artifacts: `make artifacts`, then
    // `--backend pjrt`
    let session = Session::from_cfg(&cfg)?;
    ensure_fp_checkpoint(&session, &cfg, "bert_tiny", cfg.usize("train.epochs", 4))?;

    let mut t = Table::new(
        &format!("bert_tiny {bits} span-QA (F1, cf. paper Table 4)"),
        &["scheme", "F1", "step exec s"],
    );
    let mut qat_exec = 0f64;
    for mode in ["qat", "r0", "cwpl", "cwpn", "lwpn"] {
        let s = run_efqat_pipeline(&session, &cfg, "bert_tiny", &bits, mode, ratio)?;
        if mode == "qat" {
            qat_exec = s.exec_seconds;
            t.row(&["PTQ".into(), format!("{:.2}", s.ptq_headline), "-".into()]);
        }
        let label = match mode {
            "qat" => "QAT (100%)".to_string(),
            "r0" => "EfQAT 0% (qparams only)".to_string(),
            m => format!("EfQAT-{} {ratio}%", m.to_uppercase()),
        };
        t.row(&[label, format!("{:.2}", s.efqat_headline), format!("{:.2}", s.exec_seconds)]);
    }
    t.print();
    println!("(QAT exec {qat_exec:.2}s — EfQAT rows above show the backward saving)");
    Ok(())
}
