//! Quickstart: the whole EfQAT story on the native CPU backend in seconds.
//!
//!   cargo run --release --example quickstart
//!
//! No Python, no artifacts, no GPUs — the native layer-graph executor
//! (rust/src/graph.rs + rust/src/ops/) runs the `mlp` model end-to-end:
//!
//! 1. pretrains a small FP checkpoint (paper's "FP")
//! 2. PTQ-quantizes it with MinMax calibration (paper's "PTQ")
//! 3. runs one EfQAT-CWPL epoch updating 25% of channels
//! 4. compares against the QAT upper bound (100% updates)
//!
//! `--model convnet` (conv→relu→pool→fc) and `--model tiny_tf`
//! (embed→attention→MLP block) run the CNN / transformer graphs natively
//! too; the paper-scale resnet/bert/gpt models need the PJRT artifacts
//! (`make artifacts`, then `--backend pjrt --model resnet8`).

use efqat::cfg::Config;
use efqat::coordinator::pipeline::{ensure_fp_checkpoint, run_efqat_pipeline};
use efqat::coordinator::Session;
use efqat::error::Result;
use efqat::harness::Table;

fn main() -> Result<()> {
    let mut cfg = Config::empty();
    cfg.set("data.train_n", "1024");
    cfg.set("data.test_n", "512");
    cfg.set("train.lr_w", "0.02");
    cfg.set("train.epochs", "4");
    cfg.set("ckpt_dir", "ckpts");
    for (k, v) in std::env::args().skip(1).collect::<Vec<_>>().chunks(2).filter_map(|c| {
        c[0].strip_prefix("--").zip(c.get(1))
    }) {
        cfg.set(k, v);
    }
    let model = cfg.str("model", "mlp");

    let session = Session::from_cfg(&cfg)?;
    ensure_fp_checkpoint(&session, &cfg, &model, cfg.usize("train.epochs", 4))?;

    let efqat = run_efqat_pipeline(&session, &cfg, &model, "w8a8", "cwpl", 25)?;
    println!("{}\n", efqat.render());
    let qat = run_efqat_pipeline(&session, &cfg, &model, "w8a8", "qat", 100)?;

    let mut t = Table::new(
        &format!("EfQAT quickstart — {model}, W8A8 (cf. paper Table 1)"),
        &["scheme", "accuracy %", "step exec s", "speedup vs QAT"],
    );
    t.row(&[
        "PTQ".into(),
        format!("{:.2}", efqat.ptq_headline),
        "0.00".into(),
        "∞".into(),
    ]);
    t.row(&[
        "EfQAT-CWPL 25%".into(),
        format!("{:.2}", efqat.efqat_headline),
        format!("{:.2}", efqat.exec_seconds),
        format!("{:.2}x", qat.exec_seconds / efqat.exec_seconds.max(1e-9)),
    ]);
    t.row(&[
        "QAT".into(),
        format!("{:.2}", qat.efqat_headline),
        format!("{:.2}", qat.exec_seconds),
        "1.00x".into(),
    ]);
    t.print();
    Ok(())
}
