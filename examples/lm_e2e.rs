//! End-to-end driver: train a GPT-mini LM, quantize it, EfQAT it.
//!
//!   cargo run --release --example lm_e2e -- [--steps 300] [--ratio 25]
//!
//! This is the repository's full-system validation (EXPERIMENTS.md §E2E):
//!   1. pretrains gpt_mini (~3.5M params, decoder-only) on a generated
//!      Markov corpus for a few hundred steps, logging the loss curve to
//!      bench_out/lm_e2e_loss.csv
//!   2. PTQ-quantizes to W8A8 and measures perplexity
//!   3. runs an EfQAT-CWPN epoch at the requested ratio and compares
//!      perplexity + backward time against the QAT artifact
//! proving all three layers (rust coordinator, JAX graph, Pallas kernels)
//! compose on a real training workload.

use efqat::cfg::Config;
use efqat::coordinator::pipeline::{
    fp_ckpt_path, load_fp_checkpoint, parse_bits, run_efqat_pipeline, train_cfg,
};
use efqat::error::Result;
use efqat::coordinator::tasks::build_task;
use efqat::coordinator::trainer::pretrain_fp;
use efqat::coordinator::{evaluate, Session};
use efqat::harness::{sparkline, Table};
use efqat::model::{save_checkpoint, ParamStore, StateStore};

fn main() -> Result<()> {
    let mut cfg = Config::empty();
    cfg.set("train.lr_w", "0.003");
    cfg.set("train.lr_q", "1e-6");
    cfg.set("data.train_tokens", "300000");
    for c in std::env::args().skip(1).collect::<Vec<_>>().chunks(2) {
        if let (Some(k), Some(v)) = (c[0].strip_prefix("--"), c.get(1)) {
            cfg.set(k, v);
        }
    }
    let max_steps = cfg.usize("steps", 300);
    let ratio = cfg.usize("ratio", 25);
    let bits = cfg.str("bits", "w8a8");

    // gpt_mini has no native reference implementation — build the AOT
    // artifacts with `make artifacts` and pass `--backend pjrt`
    let session = Session::from_cfg(&cfg)?;

    // ---- 1. FP pretraining with loss-curve logging -----------------------
    let step = session.steps.get("gpt_mini_fp_train")?;
    let bs = step.manifest.batch_size;
    let mut task = build_task("gpt_mini", bs, &cfg)?;
    println!(
        "[e2e] gpt_mini: {} params, batch {bs}, seq {}, {} steps",
        step.manifest.params.iter().map(|p| p.shape.iter().product::<usize>()).sum::<usize>(),
        cfg.usize("data.seq_len", 128),
        max_steps
    );

    let fp_path = fp_ckpt_path(&cfg, "gpt_mini");
    if !fp_path.exists() {
        let mut params = ParamStore::init(&step.manifest, 0);
        let mut states = StateStore::init(&step.manifest);
        let tcfg = train_cfg(&cfg, "gpt_mini");
        // run whole epochs until the step budget is covered
        let steps_per_epoch = task.train.n_batches();
        let epochs = max_steps.div_ceil(steps_per_epoch.max(1)).max(1);
        let t0 = std::time::Instant::now();
        let log2 = pretrain_fp(&step, &mut params, &mut states, &mut task.train, epochs, &tcfg)?;
        let dt = t0.elapsed();
        let losses = log2.losses();
        println!(
            "[e2e] pretrain: {} steps in {:.1}s ({:.2} s/step)\n      loss {:.3} -> {:.3}  {}",
            losses.len(),
            dt.as_secs_f64(),
            dt.as_secs_f64() / losses.len().max(1) as f64,
            losses.first().copied().unwrap_or(0.0),
            log2.mean_loss_tail(10),
            sparkline(&losses, 60)
        );
        log2.write_csv(std::path::Path::new("bench_out/lm_e2e_loss.csv"))?;
        save_checkpoint(&fp_path, &[("params", &params.map), ("states", &states.map)])?;
    }

    // FP perplexity
    let (params, states) = load_fp_checkpoint(&cfg, "gpt_mini")?;
    let fwd_fp = session.steps.get("gpt_mini_fp_fwd")?;
    let fp_eval = evaluate(&fwd_fp, &params, None, &states, &mut task.test)?;
    println!("[e2e] FP perplexity {:.2} (loss {:.3})", fp_eval.perplexity(), fp_eval.loss);

    // ---- 2+3. PTQ → EfQAT vs QAT -----------------------------------------
    parse_bits(&bits)?;
    let efq = run_efqat_pipeline(&session, &cfg, "gpt_mini", &bits, "cwpn", ratio)?;
    let qat = run_efqat_pipeline(&session, &cfg, "gpt_mini", &bits, "qat", 100)?;

    let mut t = Table::new(
        &format!("gpt_mini {bits} end-to-end (token-acc %, backward time)"),
        &["scheme", "token acc %", "step exec s", "speedup"],
    );
    t.row(&["PTQ".into(), format!("{:.2}", efq.ptq_headline), "-".into(), "-".into()]);
    t.row(&[
        format!("EfQAT-CWPN {ratio}%"),
        format!("{:.2}", efq.efqat_headline),
        format!("{:.2}", efq.exec_seconds),
        format!("{:.2}x", qat.exec_seconds / efq.exec_seconds.max(1e-9)),
    ]);
    t.row(&[
        "QAT".into(),
        format!("{:.2}", qat.efqat_headline),
        format!("{:.2}", qat.exec_seconds),
        "1.00x".into(),
    ]);
    t.print();
    t.write_csv(std::path::Path::new("bench_out/lm_e2e.csv"))?;
    Ok(())
}
