//! Paper Table 6 + Figure 4: freezing frequency f vs accuracy (EfQAT-CWPN).
//!
//!   cargo bench --bench table6_freeze_freq [-- --model resnet20 --bits w8a8]
//!
//! Sweeps the importance-refresh interval f (in samples).  The paper's
//! claim: accuracy is flat in f, so the refresh cost amortizes freely.

mod common;

use efqat::coordinator::pipeline::{ensure_fp_checkpoint, run_efqat_pipeline};
use efqat::harness::Table;

fn main() {
    let cfg = common::bench_config();
    let session = common::session(&cfg);
    let quick = common::is_quick(&cfg);
    let model = cfg.str("model", "resnet20");
    let bits = cfg.str("bits", "w8a8");
    let ratio = cfg.usize("ratio", 25);
    let all_freqs: &[&str] = &["16", "128", "1024", "4096", "16384"];
    let freqs: Vec<usize> = cfg
        .list("freqs", if quick { &["128", "16384"] } else { all_freqs })
        .iter()
        .map(|s| s.parse().unwrap())
        .collect();

    ensure_fp_checkpoint(&session, &cfg, &model, cfg.usize("train.epochs", 5)).unwrap();

    let mut t = Table::new(
        &format!("Table 6 / Fig 4: freezing frequency, {model} {bits} CWPN {ratio}%"),
        &["freq f (samples)", "headline", "freeze overhead s"],
    );
    for f in freqs {
        let mut c = cfg.clone();
        c.set("train.freq", &f.to_string());
        let s = run_efqat_pipeline(&session, &c, &model, &bits, "cwpn", ratio).unwrap();
        t.row(&[
            f.to_string(),
            format!("{:.2}", s.efqat_headline),
            format!("{:.3}", s.overhead_seconds),
        ]);
    }
    t.print();
    t.write_csv(std::path::Path::new("bench_out/table6_freeze_freq.csv")).unwrap();
    println!("\npaper shape check: headline flat across f (≤ ~0.3 spread).");
}
