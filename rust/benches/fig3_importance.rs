//! Paper Figure 3: channel-importance distributions (I_B, Eq. 6) across
//! layers — shows the few-important-channels structure EfQAT exploits.
//!
//!   cargo bench --bench fig3_importance [-- --model resnet20]
//!
//! Prints a per-layer summary (max / mean / p90 importance + an ASCII
//! distribution) from the pretrained FP checkpoint and writes the raw
//! per-channel values to bench_out/fig3_importance.csv.

mod common;

use std::io::Write;

use efqat::coordinator::pipeline::{ensure_fp_checkpoint, load_fp_checkpoint};
use efqat::harness::{sparkline, Table};

fn main() {
    let cfg = common::bench_config();
    let session = common::session(&cfg);
    let model = cfg.str("model", "resnet20");
    ensure_fp_checkpoint(&session, &cfg, &model, cfg.usize("train.epochs", 5)).unwrap();
    let (params, _) = load_fp_checkpoint(&cfg, &model).unwrap();
    let man = session.steps.get(&format!("{model}_calib")).unwrap().manifest.clone();

    let mut t = Table::new(
        &format!("Fig 3: channel importance I_B per layer, {model}"),
        &["layer", "C_out", "mean", "p90", "max", "max/mean", "sorted distribution"],
    );
    std::fs::create_dir_all("bench_out").unwrap();
    let mut csv = std::fs::File::create("bench_out/fig3_importance.csv").unwrap();
    writeln!(csv, "layer,channel,importance").unwrap();

    let mut all: Vec<f32> = Vec::new();
    for site in &man.wsites {
        let w = params.get(&site.name).unwrap();
        let mut imp = w.row_abs_mean();
        for (c, v) in imp.iter().enumerate() {
            writeln!(csv, "{},{},{}", site.name, c, v).unwrap();
        }
        all.extend(imp.iter());
        imp.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let mean = imp.iter().sum::<f32>() / imp.len() as f32;
        let p90 = imp[(imp.len() as f32 * 0.1) as usize];
        t.row(&[
            site.name.clone(),
            site.c_out.to_string(),
            format!("{mean:.4}"),
            format!("{p90:.4}"),
            format!("{:.4}", imp[0]),
            format!("{:.2}", imp[0] / mean.max(1e-9)),
            sparkline(&imp, 24),
        ]);
    }
    // whole-network column (the paper's last subplot)
    all.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mean = all.iter().sum::<f32>() / all.len() as f32;
    t.row(&[
        "NETWORK".into(),
        all.len().to_string(),
        format!("{mean:.4}"),
        format!("{:.4}", all[(all.len() as f32 * 0.1) as usize]),
        format!("{:.4}", all[0]),
        format!("{:.2}", all[0] / mean.max(1e-9)),
        sparkline(&all, 24),
    ]);
    t.print();
    println!("\npaper shape check: heavy-tailed — a few channels dominate (max/mean >> 1).");
}
