//! Paper Table 3: baseline models — FP, FP+1, and PTQ at each bit-width.
//!
//!   cargo bench --bench table3_baselines [-- --full true --models resnet20]
//!
//! Pretrains FP checkpoints if missing, trains one extra FP epoch (FP+1),
//! and applies MinMax PTQ at W8A8/W4A8/W4A4 — the same three columns as
//! the paper, at repro scale (synthetic datasets, DESIGN.md §3).

mod common;

use efqat::coordinator::pipeline::{
    ensure_fp_checkpoint, fp_ckpt_path, load_fp_checkpoint, parse_bits, train_cfg,
};
use efqat::coordinator::tasks::build_task;
use efqat::coordinator::trainer::{fwd_artifact_name, pretrain_fp};
use efqat::coordinator::{calibrate, evaluate};
use efqat::harness::Table;

fn main() {
    let cfg = common::bench_config();
    let session = common::session(&cfg);
    let quick = common::is_quick(&cfg);
    let models: Vec<String> = if quick {
        cfg.list("models", &["resnet8", "resnet20"])
    } else {
        cfg.list("models", &["resnet8", "resnet20", "resnet11b", "bert_tiny"])
    };

    let mut t = Table::new(
        "Table 3: baselines (headline = acc% / F1)",
        &["model", "FP", "FP+1", "bits", "PTQ"],
    );
    for model in &models {
        ensure_fp_checkpoint(&session, &cfg, model, cfg.usize("train.epochs", 5)).unwrap();
        let (mut params, mut states) = load_fp_checkpoint(&cfg, model).unwrap();
        let fwd_fp = session.steps.get(&fwd_artifact_name(model, "fp")).unwrap();
        let mut task = build_task(model, fwd_fp.manifest.batch_size, &cfg).unwrap();
        let fp = evaluate(&fwd_fp, &params, None, &states, &mut task.test).unwrap();

        // FP+1: one more FP epoch from the checkpoint (same optimizer family)
        let step = session.steps.get(&format!("{model}_fp_train")).unwrap();
        let tcfg = train_cfg(&cfg, model);
        pretrain_fp(&step, &mut params, &mut states, &mut task.train, 1, &tcfg).unwrap();
        let fp1 = evaluate(&fwd_fp, &params, None, &states, &mut task.test).unwrap();

        // PTQ columns from the *original* checkpoint
        let (orig_params, orig_states) = load_fp_checkpoint(&cfg, model).unwrap();
        let bits_set: Vec<&str> = match model.as_str() {
            "bert_tiny" | "gpt_mini" | "resnet8" => vec!["w8a8", "w4a8"],
            _ => vec!["w8a8", "w4a8", "w4a4"],
        };
        let mut first = true;
        for bits in bits_set {
            let (wb, ab) = parse_bits(bits).unwrap();
            let calib = session.steps.get(&format!("{model}_calib")).unwrap();
            let samples = task.calib_samples;
            let q = calibrate(&calib, &orig_params, &orig_states, &mut task.calib, samples, wb, ab)
                .unwrap();
            let fwd = session.steps.get(&fwd_artifact_name(model, bits)).unwrap();
            let ptq = evaluate(&fwd, &orig_params, Some(&q), &orig_states, &mut task.test).unwrap();
            t.row(&[
                if first { model.clone() } else { String::new() },
                if first { format!("{:.2}", fp.headline()) } else { String::new() },
                if first { format!("{:.2}", fp1.headline()) } else { String::new() },
                bits.to_uppercase(),
                format!("{:.2}", ptq.headline()),
            ]);
            first = false;
        }
    }
    t.print();
    t.write_csv(std::path::Path::new("bench_out/table3_baselines.csv")).unwrap();
    println!(
        "\npaper shape check: PTQ degrades with fewer bits; W4A4 collapses on the deeper net."
    );
}
