//! Shared bench scaffolding: config from env/args, session setup.

use std::collections::BTreeMap;

use efqat::cfg::Config;
use efqat::coordinator::Session;

/// Bench config: defaults tuned for single-core repro scale; `--key value`
/// args and `EFQAT_BENCH_*`-style keys override.
pub fn bench_config() -> Config {
    let mut cfg = Config::empty();
    cfg.set("ckpt_dir", "ckpts");
    cfg.set("save_ckpt", "false");
    cfg.set("data.train_n", "1024"); // bench default: half-size epochs
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut over = BTreeMap::new();
    for c in argv.chunks(2) {
        if let (Some(k), Some(v)) = (c[0].strip_prefix("--"), c.get(1)) {
            over.insert(k.to_string(), v.clone());
        }
    }
    cfg.override_with(&over);
    cfg
}

pub fn session(cfg: &Config) -> Session {
    Session::new(std::path::Path::new(&cfg.str("artifacts", "artifacts")))
        .expect("PJRT session (run `make artifacts` first)")
}

/// `cargo bench` passes --bench; strip it so chunk-parsing stays sane.
pub fn is_quick(cfg: &Config) -> bool {
    !cfg.bool("full", false)
}
