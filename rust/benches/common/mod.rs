//! Shared bench scaffolding: config from env/args, session setup.

// each bench target compiles this module separately and uses a subset
#![allow(dead_code)]

use std::collections::BTreeMap;

use efqat::cfg::Config;
use efqat::coordinator::Session;

/// Bench config with per-bench defaults: `defaults` are applied first,
/// then `--key value` args override.
pub fn bench_config_with(defaults: &[(&str, &str)]) -> Config {
    let mut cfg = Config::empty();
    cfg.set("ckpt_dir", "ckpts");
    cfg.set("save_ckpt", "false");
    cfg.set("data.train_n", "1024"); // bench default: half-size epochs
    for (k, v) in defaults {
        cfg.set(k, v);
    }
    // `cargo bench` injects a bare `--bench` flag; drop it so the
    // `--key value` pairing below stays aligned
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let mut over = BTreeMap::new();
    for c in argv.chunks(2) {
        if let (Some(k), Some(v)) = (c[0].strip_prefix("--"), c.get(1)) {
            over.insert(k.to_string(), v.clone());
        }
    }
    cfg.override_with(&over);
    cfg
}

/// Bench config: defaults tuned for single-core repro scale; `--key value`
/// args and `EFQAT_BENCH_*`-style keys override.
pub fn bench_config() -> Config {
    // the paper-scale default models (resnet/bert/gpt) only exist as PJRT
    // artifacts, so most benches default to that backend; override with
    // `--backend native --models mlp` to run dependency-free
    bench_config_with(&[("backend", "pjrt")])
}

pub fn session(cfg: &Config) -> Session {
    Session::from_cfg(cfg)
        .expect("session (pjrt backend needs `make artifacts` and `--features pjrt`)")
}

pub fn is_quick(cfg: &Config) -> bool {
    !cfg.bool("full", false)
}

/// Synthetic mid-grid qparams for bench models — the same builder the
/// unit and parity tests use (`efqat::testing::synth_qparams`), so
/// bench fixtures cannot drift from the tested ones.
pub fn synth_qparams(
    man: &efqat::model::Manifest,
    params: &efqat::model::ParamStore,
    w_bits: u32,
    a_bits: u32,
    act_scale: f32,
) -> efqat::model::QParamStore {
    efqat::testing::synth_qparams(man, params, w_bits, a_bits, act_scale)
}
