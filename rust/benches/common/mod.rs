//! Shared bench scaffolding: config from env/args, session setup.

use std::collections::BTreeMap;

use efqat::cfg::Config;
use efqat::coordinator::Session;

/// Bench config: defaults tuned for single-core repro scale; `--key value`
/// args and `EFQAT_BENCH_*`-style keys override.
pub fn bench_config() -> Config {
    let mut cfg = Config::empty();
    cfg.set("ckpt_dir", "ckpts");
    cfg.set("save_ckpt", "false");
    cfg.set("data.train_n", "1024"); // bench default: half-size epochs
    // the paper-scale default models (resnet/bert/gpt) only exist as PJRT
    // artifacts, so benches default to that backend; override with
    // `--backend native --models mlp` to run dependency-free
    cfg.set("backend", "pjrt");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut over = BTreeMap::new();
    for c in argv.chunks(2) {
        if let (Some(k), Some(v)) = (c[0].strip_prefix("--"), c.get(1)) {
            over.insert(k.to_string(), v.clone());
        }
    }
    cfg.override_with(&over);
    cfg
}

pub fn session(cfg: &Config) -> Session {
    Session::from_cfg(cfg)
        .expect("session (pjrt backend needs `make artifacts` and `--features pjrt`)")
}

/// `cargo bench` passes --bench; strip it so chunk-parsing stays sane.
pub fn is_quick(cfg: &Config) -> bool {
    !cfg.bool("full", false)
}
