//! Paper Table 5 + Figure 2b: backward runtime of EfQAT-CWPN / LWPN vs QAT.
//!
//! For each model × ratio we time the *train-step artifact execution* (the
//! quantity the paper reports "over the total training steps during the
//! EfQAT epoch") and isolate the backward part by subtracting the forward
//! artifact's time on the same batch.  Absolute numbers are single-node
//! CPU, not A100/A10 — the paper's claim is the *shape*: time falls
//! monotonically with the update ratio, LWPN ≥ CWPN savings, up to ~2x at
//! r→0 (Eq. 7/8).
//!
//! Runs on the native backend by default (all four graph models), so the
//! perf trajectory records dependency-free; the results land in
//! `bench_out/table5_backward_runtime.csv` and `BENCH_table5.json`
//! (full vs partial backward wall-time per mode).
//!
//!   cargo bench --bench table5_backward_runtime [-- --full true]
//!   cargo bench --bench table5_backward_runtime -- --backend pjrt --models resnet20

mod common;

use std::collections::BTreeMap;

use efqat::coordinator::binder::{bind_inputs, BindCtx};
use efqat::coordinator::tasks::build_task;
use efqat::coordinator::trainer::{EfqatTrainer, TrainCfg};
use efqat::freeze::Mode;
use efqat::harness::{bench, Table};
use efqat::json::Json;
use efqat::model::{ParamStore, QParamStore, StateStore};
use efqat::quant::ActQParams;

fn qparams_for(man: &efqat::model::Manifest, params: &ParamStore) -> QParamStore {
    let mut q = QParamStore::default();
    q.init_weight_scales(man, params, man.w_bits.max(4));
    for w in &man.wsites {
        q.act.insert(w.name.clone(), ActQParams { scale: 0.05, zero_point: 128.0 });
    }
    q
}

fn time_artifact(
    session: &efqat::coordinator::Session,
    cfg: &efqat::cfg::Config,
    model: &str,
    artifact: &str,
    mode: Option<Mode>,
    iters: usize,
) -> f64 {
    let step = session.steps.get(artifact).unwrap();
    let man = step.manifest.clone();
    let params = ParamStore::init(&man, 0);
    let states = StateStore::init(&man);
    let q = qparams_for(&man, &params);
    let mut task = build_task(model, man.batch_size, cfg).unwrap();
    let batch = task.train.next_batch().unwrap();

    if man.kind == "fwd" {
        let ctx = BindCtx {
            params: &params,
            qparams: Some(&q),
            states: &states,
            batch: &batch,
            selection: None,
        };
        let inputs = bind_inputs(&man, &ctx).unwrap();
        // reused workspace: time the planned executor's steady state
        let mut ws = efqat::exec::Workspace::new();
        let st = bench(2, iters, || {
            let (outs, _) = step.execute_timed_ws(&inputs, &mut ws).unwrap();
            ws.give_values(outs);
        });
        return st.mean;
    }

    let tcfg = TrainCfg { ratio_override: Some(0.05), ..TrainCfg::default() };
    let trainer = EfqatTrainer::new(step.clone(), params, q, states, mode, tcfg).unwrap();
    let selection = trainer.policy.as_ref().map(|p| p.selection().clone());
    let ctx = BindCtx {
        params: &trainer.params,
        qparams: Some(&trainer.qparams),
        states: &trainer.states,
        batch: &batch,
        selection: selection.as_ref(),
    };
    let inputs = bind_inputs(&man, &ctx).unwrap();
    // reused workspace: time the planned executor's steady state
    let mut ws = efqat::exec::Workspace::new();
    let st = bench(2, iters, || {
        let (outs, _) = step.execute_timed_ws(&inputs, &mut ws).unwrap();
        ws.give_values(outs);
    });
    st.mean
}

fn main() {
    // native by default: the graph models record the perf trajectory with
    // zero dependencies; `--backend pjrt --models resnet20,…` still works
    let cfg = common::bench_config_with(&[
        ("backend", "native"),
        ("models", "mlp,mlp_wide,convnet,tiny_tf"),
    ]);
    let session = common::session(&cfg);
    let quick = common::is_quick(&cfg);
    let iters = cfg.usize("iters", if quick { 5 } else { 20 });
    let models: Vec<String> = cfg.list("models", &["mlp"]);
    let bits = cfg.str("bits", "w4a8");
    let ratios = [0usize, 5, 10, 25, 50];

    let mut t = Table::new(
        &format!(
            "Table 5 / Fig 2b: backward runtime per step (ms), {bits} ({} backend)",
            cfg.str("backend", "native")
        ),
        &[
            "model",
            "mode",
            "fwd",
            "r0",
            "r5",
            "r10",
            "r25",
            "r50",
            "QAT",
            "bwd speedup r5",
            "bwd speedup lwpn",
        ],
    );
    // BENCH_table5.json: per model, full vs partial backward wall-time
    let mut report = BTreeMap::new();
    for model in &models {
        let fwd = time_artifact(&session, &cfg, model, &format!("{model}_{bits}_fwd"), None, iters);
        let qat_name = format!("{model}_{bits}_train_r100");
        let qat = time_artifact(&session, &cfg, model, &qat_name, None, iters);
        let lwpn_name = format!("{model}_{bits}_train_lwpn");
        let lwpn = time_artifact(&session, &cfg, model, &lwpn_name, Some(Mode::Lwpn), iters);
        let bwd = |t: f64| (t - fwd).max(1e-9);
        let mut row = vec![model.clone(), "CWPN".to_string(), format!("{:.2}", fwd * 1e3)];
        let mut r5_time = qat;
        let mut modes = BTreeMap::new();
        for r in ratios {
            let name = format!("{model}_{bits}_train_r{r}");
            let mode = if r == 0 { None } else { Some(Mode::Cwpn) };
            let dt = time_artifact(&session, &cfg, model, &name, mode, iters);
            if r == 5 {
                r5_time = dt;
            }
            row.push(format!("{:.2}", dt * 1e3));
            modes.insert(format!("r{r}"), Json::Num(dt * 1e3));
        }
        modes.insert("lwpn".to_string(), Json::Num(lwpn * 1e3));
        row.push(format!("{:.2}", qat * 1e3));
        row.push(format!("{:.2}x", bwd(qat) / bwd(r5_time)));
        row.push(format!("{:.2}x", bwd(qat) / bwd(lwpn)));
        t.row(&row);
        t.row(&[
            model.clone(),
            "LWPN(r5)".to_string(),
            format!("{:.2}", fwd * 1e3),
            "-".into(), "-".into(), "-".into(), "-".into(), "-".into(),
            format!("{:.2}", lwpn * 1e3),
            "-".into(), "-".into(),
        ]);
        let entry: BTreeMap<String, Json> = [
            ("fwd_ms".to_string(), Json::Num(fwd * 1e3)),
            ("full_train_ms".to_string(), Json::Num(qat * 1e3)),
            ("partial_train_ms".to_string(), Json::Obj(modes)),
            ("bwd_speedup_r5".to_string(), Json::Num(bwd(qat) / bwd(r5_time))),
            ("bwd_speedup_lwpn".to_string(), Json::Num(bwd(qat) / bwd(lwpn))),
        ]
        .into_iter()
        .collect();
        report.insert(model.clone(), Json::Obj(entry));
    }
    t.print();
    t.write_csv(std::path::Path::new("bench_out/table5_backward_runtime.csv")).unwrap();

    let doc: BTreeMap<String, Json> = [
        ("bench".to_string(), Json::Str("table5_backward_runtime".to_string())),
        ("backend".to_string(), Json::Str(cfg.str("backend", "native"))),
        ("bits".to_string(), Json::Str(bits.clone())),
        ("iters".to_string(), Json::Num(iters as f64)),
        ("models".to_string(), Json::Obj(report)),
    ]
    .into_iter()
    .collect();
    std::fs::write("BENCH_table5.json", Json::Obj(doc).render()).unwrap();
    println!("\nwrote BENCH_table5.json (full vs partial backward wall-time per mode)");
    println!("paper shape check: runtime should fall monotonically r50→r0;");
    println!("QAT/r0 backward ratio approaches the theoretical 2x bound (Eq. 7/8).");
}
