//! Paper Table 5 + Figure 2b: backward runtime of EfQAT-CWPN / LWPN vs QAT.
//!
//! For each model × ratio we time the *train-step artifact execution* (the
//! quantity the paper reports "over the total training steps during the
//! EfQAT epoch") and isolate the backward part by subtracting the forward
//! artifact's time on the same batch.  Absolute numbers are single-node
//! CPU, not A100/A10 — the paper's claim is the *shape*: time falls
//! monotonically with the update ratio, LWPN ≥ CWPN savings, up to ~2x at
//! r→0 (Eq. 7/8).
//!
//! Runs on the native backend by default (all four graph models), so the
//! perf trajectory records dependency-free; the results land in
//! `bench_out/table5_backward_runtime.csv` and `BENCH_table5.json`
//! (full vs partial backward wall-time per mode).
//!
//!   cargo bench --bench table5_backward_runtime [-- --full true]
//!   cargo bench --bench table5_backward_runtime -- --backend pjrt --models resnet20

mod common;

use std::collections::BTreeMap;

use efqat::coordinator::binder::{bind_inputs, BindCtx};
use efqat::coordinator::tasks::build_task;
use efqat::coordinator::trainer::{DataParallelTrainer, EfqatTrainer, TrainCfg};
use efqat::freeze::Mode;
use efqat::harness::{bench, Table};
use efqat::json::Json;
use efqat::model::{ParamStore, QParamStore, StateStore};
use efqat::quant::ActQParams;

fn qparams_for(man: &efqat::model::Manifest, params: &ParamStore) -> QParamStore {
    let mut q = QParamStore::default();
    q.init_weight_scales(man, params, man.w_bits.max(4));
    for w in &man.wsites {
        q.act.insert(w.name.clone(), ActQParams { scale: 0.05, zero_point: 128.0 });
    }
    q
}

fn time_artifact(
    session: &efqat::coordinator::Session,
    cfg: &efqat::cfg::Config,
    model: &str,
    artifact: &str,
    mode: Option<Mode>,
    iters: usize,
) -> f64 {
    let step = session.steps.get(artifact).unwrap();
    let man = step.manifest.clone();
    let params = ParamStore::init(&man, 0);
    let states = StateStore::init(&man);
    let q = qparams_for(&man, &params);
    let mut task = build_task(model, man.batch_size, cfg).unwrap();
    let batch = task.train.next_batch().unwrap();

    if man.kind == "fwd" {
        let ctx = BindCtx {
            params: &params,
            qparams: Some(&q),
            states: &states,
            batch: &batch,
            selection: None,
        };
        let inputs = bind_inputs(&man, &ctx).unwrap();
        // reused workspace: time the planned executor's steady state
        let mut ws = efqat::exec::Workspace::new();
        let st = bench(2, iters, || {
            let (outs, _) = step.execute_timed_ws(&inputs, &mut ws).unwrap();
            ws.give_values(outs);
        });
        return st.mean;
    }

    let tcfg = TrainCfg { ratio_override: Some(0.05), ..TrainCfg::default() };
    let trainer = EfqatTrainer::new(step.clone(), params, q, states, mode, tcfg).unwrap();
    let selection = trainer.policy.as_ref().map(|p| p.selection().clone());
    let ctx = BindCtx {
        params: &trainer.params,
        qparams: Some(&trainer.qparams),
        states: &trainer.states,
        batch: &batch,
        selection: selection.as_ref(),
    };
    let inputs = bind_inputs(&man, &ctx).unwrap();
    // reused workspace: time the planned executor's steady state
    let mut ws = efqat::exec::Workspace::new();
    let st = bench(2, iters, || {
        let (outs, _) = step.execute_timed_ws(&inputs, &mut ws).unwrap();
        ws.give_values(outs);
    });
    st.mean
}

/// LWPN train step at the paper's r=0.25 with the frozen-prefix backward
/// truncation forced on and off: (on_mean, off_mean, layers_skipped).
/// Same selection and inputs both ways — the delta is exactly the dX
/// propagation below the lowest active layer.
fn time_lwpn_trunc(
    session: &efqat::coordinator::Session,
    cfg: &efqat::cfg::Config,
    model: &str,
    bits: &str,
    iters: usize,
) -> (f64, f64, usize) {
    let name = format!("{model}_{bits}_train_lwpn");
    let step = session.steps.get(&name).unwrap();
    let man = step.manifest.clone();
    let params = ParamStore::init(&man, 0);
    let states = StateStore::init(&man);
    let q = qparams_for(&man, &params);
    let mut task = build_task(model, man.batch_size, cfg).unwrap();
    let batch = task.train.next_batch().unwrap();
    let tcfg = TrainCfg { ratio_override: Some(0.25), ..TrainCfg::default() };
    let trainer =
        EfqatTrainer::new(step.clone(), params, q, states, Some(Mode::Lwpn), tcfg).unwrap();
    let policy = trainer.policy.as_ref().unwrap();
    let skipped = policy.selection().lowest_active_layer(&policy.sites).unwrap_or(0);
    let selection = Some(policy.selection().clone());
    let ctx = BindCtx {
        params: &trainer.params,
        qparams: Some(&trainer.qparams),
        states: &trainer.states,
        batch: &batch,
        selection: selection.as_ref(),
    };
    let inputs = bind_inputs(&man, &ctx).unwrap();
    let mut ws = efqat::exec::Workspace::new();
    efqat::graph::force_backward_truncation(Some(true));
    let on = bench(2, iters, || {
        let (outs, _) = step.execute_timed_ws(&inputs, &mut ws).unwrap();
        ws.give_values(outs);
    });
    efqat::graph::force_backward_truncation(Some(false));
    let off = bench(2, iters, || {
        let (outs, _) = step.execute_timed_ws(&inputs, &mut ws).unwrap();
        ws.give_values(outs);
    });
    efqat::graph::force_backward_truncation(None);
    (on.mean, off.mean, skipped)
}

/// Full data-parallel train step at `workers` workers: wall time plus the
/// gradient-exchange payload (active and dense-equivalent bytes/step).
fn time_workers(
    session: &efqat::coordinator::Session,
    cfg: &efqat::cfg::Config,
    model: &str,
    bits: &str,
    ratio: usize,
    workers: usize,
    iters: usize,
) -> (f64, u64, u64) {
    let name = format!("{model}_{bits}_train_r{ratio}");
    let mode = if ratio >= 100 { None } else { Some(Mode::Cwpn) };
    let step = session.steps.get(&name).unwrap();
    let man = step.manifest.clone();
    let params = ParamStore::init(&man, 0);
    let states = StateStore::init(&man);
    let q = qparams_for(&man, &params);
    let mut task = build_task(model, man.batch_size, cfg).unwrap();
    let batch = task.train.next_batch().unwrap();
    let inner = EfqatTrainer::new(step, params, q, states, mode, TrainCfg::default()).unwrap();
    let mut dp = DataParallelTrainer::new(inner, workers).unwrap();
    // one untimed step: warms workspaces/binders and yields the per-step
    // payload (the selection, and so the payload, is stable across steps)
    let before = (dp.active_bytes, dp.dense_bytes);
    dp.train_step(&batch).unwrap();
    let active = dp.active_bytes - before.0;
    let dense = dp.dense_bytes - before.1;
    let st = bench(1, iters, || {
        dp.train_step(&batch).unwrap();
    });
    (st.mean, active, dense)
}

fn main() {
    // native by default: the graph models record the perf trajectory with
    // zero dependencies; `--backend pjrt --models resnet20,…` still works
    let cfg = common::bench_config_with(&[
        ("backend", "native"),
        ("models", "mlp,mlp_wide,convnet,tiny_tf"),
    ]);
    let session = common::session(&cfg);
    let quick = common::is_quick(&cfg);
    let iters = cfg.usize("iters", if quick { 5 } else { 20 });
    let models: Vec<String> = cfg.list("models", &["mlp"]);
    let bits = cfg.str("bits", "w4a8");
    let ratios = [0usize, 5, 10, 25, 50];

    let mut t = Table::new(
        &format!(
            "Table 5 / Fig 2b: backward runtime per step (ms), {bits} ({} backend)",
            cfg.str("backend", "native")
        ),
        &[
            "model",
            "mode",
            "fwd",
            "r0",
            "r5",
            "r10",
            "r25",
            "r50",
            "QAT",
            "bwd speedup r5",
            "bwd speedup lwpn",
        ],
    );
    // BENCH_table5.json: per model, full vs partial backward wall-time
    let mut report = BTreeMap::new();
    let mut dt_table = Table::new(
        "f32 dispatch (QAT backward, ms) and LWPN r25 backward truncation (step, ms)",
        &["model", "bwd scalar", "bwd simd", "speedup", "trunc off", "trunc on", "layers skipped"],
    );
    // CI gates (bench-smoke): best dispatch speedup across models, and the
    // summed LWPN-r25-truncated vs QAT step times (sums absorb the
    // per-model noise of a --iters 3 smoke run)
    let mut best_dispatch = 0.0f64;
    let mut trunc_on_sum = 0.0f64;
    let mut qat_sum = 0.0f64;
    for model in &models {
        let fwd = time_artifact(&session, &cfg, model, &format!("{model}_{bits}_fwd"), None, iters);
        let qat_name = format!("{model}_{bits}_train_r100");
        let qat = time_artifact(&session, &cfg, model, &qat_name, None, iters);
        let lwpn_name = format!("{model}_{bits}_train_lwpn");
        let lwpn = time_artifact(&session, &cfg, model, &lwpn_name, Some(Mode::Lwpn), iters);
        let bwd = |t: f64| (t - fwd).max(1e-9);
        let mut row = vec![model.clone(), "CWPN".to_string(), format!("{:.2}", fwd * 1e3)];
        let mut r5_time = qat;
        let mut modes = BTreeMap::new();
        for r in ratios {
            let name = format!("{model}_{bits}_train_r{r}");
            let mode = if r == 0 { None } else { Some(Mode::Cwpn) };
            let dt = time_artifact(&session, &cfg, model, &name, mode, iters);
            if r == 5 {
                r5_time = dt;
            }
            row.push(format!("{:.2}", dt * 1e3));
            modes.insert(format!("r{r}"), Json::Num(dt * 1e3));
        }
        modes.insert("lwpn".to_string(), Json::Num(lwpn * 1e3));
        row.push(format!("{:.2}", qat * 1e3));
        row.push(format!("{:.2}x", bwd(qat) / bwd(r5_time)));
        row.push(format!("{:.2}x", bwd(qat) / bwd(lwpn)));
        t.row(&row);
        t.row(&[
            model.clone(),
            "LWPN(r5)".to_string(),
            format!("{:.2}", fwd * 1e3),
            "-".into(), "-".into(), "-".into(), "-".into(), "-".into(),
            format!("{:.2}", lwpn * 1e3),
            "-".into(), "-".into(),
        ]);
        // ---- f32 dispatch axis: the same QAT leg forced scalar -----------
        // fwd is re-timed under the forced kernel so the bwd isolation
        // (train − fwd) subtracts like from like
        efqat::ops::simd::force_f32(Some(0));
        let fwd_name = format!("{model}_{bits}_fwd");
        let fwd_sc = time_artifact(&session, &cfg, model, &fwd_name, None, iters);
        let qat_sc = time_artifact(&session, &cfg, model, &qat_name, None, iters);
        efqat::ops::simd::force_f32(None);
        let scalar_bwd = (qat_sc - fwd_sc).max(1e-9);
        let speedup = scalar_bwd / bwd(qat);
        best_dispatch = best_dispatch.max(speedup);

        // ---- truncation axis: LWPN at the paper's r=0.25, on vs off ------
        let (tr_on, tr_off, skipped) = time_lwpn_trunc(&session, &cfg, model, &bits, iters);
        trunc_on_sum += tr_on;
        qat_sum += qat;

        dt_table.row(&[
            model.clone(),
            format!("{:.2}", scalar_bwd * 1e3),
            format!("{:.2}", bwd(qat) * 1e3),
            format!("{speedup:.2}x"),
            format!("{:.2}", tr_off * 1e3),
            format!("{:.2}", tr_on * 1e3),
            skipped.to_string(),
        ]);

        let entry: BTreeMap<String, Json> = [
            ("fwd_ms".to_string(), Json::Num(fwd * 1e3)),
            ("full_train_ms".to_string(), Json::Num(qat * 1e3)),
            ("partial_train_ms".to_string(), Json::Obj(modes)),
            ("bwd_speedup_r5".to_string(), Json::Num(bwd(qat) / bwd(r5_time))),
            ("bwd_speedup_lwpn".to_string(), Json::Num(bwd(qat) / bwd(lwpn))),
            ("scalar_bwd_ms".to_string(), Json::Num(scalar_bwd * 1e3)),
            ("dispatched_bwd_ms".to_string(), Json::Num(bwd(qat) * 1e3)),
            ("dispatch_speedup".to_string(), Json::Num(speedup)),
            ("lwpn_r25_trunc_on_ms".to_string(), Json::Num(tr_on * 1e3)),
            ("lwpn_r25_trunc_off_ms".to_string(), Json::Num(tr_off * 1e3)),
            ("bwd_layers_skipped".to_string(), Json::Num(skipped as f64)),
        ]
        .into_iter()
        .collect();
        report.insert(model.clone(), Json::Obj(entry));
    }
    dt_table.print();

    // ---- CI gates (bench-smoke runs this bench and fails on panic) -------
    if efqat::ops::simd::kernels_f32().len() > 1 {
        assert!(
            best_dispatch >= 1.2,
            "dispatch gate: best f32 SIMD backward speedup {best_dispatch:.2}x < 1.2x \
             over the scalar oracle"
        );
    } else {
        println!("dispatch gate skipped: only the scalar f32 kernel is registered on this host");
    }
    assert!(
        trunc_on_sum < qat_sum,
        "truncation gate: LWPN r=0.25 with backward truncation ({:.2} ms summed) \
         not below the r=1.0 QAT step ({:.2} ms summed)",
        trunc_on_sum * 1e3,
        qat_sum * 1e3
    );
    t.print();
    t.write_csv(std::path::Path::new("bench_out/table5_backward_runtime.csv")).unwrap();

    // ---- workers axis: data-parallel step time + exchange payload --------
    // bit-identical results at every W (tests/data_parallel.rs), so this
    // axis is purely throughput: per-W step time and the bytes the sparse
    // exchange ships (which shrink ∝ (1−r) next to the dense equivalent)
    let default_ws: &[&str] = if quick { &["1", "2"] } else { &["1", "2", "4"] };
    let worker_axis: Vec<String> = cfg.list("workers", default_ws);
    let mut wt = Table::new(
        &format!("Data-parallel train step (ms) and exchange payload (KiB/step), {bits}"),
        &["model", "W", "r25 step", "r25 ship", "r25 dense", "r100 step", "r100 ship"],
    );
    let mut wreport = BTreeMap::new();
    for model in &models {
        let mut per_w = BTreeMap::new();
        for w in &worker_axis {
            let w: usize = w.parse().unwrap_or(1);
            let (t25, a25, d25) = time_workers(&session, &cfg, model, &bits, 25, w, iters);
            let (t100, a100, _) = time_workers(&session, &cfg, model, &bits, 100, w, iters);
            let kib = |b: u64| b as f64 / 1024.0;
            wt.row(&[
                model.clone(),
                w.to_string(),
                format!("{:.2}", t25 * 1e3),
                format!("{:.1}", kib(a25)),
                format!("{:.1}", kib(d25)),
                format!("{:.2}", t100 * 1e3),
                format!("{:.1}", kib(a100)),
            ]);
            let entry: BTreeMap<String, Json> = [
                ("r25_step_ms".to_string(), Json::Num(t25 * 1e3)),
                ("r25_bytes_per_step".to_string(), Json::Num(a25 as f64)),
                ("r25_dense_bytes_per_step".to_string(), Json::Num(d25 as f64)),
                ("r100_step_ms".to_string(), Json::Num(t100 * 1e3)),
                ("r100_bytes_per_step".to_string(), Json::Num(a100 as f64)),
            ]
            .into_iter()
            .collect();
            per_w.insert(format!("w{w}"), Json::Obj(entry));
        }
        wreport.insert(model.clone(), Json::Obj(per_w));
    }
    wt.print();

    let doc: BTreeMap<String, Json> = [
        ("bench".to_string(), Json::Str("table5_backward_runtime".to_string())),
        ("backend".to_string(), Json::Str(cfg.str("backend", "native"))),
        ("bits".to_string(), Json::Str(bits.clone())),
        ("iters".to_string(), Json::Num(iters as f64)),
        ("models".to_string(), Json::Obj(report)),
        ("workers".to_string(), Json::Obj(wreport)),
    ]
    .into_iter()
    .collect();
    std::fs::write("BENCH_table5.json", Json::Obj(doc).render()).unwrap();
    println!("\nwrote BENCH_table5.json (full vs partial backward wall-time per mode,");
    println!("plus per-W data-parallel step time and exchange bytes)");
    println!("paper shape check: runtime should fall monotonically r50→r0;");
    println!("QAT/r0 backward ratio approaches the theoretical 2x bound (Eq. 7/8);");
    println!("exchange bytes at r25 should sit near 25% of the dense payload.");
}
