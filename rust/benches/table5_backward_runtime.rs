//! Paper Table 5 + Figure 2b: backward runtime of EfQAT-CWPN / LWPN vs QAT.
//!
//! For each model × ratio we time the *train-step artifact execution* (the
//! quantity the paper reports "over the total training steps during the
//! EfQAT epoch") and isolate the backward part by subtracting the forward
//! artifact's time on the same batch.  Absolute numbers are CPU-PJRT, not
//! A100/A10 — the paper's claim is the *shape*: time falls monotonically
//! with the update ratio, LWPN ≥ CWPN savings, up to ~2x at r→0 (Eq. 7/8).
//!
//!   cargo bench --bench table5_backward_runtime [-- --full true]

mod common;

use efqat::coordinator::binder::{bind_inputs, BindCtx};
use efqat::coordinator::tasks::build_task;
use efqat::coordinator::trainer::{EfqatTrainer, TrainCfg};
use efqat::freeze::Mode;
use efqat::harness::{bench, Table};
use efqat::model::{ParamStore, QParamStore, StateStore};
use efqat::quant::ActQParams;

fn qparams_for(man: &efqat::model::Manifest, params: &ParamStore) -> QParamStore {
    let mut q = QParamStore::default();
    q.init_weight_scales(man, params, man.w_bits.max(4));
    for w in &man.wsites {
        q.act.insert(w.name.clone(), ActQParams { scale: 0.05, zero_point: 128.0 });
    }
    q
}

fn time_artifact(
    session: &efqat::coordinator::Session,
    cfg: &efqat::cfg::Config,
    model: &str,
    artifact: &str,
    mode: Option<Mode>,
    iters: usize,
) -> f64 {
    let step = session.steps.get(artifact).unwrap();
    let man = step.manifest.clone();
    let params = ParamStore::init(&man, 0);
    let states = StateStore::init(&man);
    let q = qparams_for(&man, &params);
    let mut task = build_task(model, man.batch_size, cfg).unwrap();
    let batch = task.train.next_batch().unwrap();

    if man.kind == "fwd" {
        let ctx = BindCtx { params: &params, qparams: Some(&q), states: &states, batch: &batch, selection: None };
        let inputs = bind_inputs(&man, &ctx).unwrap();
        let st = bench(2, iters, || {
            step.execute(&inputs).unwrap();
        });
        return st.mean;
    }

    let tcfg = TrainCfg { ratio_override: Some(0.05), ..TrainCfg::default() };
    let trainer = EfqatTrainer::new(step.clone(), params, q, states, mode, tcfg).unwrap();
    let selection = trainer.policy.as_ref().map(|p| p.selection().clone());
    let ctx = BindCtx {
        params: &trainer.params,
        qparams: Some(&trainer.qparams),
        states: &trainer.states,
        batch: &batch,
        selection: selection.as_ref(),
    };
    let inputs = bind_inputs(&man, &ctx).unwrap();
    let st = bench(2, iters, || {
        step.execute(&inputs).unwrap();
    });
    st.mean
}

fn main() {
    let cfg = common::bench_config();
    let session = common::session(&cfg);
    let quick = common::is_quick(&cfg);
    let iters = cfg.usize("iters", if quick { 3 } else { 15 });
    let models: Vec<String> = if quick {
        cfg.list("models", &["resnet20"])
    } else {
        cfg.list("models", &["resnet8", "resnet20", "resnet11b", "bert_tiny", "gpt_mini"])
    };
    let bits = cfg.str("bits", "w4a8");
    let ratios = [0usize, 5, 10, 25, 50];

    let mut t = Table::new(
        &format!("Table 5 / Fig 2b: backward runtime per step (ms), {bits} (CPU PJRT)"),
        &["model", "mode", "fwd", "r0", "r5", "r10", "r25", "r50", "QAT", "bwd speedup r5", "bwd speedup lwpn"],
    );
    for model in &models {
        let fwd = time_artifact(&session, &cfg, model, &format!("{model}_{bits}_fwd"), None, iters);
        let qat = time_artifact(&session, &cfg, model, &format!("{model}_{bits}_train_r100"), None, iters);
        let lwpn = time_artifact(&session, &cfg, model, &format!("{model}_{bits}_train_lwpn"), Some(Mode::Lwpn), iters);
        let mut row = vec![model.clone(), "CWPN".to_string(), format!("{:.1}", fwd * 1e3)];
        let mut r5_time = qat;
        for r in ratios {
            let name = format!("{model}_{bits}_train_r{r}");
            let mode = if r == 0 { None } else { Some(Mode::Cwpn) };
            let dt = time_artifact(&session, &cfg, model, &name, mode, iters);
            if r == 5 {
                r5_time = dt;
            }
            row.push(format!("{:.1}", dt * 1e3));
        }
        row.push(format!("{:.1}", qat * 1e3));
        let bwd = |t: f64| (t - fwd).max(1e-9);
        row.push(format!("{:.2}x", bwd(qat) / bwd(r5_time)));
        row.push(format!("{:.2}x", bwd(qat) / bwd(lwpn)));
        t.row(&row);
        // LWPN row: same artifact, flags from the policy at ratio 1.0 (all
        // unfrozen) vs the paper's per-ratio budget is exercised in fig2b
        t.row(&[
            model.clone(),
            "LWPN(r5)".to_string(),
            format!("{:.1}", fwd * 1e3),
            "-".into(), "-".into(), "-".into(), "-".into(), "-".into(),
            format!("{:.1}", lwpn * 1e3),
            "-".into(), "-".into(),
        ]);
    }
    t.print();
    t.write_csv(std::path::Path::new("bench_out/table5_backward_runtime.csv")).unwrap();
    println!("\npaper shape check: runtime should fall monotonically r50→r0;");
    println!("QAT/r0 backward ratio approaches the theoretical 2x bound (Eq. 7/8).");
}
