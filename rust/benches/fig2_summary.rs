//! Paper Figure 2a: EfQAT-CWPN accuracy vs PTQ / FP+1 across precisions,
//! and Figure 2b companion: LWPN backward speedup across ratios.
//!
//!   cargo bench --bench fig2_summary [-- --model resnet20]

mod common;

use efqat::coordinator::pipeline::{
    ensure_fp_checkpoint, load_fp_checkpoint, run_efqat_pipeline, train_cfg,
};
use efqat::coordinator::tasks::build_task;
use efqat::coordinator::trainer::pretrain_fp;
use efqat::coordinator::evaluate;
use efqat::harness::Table;

fn main() {
    let cfg = common::bench_config();
    let session = common::session(&cfg);
    let model = cfg.str("model", "resnet20");
    let ratio = cfg.usize("ratio", 25);
    let bits_set = cfg.list("bits", &["w8a8", "w4a8"]);

    ensure_fp_checkpoint(&session, &cfg, &model, cfg.usize("train.epochs", 5)).unwrap();

    // FP+1 reference
    let (mut params, mut states) = load_fp_checkpoint(&cfg, &model).unwrap();
    let step = session.steps.get(&format!("{model}_fp_train")).unwrap();
    let fwd_fp = session.steps.get(&format!("{model}_fp_fwd")).unwrap();
    let mut task = build_task(&model, step.manifest.batch_size, &cfg).unwrap();
    let tcfg = train_cfg(&cfg, &model);
    pretrain_fp(&step, &mut params, &mut states, &mut task.train, 1, &tcfg).unwrap();
    let fp1 = evaluate(&fwd_fp, &params, None, &states, &mut task.test).unwrap();

    let mut t = Table::new(
        &format!("Fig 2a: {model}, EfQAT-CWPN {ratio}% vs PTQ vs FP+1"),
        &["bits", "PTQ", "EfQAT-CWPN", "FP+1", "EfQAT exec s", "QAT exec s", "speedup"],
    );
    for bits in &bits_set {
        let s = run_efqat_pipeline(&session, &cfg, &model, bits, "cwpn", ratio).unwrap();
        let q = run_efqat_pipeline(&session, &cfg, &model, bits, "qat", 100).unwrap();
        t.row(&[
            bits.to_uppercase(),
            format!("{:.2}", s.ptq_headline),
            format!("{:.2}", s.efqat_headline),
            format!("{:.2}", fp1.headline()),
            format!("{:.2}", s.exec_seconds),
            format!("{:.2}", q.exec_seconds),
            format!("{:.2}x", q.exec_seconds / s.exec_seconds.max(1e-9)),
        ]);
    }
    t.print();
    t.write_csv(std::path::Path::new("bench_out/fig2_summary.csv")).unwrap();
    println!("\npaper shape check: EfQAT recovers most of the PTQ→FP+1 gap at every precision.");
}
