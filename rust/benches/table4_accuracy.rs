//! Paper Table 4: EfQAT accuracy across modes × update ratios vs PTQ/QAT.
//!
//!   cargo bench --bench table4_accuracy [-- --models resnet20 --bits w4a8 \
//!        --ratios 5,25 --seeds 1 --full true]
//!
//! For each (model, bits): rows CWPL/CWPN/LWPN × ratio columns {0,5,10,25,50}
//! plus the PTQ and QAT reference columns — the exact layout of Table 4 at
//! repro scale.  Multi-seed runs report mean±std like the paper.

mod common;

use efqat::coordinator::pipeline::{ensure_fp_checkpoint, run_efqat_pipeline};
use efqat::harness::Table;

fn mean_std(xs: &[f32]) -> (f32, f32) {
    let n = xs.len() as f32;
    let m = xs.iter().sum::<f32>() / n;
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / n;
    (m, v.sqrt())
}

fn main() {
    let cfg = common::bench_config();
    let session = common::session(&cfg);
    let quick = common::is_quick(&cfg);

    let models = if quick {
        cfg.list("models", &["resnet20"])
    } else {
        cfg.list("models", &["resnet20", "resnet11b", "bert_tiny"])
    };
    let seeds: Vec<u64> = cfg
        .list("seeds", if quick { &["0"] } else { &["0", "1", "2"] })
        .iter()
        .map(|s| s.parse().unwrap())
        .collect();
    let ratios: Vec<usize> = cfg
        .list("ratios", if quick { &["5", "25"] } else { &["5", "10", "25", "50"] })
        .iter()
        .map(|s| s.parse().unwrap())
        .collect();

    for model in &models {
        ensure_fp_checkpoint(&session, &cfg, model, cfg.usize("train.epochs", 5)).unwrap();
        let bits_set: Vec<String> = match model.as_str() {
            "bert_tiny" => cfg.list("bits", &["w8a8", "w4a8"]),
            "resnet8" => cfg.list("bits", &["w8a8", "w4a8"]),
            _ => {
                if quick {
                    cfg.list("bits", &["w4a8"])
                } else {
                    cfg.list("bits", &["w8a8", "w4a8", "w4a4"])
                }
            }
        };
        for bits in &bits_set {
            let mut header = vec!["mode".to_string(), "PTQ".to_string(), "0%".to_string()];
            header.extend(ratios.iter().map(|r| format!("{r}%")));
            header.push("QAT".to_string());
            let hdr: Vec<&str> = header.iter().map(String::as_str).collect();
            let mut t = Table::new(&format!("Table 4: {model} {bits} (headline)"), &hdr);

            let run_cell = |mode: &str, ratio: usize| -> (f32, f32, f32) {
                let mut ptqs = Vec::new();
                let mut effs = Vec::new();
                for &seed in &seeds {
                    let mut c = cfg.clone();
                    c.set("train.seed", &seed.to_string());
                    c.set("data.seed", &seed.to_string());
                    let s = run_efqat_pipeline(&session, &c, model, bits, mode, ratio).unwrap();
                    ptqs.push(s.ptq_headline);
                    effs.push(s.efqat_headline);
                }
                let (pm, _) = mean_std(&ptqs);
                let (em, es) = mean_std(&effs);
                (pm, em, es)
            };

            let (ptq_ref, r0, _) = run_cell("r0", 0);
            let (_, qat, _) = run_cell("qat", 100);
            for mode in ["cwpl", "cwpn", "lwpn"] {
                let mut row = vec![
                    mode.to_uppercase(),
                    format!("{ptq_ref:.2}"),
                    format!("{r0:.2}"),
                ];
                for &r in &ratios {
                    let (_, em, es) = run_cell(mode, r);
                    row.push(if seeds.len() > 1 {
                        format!("{em:.2}±{es:.2}")
                    } else {
                        format!("{em:.2}")
                    });
                }
                row.push(format!("{qat:.2}"));
                t.row(&row);
            }
            t.print();
            t.write_csv(std::path::Path::new("bench_out/table4_accuracy.csv")).unwrap();
        }
    }
    println!(
        "\npaper shape check: PTQ < 0% < EfQAT(r) ≤ QAT, rising with r; modes within noise."
    );
}
