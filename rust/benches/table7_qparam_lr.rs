//! Paper Table 7 (Appendix A.2): qparam learning rate × raw-vs-log scales.
//!
//!   cargo bench --bench table7_qparam_lr [-- --model resnet20 --bits w8a8]
//!
//! Trains EfQAT-CWPN with the nominal Adam LR and a 100× larger one, with
//! the scales optimized directly (raw) and in the log domain (TQT-style).
//! Paper's claim: EfQAT is robust to the LR and raw ≥ log throughout.

mod common;

use efqat::coordinator::pipeline::{ensure_fp_checkpoint, run_efqat_pipeline};
use efqat::harness::Table;

fn main() {
    let cfg = common::bench_config();
    let session = common::session(&cfg);
    let model = cfg.str("model", "resnet20");
    let bits = cfg.str("bits", "w8a8");
    let ratios: Vec<usize> = cfg
        .list("ratios", &["0", "5", "25"])
        .iter()
        .map(|s| s.parse().unwrap())
        .collect();
    let nominal = cfg.f32("train.lr_q", 1e-6);

    ensure_fp_checkpoint(&session, &cfg, &model, cfg.usize("train.epochs", 5)).unwrap();

    let mut header = vec!["qparam func".to_string(), "LR".to_string()];
    header.extend(ratios.iter().map(|r| format!("{r}%")));
    let hdr: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = Table::new(&format!("Table 7: {model} {bits}, EfQAT-CWPN"), &hdr);

    for (log_scales, label) in [(false, "raw"), (true, "log")] {
        for lr in [nominal, nominal * 100.0] {
            let mut row = vec![label.to_string(), format!("{lr:.0e}")];
            for &r in &ratios {
                let mut c = cfg.clone();
                c.set("train.lr_q", &lr.to_string());
                c.set("train.log_scales", if log_scales { "true" } else { "false" });
                let mode = if r == 0 { "r0" } else { "cwpn" };
                let s = run_efqat_pipeline(&session, &c, &model, &bits, mode, r).unwrap();
                row.push(format!("{:.2}", s.efqat_headline));
            }
            t.row(&row);
        }
    }
    t.print();
    t.write_csv(std::path::Path::new("bench_out/table7_qparam_lr.csv")).unwrap();
    println!("\npaper shape check: all cells within noise; raw ≥ log.");
}
