//! Serving latency/throughput under dynamic micro-batching: offered
//! load × batcher settings, per-request latency percentiles.
//!
//! For each (submitters, max_batch) cell, S submitter threads each fire
//! `requests` single-example requests at the serving runtime
//! (`efqat::serve`), keeping a `window` of them in flight (pipelined
//! closed loop), so offered load scales with S × window and the batcher
//! sees real backlog rather than lockstep arrivals.  Per-request latency
//! (submit → logits, queueing included) lands in p50/p95/p99; completed
//! examples over wall time is the throughput.  The worker pool is pinned
//! to one thread so the lever being measured is *batching*, not worker
//! parallelism: at `max_batch 1` every request pays its own queue hops
//! and GEMM, at `max_batch ≥ 8` the `u8×i8→i32` GEMMs amortize — the
//! north-star check asserts batched throughput beats unbatched at the
//! highest offered load.
//!
//! A second leg runs the multi-model registry: two models served from
//! one runtime under concurrent load, reported per model, plus a
//! checkpoint hot swap landed mid-load — `swap_latency_ms` is the time
//! from `Registry::install` to the first reply served by the new
//! checkpoint.
//!
//! A third leg replays synthesized **bursty** traffic (RFC 0006 replay
//! records: short arrival bursts separated by idle gaps) through the
//! static and the adaptive batcher and reports per-stage
//! (queue/batch/exec) percentiles from the trace layer — the adaptive
//! window must beat the static one on p95 for bursty arrivals, and a
//! steady closed-loop adaptive cell must hold throughput within 5% of
//! static.  Results go to `BENCH_latency.json` (`cells`, `two_model`,
//! `swap_latency_ms`, `bursty`, `adaptive_steady`) and
//! `bench_out/serve_latency.csv`.
//!
//!   cargo bench --bench serve_latency [-- --full true]
//!   cargo bench --bench serve_latency -- --model mlp --requests 200 --wait-ms 1
mod common;

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use efqat::backend::Value;
use efqat::graph::InputKind;
use efqat::harness::Table;
use efqat::json::Json;
use efqat::lower::{lower, QuantizedGraph};
use efqat::rng::Pcg64;
use efqat::serve::replay::{replay, ReplayRecord};
use efqat::serve::{BatchCfg, Registry, Server, ServeCfg, StagePcts, Ticket};
use efqat::tensor::{ITensor, Tensor};

/// Percentile over a sorted sample (nearest-rank on the inclusive grid).
fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

fn example(kind: InputKind, classes: usize, rng: &mut Pcg64) -> Value {
    match kind {
        InputKind::Image { channels, hw } => Value::F32(Tensor {
            shape: vec![channels, hw, hw],
            data: rng.normal_vec(channels * hw * hw, 1.0),
        }),
        InputKind::Tokens { seq } => Value::I32(ITensor {
            shape: vec![seq],
            data: (0..seq).map(|_| rng.below(classes) as i32).collect(),
        }),
    }
}

/// The bench model lowered at a chosen init seed (distinct seeds stand
/// in for successive training checkpoints of one model).
fn lowered_at(model: &str, seed: u64) -> Arc<QuantizedGraph> {
    let (g, params, q) = efqat::testing::synth_lowering_fixture_seeded(model, seed);
    Arc::new(lower(&g, &params, &q, 8, 8).unwrap())
}

/// Pipelined closed-loop submitter: keeps `window` requests in flight
/// against `model` (`None` = the default model), returns per-request
/// latency in ms (submit → logits, queueing included).  `done` counts
/// completions for cross-thread progress gating.
#[allow(clippy::too_many_arguments)]
fn pump(
    server: &Server,
    model: Option<&str>,
    kind: InputKind,
    classes: usize,
    requests: usize,
    window: usize,
    seed: u64,
    done: Option<&AtomicUsize>,
) -> Vec<f64> {
    let mut rng = Pcg64::new(seed);
    let mut lats = Vec::with_capacity(requests);
    let mut inflight: VecDeque<(Instant, Ticket)> = VecDeque::with_capacity(window);
    let mut drain = |(q0, tk): (Instant, Ticket), lats: &mut Vec<f64>| {
        tk.wait().expect("request failed");
        lats.push(q0.elapsed().as_secs_f64() * 1e3);
        if let Some(d) = done {
            d.fetch_add(1, Ordering::Relaxed);
        }
    };
    for _ in 0..requests {
        if inflight.len() >= window {
            let head = inflight.pop_front().unwrap();
            drain(head, &mut lats);
        }
        let x = example(kind, classes, &mut rng);
        let tk = server.try_submit(model, x).unwrap_or_else(|e| panic!("submit: {e}"));
        inflight.push_back((Instant::now(), tk));
    }
    for pair in inflight {
        drain(pair, &mut lats);
    }
    lats
}

/// One closed-loop cell: `submitters` pipelined submitter threads
/// against a fresh single-model server, returning per-request latencies
/// (ms) and elapsed wall seconds.
fn closed_loop(
    engine: &Arc<QuantizedGraph>,
    scfg: ServeCfg,
    submitters: usize,
    requests: usize,
    window: usize,
) -> (Vec<f64>, f64) {
    let (kind, classes) = (engine.input, engine.classes);
    let server = Server::single(engine.clone(), scfg);
    let t0 = Instant::now();
    let lat_ms: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..submitters)
            .map(|si| {
                let server = &server;
                s.spawn(move || {
                    pump(server, None, kind, classes, requests, window, 1000 + si as u64, None)
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let elapsed = t0.elapsed().as_secs_f64();
    server.shutdown();
    (lat_ms, elapsed)
}

/// Synthesized bursty arrivals (RFC 0006 replay records): `n_bursts`
/// bursts of `burst` requests 20µs apart, separated by `gap_us` of idle
/// — the arrival pattern a fixed flush window handles worst, because a
/// burst smaller than `max_batch` always waits out the full deadline.
fn bursty_records(
    kind: InputKind,
    classes: usize,
    n_bursts: usize,
    burst: usize,
    gap_us: u64,
) -> Vec<ReplayRecord> {
    let mut rng = Pcg64::new(424242);
    let mut out = Vec::with_capacity(n_bursts * burst);
    for j in 0..n_bursts {
        for k in 0..burst {
            out.push(ReplayRecord {
                t_us: j as u64 * gap_us + k as u64 * 20,
                model: "m".to_string(),
                input: example(kind, classes, &mut rng),
            });
        }
    }
    out
}

/// Per-stage percentile snapshot as a JSON object (µs).
fn stage_json(p: &StagePcts) -> Json {
    let obj: BTreeMap<String, Json> = [
        ("p50_us".to_string(), Json::Num(p.p50_us)),
        ("p95_us".to_string(), Json::Num(p.p95_us)),
        ("p99_us".to_string(), Json::Num(p.p99_us)),
    ]
    .into_iter()
    .collect();
    Json::Obj(obj)
}

/// p50/p95/p99 + throughput for one latency sample, as a JSON cell.
fn cell(lat_ms: &mut Vec<f64>, elapsed_s: f64) -> (f64, f64, f64, f64, BTreeMap<String, Json>) {
    lat_ms.sort_unstable_by(f64::total_cmp);
    let total = lat_ms.len() as f64;
    let tput = total / elapsed_s;
    let (p50, p95, p99) = (pct(lat_ms, 0.50), pct(lat_ms, 0.95), pct(lat_ms, 0.99));
    let cell: BTreeMap<String, Json> = [
        ("ex_per_s".to_string(), Json::Num(tput)),
        ("p50_ms".to_string(), Json::Num(p50)),
        ("p95_ms".to_string(), Json::Num(p95)),
        ("p99_ms".to_string(), Json::Num(p99)),
        ("requests".to_string(), Json::Num(total)),
    ]
    .into_iter()
    .collect();
    (tput, p50, p95, p99, cell)
}

fn main() {
    let cfg = common::bench_config_with(&[("model", "mlp")]);
    let quick = common::is_quick(&cfg);
    let model = cfg.str("model", "mlp");
    let requests = cfg.usize("requests", if quick { 400 } else { 4000 });
    let window = cfg.usize("window", 8).max(1);
    let workers = cfg.usize("workers", 1);
    let wait_ms = cfg.f32("wait-ms", 2.0);
    let submitter_counts: &[usize] = if quick { &[1, 32] } else { &[1, 8, 32] };
    let batch_sizes: &[usize] = &[1, 8, 32];
    let max_wait = Duration::from_secs_f32(wait_ms / 1e3);

    // lowered once from the shared synthetic fixture, reused by every cell
    let engine = lowered_at(&model, 1);
    let (kind, classes) = (engine.input, engine.classes);

    let mut t = Table::new(
        &format!("Serve latency: offered load × max_batch, {model} int8, {workers} worker(s)"),
        &["submitters", "max_batch", "ex/s", "p50 ms", "p95 ms", "p99 ms"],
    );
    let mut cells = BTreeMap::new();
    let mut unbatched_at_max_load = 0.0f64;
    let mut batched_at_max_load = 0.0f64;
    let mut static_b32_tput = 0.0f64;
    let max_load = *submitter_counts.last().unwrap();
    for &submitters in submitter_counts {
        for &max_batch in batch_sizes {
            let scfg = ServeCfg {
                batch: BatchCfg { max_batch, max_wait, adaptive: false },
                workers,
                queue_cap: 4096,
            };
            let (mut lat_ms, elapsed) = closed_loop(&engine, scfg, submitters, requests, window);
            let (tput, p50, p95, p99, c) = cell(&mut lat_ms, elapsed);
            if submitters == max_load {
                if max_batch == 1 {
                    unbatched_at_max_load = tput;
                } else if max_batch >= 8 {
                    batched_at_max_load = batched_at_max_load.max(tput);
                }
                if max_batch == 32 {
                    static_b32_tput = tput;
                }
            }
            t.row(&[
                submitters.to_string(),
                max_batch.to_string(),
                format!("{tput:.0}"),
                format!("{p50:.3}"),
                format!("{p95:.3}"),
                format!("{p99:.3}"),
            ]);
            cells.insert(format!("s{submitters}_b{max_batch}"), Json::Obj(c));
        }
    }
    t.print();

    // ---- two-model registry leg: per-model lanes + a hot swap under
    // load.  Model "a" starts on checkpoint 1 and is swapped to
    // checkpoint 2 once half its requests completed; "b" rides along to
    // show one lane's swap does not stall the other.
    let swapped = lowered_at(&model, 2);
    let registry = Registry::new();
    registry.install("a", engine.clone(), "fp-a-ckpt1").unwrap();
    registry.install("b", lowered_at(&model, 3), "fp-b-ckpt1").unwrap();
    let scfg = ServeCfg {
        batch: BatchCfg { max_batch: 8, max_wait, adaptive: false },
        workers,
        queue_cap: 4096,
    };
    let server = Server::start(registry, scfg).unwrap();
    let per_model_submitters = if quick { 2 } else { 4 };
    let per_model_requests = (requests / 2).max(50);
    let done_a = AtomicUsize::new(0);
    let swap_ms = Mutex::new(0.0f64);
    let t0 = Instant::now();
    let (mut lat_a, mut lat_b) = std::thread::scope(|s| {
        let spawn_lane = |name: &'static str, seed0: u64| {
            (0..per_model_submitters)
                .map(|si| {
                    let (server, done_a) = (&server, &done_a);
                    s.spawn(move || {
                        let done = (name == "a").then_some(done_a);
                        let seed = seed0 + si as u64;
                        pump(
                            server,
                            Some(name),
                            kind,
                            classes,
                            per_model_requests,
                            window,
                            seed,
                            done,
                        )
                    })
                })
                .collect::<Vec<_>>()
        };
        let a_handles = spawn_lane("a", 2000);
        let b_handles = spawn_lane("b", 3000);
        let (server, done_a, swapped, swap_ms) = (&server, &done_a, &swapped, &swap_ms);
        s.spawn(move || {
            // land the swap mid-load, then time install → first reply
            // actually served by the new checkpoint
            let target = per_model_submitters * per_model_requests / 2;
            while done_a.load(Ordering::Relaxed) < target {
                std::thread::sleep(Duration::from_millis(1));
            }
            let mut rng = Pcg64::new(7777);
            let t0 = Instant::now();
            server.registry().install("a", swapped.clone(), "fp-a-ckpt2").unwrap();
            loop {
                let x = example(kind, classes, &mut rng);
                let reply = server.try_submit(Some("a"), x).unwrap().wait_reply().unwrap();
                if &*reply.fingerprint == "fp-a-ckpt2" {
                    break;
                }
            }
            *swap_ms.lock().unwrap() = t0.elapsed().as_secs_f64() * 1e3;
        });
        let lat_a: Vec<f64> = a_handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        let lat_b: Vec<f64> = b_handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        (lat_a, lat_b)
    });
    let elapsed = t0.elapsed().as_secs_f64();
    server.shutdown();
    let swap_latency_ms = *swap_ms.lock().unwrap();
    assert!(swap_latency_ms > 0.0, "the swap probe never observed the new checkpoint");

    let mut t2 = Table::new(
        &format!(
            "Two-model registry: {per_model_submitters} submitters/model, \
             swap on \"a\" mid-load"
        ),
        &["model", "ex/s", "p50 ms", "p95 ms", "p99 ms"],
    );
    let mut two_model = BTreeMap::new();
    for (name, lat) in [("a", &mut lat_a), ("b", &mut lat_b)] {
        let (tput, p50, p95, p99, c) = cell(lat, elapsed);
        t2.row(&[
            name.to_string(),
            format!("{tput:.0}"),
            format!("{p50:.3}"),
            format!("{p95:.3}"),
            format!("{p99:.3}"),
        ]);
        two_model.insert(name.to_string(), Json::Obj(c));
    }
    t2.print();
    println!("swap latency (install -> first reply from new checkpoint): {swap_latency_ms:.3} ms");
    t.write_csv(std::path::Path::new("bench_out/serve_latency.csv")).unwrap();

    // ---- bursty replay leg: the same recorded arrival pattern through
    // the static and the adaptive flush window, with per-stage
    // percentiles read back from the trace layer (RFC 0006)
    let n_bursts = if quick { 30 } else { 120 };
    let gap_us = ((wait_ms as f64) * 4.0 * 1000.0).max(1000.0) as u64;
    let records = bursty_records(kind, classes, n_bursts, 6, gap_us);
    let mut bursty = BTreeMap::new();
    let mut bursty_p95 = BTreeMap::new();
    for (label, adaptive) in [("static", false), ("adaptive", true)] {
        let registry = Registry::new();
        registry.install("m", engine.clone(), "fp-m").unwrap();
        let scfg = ServeCfg {
            batch: BatchCfg { max_batch: 32, max_wait, adaptive },
            workers,
            queue_cap: 4096,
        };
        let server = Server::start(registry, scfg).unwrap();
        let report = replay(&server, &records, 1.0).unwrap();
        let mut lat = report.lat_ms.clone();
        let (tput, p50, p95, p99, mut c) = cell(&mut lat, report.wall.as_secs_f64());
        let st = server.stats().into_iter().next().unwrap();
        if let Some(tr) = &st.trace {
            c.insert("queue_us".to_string(), stage_json(&tr.queue));
            c.insert("batch_us".to_string(), stage_json(&tr.batch));
            c.insert("exec_us".to_string(), stage_json(&tr.exec));
            c.insert("total_us".to_string(), stage_json(&tr.total));
            c.insert("batch_fill".to_string(), Json::Num(st.batch_fill));
            c.insert("mean_batch".to_string(), Json::Num(tr.mean_batch));
        }
        server.shutdown();
        println!(
            "bursty replay [{label:>8}]: {tput:.0} ex/s, \
             p50 {p50:.3} p95 {p95:.3} p99 {p99:.3} ms"
        );
        bursty_p95.insert(label, p95);
        bursty.insert(label.to_string(), Json::Obj(c));
    }
    let bursty_ratio = bursty_p95["adaptive"] / bursty_p95["static"].max(1e-12);
    bursty.insert("adaptive_over_static_p95".to_string(), Json::Num(bursty_ratio));
    println!("bursty p95: adaptive/static = {bursty_ratio:.3}");
    if max_wait >= Duration::from_millis(1) {
        assert!(
            bursty_ratio < 1.0,
            "the adaptive flush window must beat the static one on bursty p95 \
             ({:.3} vs {:.3} ms)",
            bursty_p95["adaptive"],
            bursty_p95["static"]
        );
    }

    // ---- steady closed-loop adaptive cell: under sustained offered
    // load batches fill before any deadline, so adaptive and static must
    // converge — the adaptive window is not allowed to cost throughput.
    // Best of two runs to keep scheduler noise out of the ratio.
    let mut adaptive_tput = 0.0f64;
    let mut adaptive_cell = BTreeMap::new();
    for _ in 0..2 {
        let scfg = ServeCfg {
            batch: BatchCfg { max_batch: 32, max_wait, adaptive: true },
            workers,
            queue_cap: 4096,
        };
        let (mut lat, el) = closed_loop(&engine, scfg, max_load, requests, window);
        let (tput, _, _, _, c) = cell(&mut lat, el);
        if tput > adaptive_tput {
            adaptive_tput = tput;
            adaptive_cell = c;
        }
    }
    let steady_ratio = adaptive_tput / static_b32_tput.max(1e-12);
    adaptive_cell.insert("tput_over_static".to_string(), Json::Num(steady_ratio));
    println!(
        "steady adaptive at {max_load} submitters: {adaptive_tput:.0} ex/s \
         ({steady_ratio:.3}x static b32)"
    );
    assert!(
        steady_ratio >= 0.95,
        "adaptive batching must hold steady-state throughput within 5% of static \
         ({adaptive_tput:.0} vs {static_b32_tput:.0} ex/s)"
    );

    let speedup = batched_at_max_load / unbatched_at_max_load.max(1e-12);
    let doc: BTreeMap<String, Json> = [
        ("bench".to_string(), Json::Str("serve_latency".to_string())),
        ("model".to_string(), Json::Str(model.clone())),
        ("kernel".to_string(), Json::Str(efqat::ops::simd::active().name.to_string())),
        ("workers".to_string(), Json::Num(workers as f64)),
        ("wait_ms".to_string(), Json::Num(wait_ms as f64)),
        ("window".to_string(), Json::Num(window as f64)),
        ("requests_per_submitter".to_string(), Json::Num(requests as f64)),
        ("cells".to_string(), Json::Obj(cells)),
        ("two_model".to_string(), Json::Obj(two_model)),
        ("bursty".to_string(), Json::Obj(bursty)),
        ("adaptive_steady".to_string(), Json::Obj(adaptive_cell)),
        ("swap_latency_ms".to_string(), Json::Num(swap_latency_ms)),
        ("unbatched_ex_per_s_at_max_load".to_string(), Json::Num(unbatched_at_max_load)),
        ("batched_ex_per_s_at_max_load".to_string(), Json::Num(batched_at_max_load)),
        ("batched_over_unbatched".to_string(), Json::Num(speedup)),
    ]
    .into_iter()
    .collect();
    std::fs::write("BENCH_latency.json", Json::Obj(doc).render()).unwrap();
    println!(
        "\nwrote BENCH_latency.json (per-cell + per-model latency, bursty replay \
         with per-stage percentiles, swap latency)"
    );
    println!(
        "north-star check: batched throughput at {max_load} submitters is {speedup:.2}x unbatched"
    );
    assert!(
        speedup > 1.0,
        "micro-batching must beat unbatched serving at max offered load \
         ({batched_at_max_load:.0} vs {unbatched_at_max_load:.0} ex/s)"
    );
}
