//! Serving latency/throughput under dynamic micro-batching: offered
//! load × batcher settings, per-request latency percentiles.
//!
//! For each (submitters, max_batch) cell, S submitter threads each fire
//! `requests` single-example requests at the serving runtime
//! (`efqat::serve`), keeping a `window` of them in flight (pipelined
//! closed loop), so offered load scales with S × window and the batcher
//! sees real backlog rather than lockstep arrivals.  Per-request latency
//! (submit → logits, queueing included) lands in p50/p95/p99; completed
//! examples over wall time is the throughput.  The worker pool is pinned
//! to one thread so the lever being measured is *batching*, not worker
//! parallelism: at `max_batch 1` every request pays its own queue hops
//! and GEMM, at `max_batch ≥ 8` the `u8×i8→i32` GEMMs amortize — the
//! north-star check asserts batched throughput beats unbatched at the
//! highest offered load.  Results go to `BENCH_latency.json` and
//! `bench_out/serve_latency.csv`.
//!
//!   cargo bench --bench serve_latency [-- --full true]
//!   cargo bench --bench serve_latency -- --model mlp --requests 200 --wait-ms 1

mod common;

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use efqat::backend::Value;
use efqat::graph::InputKind;
use efqat::harness::Table;
use efqat::json::Json;
use efqat::lower::lower;
use efqat::rng::Pcg64;
use efqat::serve::{BatchCfg, Engine, Server, ServeCfg};
use efqat::tensor::{ITensor, Tensor};

/// Percentile over a sorted sample (nearest-rank on the inclusive grid).
fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

fn example(kind: InputKind, classes: usize, rng: &mut Pcg64) -> Value {
    match kind {
        InputKind::Image { channels, hw } => Value::F32(Tensor {
            shape: vec![channels, hw, hw],
            data: rng.normal_vec(channels * hw * hw, 1.0),
        }),
        InputKind::Tokens { seq } => Value::I32(ITensor {
            shape: vec![seq],
            data: (0..seq).map(|_| rng.below(classes) as i32).collect(),
        }),
    }
}

fn main() {
    let cfg = common::bench_config_with(&[("model", "mlp")]);
    let quick = common::is_quick(&cfg);
    let model = cfg.str("model", "mlp");
    let requests = cfg.usize("requests", if quick { 400 } else { 4000 });
    let window = cfg.usize("window", 8).max(1);
    let workers = cfg.usize("workers", 1);
    let wait_ms = cfg.f32("wait-ms", 2.0);
    let submitter_counts: &[usize] = if quick { &[1, 32] } else { &[1, 8, 32] };
    let batch_sizes: &[usize] = &[1, 8, 32];

    // lowered once from the shared synthetic fixture, reused by every cell
    let (base, params, q) = efqat::testing::synth_lowering_fixture(&model);
    let engine = Arc::new(lower(&base, &params, &q, 8, 8).unwrap());

    let mut t = Table::new(
        &format!("Serve latency: offered load × max_batch, {model} int8, {workers} worker(s)"),
        &["submitters", "max_batch", "ex/s", "p50 ms", "p95 ms", "p99 ms"],
    );
    let mut cells = BTreeMap::new();
    let mut unbatched_at_max_load = 0.0f64;
    let mut batched_at_max_load = 0.0f64;
    let max_load = *submitter_counts.last().unwrap();
    for &submitters in submitter_counts {
        for &max_batch in batch_sizes {
            let scfg = ServeCfg {
                batch: BatchCfg {
                    max_batch,
                    max_wait: Duration::from_secs_f32(wait_ms / 1e3),
                },
                workers,
                queue_cap: 4096,
            };
            let server = Server::start(engine.clone() as Arc<dyn Engine>, scfg);
            let t0 = Instant::now();
            let mut lat_ms: Vec<f64> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..submitters)
                    .map(|si| {
                        let (server, engine) = (&server, &engine);
                        s.spawn(move || {
                            let mut rng = Pcg64::new(1000 + si as u64);
                            let mut lats = Vec::with_capacity(requests);
                            let mut inflight = std::collections::VecDeque::with_capacity(window);
                            for _ in 0..requests {
                                if inflight.len() >= window {
                                    let (q0, tk): (Instant, efqat::serve::Ticket) =
                                        inflight.pop_front().unwrap();
                                    tk.wait().expect("request failed");
                                    lats.push(q0.elapsed().as_secs_f64() * 1e3);
                                }
                                let x = example(engine.input, engine.classes, &mut rng);
                                inflight.push_back((Instant::now(), server.submit(x).unwrap()));
                            }
                            for (q0, tk) in inflight {
                                tk.wait().expect("request failed");
                                lats.push(q0.elapsed().as_secs_f64() * 1e3);
                            }
                            lats
                        })
                    })
                    .collect();
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
            });
            let elapsed = t0.elapsed().as_secs_f64();
            server.shutdown();
            lat_ms.sort_unstable_by(f64::total_cmp);
            let total = (submitters * requests) as f64;
            let tput = total / elapsed;
            let (p50, p95, p99) = (pct(&lat_ms, 0.50), pct(&lat_ms, 0.95), pct(&lat_ms, 0.99));
            if submitters == max_load {
                if max_batch == 1 {
                    unbatched_at_max_load = tput;
                } else if max_batch >= 8 {
                    batched_at_max_load = batched_at_max_load.max(tput);
                }
            }
            t.row(&[
                submitters.to_string(),
                max_batch.to_string(),
                format!("{tput:.0}"),
                format!("{p50:.3}"),
                format!("{p95:.3}"),
                format!("{p99:.3}"),
            ]);
            let cell: BTreeMap<String, Json> = [
                ("ex_per_s".to_string(), Json::Num(tput)),
                ("p50_ms".to_string(), Json::Num(p50)),
                ("p95_ms".to_string(), Json::Num(p95)),
                ("p99_ms".to_string(), Json::Num(p99)),
                ("requests".to_string(), Json::Num(total)),
            ]
            .into_iter()
            .collect();
            cells.insert(format!("s{submitters}_b{max_batch}"), Json::Obj(cell));
        }
    }
    t.print();
    t.write_csv(std::path::Path::new("bench_out/serve_latency.csv")).unwrap();

    let speedup = batched_at_max_load / unbatched_at_max_load.max(1e-12);
    let doc: BTreeMap<String, Json> = [
        ("bench".to_string(), Json::Str("serve_latency".to_string())),
        ("model".to_string(), Json::Str(model.clone())),
        ("kernel".to_string(), Json::Str(efqat::ops::simd::active().name.to_string())),
        ("workers".to_string(), Json::Num(workers as f64)),
        ("wait_ms".to_string(), Json::Num(wait_ms as f64)),
        ("window".to_string(), Json::Num(window as f64)),
        ("requests_per_submitter".to_string(), Json::Num(requests as f64)),
        ("cells".to_string(), Json::Obj(cells)),
        ("unbatched_ex_per_s_at_max_load".to_string(), Json::Num(unbatched_at_max_load)),
        ("batched_ex_per_s_at_max_load".to_string(), Json::Num(batched_at_max_load)),
        ("batched_over_unbatched".to_string(), Json::Num(speedup)),
    ]
    .into_iter()
    .collect();
    std::fs::write("BENCH_latency.json", Json::Obj(doc).render()).unwrap();
    println!("\nwrote BENCH_latency.json (p50/p95/p99 latency + examples/sec per cell)");
    println!(
        "north-star check: batched throughput at {max_load} submitters is {speedup:.2}x unbatched"
    );
    assert!(
        speedup > 1.0,
        "micro-batching must beat unbatched serving at max offered load \
         ({batched_at_max_load:.0} vs {unbatched_at_max_load:.0} ex/s)"
    );
}
