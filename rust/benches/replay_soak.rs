//! Deterministic stress/soak suite over the record/replay harness
//! (RFC 0006): synthesize a bursty two-model traffic trace, write it to
//! `bench_out/trace_soak.jsonl` (the CI artifact), then replay it at N×
//! speed against a fresh registry while a checkpoint hot swap lands on
//! one lane mid-replay.
//!
//! Hard failure conditions, checked per reply:
//!
//! * **dropped** — the replay must return exactly one reply per record
//!   (`overloaded` verdicts are retried, never dropped);
//! * **mis-routed** — `replies[i]` must name `records[i]`'s lane, its
//!   fingerprint must be one this run installed on that lane, and its
//!   logits must be bit-identical to an offline batch-of-1 forward of
//!   the record's payload through the engine that fingerprint names;
//! * **swap invisible** — both the pre-swap and post-swap checkpoint of
//!   the swapped lane must answer at least once.
//!
//! Results go to `BENCH_soak.json`.
//!
//!   cargo bench --bench replay_soak [-- --full true] [-- --speed 8]
mod common;

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use efqat::backend::Value;
use efqat::graph::InputKind;
use efqat::json::Json;
use efqat::lower::{lower, QuantizedGraph};
use efqat::rng::Pcg64;
use efqat::serve::replay::{load_trace, replay, write_trace, ReplayRecord};
use efqat::serve::{BatchCfg, Registry, Server, ServeCfg};
use efqat::tensor::{ITensor, Tensor};

fn lowered_at(model: &str, seed: u64) -> Arc<QuantizedGraph> {
    let (g, params, q) = efqat::testing::synth_lowering_fixture_seeded(model, seed);
    Arc::new(lower(&g, &params, &q, 8, 8).unwrap())
}

fn example(kind: InputKind, classes: usize, rng: &mut Pcg64) -> Value {
    match kind {
        InputKind::Image { channels, hw } => Value::F32(Tensor {
            shape: vec![channels, hw, hw],
            data: rng.normal_vec(channels * hw * hw, 1.0),
        }),
        InputKind::Tokens { seq } => Value::I32(ITensor {
            shape: vec![seq],
            data: (0..seq).map(|_| rng.below(classes) as i32).collect(),
        }),
    }
}

fn unit_batch(v: &Value) -> Value {
    match v {
        Value::F32(t) => {
            let mut shape = vec![1];
            shape.extend_from_slice(&t.shape);
            Value::F32(Tensor { shape, data: t.data.clone() })
        }
        Value::I32(t) => {
            let mut shape = vec![1];
            shape.extend_from_slice(&t.shape);
            Value::I32(ITensor { shape, data: t.data.clone() })
        }
    }
}

fn main() {
    let cfg = common::bench_config_with(&[("model", "mlp")]);
    let quick = common::is_quick(&cfg);
    let model = cfg.str("model", "mlp");
    let speed = cfg.f32("speed", 8.0) as f64;
    let n_bursts = cfg.usize("bursts", if quick { 100 } else { 600 });
    let burst = cfg.usize("burst", 4);
    let gap_us = cfg.u64("gap-us", 20_000);

    // lane "a" swaps checkpoints mid-replay; lane "b" must ride through
    // untouched.  Fingerprint → engine is the mis-route oracle.
    let a1 = lowered_at(&model, 1);
    let a2 = lowered_at(&model, 2);
    let b1 = lowered_at(&model, 3);
    let (kind, classes) = (a1.input, a1.classes);
    let mut engines: BTreeMap<&str, &Arc<QuantizedGraph>> = BTreeMap::new();
    engines.insert("fp-a-1", &a1);
    engines.insert("fp-a-2", &a2);
    engines.insert("fp-b-1", &b1);

    // synthesize, write, and re-load the trace: the replayed records are
    // exactly what a future `efqat replay` of the artifact would see
    let mut rng = Pcg64::new(99);
    let mut records = Vec::with_capacity(n_bursts * burst);
    for j in 0..n_bursts {
        for k in 0..burst {
            let name = if (j + k) % 2 == 0 { "a" } else { "b" };
            records.push(ReplayRecord {
                t_us: j as u64 * gap_us + k as u64 * 25,
                model: name.to_string(),
                input: example(kind, classes, &mut rng),
            });
        }
    }
    std::fs::create_dir_all("bench_out").unwrap();
    write_trace("bench_out/trace_soak.jsonl", &records).unwrap();
    let records = load_trace("bench_out/trace_soak.jsonl").unwrap();
    assert_eq!(records.len(), n_bursts * burst, "trace artifact lost records");

    let registry = Registry::new();
    registry.install("a", a1.clone(), "fp-a-1").unwrap();
    registry.install("b", b1.clone(), "fp-b-1").unwrap();
    let scfg = ServeCfg {
        batch: BatchCfg { max_batch: 16, max_wait: Duration::from_millis(2), adaptive: true },
        workers: 2,
        queue_cap: 4096,
    };
    let server = Server::start(registry, scfg).unwrap();

    // land the swap halfway through the replayed timeline: submissions
    // are paced by arrival deadlines, so at span/2 about half the trace
    // is still ahead of the swap
    let span_ms = (records.last().unwrap().t_us as f64 / speed) / 1e3;
    let report = std::thread::scope(|s| {
        let (server, a2) = (&server, &a2);
        s.spawn(move || {
            std::thread::sleep(Duration::from_millis((span_ms / 2.0) as u64));
            server.registry().install("a", a2.clone(), "fp-a-2").unwrap();
        });
        replay(server, &records, speed).unwrap()
    });

    // dropped / mis-routed checks, reply by reply
    assert_eq!(report.replies.len(), records.len(), "soak dropped replies");
    let mut fp_counts: BTreeMap<String, u64> = BTreeMap::new();
    for (i, (reply, rec)) in report.replies.iter().zip(&records).enumerate() {
        assert_eq!(&*reply.model, rec.model.as_str(), "record {i} answered by the wrong lane");
        let engine = engines
            .get(&*reply.fingerprint)
            .unwrap_or_else(|| panic!("record {i}: unknown fingerprint {}", reply.fingerprint));
        let want = engine.forward_owned(unit_batch(&rec.input)).unwrap();
        assert_eq!(reply.logits.data, want.data, "record {i} diverged from its fingerprint");
        *fp_counts.entry(reply.fingerprint.to_string()).or_insert(0) += 1;
    }
    assert!(fp_counts.contains_key("fp-a-1"), "pre-swap checkpoint never answered: {fp_counts:?}");
    assert!(fp_counts.contains_key("fp-a-2"), "post-swap checkpoint never answered: {fp_counts:?}");
    assert!(fp_counts.contains_key("fp-b-1"), "the untouched lane never answered: {fp_counts:?}");

    let wall_ms = report.wall.as_secs_f64() * 1e3;
    println!(
        "replay soak: {} records at {speed}x in {wall_ms:.0} ms ({} retried), \
         p50/p95/p99 {:.3}/{:.3}/{:.3} ms",
        records.len(),
        report.retries,
        report.lat_pct(0.50),
        report.lat_pct(0.95),
        report.lat_pct(0.99)
    );
    println!("per-fingerprint replies: {fp_counts:?}");

    let mut stage = BTreeMap::new();
    for st in server.stats() {
        if let Some(tr) = &st.trace {
            let obj: BTreeMap<String, Json> = [
                ("events".to_string(), Json::Num(tr.events as f64)),
                ("batches".to_string(), Json::Num(tr.batches as f64)),
                ("batch_fill".to_string(), Json::Num(st.batch_fill)),
                ("queue_p95_us".to_string(), Json::Num(tr.queue.p95_us)),
                ("batch_p95_us".to_string(), Json::Num(tr.batch.p95_us)),
                ("exec_p95_us".to_string(), Json::Num(tr.exec.p95_us)),
                ("total_p95_us".to_string(), Json::Num(tr.total.p95_us)),
            ]
            .into_iter()
            .collect();
            stage.insert(st.model.clone(), Json::Obj(obj));
        }
    }
    server.shutdown();

    let fps: BTreeMap<String, Json> =
        fp_counts.iter().map(|(k, &v)| (k.clone(), Json::Num(v as f64))).collect();
    let doc: BTreeMap<String, Json> = [
        ("bench".to_string(), Json::Str("replay_soak".to_string())),
        ("model".to_string(), Json::Str(model.clone())),
        ("records".to_string(), Json::Num(records.len() as f64)),
        ("speed".to_string(), Json::Num(speed)),
        ("wall_ms".to_string(), Json::Num(wall_ms)),
        ("retries".to_string(), Json::Num(report.retries as f64)),
        ("p50_ms".to_string(), Json::Num(report.lat_pct(0.50))),
        ("p95_ms".to_string(), Json::Num(report.lat_pct(0.95))),
        ("p99_ms".to_string(), Json::Num(report.lat_pct(0.99))),
        ("replies_per_fingerprint".to_string(), Json::Obj(fps)),
        ("lanes".to_string(), Json::Obj(stage)),
    ]
    .into_iter()
    .collect();
    std::fs::write("BENCH_soak.json", Json::Obj(doc).render()).unwrap();
    println!("wrote BENCH_soak.json and bench_out/trace_soak.jsonl");
}
