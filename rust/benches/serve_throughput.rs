//! Serving throughput: the lowered int8 engine vs the fake-quant float
//! forward — the first *deployed-arithmetic* entry in the perf
//! trajectory.
//!
//! For each native model × batch size we time (a) the float serving
//! path — the `w8a8` graph forward-to-logits, which fake-quants weights
//! and activations in f32 on every call — and (b) one
//! [`efqat::lower::QuantizedGraph`] forward, whose weights were
//! quantized to i8 once at lowering time and whose GEMMs run
//! `u8×i8→i32`.  Both sides stop at logits (no loss/metric work), so the
//! speedup isolates the quantized kernels.  Examples/sec for both,
//! speedup, and the max per-logit deviation land in
//! `bench_out/serve_throughput.csv` and `BENCH_serve.json`.
//!
//!   cargo bench --bench serve_throughput [-- --full true]
//!   cargo bench --bench serve_throughput -- --models mlp --iters 50

mod common;

use std::collections::BTreeMap;

use efqat::backend::native::model_graph;
use efqat::backend::Value;
use efqat::coordinator::binder::{bind_inputs, BindCtx};
use efqat::data::Batch;
use efqat::graph::{GraphStep, InputKind, StepId, StepKind};
use efqat::harness::{bench, Table};
use efqat::json::Json;
use efqat::lower::lower;
use efqat::model::{ParamStore, StateStore};
use efqat::ops::simd;
use efqat::rng::Pcg64;
use efqat::tensor::{ITensor, Tensor};

fn max_abs_dev(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).fold(0.0, f64::max)
}

fn main() {
    let cfg = common::bench_config_with(&[("models", "mlp,convnet,tiny_tf")]);
    let quick = common::is_quick(&cfg);
    let iters = cfg.usize("iters", if quick { 15 } else { 50 });
    let models: Vec<String> = cfg.list("models", &["mlp"]);
    let bits = cfg.str("bits", "w8a8");
    let (w_bits, a_bits) = efqat::quant::parse_bits_tag(&bits).expect("bits tag");
    let batches: &[usize] = if quick { &[1, 32] } else { &[1, 8, 32, 128] };

    // the kernel EFQAT_SIMD/auto dispatch resolved for this process —
    // each timed int8 leg runs twice, dispatched and forced-scalar, so
    // the SIMD speedup is measured in the same process and gated below
    let kernel = simd::active().name;
    let mut t = Table::new(
        &format!("Serving throughput: int8 engine ({kernel}) vs fake-quant float fwd, {bits}"),
        &["model", "batch", "float ex/s", "int8 ex/s", "speedup", "simd/scalar", "max |Δlogit|"],
    );
    let mut report = BTreeMap::new();
    let mut best_speedup_b32 = 0.0f64;
    let mut best_simd_b8 = 0.0f64;
    for model in &models {
        let base = model_graph(model).unwrap_or_else(|| panic!("{model}: not a native model"));
        let id = StepId { kind: StepKind::Fwd, w_bits, a_bits };
        let man0 = efqat::graph::build_manifest(&base, &format!("{model}_{bits}_fwd"), &id);
        let params = ParamStore::init(&man0, 0);
        let q = common::synth_qparams(&man0, &params, w_bits, a_bits, 0.05);
        // lowered once: i8 weights are frozen here, not per call
        let qg = lower(&base, &params, &q, w_bits, a_bits).unwrap();

        let mut per_batch = BTreeMap::new();
        for &b in batches {
            let mut g = base.clone();
            g.batch = b;
            let step = GraphStep::new(g, &format!("{model}_{bits}_fwd_b{b}"), id).unwrap();
            let mut rng = Pcg64::new(17 + b as u64);
            // one synthetic batch: x plus zero labels, bound through the
            // coordinator's real binder (one role-dispatch in the tree)
            let mut batch = Batch { f32s: BTreeMap::new(), i32s: BTreeMap::new(), count: b };
            let x = match base.input {
                InputKind::Image { channels, hw } => {
                    batch.i32s.insert("y".into(), ITensor::zeros(&[b]));
                    Value::F32(Tensor {
                        shape: vec![b, channels, hw, hw],
                        data: rng.normal_vec(b * channels * hw * hw, 1.0),
                    })
                }
                InputKind::Tokens { seq } => {
                    batch.i32s.insert("y".into(), ITensor::zeros(&[b, seq]));
                    Value::I32(ITensor {
                        shape: vec![b, seq],
                        data: (0..b * seq).map(|_| rng.below(base.classes) as i32).collect(),
                    })
                }
            };
            match &x {
                Value::F32(t) => {
                    batch.f32s.insert("x".into(), t.clone());
                }
                Value::I32(t) => {
                    batch.i32s.insert("x".into(), t.clone());
                }
            }
            let states = StateStore::init(&step.man);
            let ctx = BindCtx {
                params: &params,
                qparams: Some(&q),
                states: &states,
                batch: &batch,
                selection: None,
            };
            let inputs = bind_inputs(&step.man, &ctx).unwrap();

            // parity before timing: the two engines must agree on logits
            let float_logits = step.forward_logits(&inputs).unwrap();
            let int8_logits = qg.forward(&x).unwrap();
            let dev = max_abs_dev(&float_logits.data, &int8_logits.data);

            // both sides run forward-to-logits only (no loss/metrics) over
            // a reused workspace — the planned-executor steady state the
            // serving workers actually run — so the speedup is the
            // quantized GEMMs vs the fake-quant f32 path
            let mut fws = efqat::exec::Workspace::new();
            let fs = bench(2, iters, || {
                let y = step.forward_logits_ws(&inputs, &mut fws).unwrap();
                fws.give_tensor(y);
            });
            let mut iws = efqat::exec::Workspace::new();
            let is = bench(2, iters, || {
                let y = qg.forward_into(&x, &mut iws).unwrap();
                iws.give_f32(y);
            });
            // same GEMMs forced onto the scalar oracle: the SIMD payoff,
            // measured in-process on identical inputs and workspace state
            simd::force(Some(0));
            let mut sws = efqat::exec::Workspace::new();
            let ss = bench(2, iters, || {
                let y = qg.forward_into(&x, &mut sws).unwrap();
                sws.give_f32(y);
            });
            simd::force(None);
            let f_ex = b as f64 / fs.mean;
            let i_ex = b as f64 / is.mean;
            let s_ex = b as f64 / ss.mean;
            let speedup = fs.mean / is.mean;
            let simd_speedup = ss.mean / is.mean;
            if b >= 32 {
                best_speedup_b32 = best_speedup_b32.max(speedup);
            }
            if b >= 8 {
                best_simd_b8 = best_simd_b8.max(simd_speedup);
            }
            t.row(&[
                model.clone(),
                b.to_string(),
                format!("{f_ex:.0}"),
                format!("{i_ex:.0}"),
                format!("{speedup:.2}x"),
                format!("{simd_speedup:.2}x"),
                format!("{dev:.2e}"),
            ]);
            let entry: BTreeMap<String, Json> = [
                ("float_ex_per_s".to_string(), Json::Num(f_ex)),
                ("int8_ex_per_s".to_string(), Json::Num(i_ex)),
                ("int8_scalar_ex_per_s".to_string(), Json::Num(s_ex)),
                ("speedup".to_string(), Json::Num(speedup)),
                ("simd_speedup".to_string(), Json::Num(simd_speedup)),
                ("max_logit_dev".to_string(), Json::Num(dev)),
            ]
            .into_iter()
            .collect();
            per_batch.insert(format!("b{b}"), Json::Obj(entry));
            assert!(
                dev <= 1e-3,
                "{model} b{b}: int8 logits deviate {dev} from the float reference"
            );
        }
        report.insert(model.clone(), Json::Obj(per_batch));
    }
    t.print();
    t.write_csv(std::path::Path::new("bench_out/serve_throughput.csv")).unwrap();

    let doc: BTreeMap<String, Json> = [
        ("bench".to_string(), Json::Str("serve_throughput".to_string())),
        ("bits".to_string(), Json::Str(bits.clone())),
        ("kernel".to_string(), Json::Str(kernel.to_string())),
        ("iters".to_string(), Json::Num(iters as f64)),
        ("batches".to_string(), Json::Arr(batches.iter().map(|&b| Json::Num(b as f64)).collect())),
        ("models".to_string(), Json::Obj(report)),
        ("best_speedup_at_batch_ge_32".to_string(), Json::Num(best_speedup_b32)),
        ("best_simd_speedup_at_batch_ge_8".to_string(), Json::Num(best_simd_b8)),
    ]
    .into_iter()
    .collect();
    std::fs::write("BENCH_serve.json", Json::Obj(doc).render()).unwrap();
    println!("\nwrote BENCH_serve.json (int8 vs float forward examples/sec per batch size)");
    println!(
        "north-star check: best int8 speedup at batch ≥ 32 is {best_speedup_b32:.2}x \
         (target ≥ 1.5x on at least one model)"
    );
    if kernel != "scalar" {
        println!(
            "simd check: {kernel} is {best_simd_b8:.2}x the scalar oracle at batch ≥ 8 \
             (gate ≥ 1.3x)"
        );
        assert!(
            best_simd_b8 >= 1.3,
            "SIMD kernel {kernel} is only {best_simd_b8:.2}x scalar at batch ≥ 8 — \
             the dispatched path must beat the oracle by ≥ 1.3x"
        );
    }
}
