//! Host-side quantization math (paper Eq. 1–4) and the PTQ MinMax observer.
//!
//! The coordinator computes the *initial* quantization parameters here
//! (the PTQ step of Algorithm 1); the training-time fake-quant itself
//! runs inside the step functions — the L1 Pallas kernels on the PJRT
//! backend, [`crate::ops::fakequant`] on the native graph executor, both
//! built on these scalar formulas.  The formulas are unit-tested to
//! mirror `python/compile/kernels/ref.py` exactly so every layer agrees
//! bit-for-bit.

/// Parse a `wXaY` bits tag (e.g. `w8a8` → `(8, 8)`) — the one grammar
/// shared by artifact names, the CLI, and the native backend.  Widths
/// outside 2..=16 are rejected here so malformed tags fail with the
/// caller's descriptive error instead of overflowing `qrange_*`
/// downstream (the paper only uses 4/8-bit grids).
pub fn parse_bits_tag(tag: &str) -> Option<(u32, u32)> {
    let rest = tag.strip_prefix('w')?;
    let (w, a) = rest.split_once('a')?;
    let (w, a): (u32, u32) = (w.parse().ok()?, a.parse().ok()?);
    if !(2..=16).contains(&w) || !(2..=16).contains(&a) {
        return None;
    }
    Some((w, a))
}

/// Symmetric signed range for b-bit weights: [-(2^{b-1}-1), 2^{b-1}-1].
pub fn qrange_sym(bits: u32) -> (i32, i32) {
    let m = (1i32 << (bits - 1)) - 1;
    (-m, m)
}

/// Asymmetric unsigned range for b-bit activations: [0, 2^b - 1].
pub fn qrange_asym(bits: u32) -> (i32, i32) {
    (0, (1i32 << bits) - 1)
}

/// Quantization parameters of one activation site (per-tensor, asymmetric).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ActQParams {
    /// Activation scale `S_x` (Eq. 2).
    pub scale: f32,
    /// Activation zero point `Z_x` (Eq. 2); stored unrounded, rounded at
    /// quantization time (Eq. 1).
    pub zero_point: f32,
}

/// MinMax observer (Eq. 2): S_x = (β-α)/(2^b-1), Z_x = -round(α/S_x).
#[derive(Clone, Debug)]
pub struct MinMaxObserver {
    /// Smallest activation seen (α).
    pub min: f32,
    /// Largest activation seen (β).
    pub max: f32,
    samples: usize,
}

impl Default for MinMaxObserver {
    fn default() -> Self {
        MinMaxObserver { min: f32::INFINITY, max: f32::NEG_INFINITY, samples: 0 }
    }
}

impl MinMaxObserver {
    /// Fold one pre-reduced (min, max) pair into the range — what the
    /// calib artifacts' per-batch taps report.
    pub fn observe(&mut self, lo: f32, hi: f32) {
        self.min = self.min.min(lo);
        self.max = self.max.max(hi);
        self.samples += 1;
    }

    /// Fold a raw activation slice into the range.
    pub fn observe_slice(&mut self, xs: &[f32]) {
        for &x in xs {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.samples += 1;
    }

    /// Derive the activation scale/zero-point from the observed range
    /// (Eq. 2), forcing the range to contain zero so that zero maps to
    /// an exact code.
    pub fn qparams(&self, bits: u32) -> ActQParams {
        assert!(self.samples > 0, "observer saw no data");
        // the range must include 0 so that zero maps to an exact code
        let lo = self.min.min(0.0);
        let hi = self.max.max(0.0);
        let (_, qmax) = qrange_asym(bits);
        let scale = ((hi - lo) / qmax as f32).max(1e-8);
        let zero_point = (-lo / scale).round();
        ActQParams { scale, zero_point }
    }
}

/// Per-row symmetric weight scales (Eq. 4): S_w = max(|α|,|β|)/(2^{b-1}-1).
pub fn weight_scales(row_abs_max: &[f32], bits: u32) -> Vec<f32> {
    let (_, qmax) = qrange_sym(bits);
    row_abs_max.iter().map(|&m| (m / qmax as f32).max(1e-8)).collect()
}

/// The b-bit symmetric signed *code* of a weight (the round+clip of
/// Eq. 3).  [`fq_sym`] and the int8 serving path
/// ([`crate::ops::qmatmul`]) are both defined through this function, so
/// the integer engine and the fake-quant simulation agree on every code
/// by construction.
///
/// Codes round-trip: dequantizing a code (`c·S_w`) reproduces the
/// fake-quant value exactly, and in-range weights land within half a
/// step of themselves:
///
/// ```
/// use efqat::quant::{code_sym, fq_sym, qrange_sym};
/// let (s, bits) = (0.01_f32, 8);
/// for w in [0.1234_f32, -0.5, 0.0, 1.26] {
///     let c = code_sym(w, s, bits);
///     let (qmin, qmax) = qrange_sym(bits);
///     assert!(c >= qmin && c <= qmax);
///     assert_eq!(c as f32 * s, fq_sym(w, s, bits));       // code ↔ fake-quant
///     assert!((w - c as f32 * s).abs() <= 0.5 * s + 1e-6); // round-trip error ≤ s/2
/// }
/// // out-of-range weights clip to the grid edge instead of overflowing i8
/// assert_eq!(code_sym(10.0, s, bits), 127);
/// assert_eq!(code_sym(-10.0, s, bits), -127);
/// ```
pub fn code_sym(w: f32, s: f32, bits: u32) -> i32 {
    let (qmin, qmax) = qrange_sym(bits);
    (w / s).round().clamp(qmin as f32, qmax as f32) as i32
}

/// The b-bit asymmetric unsigned *code* of an activation (the
/// round+shift+clip of Eq. 1).  Shared by [`fq_asym`] and the int8
/// activation quantizer for bit-identical codes.
///
/// Codes round-trip through the zero point: `(c − Z_x)·S_x` rebuilds
/// the fake-quant value exactly, zero maps to the zero-point code, and
/// in-range activations land within half a step:
///
/// ```
/// use efqat::quant::{code_asym, fq_asym, qrange_asym};
/// let (s, z, bits) = (0.05_f32, 128.0_f32, 8);
/// assert_eq!(code_asym(0.0, s, z, bits), 128);             // zero → Z_x exactly
/// for x in [-1.7_f32, 0.03, 2.5] {
///     let c = code_asym(x, s, z, bits);
///     let (qmin, qmax) = qrange_asym(bits);
///     assert!(c >= qmin && c <= qmax);
///     let back = (c as f32 - z) * s;                        // dequantize
///     assert_eq!(back, fq_asym(x, s, z, bits));             // code ↔ fake-quant
///     assert!((x - back).abs() <= 0.5 * s + 1e-6);          // round-trip error ≤ s/2
/// }
/// // saturation: far outside the range clips to the u8 grid edges
/// assert_eq!(code_asym(1e9, s, z, bits), 255);
/// assert_eq!(code_asym(-1e9, s, z, bits), 0);
/// ```
pub fn code_asym(x: f32, s: f32, z: f32, bits: u32) -> i32 {
    let (qmin, qmax) = qrange_asym(bits);
    ((x / s).round() + z.round()).clamp(qmin as f32, qmax as f32) as i32
}

/// Reference symmetric fake-quant (Eq. 3) — mirrors kernels/ref.py.
pub fn fq_sym(w: f32, s: f32, bits: u32) -> f32 {
    code_sym(w, s, bits) as f32 * s
}

/// Reference asymmetric fake-quant (Eq. 1) — mirrors kernels/ref.py.
pub fn fq_asym(x: f32, s: f32, z: f32, bits: u32) -> f32 {
    (code_asym(x, s, z, bits) as f32 - z.round()) * s
}

/// Mean squared quantization error of a row under a given scale — used by
/// tests and by the `fig3` importance analysis bench.
pub fn row_quant_mse(row: &[f32], s: f32, bits: u32) -> f32 {
    row.iter().map(|&w| {
        let d = w - fq_sym(w, s, bits);
        d * d
    }).sum::<f32>() / row.len().max(1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    #[test]
    fn bits_tag_grammar() {
        assert_eq!(parse_bits_tag("w8a8"), Some((8, 8)));
        assert_eq!(parse_bits_tag("w4a8"), Some((4, 8)));
        assert_eq!(parse_bits_tag("8a8"), None);
        assert_eq!(parse_bits_tag("w8"), None);
        assert_eq!(parse_bits_tag("wXa8"), None);
        // out-of-range widths would overflow qrange_* downstream
        assert_eq!(parse_bits_tag("w33a8"), None);
        assert_eq!(parse_bits_tag("w0a8"), None);
        assert_eq!(parse_bits_tag("w8a1"), None);
    }

    #[test]
    fn ranges() {
        assert_eq!(qrange_sym(8), (-127, 127));
        assert_eq!(qrange_sym(4), (-7, 7));
        assert_eq!(qrange_asym(8), (0, 255));
        assert_eq!(qrange_asym(4), (0, 15));
    }

    #[test]
    fn observer_matches_eq2() {
        let mut o = MinMaxObserver::default();
        o.observe_slice(&[-1.0, 0.5, 2.0]);
        let q = o.qparams(8);
        assert!((q.scale - 3.0 / 255.0).abs() < 1e-7);
        assert_eq!(q.zero_point, (1.0 / q.scale).round());
    }

    #[test]
    fn observer_range_always_contains_zero() {
        let mut o = MinMaxObserver::default();
        o.observe_slice(&[3.0, 5.0]); // all-positive activations
        let q = o.qparams(8);
        // zero must map to code 0 exactly
        assert_eq!(q.zero_point, 0.0);
        assert!((fq_asym(0.0, q.scale, q.zero_point, 8)).abs() < 1e-7);
    }

    #[test]
    fn weight_scale_covers_max() {
        let s = weight_scales(&[1.27], 8)[0];
        assert!((fq_sym(1.27, s, 8) - 1.27).abs() < 1e-6);
        assert!((fq_sym(-1.27, s, 8) + 1.27).abs() < 1e-6);
    }

    #[test]
    fn prop_fq_sym_within_one_half_scale_in_range() {
        forall(1000, |r| {
            let bits = if r.uniform() < 0.5 { 4 } else { 8 };
            let s = r.uniform_in(1e-3, 0.2);
            let (qmin, qmax) = qrange_sym(bits);
            let w = r.uniform_in(qmin as f32 * s, qmax as f32 * s);
            let err = (w - fq_sym(w, s, bits)).abs();
            assert!(err <= s * 0.5 + 1e-6, "err {err} s {s} bits {bits}");
        });
    }

    #[test]
    fn prop_codes_land_in_range_and_rebuild_fq() {
        forall(1000, |r| {
            let bits = if r.uniform() < 0.5 { 4 } else { 8 };
            let s = r.uniform_in(1e-4, 0.3);
            let z = r.uniform_in(0.0, qrange_asym(bits).1 as f32).round();
            let w = r.uniform_in(-50.0, 50.0);
            let (wmin, wmax) = qrange_sym(bits);
            let cw = code_sym(w, s, bits);
            assert!(cw >= wmin && cw <= wmax, "weight code {cw} out of range");
            assert_eq!(fq_sym(w, s, bits), cw as f32 * s);
            let (amin, amax) = qrange_asym(bits);
            let ca = code_asym(w, s, z, bits);
            assert!(ca >= amin && ca <= amax, "act code {ca} out of range");
            assert_eq!(fq_asym(w, s, z, bits), (ca as f32 - z) * s);
        });
    }

    #[test]
    fn adversarial_weight_rows_quantize_in_range() {
        // all-zero, constant, outlier-dominated, and near-denormal rows:
        // Eq. 4 scales must stay positive and every code must stay inside
        // the symmetric grid, with per-element error ≤ s/2 for in-range w
        let rows: &[&[f32]] = &[
            &[0.0, 0.0, 0.0, 0.0],
            &[0.5, 0.5, 0.5, 0.5],
            &[1e4, -1.0, 0.001, 2.0],
            &[1e-30, -1e-30, 0.0, 1e-38],
            &[-3.0, -7.5, -0.25, -1e3],
        ];
        for row in rows {
            let amax = row.iter().fold(0f32, |m, x| m.max(x.abs()));
            let s = weight_scales(&[amax], 8)[0];
            assert!(s > 0.0 && s.is_finite(), "scale {s} for row {row:?}");
            let (qmin, qmax) = qrange_sym(8);
            for &w in *row {
                let c = code_sym(w, s, 8);
                assert!(c >= qmin && c <= qmax, "code {c} for {w} (s {s})");
                // Eq. 4 covers the whole row, so nothing clips: the
                // dequantization error is at most half a step
                let err = (w - fq_sym(w, s, 8)).abs();
                assert!(err <= 0.5 * s + 1e-6 * w.abs(), "err {err} vs s {s} for {w}");
            }
        }
    }

    #[test]
    fn adversarial_activation_ranges_keep_zero_point_in_range() {
        // all-positive, all-negative, constant, and outlier-heavy
        // calibration ranges must all produce z ∈ [0, qmax] (u8-codable)
        for range in [[3.0, 5.0], [-9.0, -2.0], [0.0, 0.0], [-1e-6, 1e4], [-1e4, 1e-6]] {
            let mut o = MinMaxObserver::default();
            o.observe(range[0], range[1]);
            let q = o.qparams(8);
            let (_, qmax) = qrange_asym(8);
            assert!(q.scale > 0.0 && q.scale.is_finite(), "{range:?}");
            assert!(
                q.zero_point >= 0.0 && q.zero_point <= qmax as f32,
                "{range:?}: zero point {} escapes [0, {qmax}]",
                q.zero_point
            );
            // zero always maps to an exact code
            assert_eq!(fq_asym(0.0, q.scale, q.zero_point, 8), 0.0);
        }
    }

    #[test]
    fn prop_fq_asym_idempotent() {
        forall(1000, |r| {
            let bits = 8;
            let s = r.uniform_in(1e-3, 0.1);
            let z = r.uniform_in(0.0, 255.0).round();
            let x = r.uniform_in(-5.0, 5.0);
            let once = fq_asym(x, s, z, bits);
            let twice = fq_asym(once, s, z, bits);
            assert!((once - twice).abs() < 1e-5, "not idempotent: {once} vs {twice}");
        });
    }

    #[test]
    fn prop_quantization_error_bounded_by_clip() {
        forall(500, |r| {
            let s = r.uniform_in(0.01, 0.1);
            let x = r.uniform_in(-1.0, 1.0);
            let q = fq_asym(x, s, 128.0, 8);
            // in-range values: |err| <= s/2; clipped: err can be larger but
            // output stays inside the representable interval
            let (qmin, qmax) = qrange_asym(8);
            let lo = (qmin as f32 - 128.0) * s;
            let hi = (qmax as f32 - 128.0) * s;
            assert!(q >= lo - 1e-5 && q <= hi + 1e-5);
        });
    }
}
