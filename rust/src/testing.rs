//! Micro property-testing harness (proptest is unavailable offline).
//!
//! `forall(n, |rng| ...)` runs a closure against `n` seeded random cases;
//! on panic it re-raises with the failing case index and seed so the case
//! reproduces deterministically.  Not shrinking — cases are printed small
//! enough to debug directly.

use crate::rng::Pcg64;

/// Run `f` against `n` deterministic random cases.
pub fn forall<F: Fn(&mut Pcg64)>(n: usize, f: F) {
    for case in 0..n {
        let seed = 0x5eed_0000 + case as u64;
        let mut rng = Pcg64::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Random vector helper for property tests.
pub fn fvec(rng: &mut Pcg64, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..n).map(|_| rng.uniform_in(lo, hi)).collect()
}

/// Synthetic-but-valid int8-lowering inputs for a native model: real
/// weights from the init distribution, PTQ weight scales, and mid-grid
/// activation qparams (`S_x = 0.05`, `Z_x = 128`).  One definition for
/// the `lower.rs` units, the serve tests, and the serve benches, so the
/// fixtures cannot drift from each other.
pub fn synth_lowering_fixture(
    model: &str,
) -> (crate::graph::LayerGraph, crate::model::ParamStore, crate::model::QParamStore) {
    use crate::graph::{build_manifest, StepId, StepKind};
    use crate::quant::ActQParams;

    let g = crate::backend::native::model_graph(model)
        .unwrap_or_else(|| panic!("{model}: not a native model"));
    let man = build_manifest(&g, "fwd", &StepId { kind: StepKind::Fwd, w_bits: 8, a_bits: 8 });
    let params = crate::model::ParamStore::init(&man, 1);
    let mut q = crate::model::QParamStore::default();
    q.init_weight_scales(&man, &params, 8);
    for s in &man.wsites {
        q.act.insert(s.name.clone(), ActQParams { scale: 0.05, zero_point: 128.0 });
    }
    (g, params, q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let count = std::cell::Cell::new(0);
        forall(25, |_| count.set(count.get() + 1));
        assert_eq!(count.get(), 25);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall(10, |r| assert!(r.uniform() < 0.0));
    }
}
