//! Micro property-testing harness (proptest is unavailable offline).
//!
//! `forall(n, |rng| ...)` runs a closure against `n` seeded random cases;
//! on panic it re-raises with the failing case index and seed so the case
//! reproduces deterministically.  Not shrinking — cases are printed small
//! enough to debug directly.

use crate::rng::Pcg64;

/// Run `f` against `n` deterministic random cases.
pub fn forall<F: Fn(&mut Pcg64)>(n: usize, f: F) {
    for case in 0..n {
        let seed = 0x5eed_0000 + case as u64;
        let mut rng = Pcg64::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Random vector helper for property tests.
pub fn fvec(rng: &mut Pcg64, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..n).map(|_| rng.uniform_in(lo, hi)).collect()
}

/// Seeded random activation codes — the full `u8` domain `0..=255`,
/// including the saturated endpoints.
pub fn rand_act_codes(rng: &mut Pcg64, n: usize) -> Vec<u8> {
    (0..n).map(|_| rng.below(256) as u8).collect()
}

/// Seeded random weight codes over the symmetric int8 grid
/// `−127..=127` (the code domain [`crate::quant::code_sym`] produces —
/// `−128` is never a valid weight code).
pub fn rand_weight_codes(rng: &mut Pcg64, n: usize) -> Vec<i8> {
    (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect()
}

/// Per-row code sums of a `[rows, k]` weight-code matrix — the
/// zero-point correction term [`crate::ops::qmatmul::quantize_weight_rows`]
/// precomputes at lowering time, rebuilt here for synthetic-code tests.
pub fn wsum_rows(qw: &[i8], rows: usize) -> Vec<i32> {
    if rows == 0 {
        return Vec::new();
    }
    let k = qw.len() / rows;
    debug_assert_eq!(qw.len(), rows * k);
    (0..rows).map(|r| qw[r * k..(r + 1) * k].iter().map(|&c| c as i32).sum()).collect()
}

/// Per-row symmetric weight scales (Eq. 4) for a `[rows, row_size]` f32
/// matrix: the row-amax fold + [`crate::quant::weight_scales`] recipe
/// previously duplicated across the qmatmul/qconv/parity tests.
pub fn synth_row_scales(w: &[f32], rows: usize, row_size: usize, bits: u32) -> Vec<f32> {
    debug_assert_eq!(w.len(), rows * row_size);
    let amax: Vec<f32> = (0..rows)
        .map(|r| w[r * row_size..(r + 1) * row_size].iter().fold(0f32, |a, &v| a.max(v.abs())))
        .collect();
    crate::quant::weight_scales(&amax, bits)
}

/// Synthetic-but-valid qparams for a manifest's weight sites: PTQ
/// weight scales from the real params plus mid-grid activation qparams
/// (`Z_x = 128` at a8, `8` at a4).  One definition for the `lower.rs`
/// units, the parity/serve tests, and the serve benches, so the
/// fixtures cannot drift from each other.
pub fn synth_qparams(
    man: &crate::model::Manifest,
    params: &crate::model::ParamStore,
    w_bits: u32,
    a_bits: u32,
    act_scale: f32,
) -> crate::model::QParamStore {
    let zp = ((crate::quant::qrange_asym(a_bits).1 + 1) / 2) as f32;
    let mut q = crate::model::QParamStore::default();
    q.init_weight_scales(man, params, w_bits);
    for s in &man.wsites {
        q.act.insert(
            s.name.clone(),
            crate::quant::ActQParams { scale: act_scale, zero_point: zp },
        );
    }
    q
}

/// Synthetic-but-valid int8-lowering inputs for a native model: real
/// weights from the init distribution, PTQ weight scales, and mid-grid
/// activation qparams (`S_x = 0.05`, `Z_x = 128`) via [`synth_qparams`].
pub fn synth_lowering_fixture(
    model: &str,
) -> (crate::graph::LayerGraph, crate::model::ParamStore, crate::model::QParamStore) {
    synth_lowering_fixture_seeded(model, 1)
}

/// [`synth_lowering_fixture`] with a caller-chosen init seed: distinct
/// seeds yield the same architecture with different weights — the
/// hot-swap tests use these as stand-ins for successive training
/// checkpoints of one model.
pub fn synth_lowering_fixture_seeded(
    model: &str,
    seed: u64,
) -> (crate::graph::LayerGraph, crate::model::ParamStore, crate::model::QParamStore) {
    use crate::graph::{build_manifest, StepId, StepKind};

    let g = crate::backend::native::model_graph(model)
        .unwrap_or_else(|| panic!("{model}: not a native model"));
    let man = build_manifest(&g, "fwd", &StepId { kind: StepKind::Fwd, w_bits: 8, a_bits: 8 });
    let params = crate::model::ParamStore::init(&man, seed);
    let q = synth_qparams(&man, &params, 8, 8, 0.05);
    (g, params, q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let count = std::cell::Cell::new(0);
        forall(25, |_| count.set(count.get() + 1));
        assert_eq!(count.get(), 25);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall(10, |r| assert!(r.uniform() < 0.0));
    }
}
