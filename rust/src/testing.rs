//! Micro property-testing harness (proptest is unavailable offline).
//!
//! `forall(n, |rng| ...)` runs a closure against `n` seeded random cases;
//! on panic it re-raises with the failing case index and seed so the case
//! reproduces deterministically.  Not shrinking — cases are printed small
//! enough to debug directly.

use crate::rng::Pcg64;

/// Run `f` against `n` deterministic random cases.
pub fn forall<F: Fn(&mut Pcg64)>(n: usize, f: F) {
    for case in 0..n {
        let seed = 0x5eed_0000 + case as u64;
        let mut rng = Pcg64::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Random vector helper for property tests.
pub fn fvec(rng: &mut Pcg64, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..n).map(|_| rng.uniform_in(lo, hi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let count = std::cell::Cell::new(0);
        forall(25, |_| count.set(count.get() + 1));
        assert_eq!(count.get(), 25);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall(10, |r| assert!(r.uniform() < 0.0));
    }
}
