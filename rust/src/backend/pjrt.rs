//! PJRT backend: load AOT HLO-text artifacts, compile once, execute many.
//!
//! Follows the /opt/xla-example/load_hlo pattern: HLO *text* is the
//! interchange format (xla_extension 0.5.1 rejects jax≥0.5 serialized
//! protos), `HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile` → `execute`.
//!
//! Before anything is compiled, the artifacts directory's schema-versioned
//! bundle manifest ([`crate::bundle::Bundle`], written by `make
//! artifacts` / `efqat bundle`) is loaded and the requested artifact's
//! files are verified against their recorded SHA-256 checksums — a stale
//! or corrupted artifact set fails with a descriptive error before any
//! training starts.
//!
//! This module is compiled only with the `pjrt` cargo feature, which in
//! turn requires the vendored `xla` crate as a dependency (see README.md
//! §PJRT backend).  Without the feature, requesting `--backend pjrt`
//! reports a descriptive error from [`crate::backend::create`].

#[cfg(feature = "pjrt")]
pub use imp::PjrtBackend;

#[cfg(feature = "pjrt")]
mod imp {
    use std::path::{Path, PathBuf};
    use std::time::{Duration, Instant};

    use crate::backend::{Backend, Step, StepExec, Value};
    use crate::bundle::Bundle;
    use crate::error::{anyhow, bail, Context, Result};
    use crate::model::{Dtype, IoSpec, Manifest};
    use crate::tensor::{ITensor, Tensor};

    /// PJRT CPU backend over a verified artifact bundle.
    pub struct PjrtBackend {
        client: xla::PjRtClient,
        artifacts_dir: PathBuf,
        bundle: Bundle,
    }

    impl PjrtBackend {
        /// Create a CPU PJRT client and load + schema-check the bundle
        /// manifest for `artifacts_dir`.
        pub fn new(artifacts_dir: &Path) -> Result<PjrtBackend> {
            let bundle = Bundle::load(&Bundle::manifest_path(artifacts_dir)).context(
                "the PJRT backend needs a bundle manifest; run `make artifacts` \
                 (or `efqat bundle` over an existing artifacts directory)",
            )?;
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(PjrtBackend { client, artifacts_dir: artifacts_dir.to_path_buf(), bundle })
        }
    }

    impl Backend for PjrtBackend {
        fn name(&self) -> &'static str {
            "pjrt"
        }

        /// Verify the artifact against the bundle, then parse + compile
        /// its HLO text.
        fn load(&self, artifact: &str) -> Result<Step> {
            self.bundle.verify_entry(&self.artifacts_dir, artifact)?;
            let entry = self.bundle.entry(artifact)?;
            let man_file = entry
                .files
                .get("manifest")
                .ok_or_else(|| anyhow!("bundle entry {artifact} has no manifest file"))?;
            let hlo_file = entry
                .files
                .get("hlo")
                .ok_or_else(|| anyhow!("bundle entry {artifact} has no hlo file"))?;
            let manifest = Manifest::load(&self.artifacts_dir.join(&man_file.path))?;
            let hlo = self.artifacts_dir.join(&hlo_file.path);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&hlo)
                .map_err(|e| anyhow!("parsing {}: {e:?}", hlo.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {artifact}: {e:?}"))?;
            let exec =
                PjrtStep { exe, outputs: manifest.outputs.clone(), name: artifact.to_string() };
            Ok(Step::new(manifest, "pjrt", t0.elapsed(), Box::new(exec)))
        }
    }

    struct PjrtStep {
        exe: xla::PjRtLoadedExecutable,
        outputs: Vec<IoSpec>,
        name: String,
    }

    impl StepExec for PjrtStep {
        fn run(&self, inputs: &[Value]) -> Result<(Vec<Value>, Duration)> {
            let literals = inputs.iter().map(literal_of).collect::<Result<Vec<_>>>()?;
            // time exactly the device execute + result fetch (the seed
            // runtime's Table 5 window) — literal packing above and
            // unpacking below are host overhead, reported separately
            let t0 = Instant::now();
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("executing {}: {e:?}", self.name))?;
            let tuple = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e:?}"))?;
            let dt = t0.elapsed();
            let parts = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
            if parts.len() != self.outputs.len() {
                bail!(
                    "{}: {} outputs returned, manifest declares {}",
                    self.name,
                    parts.len(),
                    self.outputs.len()
                );
            }
            let outs = self
                .outputs
                .iter()
                .zip(parts)
                .map(|(spec, lit)| unpack(spec, lit))
                .collect::<Result<Vec<_>>>()?;
            Ok((outs, dt))
        }
    }

    /// Pack a host value into an XLA literal of its own shape.
    fn literal_of(v: &Value) -> Result<xla::Literal> {
        match v {
            Value::F32(t) => {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape {:?}: {e:?}", t.shape))
            }
            Value::I32(t) => {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape {:?}: {e:?}", t.shape))
            }
        }
    }

    fn unpack(spec: &IoSpec, lit: xla::Literal) -> Result<Value> {
        match spec.dtype {
            Dtype::F32 => {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("{}: to_vec f32: {e:?}", spec.name))?;
                Ok(Value::F32(Tensor::new(spec.shape.clone(), data)?))
            }
            Dtype::I32 => {
                let data = lit
                    .to_vec::<i32>()
                    .map_err(|e| anyhow!("{}: to_vec i32: {e:?}", spec.name))?;
                Ok(Value::I32(ITensor::new(spec.shape.clone(), data)?))
            }
        }
    }
}
