//! Native CPU backend: artifact-name parsing + layer-graph dispatch.
//!
//! This backend makes the rust coordinator self-sufficient — no JAX, no
//! PJRT, no pre-built artifacts.  Each native model is a declarative
//! [`crate::graph::LayerGraph`]; the graph synthesizes the same manifests
//! `python/compile/aot.py` would emit and executes every step kind
//! (train / fwd / calib at every precision, ratio and freezing mode)
//! through the shared op library in [`crate::ops`].  There is no
//! per-model step code here: adding a model means adding a graph
//! declaration below.
//!
//! The artifact-name grammar matches
//! [`crate::coordinator::trainer::artifact_name`]:
//! `mlp_calib`, `mlp_fp_train`, `mlp_fp_fwd`, `mlp_w8a8_fwd`,
//! `mlp_w8a8_train_r25`, `convnet_w4a8_train_lwpn`, … for every model in
//! [`NATIVE_MODELS`].  Unknown models produce a descriptive error
//! pointing at the PJRT backend.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::error::{anyhow, bail, Result};
use crate::graph::{
    AttnSpec, ConvSpec, EmbedSpec, GraphStep, InputKind, Layer, LayerGraph, LinearSpec, NormSpec,
    StepId, StepKind, TrainSel,
};

use super::{Backend, Step, StepExec, Value};

// ---------------------------------------------------------------------------
// Native model registry — each entry is one graph declaration
// ---------------------------------------------------------------------------

/// One native model: a name plus its layer-graph constructor.
pub struct NativeModel {
    /// Model name as used in artifact names and the task registry.
    pub name: &'static str,
    build: fn() -> LayerGraph,
}

/// Models the native backend can execute.  The MLP family exercises the
/// coordinator at sub-second scale; `convnet` brings conv-style `WSite`s
/// (output channels of an OIHW kernel) through the freezing policies;
/// `tiny_tf` is the paper's transformer shape (embed → attention → MLP
/// block) with seven freezable projection sites.
pub const NATIVE_MODELS: &[NativeModel] = &[
    NativeModel { name: "mlp", build: graph_mlp },
    NativeModel { name: "mlp_wide", build: graph_mlp_wide },
    NativeModel { name: "convnet", build: graph_convnet },
    NativeModel { name: "tiny_tf", build: graph_tiny_tf },
];

/// Build a native model's graph by name.
pub fn model_graph(model: &str) -> Option<LayerGraph> {
    NATIVE_MODELS.iter().find(|m| m.name == model).map(|m| (m.build)())
}

fn lin(name: &str, c_in: usize, c_out: usize) -> Layer {
    Layer::Linear(LinearSpec { name: name.into(), c_in, c_out, bias: true })
}

fn mlp_family(name: &str, hidden: usize) -> LayerGraph {
    LayerGraph {
        model: name.into(),
        batch: 16,
        input: InputKind::Image { channels: 3, hw: 8 },
        classes: 10,
        layers: vec![
            Layer::Flatten,
            lin("fc1", 3 * 8 * 8, hidden),
            Layer::Relu,
            lin("fc2", hidden, 10),
        ],
    }
}

fn graph_mlp() -> LayerGraph {
    mlp_family("mlp", 32)
}

fn graph_mlp_wide() -> LayerGraph {
    mlp_family("mlp_wide", 128)
}

/// conv → relu → pool → linear: the smallest graph that exercises
/// conv-style freezable sites (EfQAT's CNN workloads, paper Tables 3–5).
fn graph_convnet() -> LayerGraph {
    LayerGraph {
        model: "convnet".into(),
        batch: 16,
        input: InputKind::Image { channels: 3, hw: 8 },
        classes: 10,
        layers: vec![
            Layer::Conv2d(ConvSpec {
                name: "conv1".into(),
                c_in: 3,
                c_out: 8,
                k: 3,
                stride: 1,
                pad: 1,
            }),
            Layer::Relu,
            Layer::AvgPool2x2,
            Layer::Flatten,
            lin("fc", 8 * 4 * 4, 10),
        ],
    }
}

/// embed → attention block → MLP block → head: a one-block causal LM in
/// the paper's transformer shape, with every projection freezable.
fn graph_tiny_tf() -> LayerGraph {
    let (d, vocab, seq) = (16, 64, 16);
    LayerGraph {
        model: "tiny_tf".into(),
        batch: 8,
        input: InputKind::Tokens { seq },
        classes: vocab,
        layers: vec![
            Layer::Embed(EmbedSpec { name: "emb".into(), vocab, seq, d }),
            Layer::Residual(vec![
                Layer::LayerNorm(NormSpec { name: "ln1".into(), d }),
                Layer::Attention(AttnSpec { name: "attn".into(), d, heads: 2, causal: true }),
            ]),
            Layer::Residual(vec![
                Layer::LayerNorm(NormSpec { name: "ln2".into(), d }),
                lin("ffn1", d, 2 * d),
                Layer::Relu,
                lin("ffn2", 2 * d, d),
            ]),
            Layer::LayerNorm(NormSpec { name: "lnf".into(), d }),
            lin("head", d, vocab),
        ],
    }
}

// ---------------------------------------------------------------------------
// Artifact-name grammar
// ---------------------------------------------------------------------------

fn parse_artifact(name: &str) -> Result<(&'static NativeModel, StepId)> {
    // longest-prefix match over the registry, tracked inline (no per-call
    // allocation or sort) so "mlp_wide_…" never resolves to "mlp"
    let mut best: Option<(&'static NativeModel, &str)> = None;
    for m in NATIVE_MODELS {
        if let Some(rest) = name.strip_prefix(m.name).and_then(|r| r.strip_prefix('_')) {
            if !best.is_some_and(|(b, _)| b.name.len() >= m.name.len()) {
                best = Some((m, rest));
            }
        }
    }
    let Some((model, rest)) = best else {
        let supported: Vec<&str> = NATIVE_MODELS.iter().map(|m| m.name).collect();
        bail!(
            "artifact {name:?}: no native reference implementation for this model \
             (native backend supports: {}); build the AOT artifacts with `make artifacts` \
             and select `--backend pjrt` for the resnet/bert/gpt models",
            supported.join(", ")
        )
    };
    let id = match rest {
        "calib" => StepId { kind: StepKind::Calib, w_bits: 0, a_bits: 0 },
        "fp_train" => StepId { kind: StepKind::Train(TrainSel::Fp), w_bits: 0, a_bits: 0 },
        "fp_fwd" => StepId { kind: StepKind::Fwd, w_bits: 0, a_bits: 0 },
        _ => {
            let (tag, tail) = rest
                .split_once('_')
                .ok_or_else(|| anyhow!("artifact {name:?}: malformed suffix {rest:?}"))?;
            let (w, a) = crate::quant::parse_bits_tag(tag).ok_or_else(|| {
                anyhow!("artifact {name:?}: bad bits tag {tag:?} (want e.g. w8a8)")
            })?;
            let kind = if tail == "fwd" {
                StepKind::Fwd
            } else if tail == "train_lwpn" {
                StepKind::Train(TrainSel::Lwpn)
            } else if let Some(pct) = tail.strip_prefix("train_r") {
                let pct: u32 = pct
                    .parse()
                    .map_err(|_| anyhow!("artifact {name:?}: bad ratio in {tail:?}"))?;
                StepKind::Train(TrainSel::Ratio(pct as f32 / 100.0))
            } else {
                bail!("artifact {name:?}: unknown step kind {tail:?}");
            };
            StepId { kind, w_bits: w, a_bits: a }
        }
    };
    Ok((model, id))
}

/// Synthesize a [`GraphStep`] for `artifact` with the model's static
/// batch dimension overridden to `batch`.  The data-parallel trainer
/// ([`crate::coordinator::DataParallelTrainer`]) builds one per worker:
/// gradient outputs are batch-independent, so shard steps stay drop-in
/// compatible with the full-batch manifest's optimizer ABI.
pub fn shard_step(artifact: &str, batch: usize) -> Result<GraphStep> {
    let (model, id) = parse_artifact(artifact)?;
    let mut graph = (model.build)();
    graph.batch = batch;
    GraphStep::new(graph, artifact, id)
}

// ---------------------------------------------------------------------------
// Step execution: the graph executor does the work, this wrapper times it
// ---------------------------------------------------------------------------

struct NativeStep {
    step: GraphStep,
}

impl StepExec for NativeStep {
    fn run(&self, inputs: &[Value]) -> Result<(Vec<Value>, Duration)> {
        // the host compute IS the device here — time the whole evaluation
        let t0 = Instant::now();
        let outs = self.step.execute(inputs)?;
        Ok((outs, t0.elapsed()))
    }

    fn run_ws(
        &self,
        inputs: &[Value],
        ws: &mut crate::exec::Workspace,
    ) -> Result<(Vec<Value>, Duration)> {
        let t0 = Instant::now();
        let outs = self.step.execute_ws(inputs, ws)?;
        Ok((outs, t0.elapsed()))
    }
}

// ---------------------------------------------------------------------------
// Backend
// ---------------------------------------------------------------------------

/// The native CPU reference backend.  Holds the artifacts directory only
/// for error messages and parity with the PJRT constructor — native steps
/// are synthesized from graph declarations, not loaded from disk.
pub struct NativeBackend {
    /// Where PJRT artifacts would live; echoed in diagnostics.
    pub artifacts_dir: PathBuf,
}

impl NativeBackend {
    /// Create the backend; never fails (nothing to probe).
    pub fn new(artifacts_dir: &Path) -> NativeBackend {
        NativeBackend { artifacts_dir: artifacts_dir.to_path_buf() }
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn load(&self, artifact: &str) -> Result<Step> {
        let t0 = Instant::now();
        let (model, id) = parse_artifact(artifact)?;
        let step = GraphStep::new((model.build)(), artifact, id)?;
        let man = step.man.clone();
        Ok(Step::new(man, "native", t0.elapsed(), Box::new(NativeStep { step })))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Dtype;
    use crate::tensor::{ITensor, Tensor};

    #[test]
    fn parses_every_artifact_kind() {
        for (name, kind, w, a) in [
            ("mlp_calib", StepKind::Calib, 0, 0),
            ("mlp_fp_train", StepKind::Train(TrainSel::Fp), 0, 0),
            ("mlp_fp_fwd", StepKind::Fwd, 0, 0),
            ("mlp_w8a8_fwd", StepKind::Fwd, 8, 8),
            ("mlp_w4a8_train_r25", StepKind::Train(TrainSel::Ratio(0.25)), 4, 8),
            ("mlp_w8a8_train_r100", StepKind::Train(TrainSel::Ratio(1.0)), 8, 8),
            ("mlp_w8a8_train_r0", StepKind::Train(TrainSel::Ratio(0.0)), 8, 8),
            ("mlp_w8a8_train_lwpn", StepKind::Train(TrainSel::Lwpn), 8, 8),
            ("mlp_wide_w8a8_fwd", StepKind::Fwd, 8, 8),
            ("convnet_w4a8_train_r25", StepKind::Train(TrainSel::Ratio(0.25)), 4, 8),
            ("tiny_tf_w8a8_train_lwpn", StepKind::Train(TrainSel::Lwpn), 8, 8),
        ] {
            let (model, id) = parse_artifact(name).unwrap();
            assert_eq!(id.kind, kind, "{name}");
            assert_eq!((id.w_bits, id.a_bits), (w, a), "{name}");
            assert!(name.starts_with(model.name), "{name} vs {}", model.name);
        }
        assert!(name_err("resnet8_fp_train").contains("no native reference implementation"));
        assert!(name_err("mlp_w8a8_train_rx").contains("bad ratio"));
        assert!(name_err("mlp_8a8_fwd").contains("bits tag"));
    }

    fn name_err(name: &str) -> String {
        parse_artifact(name).unwrap_err().to_string()
    }

    #[test]
    fn longest_model_name_wins_prefix_race() {
        let (model, _) = parse_artifact("mlp_wide_calib").unwrap();
        assert_eq!(model.name, "mlp_wide");
    }

    #[test]
    fn every_model_declares_a_consistent_graph() {
        for m in NATIVE_MODELS {
            let g = model_graph(m.name).unwrap();
            assert_eq!(g.model, m.name);
            assert!(!g.wsites().is_empty(), "{}: no freezable sites", m.name);
            // every wsite is a declared weight param with matching shape
            let params = g.params();
            for s in g.wsites() {
                let p = params.iter().find(|p| p.name == s.name).unwrap_or_else(|| {
                    panic!("{}: site {} has no param", m.name, s.name)
                });
                assert_eq!(p.kind, "weight", "{}:{}", m.name, s.name);
                assert_eq!(p.shape[0], s.c_out, "{}:{}", m.name, s.name);
                assert_eq!(p.shape.iter().product::<usize>(), s.size, "{}:{}", m.name, s.name);
            }
        }
    }

    #[test]
    fn no_per_model_step_code_means_manifests_come_from_the_graph() {
        let backend = NativeBackend::new(Path::new("artifacts"));
        let step = backend.load("convnet_w8a8_train_r25").unwrap();
        let m = &step.manifest;
        assert_eq!(m.model, "convnet");
        assert_eq!(m.wsites.len(), 2);
        // conv partial grads are [k, C_in·k·k]
        let dw = m.outputs.iter().find(|o| o.name == "d:conv1.w").unwrap();
        assert_eq!(dw.shape, vec![2, 27]);
    }

    #[test]
    fn bad_input_values_error_instead_of_panicking() {
        // a native step never panics on bad input values — scales of zero
        // are caught with a descriptive error
        let step = NativeBackend::new(Path::new("artifacts")).load("mlp_w8a8_fwd").unwrap();
        let mut inputs = Vec::new();
        for spec in &step.manifest.inputs {
            inputs.push(match spec.dtype {
                Dtype::F32 => Value::F32(Tensor::zeros(&spec.shape)),
                Dtype::I32 => Value::I32(ITensor::zeros(&spec.shape)),
            });
        }
        let err = step.execute(&inputs).unwrap_err().to_string();
        assert!(err.contains("scale"), "{err}");
    }
}
