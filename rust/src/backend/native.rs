//! Native CPU reference backend: the full EfQAT step executed host-side.
//!
//! This backend makes the rust coordinator self-sufficient — no JAX, no
//! PJRT, no pre-built artifacts.  For the native MLP model family it
//! synthesizes the same step-function manifests `python/compile/aot.py`
//! would emit and executes them on [`crate::tensor::Tensor`] directly:
//!
//! * forward: flatten → quantized linear → ReLU → quantized linear →
//!   softmax cross-entropy, with per-row symmetric weight fake-quant
//!   (paper Eq. 3/4) and per-tensor asymmetric activation fake-quant
//!   (Eq. 1/2), mirroring `python/compile/kernels/ref.py` bit-for-bit
//!   (see the `quant.rs` agreement tests below);
//! * backward: manual VJP with STE/LSQ gradients through the quantizers
//!   and the frozen-channel-aware partial weight gradient of the paper's
//!   Fig. 1 (right): under a CWPL/CWPN selection only the gathered
//!   unfrozen rows of `dW`/`dS_w` are ever materialized, under LWPN a
//!   frozen layer's weight-gradient matmul is skipped entirely;
//! * calib: an FP forward that records per-site activation `(min, max)`
//!   for the MinMax observer (Eq. 2).
//!
//! The artifact-name grammar matches
//! [`crate::coordinator::trainer::artifact_name`]:
//! `mlp_calib`, `mlp_fp_train`, `mlp_fp_fwd`, `mlp_w8a8_fwd`,
//! `mlp_w8a8_train_r25`, `mlp_w8a8_train_lwpn`, … for every native model
//! in [`NATIVE_MODELS`].  Unknown models produce a descriptive error
//! pointing at the PJRT backend.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::error::{anyhow, bail, Result};
use crate::freeze::site_k;
use crate::model::{Dtype, Init, IoSpec, Manifest, ParamInfo, WSite};
use crate::quant::{fq_asym, fq_sym, qrange_asym, qrange_sym};
use crate::tensor::{argmax, ITensor, Tensor};

use super::{Backend, Step, StepExec, Value};

// ---------------------------------------------------------------------------
// Native model family
// ---------------------------------------------------------------------------

/// One native MLP model: flatten(channels·hw·hw) → hidden → classes.
#[derive(Clone, Copy, Debug)]
pub struct MlpSpec {
    /// Model name as used in artifact names and the task registry.
    pub name: &'static str,
    /// Input image channels (the loader packs `x` as `[B, C, hw, hw]`).
    pub channels: usize,
    /// Input image side length.
    pub hw: usize,
    /// Hidden width (= `fc1.w`'s output-channel count).
    pub hidden: usize,
    /// Class count (= `fc2.w`'s output-channel count).
    pub classes: usize,
    /// Static batch dimension baked into the manifests.
    pub batch: usize,
}

impl MlpSpec {
    /// Flattened input dimension `channels · hw · hw`.
    pub fn d_in(&self) -> usize {
        self.channels * self.hw * self.hw
    }
}

/// Models the native backend can execute.  Kept deliberately small: the
/// MLP family exercises every coordinator code path (both freezable
/// weight sites, all three EfQAT modes, PTQ calibration) at a scale where
/// a full pipeline runs in seconds on one CPU core.
pub const NATIVE_MODELS: &[MlpSpec] = &[
    MlpSpec { name: "mlp", channels: 3, hw: 8, hidden: 32, classes: 10, batch: 16 },
    MlpSpec { name: "mlp_wide", channels: 3, hw: 8, hidden: 128, classes: 10, batch: 16 },
];

/// Look up a native model spec by name.
pub fn model_spec(model: &str) -> Option<&'static MlpSpec> {
    NATIVE_MODELS.iter().find(|m| m.name == model)
}

// ---------------------------------------------------------------------------
// Artifact-name grammar
// ---------------------------------------------------------------------------

/// Weight-gradient selection baked into a train artifact's ABI.
#[derive(Clone, Copy, Debug, PartialEq)]
enum TrainSel {
    /// FP pretraining: no quantization, full `dW`.
    Fp,
    /// Ratio artifact: `r=1` full, `r=0` none, otherwise per-site index
    /// vectors of `site_k(c_out, r)` unfrozen rows.
    Ratio(f32),
    /// LWPN artifact: per-site flags gate whole layers at runtime.
    Lwpn,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum ArtifactKind {
    Train(TrainSel),
    Fwd,
    Calib,
}

#[derive(Clone, Copy, Debug)]
struct ArtifactId {
    kind: ArtifactKind,
    w_bits: u32,
    a_bits: u32,
}

fn parse_artifact(name: &str) -> Result<(&'static MlpSpec, ArtifactId)> {
    // longest model name first so "mlp_wide_…" never matches "mlp"
    let mut specs: Vec<&MlpSpec> = NATIVE_MODELS.iter().collect();
    specs.sort_by_key(|s| std::cmp::Reverse(s.name.len()));
    for spec in specs {
        let Some(rest) = name.strip_prefix(spec.name).and_then(|r| r.strip_prefix('_')) else {
            continue;
        };
        let id = match rest {
            "calib" => ArtifactId { kind: ArtifactKind::Calib, w_bits: 0, a_bits: 0 },
            "fp_train" => {
                ArtifactId { kind: ArtifactKind::Train(TrainSel::Fp), w_bits: 0, a_bits: 0 }
            }
            "fp_fwd" => ArtifactId { kind: ArtifactKind::Fwd, w_bits: 0, a_bits: 0 },
            _ => {
                let (tag, tail) = rest
                    .split_once('_')
                    .ok_or_else(|| anyhow!("artifact {name:?}: malformed suffix {rest:?}"))?;
                let (w, a) = crate::quant::parse_bits_tag(tag)
                    .ok_or_else(|| anyhow!("artifact {name:?}: bad bits tag {tag:?} (want e.g. w8a8)"))?;
                let kind = if tail == "fwd" {
                    ArtifactKind::Fwd
                } else if tail == "train_lwpn" {
                    ArtifactKind::Train(TrainSel::Lwpn)
                } else if let Some(pct) = tail.strip_prefix("train_r") {
                    let pct: u32 = pct
                        .parse()
                        .map_err(|_| anyhow!("artifact {name:?}: bad ratio in {tail:?}"))?;
                    ArtifactKind::Train(TrainSel::Ratio(pct as f32 / 100.0))
                } else {
                    bail!("artifact {name:?}: unknown step kind {tail:?}");
                };
                ArtifactId { kind, w_bits: w, a_bits: a }
            }
        };
        return Ok((spec, id));
    }
    let supported: Vec<&str> = NATIVE_MODELS.iter().map(|m| m.name).collect();
    bail!(
        "artifact {name:?}: no native reference implementation for this model \
         (native backend supports: {}); build the AOT artifacts with `make artifacts` \
         and select `--backend pjrt` for the resnet/bert/gpt models",
        supported.join(", ")
    )
}

// ---------------------------------------------------------------------------
// Manifest synthesis (mirrors python/compile/step.py's IOSpec ordering)
// ---------------------------------------------------------------------------

fn param_infos(m: &MlpSpec) -> Vec<ParamInfo> {
    vec![
        ParamInfo {
            name: "fc1.w".into(),
            shape: vec![m.hidden, m.d_in()],
            init: Init::HeLin(m.d_in()),
            kind: "weight".into(),
        },
        ParamInfo { name: "fc1.b".into(), shape: vec![m.hidden], init: Init::Zeros, kind: "bias".into() },
        ParamInfo {
            name: "fc2.w".into(),
            shape: vec![m.classes, m.hidden],
            init: Init::HeLin(m.hidden),
            kind: "weight".into(),
        },
        ParamInfo { name: "fc2.b".into(), shape: vec![m.classes], init: Init::Zeros, kind: "bias".into() },
    ]
}

fn wsite_infos(m: &MlpSpec) -> Vec<WSite> {
    vec![
        WSite { name: "fc1.w".into(), c_out: m.hidden, size: m.hidden * m.d_in() },
        WSite { name: "fc2.w".into(), c_out: m.classes, size: m.classes * m.hidden },
    ]
}

fn io(name: &str, shape: Vec<usize>, dtype: Dtype, role: &str, of: Option<&str>) -> IoSpec {
    IoSpec {
        name: name.to_string(),
        shape,
        dtype,
        role: role.to_string(),
        of: of.map(str::to_string),
    }
}

fn build_manifest(m: &MlpSpec, name: &str, id: &ArtifactId) -> Manifest {
    let quant = id.w_bits > 0;
    let params = param_infos(m);
    let wsites = wsite_infos(m);

    let mut inputs: Vec<IoSpec> =
        params.iter().map(|p| io(&p.name, p.shape.clone(), Dtype::F32, "param", None)).collect();
    if quant && id.kind != ArtifactKind::Calib {
        for s in &wsites {
            inputs.push(io(&format!("sw:{}", s.name), vec![s.c_out], Dtype::F32, "qparam_sw", Some(&s.name)));
            inputs.push(io(&format!("sx:{}", s.name), vec![1], Dtype::F32, "qparam_sx", Some(&s.name)));
            inputs.push(io(&format!("zx:{}", s.name), vec![1], Dtype::F32, "qparam_zx", Some(&s.name)));
        }
    }
    inputs.push(io("x", vec![m.batch, m.channels, m.hw, m.hw], Dtype::F32, "data", None));
    if id.kind != ArtifactKind::Calib {
        inputs.push(io("y", vec![m.batch], Dtype::I32, "data", None));
    }

    let mut outputs: Vec<IoSpec> = Vec::new();
    match id.kind {
        ArtifactKind::Calib => {
            for s in &wsites {
                outputs.push(io(&format!("mm:{}", s.name), vec![2], Dtype::F32, "calib", Some(&s.name)));
            }
        }
        ArtifactKind::Fwd => {
            outputs.push(io("loss", vec![1], Dtype::F32, "loss", None));
            outputs.push(io("correct", vec![1], Dtype::I32, "metric", None));
            outputs.push(io("logits", vec![m.batch, m.classes], Dtype::F32, "logits", None));
        }
        ArtifactKind::Train(sel) => {
            if let TrainSel::Ratio(r) = sel {
                if r > 0.0 && r < 1.0 {
                    for s in &wsites {
                        inputs.push(io(
                            &format!("id:{}", s.name),
                            vec![site_k(s.c_out, r)],
                            Dtype::I32,
                            "index",
                            Some(&s.name),
                        ));
                    }
                }
            }
            if sel == TrainSel::Lwpn {
                for s in &wsites {
                    inputs.push(io(&format!("flag:{}", s.name), vec![1], Dtype::I32, "flag", Some(&s.name)));
                }
            }
            outputs.push(io("loss", vec![1], Dtype::F32, "loss", None));
            outputs.push(io("correct", vec![1], Dtype::I32, "metric", None));
            // weight/bias grads in parameter order, then qparam grads per
            // site — exactly python/compile/step.py's manifest order
            let weight_grads = |p: &ParamInfo| -> Option<Vec<usize>> {
                match sel {
                    TrainSel::Fp => Some(p.shape.clone()),
                    TrainSel::Lwpn => Some(p.shape.clone()),
                    TrainSel::Ratio(r) if r >= 1.0 => Some(p.shape.clone()),
                    TrainSel::Ratio(r) if r <= 0.0 => None,
                    TrainSel::Ratio(r) => {
                        Some(vec![site_k(p.shape[0], r), p.shape[1..].iter().product()])
                    }
                }
            };
            for p in &params {
                let shape = if p.kind == "weight" {
                    match weight_grads(p) {
                        Some(s) => s,
                        None => continue,
                    }
                } else {
                    p.shape.clone()
                };
                outputs.push(io(&format!("d:{}", p.name), shape, Dtype::F32, "grad", Some(&p.name)));
            }
            if sel != TrainSel::Fp {
                for s in &wsites {
                    let sw_rows = match sel {
                        TrainSel::Ratio(r) if r <= 0.0 => None,
                        TrainSel::Ratio(r) if r < 1.0 => Some(site_k(s.c_out, r)),
                        _ => Some(s.c_out),
                    };
                    if let Some(k) = sw_rows {
                        outputs.push(io(
                            &format!("d:sw:{}", s.name),
                            vec![k],
                            Dtype::F32,
                            "grad",
                            Some(&format!("sw:{}", s.name)),
                        ));
                    }
                    outputs.push(io(
                        &format!("d:sx:{}", s.name),
                        vec![1],
                        Dtype::F32,
                        "grad",
                        Some(&format!("sx:{}", s.name)),
                    ));
                    outputs.push(io(
                        &format!("d:zx:{}", s.name),
                        vec![1],
                        Dtype::F32,
                        "grad",
                        Some(&format!("zx:{}", s.name)),
                    ));
                }
            }
        }
    }

    let (sel_mode, ratio) = match id.kind {
        ArtifactKind::Train(TrainSel::Fp) => ("fp", 1.0),
        ArtifactKind::Train(TrainSel::Ratio(r)) => ("ratio", r),
        ArtifactKind::Train(TrainSel::Lwpn) => ("lwpn", 1.0),
        _ => ("", 1.0),
    };
    Manifest {
        name: name.to_string(),
        model: m.name.to_string(),
        kind: match id.kind {
            ArtifactKind::Train(_) => "train",
            ArtifactKind::Fwd => "fwd",
            ArtifactKind::Calib => "calib",
        }
        .to_string(),
        sel_mode: sel_mode.to_string(),
        ratio,
        w_bits: id.w_bits,
        a_bits: id.a_bits,
        batch_size: m.batch,
        params,
        states: Vec::new(),
        wsites,
        inputs,
        outputs,
    }
}

// ---------------------------------------------------------------------------
// Host kernels (vectorized counterparts of kernels/ref.py; the scalar
// formulas live in crate::quant and are shared so both layers agree)
// ---------------------------------------------------------------------------

/// Per-row symmetric weight fake-quant (Eq. 3): `ŵ = clip(round(w/s))·s`.
pub fn fq_weight_rows(w: &[f32], s: &[f32], row_size: usize, bits: u32) -> Vec<f32> {
    let mut out = vec![0.0; w.len()];
    for (r, &sr) in s.iter().enumerate() {
        for i in 0..row_size {
            out[r * row_size + i] = fq_sym(w[r * row_size + i], sr, bits);
        }
    }
    out
}

/// Per-tensor asymmetric activation fake-quant (Eq. 1).
pub fn fq_act_tensor(x: &[f32], s: f32, z: f32, bits: u32) -> Vec<f32> {
    x.iter().map(|&v| fq_asym(v, s, z, bits)).collect()
}

/// STE/LSQ backward of the weight quantizer for the given (already
/// row-restricted) rows.  Returns `(dw, dsw)`; mirrors
/// `python/compile/quantization.py::fq_weight_bwd`.
pub fn fq_weight_bwd_rows(
    w_rows: &[f32],
    s: &[f32],
    dwhat: &[f32],
    row_size: usize,
    bits: u32,
) -> (Vec<f32>, Vec<f32>) {
    let (qmin, qmax) = qrange_sym(bits);
    let (qmin, qmax) = (qmin as f32, qmax as f32);
    let mut dw = vec![0.0; w_rows.len()];
    let mut ds = vec![0.0; s.len()];
    for (r, &sr) in s.iter().enumerate() {
        for i in 0..row_size {
            let idx = r * row_size + i;
            let v = w_rows[idx] / sr;
            let q = v.round().clamp(qmin, qmax);
            if v >= qmin && v <= qmax {
                dw[idx] = dwhat[idx]; // STE pass-through inside the clip range
                ds[r] += dwhat[idx] * (q - v); // LSQ: ∂ŵ/∂s = q - v
            } else {
                ds[r] += dwhat[idx] * q; // clipped: boundary code
            }
        }
    }
    (dw, ds)
}

/// STE/LSQ+ backward of the activation quantizer.  Returns
/// `(dx, ds, dz)`; mirrors
/// `python/compile/quantization.py::fq_act_bwd`.
pub fn fq_act_bwd_tensor(x: &[f32], s: f32, z: f32, dxhat: &[f32], bits: u32) -> (Vec<f32>, f32, f32) {
    let (qmin, qmax) = qrange_asym(bits);
    let (qmin, qmax) = (qmin as f32, qmax as f32);
    let zr = z.round();
    let mut dx = vec![0.0; x.len()];
    let (mut ds, mut dz) = (0f32, 0f32);
    for i in 0..x.len() {
        let v = x[i] / s;
        let c = (v.round() + zr).clamp(qmin, qmax);
        // LSQ+ convention: the pass-through mask uses the continuous code
        if v + zr >= qmin && v + zr <= qmax {
            dx[i] = dxhat[i];
            ds += dxhat[i] * ((c - zr) - v);
        } else {
            ds += dxhat[i] * (c - zr);
            dz += dxhat[i] * (-s);
        }
    }
    (dx, ds, dz)
}

/// `y[b,o] = Σ_i x[b,i]·w[o,i] (+ bias[o])` — the linear forward.
fn linear_fwd(x: &[f32], w: &[f32], bias: Option<&[f32]>, bsz: usize, cin: usize, cout: usize) -> Vec<f32> {
    let mut y = vec![0.0; bsz * cout];
    for b in 0..bsz {
        let xr = &x[b * cin..(b + 1) * cin];
        for o in 0..cout {
            let wr = &w[o * cin..(o + 1) * cin];
            let mut acc = match bias {
                Some(bv) => bv[o],
                None => 0.0,
            };
            for i in 0..cin {
                acc += xr[i] * wr[i];
            }
            y[b * cout + o] = acc;
        }
    }
    y
}

/// `dx[b,i] = Σ_o dy[b,o]·w[o,i]` — the full input gradient (always
/// computed dense, like QAT: Eq. 5's first matmul).
fn matmul_dy_w(dy: &[f32], w: &[f32], bsz: usize, cout: usize, cin: usize) -> Vec<f32> {
    let mut dx = vec![0.0; bsz * cin];
    for b in 0..bsz {
        for o in 0..cout {
            let g = dy[b * cout + o];
            if g == 0.0 {
                continue;
            }
            let wr = &w[o * cin..(o + 1) * cin];
            let dxr = &mut dx[b * cin..(b + 1) * cin];
            for i in 0..cin {
                dxr[i] += g * wr[i];
            }
        }
    }
    dx
}

/// `dW[o,i] = Σ_b dy[b,o]·x[b,i]` — the full weight gradient.
fn matmul_dyt_x(dy: &[f32], x: &[f32], bsz: usize, cout: usize, cin: usize) -> Vec<f32> {
    let mut dw = vec![0.0; cout * cin];
    for b in 0..bsz {
        let xr = &x[b * cin..(b + 1) * cin];
        for o in 0..cout {
            let g = dy[b * cout + o];
            if g == 0.0 {
                continue;
            }
            let dwr = &mut dw[o * cin..(o + 1) * cin];
            for i in 0..cin {
                dwr[i] += g * xr[i];
            }
        }
    }
    dw
}

/// Partial weight gradient (paper Fig. 1 right, mirrors
/// `kernels/ref.py::partial_dw_ref`): `dW[idx] = gather(dy, idx)ᵀ · x̂` —
/// only the `k` unfrozen rows are ever materialized.
pub fn partial_dw(dy: &[f32], x: &[f32], idx: &[usize], bsz: usize, cout: usize, cin: usize) -> Vec<f32> {
    let mut dw = vec![0.0; idx.len() * cin];
    for b in 0..bsz {
        let xr = &x[b * cin..(b + 1) * cin];
        for (r, &o) in idx.iter().enumerate() {
            let g = dy[b * cout + o];
            if g == 0.0 {
                continue;
            }
            let dwr = &mut dw[r * cin..(r + 1) * cin];
            for i in 0..cin {
                dwr[i] += g * xr[i];
            }
        }
    }
    dw
}

// ---------------------------------------------------------------------------
// Step execution
// ---------------------------------------------------------------------------

/// Runtime weight-gradient selection for one site, resolved from the
/// manifest + selector inputs.
#[derive(Clone, Debug)]
enum RunSel {
    All,
    None,
    Idx(Vec<usize>),
    Flag(bool),
}

/// Per-site quantization parameters pulled from the inputs.
struct SiteQ {
    sw: Vec<f32>,
    sx: f32,
    zx: f32,
}

struct NativeStep {
    spec: &'static MlpSpec,
    id: ArtifactId,
    man: Manifest,
}

struct Vals<'a> {
    map: BTreeMap<&'a str, &'a Value>,
}

impl<'a> Vals<'a> {
    fn f32(&self, name: &str) -> Result<&'a Tensor> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow!("native step: missing input {name:?}"))?
            .f32()
    }

    fn i32(&self, name: &str) -> Result<&'a ITensor> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow!("native step: missing input {name:?}"))?
            .i32()
    }

    fn scalar(&self, name: &str) -> Result<f32> {
        Ok(self.f32(name)?.data[0])
    }
}

/// Everything the forward pass leaves behind for the backward pass
/// (the residual cache of `layers.py::qlinear_fwd`), including the
/// validated per-site quantization parameters so the backward never
/// re-derives them.
struct Fwd {
    xh1: Vec<f32>,
    wh1: Vec<f32>,
    h_pre: Vec<f32>,
    act: Vec<f32>,
    xh2: Vec<f32>,
    wh2: Vec<f32>,
    logits: Vec<f32>,
    q1: Option<SiteQ>,
    q2: Option<SiteQ>,
}

impl NativeStep {
    fn quantized(&self) -> bool {
        self.id.w_bits > 0 && self.id.kind != ArtifactKind::Calib
    }

    fn siteq(&self, vals: &Vals, site: &str) -> Result<SiteQ> {
        Ok(SiteQ {
            sw: self.guard_scales(vals.f32(&format!("sw:{site}"))?.data.clone(), site)?,
            sx: vals.scalar(&format!("sx:{site}"))?,
            zx: vals.scalar(&format!("zx:{site}"))?,
        })
    }

    fn guard_scales(&self, sw: Vec<f32>, site: &str) -> Result<Vec<f32>> {
        if sw.iter().any(|&s| s <= 0.0 || !s.is_finite()) {
            bail!("{}: non-positive weight scale for site {site:?}", self.man.name);
        }
        Ok(sw)
    }

    fn forward(&self, vals: &Vals) -> Result<Fwd> {
        let m = self.spec;
        let (bsz, d_in, hidden, classes) = (m.batch, m.d_in(), m.hidden, m.classes);
        let x = &vals.f32("x")?.data;
        let w1 = &vals.f32("fc1.w")?.data;
        let b1 = &vals.f32("fc1.b")?.data;
        let w2 = &vals.f32("fc2.w")?.data;
        let b2 = &vals.f32("fc2.b")?.data;

        let q1 = if self.quantized() {
            let q = self.siteq(vals, "fc1.w")?;
            if q.sx <= 0.0 {
                bail!("{}: non-positive activation scale for site \"fc1.w\"", self.man.name);
            }
            Some(q)
        } else {
            None
        };
        let (xh1, wh1) = match &q1 {
            Some(q) => (
                fq_act_tensor(x, q.sx, q.zx, self.id.a_bits),
                fq_weight_rows(w1, &q.sw, d_in, self.id.w_bits),
            ),
            None => (x.clone(), w1.clone()),
        };
        let h_pre = linear_fwd(&xh1, &wh1, Some(b1), bsz, d_in, hidden);
        let act: Vec<f32> = h_pre.iter().map(|&v| v.max(0.0)).collect();

        let q2 = if self.quantized() {
            let q = self.siteq(vals, "fc2.w")?;
            if q.sx <= 0.0 {
                bail!("{}: non-positive activation scale for site \"fc2.w\"", self.man.name);
            }
            Some(q)
        } else {
            None
        };
        let (xh2, wh2) = match &q2 {
            Some(q) => (
                fq_act_tensor(&act, q.sx, q.zx, self.id.a_bits),
                fq_weight_rows(w2, &q.sw, hidden, self.id.w_bits),
            ),
            None => (act.clone(), w2.clone()),
        };
        let logits = linear_fwd(&xh2, &wh2, Some(b2), bsz, hidden, classes);
        Ok(Fwd { xh1, wh1, h_pre, act, xh2, wh2, logits, q1, q2 })
    }

    /// Mean softmax cross-entropy over the static batch (the AOT
    /// artifacts do the same; the evaluator compensates for wrap-padding
    /// host-side).  Returns `(loss, correct, dlogits)`.
    fn ce(&self, logits: &[f32], labels: &[i32]) -> Result<(f32, i32, Vec<f32>)> {
        let (bsz, classes) = (self.spec.batch, self.spec.classes);
        let mut loss = 0f32;
        let mut correct = 0i32;
        let mut dlogits = vec![0f32; bsz * classes];
        for b in 0..bsz {
            let row = &logits[b * classes..(b + 1) * classes];
            let y = labels[b];
            if y < 0 || y as usize >= classes {
                bail!("{}: label {y} out of range [0, {classes})", self.man.name);
            }
            let y = y as usize;
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
            let sum: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
            let lse = sum.ln() + mx;
            loss += lse - row[y];
            if argmax(row) == y {
                correct += 1;
            }
            for c in 0..classes {
                let p = (row[c] - lse).exp();
                let onehot = if c == y { 1.0 } else { 0.0 };
                dlogits[b * classes + c] = (p - onehot) / bsz as f32;
            }
        }
        Ok((loss / bsz as f32, correct, dlogits))
    }

    fn run_sel(&self, vals: &Vals, site: &str, c_out: usize) -> Result<RunSel> {
        match self.id.kind {
            ArtifactKind::Train(TrainSel::Fp) => Ok(RunSel::All),
            ArtifactKind::Train(TrainSel::Lwpn) => {
                Ok(RunSel::Flag(vals.i32(&format!("flag:{site}"))?.data[0] > 0))
            }
            ArtifactKind::Train(TrainSel::Ratio(r)) if r >= 1.0 => Ok(RunSel::All),
            ArtifactKind::Train(TrainSel::Ratio(r)) if r <= 0.0 => Ok(RunSel::None),
            ArtifactKind::Train(TrainSel::Ratio(_)) => {
                let ids = vals.i32(&format!("id:{site}"))?;
                let mut out = Vec::with_capacity(ids.data.len());
                for &c in &ids.data {
                    if c < 0 || c as usize >= c_out {
                        bail!(
                            "{}: selection index {c} out of range for site {site:?} (c_out {c_out})",
                            self.man.name
                        );
                    }
                    out.push(c as usize);
                }
                Ok(RunSel::Idx(out))
            }
            _ => Ok(RunSel::All),
        }
    }

    /// Backward through one quantized (or FP) linear layer, honoring the
    /// per-site selection.  Returns `(dx, dw, dsw, db, dsx, dzx)`; `dw` /
    /// `dsw` are `None` when the selection produces no weight gradient.
    #[allow(clippy::too_many_arguments)]
    fn qlinear_bwd(
        &self,
        dy: &[f32],
        x_raw: &[f32],
        xh: &[f32],
        wh: &[f32],
        w: &[f32],
        q: Option<&SiteQ>,
        sel: &RunSel,
        cin: usize,
        cout: usize,
    ) -> (Vec<f32>, Option<Vec<f32>>, Option<Vec<f32>>, Vec<f32>, f32, f32) {
        let bsz = self.spec.batch;
        let mut db = vec![0f32; cout];
        for b in 0..bsz {
            for o in 0..cout {
                db[o] += dy[b * cout + o];
            }
        }
        let dxh = matmul_dy_w(dy, wh, bsz, cout, cin);
        match q {
            Some(q) => {
                let (dw, dsw) = match sel {
                    RunSel::All | RunSel::Flag(true) => {
                        let dwhat = matmul_dyt_x(dy, xh, bsz, cout, cin);
                        let (dw, ds) = fq_weight_bwd_rows(w, &q.sw, &dwhat, cin, self.id.w_bits);
                        (Some(dw), Some(ds))
                    }
                    RunSel::Flag(false) => {
                        // frozen layer: the dW matmul is skipped at
                        // runtime (the LWPN compute saving); the ABI
                        // still carries full-shape zero grads
                        (Some(vec![0.0; cout * cin]), Some(vec![0.0; cout]))
                    }
                    RunSel::Idx(ids) => {
                        let dwhat = partial_dw(dy, xh, ids, bsz, cout, cin);
                        let w_rows: Vec<f32> = ids
                            .iter()
                            .flat_map(|&r| w[r * cin..(r + 1) * cin].iter().copied())
                            .collect();
                        let s_rows: Vec<f32> = ids.iter().map(|&r| q.sw[r]).collect();
                        let (dw, ds) =
                            fq_weight_bwd_rows(&w_rows, &s_rows, &dwhat, cin, self.id.w_bits);
                        (Some(dw), Some(ds))
                    }
                    RunSel::None => (None, None),
                };
                let (dx, dsx, dzx) = fq_act_bwd_tensor(x_raw, q.sx, q.zx, &dxh, self.id.a_bits);
                (dx, dw, dsw, db, dsx, dzx)
            }
            None => {
                let dw = match sel {
                    RunSel::None => None,
                    _ => Some(matmul_dyt_x(dy, xh, bsz, cout, cin)),
                };
                (dxh, dw, None, db, 0.0, 0.0)
            }
        }
    }

    fn run_train(&self, vals: &Vals) -> Result<BTreeMap<String, Value>> {
        let m = self.spec;
        let fwd = self.forward(vals)?;
        let labels = &vals.i32("y")?.data;
        let (loss, correct, dlogits) = self.ce(&fwd.logits, labels)?;

        let quant = self.quantized();
        let sel1 = self.run_sel(vals, "fc1.w", m.hidden)?;
        let sel2 = self.run_sel(vals, "fc2.w", m.classes)?;

        // layer 2 backward
        let w2 = &vals.f32("fc2.w")?.data;
        let (da, dw2, dsw2, db2, dsx2, dzx2) = self.qlinear_bwd(
            &dlogits,
            &fwd.act,
            &fwd.xh2,
            &fwd.wh2,
            w2,
            fwd.q2.as_ref(),
            &sel2,
            m.hidden,
            m.classes,
        );
        // ReLU backward
        let dh: Vec<f32> =
            da.iter().zip(&fwd.h_pre).map(|(&g, &h)| if h > 0.0 { g } else { 0.0 }).collect();
        // layer 1 backward (dx is discarded — the input is data)
        let x = &vals.f32("x")?.data;
        let w1 = &vals.f32("fc1.w")?.data;
        let (_dx, dw1, dsw1, db1, dsx1, dzx1) = self.qlinear_bwd(
            &dh,
            x,
            &fwd.xh1,
            &fwd.wh1,
            w1,
            fwd.q1.as_ref(),
            &sel1,
            m.d_in(),
            m.hidden,
        );

        let mut out: BTreeMap<String, Value> = BTreeMap::new();
        out.insert("loss".into(), Value::F32(Tensor::scalar(loss)));
        out.insert("correct".into(), Value::I32(ITensor { shape: vec![1], data: vec![correct] }));
        let grad_rows = |sel: &RunSel, full: usize| match sel {
            RunSel::Idx(ids) => ids.len(),
            _ => full,
        };
        if let Some(dw) = dw1 {
            let rows = grad_rows(&sel1, m.hidden);
            out.insert(
                "d:fc1.w".into(),
                Value::F32(Tensor { shape: vec![rows, m.d_in()], data: dw }),
            );
        }
        out.insert("d:fc1.b".into(), Value::F32(Tensor { shape: vec![m.hidden], data: db1 }));
        if let Some(dw) = dw2 {
            let rows = grad_rows(&sel2, m.classes);
            out.insert(
                "d:fc2.w".into(),
                Value::F32(Tensor { shape: vec![rows, m.hidden], data: dw }),
            );
        }
        out.insert("d:fc2.b".into(), Value::F32(Tensor { shape: vec![m.classes], data: db2 }));
        if quant {
            if let Some(ds) = dsw1 {
                let rows = ds.len();
                out.insert("d:sw:fc1.w".into(), Value::F32(Tensor { shape: vec![rows], data: ds }));
            }
            out.insert("d:sx:fc1.w".into(), Value::F32(Tensor::scalar(dsx1)));
            out.insert("d:zx:fc1.w".into(), Value::F32(Tensor::scalar(dzx1)));
            if let Some(ds) = dsw2 {
                let rows = ds.len();
                out.insert("d:sw:fc2.w".into(), Value::F32(Tensor { shape: vec![rows], data: ds }));
            }
            out.insert("d:sx:fc2.w".into(), Value::F32(Tensor::scalar(dsx2)));
            out.insert("d:zx:fc2.w".into(), Value::F32(Tensor::scalar(dzx2)));
        }
        Ok(out)
    }

    fn run_fwd(&self, vals: &Vals) -> Result<BTreeMap<String, Value>> {
        let m = self.spec;
        let fwd = self.forward(vals)?;
        let labels = &vals.i32("y")?.data;
        let (loss, correct, _) = self.ce(&fwd.logits, labels)?;
        let mut out = BTreeMap::new();
        out.insert("loss".to_string(), Value::F32(Tensor::scalar(loss)));
        out.insert("correct".to_string(), Value::I32(ITensor { shape: vec![1], data: vec![correct] }));
        out.insert(
            "logits".to_string(),
            Value::F32(Tensor { shape: vec![m.batch, m.classes], data: fwd.logits }),
        );
        Ok(out)
    }

    fn run_calib(&self, vals: &Vals) -> Result<BTreeMap<String, Value>> {
        // FP forward with (min, max) taps at each quantized layer's input
        let fwd = self.forward(vals)?;
        let x = &vals.f32("x")?.data;
        let minmax = |xs: &[f32]| {
            let lo = xs.iter().copied().fold(f32::INFINITY, f32::min);
            let hi = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            Value::F32(Tensor { shape: vec![2], data: vec![lo, hi] })
        };
        let mut out = BTreeMap::new();
        out.insert("mm:fc1.w".to_string(), minmax(x));
        out.insert("mm:fc2.w".to_string(), minmax(&fwd.act));
        Ok(out)
    }
}

impl StepExec for NativeStep {
    fn run(&self, inputs: &[Value]) -> Result<(Vec<Value>, Duration)> {
        let vals = Vals {
            map: self.man.inputs.iter().map(|s| s.name.as_str()).zip(inputs).collect(),
        };
        // the host compute IS the device here — time the whole evaluation
        let t0 = Instant::now();
        let mut named = match self.id.kind {
            ArtifactKind::Train(_) => self.run_train(&vals)?,
            ArtifactKind::Fwd => self.run_fwd(&vals)?,
            ArtifactKind::Calib => self.run_calib(&vals)?,
        };
        let dt = t0.elapsed();
        let outs = self
            .man
            .outputs
            .iter()
            .map(|spec| {
                named.remove(&spec.name).ok_or_else(|| {
                    anyhow!("{}: native step produced no output {:?}", self.man.name, spec.name)
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok((outs, dt))
    }
}

// ---------------------------------------------------------------------------
// Backend
// ---------------------------------------------------------------------------

/// The native CPU reference backend.  Holds the artifacts directory only
/// for error messages and parity with the PJRT constructor — native steps
/// are synthesized, not loaded from disk.
pub struct NativeBackend {
    /// Where PJRT artifacts would live; echoed in diagnostics.
    pub artifacts_dir: PathBuf,
}

impl NativeBackend {
    /// Create the backend; never fails (nothing to probe).
    pub fn new(artifacts_dir: &Path) -> NativeBackend {
        NativeBackend { artifacts_dir: artifacts_dir.to_path_buf() }
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn load(&self, artifact: &str) -> Result<Step> {
        let t0 = Instant::now();
        let (spec, id) = parse_artifact(artifact)?;
        let man = build_manifest(spec, artifact, &id);
        let exec = NativeStep { spec, id, man: man.clone() };
        Ok(Step::new(man, "native", t0.elapsed(), Box::new(exec)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant;
    use crate::testing::forall;

    // ---- artifact-name grammar -------------------------------------------

    #[test]
    fn parses_every_artifact_kind() {
        for (name, kind, w, a) in [
            ("mlp_calib", ArtifactKind::Calib, 0, 0),
            ("mlp_fp_train", ArtifactKind::Train(TrainSel::Fp), 0, 0),
            ("mlp_fp_fwd", ArtifactKind::Fwd, 0, 0),
            ("mlp_w8a8_fwd", ArtifactKind::Fwd, 8, 8),
            ("mlp_w4a8_train_r25", ArtifactKind::Train(TrainSel::Ratio(0.25)), 4, 8),
            ("mlp_w8a8_train_r100", ArtifactKind::Train(TrainSel::Ratio(1.0)), 8, 8),
            ("mlp_w8a8_train_r0", ArtifactKind::Train(TrainSel::Ratio(0.0)), 8, 8),
            ("mlp_w8a8_train_lwpn", ArtifactKind::Train(TrainSel::Lwpn), 8, 8),
            ("mlp_wide_w8a8_fwd", ArtifactKind::Fwd, 8, 8),
        ] {
            let (spec, id) = parse_artifact(name).unwrap();
            assert_eq!(id.kind, kind, "{name}");
            assert_eq!((id.w_bits, id.a_bits), (w, a), "{name}");
            assert!(name.starts_with(spec.name), "{name} vs {}", spec.name);
        }
        assert!(name_err("resnet8_fp_train").contains("no native reference implementation"));
        assert!(name_err("mlp_w8a8_train_rx").contains("bad ratio"));
        assert!(name_err("mlp_8a8_fwd").contains("bits tag"));
    }

    fn name_err(name: &str) -> String {
        parse_artifact(name).unwrap_err().to_string()
    }

    #[test]
    fn wide_model_wins_prefix_race() {
        let (spec, _) = parse_artifact("mlp_wide_calib").unwrap();
        assert_eq!(spec.name, "mlp_wide");
    }

    // ---- manifest shapes --------------------------------------------------

    fn load(name: &str) -> Step {
        NativeBackend::new(Path::new("artifacts")).load(name).unwrap()
    }

    #[test]
    fn train_manifest_matches_step_contract() {
        let m = load("mlp_w8a8_train_r25").manifest;
        assert_eq!(m.sel_mode, "ratio");
        assert_eq!(m.ratio, 0.25);
        assert_eq!(m.wsites.len(), 2);
        // index slots sized by site_k
        let idx: Vec<&IoSpec> = m.inputs.iter().filter(|i| i.role == "index").collect();
        assert_eq!(idx.len(), 2);
        assert_eq!(idx[0].shape, vec![site_k(32, 0.25)]);
        assert_eq!(idx[1].shape, vec![site_k(10, 0.25)]);
        // gathered grad rows match the slots
        let dw: Vec<&IoSpec> =
            m.outputs.iter().filter(|o| o.name.starts_with("d:fc") && o.name.ends_with(".w")).collect();
        assert_eq!(dw[0].shape, vec![site_k(32, 0.25), 192]);
        assert_eq!(dw[1].shape, vec![site_k(10, 0.25), 32]);
    }

    #[test]
    fn r0_manifest_has_no_weight_grads_but_keeps_act_qparam_grads() {
        let m = load("mlp_w8a8_train_r0").manifest;
        assert!(!m.outputs.iter().any(|o| o.name == "d:fc1.w"));
        assert!(!m.outputs.iter().any(|o| o.name == "d:sw:fc1.w"));
        assert!(m.outputs.iter().any(|o| o.name == "d:sx:fc1.w"));
        assert!(m.outputs.iter().any(|o| o.name == "d:fc1.b"));
    }

    #[test]
    fn fp_manifest_has_no_qparams() {
        let m = load("mlp_fp_train").manifest;
        assert_eq!(m.sel_mode, "fp");
        assert!(!m.inputs.iter().any(|i| i.role.starts_with("qparam")));
        assert!(m.outputs.iter().any(|o| o.name == "d:fc1.w"));
        assert!(!m.outputs.iter().any(|o| o.name.starts_with("d:sw")));
    }

    #[test]
    fn calib_manifest_taps_every_site() {
        let m = load("mlp_calib").manifest;
        assert_eq!(m.kind, "calib");
        assert_eq!(m.outputs.len(), 2);
        assert!(m.outputs.iter().all(|o| o.role == "calib"));
        // calib binds x only (no labels)
        assert!(!m.inputs.iter().any(|i| i.name == "y"));
    }

    // ---- native kernels agree with the host-side quant.rs (Eq. 1–4) ------

    #[test]
    fn prop_fq_weight_rows_matches_scalar_fq_sym() {
        forall(200, |r| {
            let rows = 1 + r.below(6);
            let rs = 1 + r.below(8);
            let bits = if r.uniform() < 0.5 { 4 } else { 8 };
            let mut rng = r.split(11);
            let w = rng.normal_vec(rows * rs, 1.0);
            let s: Vec<f32> = (0..rows).map(|_| r.uniform_in(1e-3, 0.2)).collect();
            let out = fq_weight_rows(&w, &s, rs, bits);
            for row in 0..rows {
                for i in 0..rs {
                    let want = quant::fq_sym(w[row * rs + i], s[row], bits);
                    assert_eq!(out[row * rs + i], want);
                }
            }
        });
    }

    #[test]
    fn prop_fq_act_tensor_matches_scalar_fq_asym() {
        forall(200, |r| {
            let n = 1 + r.below(32);
            let s = r.uniform_in(1e-3, 0.1);
            let z = r.uniform_in(0.0, 255.0).round();
            let mut rng = r.split(12);
            let x = rng.normal_vec(n, 2.0);
            let out = fq_act_tensor(&x, s, z, 8);
            for i in 0..n {
                assert_eq!(out[i], quant::fq_asym(x[i], s, z, 8));
            }
        });
    }

    #[test]
    fn fq_weight_bwd_ste_rules() {
        // in range: dw passes through, ds = (q - v)·g
        let (dw, ds) = fq_weight_bwd_rows(&[0.05], &[0.1], &[2.0], 1, 8);
        assert_eq!(dw, vec![2.0]);
        // v = 0.5 → q = round(0.5) = 0 (ties-to-even? f32::round is
        // away-from-zero: q = 1) → ds = (1 - 0.5)·2 = 1
        assert!((ds[0] - 1.0).abs() < 1e-6, "{}", ds[0]);
        // clipped: dw = 0, ds = boundary code · g
        let (dw, ds) = fq_weight_bwd_rows(&[100.0], &[0.1], &[1.0], 1, 8);
        assert_eq!(dw, vec![0.0]);
        assert!((ds[0] - 127.0).abs() < 1e-6);
    }

    #[test]
    fn fq_act_bwd_ste_rules() {
        // in range: dx passes through, dz = 0
        let (dx, _ds, dz) = fq_act_bwd_tensor(&[0.5], 0.1, 10.0, &[3.0], 8);
        assert_eq!(dx, vec![3.0]);
        assert_eq!(dz, 0.0);
        // clipped high: dx = 0, dz = -s·g
        let (dx, _ds, dz) = fq_act_bwd_tensor(&[100.0], 0.1, 10.0, &[1.0], 8);
        assert_eq!(dx, vec![0.0]);
        assert!((dz + 0.1).abs() < 1e-7);
    }

    #[test]
    fn partial_dw_matches_gathered_full_dw() {
        // partial_dw == rows of the full dW (ref.py::partial_dw_ref)
        forall(100, |r| {
            let (bsz, cout, cin) = (2 + r.below(4), 2 + r.below(6), 1 + r.below(5));
            let mut rng = r.split(13);
            let dy = rng.normal_vec(bsz * cout, 1.0);
            let x = rng.normal_vec(bsz * cin, 1.0);
            let k = 1 + r.below(cout);
            let idx = {
                let mut rng2 = r.split(14);
                rng2.choice(cout, k)
            };
            let full = matmul_dyt_x(&dy, &x, bsz, cout, cin);
            let part = partial_dw(&dy, &x, &idx, bsz, cout, cin);
            for (gi, &row) in idx.iter().enumerate() {
                for i in 0..cin {
                    let a = full[row * cin + i];
                    let b = part[gi * cin + i];
                    assert!((a - b).abs() < 1e-5, "row {row}: {a} vs {b}");
                }
            }
        });
    }

    #[test]
    fn unknown_output_is_internal_error_not_panic() {
        // a native step never panics on bad input values — scales of zero
        // are caught with a descriptive error
        let step = load("mlp_w8a8_fwd");
        let mut inputs = Vec::new();
        for spec in &step.manifest.inputs {
            inputs.push(match spec.dtype {
                Dtype::F32 => Value::F32(Tensor::zeros(&spec.shape)),
                Dtype::I32 => Value::I32(ITensor::zeros(&spec.shape)),
            });
        }
        let err = step.execute(&inputs).unwrap_err().to_string();
        assert!(err.contains("scale"), "{err}");
    }
}
