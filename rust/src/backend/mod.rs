//! Execution backends: the seam between the coordinator and "something
//! that can run a compiled step function".
//!
//! The coordinator (Algorithm 1) is backend-agnostic: it binds host
//! tensors to a [`crate::model::Manifest`]'s input specs, asks a [`Step`]
//! to execute, and unpacks named outputs.  Two backends implement that
//! contract:
//!
//! * [`native`] — a pure-rust CPU reference executor that evaluates the
//!   forward, fake-quant (paper Eq. 1–4), loss, and frozen-channel-aware
//!   partial backward entirely host-side, mirroring
//!   `python/compile/kernels/ref.py`.  Zero dependencies; this is what
//!   `cargo test` and the quickstart run.
//! * [`pjrt`] — the XLA/PJRT backend for AOT-compiled HLO artifacts built
//!   by `make artifacts` (feature `pjrt`; requires the vendored `xla`
//!   crate).  Artifact integrity is checked against the schema-versioned
//!   bundle manifest ([`crate::bundle::Bundle`]) before compilation.
//!
//! Backends are selected by name (`--backend native|pjrt`, see
//! [`BackendKind`]); an unavailable backend or a stale/corrupt artifact
//! bundle fails with a descriptive error, never a panic.

pub mod native;
pub mod pjrt;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;
use std::rc::Rc;
use std::time::Duration;

use crate::error::{anyhow, bail, Context, Result};
use crate::exec::Workspace;
use crate::model::{Dtype, IoSpec, Manifest};
use crate::tensor::{ITensor, Tensor};

/// A host value crossing the backend boundary.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(ITensor),
}

impl Value {
    /// Borrow as an f32 tensor, or error.
    pub fn f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => bail!("expected f32 value"),
        }
    }

    /// Borrow as an i32 tensor, or error.
    pub fn i32(&self) -> Result<&ITensor> {
        match self {
            Value::I32(t) => Ok(t),
            _ => bail!("expected i32 value"),
        }
    }

    /// The single element of a `[1]`-shaped f32 value.  Empty or
    /// multi-element tensors are a descriptive error, never an index
    /// panic.
    pub fn scalar(&self) -> Result<f32> {
        let t = self.f32()?;
        match t.data.as_slice() {
            [v] => Ok(*v),
            _ => bail!("expected a scalar value, got shape {:?} ({} elems)", t.shape, t.data.len()),
        }
    }

    /// Element type of the value.
    pub fn dtype(&self) -> Dtype {
        match self {
            Value::F32(_) => Dtype::F32,
            Value::I32(_) => Dtype::I32,
        }
    }

    /// Shape of the underlying tensor.
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
        }
    }

    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape().iter().product()
    }
}

/// Named outputs of one step execution.
#[derive(Debug)]
pub struct Outputs {
    pub map: BTreeMap<String, Value>,
}

impl Outputs {
    /// Fetch an output by manifest name.
    pub fn get(&self, name: &str) -> Result<&Value> {
        self.map.get(name).ok_or_else(|| anyhow!("missing output {name:?}"))
    }

    /// The scalar training loss (`loss` output).
    pub fn loss(&self) -> Result<f32> {
        self.get("loss")?.scalar()
    }

    /// The per-batch correct-prediction count (`correct` output).
    pub fn correct(&self) -> Result<i32> {
        Ok(self.get("correct")?.i32()?.data[0])
    }
}

/// The executable part of a [`Step`]: run positional inputs to positional
/// outputs.  Implementations do no ABI validation — [`Step`] validates
/// both directions against the manifest so every backend fails with the
/// same descriptive errors.
pub trait StepExec {
    /// Execute on inputs packed in manifest order; return outputs in
    /// manifest order plus the backend's own measure of execution
    /// wall-time.  The duration must cover exactly the step-function
    /// evaluation (device execute + result fetch for PJRT; the host
    /// compute for native) and exclude host-side packing/unpacking, so
    /// the Table 5 runtime numbers stay comparable across backends.
    fn run(&self, inputs: &[Value]) -> Result<(Vec<Value>, Duration)>;

    /// Like [`Self::run`], drawing every scratch/output buffer from a
    /// caller-owned [`Workspace`] so steady-state execution performs no
    /// heap allocation.  Backends without a planned executor (PJRT —
    /// the device owns its buffers) fall through to [`Self::run`].
    fn run_ws(&self, inputs: &[Value], ws: &mut Workspace) -> Result<(Vec<Value>, Duration)> {
        let _ = ws;
        self.run(inputs)
    }
}

/// One loaded step function: its manifest (the cross-language ABI) plus a
/// backend executor.
pub struct Step {
    /// The artifact manifest this step was loaded against.
    pub manifest: Manifest,
    /// Which backend produced this step (`"native"` / `"pjrt"`).
    pub backend: &'static str,
    /// Wall time spent loading/compiling the step.
    pub compile_time: Duration,
    exec: Box<dyn StepExec>,
}

impl Step {
    /// Couple a manifest with a backend executor.
    pub fn new(
        manifest: Manifest,
        backend: &'static str,
        compile_time: Duration,
        exec: Box<dyn StepExec>,
    ) -> Step {
        Step { manifest, backend, compile_time, exec }
    }

    /// Artifact name from the manifest.
    pub fn name(&self) -> &str {
        &self.manifest.name
    }

    /// Execute with values packed in manifest input order.
    pub fn execute(&self, inputs: &[Value]) -> Result<Outputs> {
        let (out, _) = self.execute_timed(inputs)?;
        Ok(out)
    }

    /// Execute and report the backend's execution wall-time (the paper's
    /// backward-runtime measurements in Table 5 report exactly this
    /// duration — see [`StepExec::run`] for what it covers).
    pub fn execute_timed(&self, inputs: &[Value]) -> Result<(Outputs, Duration)> {
        let mut ws = Workspace::new();
        let (outs, dt) = self.execute_timed_ws(inputs, &mut ws)?;
        let mut map = BTreeMap::new();
        for (spec, v) in self.manifest.outputs.iter().zip(outs) {
            map.insert(spec.name.clone(), v);
        }
        Ok((Outputs { map }, dt))
    }

    /// Positional, workspace-pooled execution: outputs come back in
    /// manifest order with no named map built, and on the native
    /// backend every buffer is drawn from `ws` — this is the trainer's
    /// and evaluator's hot path.  Recycle the returned values with
    /// [`Workspace::give_values`] after consuming them and the steady
    /// state performs zero heap allocations per step.
    pub fn execute_timed_ws(
        &self,
        inputs: &[Value],
        ws: &mut Workspace,
    ) -> Result<(Vec<Value>, Duration)> {
        if inputs.len() != self.manifest.inputs.len() {
            bail!(
                "{}: {} inputs supplied, manifest wants {}",
                self.manifest.name,
                inputs.len(),
                self.manifest.inputs.len()
            );
        }
        for (spec, v) in self.manifest.inputs.iter().zip(inputs) {
            check_abi(&self.manifest.name, "input", spec, v)?;
        }
        let (outs, dt) = self.exec.run_ws(inputs, ws)?;
        if outs.len() != self.manifest.outputs.len() {
            bail!(
                "{}: {} outputs returned, manifest declares {}",
                self.manifest.name,
                outs.len(),
                self.manifest.outputs.len()
            );
        }
        for (spec, v) in self.manifest.outputs.iter().zip(&outs) {
            check_abi(&self.manifest.name, "output", spec, v)?;
        }
        Ok((outs, dt))
    }
}

fn check_abi(step: &str, dir: &str, spec: &IoSpec, v: &Value) -> Result<()> {
    if v.dtype() != spec.dtype {
        bail!(
            "{step}: {dir} {:?} has dtype {:?}, manifest declares {:?}",
            spec.name,
            v.dtype(),
            spec.dtype
        );
    }
    if v.shape() != spec.shape.as_slice() {
        bail!(
            "{step}: {dir} {:?} has shape {:?} ({} elems), manifest declares {:?} ({} elems)",
            spec.name,
            v.shape(),
            v.elems(),
            spec.shape,
            spec.elems()
        );
    }
    Ok(())
}

/// A named execution backend: loads artifacts into executable [`Step`]s.
pub trait Backend {
    /// Stable backend name used in logs and errors.
    fn name(&self) -> &'static str;
    /// Load (and, for compiled backends, verify + compile) one artifact.
    fn load(&self, artifact: &str) -> Result<Step>;
}

/// Which backend to use; selected by name on the CLI (`--backend`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-rust CPU reference executor ([`native`]); always available.
    #[default]
    Native,
    /// XLA/PJRT artifact executor ([`pjrt`]); needs the `pjrt` feature
    /// and a bundle of AOT-compiled artifacts.
    Pjrt,
}

impl BackendKind {
    /// Parse a CLI/config backend name.
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" | "cpu" | "ref" => Ok(BackendKind::Native),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            other => bail!("unknown backend {other:?} (available: native, pjrt)"),
        }
    }
}

impl fmt::Display for BackendKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        })
    }
}

/// Instantiate a backend by kind.  Fails with a descriptive error when
/// the requested backend is not compiled in or its artifact bundle is
/// missing/invalid.
pub fn create(kind: BackendKind, artifacts_dir: &Path) -> Result<Rc<dyn Backend>> {
    match kind {
        BackendKind::Native => Ok(Rc::new(native::NativeBackend::new(artifacts_dir))),
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => Ok(Rc::new(pjrt::PjrtBackend::new(artifacts_dir)?)),
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Pjrt => bail!(
            "this build does not include the PJRT backend; rebuild with \
             `cargo build --features pjrt` and the vendored `xla` crate \
             (README.md §PJRT backend), or use `--backend native`"
        ),
    }
}

/// Lazily-loaded, memoized steps keyed by artifact name.
pub struct StepCache {
    backend: Rc<dyn Backend>,
    cache: RefCell<BTreeMap<String, Rc<Step>>>,
}

impl StepCache {
    /// Wrap a backend with a per-process step cache.
    pub fn new(backend: Rc<dyn Backend>) -> StepCache {
        StepCache { backend, cache: RefCell::new(BTreeMap::new()) }
    }

    /// Get (loading + memoizing on first use) a step by artifact name.
    pub fn get(&self, name: &str) -> Result<Rc<Step>> {
        if let Some(s) = self.cache.borrow().get(name) {
            return Ok(s.clone());
        }
        let step = Rc::new(self.backend.load(name).with_context(|| {
            format!("loading artifact {name} on the {} backend", self.backend.name())
        })?);
        self.cache.borrow_mut().insert(name.to_string(), step.clone());
        Ok(step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;
    impl StepExec for Echo {
        fn run(&self, inputs: &[Value]) -> Result<(Vec<Value>, Duration)> {
            Ok((vec![inputs[0].clone()], Duration::ZERO))
        }
    }

    fn toy_manifest() -> Manifest {
        Manifest::parse(
            r#"{
              "name": "toy_fwd", "model": "toy", "kind": "fwd",
              "w_bits": 0, "a_bits": 0, "batch_size": 2,
              "params": [], "states": [], "wsites": [],
              "inputs": [{"name": "x", "shape": [2, 3], "dtype": "f32", "role": "data"}],
              "outputs": [{"name": "y", "shape": [2, 3], "dtype": "f32", "role": "logits"}]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn step_validates_input_count_and_shape() {
        let step = Step::new(toy_manifest(), "native", Duration::ZERO, Box::new(Echo));
        assert!(step.execute(&[]).is_err());
        let bad = Value::F32(Tensor::zeros(&[4, 3]));
        let err = step.execute(&[bad]).unwrap_err().to_string();
        assert!(err.contains("manifest declares"), "{err}");
        // same element count but transposed layout is also rejected
        let bad = Value::F32(Tensor::zeros(&[3, 2]));
        let err = step.execute(&[bad]).unwrap_err().to_string();
        assert!(err.contains("manifest declares"), "{err}");
        let ok = Value::F32(Tensor::zeros(&[2, 3]));
        let out = step.execute(&[ok]).unwrap();
        assert_eq!(out.get("y").unwrap().shape(), &[2, 3]);
    }

    #[test]
    fn step_validates_dtype() {
        let step = Step::new(toy_manifest(), "native", Duration::ZERO, Box::new(Echo));
        let bad = Value::I32(ITensor::zeros(&[2, 3]));
        let err = step.execute(&[bad]).unwrap_err().to_string();
        assert!(err.contains("dtype"), "{err}");
    }

    #[test]
    fn scalar_rejects_empty_and_multi_element_values() {
        assert_eq!(Value::F32(Tensor::scalar(3.5)).scalar().unwrap(), 3.5);
        let err = Value::F32(Tensor::zeros(&[0])).scalar().unwrap_err().to_string();
        assert!(err.contains("scalar"), "{err}");
        let err = Value::F32(Tensor::zeros(&[2])).scalar().unwrap_err().to_string();
        assert!(err.contains("scalar"), "{err}");
        assert!(Value::I32(ITensor::zeros(&[1])).scalar().is_err());
    }

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("PJRT").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::default(), BackendKind::Native);
    }
}
