//! Execution workspace: the reusable buffer arena behind the planned
//! executors (RFC `docs/rfcs/0003-exec-plan.md`).
//!
//! EfQAT's headline win is a cheaper backward pass (paper Fig. 1 right),
//! but an executor that re-allocates every activation, residual cache,
//! and gradient buffer on every step hands a slice of that win back to
//! the allocator.  A [`Workspace`] removes the allocator from the steady
//! state: every scratch/cache/output buffer a planned execution needs is
//! *taken* from a typed free list and *given* back when its lifetime
//! ends, so after one warmup iteration the same capacities circulate
//! forever and the per-step / per-request heap-allocation count is zero
//! (`rust/tests/workspace_alloc.rs` asserts exactly that under a
//! counting global allocator).
//!
//! Ownership model: `take_*` hands out an **owned** `Vec` (cleared and
//! zero-resized to the requested length), which makes the arena safe to
//! thread through recursive executors without aliasing bookkeeping —
//! there are no offsets to keep disjoint and no `unsafe`.  The cost is
//! one `memset` per take (cheaper than `malloc`+`memset`, and the point
//! is reuse, not zero-fill avoidance).  Buffer selection is best-fit by
//! capacity, so a serving workspace naturally implements the high-water
//! resize policy: shrinking the dynamic batch reuses the large buffers,
//! growing past the high-water mark grows exactly one buffer per slot
//! and then plateaus.
//!
//! Who holds one:
//!
//! * the trainer — one workspace across all epochs/steps
//!   ([`crate::coordinator::trainer`]);
//! * offline eval — one across all batches ([`crate::coordinator::eval`]);
//! * each serving worker — one per worker thread, reused across
//!   micro-batches ([`crate::serve::worker`]);
//! * the thin allocating wrappers (`GraphStep::execute`,
//!   `QuantizedGraph::forward`) — a throwaway workspace per call, so
//!   cold paths and tests keep their old signatures.

use crate::backend::Value;
use crate::tensor::{ITensor, Tensor};

/// Reuse statistics — how well the steady state is holding.
#[derive(Clone, Copy, Debug, Default)]
pub struct WsStats {
    /// Total `take_*` calls served.
    pub takes: u64,
    /// Takes that could not be served from pooled capacity and had to
    /// allocate or grow.  Flat across iterations ⇒ zero steady-state
    /// heap allocations from this workspace.
    pub misses: u64,
}

/// A typed free-list arena of reusable buffers.
///
/// See the module docs for the ownership model; the short version is
/// `let buf = ws.take_f32(n); ...; ws.give_f32(buf);` with `take`
/// returning a cleared, zero-filled, length-`n` owned vector.
#[derive(Default)]
pub struct Workspace {
    f32s: Vec<Vec<f32>>,
    i32s: Vec<Vec<i32>>,
    u8s: Vec<Vec<u8>>,
    shapes: Vec<Vec<usize>>,
    values: Vec<Vec<Value>>,
    slots: Vec<Vec<Option<Value>>>,
    stats: WsStats,
}

/// Free-list length cap.  Gives beyond this drop the buffer instead of
/// pooling it: a workspace can *adopt* buffers it did not hand out
/// (e.g. a serving worker recycling logits from an engine that does
/// not draw from the workspace), and without a cap such adoption grows
/// the pool — and the best-fit scan — without bound.  The planned
/// executors keep well under this many live buffers, so the cap never
/// affects the steady-state zero-allocation guarantee.
const MAX_POOL: usize = 256;

/// Best-fit pop: the smallest pooled vector whose capacity covers `n`,
/// else the largest available (growing one buffer beats allocating a
/// second), else `None`.
fn pop_fit<T>(pool: &mut Vec<Vec<T>>, n: usize) -> Option<Vec<T>> {
    let mut best: Option<(usize, usize)> = None; // (index, capacity)
    let mut biggest: Option<(usize, usize)> = None;
    for (i, v) in pool.iter().enumerate() {
        let cap = v.capacity();
        if cap >= n && !matches!(best, Some((_, b)) if b <= cap) {
            best = Some((i, cap));
        }
        if !matches!(biggest, Some((_, b)) if b >= cap) {
            biggest = Some((i, cap));
        }
    }
    best.or(biggest).map(|(i, _)| pool.swap_remove(i))
}

impl Workspace {
    /// A fresh, empty workspace.
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Reuse statistics (takes vs. pool misses).
    pub fn stats(&self) -> WsStats {
        self.stats
    }

    fn note(&mut self, missed: bool) {
        self.stats.takes += 1;
        if missed {
            self.stats.misses += 1;
        }
    }

    /// Take a zero-filled `f32` buffer of length `n`.
    pub fn take_f32(&mut self, n: usize) -> Vec<f32> {
        let mut v = pop_fit(&mut self.f32s, n).unwrap_or_default();
        self.note(v.capacity() < n);
        v.clear();
        v.resize(n, 0.0);
        v
    }

    /// Return an `f32` buffer to the pool.  Zero-capacity vectors (the
    /// `Vec::new()` placeholders some caches use) are dropped — they
    /// hold no memory worth keeping and would silt up the free list.
    pub fn give_f32(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 && self.f32s.len() < MAX_POOL {
            self.f32s.push(v);
        }
    }

    /// Take a zero-filled `i32` buffer of length `n`.
    pub fn take_i32(&mut self, n: usize) -> Vec<i32> {
        let mut v = pop_fit(&mut self.i32s, n).unwrap_or_default();
        self.note(v.capacity() < n);
        v.clear();
        v.resize(n, 0);
        v
    }

    /// Return an `i32` buffer to the pool (zero-capacity vectors drop).
    pub fn give_i32(&mut self, v: Vec<i32>) {
        if v.capacity() > 0 && self.i32s.len() < MAX_POOL {
            self.i32s.push(v);
        }
    }

    /// Take a zero-filled `u8` code buffer of length `n`.
    pub fn take_u8(&mut self, n: usize) -> Vec<u8> {
        let mut v = pop_fit(&mut self.u8s, n).unwrap_or_default();
        self.note(v.capacity() < n);
        v.clear();
        v.resize(n, 0);
        v
    }

    /// Return a `u8` buffer to the pool (zero-capacity vectors drop).
    pub fn give_u8(&mut self, v: Vec<u8>) {
        if v.capacity() > 0 && self.u8s.len() < MAX_POOL {
            self.u8s.push(v);
        }
    }

    /// Take a shape vector holding a copy of `dims`.
    pub fn take_shape(&mut self, dims: &[usize]) -> Vec<usize> {
        let mut v = pop_fit(&mut self.shapes, dims.len()).unwrap_or_default();
        self.note(v.capacity() < dims.len());
        v.clear();
        v.extend_from_slice(dims);
        v
    }

    /// Take an *empty* index vector with capacity for at least `n`
    /// entries — for callers that push a data-dependent number of
    /// elements (≤ `n`) instead of copying a template.  Requesting the
    /// full capacity up front keeps the steady state reallocation-free
    /// and the miss counter honest.
    pub fn take_indices(&mut self, n: usize) -> Vec<usize> {
        let mut v = pop_fit(&mut self.shapes, n).unwrap_or_default();
        self.note(v.capacity() < n);
        v.clear();
        v.reserve(n);
        v
    }

    /// Return a shape vector to the pool (zero-capacity vectors drop).
    pub fn give_shape(&mut self, v: Vec<usize>) {
        if v.capacity() > 0 && self.shapes.len() < MAX_POOL {
            self.shapes.push(v);
        }
    }

    /// Build an f32 tensor from pooled shape + the given (typically
    /// pooled) data.
    pub fn tensor(&mut self, dims: &[usize], data: Vec<f32>) -> Tensor {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { shape: self.take_shape(dims), data }
    }

    /// Build a pooled `[1]`-shaped scalar tensor.
    pub fn scalar(&mut self, v: f32) -> Tensor {
        let mut data = self.take_f32(1);
        data[0] = v;
        Tensor { shape: self.take_shape(&[1]), data }
    }

    /// Build an i32 tensor from pooled shape + the given data.
    pub fn itensor(&mut self, dims: &[usize], data: Vec<i32>) -> ITensor {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        ITensor { shape: self.take_shape(dims), data }
    }

    /// Dismantle a tensor back into the pools.
    pub fn give_tensor(&mut self, t: Tensor) {
        self.give_shape(t.shape);
        self.give_f32(t.data);
    }

    /// Dismantle an i32 tensor back into the pools.
    pub fn give_itensor(&mut self, t: ITensor) {
        self.give_shape(t.shape);
        self.give_i32(t.data);
    }

    /// Dismantle a backend value back into the pools.
    pub fn give_value(&mut self, v: Value) {
        match v {
            Value::F32(t) => self.give_tensor(t),
            Value::I32(t) => self.give_itensor(t),
        }
    }

    /// Take an empty reusable `Vec<Value>` (positional outputs).
    pub fn take_values(&mut self) -> Vec<Value> {
        self.values.pop().unwrap_or_default()
    }

    /// Recycle a positional output vector *and* every value in it.
    pub fn give_values(&mut self, mut vals: Vec<Value>) {
        while let Some(v) = vals.pop() {
            self.give_value(v);
        }
        self.values.push(vals);
    }

    /// Take an output-slot vector of `n` empty slots.
    pub fn take_slots(&mut self, n: usize) -> Vec<Option<Value>> {
        let mut v = self.slots.pop().unwrap_or_default();
        v.clear();
        v.resize_with(n, || None);
        v
    }

    /// Recycle an output-slot vector, dismantling any leftover values.
    pub fn give_slots(&mut self, mut slots: Vec<Option<Value>>) {
        while let Some(slot) = slots.pop() {
            if let Some(v) = slot {
                self.give_value(v);
            }
        }
        self.slots.push(slots);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_zero_fills_and_reuse_hits_the_pool() {
        let mut ws = Workspace::new();
        let mut a = ws.take_f32(16);
        assert_eq!(a.len(), 16);
        assert!(a.iter().all(|&v| v == 0.0));
        a[3] = 7.0;
        ws.give_f32(a);
        // the dirty buffer comes back clean
        let b = ws.take_f32(16);
        assert!(b.iter().all(|&v| v == 0.0));
        ws.give_f32(b);
        let s = ws.stats();
        assert_eq!(s.takes, 2);
        assert_eq!(s.misses, 1, "second take must be a pool hit");
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient_capacity() {
        let mut ws = Workspace::new();
        let small = ws.take_f32(8);
        let big = ws.take_f32(1024);
        let small_cap = small.capacity();
        ws.give_f32(small);
        ws.give_f32(big);
        let got = ws.take_f32(8);
        assert_eq!(got.capacity(), small_cap, "best-fit should not burn the big buffer");
        ws.give_f32(got);
    }

    #[test]
    fn shrink_then_regrow_stays_within_high_water() {
        let mut ws = Workspace::new();
        let a = ws.take_f32(100);
        ws.give_f32(a);
        let before = ws.stats().misses;
        for n in [40usize, 100, 7, 100] {
            let v = ws.take_f32(n);
            assert_eq!(v.len(), n);
            ws.give_f32(v);
        }
        assert_eq!(ws.stats().misses, before, "within the high-water mark nothing allocates");
        // growing past the mark misses exactly once, then plateaus again
        let v = ws.take_f32(200);
        ws.give_f32(v);
        let after_grow = ws.stats().misses;
        assert_eq!(after_grow, before + 1);
        let v = ws.take_f32(200);
        ws.give_f32(v);
        assert_eq!(ws.stats().misses, after_grow);
    }

    #[test]
    fn tensors_and_values_round_trip_through_the_pools() {
        let mut ws = Workspace::new();
        let data = ws.take_f32(6);
        let t = ws.tensor(&[2, 3], data);
        assert_eq!(t.shape, vec![2, 3]);
        ws.give_value(Value::F32(t));
        let s = ws.scalar(4.5);
        assert_eq!((s.shape.as_slice(), s.data[0]), (&[1usize][..], 4.5));
        ws.give_tensor(s);
        let mut d = ws.take_i32(2);
        d[1] = 9;
        let it = ws.itensor(&[2], d);
        assert_eq!(it.data, vec![0, 9]);
        ws.give_value(Value::I32(it));
        let mut slots = ws.take_slots(3);
        slots[1] = Some(Value::F32(ws.scalar(1.0)));
        ws.give_slots(slots);
        let vals = ws.take_values();
        assert!(vals.is_empty());
        ws.give_values(vals);
    }
}
