//! Tiny CLI parser (clap is unavailable offline).
//!
//! Grammar: `efqat <subcommand> [--key value | --flag] ...`
//!
//! Two layers:
//!
//! * [`Args`] — the tokenizer: splits argv into subcommand, `--key
//!   value` options, and bare `--flag`s.  Benches reuse it untyped.
//! * [`Cli`] — the typed layer `efqat` itself runs: each subcommand has
//!   an arg struct parsed **once**, so a misspelled or unknown option
//!   (`--moodel`) is an error instead of being silently ignored, and
//!   numeric options (`--ratio`, `--port`, `--workers`) fail loudly at
//!   parse time.  Dotted keys (`--data.train_n 4096`,
//!   `--batch.wait-ms 2`) are always accepted: they are config
//!   overrides, overlaid onto the experiment [`crate::cfg::Config`]
//!   together with the validated bare keys — so any config key stays
//!   reachable from the command line without parser support of its own.

use std::collections::BTreeMap;

use crate::error::{bail, Result};

/// Boolean switches that never consume a value (resolves the `--flag
/// positional` ambiguity the same way clap's `action = SetTrue` would).
const KNOWN_FLAGS: &[&str] = &["verbose", "force", "full", "fast", "help", "quiet", "no-save"];

/// Untyped token layer: subcommand, `--key value` options, bare flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare `--flag`
                if let Some((k, v)) = key.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if KNOWN_FLAGS.contains(&key) {
                    a.flags.push(key.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    a.options.insert(key.to_string(), it.next().unwrap().clone());
                } else {
                    a.flags.push(key.to_string());
                }
            } else if a.subcommand.is_empty() {
                a.subcommand = arg.clone();
            } else {
                a.positional.push(arg.clone());
            }
        }
        if a.subcommand.is_empty() {
            bail!("no subcommand given");
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }
}

/// Bare keys every subcommand accepts (session-level selectors read
/// across the coordinator, not per-command).
const GLOBAL_KEYS: &[&str] = &["config", "backend", "artifacts", "ckpt_dir"];

/// Flags every subcommand tolerates without error.
const GLOBAL_FLAGS: &[&str] = &["verbose", "quiet", "help"];

/// `efqat pretrain` arguments.
#[derive(Clone, Debug, Default)]
pub struct PretrainArgs {
    /// `--model` (a config file may supply it instead).
    pub model: Option<String>,
    /// `--epochs` (falls back to `train.epochs`).
    pub epochs: Option<usize>,
}

/// `efqat ptq` arguments.
#[derive(Clone, Debug, Default)]
pub struct PtqArgs {
    /// `--model`.
    pub model: Option<String>,
    /// `--bits`, e.g. `w8a8`.
    pub bits: Option<String>,
}

/// `efqat train` arguments.
#[derive(Clone, Debug, Default)]
pub struct TrainArgs {
    /// `--model`.
    pub model: Option<String>,
    /// `--bits`, e.g. `w8a8`.
    pub bits: Option<String>,
    /// `--mode cwpl|cwpn|lwpn|qat|r0`.
    pub mode: Option<String>,
    /// `--ratio` update percentage, validated as an integer.
    pub ratio: Option<usize>,
    /// `--workers` data-parallel shard count.
    pub workers: Option<usize>,
}

/// `efqat eval` arguments.
#[derive(Clone, Debug, Default)]
pub struct EvalArgs {
    /// `--model`.
    pub model: Option<String>,
    /// `--bits` (`fp` or a quantized tag).
    pub bits: Option<String>,
    /// `--ckpt` checkpoint path.
    pub ckpt: Option<String>,
    /// `--exec fakequant|int8`.
    pub exec: Option<String>,
}

/// One `--models` entry: serve `name` from the checkpoint at `path`,
/// lowered with graph architecture `arch` (defaults to `name`; spell
/// `name=arch:path` when the serving name differs from the
/// architecture — e.g. `mlp-canary=mlp:ckpt/new.ckpt`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelSpec {
    /// Registry name requests route by.
    pub name: String,
    /// Native graph architecture to lower (`mlp`, `convnet`, ...).
    pub arch: String,
    /// Checkpoint path (quantized checkpoint file).
    pub path: String,
}

/// `efqat serve` arguments.
#[derive(Clone, Debug, Default)]
pub struct ServeArgs {
    /// `--model` (single-model mode; mutually exclusive with `--models`).
    pub model: Option<String>,
    /// `--ckpt` (single-model mode).
    pub ckpt: Option<String>,
    /// `--bits`, e.g. `w8a8` (shared by every served model).
    pub bits: Option<String>,
    /// `--exec int8|f32` (single-model mode; `--models` is int8-only).
    pub exec: Option<String>,
    /// `--port` TCP listener (stdin/stdout when absent).
    pub port: Option<u16>,
    /// `--models name=path,name=arch:path,...` multi-model registry.
    pub models: Vec<ModelSpec>,
    /// `--default-model`: which model answers model-less (v1) requests.
    pub default_model: Option<String>,
    /// `--record file.jsonl`: capture accepted traffic for `replay`.
    pub record: Option<String>,
}

/// `efqat replay` arguments.
#[derive(Clone, Debug, Default)]
pub struct ReplayArgs {
    /// `--trace file.jsonl`: the recorded traffic to re-issue (required).
    pub trace: String,
    /// `--model` (single-model mode; mutually exclusive with `--models`).
    pub model: Option<String>,
    /// `--ckpt` (single-model mode).
    pub ckpt: Option<String>,
    /// `--bits`, e.g. `w8a8` (shared by every served model).
    pub bits: Option<String>,
    /// `--exec int8|f32` (single-model mode; `--models` is int8-only).
    pub exec: Option<String>,
    /// `--models name=path,...` multi-model registry (same as `serve`).
    pub models: Vec<ModelSpec>,
    /// `--default-model`: which model answers model-less (v1) records.
    pub default_model: Option<String>,
    /// `--speed N`: pacing multiplier (1.0 = recorded pace).
    pub speed: Option<f64>,
}

/// `efqat bundle` arguments.
#[derive(Clone, Debug, Default)]
pub struct BundleArgs {
    /// `--note` free-form provenance string.
    pub note: Option<String>,
}

/// A fully parsed and validated invocation.
#[derive(Clone, Debug)]
pub enum Cmd {
    /// `efqat pretrain`.
    Pretrain(PretrainArgs),
    /// `efqat ptq`.
    Ptq(PtqArgs),
    /// `efqat train`.
    Train(TrainArgs),
    /// `efqat eval`.
    Eval(EvalArgs),
    /// `efqat serve`.
    Serve(ServeArgs),
    /// `efqat replay`.
    Replay(ReplayArgs),
    /// `efqat bundle`.
    Bundle(BundleArgs),
    /// `efqat info`.
    Info,
    /// `--help` anywhere: print usage, exit 0.
    Help,
}

/// The typed CLI: one subcommand struct plus the config-overlay state.
#[derive(Clone, Debug)]
pub struct Cli {
    /// The validated subcommand.
    pub cmd: Cmd,
    /// `--config file.toml`, loaded before overrides apply.
    pub config: Option<String>,
    /// Every `--key value` pair (dotted config overrides and validated
    /// bare keys alike), to overlay onto the experiment config.
    pub overrides: BTreeMap<String, String>,
}

impl Cli {
    /// Tokenize and validate argv into a typed subcommand.  Unknown
    /// subcommands, unknown bare options, unknown flags, unexpected
    /// positionals, and malformed numeric values are all errors here —
    /// nothing is silently ignored.
    pub fn parse(argv: &[String]) -> Result<Cli> {
        let mut args = Args::parse(argv)?;
        if args.flag("help") || args.subcommand == "help" {
            return Ok(Cli { cmd: Cmd::Help, config: None, overrides: BTreeMap::new() });
        }
        // A bare dotted flag is a boolean config override: `--batch.adaptive`
        // is shorthand for `--batch.adaptive true`.
        let dotted: Vec<String> = args.flags.iter().filter(|f| f.contains('.')).cloned().collect();
        args.flags.retain(|f| !f.contains('.'));
        for k in dotted {
            args.options.entry(k).or_insert_with(|| "true".to_string());
        }
        for f in &args.flags {
            if !GLOBAL_FLAGS.contains(&f.as_str()) {
                bail!("unknown flag --{f} for `{}`", args.subcommand);
            }
        }
        if let Some(p) = args.positional.first() {
            bail!("unexpected positional argument {p:?} (options are `--key value`)");
        }
        let cmd = match args.subcommand.as_str() {
            "pretrain" => {
                check_keys(&args, &["model", "epochs", "save_ckpt"])?;
                Cmd::Pretrain(PretrainArgs {
                    model: opt_string(&args, "model"),
                    epochs: opt_usize(&args, "epochs")?,
                })
            }
            "ptq" => {
                check_keys(&args, &["model", "bits"])?;
                Cmd::Ptq(PtqArgs {
                    model: opt_string(&args, "model"),
                    bits: opt_string(&args, "bits"),
                })
            }
            "train" => {
                check_keys(&args, &["model", "bits", "mode", "ratio", "workers", "save_ckpt"])?;
                Cmd::Train(TrainArgs {
                    model: opt_string(&args, "model"),
                    bits: opt_string(&args, "bits"),
                    mode: opt_string(&args, "mode"),
                    ratio: opt_usize(&args, "ratio")?,
                    workers: opt_usize(&args, "workers")?,
                })
            }
            "eval" => {
                check_keys(&args, &["model", "bits", "ckpt", "exec"])?;
                Cmd::Eval(EvalArgs {
                    model: opt_string(&args, "model"),
                    bits: opt_string(&args, "bits"),
                    ckpt: opt_string(&args, "ckpt"),
                    exec: opt_string(&args, "exec"),
                })
            }
            "serve" => {
                check_keys(
                    &args,
                    &["model", "ckpt", "bits", "exec", "port", "models", "default-model", "record"],
                )?;
                let serve = ServeArgs {
                    model: opt_string(&args, "model"),
                    ckpt: opt_string(&args, "ckpt"),
                    bits: opt_string(&args, "bits"),
                    exec: opt_string(&args, "exec"),
                    port: opt_port(&args)?,
                    models: match args.opt("models") {
                        Some(spec) => parse_models(spec)?,
                        None => Vec::new(),
                    },
                    default_model: opt_string(&args, "default-model"),
                    record: opt_string(&args, "record"),
                };
                check_model_selectors(
                    &serve.model,
                    &serve.ckpt,
                    &serve.models,
                    &serve.default_model,
                )?;
                Cmd::Serve(serve)
            }
            "replay" => {
                check_keys(
                    &args,
                    &["trace", "model", "ckpt", "bits", "exec", "models", "default-model", "speed"],
                )?;
                let Some(trace) = opt_string(&args, "trace") else {
                    bail!("replay wants --trace file.jsonl (a recorded traffic trace)");
                };
                let replay = ReplayArgs {
                    trace,
                    model: opt_string(&args, "model"),
                    ckpt: opt_string(&args, "ckpt"),
                    bits: opt_string(&args, "bits"),
                    exec: opt_string(&args, "exec"),
                    models: match args.opt("models") {
                        Some(spec) => parse_models(spec)?,
                        None => Vec::new(),
                    },
                    default_model: opt_string(&args, "default-model"),
                    speed: opt_speed(&args)?,
                };
                check_model_selectors(
                    &replay.model,
                    &replay.ckpt,
                    &replay.models,
                    &replay.default_model,
                )?;
                Cmd::Replay(replay)
            }
            "bundle" => {
                check_keys(&args, &["note"])?;
                Cmd::Bundle(BundleArgs { note: opt_string(&args, "note") })
            }
            "info" => {
                check_keys(&args, &[])?;
                Cmd::Info
            }
            other => bail!("unknown subcommand {other:?}"),
        };
        Ok(Cli { cmd, config: opt_string(&args, "config"), overrides: args.options })
    }
}

/// Reject bare option keys the subcommand does not declare.  Dotted keys
/// are config-tree overrides and always pass.
fn check_keys(args: &Args, allowed: &[&str]) -> Result<()> {
    for k in args.options.keys() {
        if k.contains('.') || GLOBAL_KEYS.contains(&k.as_str()) || allowed.contains(&k.as_str()) {
            continue;
        }
        let mut known: Vec<&str> = allowed.iter().chain(GLOBAL_KEYS).copied().collect();
        known.sort_unstable();
        bail!(
            "unknown option --{k} for `{}` (expected one of: --{}, or a dotted config key)",
            args.subcommand,
            known.join(", --")
        );
    }
    Ok(())
}

fn opt_string(args: &Args, key: &str) -> Option<String> {
    args.opt(key).map(str::to_string)
}

fn opt_usize(args: &Args, key: &str) -> Result<Option<usize>> {
    match args.opt(key) {
        None => Ok(None),
        Some(v) => match v.parse::<usize>() {
            Ok(n) => Ok(Some(n)),
            Err(_) => bail!("--{key} wants a non-negative integer, got {v:?}"),
        },
    }
}

/// Validate the model selectors shared by `serve` and `replay`:
/// `--models` excludes `--model`/`--ckpt`, and `--default-model` must
/// name a `--models` entry.
fn check_model_selectors(
    model: &Option<String>,
    ckpt: &Option<String>,
    models: &[ModelSpec],
    default_model: &Option<String>,
) -> Result<()> {
    if !models.is_empty() {
        if model.is_some() || ckpt.is_some() {
            bail!("--models and --model/--ckpt are mutually exclusive");
        }
        if let Some(d) = default_model {
            if !models.iter().any(|m| m.name == *d) {
                let names: Vec<&str> = models.iter().map(|m| m.name.as_str()).collect();
                bail!("--default-model {d:?} is not in --models [{}]", names.join(", "));
            }
        }
    } else if default_model.is_some() {
        bail!("--default-model needs --models (single-model serving has one model)");
    }
    Ok(())
}

fn opt_speed(args: &Args) -> Result<Option<f64>> {
    match args.opt("speed") {
        None => Ok(None),
        Some(v) => match v.parse::<f64>() {
            Ok(s) if s.is_finite() && s > 0.0 => Ok(Some(s)),
            _ => bail!("--speed wants a positive number, got {v:?}"),
        },
    }
}

fn opt_port(args: &Args) -> Result<Option<u16>> {
    match opt_usize(args, "port")? {
        None => Ok(None),
        Some(p) if (1..=u16::MAX as usize).contains(&p) => Ok(Some(p as u16)),
        Some(p) => bail!("--port wants a TCP port in [1, 65535], got {p}"),
    }
}

/// Parse `--models name=path,name2=arch:path2,...`.  The architecture
/// defaults to the serving name; a `arch:` prefix on the path overrides
/// it (recognized only when the prefix looks like an arch token, so
/// plain paths containing `:` elsewhere stay usable).
pub fn parse_models(spec: &str) -> Result<Vec<ModelSpec>> {
    let mut out = Vec::new();
    for entry in spec.split(',').filter(|e| !e.trim().is_empty()) {
        let Some((name, rest)) = entry.split_once('=') else {
            bail!("--models entry {entry:?} is not name=path (or name=arch:path)");
        };
        let (name, rest) = (name.trim(), rest.trim());
        if name.is_empty() || rest.is_empty() {
            bail!("--models entry {entry:?} has an empty name or path");
        }
        let (arch, path) = match rest.split_once(':') {
            Some((a, p)) if !a.is_empty() && !a.contains('/') && !a.contains('.') => (a, p),
            _ => (name, rest),
        };
        if path.is_empty() {
            bail!("--models entry {entry:?} has an empty path");
        }
        if out.iter().any(|m: &ModelSpec| m.name == name) {
            bail!("--models names {name:?} twice");
        }
        out.push(ModelSpec { name: name.to_string(), arch: arch.to_string(), path: path.into() });
    }
    if out.is_empty() {
        bail!("--models wants at least one name=path entry");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let argv = v(&["train", "--model", "resnet20", "--ratio=0.25", "--verbose", "ckpt.bin"]);
        let a = Args::parse(&argv).unwrap();
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.opt("model"), Some("resnet20"));
        assert_eq!(a.opt("ratio"), Some("0.25"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["ckpt.bin"]);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&v(&["eval", "--fast"])).unwrap();
        assert!(a.flag("fast"));
    }

    #[test]
    fn requires_subcommand() {
        assert!(Args::parse(&v(&["--model", "x"])).is_err());
    }

    #[test]
    fn typed_layer_parses_train() {
        let cli = Cli::parse(&v(&["train", "--model", "mlp", "--ratio", "25", "--mode", "cwpn"]))
            .unwrap();
        let Cmd::Train(t) = &cli.cmd else { panic!("want Train") };
        assert_eq!(t.model.as_deref(), Some("mlp"));
        assert_eq!(t.ratio, Some(25));
        assert_eq!(t.mode.as_deref(), Some("cwpn"));
        assert_eq!(cli.overrides.get("model").map(String::as_str), Some("mlp"));
    }

    #[test]
    fn unknown_bare_option_is_an_error_dotted_keys_pass() {
        let err = Cli::parse(&v(&["train", "--moodel", "mlp"])).unwrap_err().to_string();
        assert!(err.contains("--moodel"), "{err}");
        assert!(err.contains("train"), "{err}");
        // dotted keys are config overrides — never rejected
        let cli = Cli::parse(&v(&["train", "--model", "mlp", "--data.train_n", "4096"])).unwrap();
        assert_eq!(cli.overrides.get("data.train_n").map(String::as_str), Some("4096"));
        // unknown flags are errors too (a misspelled switch never no-ops)
        let err = Cli::parse(&v(&["eval", "--fastt"])).unwrap_err().to_string();
        assert!(err.contains("--fastt"), "{err}");
    }

    #[test]
    fn numeric_options_validate_at_parse_time() {
        let err = Cli::parse(&v(&["train", "--ratio", "lots"])).unwrap_err().to_string();
        assert!(err.contains("--ratio"), "{err}");
        let err = Cli::parse(&v(&["serve", "--port", "99999"])).unwrap_err().to_string();
        assert!(err.contains("--port"), "{err}");
        let err = Cli::parse(&v(&["serve", "--port", "0"])).unwrap_err().to_string();
        assert!(err.contains("--port"), "{err}");
    }

    #[test]
    fn serve_parses_models_and_default_model() {
        let cli = Cli::parse(&v(&[
            "serve",
            "--models",
            "mlp=ckpt/a.ckpt,canary=mlp:ckpt/b.ckpt",
            "--default-model",
            "mlp",
        ]))
        .unwrap();
        let Cmd::Serve(s) = &cli.cmd else { panic!("want Serve") };
        assert_eq!(s.models.len(), 2);
        assert_eq!(
            s.models[0],
            ModelSpec { name: "mlp".into(), arch: "mlp".into(), path: "ckpt/a.ckpt".into() }
        );
        assert_eq!(
            s.models[1],
            ModelSpec { name: "canary".into(), arch: "mlp".into(), path: "ckpt/b.ckpt".into() }
        );
        assert_eq!(s.default_model.as_deref(), Some("mlp"));
    }

    #[test]
    fn serve_rejects_contradictory_model_selectors() {
        let err = Cli::parse(&v(&["serve", "--models", "a=x.ckpt", "--model", "mlp"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("mutually exclusive"), "{err}");
        let err = Cli::parse(&v(&["serve", "--models", "a=x.ckpt", "--default-model", "b"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--default-model"), "{err}");
        let err = Cli::parse(&v(&["serve", "--models", "a=x.ckpt,a=y.ckpt"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("twice"), "{err}");
        let err = Cli::parse(&v(&["serve", "--models", "nope"])).unwrap_err().to_string();
        assert!(err.contains("name=path"), "{err}");
    }

    #[test]
    fn bare_dotted_flag_becomes_true_override() {
        let cli = Cli::parse(&v(&["serve", "--model", "mlp", "--batch.adaptive"])).unwrap();
        assert_eq!(cli.overrides.get("batch.adaptive").map(String::as_str), Some("true"));
        // an explicit value wins over the bare-flag shorthand
        let cli = Cli::parse(&v(&["serve", "--batch.adaptive", "false"])).unwrap();
        assert_eq!(cli.overrides.get("batch.adaptive").map(String::as_str), Some("false"));
        // non-dotted bare flags are still validated
        let err = Cli::parse(&v(&["serve", "--adaptive"])).unwrap_err().to_string();
        assert!(err.contains("--adaptive"), "{err}");
    }

    #[test]
    fn serve_parses_record_path() {
        let cli =
            Cli::parse(&v(&["serve", "--model", "mlp", "--record", "trace.jsonl"])).unwrap();
        let Cmd::Serve(s) = &cli.cmd else { panic!("want Serve") };
        assert_eq!(s.record.as_deref(), Some("trace.jsonl"));
    }

    #[test]
    fn replay_parses_and_validates() {
        let cli = Cli::parse(&v(&[
            "replay",
            "--trace",
            "t.jsonl",
            "--models",
            "a=x.ckpt,b=mlp:y.ckpt",
            "--default-model",
            "a",
            "--speed",
            "8",
        ]))
        .unwrap();
        let Cmd::Replay(r) = &cli.cmd else { panic!("want Replay") };
        assert_eq!(r.trace, "t.jsonl");
        assert_eq!(r.models.len(), 2);
        assert_eq!(r.speed, Some(8.0));

        let err = Cli::parse(&v(&["replay", "--model", "mlp"])).unwrap_err().to_string();
        assert!(err.contains("--trace"), "{err}");
        let err = Cli::parse(&v(&["replay", "--trace", "t.jsonl", "--speed", "0"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--speed"), "{err}");
        let err = Cli::parse(&v(&["replay", "--trace", "t.jsonl", "--speed", "nope"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--speed"), "{err}");
        let err = Cli::parse(&v(&["replay", "--trace", "t", "--models", "a=x", "--model", "m"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("mutually exclusive"), "{err}");
    }

    #[test]
    fn help_short_circuits_validation() {
        assert!(matches!(Cli::parse(&v(&["serve", "--help"])).unwrap().cmd, Cmd::Help));
        assert!(matches!(Cli::parse(&v(&["help"])).unwrap().cmd, Cmd::Help));
    }
}
