//! Tiny CLI parser (clap is unavailable offline).
//!
//! Grammar: `efqat <subcommand> [--key value | --flag] ...`
//! All `--key value` pairs are collected and overlaid onto the experiment
//! [`crate::cfg::Config`], so any config key can be overridden from the
//! command line — including the execution selectors (`--backend
//! native|pjrt`, `--exec fakequant|int8`) and serving knobs like
//! `--serve.batch` or `efqat serve`'s `--batch.max` / `--batch.wait-ms`
//! / `--port`, which need no parser support of their own.

use std::collections::BTreeMap;

use crate::error::{bail, Result};

/// Boolean switches that never consume a value (resolves the `--flag
/// positional` ambiguity the same way clap's `action = SetTrue` would).
const KNOWN_FLAGS: &[&str] = &["verbose", "force", "full", "fast", "help", "quiet", "no-save"];

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare `--flag`
                if let Some((k, v)) = key.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if KNOWN_FLAGS.contains(&key) {
                    a.flags.push(key.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    a.options.insert(key.to_string(), it.next().unwrap().clone());
                } else {
                    a.flags.push(key.to_string());
                }
            } else if a.subcommand.is_empty() {
                a.subcommand = arg.clone();
            } else {
                a.positional.push(arg.clone());
            }
        }
        if a.subcommand.is_empty() {
            bail!("no subcommand given");
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let argv = v(&["train", "--model", "resnet20", "--ratio=0.25", "--verbose", "ckpt.bin"]);
        let a = Args::parse(&argv).unwrap();
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.opt("model"), Some("resnet20"));
        assert_eq!(a.opt("ratio"), Some("0.25"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["ckpt.bin"]);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&v(&["eval", "--fast"])).unwrap();
        assert!(a.flag("fast"));
    }

    #[test]
    fn requires_subcommand() {
        assert!(Args::parse(&v(&["--model", "x"])).is_err());
    }
}
