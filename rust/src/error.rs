//! Minimal error handling (the `anyhow` crate is unavailable offline).
//!
//! Provides the exact subset this project uses of the anyhow API surface:
//! an opaque string-carrying [`Error`], the [`Result`] alias with a
//! defaulted error type, the [`anyhow!`](crate::anyhow) and
//! [`bail!`](crate::bail) macros, and the [`Context`] extension trait for
//! `Result`/`Option`.  Any `std::error::Error` converts into [`Error`]
//! via `?`, so `std::fs` / parsing call sites read exactly as they would
//! with anyhow.

use std::fmt;

/// Opaque error: a human-readable message, optionally wrapped by
/// [`Context`] frames (`"outer context: inner message"`).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything stringly (the `anyhow!` macro calls this).
    pub fn msg(m: impl Into<String>) -> Error {
        Error { msg: m.into() }
    }

    /// Prepend a context frame, anyhow-style.
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Any std error converts via `?`.  `Error` itself deliberately does NOT
// implement `std::error::Error`, exactly like anyhow, so this blanket
// impl cannot collide with the reflexive `From<T> for T`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// anyhow-style `.context(..)` / `.with_context(|| ..)` on results and
/// options.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Wrap the error (or `None`) with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`](crate::error::Error) from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::error::Error::msg(format!($($arg)*)) };
}

/// Return early with an [`Error`](crate::error::Error) built from a
/// format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::error::Error::msg(format!($($arg)*))) };
}

// Make `use crate::error::{anyhow, bail}` work like the anyhow imports
// the call sites were written against.
pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
    }

    #[test]
    fn context_wraps_outermost_first() {
        let r: Result<()> = fails().context("outer");
        assert_eq!(r.unwrap_err().to_string(), "outer: boom 42");
        let r: Result<()> = fails().with_context(|| format!("step {}", 7));
        assert_eq!(r.unwrap_err().to_string(), "step 7: boom 42");
    }

    #[test]
    fn std_errors_convert() {
        fn io() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(io().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }
}
