//! Deterministic PCG64-based RNG (the `rand` crate is unavailable offline).
//!
//! Everything stochastic in the coordinator — parameter init, dataset
//! generation, shuffling, seed sweeps — flows through [`Pcg64`], so a run
//! is fully reproducible from its seed.

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((seed as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(0xcafef00dd15ea5e5 ^ ((seed as u128) << 64));
        rng.next_u64();
        rng
    }

    /// Derive an independent stream (for per-worker / per-dataset RNGs).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (k <= n).
    pub fn choice(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(2);
        let xs = r.normal_vec(50_000, 1.0);
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn choice_distinct() {
        let mut r = Pcg64::new(4);
        let c = r.choice(50, 20);
        assert_eq!(c.len(), 20);
        let mut s = c.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 20);
    }
}
