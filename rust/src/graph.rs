//! Layer-graph IR: declarative native models executed by the shared op
//! library.
//!
//! A [`LayerGraph`] is a sequential `Vec<Layer>` (with a [`Layer::Residual`]
//! combinator for transformer blocks) over named parameters.  From one
//! declaration the graph
//!
//! * enumerates parameters ([`LayerGraph::params`]) and freezable weight
//!   sites ([`LayerGraph::wsites`]) — every `Linear`/`Conv2d` output
//!   channel (and each attention projection) is an EfQAT site;
//! * synthesizes the step-function manifest ([`build_manifest`]) for each
//!   artifact kind, byte-compatible with what `python/compile/aot.py`
//!   emits for the same model;
//! * executes forward / backward / calibration generically
//!   ([`GraphStep`]), dispatching the math to [`crate::ops`].
//!
//! The point of the IR is that EfQAT's frozen-channel-aware partial
//! backward (paper Fig. 1 right) is implemented **once** — the
//! executor's `weight_site_grads` resolves the per-site selection (full
//! / gathered rows / layer flag / none) and applies the STE/LSQ
//! quantizer backward — and every layer type inherits it: a linear's
//! rows, a conv's output channels (matmul rows after im2col), and each
//! attention projection all flow through the same code path.
//!
//! Training-time execution here *simulates* quantization (fake-quant in
//! f32); the declaration is also the input of the int8 serving lowering
//! ([`crate::lower::lower`]), which compiles the same `Vec<Layer>` into
//! a [`crate::lower::QuantizedGraph`] of true integer kernels.

use std::collections::BTreeMap;

use crate::backend::Value;
use crate::error::{anyhow, bail, Result};
use crate::freeze::site_k;
use crate::model::{Dtype, Init, IoSpec, Manifest, ParamInfo, WSite};
use crate::ops::attention::{sdpa_bwd, sdpa_fwd, AttnDims};
use crate::ops::conv::{self, ConvDims};
use crate::ops::elementwise::{embed_bwd, embed_fwd, relu_bwd, relu_fwd};
use crate::ops::fakequant::{fq_act_bwd_tensor, fq_act_tensor, fq_weight_bwd_rows, fq_weight_rows};
use crate::ops::loss::softmax_xent;
use crate::ops::matmul::{col_sum, linear_fwd, matmul_dy_w, matmul_dyt_x, partial_dw};
use crate::ops::norm::{layernorm_bwd, layernorm_fwd};
use crate::tensor::{ITensor, Tensor};

// ---------------------------------------------------------------------------
// Step identity (what kind of artifact a graph is executed as)
// ---------------------------------------------------------------------------

/// Weight-gradient selection baked into a train artifact's ABI.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TrainSel {
    /// FP pretraining: no quantization, full `dW`.
    Fp,
    /// Ratio artifact: `r=1` full, `r=0` none, otherwise per-site index
    /// vectors of `site_k(c_out, r)` unfrozen rows.
    Ratio(f32),
    /// LWPN artifact: per-site flags gate whole layers at runtime.
    Lwpn,
}

/// The three step-function kinds every model compiles to.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepKind {
    Train(TrainSel),
    Fwd,
    Calib,
}

/// One artifact's identity: kind + quantization widths.
#[derive(Clone, Copy, Debug)]
pub struct StepId {
    pub kind: StepKind,
    pub w_bits: u32,
    pub a_bits: u32,
}

// ---------------------------------------------------------------------------
// The IR
// ---------------------------------------------------------------------------

/// Quantized linear site: params `{name}.w` (`[c_out, c_in]`, freezable)
/// and optionally `{name}.b`.
#[derive(Clone, Debug)]
pub struct LinearSpec {
    pub name: String,
    pub c_in: usize,
    pub c_out: usize,
    pub bias: bool,
}

/// Quantized conv2d site: param `{name}.w` (`[c_out, c_in, k, k]` OIHW,
/// bias-free like the python layer).  Square inputs/kernels only.
#[derive(Clone, Debug)]
pub struct ConvSpec {
    pub name: String,
    pub c_in: usize,
    pub c_out: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
}

/// LayerNorm over the trailing `d` features: params `{name}.g`, `{name}.b`.
#[derive(Clone, Debug)]
pub struct NormSpec {
    pub name: String,
    pub d: usize,
}

/// Token + learned-position embedding: params `{name}.tok` (`[vocab, d]`)
/// and `{name}.pos` (`[seq, d]`), fp32 and non-freezable (trained during
/// FP pretraining only, per the paper's transformer setup).
#[derive(Clone, Debug)]
pub struct EmbedSpec {
    pub name: String,
    pub vocab: usize,
    pub seq: usize,
    pub d: usize,
}

/// Multi-head self-attention block: four quantized-linear projection
/// sites `{name}.q/k/v/o` (each `[d, d]`) around a scaled-dot-product
/// core.
#[derive(Clone, Debug)]
pub struct AttnSpec {
    pub name: String,
    pub d: usize,
    pub heads: usize,
    pub causal: bool,
}

/// One node of the sequential layer graph.
#[derive(Clone, Debug)]
pub enum Layer {
    /// `[B, ...] → [B, prod]`.
    Flatten,
    Linear(LinearSpec),
    Conv2d(ConvSpec),
    Relu,
    /// 2×2 average pool, stride 2 (NCHW).
    AvgPool2x2,
    LayerNorm(NormSpec),
    Embed(EmbedSpec),
    Attention(AttnSpec),
    /// `y = x + f(x)` — the transformer residual combinator.  The inner
    /// sub-graph must preserve the activation shape.
    Residual(Vec<Layer>),
}

/// What the model consumes as `x`.
#[derive(Clone, Copy, Debug)]
pub enum InputKind {
    /// f32 images `[B, channels, hw, hw]`; labels `y: [B]`.
    Image { channels: usize, hw: usize },
    /// i32 token ids `[B, seq]`; per-token labels `y: [B, seq]` (LM).
    Tokens { seq: usize },
}

/// A declarative native model: the whole step-function family (train /
/// fwd / calib at every precision and ratio) derives from this one value.
#[derive(Clone, Debug)]
pub struct LayerGraph {
    pub model: String,
    /// Static batch dimension baked into the manifests.
    pub batch: usize,
    pub input: InputKind,
    /// Trailing logits dimension (classifier classes or LM vocab).
    pub classes: usize,
    pub layers: Vec<Layer>,
}

impl LayerGraph {
    /// Parameter inventory in graph order (recursing into residuals).
    pub fn params(&self) -> Vec<ParamInfo> {
        let mut out = Vec::new();
        collect_params(&self.layers, &mut out);
        out
    }

    /// Freezable weight sites in graph order.
    pub fn wsites(&self) -> Vec<WSite> {
        let mut out = Vec::new();
        collect_wsites(&self.layers, &mut out);
        out
    }
}

fn lin_params(l: &LinearSpec, out: &mut Vec<ParamInfo>) {
    out.push(ParamInfo {
        name: format!("{}.w", l.name),
        shape: vec![l.c_out, l.c_in],
        init: Init::HeLin(l.c_in),
        kind: "weight".into(),
    });
    if l.bias {
        out.push(ParamInfo {
            name: format!("{}.b", l.name),
            shape: vec![l.c_out],
            init: Init::Zeros,
            kind: "bias".into(),
        });
    }
}

/// The four quantized-linear projection sites of one attention block, in
/// execution order (`q`, `k`, `v`, `o`).  Public because the int8
/// lowering pass ([`crate::lower`]) must enumerate exactly the same
/// sites with exactly the same names as the float executor.
pub fn attn_projections(a: &AttnSpec) -> Vec<LinearSpec> {
    ["q", "k", "v", "o"]
        .iter()
        .map(|p| LinearSpec {
            name: format!("{}.{p}", a.name),
            c_in: a.d,
            c_out: a.d,
            bias: true,
        })
        .collect()
}

fn collect_params(layers: &[Layer], out: &mut Vec<ParamInfo>) {
    for layer in layers {
        match layer {
            Layer::Linear(l) => lin_params(l, out),
            Layer::Conv2d(c) => out.push(ParamInfo {
                name: format!("{}.w", c.name),
                shape: vec![c.c_out, c.c_in, c.k, c.k],
                init: Init::HeConv(c.c_in * c.k * c.k),
                kind: "weight".into(),
            }),
            Layer::LayerNorm(n) => {
                out.push(ParamInfo {
                    name: format!("{}.g", n.name),
                    shape: vec![n.d],
                    init: Init::Ones,
                    kind: "norm".into(),
                });
                out.push(ParamInfo {
                    name: format!("{}.b", n.name),
                    shape: vec![n.d],
                    init: Init::Zeros,
                    kind: "norm".into(),
                });
            }
            Layer::Embed(e) => {
                out.push(ParamInfo {
                    name: format!("{}.tok", e.name),
                    shape: vec![e.vocab, e.d],
                    init: Init::Normal(0.02),
                    kind: "embed".into(),
                });
                out.push(ParamInfo {
                    name: format!("{}.pos", e.name),
                    shape: vec![e.seq, e.d],
                    init: Init::Normal(0.02),
                    kind: "embed".into(),
                });
            }
            Layer::Attention(a) => {
                for p in attn_projections(a) {
                    lin_params(&p, out);
                }
            }
            Layer::Residual(inner) => collect_params(inner, out),
            Layer::Flatten | Layer::Relu | Layer::AvgPool2x2 => {}
        }
    }
}

fn collect_wsites(layers: &[Layer], out: &mut Vec<WSite>) {
    for layer in layers {
        match layer {
            Layer::Linear(l) => out.push(WSite {
                name: format!("{}.w", l.name),
                c_out: l.c_out,
                size: l.c_out * l.c_in,
            }),
            Layer::Conv2d(c) => out.push(WSite {
                name: format!("{}.w", c.name),
                c_out: c.c_out,
                size: c.c_out * c.c_in * c.k * c.k,
            }),
            Layer::Attention(a) => {
                for p in attn_projections(a) {
                    out.push(WSite {
                        name: format!("{}.w", p.name),
                        c_out: p.c_out,
                        size: p.c_out * p.c_in,
                    });
                }
            }
            Layer::Residual(inner) => collect_wsites(inner, out),
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------------
// Manifest synthesis (mirrors python/compile/step.py's IOSpec ordering)
// ---------------------------------------------------------------------------

fn io(name: &str, shape: Vec<usize>, dtype: Dtype, role: &str, of: Option<&str>) -> IoSpec {
    IoSpec {
        name: name.to_string(),
        shape,
        dtype,
        role: role.to_string(),
        of: of.map(str::to_string),
    }
}

/// Synthesize the manifest (the cross-language ABI) a compiled artifact
/// of this graph would carry: ordered params → per-site qparams → data →
/// selectors on the input side; loss/metrics, weight/bias grads in
/// parameter order, then per-site qparam grads on the output side.
pub fn build_manifest(g: &LayerGraph, name: &str, id: &StepId) -> Manifest {
    let quant = id.w_bits > 0;
    let params = g.params();
    let wsites = g.wsites();

    let mut inputs: Vec<IoSpec> =
        params.iter().map(|p| io(&p.name, p.shape.clone(), Dtype::F32, "param", None)).collect();
    if quant && id.kind != StepKind::Calib {
        for s in &wsites {
            let (sw, sx, zx) = (
                format!("sw:{}", s.name),
                format!("sx:{}", s.name),
                format!("zx:{}", s.name),
            );
            inputs.push(io(&sw, vec![s.c_out], Dtype::F32, "qparam_sw", Some(&s.name)));
            inputs.push(io(&sx, vec![1], Dtype::F32, "qparam_sx", Some(&s.name)));
            inputs.push(io(&zx, vec![1], Dtype::F32, "qparam_zx", Some(&s.name)));
        }
    }
    let (x_spec, y_spec, logits_shape) = match g.input {
        InputKind::Image { channels, hw } => (
            io("x", vec![g.batch, channels, hw, hw], Dtype::F32, "data", None),
            io("y", vec![g.batch], Dtype::I32, "data", None),
            vec![g.batch, g.classes],
        ),
        InputKind::Tokens { seq } => (
            io("x", vec![g.batch, seq], Dtype::I32, "data", None),
            io("y", vec![g.batch, seq], Dtype::I32, "data", None),
            vec![g.batch, seq, g.classes],
        ),
    };
    inputs.push(x_spec);
    if id.kind != StepKind::Calib {
        inputs.push(y_spec);
    }

    let mut outputs: Vec<IoSpec> = Vec::new();
    match id.kind {
        StepKind::Calib => {
            for s in &wsites {
                let mm = format!("mm:{}", s.name);
                outputs.push(io(&mm, vec![2], Dtype::F32, "calib", Some(&s.name)));
            }
        }
        StepKind::Fwd => {
            outputs.push(io("loss", vec![1], Dtype::F32, "loss", None));
            outputs.push(io("correct", vec![1], Dtype::I32, "metric", None));
            outputs.push(io("logits", logits_shape, Dtype::F32, "logits", None));
        }
        StepKind::Train(sel) => {
            if let TrainSel::Ratio(r) = sel {
                if r > 0.0 && r < 1.0 {
                    for s in &wsites {
                        inputs.push(io(
                            &format!("id:{}", s.name),
                            vec![site_k(s.c_out, r)],
                            Dtype::I32,
                            "index",
                            Some(&s.name),
                        ));
                    }
                }
            }
            if sel == TrainSel::Lwpn {
                for s in &wsites {
                    let flag = format!("flag:{}", s.name);
                    inputs.push(io(&flag, vec![1], Dtype::I32, "flag", Some(&s.name)));
                }
            }
            outputs.push(io("loss", vec![1], Dtype::F32, "loss", None));
            outputs.push(io("correct", vec![1], Dtype::I32, "metric", None));
            // weight/bias grads in parameter order, then qparam grads per
            // site — exactly python/compile/step.py's manifest order
            let weight_grads = |p: &ParamInfo| -> Option<Vec<usize>> {
                match sel {
                    TrainSel::Fp | TrainSel::Lwpn => Some(p.shape.clone()),
                    TrainSel::Ratio(r) if r >= 1.0 => Some(p.shape.clone()),
                    TrainSel::Ratio(r) if r <= 0.0 => None,
                    TrainSel::Ratio(r) => {
                        Some(vec![site_k(p.shape[0], r), p.shape[1..].iter().product()])
                    }
                }
            };
            for p in &params {
                let shape = match p.kind.as_str() {
                    "weight" => match weight_grads(p) {
                        Some(s) => s,
                        None => continue,
                    },
                    // embeddings train during FP pretraining only
                    "embed" if sel != TrainSel::Fp => continue,
                    _ => p.shape.clone(),
                };
                let d = format!("d:{}", p.name);
                outputs.push(io(&d, shape, Dtype::F32, "grad", Some(&p.name)));
            }
            if sel != TrainSel::Fp {
                for s in &wsites {
                    let sw_rows = match sel {
                        TrainSel::Ratio(r) if r <= 0.0 => None,
                        TrainSel::Ratio(r) if r < 1.0 => Some(site_k(s.c_out, r)),
                        _ => Some(s.c_out),
                    };
                    if let Some(k) = sw_rows {
                        outputs.push(io(
                            &format!("d:sw:{}", s.name),
                            vec![k],
                            Dtype::F32,
                            "grad",
                            Some(&format!("sw:{}", s.name)),
                        ));
                    }
                    outputs.push(io(
                        &format!("d:sx:{}", s.name),
                        vec![1],
                        Dtype::F32,
                        "grad",
                        Some(&format!("sx:{}", s.name)),
                    ));
                    outputs.push(io(
                        &format!("d:zx:{}", s.name),
                        vec![1],
                        Dtype::F32,
                        "grad",
                        Some(&format!("zx:{}", s.name)),
                    ));
                }
            }
        }
    }

    let (sel_mode, ratio) = match id.kind {
        StepKind::Train(TrainSel::Fp) => ("fp", 1.0),
        StepKind::Train(TrainSel::Ratio(r)) => ("ratio", r),
        StepKind::Train(TrainSel::Lwpn) => ("lwpn", 1.0),
        _ => ("", 1.0),
    };
    Manifest {
        name: name.to_string(),
        model: g.model.clone(),
        kind: match id.kind {
            StepKind::Train(_) => "train",
            StepKind::Fwd => "fwd",
            StepKind::Calib => "calib",
        }
        .to_string(),
        sel_mode: sel_mode.to_string(),
        ratio,
        w_bits: id.w_bits,
        a_bits: id.a_bits,
        batch_size: g.batch,
        params,
        states: Vec::new(),
        wsites,
        inputs,
        outputs,
    }
}

// ---------------------------------------------------------------------------
// Execution
// ---------------------------------------------------------------------------

/// Named input lookup over the positional input vector.
pub struct Vals<'a> {
    map: BTreeMap<&'a str, &'a Value>,
}

impl<'a> Vals<'a> {
    /// Zip manifest input specs with positional values.
    pub fn new(man: &'a Manifest, inputs: &'a [Value]) -> Vals<'a> {
        Vals { map: man.inputs.iter().map(|s| s.name.as_str()).zip(inputs).collect() }
    }

    fn f32(&self, name: &str) -> Result<&'a Tensor> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow!("graph step: missing input {name:?}"))?
            .f32()
    }

    fn i32(&self, name: &str) -> Result<&'a ITensor> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow!("graph step: missing input {name:?}"))?
            .i32()
    }

    fn scalar(&self, name: &str) -> Result<f32> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow!("graph step: missing input {name:?}"))?
            .scalar()
            .map_err(|e| anyhow!("input {name:?}: {e}"))
    }
}

/// One executable step: a graph coupled with an artifact identity and
/// the manifest synthesized for it.
pub struct GraphStep {
    pub graph: LayerGraph,
    pub id: StepId,
    pub man: Manifest,
}

/// Per-site quantization parameters pulled from the inputs.
struct SiteQ {
    sw: Vec<f32>,
    sx: f32,
    zx: f32,
}

/// Runtime weight-gradient selection for one site, resolved from the
/// step kind + selector inputs.
#[derive(Clone, Debug)]
enum RunSel {
    All,
    None,
    Idx(Vec<usize>),
    Flag(bool),
}

/// Residual cache of one quantized-linear site (shared by `Linear` and
/// the four attention projections).
struct LinCache {
    x_shape: Vec<usize>,
    /// Raw pre-quant input — populated only when the quantizer backward
    /// will need it (quantized train steps; see `Run::keep_raw`).
    x_raw: Vec<f32>,
    xh: Vec<f32>,
    wh: Vec<f32>,
    q: Option<SiteQ>,
    rows: usize,
}

struct ConvCache {
    /// Raw pre-quant input — populated only on quantized train steps.
    x_raw: Vec<f32>,
    /// im2col of the (quantized) input: `[M, C_in·k·k]`.
    cols: Vec<f32>,
    wh: Vec<f32>,
    q: Option<SiteQ>,
    dims: ConvDims,
}

struct AttnCache {
    q_lin: LinCache,
    k_lin: LinCache,
    v_lin: LinCache,
    o_lin: LinCache,
    qy: Vec<f32>,
    ky: Vec<f32>,
    vy: Vec<f32>,
    p: Vec<f32>,
    dm: AttnDims,
}

/// What each layer's forward leaves behind for the backward pass.
enum Cache {
    Flatten { shape: Vec<usize> },
    Linear(LinCache),
    Conv(ConvCache),
    Relu { pre: Vec<f32> },
    Pool { shape: Vec<usize> },
    Norm { xhat: Vec<f32>, inv: Vec<f32> },
    Embed { ids: Vec<i32> },
    Attn(Box<AttnCache>),
    Residual(Vec<Cache>),
}

/// Activation flowing between layers.
enum Act {
    F(Tensor),
    I(ITensor),
}

fn act_f32(act: Act) -> Result<Tensor> {
    match act {
        Act::F(t) => Ok(t),
        Act::I(_) => bail!("graph: layer expected an f32 activation, got i32"),
    }
}

impl GraphStep {
    /// Couple a graph with an artifact identity, synthesizing the manifest.
    pub fn new(graph: LayerGraph, artifact: &str, id: StepId) -> GraphStep {
        let man = build_manifest(&graph, artifact, &id);
        GraphStep { graph, id, man }
    }

    /// Forward to logits only — no loss, metric, or `dlogits` work.
    /// The serving bench times this against the int8 engine
    /// ([`crate::lower::QuantizedGraph::forward`]) so both sides do the
    /// same job; residual-cache building remains, as it is intrinsic to
    /// this executor.
    pub fn forward_logits(&self, inputs: &[Value]) -> Result<Tensor> {
        let vals = Vals::new(&self.man, inputs);
        let mut run = Run { step: self, vals: &vals, taps: None };
        let (logits, _caches) = run.forward()?;
        Ok(logits)
    }

    /// Execute on inputs packed in manifest order; outputs come back in
    /// manifest order (the [`crate::backend::StepExec`] contract).
    pub fn execute(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        let vals = Vals::new(&self.man, inputs);
        let mut run = Run { step: self, vals: &vals, taps: None };
        let mut named = match self.id.kind {
            StepKind::Train(_) => run.run_train()?,
            StepKind::Fwd => run.run_fwd()?,
            StepKind::Calib => run.run_calib()?,
        };
        self.man
            .outputs
            .iter()
            .map(|spec| {
                named.remove(&spec.name).ok_or_else(|| {
                    anyhow!("{}: graph step produced no output {:?}", self.man.name, spec.name)
                })
            })
            .collect()
    }
}

/// One execution of a [`GraphStep`] over bound inputs.
struct Run<'a> {
    step: &'a GraphStep,
    vals: &'a Vals<'a>,
    /// `Some` during calibration: per-site `(min, max)` of the raw input
    /// each quantized site saw (the MinMax observer taps, Eq. 2).
    taps: Option<BTreeMap<String, (f32, f32)>>,
}

impl<'a> Run<'a> {
    fn quantized(&self) -> bool {
        self.step.id.w_bits > 0 && self.step.id.kind != StepKind::Calib
    }

    // ---- shared quantized-site plumbing -----------------------------------

    fn siteq(&self, site: &str) -> Result<Option<SiteQ>> {
        if !self.quantized() {
            return Ok(None);
        }
        let sw = self.vals.f32(&format!("sw:{site}"))?.data.clone();
        if sw.iter().any(|&s| s <= 0.0 || !s.is_finite()) {
            bail!("{}: non-positive weight scale for site {site:?}", self.step.man.name);
        }
        let sx = self.vals.scalar(&format!("sx:{site}"))?;
        if sx <= 0.0 || !sx.is_finite() {
            bail!("{}: non-positive activation scale for site {site:?}", self.step.man.name);
        }
        let zx = self.vals.scalar(&format!("zx:{site}"))?;
        Ok(Some(SiteQ { sw, sx, zx }))
    }

    /// Whether a site cache must keep the raw (pre-quant) input: only
    /// the quantizer backward reads it, so fwd/calib steps — and FP
    /// backward paths — skip the clone.
    fn keep_raw(&self, q: &Option<SiteQ>) -> bool {
        q.is_some() && matches!(self.step.id.kind, StepKind::Train(_))
    }

    /// Record the (min, max) a quantized site's raw input — the MinMax
    /// observer tap of the calib artifacts.
    fn tap(&mut self, site: &str, x: &[f32]) {
        if let Some(taps) = &mut self.taps {
            let lo = x.iter().copied().fold(f32::INFINITY, f32::min);
            let hi = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            taps.insert(site.to_string(), (lo, hi));
        }
    }

    /// Resolve the runtime weight-gradient selection for one site from
    /// the step kind and the bound selector inputs.
    fn run_sel(&self, site: &str, c_out: usize) -> Result<RunSel> {
        match self.step.id.kind {
            StepKind::Train(TrainSel::Fp) => Ok(RunSel::All),
            StepKind::Train(TrainSel::Lwpn) => {
                Ok(RunSel::Flag(self.vals.i32(&format!("flag:{site}"))?.data[0] > 0))
            }
            StepKind::Train(TrainSel::Ratio(r)) if r >= 1.0 => Ok(RunSel::All),
            StepKind::Train(TrainSel::Ratio(r)) if r <= 0.0 => Ok(RunSel::None),
            StepKind::Train(TrainSel::Ratio(_)) => {
                let ids = self.vals.i32(&format!("id:{site}"))?;
                let mut out = Vec::with_capacity(ids.data.len());
                for &c in &ids.data {
                    if c < 0 || c as usize >= c_out {
                        bail!(
                            "{}: selection index {c} out of range for site {site:?} (c_out {c_out})",
                            self.step.man.name
                        );
                    }
                    out.push(c as usize);
                }
                Ok(RunSel::Idx(out))
            }
            _ => Ok(RunSel::All),
        }
    }

    /// The frozen-channel-aware weight-gradient rule (paper Fig. 1
    /// right), implemented once for every layer type.  `full_dwhat` /
    /// `partial_dwhat` supply the layer's own contraction (plain matmul
    /// for linear sites, im2col matmul for conv); this function owns the
    /// selection logic and the STE/LSQ quantizer backward:
    ///
    /// * `All` / `Flag(true)` — full `dŴ`, full quantizer backward;
    /// * `Flag(false)` — the LWPN saving: the `dŴ` contraction is
    ///   *skipped at runtime*; the ABI still carries full-shape zeros;
    /// * `Idx` — only the gathered unfrozen rows are ever materialized
    ///   (CWPL/CWPN): `dW[idx] = gather(dY, idx)ᵀ · X̂`;
    /// * `None` — the r=0 case: no weight gradient at all.
    fn weight_site_grads(
        &self,
        sel: &RunSel,
        w: &Tensor,
        q: Option<&SiteQ>,
        row_size: usize,
        full_dwhat: &mut dyn FnMut() -> Vec<f32>,
        partial_dwhat: &mut dyn FnMut(&[usize]) -> Vec<f32>,
    ) -> (Option<Tensor>, Option<Vec<f32>>) {
        let c_out = w.shape[0];
        let bits = self.step.id.w_bits;
        match q {
            Some(q) => match sel {
                RunSel::All | RunSel::Flag(true) => {
                    let dwhat = full_dwhat();
                    let (dw, ds) = fq_weight_bwd_rows(&w.data, &q.sw, &dwhat, row_size, bits);
                    (Some(Tensor { shape: w.shape.clone(), data: dw }), Some(ds))
                }
                RunSel::Flag(false) => {
                    (Some(Tensor::zeros(&w.shape)), Some(vec![0.0; c_out]))
                }
                RunSel::Idx(ids) => {
                    let dwhat = partial_dwhat(ids);
                    let w_rows = w.gather_rows(ids);
                    let s_rows: Vec<f32> = ids.iter().map(|&r| q.sw[r]).collect();
                    let (dw, ds) =
                        fq_weight_bwd_rows(&w_rows.data, &s_rows, &dwhat, row_size, bits);
                    let dw = Tensor { shape: vec![ids.len(), row_size], data: dw };
                    (Some(dw), Some(ds))
                }
                RunSel::None => (None, None),
            },
            None => {
                let dw = match sel {
                    RunSel::None => None,
                    RunSel::Flag(false) => Some(Tensor::zeros(&w.shape)),
                    RunSel::Idx(ids) => {
                        Some(Tensor { shape: vec![ids.len(), row_size], data: partial_dwhat(ids) })
                    }
                    _ => Some(Tensor { shape: w.shape.clone(), data: full_dwhat() }),
                };
                (dw, None)
            }
        }
    }

    fn emit_site_grads(
        &self,
        site: &str,
        dw: Option<Tensor>,
        dsw: Option<Vec<f32>>,
        grads: &mut BTreeMap<String, Value>,
    ) {
        if let Some(dw) = dw {
            grads.insert(format!("d:{site}"), Value::F32(dw));
        }
        if let Some(ds) = dsw {
            let n = ds.len();
            grads.insert(format!("d:sw:{site}"), Value::F32(Tensor { shape: vec![n], data: ds }));
        }
    }

    /// Backward through one site's activation quantizer (STE/LSQ+),
    /// emitting the `d:sx:`/`d:zx:` grads; FP sites pass `dxh` through.
    /// Shared by linear and conv sites, like `weight_site_grads`.
    fn act_bwd(
        &self,
        site: &str,
        q: Option<&SiteQ>,
        x_raw: &[f32],
        dxh: Vec<f32>,
        grads: &mut BTreeMap<String, Value>,
    ) -> Vec<f32> {
        match q {
            Some(q) => {
                let (dx, dsx, dzx) =
                    fq_act_bwd_tensor(x_raw, q.sx, q.zx, &dxh, self.step.id.a_bits);
                grads.insert(format!("d:sx:{site}"), Value::F32(Tensor::scalar(dsx)));
                grads.insert(format!("d:zx:{site}"), Value::F32(Tensor::scalar(dzx)));
                dx
            }
            None => dxh,
        }
    }

    // ---- quantized linear site (Linear + attention projections) -----------

    fn lin_fwd(&mut self, spec: &LinearSpec, x: &Tensor) -> Result<(Tensor, LinCache)> {
        if x.shape.last() != Some(&spec.c_in) {
            bail!(
                "{}: linear {:?} wants {} input features, activation is {:?}",
                self.step.man.name,
                spec.name,
                spec.c_in,
                x.shape
            );
        }
        let rows = x.data.len() / spec.c_in;
        let site = format!("{}.w", spec.name);
        let w = self.vals.f32(&site)?;
        self.tap(&site, &x.data);
        let q = self.siteq(&site)?;
        let (xh, wh) = match &q {
            Some(q) => (
                fq_act_tensor(&x.data, q.sx, q.zx, self.step.id.a_bits),
                fq_weight_rows(&w.data, &q.sw, spec.c_in, self.step.id.w_bits),
            ),
            None => (x.data.clone(), w.data.clone()),
        };
        let bias = if spec.bias {
            Some(&self.vals.f32(&format!("{}.b", spec.name))?.data[..])
        } else {
            None
        };
        let y = linear_fwd(&xh, &wh, bias, rows, spec.c_in, spec.c_out);
        let mut y_shape = x.shape.clone();
        *y_shape.last_mut().unwrap() = spec.c_out;
        let x_raw = if self.keep_raw(&q) { x.data.clone() } else { Vec::new() };
        let cache = LinCache { x_shape: x.shape.clone(), x_raw, xh, wh, q, rows };
        Ok((Tensor { shape: y_shape, data: y }, cache))
    }

    fn lin_bwd(
        &mut self,
        spec: &LinearSpec,
        cache: &LinCache,
        dy: &Tensor,
        grads: &mut BTreeMap<String, Value>,
    ) -> Result<Tensor> {
        let (rows, c_in, c_out) = (cache.rows, spec.c_in, spec.c_out);
        let site = format!("{}.w", spec.name);
        if spec.bias {
            let db = col_sum(&dy.data, rows, c_out);
            grads.insert(
                format!("d:{}.b", spec.name),
                Value::F32(Tensor { shape: vec![c_out], data: db }),
            );
        }
        let dxh = matmul_dy_w(&dy.data, &cache.wh, rows, c_out, c_in);
        let sel = self.run_sel(&site, c_out)?;
        let w = self.vals.f32(&site)?;
        let mut full = || matmul_dyt_x(&dy.data, &cache.xh, rows, c_out, c_in);
        let mut partial = |ids: &[usize]| partial_dw(&dy.data, &cache.xh, ids, rows, c_out, c_in);
        let (dw, dsw) =
            self.weight_site_grads(&sel, w, cache.q.as_ref(), c_in, &mut full, &mut partial);
        self.emit_site_grads(&site, dw, dsw, grads);
        let dx = self.act_bwd(&site, cache.q.as_ref(), &cache.x_raw, dxh, grads);
        Ok(Tensor { shape: cache.x_shape.clone(), data: dx })
    }

    // ---- forward ----------------------------------------------------------

    fn input_act(&self) -> Result<Act> {
        match self.step.graph.input {
            InputKind::Image { .. } => Ok(Act::F(self.vals.f32("x")?.clone())),
            InputKind::Tokens { .. } => Ok(Act::I(self.vals.i32("x")?.clone())),
        }
    }

    fn forward(&mut self) -> Result<(Tensor, Vec<Cache>)> {
        let step = self.step;
        let x0 = self.input_act()?;
        let mut caches = Vec::new();
        let out = self.forward_seq(&step.graph.layers, x0, &mut caches)?;
        Ok((act_f32(out)?, caches))
    }

    fn forward_seq(
        &mut self,
        layers: &[Layer],
        mut act: Act,
        caches: &mut Vec<Cache>,
    ) -> Result<Act> {
        for layer in layers {
            act = self.forward_layer(layer, act, caches)?;
        }
        Ok(act)
    }

    fn forward_layer(&mut self, layer: &Layer, act: Act, caches: &mut Vec<Cache>) -> Result<Act> {
        Ok(match layer {
            Layer::Flatten => {
                let x = act_f32(act)?;
                let b = x.shape.first().copied().unwrap_or(1);
                let rest: usize = x.shape[1..].iter().product();
                caches.push(Cache::Flatten { shape: x.shape });
                Act::F(Tensor { shape: vec![b, rest], data: x.data })
            }
            Layer::Linear(spec) => {
                let x = act_f32(act)?;
                let (y, cache) = self.lin_fwd(spec, &x)?;
                caches.push(Cache::Linear(cache));
                Act::F(y)
            }
            Layer::Conv2d(spec) => {
                let x = act_f32(act)?;
                if x.shape.len() != 4 || x.shape[1] != spec.c_in || x.shape[2] != x.shape[3] {
                    bail!(
                        "{}: conv {:?} wants [B, {}, H, H], activation is {:?}",
                        self.step.man.name,
                        spec.name,
                        spec.c_in,
                        x.shape
                    );
                }
                let dims = ConvDims {
                    batch: x.shape[0],
                    c_in: spec.c_in,
                    hw: x.shape[2],
                    c_out: spec.c_out,
                    k: spec.k,
                    stride: spec.stride,
                    pad: spec.pad,
                };
                let site = format!("{}.w", spec.name);
                let w = self.vals.f32(&site)?;
                self.tap(&site, &x.data);
                let q = self.siteq(&site)?;
                let (xh, wh) = match &q {
                    Some(sq) => (
                        fq_act_tensor(&x.data, sq.sx, sq.zx, self.step.id.a_bits),
                        fq_weight_rows(&w.data, &sq.sw, dims.patch(), self.step.id.w_bits),
                    ),
                    None => (x.data.clone(), w.data.clone()),
                };
                let cols = conv::im2col(&xh, &dims);
                let y2 = linear_fwd(&cols, &wh, None, dims.rows(), dims.patch(), dims.c_out);
                let y = conv::rows_to_nchw(&y2, &dims);
                let ho = dims.hw_out();
                let x_raw = if self.keep_raw(&q) { x.data } else { Vec::new() };
                caches.push(Cache::Conv(ConvCache { x_raw, cols, wh, q, dims }));
                Act::F(Tensor { shape: vec![dims.batch, dims.c_out, ho, ho], data: y })
            }
            Layer::Relu => {
                let x = act_f32(act)?;
                let y = relu_fwd(&x.data);
                caches.push(Cache::Relu { pre: x.data });
                Act::F(Tensor { shape: x.shape, data: y })
            }
            Layer::AvgPool2x2 => {
                let x = act_f32(act)?;
                if x.shape.len() != 4 || x.shape[2] % 2 != 0 || x.shape[2] != x.shape[3] {
                    let step = &self.step.man.name;
                    bail!("{step}: avgpool wants [B, C, 2n, 2n], got {:?}", x.shape);
                }
                let (b, c, hw) = (x.shape[0], x.shape[1], x.shape[2]);
                let y = conv::avgpool2_fwd(&x.data, b, c, hw);
                caches.push(Cache::Pool { shape: x.shape });
                Act::F(Tensor { shape: vec![b, c, hw / 2, hw / 2], data: y })
            }
            Layer::LayerNorm(spec) => {
                let x = act_f32(act)?;
                if x.shape.last() != Some(&spec.d) {
                    let step = &self.step.man.name;
                    bail!(
                        "{step}: layernorm {:?} wants {} features, got {:?}",
                        spec.name,
                        spec.d,
                        x.shape
                    );
                }
                let rows = x.data.len() / spec.d;
                let g = self.vals.f32(&format!("{}.g", spec.name))?;
                let b = self.vals.f32(&format!("{}.b", spec.name))?;
                let (y, xhat, inv) = layernorm_fwd(&x.data, &g.data, &b.data, rows, spec.d);
                caches.push(Cache::Norm { xhat, inv });
                Act::F(Tensor { shape: x.shape, data: y })
            }
            Layer::Embed(spec) => {
                let ids = match act {
                    Act::I(t) => t,
                    Act::F(_) => bail!("graph: embedding expects i32 token ids"),
                };
                for &id in &ids.data {
                    if id < 0 || id as usize >= spec.vocab {
                        bail!(
                            "{}: token id {id} out of range [0, {})",
                            self.step.man.name,
                            spec.vocab
                        );
                    }
                }
                let tok = self.vals.f32(&format!("{}.tok", spec.name))?;
                let pos = self.vals.f32(&format!("{}.pos", spec.name))?;
                let y = embed_fwd(&tok.data, &pos.data, &ids.data, spec.seq, spec.d);
                let b = ids.data.len() / spec.seq;
                caches.push(Cache::Embed { ids: ids.data });
                Act::F(Tensor { shape: vec![b, spec.seq, spec.d], data: y })
            }
            Layer::Attention(spec) => {
                let x = act_f32(act)?;
                if x.shape.len() != 3 || x.shape[2] != spec.d {
                    let step = &self.step.man.name;
                    bail!(
                        "{step}: attention {:?} wants [B, T, {}], got {:?}",
                        spec.name,
                        spec.d,
                        x.shape
                    );
                }
                let projs = attn_projections(spec);
                let (qy, q_lin) = self.lin_fwd(&projs[0], &x)?;
                let (ky, k_lin) = self.lin_fwd(&projs[1], &x)?;
                let (vy, v_lin) = self.lin_fwd(&projs[2], &x)?;
                let dm =
                    AttnDims { batch: x.shape[0], t: x.shape[1], d: spec.d, heads: spec.heads };
                let (om, p) = sdpa_fwd(&qy.data, &ky.data, &vy.data, &dm, spec.causal);
                let om_t = Tensor { shape: x.shape.clone(), data: om };
                let (out, o_lin) = self.lin_fwd(&projs[3], &om_t)?;
                caches.push(Cache::Attn(Box::new(AttnCache {
                    q_lin,
                    k_lin,
                    v_lin,
                    o_lin,
                    qy: qy.data,
                    ky: ky.data,
                    vy: vy.data,
                    p,
                    dm,
                })));
                Act::F(out)
            }
            Layer::Residual(inner) => {
                let x = act_f32(act)?;
                let mut sub = Vec::new();
                let y = act_f32(self.forward_seq(inner, Act::F(x.clone()), &mut sub)?)?;
                if y.shape != x.shape {
                    bail!(
                        "{}: residual sub-graph changed shape {:?} -> {:?}",
                        self.step.man.name,
                        x.shape,
                        y.shape
                    );
                }
                let data = x.data.iter().zip(&y.data).map(|(a, b)| a + b).collect();
                caches.push(Cache::Residual(sub));
                Act::F(Tensor { shape: x.shape, data })
            }
        })
    }

    // ---- backward ---------------------------------------------------------

    fn backward_seq(
        &mut self,
        layers: &[Layer],
        caches: &[Cache],
        dy: Tensor,
        grads: &mut BTreeMap<String, Value>,
    ) -> Result<Tensor> {
        debug_assert_eq!(layers.len(), caches.len());
        let mut dy = dy;
        for (layer, cache) in layers.iter().zip(caches).rev() {
            dy = self.backward_layer(layer, cache, dy, grads)?;
        }
        Ok(dy)
    }

    fn backward_layer(
        &mut self,
        layer: &Layer,
        cache: &Cache,
        dy: Tensor,
        grads: &mut BTreeMap<String, Value>,
    ) -> Result<Tensor> {
        match (layer, cache) {
            (Layer::Flatten, Cache::Flatten { shape }) => {
                Ok(Tensor { shape: shape.clone(), data: dy.data })
            }
            (Layer::Linear(spec), Cache::Linear(c)) => self.lin_bwd(spec, c, &dy, grads),
            (Layer::Conv2d(spec), Cache::Conv(c)) => {
                let d = &c.dims;
                let site = format!("{}.w", spec.name);
                let dy2 = conv::nchw_to_rows(&dy.data, d);
                let dcols = matmul_dy_w(&dy2, &c.wh, d.rows(), d.c_out, d.patch());
                let dxh = conv::col2im(&dcols, d);
                let sel = self.run_sel(&site, d.c_out)?;
                let w = self.vals.f32(&site)?;
                let mut full = || matmul_dyt_x(&dy2, &c.cols, d.rows(), d.c_out, d.patch());
                let mut partial =
                    |ids: &[usize]| partial_dw(&dy2, &c.cols, ids, d.rows(), d.c_out, d.patch());
                let patch = d.patch();
                let (dw, dsw) =
                    self.weight_site_grads(&sel, w, c.q.as_ref(), patch, &mut full, &mut partial);
                self.emit_site_grads(&site, dw, dsw, grads);
                let dx = self.act_bwd(&site, c.q.as_ref(), &c.x_raw, dxh, grads);
                Ok(Tensor { shape: vec![d.batch, d.c_in, d.hw, d.hw], data: dx })
            }
            (Layer::Relu, Cache::Relu { pre }) => {
                Ok(Tensor { shape: dy.shape, data: relu_bwd(&dy.data, pre) })
            }
            (Layer::AvgPool2x2, Cache::Pool { shape }) => {
                let (b, c, hw) = (shape[0], shape[1], shape[2]);
                Ok(Tensor { shape: shape.clone(), data: conv::avgpool2_bwd(&dy.data, b, c, hw) })
            }
            (Layer::LayerNorm(spec), Cache::Norm { xhat, inv }) => {
                let rows = dy.data.len() / spec.d;
                let g = self.vals.f32(&format!("{}.g", spec.name))?;
                let (dx, dgamma, dbeta) = layernorm_bwd(&dy.data, xhat, inv, &g.data, rows, spec.d);
                grads.insert(
                    format!("d:{}.g", spec.name),
                    Value::F32(Tensor { shape: vec![spec.d], data: dgamma }),
                );
                grads.insert(
                    format!("d:{}.b", spec.name),
                    Value::F32(Tensor { shape: vec![spec.d], data: dbeta }),
                );
                Ok(Tensor { shape: dy.shape, data: dx })
            }
            (Layer::Embed(spec), Cache::Embed { ids }) => {
                // embeddings train during FP pretraining only (the
                // manifest declares no embed grads otherwise) — skip the
                // scatter-add entirely on quantized steps
                if self.step.id.kind == StepKind::Train(TrainSel::Fp) {
                    let (dtok, dpos) = embed_bwd(&dy.data, ids, spec.vocab, spec.seq, spec.d);
                    grads.insert(
                        format!("d:{}.tok", spec.name),
                        Value::F32(Tensor { shape: vec![spec.vocab, spec.d], data: dtok }),
                    );
                    grads.insert(
                        format!("d:{}.pos", spec.name),
                        Value::F32(Tensor { shape: vec![spec.seq, spec.d], data: dpos }),
                    );
                }
                // the input is token ids — there is no dx
                Ok(Tensor { shape: vec![0], data: Vec::new() })
            }
            (Layer::Attention(spec), Cache::Attn(c)) => {
                let projs = attn_projections(spec);
                let dom = self.lin_bwd(&projs[3], &c.o_lin, &dy, grads)?;
                let (dq, dk, dv) = sdpa_bwd(&dom.data, &c.qy, &c.ky, &c.vy, &c.p, &c.dm);
                let shape = dom.shape;
                let dq = Tensor { shape: shape.clone(), data: dq };
                let dxq = self.lin_bwd(&projs[0], &c.q_lin, &dq, grads)?;
                let dk = Tensor { shape: shape.clone(), data: dk };
                let dxk = self.lin_bwd(&projs[1], &c.k_lin, &dk, grads)?;
                let dv = Tensor { shape, data: dv };
                let dxv = self.lin_bwd(&projs[2], &c.v_lin, &dv, grads)?;
                let data = dxq
                    .data
                    .iter()
                    .zip(&dxk.data)
                    .zip(&dxv.data)
                    .map(|((a, b), c)| a + b + c)
                    .collect();
                Ok(Tensor { shape: dxq.shape, data })
            }
            (Layer::Residual(inner), Cache::Residual(sub)) => {
                let dinner = self.backward_seq(inner, sub, dy.clone(), grads)?;
                if dinner.data.len() != dy.data.len() {
                    bail!("{}: residual backward shape mismatch", self.step.man.name);
                }
                let data = dy.data.iter().zip(&dinner.data).map(|(a, b)| a + b).collect();
                Ok(Tensor { shape: dy.shape, data })
            }
            _ => bail!("{}: layer/cache mismatch in backward", self.step.man.name),
        }
    }

    // ---- step kinds -------------------------------------------------------

    fn loss_and_correct(&self, logits: &Tensor) -> Result<(f32, i32, Vec<f32>)> {
        let classes = self.step.graph.classes;
        let rows = logits.data.len() / classes;
        let labels = &self.vals.i32("y")?.data;
        let (loss, correct_rows, dlogits) = softmax_xent(&logits.data, labels, rows, classes)
            .map_err(|e| anyhow!("{}: {e}", self.step.man.name))?;
        // `correct` is the raw correct-row count — examples for
        // classifiers, *tokens* for LM graphs — matching what the AOT
        // artifacts emit (python ce_loss_fwd reports token counts)
        Ok((loss, correct_rows as i32, dlogits))
    }

    fn run_train(&mut self) -> Result<BTreeMap<String, Value>> {
        let step = self.step;
        let (logits, caches) = self.forward()?;
        let (loss, correct, dlogits) = self.loss_and_correct(&logits)?;
        let mut out = BTreeMap::new();
        let dl = Tensor { shape: logits.shape.clone(), data: dlogits };
        self.backward_seq(&step.graph.layers, &caches, dl, &mut out)?;
        out.insert("loss".into(), Value::F32(Tensor::scalar(loss)));
        out.insert("correct".into(), Value::I32(ITensor { shape: vec![1], data: vec![correct] }));
        Ok(out)
    }

    fn run_fwd(&mut self) -> Result<BTreeMap<String, Value>> {
        let (logits, _caches) = self.forward()?;
        let (loss, correct, _) = self.loss_and_correct(&logits)?;
        let mut out = BTreeMap::new();
        out.insert("loss".to_string(), Value::F32(Tensor::scalar(loss)));
        let correct = ITensor { shape: vec![1], data: vec![correct] };
        out.insert("correct".to_string(), Value::I32(correct));
        out.insert("logits".to_string(), Value::F32(logits));
        Ok(out)
    }

    fn run_calib(&mut self) -> Result<BTreeMap<String, Value>> {
        self.taps = Some(BTreeMap::new());
        self.forward()?;
        let taps = self.taps.take().unwrap_or_default();
        let mut out = BTreeMap::new();
        for site in &self.step.man.wsites {
            let (lo, hi) = taps.get(&site.name).copied().ok_or_else(|| {
                anyhow!("{}: calib tapped no data for site {:?}", self.step.man.name, site.name)
            })?;
            out.insert(
                format!("mm:{}", site.name),
                Value::F32(Tensor { shape: vec![2], data: vec![lo, hi] }),
            );
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The mlp family as a graph — must match the manifests the seed
    /// native backend synthesized by hand.
    fn mlp_graph() -> LayerGraph {
        LayerGraph {
            model: "mlp".into(),
            batch: 16,
            input: InputKind::Image { channels: 3, hw: 8 },
            classes: 10,
            layers: vec![
                Layer::Flatten,
                Layer::Linear(LinearSpec { name: "fc1".into(), c_in: 192, c_out: 32, bias: true }),
                Layer::Relu,
                Layer::Linear(LinearSpec { name: "fc2".into(), c_in: 32, c_out: 10, bias: true }),
            ],
        }
    }

    fn tf_graph() -> LayerGraph {
        LayerGraph {
            model: "tiny_tf".into(),
            batch: 8,
            input: InputKind::Tokens { seq: 16 },
            classes: 64,
            layers: vec![
                Layer::Embed(EmbedSpec { name: "emb".into(), vocab: 64, seq: 16, d: 16 }),
                Layer::Residual(vec![
                    Layer::LayerNorm(NormSpec { name: "ln1".into(), d: 16 }),
                    Layer::Attention(AttnSpec {
                        name: "attn".into(),
                        d: 16,
                        heads: 2,
                        causal: true,
                    }),
                ]),
                Layer::Residual(vec![
                    Layer::LayerNorm(NormSpec { name: "ln2".into(), d: 16 }),
                    Layer::Linear(LinearSpec {
                        name: "ffn1".into(),
                        c_in: 16,
                        c_out: 32,
                        bias: true,
                    }),
                    Layer::Relu,
                    Layer::Linear(LinearSpec {
                        name: "ffn2".into(),
                        c_in: 32,
                        c_out: 16,
                        bias: true,
                    }),
                ]),
                Layer::LayerNorm(NormSpec { name: "lnf".into(), d: 16 }),
                Layer::Linear(LinearSpec { name: "head".into(), c_in: 16, c_out: 64, bias: true }),
            ],
        }
    }

    fn id(kind: StepKind, w: u32, a: u32) -> StepId {
        StepId { kind, w_bits: w, a_bits: a }
    }

    #[test]
    fn train_manifest_matches_step_contract() {
        let g = mlp_graph();
        let sel = id(StepKind::Train(TrainSel::Ratio(0.25)), 8, 8);
        let m = build_manifest(&g, "mlp_w8a8_train_r25", &sel);
        assert_eq!(m.sel_mode, "ratio");
        assert_eq!(m.ratio, 0.25);
        assert_eq!(m.wsites.len(), 2);
        // index slots sized by site_k
        let idx: Vec<&IoSpec> = m.inputs.iter().filter(|i| i.role == "index").collect();
        assert_eq!(idx.len(), 2);
        assert_eq!(idx[0].shape, vec![site_k(32, 0.25)]);
        assert_eq!(idx[1].shape, vec![site_k(10, 0.25)]);
        // gathered grad rows match the slots
        let dw: Vec<&IoSpec> = m
            .outputs
            .iter()
            .filter(|o| o.name.starts_with("d:fc") && o.name.ends_with(".w"))
            .collect();
        assert_eq!(dw[0].shape, vec![site_k(32, 0.25), 192]);
        assert_eq!(dw[1].shape, vec![site_k(10, 0.25), 32]);
    }

    #[test]
    fn r0_manifest_has_no_weight_grads_but_keeps_act_qparam_grads() {
        let sel = id(StepKind::Train(TrainSel::Ratio(0.0)), 8, 8);
        let m = build_manifest(&mlp_graph(), "mlp_w8a8_train_r0", &sel);
        assert!(!m.outputs.iter().any(|o| o.name == "d:fc1.w"));
        assert!(!m.outputs.iter().any(|o| o.name == "d:sw:fc1.w"));
        assert!(m.outputs.iter().any(|o| o.name == "d:sx:fc1.w"));
        assert!(m.outputs.iter().any(|o| o.name == "d:fc1.b"));
    }

    #[test]
    fn fp_manifest_has_no_qparams() {
        let sel = id(StepKind::Train(TrainSel::Fp), 0, 0);
        let m = build_manifest(&mlp_graph(), "mlp_fp_train", &sel);
        assert_eq!(m.sel_mode, "fp");
        assert!(!m.inputs.iter().any(|i| i.role.starts_with("qparam")));
        assert!(m.outputs.iter().any(|o| o.name == "d:fc1.w"));
        assert!(!m.outputs.iter().any(|o| o.name.starts_with("d:sw")));
    }

    #[test]
    fn calib_manifest_taps_every_site() {
        let m = build_manifest(&mlp_graph(), "mlp_calib", &id(StepKind::Calib, 0, 0));
        assert_eq!(m.kind, "calib");
        assert_eq!(m.outputs.len(), 2);
        assert!(m.outputs.iter().all(|o| o.role == "calib"));
        // calib binds x only (no labels)
        assert!(!m.inputs.iter().any(|i| i.name == "y"));
    }

    #[test]
    fn transformer_graph_enumerates_all_sites_and_params() {
        let g = tf_graph();
        let sites = g.wsites();
        let names: Vec<&str> = sites.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["attn.q.w", "attn.k.w", "attn.v.w", "attn.o.w", "ffn1.w", "ffn2.w", "head.w"]
        );
        let params = g.params();
        // 2 embeds + 3 LN pairs + 7 linears × (w, b)
        assert_eq!(params.len(), 2 + 6 + 14);
        assert!(params.iter().any(|p| p.name == "emb.pos" && p.kind == "embed"));
        // embeds get grads in FP training only
        let fp = build_manifest(&g, "tiny_tf_fp_train", &id(StepKind::Train(TrainSel::Fp), 0, 0));
        assert!(fp.outputs.iter().any(|o| o.name == "d:emb.tok"));
        let sel = id(StepKind::Train(TrainSel::Ratio(1.0)), 8, 8);
        let q = build_manifest(&g, "tiny_tf_w8a8_train_r100", &sel);
        assert!(!q.outputs.iter().any(|o| o.name == "d:emb.tok"));
        // norm params always train
        assert!(q.outputs.iter().any(|o| o.name == "d:ln1.g"));
        // LM data is token-shaped
        let x = q.inputs.iter().find(|i| i.name == "x").unwrap();
        assert_eq!((x.shape.clone(), x.dtype), (vec![8, 16], Dtype::I32));
        let logits_shape = build_manifest(&g, "tiny_tf_fp_fwd", &id(StepKind::Fwd, 0, 0))
            .outputs
            .iter()
            .find(|o| o.name == "logits")
            .unwrap()
            .shape
            .clone();
        assert_eq!(logits_shape, vec![8, 16, 64]);
    }

    #[test]
    fn lwpn_manifest_carries_flags_and_full_grad_shapes() {
        let g = tf_graph();
        let sel = id(StepKind::Train(TrainSel::Lwpn), 8, 8);
        let m = build_manifest(&g, "tiny_tf_w8a8_train_lwpn", &sel);
        assert_eq!(m.inputs.iter().filter(|i| i.role == "flag").count(), 7);
        let dw = m.outputs.iter().find(|o| o.name == "d:attn.q.w").unwrap();
        assert_eq!(dw.shape, vec![16, 16]);
    }
}
