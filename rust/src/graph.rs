//! Layer-graph IR: declarative native models executed by the shared op
//! library.
//!
//! A [`LayerGraph`] is a sequential `Vec<Layer>` (with a [`Layer::Residual`]
//! combinator for transformer blocks) over named parameters.  From one
//! declaration the graph
//!
//! * enumerates parameters ([`LayerGraph::params`]) and freezable weight
//!   sites ([`LayerGraph::wsites`]) — every `Linear`/`Conv2d` output
//!   channel (and each attention projection) is an EfQAT site;
//! * synthesizes the step-function manifest ([`build_manifest`]) for each
//!   artifact kind, byte-compatible with what `python/compile/aot.py`
//!   emits for the same model;
//! * executes forward / backward / calibration generically
//!   ([`GraphStep`]), dispatching the math to [`crate::ops`] — through
//!   an execution plan compiled once at load (names resolved to
//!   positions) over a reusable [`crate::exec::Workspace`], so
//!   steady-state steps perform zero heap allocations (RFC 0003).
//!
//! The point of the IR is that EfQAT's frozen-channel-aware partial
//! backward (paper Fig. 1 right) is implemented **once** — the
//! executor's `weight_site_grads` resolves the per-site selection (full
//! / gathered rows / layer flag / none) and applies the STE/LSQ
//! quantizer backward — and every layer type inherits it: a linear's
//! rows, a conv's output channels (matmul rows after im2col), and each
//! attention projection all flow through the same code path.  Quantized
//! train steps additionally truncate the backward below the lowest
//! layer holding an active site (`EFQAT_BWD_TRUNC`, default on): the
//! frozen prefix skips its dX propagation outright and emits the zero
//! gradients the masked-update contract already prescribes —
//! bit-identical for every gradient still computed, and LWPN's frozen
//! prefix becomes skipped compute instead of wasted work.
//!
//! Training-time execution here *simulates* quantization (fake-quant in
//! f32); the declaration is also the input of the int8 serving lowering
//! ([`crate::lower::lower`]), which compiles the same `Vec<Layer>` into
//! a [`crate::lower::QuantizedGraph`] of true integer kernels.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::backend::Value;
use crate::error::{anyhow, bail, Result};
use crate::exec::Workspace;
use crate::freeze::site_k;
use crate::model::{Dtype, Init, IoSpec, Manifest, ParamInfo, WSite};
use crate::ops::attention::{sdpa_bwd_into, sdpa_fwd_into, AttnDims};
use crate::ops::conv::{self, ConvDims};
use crate::ops::elementwise::{embed_bwd_into, embed_fwd_into, relu_fwd_into};
use crate::ops::fakequant::{
    fq_act_bwd_tensor_into, fq_act_tensor_into, fq_weight_bwd_rows_into, fq_weight_rows_into,
};
use crate::ops::loss::softmax_xent_into;
use crate::ops::matmul::{
    col_sum_into, linear_fwd_into, matmul_dy_w_into, matmul_dyt_x_into, partial_dw_into,
};
use crate::ops::norm::{layernorm_bwd_into, layernorm_fwd_into};
use crate::tensor::{ITensor, Tensor};

// ---------------------------------------------------------------------------
// Step identity (what kind of artifact a graph is executed as)
// ---------------------------------------------------------------------------

/// Weight-gradient selection baked into a train artifact's ABI.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TrainSel {
    /// FP pretraining: no quantization, full `dW`.
    Fp,
    /// Ratio artifact: `r=1` full, `r=0` none, otherwise per-site index
    /// vectors of `site_k(c_out, r)` unfrozen rows.
    Ratio(f32),
    /// LWPN artifact: per-site flags gate whole layers at runtime.
    Lwpn,
}

/// The three step-function kinds every model compiles to.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepKind {
    Train(TrainSel),
    Fwd,
    Calib,
}

/// One artifact's identity: kind + quantization widths.
#[derive(Clone, Copy, Debug)]
pub struct StepId {
    pub kind: StepKind,
    pub w_bits: u32,
    pub a_bits: u32,
}

// ---------------------------------------------------------------------------
// Frozen-prefix backward truncation (process-wide toggle)
// ---------------------------------------------------------------------------

/// Tri-state override for the frozen-prefix backward truncation:
/// `0` = forced off, `1` = forced on, [`TRUNC_UNFORCED`] = follow the
/// `EFQAT_BWD_TRUNC` environment variable (default on).
static BWD_TRUNC_FORCE: AtomicUsize = AtomicUsize::new(TRUNC_UNFORCED);
const TRUNC_UNFORCED: usize = usize::MAX;
static BWD_TRUNC_ENV: OnceLock<bool> = OnceLock::new();

/// Force the frozen-prefix backward truncation on or off for the whole
/// process, overriding `EFQAT_BWD_TRUNC`; `None` restores env-driven
/// behavior.  A test/bench hook, mirroring
/// [`crate::ops::simd::force_f32`]: truncation is bit-identical for
/// every gradient still computed, so production code never needs this —
/// benches use it to time the truncated-vs-full legs and tests to
/// assert the identity.
pub fn force_backward_truncation(on: Option<bool>) {
    let v = match on {
        Some(false) => 0,
        Some(true) => 1,
        None => TRUNC_UNFORCED,
    };
    BWD_TRUNC_FORCE.store(v, Ordering::SeqCst);
}

/// Whether quantized train steps skip the dX propagation below the
/// lowest active weight site.  `EFQAT_BWD_TRUNC=off` (or `0`) disables;
/// anything else — including unset — enables.  Public so the trainer's
/// `bwd_layers_skipped` metric can mirror what the executor will do.
pub fn backward_truncation_enabled() -> bool {
    match BWD_TRUNC_FORCE.load(Ordering::SeqCst) {
        0 => false,
        1 => true,
        _ => *BWD_TRUNC_ENV.get_or_init(|| {
            !matches!(
                std::env::var("EFQAT_BWD_TRUNC").ok().as_deref().map(str::trim),
                Some("off") | Some("0")
            )
        }),
    }
}

// ---------------------------------------------------------------------------
// The IR
// ---------------------------------------------------------------------------

/// Quantized linear site: params `{name}.w` (`[c_out, c_in]`, freezable)
/// and optionally `{name}.b`.
#[derive(Clone, Debug)]
pub struct LinearSpec {
    pub name: String,
    pub c_in: usize,
    pub c_out: usize,
    pub bias: bool,
}

/// Quantized conv2d site: param `{name}.w` (`[c_out, c_in, k, k]` OIHW,
/// bias-free like the python layer).  Square inputs/kernels only.
#[derive(Clone, Debug)]
pub struct ConvSpec {
    pub name: String,
    pub c_in: usize,
    pub c_out: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
}

/// LayerNorm over the trailing `d` features: params `{name}.g`, `{name}.b`.
#[derive(Clone, Debug)]
pub struct NormSpec {
    pub name: String,
    pub d: usize,
}

/// Token + learned-position embedding: params `{name}.tok` (`[vocab, d]`)
/// and `{name}.pos` (`[seq, d]`), fp32 and non-freezable (trained during
/// FP pretraining only, per the paper's transformer setup).
#[derive(Clone, Debug)]
pub struct EmbedSpec {
    pub name: String,
    pub vocab: usize,
    pub seq: usize,
    pub d: usize,
}

/// Multi-head self-attention block: four quantized-linear projection
/// sites `{name}.q/k/v/o` (each `[d, d]`) around a scaled-dot-product
/// core.
#[derive(Clone, Debug)]
pub struct AttnSpec {
    pub name: String,
    pub d: usize,
    pub heads: usize,
    pub causal: bool,
}

/// One node of the sequential layer graph.
#[derive(Clone, Debug)]
pub enum Layer {
    /// `[B, ...] → [B, prod]`.
    Flatten,
    Linear(LinearSpec),
    Conv2d(ConvSpec),
    Relu,
    /// 2×2 average pool, stride 2 (NCHW).
    AvgPool2x2,
    LayerNorm(NormSpec),
    Embed(EmbedSpec),
    Attention(AttnSpec),
    /// `y = x + f(x)` — the transformer residual combinator.  The inner
    /// sub-graph must preserve the activation shape.
    Residual(Vec<Layer>),
}

/// What the model consumes as `x`.  `PartialEq` so the serving registry
/// can verify a hot-swapped checkpoint preserves the input domain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InputKind {
    /// f32 images `[B, channels, hw, hw]`; labels `y: [B]`.
    Image { channels: usize, hw: usize },
    /// i32 token ids `[B, seq]`; per-token labels `y: [B, seq]` (LM).
    Tokens { seq: usize },
}

/// A declarative native model: the whole step-function family (train /
/// fwd / calib at every precision and ratio) derives from this one value.
#[derive(Clone, Debug)]
pub struct LayerGraph {
    pub model: String,
    /// Static batch dimension baked into the manifests.
    pub batch: usize,
    pub input: InputKind,
    /// Trailing logits dimension (classifier classes or LM vocab).
    pub classes: usize,
    pub layers: Vec<Layer>,
}

impl LayerGraph {
    /// Parameter inventory in graph order (recursing into residuals).
    pub fn params(&self) -> Vec<ParamInfo> {
        let mut out = Vec::new();
        collect_params(&self.layers, &mut out);
        out
    }

    /// Freezable weight sites in graph order.
    pub fn wsites(&self) -> Vec<WSite> {
        let mut out = Vec::new();
        collect_wsites(&self.layers, &mut out);
        out
    }
}

fn lin_params(l: &LinearSpec, out: &mut Vec<ParamInfo>) {
    out.push(ParamInfo {
        name: format!("{}.w", l.name),
        shape: vec![l.c_out, l.c_in],
        init: Init::HeLin(l.c_in),
        kind: "weight".into(),
    });
    if l.bias {
        out.push(ParamInfo {
            name: format!("{}.b", l.name),
            shape: vec![l.c_out],
            init: Init::Zeros,
            kind: "bias".into(),
        });
    }
}

/// The four quantized-linear projection sites of one attention block, in
/// execution order (`q`, `k`, `v`, `o`).  Public because the int8
/// lowering pass ([`crate::lower`]) must enumerate exactly the same
/// sites with exactly the same names as the float executor.
pub fn attn_projections(a: &AttnSpec) -> Vec<LinearSpec> {
    ["q", "k", "v", "o"]
        .iter()
        .map(|p| LinearSpec {
            name: format!("{}.{p}", a.name),
            c_in: a.d,
            c_out: a.d,
            bias: true,
        })
        .collect()
}

fn collect_params(layers: &[Layer], out: &mut Vec<ParamInfo>) {
    for layer in layers {
        match layer {
            Layer::Linear(l) => lin_params(l, out),
            Layer::Conv2d(c) => out.push(ParamInfo {
                name: format!("{}.w", c.name),
                shape: vec![c.c_out, c.c_in, c.k, c.k],
                init: Init::HeConv(c.c_in * c.k * c.k),
                kind: "weight".into(),
            }),
            Layer::LayerNorm(n) => {
                out.push(ParamInfo {
                    name: format!("{}.g", n.name),
                    shape: vec![n.d],
                    init: Init::Ones,
                    kind: "norm".into(),
                });
                out.push(ParamInfo {
                    name: format!("{}.b", n.name),
                    shape: vec![n.d],
                    init: Init::Zeros,
                    kind: "norm".into(),
                });
            }
            Layer::Embed(e) => {
                out.push(ParamInfo {
                    name: format!("{}.tok", e.name),
                    shape: vec![e.vocab, e.d],
                    init: Init::Normal(0.02),
                    kind: "embed".into(),
                });
                out.push(ParamInfo {
                    name: format!("{}.pos", e.name),
                    shape: vec![e.seq, e.d],
                    init: Init::Normal(0.02),
                    kind: "embed".into(),
                });
            }
            Layer::Attention(a) => {
                for p in attn_projections(a) {
                    lin_params(&p, out);
                }
            }
            Layer::Residual(inner) => collect_params(inner, out),
            Layer::Flatten | Layer::Relu | Layer::AvgPool2x2 => {}
        }
    }
}

fn collect_wsites(layers: &[Layer], out: &mut Vec<WSite>) {
    for layer in layers {
        match layer {
            Layer::Linear(l) => out.push(WSite {
                name: format!("{}.w", l.name),
                c_out: l.c_out,
                size: l.c_out * l.c_in,
            }),
            Layer::Conv2d(c) => out.push(WSite {
                name: format!("{}.w", c.name),
                c_out: c.c_out,
                size: c.c_out * c.c_in * c.k * c.k,
            }),
            Layer::Attention(a) => {
                for p in attn_projections(a) {
                    out.push(WSite {
                        name: format!("{}.w", p.name),
                        c_out: p.c_out,
                        size: p.c_out * p.c_in,
                    });
                }
            }
            Layer::Residual(inner) => collect_wsites(inner, out),
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------------
// Manifest synthesis (mirrors python/compile/step.py's IOSpec ordering)
// ---------------------------------------------------------------------------

fn io(name: &str, shape: Vec<usize>, dtype: Dtype, role: &str, of: Option<&str>) -> IoSpec {
    IoSpec {
        name: name.to_string(),
        shape,
        dtype,
        role: role.to_string(),
        of: of.map(str::to_string),
    }
}

/// Synthesize the manifest (the cross-language ABI) a compiled artifact
/// of this graph would carry: ordered params → per-site qparams → data →
/// selectors on the input side; loss/metrics, weight/bias grads in
/// parameter order, then per-site qparam grads on the output side.
pub fn build_manifest(g: &LayerGraph, name: &str, id: &StepId) -> Manifest {
    let quant = id.w_bits > 0;
    let params = g.params();
    let wsites = g.wsites();

    let mut inputs: Vec<IoSpec> =
        params.iter().map(|p| io(&p.name, p.shape.clone(), Dtype::F32, "param", None)).collect();
    if quant && id.kind != StepKind::Calib {
        for s in &wsites {
            let (sw, sx, zx) = (
                format!("sw:{}", s.name),
                format!("sx:{}", s.name),
                format!("zx:{}", s.name),
            );
            inputs.push(io(&sw, vec![s.c_out], Dtype::F32, "qparam_sw", Some(&s.name)));
            inputs.push(io(&sx, vec![1], Dtype::F32, "qparam_sx", Some(&s.name)));
            inputs.push(io(&zx, vec![1], Dtype::F32, "qparam_zx", Some(&s.name)));
        }
    }
    let (x_spec, y_spec, logits_shape) = match g.input {
        InputKind::Image { channels, hw } => (
            io("x", vec![g.batch, channels, hw, hw], Dtype::F32, "data", None),
            io("y", vec![g.batch], Dtype::I32, "data", None),
            vec![g.batch, g.classes],
        ),
        InputKind::Tokens { seq } => (
            io("x", vec![g.batch, seq], Dtype::I32, "data", None),
            io("y", vec![g.batch, seq], Dtype::I32, "data", None),
            vec![g.batch, seq, g.classes],
        ),
    };
    inputs.push(x_spec);
    if id.kind != StepKind::Calib {
        inputs.push(y_spec);
    }

    let mut outputs: Vec<IoSpec> = Vec::new();
    match id.kind {
        StepKind::Calib => {
            for s in &wsites {
                let mm = format!("mm:{}", s.name);
                outputs.push(io(&mm, vec![2], Dtype::F32, "calib", Some(&s.name)));
            }
        }
        StepKind::Fwd => {
            outputs.push(io("loss", vec![1], Dtype::F32, "loss", None));
            outputs.push(io("correct", vec![1], Dtype::I32, "metric", None));
            outputs.push(io("logits", logits_shape, Dtype::F32, "logits", None));
        }
        StepKind::Train(sel) => {
            if let TrainSel::Ratio(r) = sel {
                if r > 0.0 && r < 1.0 {
                    for s in &wsites {
                        inputs.push(io(
                            &format!("id:{}", s.name),
                            vec![site_k(s.c_out, r)],
                            Dtype::I32,
                            "index",
                            Some(&s.name),
                        ));
                    }
                }
            }
            if sel == TrainSel::Lwpn {
                for s in &wsites {
                    let flag = format!("flag:{}", s.name);
                    inputs.push(io(&flag, vec![1], Dtype::I32, "flag", Some(&s.name)));
                }
            }
            outputs.push(io("loss", vec![1], Dtype::F32, "loss", None));
            outputs.push(io("correct", vec![1], Dtype::I32, "metric", None));
            // weight/bias grads in parameter order, then qparam grads per
            // site — exactly python/compile/step.py's manifest order
            let weight_grads = |p: &ParamInfo| -> Option<Vec<usize>> {
                match sel {
                    TrainSel::Fp | TrainSel::Lwpn => Some(p.shape.clone()),
                    TrainSel::Ratio(r) if r >= 1.0 => Some(p.shape.clone()),
                    TrainSel::Ratio(r) if r <= 0.0 => None,
                    TrainSel::Ratio(r) => {
                        Some(vec![site_k(p.shape[0], r), p.shape[1..].iter().product()])
                    }
                }
            };
            for p in &params {
                let shape = match p.kind.as_str() {
                    "weight" => match weight_grads(p) {
                        Some(s) => s,
                        None => continue,
                    },
                    // embeddings train during FP pretraining only
                    "embed" if sel != TrainSel::Fp => continue,
                    _ => p.shape.clone(),
                };
                let d = format!("d:{}", p.name);
                outputs.push(io(&d, shape, Dtype::F32, "grad", Some(&p.name)));
            }
            if sel != TrainSel::Fp {
                for s in &wsites {
                    let sw_rows = match sel {
                        TrainSel::Ratio(r) if r <= 0.0 => None,
                        TrainSel::Ratio(r) if r < 1.0 => Some(site_k(s.c_out, r)),
                        _ => Some(s.c_out),
                    };
                    if let Some(k) = sw_rows {
                        outputs.push(io(
                            &format!("d:sw:{}", s.name),
                            vec![k],
                            Dtype::F32,
                            "grad",
                            Some(&format!("sw:{}", s.name)),
                        ));
                    }
                    outputs.push(io(
                        &format!("d:sx:{}", s.name),
                        vec![1],
                        Dtype::F32,
                        "grad",
                        Some(&format!("sx:{}", s.name)),
                    ));
                    outputs.push(io(
                        &format!("d:zx:{}", s.name),
                        vec![1],
                        Dtype::F32,
                        "grad",
                        Some(&format!("zx:{}", s.name)),
                    ));
                }
            }
        }
    }

    let (sel_mode, ratio) = match id.kind {
        StepKind::Train(TrainSel::Fp) => ("fp", 1.0),
        StepKind::Train(TrainSel::Ratio(r)) => ("ratio", r),
        StepKind::Train(TrainSel::Lwpn) => ("lwpn", 1.0),
        _ => ("", 1.0),
    };
    Manifest {
        name: name.to_string(),
        model: g.model.clone(),
        kind: match id.kind {
            StepKind::Train(_) => "train",
            StepKind::Fwd => "fwd",
            StepKind::Calib => "calib",
        }
        .to_string(),
        sel_mode: sel_mode.to_string(),
        ratio,
        w_bits: id.w_bits,
        a_bits: id.a_bits,
        batch_size: g.batch,
        params,
        states: Vec::new(),
        wsites,
        inputs,
        outputs,
    }
}

// ---------------------------------------------------------------------------
// Execution plan: manifest names resolved to positions, once, at load
// ---------------------------------------------------------------------------
//
// The executor below never performs a name lookup per step.  At
// `GraphStep::new` time the graph is compiled against its own manifest
// into a `GraphPlan`: every parameter / qparam / selector input becomes
// a position into the positional input vector, and every gradient /
// metric output becomes a slot into the positional output vector.  The
// per-step cost of the old `Vals` map (a BTreeMap rebuilt per
// execution, plus `format!` keys on every access — including a full
// clone of each site's `sw:` scale tensor) is gone.

/// Input positions of one site's quantization parameters.
struct QSlots {
    sw: usize,
    sx: usize,
    zx: usize,
}

/// Compile-time weight-gradient selection: which selector input (if
/// any) gates this site at run time.
enum PlanSel {
    All,
    None,
    /// Position of the `id:{site}` index vector (CWPL/CWPN ratios).
    Idx(usize),
    /// Position of the `flag:{site}` scalar (LWPN).
    Flag(usize),
}

/// One quantized-linear site with every manifest name resolved.
struct PlanLin {
    /// Site name (`{layer}.w`) — diagnostics and calib taps only.
    site: String,
    c_in: usize,
    c_out: usize,
    w: usize,
    b_in: Option<usize>,
    q: Option<QSlots>,
    sel: PlanSel,
    dw: Option<usize>,
    db: Option<usize>,
    dsw: Option<usize>,
    dsx: Option<usize>,
    dzx: Option<usize>,
}

struct PlanConv {
    /// The site view: `c_in` here is the im2col patch size.
    lin: PlanLin,
    c_in: usize,
    k: usize,
    stride: usize,
    pad: usize,
}

struct PlanNorm {
    name: String,
    d: usize,
    g: usize,
    b: usize,
    dg: Option<usize>,
    db: Option<usize>,
}

struct PlanEmbed {
    vocab: usize,
    seq: usize,
    d: usize,
    tok: usize,
    pos: usize,
    dtok: Option<usize>,
    dpos: Option<usize>,
}

struct PlanAttn {
    proj: [PlanLin; 4],
    heads: usize,
    causal: bool,
    d: usize,
}

/// The planned mirror of one [`Layer`].
#[allow(clippy::large_enum_variant)] // compile-time structure, built once per artifact
enum PlanLayer {
    Flatten,
    Linear(PlanLin),
    Conv(PlanConv),
    Relu,
    Pool,
    Norm(PlanNorm),
    Embed(PlanEmbed),
    Attn(Box<PlanAttn>),
    Residual(Vec<PlanLayer>),
}

/// The compiled execution plan of one `GraphStep`.
struct GraphPlan {
    layers: Vec<PlanLayer>,
    x: usize,
    y: Option<usize>,
    loss: Option<usize>,
    correct: Option<usize>,
    logits: Option<usize>,
}

struct PlanCx<'m> {
    man: &'m Manifest,
    id: &'m StepId,
}

impl PlanCx<'_> {
    fn in_pos(&self, name: &str) -> Result<usize> {
        self.man
            .inputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("{}: plan: manifest missing input {name:?}", self.man.name))
    }

    fn find_in(&self, name: &str) -> Option<usize> {
        self.man.inputs.iter().position(|s| s.name == name)
    }

    fn find_out(&self, name: &str) -> Option<usize> {
        self.man.outputs.iter().position(|s| s.name == name)
    }

    fn quantized(&self) -> bool {
        self.id.w_bits > 0 && self.id.kind != StepKind::Calib
    }

    fn lin(&self, spec: &LinearSpec) -> Result<PlanLin> {
        let bias = if spec.bias { Some(format!("{}.b", spec.name)) } else { None };
        self.raw_site(&format!("{}.w", spec.name), spec.c_in, spec.c_out, bias)
    }

    fn raw_site(
        &self,
        site: &str,
        c_in: usize,
        c_out: usize,
        bias: Option<String>,
    ) -> Result<PlanLin> {
        let q = if self.quantized() {
            Some(QSlots {
                sw: self.in_pos(&format!("sw:{site}"))?,
                sx: self.in_pos(&format!("sx:{site}"))?,
                zx: self.in_pos(&format!("zx:{site}"))?,
            })
        } else {
            None
        };
        let sel = match self.id.kind {
            StepKind::Train(TrainSel::Lwpn) => {
                PlanSel::Flag(self.in_pos(&format!("flag:{site}"))?)
            }
            StepKind::Train(TrainSel::Ratio(r)) if r <= 0.0 => PlanSel::None,
            StepKind::Train(TrainSel::Ratio(r)) if r < 1.0 => {
                PlanSel::Idx(self.in_pos(&format!("id:{site}"))?)
            }
            _ => PlanSel::All,
        };
        let b_in = match &bias {
            Some(b) => Some(self.in_pos(b)?),
            None => None,
        };
        let db = bias.as_deref().and_then(|b| self.find_out(&format!("d:{b}")));
        Ok(PlanLin {
            site: site.to_string(),
            c_in,
            c_out,
            w: self.in_pos(site)?,
            b_in,
            q,
            sel,
            dw: self.find_out(&format!("d:{site}")),
            db,
            dsw: self.find_out(&format!("d:sw:{site}")),
            dsx: self.find_out(&format!("d:sx:{site}")),
            dzx: self.find_out(&format!("d:zx:{site}")),
        })
    }

    fn layers(&self, layers: &[Layer]) -> Result<Vec<PlanLayer>> {
        layers.iter().map(|l| self.layer(l)).collect()
    }

    fn layer(&self, layer: &Layer) -> Result<PlanLayer> {
        Ok(match layer {
            Layer::Flatten => PlanLayer::Flatten,
            Layer::Relu => PlanLayer::Relu,
            Layer::AvgPool2x2 => PlanLayer::Pool,
            Layer::Linear(spec) => PlanLayer::Linear(self.lin(spec)?),
            Layer::Conv2d(spec) => {
                let patch = spec.c_in * spec.k * spec.k;
                let wname = format!("{}.w", spec.name);
                PlanLayer::Conv(PlanConv {
                    lin: self.raw_site(&wname, patch, spec.c_out, None)?,
                    c_in: spec.c_in,
                    k: spec.k,
                    stride: spec.stride,
                    pad: spec.pad,
                })
            }
            Layer::LayerNorm(spec) => PlanLayer::Norm(PlanNorm {
                name: spec.name.clone(),
                d: spec.d,
                g: self.in_pos(&format!("{}.g", spec.name))?,
                b: self.in_pos(&format!("{}.b", spec.name))?,
                dg: self.find_out(&format!("d:{}.g", spec.name)),
                db: self.find_out(&format!("d:{}.b", spec.name)),
            }),
            Layer::Embed(spec) => PlanLayer::Embed(PlanEmbed {
                vocab: spec.vocab,
                seq: spec.seq,
                d: spec.d,
                tok: self.in_pos(&format!("{}.tok", spec.name))?,
                pos: self.in_pos(&format!("{}.pos", spec.name))?,
                dtok: self.find_out(&format!("d:{}.tok", spec.name)),
                dpos: self.find_out(&format!("d:{}.pos", spec.name)),
            }),
            Layer::Attention(spec) => {
                let projs = attn_projections(spec);
                let mut lins = projs.iter().map(|p| self.lin(p));
                let proj = [
                    lins.next().unwrap()?,
                    lins.next().unwrap()?,
                    lins.next().unwrap()?,
                    lins.next().unwrap()?,
                ];
                PlanLayer::Attn(Box::new(PlanAttn {
                    proj,
                    heads: spec.heads,
                    causal: spec.causal,
                    d: spec.d,
                }))
            }
            Layer::Residual(inner) => PlanLayer::Residual(self.layers(inner)?),
        })
    }
}

impl GraphPlan {
    fn compile(graph: &LayerGraph, man: &Manifest, id: &StepId) -> Result<GraphPlan> {
        let cx = PlanCx { man, id };
        Ok(GraphPlan {
            layers: cx.layers(&graph.layers)?,
            x: cx.in_pos("x")?,
            y: cx.find_in("y"),
            loss: cx.find_out("loss"),
            correct: cx.find_out("correct"),
            logits: cx.find_out("logits"),
        })
    }
}

// ---------------------------------------------------------------------------
// Execution
// ---------------------------------------------------------------------------

/// One executable step: a graph coupled with an artifact identity, the
/// manifest synthesized for it, and the execution plan compiled against
/// that manifest.
pub struct GraphStep {
    pub graph: LayerGraph,
    pub id: StepId,
    pub man: Manifest,
    plan: GraphPlan,
    /// Recycled residual-cache vectors (capacity only — always empty
    /// between executions), so the per-step cache bookkeeping performs
    /// no heap allocation either.
    cache_pool: RefCell<Vec<Vec<Cache>>>,
}

/// Per-site quantization parameters borrowed from the bound inputs —
/// the `sw:` scale tensor is **borrowed**, never cloned per step.
struct SiteQ<'v> {
    sw: &'v [f32],
    sx: f32,
    zx: f32,
}

/// Runtime weight-gradient selection for one site; the `Idx` vector is
/// drawn from the workspace and returned to it after use.
enum RunSel {
    All,
    None,
    Idx(Vec<usize>),
    Flag(bool),
}

/// Residual cache of one quantized-linear site (shared by `Linear` and
/// the four attention projections).  All buffers are workspace-owned;
/// `None` means the backward reads the shared fallback instead
/// (attention's FP path, where `x̂ = x` for all three of q/k/v).
struct LinCache {
    xh: Option<Vec<f32>>,
    /// Fake-quantized weights; `None` on FP paths (backward reads the
    /// raw weight input — no clone).
    wh: Option<Vec<f32>>,
    rows: usize,
}

struct ConvCache {
    /// Raw pre-quant input — kept only on quantized train steps.
    x_raw: Vec<f32>,
    /// im2col of the (quantized) input: `[M, C_in·k·k]`.
    cols: Vec<f32>,
    wh: Option<Vec<f32>>,
    dims: ConvDims,
}

struct AttnCache {
    /// Block input: the raw input of the q/k/v quantizer backwards and
    /// the shared `x̂` fallback on FP paths.
    x: Vec<f32>,
    /// SDPA output: the o-projection's input, in the same dual role.
    om: Vec<f32>,
    q_lin: LinCache,
    k_lin: LinCache,
    v_lin: LinCache,
    o_lin: LinCache,
    qy: Vec<f32>,
    ky: Vec<f32>,
    vy: Vec<f32>,
    p: Vec<f32>,
    dm: AttnDims,
}

/// What each layer's forward leaves behind for the backward pass.
/// Everything inside is workspace-owned and returned to the pools as
/// the backward consumes it.
#[allow(clippy::large_enum_variant)] // few live at once; boxing would cost a per-step alloc
enum Cache {
    Flatten { shape: Vec<usize> },
    Linear { lin: LinCache, x_raw: Vec<f32>, x_shape: Vec<usize> },
    Conv(ConvCache),
    Relu { pre: Vec<f32> },
    Pool { b: usize, c: usize, hw: usize },
    Norm { xhat: Vec<f32>, inv: Vec<f32>, rows: usize },
    Embed,
    Attn(AttnCache),
    Residual(Vec<Cache>),
}

/// Activation flowing between layers.  Token ids never leave the input
/// vector — the embedding (and its backward) reads them through the
/// plan, so `I` carries nothing.
enum Act {
    F(Tensor),
    I,
}

fn act_f32(act: Act) -> Result<Tensor> {
    match act {
        Act::F(t) => Ok(t),
        Act::I => bail!("graph: layer expected an f32 activation, got i32"),
    }
}

impl GraphStep {
    /// Couple a graph with an artifact identity, synthesizing the
    /// manifest and compiling the execution plan against it.
    pub fn new(graph: LayerGraph, artifact: &str, id: StepId) -> Result<GraphStep> {
        let man = build_manifest(&graph, artifact, &id);
        let plan = GraphPlan::compile(&graph, &man, &id)?;
        Ok(GraphStep { graph, id, man, plan, cache_pool: RefCell::new(Vec::new()) })
    }

    fn take_caches(&self) -> Vec<Cache> {
        self.cache_pool.borrow_mut().pop().unwrap_or_default()
    }

    fn give_caches(&self, caches: Vec<Cache>) {
        debug_assert!(caches.is_empty(), "recycled cache vec must be drained");
        self.cache_pool.borrow_mut().push(caches);
    }

    /// Forward to logits only — no loss, metric, or `dlogits` work.
    /// The serving bench times this against the int8 engine
    /// ([`crate::lower::QuantizedGraph::forward`]) so both sides do the
    /// same job.  Allocating wrapper over [`Self::forward_logits_ws`].
    pub fn forward_logits(&self, inputs: &[Value]) -> Result<Tensor> {
        let mut ws = Workspace::new();
        self.forward_logits_ws(inputs, &mut ws)
    }

    /// Forward to logits over a caller-owned workspace; the returned
    /// tensor's buffers are pooled — give them back to `ws` to recycle.
    pub fn forward_logits_ws(&self, inputs: &[Value], ws: &mut Workspace) -> Result<Tensor> {
        self.check_arity(inputs)?;
        let out = ws.take_slots(0);
        let mut caches = self.take_caches();
        let mut run = Run { step: self, inputs, ws: &mut *ws, out, taps: None };
        let result = run.forward(&mut caches);
        run.drop_caches(&mut caches);
        let out = run.out;
        self.give_caches(caches);
        ws.give_slots(out);
        result
    }

    /// Execute on inputs packed in manifest order; outputs come back in
    /// manifest order (the [`crate::backend::StepExec`] contract).
    /// Allocating wrapper over [`Self::execute_ws`].
    pub fn execute(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        let mut ws = Workspace::new();
        self.execute_ws(inputs, &mut ws)
    }

    /// Execute over a caller-owned workspace.  Every activation, cache,
    /// gradient, and output buffer is drawn from `ws`; recycle the
    /// returned values with [`Workspace::give_values`] after consuming
    /// them and the steady state performs zero heap allocations per
    /// step (`rust/tests/workspace_alloc.rs`).
    pub fn execute_ws(&self, inputs: &[Value], ws: &mut Workspace) -> Result<Vec<Value>> {
        self.check_arity(inputs)?;
        let slots = ws.take_slots(self.man.outputs.len());
        let mut run = Run { step: self, inputs, ws: &mut *ws, out: slots, taps: None };
        let result = match self.id.kind {
            StepKind::Train(_) => run.run_train(),
            StepKind::Fwd => run.run_fwd(),
            StepKind::Calib => run.run_calib(),
        };
        let mut slots = run.out;
        if let Err(e) = result {
            ws.give_slots(slots);
            return Err(e);
        }
        let mut vals = ws.take_values();
        let mut missing = None;
        for (i, slot) in slots.iter_mut().enumerate() {
            match slot.take() {
                Some(v) => vals.push(v),
                None => {
                    missing = Some(i);
                    break;
                }
            }
        }
        if let Some(i) = missing {
            let name = self.man.outputs[i].name.clone();
            ws.give_values(vals);
            ws.give_slots(slots);
            bail!("{}: graph step produced no output {name:?}", self.man.name);
        }
        ws.give_slots(slots);
        Ok(vals)
    }

    fn check_arity(&self, inputs: &[Value]) -> Result<()> {
        if inputs.len() != self.man.inputs.len() {
            bail!(
                "{}: {} inputs supplied, manifest wants {}",
                self.man.name,
                inputs.len(),
                self.man.inputs.len()
            );
        }
        Ok(())
    }
}

/// The frozen-channel-aware weight-gradient rule (paper Fig. 1 right),
/// implemented once for every layer type.  `full_dwhat` /
/// `partial_dwhat` supply the layer's own contraction (plain matmul for
/// linear sites, im2col matmul for conv) as workspace-drawing closures;
/// this function owns the selection logic and the STE/LSQ quantizer
/// backward:
///
/// * `All` / `Flag(true)` — full `dŴ`, full quantizer backward;
/// * `Flag(false)` — the LWPN saving: the `dŴ` contraction is
///   *skipped at runtime*; the ABI still carries full-shape zeros;
/// * `Idx` — only the gathered unfrozen rows are ever materialized
///   (CWPL/CWPN): `dW[idx] = gather(dY, idx)ᵀ · X̂`;
/// * `None` — the r=0 case: no weight gradient at all.
#[allow(clippy::too_many_arguments)] // a VJP dispatcher: selection, operands, ws, contractions
fn weight_site_grads(
    w_bits: u32,
    sel: &RunSel,
    w: &Tensor,
    q: Option<&SiteQ<'_>>,
    row_size: usize,
    ws: &mut Workspace,
    full_dwhat: &mut dyn FnMut(&mut Workspace) -> Vec<f32>,
    partial_dwhat: &mut dyn FnMut(&mut Workspace, &[usize]) -> Vec<f32>,
) -> (Option<Tensor>, Option<Vec<f32>>) {
    let c_out = w.shape[0];
    match q {
        Some(q) => match sel {
            RunSel::All | RunSel::Flag(true) => {
                let dwhat = full_dwhat(ws);
                let mut dw = ws.take_f32(w.data.len());
                let mut ds = ws.take_f32(c_out);
                fq_weight_bwd_rows_into(&w.data, q.sw, &dwhat, row_size, w_bits, &mut dw, &mut ds);
                ws.give_f32(dwhat);
                (Some(Tensor { shape: ws.take_shape(&w.shape), data: dw }), Some(ds))
            }
            RunSel::Flag(false) => {
                // take_* zero-fills, so these are the ABI's zero grads
                let data = ws.take_f32(w.data.len());
                let dw = Tensor { shape: ws.take_shape(&w.shape), data };
                (Some(dw), Some(ws.take_f32(c_out)))
            }
            RunSel::Idx(ids) => {
                let dwhat = partial_dwhat(ws, ids);
                let mut w_rows = ws.take_f32(ids.len() * row_size);
                let mut s_rows = ws.take_f32(ids.len());
                for (gi, &r) in ids.iter().enumerate() {
                    let src = &w.data[r * row_size..(r + 1) * row_size];
                    w_rows[gi * row_size..(gi + 1) * row_size].copy_from_slice(src);
                    s_rows[gi] = q.sw[r];
                }
                let mut dw = ws.take_f32(ids.len() * row_size);
                let mut ds = ws.take_f32(ids.len());
                fq_weight_bwd_rows_into(
                    &w_rows, &s_rows, &dwhat, row_size, w_bits, &mut dw, &mut ds,
                );
                ws.give_f32(dwhat);
                ws.give_f32(w_rows);
                ws.give_f32(s_rows);
                let dw = Tensor { shape: ws.take_shape(&[ids.len(), row_size]), data: dw };
                (Some(dw), Some(ds))
            }
            RunSel::None => (None, None),
        },
        None => {
            let dw = match sel {
                RunSel::None => None,
                RunSel::Flag(false) => Some(Tensor {
                    shape: ws.take_shape(&w.shape),
                    data: ws.take_f32(w.data.len()),
                }),
                RunSel::Idx(ids) => {
                    let data = partial_dwhat(ws, ids);
                    Some(Tensor { shape: ws.take_shape(&[ids.len(), row_size]), data })
                }
                _ => {
                    let data = full_dwhat(ws);
                    Some(Tensor { shape: ws.take_shape(&w.shape), data })
                }
            };
            (dw, None)
        }
    }
}

/// One execution of a [`GraphStep`] over bound inputs and a workspace.
struct Run<'p, 'v, 'w> {
    step: &'p GraphStep,
    inputs: &'v [Value],
    ws: &'w mut Workspace,
    /// Positional output slots (manifest order).
    out: Vec<Option<Value>>,
    /// `Some` during calibration: per-site `(min, max)` of the raw input
    /// each quantized site saw (the MinMax observer taps, Eq. 2).
    taps: Option<BTreeMap<String, (f32, f32)>>,
}

impl<'p, 'v, 'w> Run<'p, 'v, 'w> {
    // ---- plan-resolved input access (decoupled from &self) ----------------

    fn f32_in(&self, i: usize) -> Result<&'v Tensor> {
        let inputs: &'v [Value] = self.inputs;
        inputs[i].f32()
    }

    fn i32_in(&self, i: usize) -> Result<&'v ITensor> {
        let inputs: &'v [Value] = self.inputs;
        inputs[i].i32()
    }

    fn scalar_in(&self, i: usize) -> Result<f32> {
        let inputs: &'v [Value] = self.inputs;
        inputs[i].scalar().map_err(|e| anyhow!("{}: input {i}: {e}", self.step.man.name))
    }

    fn quantized(&self) -> bool {
        self.step.id.w_bits > 0 && self.step.id.kind != StepKind::Calib
    }

    /// Whether a quantized site must keep its raw (pre-quant) input:
    /// only the quantizer backward reads it, so fwd/calib steps skip it.
    fn keep_raw(&self) -> bool {
        matches!(self.step.id.kind, StepKind::Train(_))
    }

    // ---- shared quantized-site plumbing -----------------------------------

    fn siteq(&self, p: &PlanLin) -> Result<Option<SiteQ<'v>>> {
        let slots = match (&p.q, self.quantized()) {
            (Some(s), true) => s,
            _ => return Ok(None),
        };
        let sw = &self.f32_in(slots.sw)?.data[..];
        if sw.iter().any(|&s| s <= 0.0 || !s.is_finite()) {
            bail!("{}: non-positive weight scale for site {:?}", self.step.man.name, p.site);
        }
        let sx = self.scalar_in(slots.sx)?;
        if sx <= 0.0 || !sx.is_finite() {
            bail!("{}: non-positive activation scale for site {:?}", self.step.man.name, p.site);
        }
        let zx = self.scalar_in(slots.zx)?;
        Ok(Some(SiteQ { sw, sx, zx }))
    }

    /// Record the (min, max) a quantized site's raw input — the MinMax
    /// observer tap of the calib artifacts.
    fn tap_site(&mut self, site: &str, x: &[f32]) {
        if let Some(taps) = &mut self.taps {
            let lo = x.iter().copied().fold(f32::INFINITY, f32::min);
            let hi = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            taps.insert(site.to_string(), (lo, hi));
        }
    }

    /// Resolve the runtime weight-gradient selection for one site.  The
    /// `Idx` vector is pooled — return it with `give_shape` after use.
    fn run_sel(&mut self, p: &PlanLin) -> Result<RunSel> {
        Ok(match p.sel {
            PlanSel::All => RunSel::All,
            PlanSel::None => RunSel::None,
            PlanSel::Flag(pos) => RunSel::Flag(self.i32_in(pos)?.data[0] > 0),
            PlanSel::Idx(pos) => {
                let ids = self.i32_in(pos)?;
                let mut out = self.ws.take_indices(ids.data.len());
                for &c in &ids.data {
                    if c < 0 || c as usize >= p.c_out {
                        bail!(
                            "{}: selection index {c} out of range for site {:?} (c_out {})",
                            self.step.man.name,
                            p.site,
                            p.c_out
                        );
                    }
                    out.push(c as usize);
                }
                RunSel::Idx(out)
            }
        })
    }

    // ---- output emission --------------------------------------------------

    fn emit(&mut self, slot: Option<usize>, v: Value) {
        match slot {
            Some(s) => self.out[s] = Some(v),
            None => self.ws.give_value(v),
        }
    }

    fn emit_f32(&mut self, slot: Option<usize>, t: Option<Tensor>) {
        if let Some(t) = t {
            self.emit(slot, Value::F32(t));
        }
    }

    fn emit_dsw(&mut self, slot: Option<usize>, ds: Option<Vec<f32>>) {
        if let Some(ds) = ds {
            let n = ds.len();
            let t = self.ws.tensor(&[n], ds);
            self.emit_f32(slot, Some(t));
        }
    }

    // ---- quantized linear site (Linear + attention projections) -----------

    /// Linear forward consuming its input: the input buffer becomes the
    /// FP `x̂` cache, the quantizer's raw cache, or goes straight back
    /// to the workspace — never a clone.
    fn lin_fwd_owned(&mut self, p: &PlanLin, x: Tensor) -> Result<(Tensor, Cache)> {
        let step = self.step;
        if x.shape.last() != Some(&p.c_in) {
            bail!(
                "{}: linear {:?} wants {} input features, activation is {:?}",
                step.man.name,
                p.site,
                p.c_in,
                x.shape
            );
        }
        let rows = x.data.len() / p.c_in;
        self.tap_site(&p.site, &x.data);
        let q = self.siteq(p)?;
        let w = self.f32_in(p.w)?;
        let bias: Option<&[f32]> = match p.b_in {
            Some(i) => Some(&self.f32_in(i)?.data[..]),
            None => None,
        };
        let mut y = self.ws.take_f32(rows * p.c_out);
        let keep = q.is_some() && self.keep_raw();
        let (lin, x_raw, x_shape) = match &q {
            Some(sq) => {
                let mut xh = self.ws.take_f32(x.data.len());
                fq_act_tensor_into(&x.data, sq.sx, sq.zx, step.id.a_bits, &mut xh);
                let mut wh = self.ws.take_f32(w.data.len());
                fq_weight_rows_into(&w.data, sq.sw, p.c_in, step.id.w_bits, &mut wh);
                linear_fwd_into(&xh, &wh, bias, rows, p.c_in, p.c_out, &mut y);
                let Tensor { shape, data } = x;
                let x_raw = if keep {
                    data
                } else {
                    self.ws.give_f32(data);
                    Vec::new()
                };
                (LinCache { xh: Some(xh), wh: Some(wh), rows }, x_raw, shape)
            }
            None => {
                linear_fwd_into(&x.data, &w.data, bias, rows, p.c_in, p.c_out, &mut y);
                let Tensor { shape, data } = x;
                (LinCache { xh: Some(data), wh: None, rows }, Vec::new(), shape)
            }
        };
        let mut y_shape = self.ws.take_shape(&x_shape);
        *y_shape.last_mut().unwrap() = p.c_out;
        Ok((Tensor { shape: y_shape, data: y }, Cache::Linear { lin, x_raw, x_shape }))
    }

    /// Linear forward over a shared input (the attention projections,
    /// which all read the same block input).  On FP paths the cache
    /// stores nothing — backward falls back to the shared slice.
    fn lin_fwd_shared(
        &mut self,
        p: &PlanLin,
        x: &[f32],
        rows: usize,
    ) -> Result<(Vec<f32>, LinCache)> {
        let step = self.step;
        self.tap_site(&p.site, x);
        let q = self.siteq(p)?;
        let w = self.f32_in(p.w)?;
        let bias: Option<&[f32]> = match p.b_in {
            Some(i) => Some(&self.f32_in(i)?.data[..]),
            None => None,
        };
        let mut y = self.ws.take_f32(rows * p.c_out);
        let lin = match &q {
            Some(sq) => {
                let mut xh = self.ws.take_f32(x.len());
                fq_act_tensor_into(x, sq.sx, sq.zx, step.id.a_bits, &mut xh);
                let mut wh = self.ws.take_f32(w.data.len());
                fq_weight_rows_into(&w.data, sq.sw, p.c_in, step.id.w_bits, &mut wh);
                linear_fwd_into(&xh, &wh, bias, rows, p.c_in, p.c_out, &mut y);
                LinCache { xh: Some(xh), wh: Some(wh), rows }
            }
            None => {
                linear_fwd_into(x, &w.data, bias, rows, p.c_in, p.c_out, &mut y);
                LinCache { xh: None, wh: None, rows }
            }
        };
        Ok((y, lin))
    }

    /// Shared linear backward.  `xh_fallback` / `x_raw` supply the
    /// shared-input roles for attention projections (`cache.xh == None`
    /// on FP paths); plain linears pass their own cached buffers.
    /// Returns the pooled `dx` data.
    fn lin_bwd_core(
        &mut self,
        p: &PlanLin,
        cache: LinCache,
        dy: &[f32],
        xh_fallback: &[f32],
        x_raw: &[f32],
    ) -> Result<Vec<f32>> {
        let step = self.step;
        let (rows, c_in, c_out) = (cache.rows, p.c_in, p.c_out);
        if let Some(slot) = p.db {
            let mut db = self.ws.take_f32(c_out);
            col_sum_into(dy, rows, c_out, &mut db);
            let t = self.ws.tensor(&[c_out], db);
            self.emit_f32(Some(slot), Some(t));
        }
        let q = self.siteq(p)?;
        let w = self.f32_in(p.w)?;
        let wh: &[f32] = match &cache.wh {
            Some(v) => v,
            None => &w.data,
        };
        let mut dxh = self.ws.take_f32(rows * c_in);
        matmul_dy_w_into(dy, wh, rows, c_out, c_in, &mut dxh);
        let sel = self.run_sel(p)?;
        let xh: &[f32] = match &cache.xh {
            Some(v) => v,
            None => xh_fallback,
        };
        let mut full = |ws: &mut Workspace| {
            let mut dw = ws.take_f32(c_out * c_in);
            matmul_dyt_x_into(dy, xh, rows, c_out, c_in, &mut dw);
            dw
        };
        let mut partial = |ws: &mut Workspace, ids: &[usize]| {
            let mut dw = ws.take_f32(ids.len() * c_in);
            partial_dw_into(dy, xh, ids, rows, c_out, c_in, &mut dw);
            dw
        };
        let (dw, dsw) = weight_site_grads(
            step.id.w_bits,
            &sel,
            w,
            q.as_ref(),
            c_in,
            &mut *self.ws,
            &mut full,
            &mut partial,
        );
        if let RunSel::Idx(ids) = sel {
            self.ws.give_shape(ids);
        }
        self.emit_f32(p.dw, dw);
        self.emit_dsw(p.dsw, dsw);
        let dx = match &q {
            Some(sq) => {
                let mut dx = self.ws.take_f32(rows * c_in);
                let (ds, dz) =
                    fq_act_bwd_tensor_into(x_raw, sq.sx, sq.zx, &dxh, step.id.a_bits, &mut dx);
                self.ws.give_f32(dxh);
                let t = self.ws.scalar(ds);
                self.emit_f32(p.dsx, Some(t));
                let t = self.ws.scalar(dz);
                self.emit_f32(p.dzx, Some(t));
                dx
            }
            None => dxh,
        };
        if let Some(v) = cache.xh {
            self.ws.give_f32(v);
        }
        if let Some(v) = cache.wh {
            self.ws.give_f32(v);
        }
        Ok(dx)
    }

    // ---- forward ----------------------------------------------------------

    fn input_act(&mut self) -> Result<Act> {
        let step = self.step;
        match step.graph.input {
            InputKind::Image { .. } => {
                let x = self.f32_in(step.plan.x)?;
                let mut data = self.ws.take_f32(x.data.len());
                data.copy_from_slice(&x.data);
                let shape = self.ws.take_shape(&x.shape);
                Ok(Act::F(Tensor { shape, data }))
            }
            InputKind::Tokens { .. } => Ok(Act::I),
        }
    }

    fn forward(&mut self, caches: &mut Vec<Cache>) -> Result<Tensor> {
        let step = self.step;
        let x0 = self.input_act()?;
        let out = self.forward_seq(&step.plan.layers, x0, caches)?;
        act_f32(out)
    }

    fn forward_seq(
        &mut self,
        plans: &'p [PlanLayer],
        mut act: Act,
        caches: &mut Vec<Cache>,
    ) -> Result<Act> {
        for plan in plans {
            act = self.forward_layer(plan, act, caches)?;
        }
        Ok(act)
    }

    fn forward_layer(
        &mut self,
        plan: &'p PlanLayer,
        act: Act,
        caches: &mut Vec<Cache>,
    ) -> Result<Act> {
        Ok(match plan {
            PlanLayer::Flatten => {
                let x = act_f32(act)?;
                let b = x.shape.first().copied().unwrap_or(1);
                let rest: usize = x.shape[1..].iter().product();
                let Tensor { shape, data } = x;
                caches.push(Cache::Flatten { shape });
                Act::F(Tensor { shape: self.ws.take_shape(&[b, rest]), data })
            }
            PlanLayer::Linear(p) => {
                let x = act_f32(act)?;
                let (y, cache) = self.lin_fwd_owned(p, x)?;
                caches.push(cache);
                Act::F(y)
            }
            PlanLayer::Conv(pc) => {
                let x = act_f32(act)?;
                let p = &pc.lin;
                if x.shape.len() != 4 || x.shape[1] != pc.c_in || x.shape[2] != x.shape[3] {
                    bail!(
                        "{}: conv {:?} wants [B, {}, H, H], activation is {:?}",
                        self.step.man.name,
                        p.site,
                        pc.c_in,
                        x.shape
                    );
                }
                let dims = ConvDims {
                    batch: x.shape[0],
                    c_in: pc.c_in,
                    hw: x.shape[2],
                    c_out: p.c_out,
                    k: pc.k,
                    stride: pc.stride,
                    pad: pc.pad,
                };
                self.tap_site(&p.site, &x.data);
                let q = self.siteq(p)?;
                let w = self.f32_in(p.w)?;
                let patch = dims.patch();
                let mut cols = self.ws.take_f32(dims.rows() * patch);
                let wh = match &q {
                    Some(sq) => {
                        let mut xh = self.ws.take_f32(x.data.len());
                        fq_act_tensor_into(&x.data, sq.sx, sq.zx, self.step.id.a_bits, &mut xh);
                        let mut wh = self.ws.take_f32(w.data.len());
                        fq_weight_rows_into(&w.data, sq.sw, patch, self.step.id.w_bits, &mut wh);
                        conv::im2col_into(&xh, &dims, &mut cols);
                        self.ws.give_f32(xh);
                        Some(wh)
                    }
                    None => {
                        conv::im2col_into(&x.data, &dims, &mut cols);
                        None
                    }
                };
                let keep = q.is_some() && self.keep_raw();
                let mut y2 = self.ws.take_f32(dims.rows() * p.c_out);
                let whs: &[f32] = match &wh {
                    Some(v) => v,
                    None => &w.data,
                };
                linear_fwd_into(&cols, whs, None, dims.rows(), patch, p.c_out, &mut y2);
                let mut y = self.ws.take_f32(y2.len());
                conv::rows_to_nchw_into(&y2, &dims, &mut y);
                self.ws.give_f32(y2);
                let ho = dims.hw_out();
                let Tensor { mut shape, data } = x;
                let x_raw = if keep {
                    data
                } else {
                    self.ws.give_f32(data);
                    Vec::new()
                };
                shape[1] = p.c_out;
                shape[2] = ho;
                shape[3] = ho;
                caches.push(Cache::Conv(ConvCache { x_raw, cols, wh, dims }));
                Act::F(Tensor { shape, data: y })
            }
            PlanLayer::Relu => {
                let x = act_f32(act)?;
                let mut y = self.ws.take_f32(x.data.len());
                relu_fwd_into(&x.data, &mut y);
                let Tensor { shape, data } = x;
                caches.push(Cache::Relu { pre: data });
                Act::F(Tensor { shape, data: y })
            }
            PlanLayer::Pool => {
                let x = act_f32(act)?;
                if x.shape.len() != 4 || x.shape[2] % 2 != 0 || x.shape[2] != x.shape[3] {
                    let step = &self.step.man.name;
                    bail!("{step}: avgpool wants [B, C, 2n, 2n], got {:?}", x.shape);
                }
                let (b, c, hw) = (x.shape[0], x.shape[1], x.shape[2]);
                let ho = hw / 2;
                let mut y = self.ws.take_f32(b * c * ho * ho);
                conv::avgpool2_fwd_into(&x.data, b, c, hw, &mut y);
                let Tensor { mut shape, data } = x;
                self.ws.give_f32(data);
                shape[2] = ho;
                shape[3] = ho;
                caches.push(Cache::Pool { b, c, hw });
                Act::F(Tensor { shape, data: y })
            }
            PlanLayer::Norm(pn) => {
                let x = act_f32(act)?;
                if x.shape.last() != Some(&pn.d) {
                    let step = &self.step.man.name;
                    bail!(
                        "{step}: layernorm {:?} wants {} features, got {:?}",
                        pn.name,
                        pn.d,
                        x.shape
                    );
                }
                let rows = x.data.len() / pn.d;
                let g = self.f32_in(pn.g)?;
                let bb = self.f32_in(pn.b)?;
                let mut y = self.ws.take_f32(x.data.len());
                let mut xhat = self.ws.take_f32(x.data.len());
                let mut inv = self.ws.take_f32(rows);
                layernorm_fwd_into(
                    &x.data, &g.data, &bb.data, rows, pn.d, &mut y, &mut xhat, &mut inv,
                );
                let Tensor { shape, data } = x;
                self.ws.give_f32(data);
                caches.push(Cache::Norm { xhat, inv, rows });
                Act::F(Tensor { shape, data: y })
            }
            PlanLayer::Embed(pe) => {
                if let Act::F(_) = act {
                    bail!("graph: embedding expects i32 token ids");
                }
                let ids = self.i32_in(self.step.plan.x)?;
                for &id in &ids.data {
                    if id < 0 || id as usize >= pe.vocab {
                        bail!(
                            "{}: token id {id} out of range [0, {})",
                            self.step.man.name,
                            pe.vocab
                        );
                    }
                }
                let b = ids.data.len() / pe.seq;
                let tok = self.f32_in(pe.tok)?;
                let pos = self.f32_in(pe.pos)?;
                let mut y = self.ws.take_f32(ids.data.len() * pe.d);
                embed_fwd_into(&tok.data, &pos.data, &ids.data, pe.seq, pe.d, &mut y);
                caches.push(Cache::Embed);
                Act::F(Tensor { shape: self.ws.take_shape(&[b, pe.seq, pe.d]), data: y })
            }
            PlanLayer::Attn(pa) => {
                let x = act_f32(act)?;
                if x.shape.len() != 3 || x.shape[2] != pa.d {
                    let step = &self.step.man.name;
                    bail!("{step}: attention wants [B, T, {}], got {:?}", pa.d, x.shape);
                }
                let rows = x.data.len() / pa.d;
                let (b, t) = (x.shape[0], x.shape[1]);
                let (qy, q_lin) = self.lin_fwd_shared(&pa.proj[0], &x.data, rows)?;
                let (ky, k_lin) = self.lin_fwd_shared(&pa.proj[1], &x.data, rows)?;
                let (vy, v_lin) = self.lin_fwd_shared(&pa.proj[2], &x.data, rows)?;
                let dm = AttnDims { batch: b, t, d: pa.d, heads: pa.heads };
                let mut om = self.ws.take_f32(x.data.len());
                let mut p = self.ws.take_f32(b * pa.heads * t * t);
                let mut scores = self.ws.take_f32(t);
                sdpa_fwd_into(&qy, &ky, &vy, &dm, pa.causal, &mut om, &mut p, &mut scores);
                self.ws.give_f32(scores);
                let (out, o_lin) = self.lin_fwd_shared(&pa.proj[3], &om, rows)?;
                let Tensor { shape, data } = x;
                caches.push(Cache::Attn(AttnCache {
                    x: data,
                    om,
                    q_lin,
                    k_lin,
                    v_lin,
                    o_lin,
                    qy,
                    ky,
                    vy,
                    p,
                    dm,
                }));
                Act::F(Tensor { shape, data: out })
            }
            PlanLayer::Residual(inner) => {
                let x = act_f32(act)?;
                let step = self.step;
                let mut xc_data = self.ws.take_f32(x.data.len());
                xc_data.copy_from_slice(&x.data);
                let xc = Tensor { shape: self.ws.take_shape(&x.shape), data: xc_data };
                let mut sub = step.take_caches();
                let y = self.forward_seq(inner, Act::F(xc), &mut sub)?;
                let mut y = act_f32(y)?;
                if y.shape != x.shape {
                    bail!(
                        "{}: residual sub-graph changed shape {:?} -> {:?}",
                        step.man.name,
                        x.shape,
                        y.shape
                    );
                }
                for (yo, xi) in y.data.iter_mut().zip(&x.data) {
                    *yo += xi;
                }
                self.ws.give_tensor(x);
                caches.push(Cache::Residual(sub));
                Act::F(y)
            }
        })
    }

    // ---- frozen-prefix truncation -----------------------------------------

    /// Whether a site's weight-gradient selection is active at runtime.
    /// Only LWPN's `Flag(false)` counts as frozen: `All`/`Idx`/`None`
    /// sites keep their full backward (CWPL/CWPN gather rows and r=0
    /// still trains activation qparams through this site's `dsx`/`dzx`),
    /// so truncating below them would change computed gradients.
    fn plan_sel_active(&self, sel: &PlanSel) -> Result<bool> {
        Ok(match sel {
            PlanSel::Flag(pos) => self.i32_in(*pos)?.data[0] > 0,
            _ => true,
        })
    }

    /// Whether any weight site inside this (possibly nested) layer is
    /// active this step.  Granularity is the top-level plan layer: a
    /// residual block with one active projection runs its whole
    /// backward.
    fn layer_has_active_site(&self, plan: &PlanLayer) -> Result<bool> {
        Ok(match plan {
            PlanLayer::Linear(p) => self.plan_sel_active(&p.sel)?,
            PlanLayer::Conv(pc) => self.plan_sel_active(&pc.lin.sel)?,
            PlanLayer::Attn(pa) => {
                let mut any = false;
                for p in &pa.proj {
                    any |= self.plan_sel_active(&p.sel)?;
                }
                any
            }
            PlanLayer::Residual(inner) => {
                let mut any = false;
                for l in inner {
                    any |= self.layer_has_active_site(l)?;
                }
                any
            }
            _ => false,
        })
    }

    /// The first top-level layer index the backward must reach: the
    /// lowest layer holding any active weight site.  Everything below it
    /// is frozen prefix — dX propagation there feeds only zeroed (or
    /// absent) gradients, so `backward_seq_from` skips it outright.
    ///
    /// Returns 0 (full backward) on FP training (embeddings train, so
    /// the backward must reach the bottom), when the truncation is
    /// disabled, or — defensively — when no site is active at all.
    fn bwd_start(&self) -> Result<usize> {
        match self.step.id.kind {
            StepKind::Train(TrainSel::Fp) => return Ok(0),
            StepKind::Train(_) => {}
            _ => return Ok(0),
        }
        if !backward_truncation_enabled() {
            return Ok(0);
        }
        for (i, plan) in self.step.plan.layers.iter().enumerate() {
            if self.layer_has_active_site(plan)? {
                return Ok(i);
            }
        }
        Ok(0)
    }

    // ---- backward ---------------------------------------------------------

    fn backward_seq(
        &mut self,
        plans: &'p [PlanLayer],
        caches: &mut Vec<Cache>,
        dy: Tensor,
    ) -> Result<Tensor> {
        self.backward_seq_from(plans, caches, dy, 0)
    }

    /// Backward over `plans[start..]`; the frozen prefix `plans[..start]`
    /// skips the dX propagation entirely — each skipped layer recycles
    /// its cache and emits zero gradients of the manifest shapes
    /// ([`Run::skip_layer_backward`]).  Bit-identical to `start = 0` for
    /// every gradient still computed; the zeroed prefix gradients apply
    /// as no-op masked updates (the LWPN contract already zero-fills
    /// frozen `dW`, this extends it to the prefix's bias/norm/qparam
    /// slots).  When `start > 0` the returned tensor is the dX at layer
    /// `start`, not the model input gradient.
    fn backward_seq_from(
        &mut self,
        plans: &'p [PlanLayer],
        caches: &mut Vec<Cache>,
        mut dy: Tensor,
        start: usize,
    ) -> Result<Tensor> {
        debug_assert_eq!(plans.len(), caches.len());
        for plan in plans[start..].iter().rev() {
            let cache = caches.pop().ok_or_else(|| {
                anyhow!("{}: cache underflow in backward", self.step.man.name)
            })?;
            dy = self.backward_layer(plan, cache, dy)?;
        }
        for plan in plans[..start].iter().rev() {
            let cache = caches.pop().ok_or_else(|| {
                anyhow!("{}: cache underflow in backward", self.step.man.name)
            })?;
            self.skip_layer_backward(plan, cache)?;
        }
        Ok(dy)
    }

    fn conv_bwd(&mut self, pc: &PlanConv, c: ConvCache, dy: &[f32]) -> Result<Vec<f32>> {
        let step = self.step;
        let p = &pc.lin;
        let d = c.dims;
        let patch = d.patch();
        let mut dy2 = self.ws.take_f32(d.rows() * d.c_out);
        conv::nchw_to_rows_into(dy, &d, &mut dy2);
        let q = self.siteq(p)?;
        let w = self.f32_in(p.w)?;
        let wh: &[f32] = match &c.wh {
            Some(v) => v,
            None => &w.data,
        };
        let mut dcols = self.ws.take_f32(d.rows() * patch);
        matmul_dy_w_into(&dy2, wh, d.rows(), d.c_out, patch, &mut dcols);
        let mut dxh = self.ws.take_f32(d.batch * d.c_in * d.hw * d.hw);
        conv::col2im_into(&dcols, &d, &mut dxh);
        self.ws.give_f32(dcols);
        let sel = self.run_sel(p)?;
        let cols = &c.cols;
        let mut full = |ws: &mut Workspace| {
            let mut dw = ws.take_f32(d.c_out * patch);
            matmul_dyt_x_into(&dy2, cols, d.rows(), d.c_out, patch, &mut dw);
            dw
        };
        let mut partial = |ws: &mut Workspace, ids: &[usize]| {
            let mut dw = ws.take_f32(ids.len() * patch);
            partial_dw_into(&dy2, cols, ids, d.rows(), d.c_out, patch, &mut dw);
            dw
        };
        let (dw, dsw) = weight_site_grads(
            step.id.w_bits,
            &sel,
            w,
            q.as_ref(),
            patch,
            &mut *self.ws,
            &mut full,
            &mut partial,
        );
        if let RunSel::Idx(ids) = sel {
            self.ws.give_shape(ids);
        }
        self.ws.give_f32(dy2);
        self.emit_f32(p.dw, dw);
        self.emit_dsw(p.dsw, dsw);
        let dx = match &q {
            Some(sq) => {
                let mut dx = self.ws.take_f32(dxh.len());
                let (ds, dz) =
                    fq_act_bwd_tensor_into(&c.x_raw, sq.sx, sq.zx, &dxh, step.id.a_bits, &mut dx);
                self.ws.give_f32(dxh);
                let t = self.ws.scalar(ds);
                self.emit_f32(p.dsx, Some(t));
                let t = self.ws.scalar(dz);
                self.emit_f32(p.dzx, Some(t));
                dx
            }
            None => dxh,
        };
        self.ws.give_f32(c.x_raw);
        self.ws.give_f32(c.cols);
        if let Some(v) = c.wh {
            self.ws.give_f32(v);
        }
        Ok(dx)
    }

    fn backward_layer(
        &mut self,
        plan: &'p PlanLayer,
        cache: Cache,
        mut dy: Tensor,
    ) -> Result<Tensor> {
        match (plan, cache) {
            (PlanLayer::Flatten, Cache::Flatten { shape }) => {
                let Tensor { shape: dy_shape, data } = dy;
                self.ws.give_shape(dy_shape);
                Ok(Tensor { shape, data })
            }
            (PlanLayer::Linear(p), Cache::Linear { lin, x_raw, x_shape }) => {
                let dx = self.lin_bwd_core(p, lin, &dy.data, &x_raw, &x_raw)?;
                self.ws.give_f32(x_raw);
                self.ws.give_tensor(dy);
                Ok(Tensor { shape: x_shape, data: dx })
            }
            (PlanLayer::Conv(pc), Cache::Conv(c)) => {
                let d = c.dims;
                let dx = self.conv_bwd(pc, c, &dy.data)?;
                let Tensor { mut shape, data } = dy;
                self.ws.give_f32(data);
                shape[0] = d.batch;
                shape[1] = d.c_in;
                shape[2] = d.hw;
                shape[3] = d.hw;
                Ok(Tensor { shape, data: dx })
            }
            (PlanLayer::Relu, Cache::Relu { pre }) => {
                // gate in place on the cached pre-activation — no new buffer
                for (g, &h) in dy.data.iter_mut().zip(&pre) {
                    if h <= 0.0 {
                        *g = 0.0;
                    }
                }
                self.ws.give_f32(pre);
                Ok(dy)
            }
            (PlanLayer::Pool, Cache::Pool { b, c, hw }) => {
                let mut dx = self.ws.take_f32(b * c * hw * hw);
                conv::avgpool2_bwd_into(&dy.data, b, c, hw, &mut dx);
                let Tensor { mut shape, data } = dy;
                self.ws.give_f32(data);
                shape[2] = hw;
                shape[3] = hw;
                Ok(Tensor { shape, data: dx })
            }
            (PlanLayer::Norm(pn), Cache::Norm { xhat, inv, rows }) => {
                let g = self.f32_in(pn.g)?;
                let mut dx = self.ws.take_f32(dy.data.len());
                let mut dgamma = self.ws.take_f32(pn.d);
                let mut dbeta = self.ws.take_f32(pn.d);
                layernorm_bwd_into(
                    &dy.data, &xhat, &inv, &g.data, rows, pn.d, &mut dx, &mut dgamma, &mut dbeta,
                );
                self.ws.give_f32(xhat);
                self.ws.give_f32(inv);
                let t = self.ws.tensor(&[pn.d], dgamma);
                self.emit_f32(pn.dg, Some(t));
                let t = self.ws.tensor(&[pn.d], dbeta);
                self.emit_f32(pn.db, Some(t));
                let Tensor { shape, data } = dy;
                self.ws.give_f32(data);
                Ok(Tensor { shape, data: dx })
            }
            (PlanLayer::Embed(pe), Cache::Embed) => {
                // embeddings train during FP pretraining only (the
                // manifest declares no embed grads otherwise) — skip the
                // scatter-add entirely on quantized steps
                if pe.dtok.is_some() {
                    let ids = self.i32_in(self.step.plan.x)?;
                    let mut dtok = self.ws.take_f32(pe.vocab * pe.d);
                    let mut dpos = self.ws.take_f32(pe.seq * pe.d);
                    embed_bwd_into(&dy.data, &ids.data, pe.seq, pe.d, &mut dtok, &mut dpos);
                    let t = self.ws.tensor(&[pe.vocab, pe.d], dtok);
                    self.emit_f32(pe.dtok, Some(t));
                    let t = self.ws.tensor(&[pe.seq, pe.d], dpos);
                    self.emit_f32(pe.dpos, Some(t));
                }
                self.ws.give_tensor(dy);
                // the input is token ids — there is no dx
                Ok(Tensor { shape: self.ws.take_shape(&[0]), data: self.ws.take_f32(0) })
            }
            (PlanLayer::Attn(pa), Cache::Attn(ac)) => {
                let AttnCache { x, om, q_lin, k_lin, v_lin, o_lin, qy, ky, vy, p, dm } = ac;
                let dom = self.lin_bwd_core(&pa.proj[3], o_lin, &dy.data, &om, &om)?;
                let n = dy.data.len();
                let mut dq = self.ws.take_f32(n);
                let mut dk = self.ws.take_f32(n);
                let mut dv = self.ws.take_f32(n);
                let mut dp = self.ws.take_f32(dm.t);
                sdpa_bwd_into(&dom, &qy, &ky, &vy, &p, &dm, &mut dq, &mut dk, &mut dv, &mut dp);
                self.ws.give_f32(dom);
                self.ws.give_f32(dp);
                self.ws.give_f32(om);
                self.ws.give_f32(qy);
                self.ws.give_f32(ky);
                self.ws.give_f32(vy);
                self.ws.give_f32(p);
                let mut dxq = self.lin_bwd_core(&pa.proj[0], q_lin, &dq, &x, &x)?;
                self.ws.give_f32(dq);
                let dxk = self.lin_bwd_core(&pa.proj[1], k_lin, &dk, &x, &x)?;
                self.ws.give_f32(dk);
                let dxv = self.lin_bwd_core(&pa.proj[2], v_lin, &dv, &x, &x)?;
                self.ws.give_f32(dv);
                for ((a, b), c) in dxq.iter_mut().zip(&dxk).zip(&dxv) {
                    *a += b + c;
                }
                self.ws.give_f32(dxk);
                self.ws.give_f32(dxv);
                self.ws.give_f32(x);
                let Tensor { shape, data } = dy;
                self.ws.give_f32(data);
                Ok(Tensor { shape, data: dxq })
            }
            (PlanLayer::Residual(inner), Cache::Residual(mut sub)) => {
                let step = self.step;
                let mut dc_data = self.ws.take_f32(dy.data.len());
                dc_data.copy_from_slice(&dy.data);
                let dc = Tensor { shape: self.ws.take_shape(&dy.shape), data: dc_data };
                let dinner = self.backward_seq(inner, &mut sub, dc)?;
                step.give_caches(sub);
                if dinner.data.len() != dy.data.len() {
                    bail!("{}: residual backward shape mismatch", step.man.name);
                }
                for (a, b) in dy.data.iter_mut().zip(&dinner.data) {
                    *a += b;
                }
                self.ws.give_tensor(dinner);
                Ok(dy)
            }
            _ => bail!("{}: layer/cache mismatch in backward", self.step.man.name),
        }
    }

    /// One layer of the skipped frozen prefix: recycle the cache
    /// buffers exactly as [`Run::drop_caches`] would and emit zero
    /// gradients for every output slot the manifest declares — the ABI
    /// is selection-invariant, so skipped layers still owe full-shape
    /// values (`take_f32` zero-fills, making them the zero gradients of
    /// the masked-update contract).  No dX is computed anywhere in here;
    /// that is the saving.
    fn skip_layer_backward(&mut self, plan: &'p PlanLayer, cache: Cache) -> Result<()> {
        match (plan, cache) {
            (PlanLayer::Flatten, Cache::Flatten { shape }) => self.ws.give_shape(shape),
            (PlanLayer::Linear(p), Cache::Linear { lin, x_raw, x_shape }) => {
                self.skip_lin(p)?;
                self.give_lin(lin);
                self.ws.give_f32(x_raw);
                self.ws.give_shape(x_shape);
            }
            (PlanLayer::Conv(pc), Cache::Conv(c)) => {
                self.skip_lin(&pc.lin)?;
                self.ws.give_f32(c.x_raw);
                self.ws.give_f32(c.cols);
                if let Some(v) = c.wh {
                    self.ws.give_f32(v);
                }
            }
            (PlanLayer::Relu, Cache::Relu { pre }) => self.ws.give_f32(pre),
            // embeds only have grads on FP steps, which never truncate
            (PlanLayer::Pool, Cache::Pool { .. }) | (PlanLayer::Embed(_), Cache::Embed) => {}
            (PlanLayer::Norm(pn), Cache::Norm { xhat, inv, .. }) => {
                self.ws.give_f32(xhat);
                self.ws.give_f32(inv);
                let dg = self.ws.take_f32(pn.d);
                let t = self.ws.tensor(&[pn.d], dg);
                self.emit_f32(pn.dg, Some(t));
                let db = self.ws.take_f32(pn.d);
                let t = self.ws.tensor(&[pn.d], db);
                self.emit_f32(pn.db, Some(t));
            }
            (PlanLayer::Attn(pa), Cache::Attn(ac)) => {
                let AttnCache { x, om, q_lin, k_lin, v_lin, o_lin, qy, ky, vy, p, .. } = ac;
                for v in [x, om, qy, ky, vy, p] {
                    self.ws.give_f32(v);
                }
                for lin in [q_lin, k_lin, v_lin, o_lin] {
                    self.give_lin(lin);
                }
                for p in &pa.proj {
                    self.skip_lin(p)?;
                }
            }
            (PlanLayer::Residual(inner), Cache::Residual(mut sub)) => {
                // below the boundary no nested site is active either
                // (layer_has_active_site recursed), so skip the whole tree
                debug_assert_eq!(inner.len(), sub.len());
                for plan in inner.iter().rev() {
                    let cache = sub.pop().ok_or_else(|| {
                        anyhow!("{}: cache underflow in skipped backward", self.step.man.name)
                    })?;
                    self.skip_layer_backward(plan, cache)?;
                }
                self.step.give_caches(sub);
            }
            _ => bail!("{}: layer/cache mismatch in skipped backward", self.step.man.name),
        }
        Ok(())
    }

    /// Emit the zero gradients a skipped quantized-linear site still
    /// owes the manifest.  Every site below the truncation boundary
    /// resolved to `Flag(false)` (anything else counts as active in
    /// [`Run::bwd_start`]), so the declared `dW` slot — when present —
    /// carries the full weight shape, never gathered rows.
    fn skip_lin(&mut self, p: &PlanLin) -> Result<()> {
        debug_assert!(matches!(p.sel, PlanSel::Flag(_)) || (p.dw.is_none() && p.dsw.is_none()));
        if let Some(slot) = p.db {
            let db = self.ws.take_f32(p.c_out);
            let t = self.ws.tensor(&[p.c_out], db);
            self.emit_f32(Some(slot), Some(t));
        }
        if p.dw.is_some() {
            let w = self.f32_in(p.w)?;
            let shape = self.ws.take_shape(&w.shape);
            let data = self.ws.take_f32(w.data.len());
            self.emit_f32(p.dw, Some(Tensor { shape, data }));
        }
        if p.dsw.is_some() {
            let ds = self.ws.take_f32(p.c_out);
            self.emit_dsw(p.dsw, Some(ds));
        }
        if p.dsx.is_some() {
            let t = self.ws.scalar(0.0);
            self.emit_f32(p.dsx, Some(t));
        }
        if p.dzx.is_some() {
            let t = self.ws.scalar(0.0);
            self.emit_f32(p.dzx, Some(t));
        }
        Ok(())
    }

    /// Recycle a forward-only cache tree (fwd/calib steps, error paths).
    fn drop_caches(&mut self, caches: &mut Vec<Cache>) {
        while let Some(cache) = caches.pop() {
            match cache {
                Cache::Flatten { shape } => self.ws.give_shape(shape),
                Cache::Linear { lin, x_raw, x_shape } => {
                    self.give_lin(lin);
                    self.ws.give_f32(x_raw);
                    self.ws.give_shape(x_shape);
                }
                Cache::Conv(c) => {
                    self.ws.give_f32(c.x_raw);
                    self.ws.give_f32(c.cols);
                    if let Some(v) = c.wh {
                        self.ws.give_f32(v);
                    }
                }
                Cache::Relu { pre } => self.ws.give_f32(pre),
                Cache::Pool { .. } | Cache::Embed => {}
                Cache::Norm { xhat, inv, .. } => {
                    self.ws.give_f32(xhat);
                    self.ws.give_f32(inv);
                }
                Cache::Attn(ac) => {
                    let AttnCache { x, om, q_lin, k_lin, v_lin, o_lin, qy, ky, vy, p, .. } = ac;
                    for v in [x, om, qy, ky, vy, p] {
                        self.ws.give_f32(v);
                    }
                    for lin in [q_lin, k_lin, v_lin, o_lin] {
                        self.give_lin(lin);
                    }
                }
                Cache::Residual(mut sub) => {
                    self.drop_caches(&mut sub);
                    self.step.give_caches(sub);
                }
            }
        }
    }

    fn give_lin(&mut self, lin: LinCache) {
        if let Some(v) = lin.xh {
            self.ws.give_f32(v);
        }
        if let Some(v) = lin.wh {
            self.ws.give_f32(v);
        }
    }

    // ---- step kinds -------------------------------------------------------

    /// Mean softmax cross-entropy over the logits against the bound
    /// labels — shared by train and fwd steps so the metric convention
    /// cannot fork.  Returns `(loss, correct_rows, dlogits)`; `correct`
    /// is the raw correct-row count — examples for classifiers,
    /// *tokens* for LM graphs — matching what the AOT artifacts emit
    /// (python ce_loss_fwd reports token counts).  `dlogits` is pooled;
    /// give it back if unused.
    fn loss_and_correct(&mut self, logits: &Tensor) -> Result<(f32, usize, Vec<f32>)> {
        let step = self.step;
        let classes = step.graph.classes;
        let rows = logits.data.len() / classes;
        let y_idx = step.plan.y.ok_or_else(|| anyhow!("{}: plan has no labels", step.man.name))?;
        let labels = self.i32_in(y_idx)?;
        let mut dl = self.ws.take_f32(logits.data.len());
        let (loss, correct) = softmax_xent_into(&logits.data, &labels.data, rows, classes, &mut dl)
            .map_err(|e| anyhow!("{}: {e}", step.man.name))?;
        Ok((loss, correct, dl))
    }

    /// Emit the pooled `loss` / `correct` outputs.
    fn emit_metrics(&mut self, loss: f32, correct: usize) {
        let (loss_slot, correct_slot) = (self.step.plan.loss, self.step.plan.correct);
        let loss_t = self.ws.scalar(loss);
        self.emit(loss_slot, Value::F32(loss_t));
        let mut cdata = self.ws.take_i32(1);
        cdata[0] = correct as i32;
        let correct_t = self.ws.itensor(&[1], cdata);
        self.emit(correct_slot, Value::I32(correct_t));
    }

    fn run_train(&mut self) -> Result<()> {
        let step = self.step;
        let start = self.bwd_start()?;
        let mut caches = step.take_caches();
        let logits = self.forward(&mut caches)?;
        let (loss, correct, dl_data) = self.loss_and_correct(&logits)?;
        let Tensor { shape: dl_shape, data: logits_data } = logits;
        self.ws.give_f32(logits_data);
        let dl = Tensor { shape: dl_shape, data: dl_data };
        let dx = self.backward_seq_from(&step.plan.layers, &mut caches, dl, start)?;
        self.ws.give_tensor(dx);
        step.give_caches(caches);
        self.emit_metrics(loss, correct);
        Ok(())
    }

    fn run_fwd(&mut self) -> Result<()> {
        let step = self.step;
        let mut caches = step.take_caches();
        let logits = self.forward(&mut caches)?;
        self.drop_caches(&mut caches);
        step.give_caches(caches);
        let (loss, correct, dl) = self.loss_and_correct(&logits)?;
        self.ws.give_f32(dl);
        self.emit_metrics(loss, correct);
        self.emit(step.plan.logits, Value::F32(logits));
        Ok(())
    }

    fn run_calib(&mut self) -> Result<()> {
        self.taps = Some(BTreeMap::new());
        let step = self.step;
        let mut caches = step.take_caches();
        let logits = self.forward(&mut caches)?;
        self.ws.give_tensor(logits);
        self.drop_caches(&mut caches);
        step.give_caches(caches);
        let taps = self.taps.take().unwrap_or_default();
        // calib outputs are exactly the wsites, in order (build_manifest)
        debug_assert_eq!(step.man.outputs.len(), step.man.wsites.len());
        for (i, site) in step.man.wsites.iter().enumerate() {
            let (lo, hi) = taps.get(&site.name).copied().ok_or_else(|| {
                anyhow!("{}: calib tapped no data for site {:?}", step.man.name, site.name)
            })?;
            let mut data = self.ws.take_f32(2);
            data[0] = lo;
            data[1] = hi;
            let t = self.ws.tensor(&[2], data);
            self.emit(Some(i), Value::F32(t));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The mlp family as a graph — must match the manifests the seed
    /// native backend synthesized by hand.
    fn mlp_graph() -> LayerGraph {
        LayerGraph {
            model: "mlp".into(),
            batch: 16,
            input: InputKind::Image { channels: 3, hw: 8 },
            classes: 10,
            layers: vec![
                Layer::Flatten,
                Layer::Linear(LinearSpec { name: "fc1".into(), c_in: 192, c_out: 32, bias: true }),
                Layer::Relu,
                Layer::Linear(LinearSpec { name: "fc2".into(), c_in: 32, c_out: 10, bias: true }),
            ],
        }
    }

    fn tf_graph() -> LayerGraph {
        LayerGraph {
            model: "tiny_tf".into(),
            batch: 8,
            input: InputKind::Tokens { seq: 16 },
            classes: 64,
            layers: vec![
                Layer::Embed(EmbedSpec { name: "emb".into(), vocab: 64, seq: 16, d: 16 }),
                Layer::Residual(vec![
                    Layer::LayerNorm(NormSpec { name: "ln1".into(), d: 16 }),
                    Layer::Attention(AttnSpec {
                        name: "attn".into(),
                        d: 16,
                        heads: 2,
                        causal: true,
                    }),
                ]),
                Layer::Residual(vec![
                    Layer::LayerNorm(NormSpec { name: "ln2".into(), d: 16 }),
                    Layer::Linear(LinearSpec {
                        name: "ffn1".into(),
                        c_in: 16,
                        c_out: 32,
                        bias: true,
                    }),
                    Layer::Relu,
                    Layer::Linear(LinearSpec {
                        name: "ffn2".into(),
                        c_in: 32,
                        c_out: 16,
                        bias: true,
                    }),
                ]),
                Layer::LayerNorm(NormSpec { name: "lnf".into(), d: 16 }),
                Layer::Linear(LinearSpec { name: "head".into(), c_in: 16, c_out: 64, bias: true }),
            ],
        }
    }

    fn id(kind: StepKind, w: u32, a: u32) -> StepId {
        StepId { kind, w_bits: w, a_bits: a }
    }

    #[test]
    fn train_manifest_matches_step_contract() {
        let g = mlp_graph();
        let sel = id(StepKind::Train(TrainSel::Ratio(0.25)), 8, 8);
        let m = build_manifest(&g, "mlp_w8a8_train_r25", &sel);
        assert_eq!(m.sel_mode, "ratio");
        assert_eq!(m.ratio, 0.25);
        assert_eq!(m.wsites.len(), 2);
        // index slots sized by site_k
        let idx: Vec<&IoSpec> = m.inputs.iter().filter(|i| i.role == "index").collect();
        assert_eq!(idx.len(), 2);
        assert_eq!(idx[0].shape, vec![site_k(32, 0.25)]);
        assert_eq!(idx[1].shape, vec![site_k(10, 0.25)]);
        // gathered grad rows match the slots
        let dw: Vec<&IoSpec> = m
            .outputs
            .iter()
            .filter(|o| o.name.starts_with("d:fc") && o.name.ends_with(".w"))
            .collect();
        assert_eq!(dw[0].shape, vec![site_k(32, 0.25), 192]);
        assert_eq!(dw[1].shape, vec![site_k(10, 0.25), 32]);
    }

    #[test]
    fn r0_manifest_has_no_weight_grads_but_keeps_act_qparam_grads() {
        let sel = id(StepKind::Train(TrainSel::Ratio(0.0)), 8, 8);
        let m = build_manifest(&mlp_graph(), "mlp_w8a8_train_r0", &sel);
        assert!(!m.outputs.iter().any(|o| o.name == "d:fc1.w"));
        assert!(!m.outputs.iter().any(|o| o.name == "d:sw:fc1.w"));
        assert!(m.outputs.iter().any(|o| o.name == "d:sx:fc1.w"));
        assert!(m.outputs.iter().any(|o| o.name == "d:fc1.b"));
    }

    #[test]
    fn fp_manifest_has_no_qparams() {
        let sel = id(StepKind::Train(TrainSel::Fp), 0, 0);
        let m = build_manifest(&mlp_graph(), "mlp_fp_train", &sel);
        assert_eq!(m.sel_mode, "fp");
        assert!(!m.inputs.iter().any(|i| i.role.starts_with("qparam")));
        assert!(m.outputs.iter().any(|o| o.name == "d:fc1.w"));
        assert!(!m.outputs.iter().any(|o| o.name.starts_with("d:sw")));
    }

    #[test]
    fn calib_manifest_taps_every_site() {
        let m = build_manifest(&mlp_graph(), "mlp_calib", &id(StepKind::Calib, 0, 0));
        assert_eq!(m.kind, "calib");
        assert_eq!(m.outputs.len(), 2);
        assert!(m.outputs.iter().all(|o| o.role == "calib"));
        // calib binds x only (no labels)
        assert!(!m.inputs.iter().any(|i| i.name == "y"));
    }

    #[test]
    fn transformer_graph_enumerates_all_sites_and_params() {
        let g = tf_graph();
        let sites = g.wsites();
        let names: Vec<&str> = sites.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["attn.q.w", "attn.k.w", "attn.v.w", "attn.o.w", "ffn1.w", "ffn2.w", "head.w"]
        );
        let params = g.params();
        // 2 embeds + 3 LN pairs + 7 linears × (w, b)
        assert_eq!(params.len(), 2 + 6 + 14);
        assert!(params.iter().any(|p| p.name == "emb.pos" && p.kind == "embed"));
        // embeds get grads in FP training only
        let fp = build_manifest(&g, "tiny_tf_fp_train", &id(StepKind::Train(TrainSel::Fp), 0, 0));
        assert!(fp.outputs.iter().any(|o| o.name == "d:emb.tok"));
        let sel = id(StepKind::Train(TrainSel::Ratio(1.0)), 8, 8);
        let q = build_manifest(&g, "tiny_tf_w8a8_train_r100", &sel);
        assert!(!q.outputs.iter().any(|o| o.name == "d:emb.tok"));
        // norm params always train
        assert!(q.outputs.iter().any(|o| o.name == "d:ln1.g"));
        // LM data is token-shaped
        let x = q.inputs.iter().find(|i| i.name == "x").unwrap();
        assert_eq!((x.shape.clone(), x.dtype), (vec![8, 16], Dtype::I32));
        let logits_shape = build_manifest(&g, "tiny_tf_fp_fwd", &id(StepKind::Fwd, 0, 0))
            .outputs
            .iter()
            .find(|o| o.name == "logits")
            .unwrap()
            .shape
            .clone();
        assert_eq!(logits_shape, vec![8, 16, 64]);
    }

    #[test]
    fn lwpn_manifest_carries_flags_and_full_grad_shapes() {
        let g = tf_graph();
        let sel = id(StepKind::Train(TrainSel::Lwpn), 8, 8);
        let m = build_manifest(&g, "tiny_tf_w8a8_train_lwpn", &sel);
        assert_eq!(m.inputs.iter().filter(|i| i.role == "flag").count(), 7);
        let dw = m.outputs.iter().find(|o| o.name == "d:attn.q.w").unwrap();
        assert_eq!(dw.shape, vec![16, 16]);
    }
}
