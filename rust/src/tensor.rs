//! Minimal host tensor: shape + contiguous f32 (or i32) storage.
//!
//! The coordinator owns all training state (parameters, optimizer moments,
//! quantization parameters) host-side; the accelerator artifacts are pure
//! functions.  Only the handful of ops the coordinator itself needs live
//! here — row reductions for the importance metric (Eq. 6), Top-K for
//! channel selection, and elementwise update helpers for the optimizers.

use crate::error::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![1.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![1], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Leading dimension = output-channel count for weight tensors.
    pub fn rows(&self) -> usize {
        *self.shape.first().unwrap_or(&1)
    }

    /// Elements per output channel.
    pub fn row_size(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.data.len() / self.shape[0]
        }
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let rs = self.row_size();
        &self.data[r * rs..(r + 1) * rs]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let rs = self.row_size();
        &mut self.data[r * rs..(r + 1) * rs]
    }

    /// Gather whole rows by index into a new `[idx.len(), row_size]`
    /// tensor — the EfQAT "unfrozen rows" view of a weight site.
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        let rs = self.row_size();
        let mut data = Vec::with_capacity(idx.len() * rs);
        for &r in idx {
            data.extend_from_slice(self.row(r));
        }
        Tensor { shape: vec![idx.len(), rs], data }
    }

    /// Channel importance I_B = mean |w| per output row (paper Eq. 6).
    pub fn row_abs_mean(&self) -> Vec<f32> {
        let rs = self.row_size() as f32;
        (0..self.rows())
            .map(|r| self.row(r).iter().map(|x| x.abs()).sum::<f32>() / rs)
            .collect()
    }

    /// Per-row absolute maximum (symmetric weight-scale init, Eq. 4).
    pub fn row_abs_max(&self) -> Vec<f32> {
        (0..self.rows())
            .map(|r| self.row(r).iter().fold(0f32, |m, x| m.max(x.abs())))
            .collect()
    }

    pub fn min(&self) -> f32 {
        self.data.iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    pub fn sq_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum()
    }
}

/// Integer tensor (labels, token ids, channel indices, flags).
#[derive(Clone, Debug, PartialEq)]
pub struct ITensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl ITensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(ITensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        ITensor { shape: shape.to_vec(), data: vec![0; n] }
    }
}

/// Indices of the k largest values (descending).  Deterministic: ties break
/// toward the lower index, matching jnp.argsort stability assumptions.
pub fn topk(values: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..values.len()).collect();
    let k = k.min(values.len());
    idx.sort_by(|&a, &b| {
        values[b]
            .partial_cmp(&values[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// argmax over a slice (first max wins).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(ITensor::new(vec![2], vec![1]).is_err());
    }

    #[test]
    fn row_ops() {
        let t = Tensor::new(vec![2, 3], vec![1., -2., 3., -4., 5., -6.]).unwrap();
        assert_eq!(t.rows(), 2);
        assert_eq!(t.row_size(), 3);
        assert_eq!(t.row_abs_mean(), vec![2.0, 5.0]);
        assert_eq!(t.row_abs_max(), vec![3.0, 6.0]);
        assert_eq!(t.min(), -6.0);
        assert_eq!(t.max(), 5.0);
    }

    #[test]
    fn gather_rows_copies_whole_rows() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let g = t.gather_rows(&[2, 0]);
        assert_eq!(g.shape, vec![2, 2]);
        assert_eq!(g.data, vec![5., 6., 1., 2.]);
        assert_eq!(t.gather_rows(&[]).data, Vec::<f32>::new());
    }

    #[test]
    fn topk_orders_and_breaks_ties_low_index_first() {
        assert_eq!(topk(&[1.0, 5.0, 3.0, 5.0], 3), vec![1, 3, 2]);
        assert_eq!(topk(&[1.0], 5), vec![0]);
        assert_eq!(topk(&[], 2), Vec::<usize>::new());
    }

    #[test]
    fn argmax_first_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
    }
}
