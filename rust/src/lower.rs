//! Float-train → int8-serve lowering: compile a trained/calibrated
//! [`crate::graph::LayerGraph`] + its [`crate::model::QParamStore`] into
//! a [`QuantizedGraph`] of true integer kernels behind a *planned*
//! forward schedule.
//!
//! Training simulates quantization (fake-quant in f32, so gradients
//! exist); deployment should *execute* it.  [`lower`] freezes that
//! boundary:
//!
//! * every weight site is quantized **once** to per-channel `i8` codes
//!   (Eq. 3/4), with the per-channel code sums precomputed for the
//!   zero-point correction;
//! * activations are quantized to `u8` codes at each site boundary
//!   (Eq. 1/2) and the site's GEMM/conv runs `u8×i8→i32` with a
//!   per-channel `S_x·S_w[o]` rescale back to f32
//!   ([`crate::ops::qmatmul`], [`crate::ops::qconv`]);
//! * everything between sites (ReLU, pooling, LayerNorm, softmax
//!   attention core, residual adds, embeddings) stays f32 — exactly the
//!   arithmetic the fake-quant simulation trains against, so the lowered
//!   engine reproduces the float reference's logits to ≤ 1e-3 and its
//!   eval accuracy bit-for-bit (`tests/int8_parity.rs`);
//! * the layer tree is flattened into an [`ExecPlan`]: a straight-line
//!   op schedule (residual combinators become save/add-skip
//!   instructions) with every per-example shape inferred **at lowering
//!   time**, so a malformed graph fails in [`lower`] with a
//!   descriptive error — never at serve time — and the runtime walk
//!   does no shape bookkeeping at all.
//!
//! The executor is forward-only and *batch-flexible*: unlike the
//! training artifacts (whose manifests bake in a static batch), a
//! [`QuantizedGraph`] serves any leading batch dimension.  The hot
//! entry point is [`QuantizedGraph::forward_into`], which draws every
//! activation, code, and accumulator buffer from a caller-owned
//! [`Workspace`] — after one warmup batch a serving worker's steady
//! state performs **zero** heap allocations per request batch, and a
//! shrinking dynamic batch reuses the high-water buffers while a
//! growing one resizes exactly once (`rust/tests/workspace_alloc.rs`).
//! The borrowing [`QuantizedGraph::forward`] / consuming
//! [`QuantizedGraph::forward_owned`] wrappers keep the historical
//! allocate-per-call signatures for tests and cold paths.

#![warn(missing_docs)]

use crate::backend::Value;
use crate::error::{anyhow, bail, Result};
use crate::exec::Workspace;
use crate::graph::{attn_projections, InputKind, Layer, LayerGraph, LinearSpec};
use crate::model::{ParamStore, QParamStore};
use crate::ops::attention::{sdpa_fwd_into, AttnDims};
use crate::ops::conv::{avgpool2_fwd_into, ConvDims};
use crate::ops::elementwise::embed_fwd_into;
use crate::ops::norm::layernorm_fwd_into;
use crate::ops::qconv::qconv_fwd_into;
use crate::ops::qmatmul::{
    qlinear_fwd_into, qlinear_scratch_len, quantize_acts_into, quantize_weight_rows,
};
use crate::quant::qrange_asym;
use crate::tensor::Tensor;

/// i32 accumulation is exact for contractions up to
/// [`crate::ops::qmatmul::I32_EXACT_MAX_K`]; stay well inside it.  The
/// compile-time check below keeps this guard at least as strict as the
/// kernels' actual overflow bound, so serving can never reach the
/// overflowing regime (and `qlinear_fwd_into` debug-asserts the same).
const MAX_CONTRACTION: usize = 60_000;
const _: () = assert!(MAX_CONTRACTION <= crate::ops::qmatmul::I32_EXACT_MAX_K);

/// Deepest supported residual nesting.  Skip saves live in a fixed
/// on-stack array at run time (no per-forward allocation); every repro
/// model nests at most once.
const MAX_SKIP_DEPTH: usize = 4;

// ---------------------------------------------------------------------------
// Lowered sites and the planned schedule
// ---------------------------------------------------------------------------

/// One lowered quantized-linear site: weights frozen to i8 codes, the
/// activation quantizer's `(S_x, Z_x)` baked in, rescale per channel.
pub struct QLinearSite {
    /// Site name (`{layer}.w`), kept for diagnostics.
    pub name: String,
    c_in: usize,
    c_out: usize,
    qw: Vec<i8>,
    /// Per-channel `Σ_i qw[o,i]` — the zero-point correction term.
    wsum: Vec<i32>,
    /// Per-channel dequantization scale `S_x·S_w[o]`.
    scale: Vec<f32>,
    bias: Option<Vec<f32>>,
    sx: f32,
    /// Rounded activation zero-point code, validated into `[0, 2^a−1]`.
    zx: i32,
    a_bits: u32,
}

impl QLinearSite {
    /// Quantize the f32 input to codes and run the integer GEMM over
    /// workspace buffers.  `x` is `[rows, c_in]` flattened; returns the
    /// pooled `[rows, c_out]` output.
    fn fwd_ws(&self, x: &[f32], rows: usize, ws: &mut Workspace) -> Vec<f32> {
        let mut qx = ws.take_u8(rows * self.c_in);
        quantize_acts_into(x, self.sx, self.zx as f32, self.a_bits, &mut qx);
        let mut y = ws.take_f32(rows * self.c_out);
        let mut acc = ws.take_i32(qlinear_scratch_len(rows, self.c_in, self.c_out));
        qlinear_fwd_into(
            &qx,
            &self.qw,
            &self.wsum,
            self.zx,
            &self.scale,
            self.bias.as_deref(),
            rows,
            self.c_in,
            self.c_out,
            &mut y,
            &mut acc,
        );
        ws.give_u8(qx);
        ws.give_i32(acc);
        y
    }
}

/// Lowered LayerNorm parameters.
struct QNorm {
    g: Vec<f32>,
    b: Vec<f32>,
    d: usize,
}

/// Lowered embedding tables.
struct QEmbed {
    tok: Vec<f32>,
    pos: Vec<f32>,
    vocab: usize,
    seq: usize,
    d: usize,
}

/// One instruction of the flattened forward schedule.  All indices are
/// into the [`QuantizedGraph`]'s flat site/norm/embed tables; all
/// geometry is per-example and was inferred at lowering time — the
/// runtime multiplies by the dynamic batch and nothing else.
enum QOp {
    /// Pure reshape — contiguous data, nothing to do at run time.
    Flatten,
    /// Quantized linear site over `rows_per` rows per example.
    Linear { site: usize, rows_per: usize },
    /// Quantized conv2d site (`hw` = input spatial side).
    Conv { site: usize, c_in: usize, hw: usize, k: usize, stride: usize, pad: usize },
    /// In-place `max(x, 0)`.
    Relu,
    /// 2×2 average pool over `[B, c, hw, hw]`.
    AvgPool { c: usize, hw: usize },
    /// LayerNorm over `rows_per` rows per example.
    LayerNorm { norm: usize, rows_per: usize },
    /// Token + position embedding (always the first op of token graphs).
    Embed { embed: usize },
    /// Four projection sites around a scaled-dot-product core.
    Attention { proj: [usize; 4], heads: usize, causal: bool, t: usize, d: usize },
    /// Copy the current activation into skip slot `slot`.
    SaveSkip { slot: usize },
    /// Add skip slot `slot` back into the current activation.
    AddSkip { slot: usize },
}

/// The compiled straight-line schedule of a [`QuantizedGraph`] — what
/// the tentpole refactor calls the execution plan.  Owned by the graph;
/// exposed as a type so diagnostics can talk about it.
pub struct ExecPlan {
    ops: Vec<QOp>,
    /// Per-example logits element count (classes, or seq·classes).
    logits_per: usize,
}

impl ExecPlan {
    /// Number of instructions in the flattened schedule.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// Whether the schedule is empty (never true for a lowered model).
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

/// A lowered, forward-only integer inference graph with its compiled
/// execution plan.
///
/// All state is owned, immutable after [`lower`], and free of interior
/// mutability, so one graph is shared across serving worker threads as
/// a plain `Arc<QuantizedGraph>` — the compile-time proof is below.
pub struct QuantizedGraph {
    /// Name of the native model this graph was lowered from.
    pub model: String,
    /// Input domain (image geometry or token sequence length).
    pub input: InputKind,
    /// Trailing logits dimension (classes or vocab).
    pub classes: usize,
    /// Weight-grid width the i8 codes were quantized on (Eq. 3/4).
    pub w_bits: u32,
    /// Activation-grid width the u8 codes are quantized on (Eq. 1/2).
    pub a_bits: u32,
    sites: Vec<QLinearSite>,
    norms: Vec<QNorm>,
    embeds: Vec<QEmbed>,
    plan: ExecPlan,
}

// The serving runtime (`crate::serve`) pools `std::thread` workers over
// one `Arc<QuantizedGraph>`; keep the graph shareable by construction.
// This fails to compile if a future field introduces `Rc`/`RefCell`/raw
// pointers instead of failing at the distant `Server::start` call site.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<QuantizedGraph>();
};

// ---------------------------------------------------------------------------
// The lowering pass
// ---------------------------------------------------------------------------

/// Lower a graph + calibrated qparams to an int8 inference engine and
/// compile its execution plan.  Fails with a descriptive error on
/// missing/invalid qparams, widths the i8/u8 code domain cannot hold,
/// contractions too large for exact i32 accumulation, or a graph whose
/// shapes do not chain — never at serve time.
pub fn lower(
    g: &LayerGraph,
    params: &ParamStore,
    qparams: &QParamStore,
    w_bits: u32,
    a_bits: u32,
) -> Result<QuantizedGraph> {
    if !(2..=8).contains(&w_bits) || !(2..=8).contains(&a_bits) {
        bail!(
            "lower({}): w{w_bits}a{a_bits} does not fit the i8/u8 code domain \
             (the int8 engine serves 2..=8-bit grids)",
            g.model
        );
    }
    let cx = LowerCtx { model: &g.model, params, qparams, w_bits, a_bits };
    let mut b = Builder::default();
    let entry = match g.input {
        InputKind::Image { channels, hw } => Dims::Chw { c: channels, hw },
        InputKind::Tokens { seq } => Dims::Tokens { t: seq },
    };
    let exit = cx.lower_seq(&g.layers, entry, 0, &mut b)?;
    let logits_per = match (g.input, exit) {
        (InputKind::Image { .. }, Dims::Flat { n }) if n == g.classes => g.classes,
        (InputKind::Tokens { seq }, Dims::Seq { t, d }) if t == seq && d == g.classes => {
            seq * g.classes
        }
        (_, exit) => bail!(
            "lower({}): graph ends in {exit:?}, but the model declares {} logit classes",
            g.model,
            g.classes
        ),
    };
    Ok(QuantizedGraph {
        model: g.model.clone(),
        input: g.input,
        classes: g.classes,
        w_bits,
        a_bits,
        sites: b.sites,
        norms: b.norms,
        embeds: b.embeds,
        plan: ExecPlan { ops: b.ops, logits_per },
    })
}

/// Convenience: lower a named native model
/// ([`crate::backend::native::NATIVE_MODELS`]).
pub fn lower_native(
    model: &str,
    params: &ParamStore,
    qparams: &QParamStore,
    w_bits: u32,
    a_bits: u32,
) -> Result<QuantizedGraph> {
    let g = crate::backend::native::model_graph(model).ok_or_else(|| {
        anyhow!(
            "model {model:?} has no native graph declaration — the int8 engine lowers \
             native models only (the PJRT artifacts serve through XLA)"
        )
    })?;
    lower(&g, params, qparams, w_bits, a_bits)
}

/// Per-example activation geometry tracked by the lowering-time shape
/// inference.  The batch dimension is symbolic — everything here is
/// multiplied by the dynamic batch at run time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Dims {
    /// f32 feature maps `[c, hw, hw]`.
    Chw { c: usize, hw: usize },
    /// Flattened f32 features `[n]`.
    Flat { n: usize },
    /// f32 sequence activations `[t, d]`.
    Seq { t: usize, d: usize },
    /// i32 token ids `[t]` (before the embedding).
    Tokens { t: usize },
}

#[derive(Default)]
struct Builder {
    sites: Vec<QLinearSite>,
    norms: Vec<QNorm>,
    embeds: Vec<QEmbed>,
    ops: Vec<QOp>,
}

struct LowerCtx<'a> {
    model: &'a str,
    params: &'a ParamStore,
    qparams: &'a QParamStore,
    w_bits: u32,
    a_bits: u32,
}

impl LowerCtx<'_> {
    fn lower_seq(
        &self,
        layers: &[Layer],
        mut dims: Dims,
        depth: usize,
        b: &mut Builder,
    ) -> Result<Dims> {
        for layer in layers {
            dims = self.lower_layer(layer, dims, depth, b)?;
        }
        Ok(dims)
    }

    fn lower_layer(
        &self,
        layer: &Layer,
        dims: Dims,
        depth: usize,
        b: &mut Builder,
    ) -> Result<Dims> {
        let m = self.model;
        Ok(match layer {
            Layer::Flatten => {
                let n = match dims {
                    Dims::Chw { c, hw } => c * hw * hw,
                    Dims::Flat { n } => n,
                    Dims::Seq { t, d } => t * d,
                    Dims::Tokens { .. } => {
                        bail!("lower({m}): flatten over token ids (embed first)")
                    }
                };
                b.ops.push(QOp::Flatten);
                Dims::Flat { n }
            }
            Layer::Linear(spec) => {
                let (rows_per, out) = match dims {
                    Dims::Flat { n } if n == spec.c_in => (1, Dims::Flat { n: spec.c_out }),
                    Dims::Seq { t, d } if d == spec.c_in => (t, Dims::Seq { t, d: spec.c_out }),
                    other => bail!(
                        "lower({m}): linear {:?} wants {} input features, activation is {other:?}",
                        spec.name,
                        spec.c_in
                    ),
                };
                let site = b.push_site(self.lower_site(spec)?);
                b.ops.push(QOp::Linear { site, rows_per });
                out
            }
            Layer::Conv2d(spec) => {
                let hw = match dims {
                    Dims::Chw { c, hw } if c == spec.c_in => hw,
                    other => bail!(
                        "lower({m}): conv {:?} wants [B, {}, H, H], activation is {other:?}",
                        spec.name,
                        spec.c_in
                    ),
                };
                let patch = spec.c_in * spec.k * spec.k;
                let wname = format!("{}.w", spec.name);
                let site = self.lower_raw_site(&wname, patch, spec.c_out, None)?;
                let d = ConvDims {
                    batch: 1,
                    c_in: spec.c_in,
                    hw,
                    c_out: spec.c_out,
                    k: spec.k,
                    stride: spec.stride,
                    pad: spec.pad,
                };
                if d.hw_out() == 0 {
                    bail!("lower({m}): conv {:?} produces an empty output", spec.name);
                }
                let site = b.push_site(site);
                b.ops.push(QOp::Conv {
                    site,
                    c_in: spec.c_in,
                    hw,
                    k: spec.k,
                    stride: spec.stride,
                    pad: spec.pad,
                });
                Dims::Chw { c: spec.c_out, hw: d.hw_out() }
            }
            Layer::Relu => {
                if matches!(dims, Dims::Tokens { .. }) {
                    bail!("lower({m}): relu over token ids");
                }
                b.ops.push(QOp::Relu);
                dims
            }
            Layer::AvgPool2x2 => {
                let (c, hw) = match dims {
                    Dims::Chw { c, hw } if hw % 2 == 0 => (c, hw),
                    other => bail!("lower({m}): avgpool wants [B, C, 2n, 2n], got {other:?}"),
                };
                b.ops.push(QOp::AvgPool { c, hw });
                Dims::Chw { c, hw: hw / 2 }
            }
            Layer::LayerNorm(spec) => {
                let rows_per = match dims {
                    Dims::Flat { n } if n == spec.d => 1,
                    Dims::Seq { t, d } if d == spec.d => t,
                    other => bail!(
                        "lower({m}): layernorm {:?} wants {} features, got {other:?}",
                        spec.name,
                        spec.d
                    ),
                };
                let norm = b.norms.len();
                b.norms.push(QNorm {
                    g: self.param(&format!("{}.g", spec.name), spec.d)?,
                    b: self.param(&format!("{}.b", spec.name), spec.d)?,
                    d: spec.d,
                });
                b.ops.push(QOp::LayerNorm { norm, rows_per });
                dims
            }
            Layer::Embed(spec) => {
                match dims {
                    Dims::Tokens { t } if t == spec.seq => {}
                    other => bail!(
                        "lower({m}): embedding {:?} wants [B, {}] token ids, got {other:?}",
                        spec.name,
                        spec.seq
                    ),
                }
                let embed = b.embeds.len();
                b.embeds.push(QEmbed {
                    tok: self.param(&format!("{}.tok", spec.name), spec.vocab * spec.d)?,
                    pos: self.param(&format!("{}.pos", spec.name), spec.seq * spec.d)?,
                    vocab: spec.vocab,
                    seq: spec.seq,
                    d: spec.d,
                });
                b.ops.push(QOp::Embed { embed });
                Dims::Seq { t: spec.seq, d: spec.d }
            }
            Layer::Attention(spec) => {
                let t = match dims {
                    Dims::Seq { t, d } if d == spec.d => t,
                    other => bail!(
                        "lower({m}): attention {:?} wants [B, T, {}], got {other:?}",
                        spec.name,
                        spec.d
                    ),
                };
                if spec.heads == 0 || spec.d % spec.heads != 0 {
                    bail!(
                        "lower({m}): attention {:?} width {} not divisible by {} heads",
                        spec.name,
                        spec.d,
                        spec.heads
                    );
                }
                let projs = attn_projections(spec);
                let mut ids = [0usize; 4];
                for (i, p) in projs.iter().enumerate() {
                    ids[i] = b.push_site(self.lower_site(p)?);
                }
                b.ops.push(QOp::Attention {
                    proj: ids,
                    heads: spec.heads,
                    causal: spec.causal,
                    t,
                    d: spec.d,
                });
                dims
            }
            Layer::Residual(inner) => {
                if matches!(dims, Dims::Tokens { .. }) {
                    bail!("lower({m}): residual over token ids");
                }
                if depth >= MAX_SKIP_DEPTH {
                    bail!("lower({m}): residual nesting deeper than {MAX_SKIP_DEPTH}");
                }
                b.ops.push(QOp::SaveSkip { slot: depth });
                let exit = self.lower_seq(inner, dims, depth + 1, b)?;
                if exit != dims {
                    bail!("lower({m}): residual sub-graph changed shape {dims:?} -> {exit:?}");
                }
                b.ops.push(QOp::AddSkip { slot: depth });
                dims
            }
        })
    }

    fn param(&self, name: &str, want: usize) -> Result<Vec<f32>> {
        let t = self.params.get(name)?;
        if t.data.len() != want {
            let got = t.data.len();
            bail!("lower({}): param {name:?} has {got} elems, graph wants {want}", self.model);
        }
        Ok(t.data.clone())
    }

    fn lower_site(&self, spec: &LinearSpec) -> Result<QLinearSite> {
        let bias = if spec.bias {
            Some(self.param(&format!("{}.b", spec.name), spec.c_out)?)
        } else {
            None
        };
        self.lower_raw_site(&format!("{}.w", spec.name), spec.c_in, spec.c_out, bias)
    }

    /// Quantize one weight site's rows to i8 once and bake its activation
    /// quantizer in — shared by linear, conv (rows are im2col patches),
    /// and the four attention projections.
    fn lower_raw_site(
        &self,
        site: &str,
        row_size: usize,
        c_out: usize,
        bias: Option<Vec<f32>>,
    ) -> Result<QLinearSite> {
        if row_size > MAX_CONTRACTION {
            bail!(
                "lower({}): site {site:?} contracts over {row_size} elements — too large \
                 for exact i32 accumulation (max {MAX_CONTRACTION})",
                self.model
            );
        }
        let w = self.params.get(site)?;
        if w.data.len() != c_out * row_size {
            let (m, got) = (self.model, w.data.len());
            bail!("lower({m}): weight {site:?} has {got} elems, want {c_out}×{row_size}");
        }
        let sw = self.qparams.sw.get(site).ok_or_else(|| {
            anyhow!(
                "lower({}): no weight scales for site {site:?} — calibrate or load a \
                 quantized checkpoint",
                self.model
            )
        })?;
        if sw.data.len() != c_out {
            let got = sw.data.len();
            bail!("lower({}): site {site:?} has {got} weight scales, want {c_out}", self.model);
        }
        if sw.data.iter().any(|&s| s <= 0.0 || !s.is_finite()) {
            bail!("lower({}): non-positive weight scale for site {site:?}", self.model);
        }
        let act = self.qparams.act.get(site).ok_or_else(|| {
            anyhow!("lower({}): no activation qparams for site {site:?}", self.model)
        })?;
        if act.scale <= 0.0 || !act.scale.is_finite() {
            bail!("lower({}): non-positive activation scale for site {site:?}", self.model);
        }
        let (_, qmax) = qrange_asym(self.a_bits);
        let zx = act.zero_point.round();
        if !(0.0..=qmax as f32).contains(&zx) {
            bail!(
                "lower({}): site {site:?} zero point {zx} escapes [0, {qmax}] — the float \
                 reference pads with an exact zero code the u8 grid cannot represent",
                self.model
            );
        }
        let (qw, wsum) = quantize_weight_rows(&w.data, &sw.data, row_size, self.w_bits);
        let scale: Vec<f32> = sw.data.iter().map(|&s| s * act.scale).collect();
        Ok(QLinearSite {
            name: site.to_string(),
            c_in: row_size,
            c_out,
            qw,
            wsum,
            scale,
            bias,
            sx: act.scale,
            zx: zx as i32,
            a_bits: self.a_bits,
        })
    }
}

impl Builder {
    fn push_site(&mut self, site: QLinearSite) -> usize {
        self.sites.push(site);
        self.sites.len() - 1
    }
}

// ---------------------------------------------------------------------------
// Planned forward execution
// ---------------------------------------------------------------------------

impl QuantizedGraph {
    /// The compiled execution plan (diagnostics / tests).
    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }

    /// Vocabulary size of a token-input graph (`None` for image
    /// models).  The serving runtime validates ids against this at
    /// submission time, so one bad request cannot fail the healthy
    /// requests micro-batched with it.
    pub fn vocab(&self) -> Option<usize> {
        self.embeds.first().map(|e| e.vocab)
    }

    /// Count of frozen i8 weight codes — what a deployment would ship.
    pub fn quantized_weights(&self) -> usize {
        self.sites.iter().map(|s| s.qw.len()).sum()
    }

    /// One-line deployment summary for serve/eval logs: model, bit
    /// grid, input domain, logits width, shipped i8 weight count.
    pub fn describe(&self) -> String {
        let input = match self.input {
            InputKind::Image { channels, hw } => format!("image [{channels}, {hw}, {hw}]"),
            InputKind::Tokens { seq } => format!("tokens [{seq}]"),
        };
        format!(
            "{} w{}a{} {input} -> {} classes, {} i8 weights",
            self.model,
            self.w_bits,
            self.a_bits,
            self.classes,
            self.quantized_weights()
        )
    }

    /// Logits shape for a batch of `b` examples.
    pub fn logits_dims(&self, b: usize) -> Vec<usize> {
        match self.input {
            InputKind::Image { .. } => vec![b, self.classes],
            InputKind::Tokens { seq } => vec![b, seq, self.classes],
        }
    }

    /// Batched forward to logits — borrowing wrapper over
    /// [`Self::forward_into`] with a throwaway workspace (cold paths
    /// and tests; the serving workers reuse a per-worker workspace).
    pub fn forward(&self, x: &Value) -> Result<Tensor> {
        let mut ws = Workspace::new();
        let data = self.forward_into(x, &mut ws)?;
        let b = x.shape().first().copied().unwrap_or(0);
        Ok(Tensor { shape: self.logits_dims(b), data })
    }

    /// Consuming wrapper over [`Self::forward_into`] — kept for callers
    /// that hand the batch tensor off (e.g.
    /// [`crate::coordinator::eval::evaluate_int8`]'s historical entry).
    pub fn forward_owned(&self, x: Value) -> Result<Tensor> {
        self.forward(&x)
    }

    /// Walk the compiled plan over a batch, drawing every buffer from
    /// `ws`.  `x` is f32 images `[B, C, H, H]` or i32 token ids
    /// `[B, T]` per the graph's [`InputKind`]; any batch size is
    /// accepted (serving is not bound to the training batch).  Returns
    /// the pooled logits data (`b ·` per-example logits, layout per
    /// [`Self::logits_dims`]); give it back to `ws` when done.  After
    /// warmup this path performs zero heap allocations.
    pub fn forward_into(&self, x: &Value, ws: &mut Workspace) -> Result<Vec<f32>> {
        let (b, ids): (usize, &[i32]) = match (self.input, x) {
            (InputKind::Image { channels, hw }, Value::F32(t)) => {
                let good = t.shape.len() == 4
                    && t.shape[1] == channels
                    && t.shape[2] == hw
                    && t.shape[3] == hw;
                if !good {
                    bail!(
                        "{} int8 forward: want images [B, {channels}, {hw}, {hw}], got {:?}",
                        self.model,
                        t.shape
                    );
                }
                (t.shape[0], &[])
            }
            (InputKind::Tokens { seq }, Value::I32(t)) => {
                if t.shape.len() != 2 || t.shape[1] != seq {
                    let m = &self.model;
                    bail!("{m} int8 forward: want token ids [B, {seq}], got {:?}", t.shape);
                }
                (t.shape[0], &t.data[..])
            }
            _ => bail!(
                "{} int8 forward: input dtype does not match the graph's input kind",
                self.model
            ),
        };

        // current activation: image graphs start from a pooled copy of
        // the input (one memcpy — the integer kernels quantize from it
        // in place), token graphs start empty until the embedding op
        let mut cur: Vec<f32> = match x {
            Value::F32(t) => {
                let mut c = ws.take_f32(t.data.len());
                c.copy_from_slice(&t.data);
                c
            }
            Value::I32(_) => Vec::new(),
        };
        let mut skips: [Option<Vec<f32>>; MAX_SKIP_DEPTH] = Default::default();

        for op in &self.plan.ops {
            match op {
                QOp::Flatten => {}
                QOp::Linear { site, rows_per } => {
                    let site = &self.sites[*site];
                    let y = site.fwd_ws(&cur, b * rows_per, ws);
                    ws.give_f32(std::mem::replace(&mut cur, y));
                }
                QOp::Conv { site, c_in, hw, k, stride, pad } => {
                    let site = &self.sites[*site];
                    let d = ConvDims {
                        batch: b,
                        c_in: *c_in,
                        hw: *hw,
                        c_out: site.c_out,
                        k: *k,
                        stride: *stride,
                        pad: *pad,
                    };
                    let mut qx = ws.take_u8(cur.len());
                    quantize_acts_into(&cur, site.sx, site.zx as f32, site.a_bits, &mut qx);
                    let mut cols = ws.take_u8(d.rows() * d.patch());
                    let mut y2 = ws.take_f32(d.rows() * d.c_out);
                    let mut acc = ws.take_i32(qlinear_scratch_len(d.rows(), d.patch(), d.c_out));
                    let mut y = ws.take_f32(d.rows() * d.c_out);
                    qconv_fwd_into(
                        &qx,
                        &site.qw,
                        &site.wsum,
                        site.zx,
                        &site.scale,
                        &d,
                        &mut y,
                        &mut cols,
                        &mut y2,
                        &mut acc,
                    );
                    ws.give_u8(qx);
                    ws.give_u8(cols);
                    ws.give_f32(y2);
                    ws.give_i32(acc);
                    ws.give_f32(std::mem::replace(&mut cur, y));
                }
                QOp::Relu => {
                    for v in cur.iter_mut() {
                        *v = v.max(0.0);
                    }
                }
                QOp::AvgPool { c, hw } => {
                    let ho = hw / 2;
                    let mut y = ws.take_f32(b * c * ho * ho);
                    avgpool2_fwd_into(&cur, b, *c, *hw, &mut y);
                    ws.give_f32(std::mem::replace(&mut cur, y));
                }
                QOp::LayerNorm { norm, rows_per } => {
                    let n = &self.norms[*norm];
                    let rows = b * rows_per;
                    let mut y = ws.take_f32(rows * n.d);
                    let mut xhat = ws.take_f32(rows * n.d);
                    let mut inv = ws.take_f32(rows);
                    layernorm_fwd_into(&cur, &n.g, &n.b, rows, n.d, &mut y, &mut xhat, &mut inv);
                    ws.give_f32(xhat);
                    ws.give_f32(inv);
                    ws.give_f32(std::mem::replace(&mut cur, y));
                }
                QOp::Embed { embed } => {
                    let e = &self.embeds[*embed];
                    for &id in ids {
                        if id < 0 || id as usize >= e.vocab {
                            let (m, v) = (&self.model, e.vocab);
                            bail!("{m} int8 forward: token id {id} out of range [0, {v})");
                        }
                    }
                    let mut y = ws.take_f32(ids.len() * e.d);
                    embed_fwd_into(&e.tok, &e.pos, ids, e.seq, e.d, &mut y);
                    ws.give_f32(std::mem::replace(&mut cur, y));
                }
                QOp::Attention { proj, heads, causal, t, d } => {
                    let rows = b * t;
                    let qy = self.sites[proj[0]].fwd_ws(&cur, rows, ws);
                    let ky = self.sites[proj[1]].fwd_ws(&cur, rows, ws);
                    let vy = self.sites[proj[2]].fwd_ws(&cur, rows, ws);
                    let dm = AttnDims { batch: b, t: *t, d: *d, heads: *heads };
                    let mut om = ws.take_f32(rows * d);
                    let mut p = ws.take_f32(b * heads * t * t);
                    let mut scores = ws.take_f32(*t);
                    sdpa_fwd_into(&qy, &ky, &vy, &dm, *causal, &mut om, &mut p, &mut scores);
                    ws.give_f32(qy);
                    ws.give_f32(ky);
                    ws.give_f32(vy);
                    ws.give_f32(p);
                    ws.give_f32(scores);
                    let out = self.sites[proj[3]].fwd_ws(&om, rows, ws);
                    ws.give_f32(om);
                    ws.give_f32(std::mem::replace(&mut cur, out));
                }
                QOp::SaveSkip { slot } => {
                    let mut skip = ws.take_f32(cur.len());
                    skip.copy_from_slice(&cur);
                    skips[*slot] = Some(skip);
                }
                QOp::AddSkip { slot } => {
                    let skip = skips[*slot].take().expect("plan: AddSkip without SaveSkip");
                    for (c, s) in cur.iter_mut().zip(&skip) {
                        *c += s;
                    }
                    ws.give_f32(skip);
                }
            }
        }
        debug_assert_eq!(cur.len(), b * self.plan.logits_per);
        Ok(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ActQParams;
    use crate::tensor::ITensor;

    fn fixture(model: &str) -> (LayerGraph, ParamStore, QParamStore) {
        crate::testing::synth_lowering_fixture(model)
    }

    #[test]
    fn lowers_every_native_model() {
        for model in ["mlp", "mlp_wide", "convnet", "tiny_tf"] {
            let (g, params, q) = fixture(model);
            let qg = lower(&g, &params, &q, 8, 8).unwrap_or_else(|e| panic!("{model}: {e}"));
            assert!(qg.quantized_weights() > 0, "{model}");
            assert_eq!(qg.classes, g.classes);
            assert!(!qg.plan().is_empty(), "{model}: empty plan");
        }
    }

    #[test]
    fn rejects_wide_grids_and_missing_qparams() {
        let (g, params, q) = fixture("mlp");
        let err = lower(&g, &params, &q, 16, 8).unwrap_err().to_string();
        assert!(err.contains("i8/u8 code domain"), "{err}");
        let err = lower(&g, &params, &QParamStore::default(), 8, 8).unwrap_err().to_string();
        assert!(err.contains("weight scales"), "{err}");
    }

    #[test]
    fn absurd_contraction_rejected_at_lowering_not_serve() {
        // a contraction beyond MAX_CONTRACTION would overflow i32 lanes
        // at serve time; lower() must refuse it up front (before even
        // touching weights — the guard is purely geometric)
        let k = MAX_CONTRACTION + 1;
        let g = LayerGraph {
            model: "absurd".into(),
            batch: 1,
            input: InputKind::Image { channels: k, hw: 1 },
            classes: 2,
            layers: vec![
                Layer::Flatten,
                Layer::Linear(LinearSpec { name: "fc".into(), c_in: k, c_out: 2, bias: false }),
            ],
        };
        let params = ParamStore { map: Default::default() };
        let err = lower(&g, &params, &QParamStore::default(), 8, 8).unwrap_err().to_string();
        assert!(err.contains("too large"), "{err}");
    }

    #[test]
    fn rejects_out_of_range_zero_point() {
        let (g, params, mut q) = fixture("mlp");
        q.act.insert("fc1.w".into(), ActQParams { scale: 0.05, zero_point: 300.0 });
        let err = lower(&g, &params, &q, 8, 8).unwrap_err().to_string();
        assert!(err.contains("zero point"), "{err}");
    }

    #[test]
    fn shape_inference_rejects_inconsistent_graphs_at_lowering() {
        // a linear whose c_in does not chain fails in lower(), not at
        // serve time — the planned executor assumes shapes are proven
        let (g, params, q) = fixture("mlp");
        let mut bad = g.clone();
        if let Layer::Linear(spec) = &mut bad.layers[1] {
            spec.c_in = 7;
        }
        let err = lower(&bad, &params, &q, 8, 8).unwrap_err().to_string();
        assert!(err.contains("input features"), "{err}");
    }

    #[test]
    fn forward_accepts_any_batch_size() {
        let (g, params, q) = fixture("mlp");
        let qg = lower(&g, &params, &q, 8, 8).unwrap();
        for b in [1usize, 3, 32] {
            let x = Value::F32(Tensor::zeros(&[b, 3, 8, 8]));
            let y = qg.forward(&x).unwrap();
            assert_eq!(y.shape, vec![b, 10]);
        }
        // wrong geometry is a descriptive error
        let err = qg.forward(&Value::F32(Tensor::zeros(&[2, 3, 16, 16]))).unwrap_err().to_string();
        assert!(err.contains("images"), "{err}");
    }

    #[test]
    fn forward_into_reuses_one_workspace_bit_identically() {
        // grow, shrink, regrow: recycled buffers must never change the
        // logits vs a fresh-allocation forward
        for model in ["mlp", "convnet", "tiny_tf"] {
            let (g, params, q) = fixture(model);
            let qg = lower(&g, &params, &q, 8, 8).unwrap();
            let mut ws = Workspace::new();
            for (i, b) in [2usize, 5, 1, 5, 3].into_iter().enumerate() {
                let x = match g.input {
                    InputKind::Image { channels, hw } => {
                        let mut rng = crate::rng::Pcg64::new(90 + i as u64);
                        Value::F32(Tensor {
                            shape: vec![b, channels, hw, hw],
                            data: rng.normal_vec(b * channels * hw * hw, 1.0),
                        })
                    }
                    InputKind::Tokens { seq } => {
                        let data: Vec<i32> =
                            (0..b * seq).map(|j| (j as i32 * 7 + i as i32) % 64).collect();
                        Value::I32(ITensor { shape: vec![b, seq], data })
                    }
                };
                let got = qg.forward_into(&x, &mut ws).unwrap();
                let want = qg.forward(&x).unwrap();
                assert_eq!(got, want.data, "{model} b={b} iter {i}");
                ws.give_f32(got);
            }
        }
    }

    #[test]
    fn vocab_reported_for_token_graphs_only() {
        let (g, params, q) = fixture("tiny_tf");
        let qg = lower(&g, &params, &q, 8, 8).unwrap();
        assert_eq!(qg.vocab(), Some(64));
        let (g, params, q) = fixture("mlp");
        assert_eq!(lower(&g, &params, &q, 8, 8).unwrap().vocab(), None);
    }

    #[test]
    fn token_graph_validates_ids_and_seq() {
        let (g, params, q) = fixture("tiny_tf");
        let qg = lower(&g, &params, &q, 8, 8).unwrap();
        let y = qg.forward(&Value::I32(ITensor::zeros(&[2, 16]))).unwrap();
        assert_eq!(y.shape, vec![2, 16, 64]);
        let err = qg.forward(&Value::I32(ITensor::zeros(&[2, 8]))).unwrap_err().to_string();
        assert!(err.contains("token ids"), "{err}");
        let bad = ITensor { shape: vec![1, 16], data: vec![99; 16] };
        let err = qg.forward(&Value::I32(bad)).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
    }
}
