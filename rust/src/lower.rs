//! Float-train → int8-serve lowering: compile a trained/calibrated
//! [`crate::graph::LayerGraph`] + its [`crate::model::QParamStore`] into
//! a [`QuantizedGraph`] of true integer kernels.
//!
//! Training simulates quantization (fake-quant in f32, so gradients
//! exist); deployment should *execute* it.  [`lower`] freezes that
//! boundary:
//!
//! * every weight site is quantized **once** to per-channel `i8` codes
//!   (Eq. 3/4), with the per-channel code sums precomputed for the
//!   zero-point correction;
//! * activations are quantized to `u8` codes at each site boundary
//!   (Eq. 1/2) and the site's GEMM/conv runs `u8×i8→i32` with a
//!   per-channel `S_x·S_w[o]` rescale back to f32
//!   ([`crate::ops::qmatmul`], [`crate::ops::qconv`]);
//! * everything between sites (ReLU, pooling, LayerNorm, softmax
//!   attention core, residual adds, embeddings) stays f32 — exactly the
//!   arithmetic the fake-quant simulation trains against, so the lowered
//!   engine reproduces the float reference's logits to ≤ 1e-3 and its
//!   eval accuracy bit-for-bit (`tests/int8_parity.rs`).
//!
//! The executor is forward-only and *batch-flexible*: unlike the
//! training artifacts (whose manifests bake in a static batch), a
//! [`QuantizedGraph`] serves any leading batch dimension — that is what
//! `benches/serve_throughput.rs` sweeps and what the concurrent serving
//! runtime ([`crate::serve`]) micro-batches over.

#![warn(missing_docs)]

use crate::backend::Value;
use crate::error::{anyhow, bail, Result};
use crate::graph::{attn_projections, InputKind, Layer, LayerGraph, LinearSpec};
use crate::model::{ParamStore, QParamStore};
use crate::ops::attention::{sdpa_fwd, AttnDims};
use crate::ops::conv::{avgpool2_fwd, ConvDims};
use crate::ops::elementwise::{embed_fwd, relu_fwd};
use crate::ops::norm::layernorm_fwd;
use crate::ops::qconv::qconv_fwd;
use crate::ops::qmatmul::{qlinear_fwd, quantize_acts, quantize_weight_rows};
use crate::quant::qrange_asym;
use crate::tensor::{ITensor, Tensor};

/// i32 accumulation is exact for contractions up to 2³¹/(255·127); stay
/// well inside it.
const MAX_CONTRACTION: usize = 60_000;

// ---------------------------------------------------------------------------
// Lowered layers
// ---------------------------------------------------------------------------

/// One lowered quantized-linear site: weights frozen to i8 codes, the
/// activation quantizer's `(S_x, Z_x)` baked in, rescale per channel.
pub struct QLinearSite {
    /// Site name (`{layer}.w`), kept for diagnostics.
    pub name: String,
    c_in: usize,
    c_out: usize,
    qw: Vec<i8>,
    /// Per-channel `Σ_i qw[o,i]` — the zero-point correction term.
    wsum: Vec<i32>,
    /// Per-channel dequantization scale `S_x·S_w[o]`.
    scale: Vec<f32>,
    bias: Option<Vec<f32>>,
    sx: f32,
    /// Rounded activation zero-point code, validated into `[0, 2^a−1]`.
    zx: i32,
    a_bits: u32,
}

impl QLinearSite {
    /// Quantize the f32 input to codes and run the integer GEMM.
    /// `x` is `[rows, c_in]` flattened; returns `[rows, c_out]`.
    fn fwd(&self, x: &[f32], rows: usize) -> Vec<f32> {
        let qx = quantize_acts(x, self.sx, self.zx as f32, self.a_bits);
        qlinear_fwd(
            &qx,
            &self.qw,
            &self.wsum,
            self.zx,
            &self.scale,
            self.bias.as_deref(),
            rows,
            self.c_in,
            self.c_out,
        )
    }
}

enum QLayer {
    Flatten,
    Linear(QLinearSite),
    Conv { site: QLinearSite, c_in: usize, k: usize, stride: usize, pad: usize },
    Relu,
    AvgPool2x2,
    LayerNorm { g: Vec<f32>, b: Vec<f32>, d: usize },
    Embed { tok: Vec<f32>, pos: Vec<f32>, vocab: usize, seq: usize, d: usize },
    Attention { proj: Vec<QLinearSite>, heads: usize, causal: bool, d: usize },
    Residual(Vec<QLayer>),
}

/// A lowered, forward-only integer inference graph.
///
/// All state is owned, immutable after [`lower`], and free of interior
/// mutability, so one graph is shared across serving worker threads as
/// a plain `Arc<QuantizedGraph>` — the compile-time proof is below.
pub struct QuantizedGraph {
    /// Name of the native model this graph was lowered from.
    pub model: String,
    /// Input domain (image geometry or token sequence length).
    pub input: InputKind,
    /// Trailing logits dimension (classes or vocab).
    pub classes: usize,
    /// Weight-grid width the i8 codes were quantized on (Eq. 3/4).
    pub w_bits: u32,
    /// Activation-grid width the u8 codes are quantized on (Eq. 1/2).
    pub a_bits: u32,
    layers: Vec<QLayer>,
}

// The serving runtime (`crate::serve`) pools `std::thread` workers over
// one `Arc<QuantizedGraph>`; keep the graph shareable by construction.
// This fails to compile if a future field introduces `Rc`/`RefCell`/raw
// pointers instead of failing at the distant `Server::start` call site.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<QuantizedGraph>();
};

// ---------------------------------------------------------------------------
// The lowering pass
// ---------------------------------------------------------------------------

/// Lower a graph + calibrated qparams to an int8 inference engine.
/// Fails with a descriptive error on missing/invalid qparams, widths the
/// i8/u8 code domain cannot hold, or contractions too large for exact
/// i32 accumulation — never at serve time.
pub fn lower(
    g: &LayerGraph,
    params: &ParamStore,
    qparams: &QParamStore,
    w_bits: u32,
    a_bits: u32,
) -> Result<QuantizedGraph> {
    if !(2..=8).contains(&w_bits) || !(2..=8).contains(&a_bits) {
        bail!(
            "lower({}): w{w_bits}a{a_bits} does not fit the i8/u8 code domain \
             (the int8 engine serves 2..=8-bit grids)",
            g.model
        );
    }
    let cx = LowerCtx { model: &g.model, params, qparams, w_bits, a_bits };
    Ok(QuantizedGraph {
        model: g.model.clone(),
        input: g.input,
        classes: g.classes,
        w_bits,
        a_bits,
        layers: cx.lower_seq(&g.layers)?,
    })
}

/// Convenience: lower a named native model
/// ([`crate::backend::native::NATIVE_MODELS`]).
pub fn lower_native(
    model: &str,
    params: &ParamStore,
    qparams: &QParamStore,
    w_bits: u32,
    a_bits: u32,
) -> Result<QuantizedGraph> {
    let g = crate::backend::native::model_graph(model).ok_or_else(|| {
        anyhow!(
            "model {model:?} has no native graph declaration — the int8 engine lowers \
             native models only (the PJRT artifacts serve through XLA)"
        )
    })?;
    lower(&g, params, qparams, w_bits, a_bits)
}

struct LowerCtx<'a> {
    model: &'a str,
    params: &'a ParamStore,
    qparams: &'a QParamStore,
    w_bits: u32,
    a_bits: u32,
}

impl LowerCtx<'_> {
    fn lower_seq(&self, layers: &[Layer]) -> Result<Vec<QLayer>> {
        layers.iter().map(|l| self.lower_layer(l)).collect()
    }

    fn lower_layer(&self, layer: &Layer) -> Result<QLayer> {
        Ok(match layer {
            Layer::Flatten => QLayer::Flatten,
            Layer::Relu => QLayer::Relu,
            Layer::AvgPool2x2 => QLayer::AvgPool2x2,
            Layer::Linear(spec) => QLayer::Linear(self.lower_site(spec)?),
            Layer::Conv2d(spec) => {
                let patch = spec.c_in * spec.k * spec.k;
                let site = self.lower_raw_site(
                    &format!("{}.w", spec.name),
                    patch,
                    spec.c_out,
                    None,
                )?;
                QLayer::Conv {
                    site,
                    c_in: spec.c_in,
                    k: spec.k,
                    stride: spec.stride,
                    pad: spec.pad,
                }
            }
            Layer::LayerNorm(spec) => QLayer::LayerNorm {
                g: self.param(&format!("{}.g", spec.name), spec.d)?,
                b: self.param(&format!("{}.b", spec.name), spec.d)?,
                d: spec.d,
            },
            Layer::Embed(spec) => QLayer::Embed {
                tok: self.param(&format!("{}.tok", spec.name), spec.vocab * spec.d)?,
                pos: self.param(&format!("{}.pos", spec.name), spec.seq * spec.d)?,
                vocab: spec.vocab,
                seq: spec.seq,
                d: spec.d,
            },
            Layer::Attention(spec) => {
                let proj = attn_projections(spec)
                    .iter()
                    .map(|p| self.lower_site(p))
                    .collect::<Result<Vec<_>>>()?;
                QLayer::Attention { proj, heads: spec.heads, causal: spec.causal, d: spec.d }
            }
            Layer::Residual(inner) => QLayer::Residual(self.lower_seq(inner)?),
        })
    }

    fn param(&self, name: &str, want: usize) -> Result<Vec<f32>> {
        let t = self.params.get(name)?;
        if t.data.len() != want {
            let got = t.data.len();
            bail!("lower({}): param {name:?} has {got} elems, graph wants {want}", self.model);
        }
        Ok(t.data.clone())
    }

    fn lower_site(&self, spec: &LinearSpec) -> Result<QLinearSite> {
        let bias = if spec.bias {
            Some(self.param(&format!("{}.b", spec.name), spec.c_out)?)
        } else {
            None
        };
        self.lower_raw_site(&format!("{}.w", spec.name), spec.c_in, spec.c_out, bias)
    }

    /// Quantize one weight site's rows to i8 once and bake its activation
    /// quantizer in — shared by linear, conv (rows are im2col patches),
    /// and the four attention projections.
    fn lower_raw_site(
        &self,
        site: &str,
        row_size: usize,
        c_out: usize,
        bias: Option<Vec<f32>>,
    ) -> Result<QLinearSite> {
        if row_size > MAX_CONTRACTION {
            bail!(
                "lower({}): site {site:?} contracts over {row_size} elements — too large \
                 for exact i32 accumulation (max {MAX_CONTRACTION})",
                self.model
            );
        }
        let w = self.params.get(site)?;
        if w.data.len() != c_out * row_size {
            let (m, got) = (self.model, w.data.len());
            bail!("lower({m}): weight {site:?} has {got} elems, want {c_out}×{row_size}");
        }
        let sw = self.qparams.sw.get(site).ok_or_else(|| {
            anyhow!(
                "lower({}): no weight scales for site {site:?} — calibrate or load a \
                 quantized checkpoint",
                self.model
            )
        })?;
        if sw.data.len() != c_out {
            let got = sw.data.len();
            bail!("lower({}): site {site:?} has {got} weight scales, want {c_out}", self.model);
        }
        if sw.data.iter().any(|&s| s <= 0.0 || !s.is_finite()) {
            bail!("lower({}): non-positive weight scale for site {site:?}", self.model);
        }
        let act = self.qparams.act.get(site).ok_or_else(|| {
            anyhow!("lower({}): no activation qparams for site {site:?}", self.model)
        })?;
        if act.scale <= 0.0 || !act.scale.is_finite() {
            bail!("lower({}): non-positive activation scale for site {site:?}", self.model);
        }
        let (_, qmax) = qrange_asym(self.a_bits);
        let zx = act.zero_point.round();
        if !(0.0..=qmax as f32).contains(&zx) {
            bail!(
                "lower({}): site {site:?} zero point {zx} escapes [0, {qmax}] — the float \
                 reference pads with an exact zero code the u8 grid cannot represent",
                self.model
            );
        }
        let (qw, wsum) = quantize_weight_rows(&w.data, &sw.data, row_size, self.w_bits);
        let scale: Vec<f32> = sw.data.iter().map(|&s| s * act.scale).collect();
        Ok(QLinearSite {
            name: site.to_string(),
            c_in: row_size,
            c_out,
            qw,
            wsum,
            scale,
            bias,
            sx: act.scale,
            zx: zx as i32,
            a_bits: self.a_bits,
        })
    }
}

// ---------------------------------------------------------------------------
// Forward execution
// ---------------------------------------------------------------------------

enum Act {
    F(Tensor),
    I(ITensor),
}

fn act_f32(model: &str, act: Act) -> Result<Tensor> {
    match act {
        Act::F(t) => Ok(t),
        Act::I(_) => bail!("{model} int8 forward: layer expected an f32 activation, got i32"),
    }
}

impl QuantizedGraph {
    /// Vocabulary size of a token-input graph (`None` for image
    /// models).  The serving runtime validates ids against this at
    /// submission time, so one bad request cannot fail the healthy
    /// requests micro-batched with it.
    pub fn vocab(&self) -> Option<usize> {
        fn find(layers: &[QLayer]) -> Option<usize> {
            layers.iter().find_map(|l| match l {
                QLayer::Embed { vocab, .. } => Some(*vocab),
                QLayer::Residual(inner) => find(inner),
                _ => None,
            })
        }
        find(&self.layers)
    }

    /// Count of frozen i8 weight codes — what a deployment would ship.
    pub fn quantized_weights(&self) -> usize {
        fn count(layers: &[QLayer]) -> usize {
            layers
                .iter()
                .map(|l| match l {
                    QLayer::Linear(s) | QLayer::Conv { site: s, .. } => s.qw.len(),
                    QLayer::Attention { proj, .. } => proj.iter().map(|s| s.qw.len()).sum(),
                    QLayer::Residual(inner) => count(inner),
                    _ => 0,
                })
                .sum()
        }
        count(&self.layers)
    }

    /// Batched forward to logits — borrowing wrapper over
    /// [`Self::forward_owned`] (pays one input copy, symmetric with the
    /// float executor, which also clones its input into the first
    /// activation).
    pub fn forward(&self, x: &Value) -> Result<Tensor> {
        self.forward_owned(x.clone())
    }

    /// Zero-copy forward: consumes the input value — the serving eval
    /// hot path ([`crate::coordinator::eval::evaluate_int8`]) moves the
    /// batch tensor straight in.  `x` is f32 images `[B, C, H, H]` or
    /// i32 token ids `[B, T]` per the graph's [`InputKind`]; any batch
    /// size is accepted (serving is not bound to the training batch).
    pub fn forward_owned(&self, x: Value) -> Result<Tensor> {
        let x0 = match (self.input, x) {
            (InputKind::Image { channels, hw }, Value::F32(t)) => {
                let good = t.shape.len() == 4
                    && t.shape[1] == channels
                    && t.shape[2] == hw
                    && t.shape[3] == hw;
                if !good {
                    bail!(
                        "{} int8 forward: want images [B, {channels}, {hw}, {hw}], got {:?}",
                        self.model,
                        t.shape
                    );
                }
                Act::F(t)
            }
            (InputKind::Tokens { seq }, Value::I32(t)) => {
                if t.shape.len() != 2 || t.shape[1] != seq {
                    let m = &self.model;
                    bail!("{m} int8 forward: want token ids [B, {seq}], got {:?}", t.shape);
                }
                Act::I(t)
            }
            _ => bail!(
                "{} int8 forward: input dtype does not match the graph's input kind",
                self.model
            ),
        };
        let out = self.forward_seq(&self.layers, x0)?;
        act_f32(&self.model, out)
    }

    fn forward_seq(&self, layers: &[QLayer], mut act: Act) -> Result<Act> {
        for layer in layers {
            act = self.forward_layer(layer, act)?;
        }
        Ok(act)
    }

    fn forward_layer(&self, layer: &QLayer, act: Act) -> Result<Act> {
        Ok(match layer {
            QLayer::Flatten => {
                let x = act_f32(&self.model, act)?;
                let b = x.shape.first().copied().unwrap_or(1);
                let rest: usize = x.shape[1..].iter().product();
                Act::F(Tensor { shape: vec![b, rest], data: x.data })
            }
            QLayer::Linear(site) => {
                let x = act_f32(&self.model, act)?;
                if x.shape.last() != Some(&site.c_in) {
                    bail!(
                        "{} int8 forward: site {:?} wants {} input features, activation is {:?}",
                        self.model,
                        site.name,
                        site.c_in,
                        x.shape
                    );
                }
                let rows = x.data.len() / site.c_in;
                let y = site.fwd(&x.data, rows);
                let mut shape = x.shape;
                *shape.last_mut().unwrap() = site.c_out;
                Act::F(Tensor { shape, data: y })
            }
            QLayer::Conv { site, c_in, k, stride, pad } => {
                let x = act_f32(&self.model, act)?;
                if x.shape.len() != 4 || x.shape[1] != *c_in || x.shape[2] != x.shape[3] {
                    bail!(
                        "{} int8 forward: conv {:?} wants [B, {c_in}, H, H], activation is {:?}",
                        self.model,
                        site.name,
                        x.shape
                    );
                }
                let dims = ConvDims {
                    batch: x.shape[0],
                    c_in: *c_in,
                    hw: x.shape[2],
                    c_out: site.c_out,
                    k: *k,
                    stride: *stride,
                    pad: *pad,
                };
                let qx = quantize_acts(&x.data, site.sx, site.zx as f32, site.a_bits);
                let y = qconv_fwd(&qx, &site.qw, &site.wsum, site.zx, &site.scale, &dims);
                let ho = dims.hw_out();
                Act::F(Tensor { shape: vec![dims.batch, site.c_out, ho, ho], data: y })
            }
            QLayer::Relu => {
                let x = act_f32(&self.model, act)?;
                Act::F(Tensor { shape: x.shape, data: relu_fwd(&x.data) })
            }
            QLayer::AvgPool2x2 => {
                let x = act_f32(&self.model, act)?;
                if x.shape.len() != 4 || x.shape[2] % 2 != 0 || x.shape[2] != x.shape[3] {
                    let m = &self.model;
                    bail!("{m} int8 forward: avgpool wants [B, C, 2n, 2n], got {:?}", x.shape);
                }
                let (b, c, hw) = (x.shape[0], x.shape[1], x.shape[2]);
                let y = avgpool2_fwd(&x.data, b, c, hw);
                Act::F(Tensor { shape: vec![b, c, hw / 2, hw / 2], data: y })
            }
            QLayer::LayerNorm { g, b, d } => {
                let x = act_f32(&self.model, act)?;
                if x.shape.last() != Some(d) {
                    let m = &self.model;
                    bail!("{m} int8 forward: layernorm wants {d} features, got {:?}", x.shape);
                }
                let rows = x.data.len() / d;
                // layernorm_fwd also returns backward-only caches (x̂, 1/σ),
                // dropped here; a fwd-only variant is a future serving win
                // that would benefit the float forward path equally
                let (y, _xhat, _inv) = layernorm_fwd(&x.data, g, b, rows, *d);
                Act::F(Tensor { shape: x.shape, data: y })
            }
            QLayer::Embed { tok, pos, vocab, seq, d } => {
                let ids = match act {
                    Act::I(t) => t,
                    Act::F(_) => {
                        bail!("{} int8 forward: embedding expects i32 token ids", self.model)
                    }
                };
                for &id in &ids.data {
                    if id < 0 || id as usize >= *vocab {
                        let m = &self.model;
                        bail!("{m} int8 forward: token id {id} out of range [0, {vocab})");
                    }
                }
                let y = embed_fwd(tok, pos, &ids.data, *seq, *d);
                let b = ids.data.len() / seq;
                Act::F(Tensor { shape: vec![b, *seq, *d], data: y })
            }
            QLayer::Attention { proj, heads, causal, d } => {
                let x = act_f32(&self.model, act)?;
                if x.shape.len() != 3 || x.shape[2] != *d {
                    let m = &self.model;
                    bail!("{m} int8 forward: attention wants [B, T, {d}], got {:?}", x.shape);
                }
                let rows = x.data.len() / d;
                let qy = proj[0].fwd(&x.data, rows);
                let ky = proj[1].fwd(&x.data, rows);
                let vy = proj[2].fwd(&x.data, rows);
                let dm = AttnDims { batch: x.shape[0], t: x.shape[1], d: *d, heads: *heads };
                // sdpa_fwd materializes the [B·H, T, T] probs cache for the
                // training backward; dropped here — same deal as layernorm
                let (om, _p) = sdpa_fwd(&qy, &ky, &vy, &dm, *causal);
                let out = proj[3].fwd(&om, rows);
                Act::F(Tensor { shape: x.shape, data: out })
            }
            QLayer::Residual(inner) => {
                let x = act_f32(&self.model, act)?;
                let mut y = act_f32(&self.model, self.forward_seq(inner, Act::F(x.clone()))?)?;
                if y.shape != x.shape {
                    bail!(
                        "{} int8 forward: residual sub-graph changed shape {:?} -> {:?}",
                        self.model,
                        x.shape,
                        y.shape
                    );
                }
                // add into the sub-graph's buffer: one clone (the skip
                // input the inner sequence consumes) is inherent, a
                // third allocation for the sum is not
                for (yo, xi) in y.data.iter_mut().zip(&x.data) {
                    *yo += xi;
                }
                Act::F(y)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ActQParams;

    fn fixture(model: &str) -> (LayerGraph, ParamStore, QParamStore) {
        crate::testing::synth_lowering_fixture(model)
    }

    #[test]
    fn lowers_every_native_model() {
        for model in ["mlp", "mlp_wide", "convnet", "tiny_tf"] {
            let (g, params, q) = fixture(model);
            let qg = lower(&g, &params, &q, 8, 8).unwrap_or_else(|e| panic!("{model}: {e}"));
            assert!(qg.quantized_weights() > 0, "{model}");
            assert_eq!(qg.classes, g.classes);
        }
    }

    #[test]
    fn rejects_wide_grids_and_missing_qparams() {
        let (g, params, q) = fixture("mlp");
        let err = lower(&g, &params, &q, 16, 8).unwrap_err().to_string();
        assert!(err.contains("i8/u8 code domain"), "{err}");
        let err = lower(&g, &params, &QParamStore::default(), 8, 8).unwrap_err().to_string();
        assert!(err.contains("weight scales"), "{err}");
    }

    #[test]
    fn rejects_out_of_range_zero_point() {
        let (g, params, mut q) = fixture("mlp");
        q.act.insert("fc1.w".into(), ActQParams { scale: 0.05, zero_point: 300.0 });
        let err = lower(&g, &params, &q, 8, 8).unwrap_err().to_string();
        assert!(err.contains("zero point"), "{err}");
    }

    #[test]
    fn forward_accepts_any_batch_size() {
        let (g, params, q) = fixture("mlp");
        let qg = lower(&g, &params, &q, 8, 8).unwrap();
        for b in [1usize, 3, 32] {
            let x = Value::F32(Tensor::zeros(&[b, 3, 8, 8]));
            let y = qg.forward(&x).unwrap();
            assert_eq!(y.shape, vec![b, 10]);
        }
        // wrong geometry is a descriptive error
        let err = qg.forward(&Value::F32(Tensor::zeros(&[2, 3, 16, 16]))).unwrap_err().to_string();
        assert!(err.contains("images"), "{err}");
    }

    #[test]
    fn vocab_reported_for_token_graphs_only() {
        let (g, params, q) = fixture("tiny_tf");
        let qg = lower(&g, &params, &q, 8, 8).unwrap();
        assert_eq!(qg.vocab(), Some(64));
        let (g, params, q) = fixture("mlp");
        assert_eq!(lower(&g, &params, &q, 8, 8).unwrap().vocab(), None);
    }

    #[test]
    fn token_graph_validates_ids_and_seq() {
        let (g, params, q) = fixture("tiny_tf");
        let qg = lower(&g, &params, &q, 8, 8).unwrap();
        let y = qg.forward(&Value::I32(ITensor::zeros(&[2, 16]))).unwrap();
        assert_eq!(y.shape, vec![2, 16, 64]);
        let err = qg.forward(&Value::I32(ITensor::zeros(&[2, 8]))).unwrap_err().to_string();
        assert!(err.contains("token ids"), "{err}");
        let bad = ITensor { shape: vec![1, 16], data: vec![99; 16] };
        let err = qg.forward(&Value::I32(bad)).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
    }
}
