//! `efqat` — CLI launcher for the EfQAT training system.
//!
//! Subcommands:
//!   pretrain  train the FP baseline checkpoint         (paper Table 3 "FP")
//!   ptq       MinMax post-training quantization + eval (paper Table 3 "PTQ")
//!   train     full pipeline: FP ckpt → PTQ → one EfQAT epoch → eval
//!             (--mode cwpl|cwpn|lwpn|qat|r0, --ratio %, --train.freq f);
//!             `--workers W` (or EFQAT_TRAIN_WORKERS) shards each batch
//!             across W threads with a frozen-aware sparse gradient
//!             exchange — bit-identical results at any W
//!   eval      evaluate a saved checkpoint (fp or quantized);
//!             `--exec int8` lowers the graph to the integer engine and
//!             reports accuracy on the *deployed* arithmetic
//!             (`--serve.batch N` picks the serving batch size)
//!   serve     answer concurrent JSONL inference requests on the lowered
//!             int8 engine (or the f32 reference) with dynamic
//!             micro-batching: stdin/stdout by default, a TCP listener
//!             with `--port`; `--batch.max N` and `--batch.wait-ms T`
//!             set the flush policy (RFC docs/rfcs/0002-serve-protocol.md)
//!   bundle    write the schema-versioned artifacts/manifest.json inventory
//!   info      list artifacts, their manifests, and bundle integrity
//!
//! Execution backend: `--backend native` (default; pure-rust layer-graph
//! executor, models: mlp, mlp_wide, convnet, tiny_tf) or `--backend pjrt`
//! (AOT HLO artifacts built by `make artifacts`; requires the `pjrt`
//! cargo feature).
//!
//! Any config key can be overridden with `--key value`
//! (e.g. `--data.train_n 4096 --train.lr_w 1e-3 --config configs/cifar.toml`).

use std::collections::BTreeMap;
use std::path::Path;

use efqat::bundle::Bundle;
use efqat::cfg::Config;
use efqat::cli::Args;
use efqat::coordinator::pipeline::{
    artifacts_dir, fwd_artifact_name_of, load_quant_checkpoint, run_efqat_pipeline, run_pretrain,
};
use efqat::coordinator::tasks::{build_task, test_loader};
use efqat::coordinator::{evaluate, evaluate_int8, Session};
use efqat::error::{anyhow, bail, Context, Result};
use efqat::lower::lower_native;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!(
        "usage: efqat <pretrain|ptq|train|eval|serve|bundle|info> --model <m> \
         [--backend native|pjrt] [--bits w8a8] [--exec fakequant|int8] \
         [--mode cwpl|cwpn|lwpn|qat|r0] [--ratio 25] [--workers W] [--config file.toml] \
         [--key value ...]\n\
       serve: efqat serve --model <m> --ckpt <file> [--exec int8|f32] [--bits w8a8] \
         [--batch.max 32] [--batch.wait-ms 2] [--serve.workers 2] [--port 7878]"
    );
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let mut cfg = match args.opt("config") {
        Some(p) => Config::load(Path::new(p))?,
        None => Config::empty(),
    };
    let overrides: BTreeMap<String, String> = args.options.clone();
    cfg.override_with(&overrides);

    match args.subcommand.as_str() {
        "pretrain" => {
            let model = cfg.req_str("model")?;
            let session = Session::from_cfg(&cfg)?;
            run_pretrain(&session, &cfg, &model, cfg.usize("train.epochs", 3))?;
            Ok(())
        }
        "ptq" => cmd_ptq(&cfg),
        "train" => {
            let model = cfg.req_str("model")?;
            let session = Session::from_cfg(&cfg)?;
            let summary = run_efqat_pipeline(
                &session,
                &cfg,
                &model,
                &cfg.str("bits", "w8a8"),
                &cfg.str("mode", "cwpn"),
                cfg.usize("ratio", 25),
            )?;
            println!("{}", summary.render());
            Ok(())
        }
        "eval" => cmd_eval(&cfg),
        "serve" => cmd_serve(&cfg),
        "bundle" => cmd_bundle(&cfg),
        "info" => cmd_info(&cfg),
        other => {
            print_usage();
            bail!("unknown subcommand {other:?}")
        }
    }
}

fn cmd_ptq(cfg: &Config) -> Result<()> {
    use efqat::coordinator::calibrate;
    use efqat::coordinator::pipeline::{load_fp_checkpoint, parse_bits};

    let model = cfg.req_str("model")?;
    let bits = cfg.str("bits", "w8a8");
    let session = Session::from_cfg(cfg)?;
    let (params, states) = load_fp_checkpoint(cfg, &model)?;
    let calib = session.steps.get(&format!("{model}_calib"))?;
    let mut task = build_task(&model, calib.manifest.batch_size, cfg)?;
    let (w_bits, a_bits) = parse_bits(&bits)?;
    let q =
        calibrate(&calib, &params, &states, &mut task.calib, task.calib_samples, w_bits, a_bits)?;
    let fwd = session.steps.get(&fwd_artifact_name_of(&model, &bits))?;
    let result = evaluate(&fwd, &params, Some(&q), &states, &mut task.test)?;
    println!("[ptq] {model} {bits}: loss {:.4} headline {:.2}", result.loss, result.headline());
    Ok(())
}

fn cmd_eval(cfg: &Config) -> Result<()> {
    let model = cfg.req_str("model")?;
    let bits = cfg.str("bits", "fp");
    let ckpt = cfg.req_str("ckpt")?;
    let exec = cfg.str("exec", "fakequant");
    match exec.as_str() {
        "fakequant" | "float" => {
            let session = Session::from_cfg(cfg)?;
            let (params, states, q) = load_quant_checkpoint(Path::new(&ckpt))?;
            let fwd = session.steps.get(&fwd_artifact_name_of(&model, &bits))?;
            let mut task = build_task(&model, fwd.manifest.batch_size, cfg)?;
            let qopt = if bits == "fp" { None } else { Some(&q) };
            let result = evaluate(&fwd, &params, qopt, &states, &mut task.test)?;
            println!(
                "[eval] {model} {bits}: loss {:.4} acc {:.4} headline {:.2} (n={})",
                result.loss,
                result.accuracy,
                result.headline(),
                result.n
            );
            Ok(())
        }
        "int8" => {
            // deployed-arithmetic eval: lower the trained graph + qparams
            // to the integer engine and score the test set on it
            if bits == "fp" {
                bail!("--exec int8 needs a quantized --bits tag (e.g. --bits w8a8)");
            }
            let (w_bits, a_bits) = efqat::coordinator::pipeline::parse_bits(&bits)?;
            let (params, _states, q) = load_quant_checkpoint(Path::new(&ckpt))?;
            let qg = lower_native(&model, &params, &q, w_bits, a_bits)?;
            let batch = cfg.usize("serve.batch", 32);
            let mut loader = test_loader(&model, batch, cfg)?;
            let result = evaluate_int8(&qg, &mut loader)?;
            println!(
                "[eval int8] {model} {bits}: loss {:.4} acc {:.4} headline {:.2} (n={}, {} i8 weights)",
                result.loss,
                result.accuracy,
                result.headline(),
                result.n,
                qg.quantized_weights()
            );
            Ok(())
        }
        other => bail!("unknown --exec {other:?} (available: fakequant, int8)"),
    }
}

/// Serve concurrent JSONL inference requests with dynamic micro-batching
/// (RFC 0002): lower the checkpoint to the int8 engine (`--exec int8`,
/// default) or wrap the fake-quant f32 reference (`--exec f32`), start
/// the queue → batcher → worker-pool runtime, and answer over
/// stdin/stdout — or a TCP listener with `--port`.
fn cmd_serve(cfg: &Config) -> Result<()> {
    use efqat::backend::native::model_graph;
    use efqat::coordinator::pipeline::parse_bits;
    use efqat::serve::{protocol, FloatEngine, Server, ServeCfg};

    let model = cfg.req_str("model")?;
    let ckpt = cfg.req_str("ckpt")?;
    let bits = cfg.str("bits", "w8a8");
    let exec = cfg.str("exec", "int8");
    let engine: std::sync::Arc<dyn efqat::serve::Engine> = match exec.as_str() {
        "int8" => {
            let (w_bits, a_bits) = parse_bits(&bits)?;
            let (params, _states, q) = load_quant_checkpoint(Path::new(&ckpt))?;
            std::sync::Arc::new(lower_native(&model, &params, &q, w_bits, a_bits)?)
        }
        "f32" | "float" | "fakequant" => {
            let g = model_graph(&model)
                .ok_or_else(|| anyhow!("model {model:?} has no native graph declaration"))?;
            let (params, _states, q) = load_quant_checkpoint(Path::new(&ckpt))?;
            let (quant, w_bits, a_bits) = if bits == "fp" {
                (None, 0, 0)
            } else {
                let (w, a) = parse_bits(&bits)?;
                (Some(q), w, a)
            };
            std::sync::Arc::new(FloatEngine::new(g, params, quant, w_bits, a_bits))
        }
        other => bail!("unknown --exec {other:?} (available: int8, f32)"),
    };
    let scfg = ServeCfg::from_config(cfg);
    eprintln!(
        "[serve] {model} {bits} exec={exec}: max_batch={} wait={:?} workers={} queue={}",
        scfg.batch.max_batch, scfg.batch.max_wait, scfg.workers, scfg.queue_cap
    );
    let server = Server::start(engine, scfg);
    if cfg.has("port") {
        let port = cfg.usize("port", 0);
        if port == 0 || port > u16::MAX as usize {
            bail!("--port wants a TCP port in [1, 65535]");
        }
        protocol::serve_tcp(&server, &cfg.str("serve.bind", "127.0.0.1"), port as u16)?;
    } else {
        let stdin = std::io::stdin();
        let n = protocol::serve_stream(&server, stdin.lock(), std::io::stdout())?;
        eprintln!("[serve] stdin closed: answered {n} requests");
    }
    server.shutdown();
    Ok(())
}

/// Scan the artifacts directory and (re)write the schema-versioned bundle
/// manifest (RFC 0001) that the PJRT backend verifies against.
fn cmd_bundle(cfg: &Config) -> Result<()> {
    let dir = artifacts_dir(cfg);
    let mut prov = BTreeMap::new();
    prov.insert("builder".to_string(), format!("efqat bundle v{}", env!("CARGO_PKG_VERSION")));
    if let Some(note) = cfg.has("note").then(|| cfg.str("note", "")) {
        prov.insert("note".to_string(), note);
    }
    let bundle = Bundle::scan(&dir, prov)?;
    if bundle.entries.is_empty() {
        bail!(
            "no *.manifest.json artifacts found in {} — run `make artifacts` first",
            dir.display()
        );
    }
    let path = Bundle::manifest_path(&dir);
    bundle.save(&path)?;
    println!(
        "[bundle] wrote {} ({} entries, schema v{}, hash {})",
        path.display(),
        bundle.entries.len(),
        efqat::bundle::SCHEMA_VERSION,
        &bundle.bundle_hash()[..12]
    );
    Ok(())
}

fn cmd_info(cfg: &Config) -> Result<()> {
    let dir = artifacts_dir(cfg);
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .filter_map(|e| e.ok())
        .filter_map(|e| {
            e.file_name()
                .to_string_lossy()
                .strip_suffix(".manifest.json")
                .map(str::to_string)
        })
        .collect();
    names.sort();
    println!("{} artifacts in {}:", names.len(), dir.display());
    for n in &names {
        let m = efqat::model::Manifest::load(&dir.join(format!("{n}.manifest.json")))?;
        println!(
            "  {n:<40} kind={:<6} bits=w{}a{} batch={} inputs={} outputs={}",
            m.kind,
            m.w_bits,
            m.a_bits,
            m.batch_size,
            m.inputs.len(),
            m.outputs.len()
        );
    }
    let bundle_path = Bundle::manifest_path(&dir);
    if bundle_path.exists() {
        let bundle = Bundle::load(&bundle_path)?;
        match bundle.verify_all(&dir) {
            Ok(()) => println!(
                "bundle: OK — {} entries, schema v{}, hash {}",
                bundle.entries.len(),
                efqat::bundle::SCHEMA_VERSION,
                &bundle.bundle_hash()[..12]
            ),
            Err(e) => println!("bundle: STALE — {e}"),
        }
    } else {
        println!("bundle: none (run `efqat bundle` to inventory this directory)");
    }
    Ok(())
}
