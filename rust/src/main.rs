//! `efqat` — CLI launcher for the EfQAT training system.
//!
//! Subcommands:
//!   pretrain  train the FP baseline checkpoint         (paper Table 3 "FP")
//!   ptq       MinMax post-training quantization + eval (paper Table 3 "PTQ")
//!   train     full pipeline: FP ckpt → PTQ → one EfQAT epoch → eval
//!             (--mode cwpl|cwpn|lwpn|qat|r0, --ratio %, --train.freq f);
//!             `--workers W` (or EFQAT_TRAIN_WORKERS) shards each batch
//!             across W threads with a frozen-aware sparse gradient
//!             exchange — bit-identical results at any W
//!   eval      evaluate a saved checkpoint (fp or quantized);
//!             `--exec int8` lowers the graph to the integer engine and
//!             reports accuracy on the *deployed* arithmetic
//!             (`--serve.batch N` picks the serving batch size)
//!   serve     answer concurrent JSONL inference requests with dynamic
//!             micro-batching: stdin/stdout by default, a TCP listener
//!             with `--port`.  Single model (`--model` + `--ckpt`, int8
//!             or the f32 reference) or a multi-model registry
//!             (`--models name=path,... [--default-model m]`, int8) with
//!             per-model admission control and hot-swappable
//!             fingerprinted checkpoints (RFC 0002 v2 / RFC 0005);
//!             `--batch.max N` and `--batch.wait-ms T` set the flush
//!             policy, `--batch.adaptive` tunes the flush window from
//!             the observed arrival rate, and `--record file.jsonl`
//!             captures accepted traffic for `replay` (RFC 0006)
//!   replay    re-issue a recorded traffic trace against a freshly
//!             built registry at `--speed N` times the recorded pace,
//!             reporting end-to-end and per-stage latency percentiles
//!   bundle    write the schema-versioned artifacts/manifest.json inventory
//!   info      list artifacts, their manifests, and bundle integrity
//!
//! Execution backend: `--backend native` (default; pure-rust layer-graph
//! executor, models: mlp, mlp_wide, convnet, tiny_tf) or `--backend pjrt`
//! (AOT HLO artifacts built by `make artifacts`; requires the `pjrt`
//! cargo feature).
//!
//! Options are validated per subcommand (`efqat serve --moodel x` is an
//! error, not a no-op); any *dotted* config key can be overridden with
//! `--key value` (e.g. `--data.train_n 4096 --train.lr_w 1e-3
//! --config configs/cifar.toml`).

use std::collections::BTreeMap;
use std::path::Path;

use efqat::bundle::Bundle;
use efqat::cfg::Config;
use efqat::cli::{Cli, Cmd, ModelSpec, ReplayArgs, ServeArgs};
use efqat::coordinator::pipeline::{
    artifacts_dir, fwd_artifact_name_of, load_quant_checkpoint, run_efqat_pipeline, run_pretrain,
};
use efqat::coordinator::tasks::{build_task, test_loader};
use efqat::coordinator::{evaluate, evaluate_int8, Session};
use efqat::error::{anyhow, bail, Context, Result};
use efqat::lower::lower_native;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!(
        "usage: efqat <pretrain|ptq|train|eval|serve|replay|bundle|info> --model <m> \
         [--backend native|pjrt] [--bits w8a8] [--exec fakequant|int8] \
         [--mode cwpl|cwpn|lwpn|qat|r0] [--ratio 25] [--workers W] [--config file.toml] \
         [--key.dotted value ...]\n\
       serve: efqat serve --model <m> --ckpt <file> [--exec int8|f32] [--bits w8a8] \
         [--batch.max 32] [--batch.wait-ms 2] [--batch.adaptive] [--serve.workers 2] \
         [--port 7878] [--record trace.jsonl]\n\
       serve (registry): efqat serve --models m1=ckpt1,m2=arch:ckpt2 [--default-model m1] ...\n\
       replay: efqat replay --trace trace.jsonl --models m1=ckpt1,... [--speed 8] \
         [--batch.adaptive]"
    );
}

fn run(argv: &[String]) -> Result<()> {
    let cli = Cli::parse(argv)?;
    if matches!(cli.cmd, Cmd::Help) {
        print_usage();
        return Ok(());
    }
    let mut cfg = match &cli.config {
        Some(p) => Config::load(Path::new(p))?,
        None => Config::empty(),
    };
    cfg.override_with(&cli.overrides);

    match &cli.cmd {
        Cmd::Pretrain(a) => {
            let model = cfg.req_str("model")?;
            let epochs = a.epochs.unwrap_or_else(|| cfg.usize("train.epochs", 3));
            let session = Session::from_cfg(&cfg)?;
            run_pretrain(&session, &cfg, &model, epochs)?;
            Ok(())
        }
        Cmd::Ptq(_) => cmd_ptq(&cfg),
        Cmd::Train(a) => {
            let model = cfg.req_str("model")?;
            let session = Session::from_cfg(&cfg)?;
            let ratio = a.ratio.unwrap_or_else(|| cfg.usize("ratio", 25));
            let summary = run_efqat_pipeline(
                &session,
                &cfg,
                &model,
                &cfg.str("bits", "w8a8"),
                &cfg.str("mode", "cwpn"),
                ratio,
            )?;
            println!("{}", summary.render());
            Ok(())
        }
        Cmd::Eval(_) => cmd_eval(&cfg),
        Cmd::Serve(a) => cmd_serve(&cfg, a),
        Cmd::Replay(a) => cmd_replay(&cfg, a),
        Cmd::Bundle(a) => cmd_bundle(&cfg, a.note.clone()),
        Cmd::Info => cmd_info(&cfg),
        Cmd::Help => unreachable!("handled above"),
    }
}

fn cmd_ptq(cfg: &Config) -> Result<()> {
    use efqat::coordinator::calibrate;
    use efqat::coordinator::pipeline::{load_fp_checkpoint, parse_bits};

    let model = cfg.req_str("model")?;
    let bits = cfg.str("bits", "w8a8");
    let session = Session::from_cfg(cfg)?;
    let (params, states) = load_fp_checkpoint(cfg, &model)?;
    let calib = session.steps.get(&format!("{model}_calib"))?;
    let mut task = build_task(&model, calib.manifest.batch_size, cfg)?;
    let (w_bits, a_bits) = parse_bits(&bits)?;
    let q =
        calibrate(&calib, &params, &states, &mut task.calib, task.calib_samples, w_bits, a_bits)?;
    let fwd = session.steps.get(&fwd_artifact_name_of(&model, &bits))?;
    let result = evaluate(&fwd, &params, Some(&q), &states, &mut task.test)?;
    println!("[ptq] {model} {bits}: loss {:.4} headline {:.2}", result.loss, result.headline());
    Ok(())
}

fn cmd_eval(cfg: &Config) -> Result<()> {
    let model = cfg.req_str("model")?;
    let bits = cfg.str("bits", "fp");
    let ckpt = cfg.req_str("ckpt")?;
    let exec = cfg.str("exec", "fakequant");
    match exec.as_str() {
        "fakequant" | "float" => {
            let session = Session::from_cfg(cfg)?;
            let (params, states, q) = load_quant_checkpoint(Path::new(&ckpt))?;
            let fwd = session.steps.get(&fwd_artifact_name_of(&model, &bits))?;
            let mut task = build_task(&model, fwd.manifest.batch_size, cfg)?;
            let qopt = if bits == "fp" { None } else { Some(&q) };
            let result = evaluate(&fwd, &params, qopt, &states, &mut task.test)?;
            println!(
                "[eval] {model} {bits}: loss {:.4} acc {:.4} headline {:.2} (n={})",
                result.loss,
                result.accuracy,
                result.headline(),
                result.n
            );
            Ok(())
        }
        "int8" => {
            // deployed-arithmetic eval: lower the trained graph + qparams
            // to the integer engine and score the test set on it
            if bits == "fp" {
                bail!("--exec int8 needs a quantized --bits tag (e.g. --bits w8a8)");
            }
            let (w_bits, a_bits) = efqat::coordinator::pipeline::parse_bits(&bits)?;
            let (params, _states, q) = load_quant_checkpoint(Path::new(&ckpt))?;
            let qg = lower_native(&model, &params, &q, w_bits, a_bits)?;
            let batch = cfg.usize("serve.batch", 32);
            let mut loader = test_loader(&model, batch, cfg)?;
            let result = evaluate_int8(&qg, &mut loader)?;
            println!(
                "[eval int8] {model} {bits}: loss {:.4} acc {:.4} headline {:.2} (n={}, {} i8 weights)",
                result.loss,
                result.accuracy,
                result.headline(),
                result.n,
                qg.quantized_weights()
            );
            Ok(())
        }
        other => bail!("unknown --exec {other:?} (available: fakequant, int8)"),
    }
}

/// Shorten a fingerprint for log lines (stats and the RFC keep the
/// full digest).
fn fp_short(fp: &str) -> &str {
    fp.get(..12).unwrap_or(fp)
}

/// Build the serving [`Registry`](efqat::serve::Registry) shared by
/// `serve` and `replay`: one lowered int8 engine per `--models` entry,
/// each installed under its RFC 0001 checkpoint fingerprint, or a
/// single `--model`/`--ckpt` engine (`--exec int8` default, `--exec
/// f32` for the fake-quant reference).
fn build_registry(
    cfg: &Config,
    models: &[ModelSpec],
    default_model: Option<&str>,
) -> Result<efqat::serve::Registry> {
    use efqat::backend::native::model_graph;
    use efqat::coordinator::pipeline::parse_bits;
    use efqat::serve::{FloatEngine, Registry};

    let bits = cfg.str("bits", "w8a8");
    let exec = cfg.str("exec", "int8");
    let registry = Registry::new();
    if !models.is_empty() {
        // registry mode: every entry is lowered to the deployed int8
        // arithmetic (the f32 reference stays a single-model A/B tool)
        if exec != "int8" {
            bail!("--models serves lowered int8 engines; --exec {exec:?} is single-model only");
        }
        let (w_bits, a_bits) = parse_bits(&bits)?;
        for spec in models {
            let path = Path::new(&spec.path);
            let (params, _states, q) = load_quant_checkpoint(path)?;
            let qg = lower_native(&spec.arch, &params, &q, w_bits, a_bits)?;
            let fp = efqat::bundle::fingerprint(path)?;
            eprintln!("[serve] install {}: {} (fp {})", spec.name, qg.describe(), fp_short(&fp));
            registry.install(&spec.name, std::sync::Arc::new(qg), &fp)?;
        }
        if let Some(d) = default_model {
            registry.set_default(d)?;
        }
    } else {
        let model = cfg.req_str("model")?;
        let ckpt = cfg.req_str("ckpt")?;
        let fp = efqat::bundle::fingerprint(Path::new(&ckpt))?;
        let engine: std::sync::Arc<dyn efqat::serve::Engine> = match exec.as_str() {
            "int8" => {
                let (w_bits, a_bits) = parse_bits(&bits)?;
                let (params, _states, q) = load_quant_checkpoint(Path::new(&ckpt))?;
                let qg = lower_native(&model, &params, &q, w_bits, a_bits)?;
                eprintln!("[serve] install {}: {} (fp {})", model, qg.describe(), fp_short(&fp));
                std::sync::Arc::new(qg)
            }
            "f32" | "float" | "fakequant" => {
                let g = model_graph(&model)
                    .ok_or_else(|| anyhow!("model {model:?} has no native graph declaration"))?;
                let (params, _states, q) = load_quant_checkpoint(Path::new(&ckpt))?;
                let (quant, w_bits, a_bits) = if bits == "fp" {
                    (None, 0, 0)
                } else {
                    let (w, a) = parse_bits(&bits)?;
                    (Some(q), w, a)
                };
                std::sync::Arc::new(FloatEngine::new(g, params, quant, w_bits, a_bits))
            }
            other => bail!("unknown --exec {other:?} (available: int8, f32)"),
        };
        registry.install(&model, engine, &fp)?;
    }
    Ok(registry)
}

/// Print the per-model trace summary (RFC 0006) after a serving or
/// replay session: event/batch counts, batch-fill ratio, and the p95 of
/// each pipeline stage.
fn print_trace_stats(stats: &[efqat::serve::ModelStats]) {
    for st in stats {
        if let Some(t) = &st.trace {
            eprintln!(
                "[trace] {}: {} event(s) in {} batch(es), fill {:.2}, \
                 p95 queue/batch/exec/total {:.0}/{:.0}/{:.0}/{:.0} us",
                st.model,
                t.events,
                t.batches,
                st.batch_fill,
                t.queue.p95_us,
                t.batch.p95_us,
                t.exec.p95_us,
                t.total.p95_us
            );
        }
    }
}

/// Serve concurrent JSONL inference requests with dynamic micro-batching
/// (RFC 0002 v2): build the serving registry, start the per-model lanes,
/// and answer over stdin/stdout, or a TCP listener with `--port`.  With
/// `--record` every accepted request is appended to a replayable RFC
/// 0006 traffic trace.
fn cmd_serve(cfg: &Config, sa: &ServeArgs) -> Result<()> {
    use efqat::serve::{protocol, ServeCfg, Server, TrafficRecorder};

    let exec = cfg.str("exec", "int8");
    let scfg = ServeCfg::from_config(cfg)?;
    let registry = build_registry(cfg, &sa.models, sa.default_model.as_deref())?;
    eprintln!(
        "[serve] {} model(s), default {:?}, exec={exec}: max_batch={} wait={:?} adaptive={} \
         workers={} queue={}",
        registry.len(),
        registry.default_model().unwrap_or_else(|| "-".into()),
        scfg.batch.max_batch,
        scfg.batch.max_wait,
        scfg.batch.adaptive,
        scfg.workers,
        scfg.queue_cap
    );
    let server = Server::start(registry, scfg)?;
    let recorder = match &sa.record {
        Some(path) => {
            let rec = std::sync::Arc::new(TrafficRecorder::create(path)?);
            server.registry().set_recorder(rec.clone());
            eprintln!("[serve] recording accepted traffic to {path}");
            Some((path.clone(), rec))
        }
        None => None,
    };
    let port = match sa.port {
        Some(p) => Some(p),
        None if cfg.has("port") => {
            let p = cfg.usize("port", 0);
            if p == 0 || p > u16::MAX as usize {
                bail!("--port wants a TCP port in [1, 65535]");
            }
            Some(p as u16)
        }
        None => None,
    };
    if let Some(port) = port {
        protocol::serve_tcp(&server, &cfg.str("serve.bind", "127.0.0.1"), port)?;
    } else {
        let stdin = std::io::stdin();
        let n = protocol::serve_stream(&server, stdin.lock(), std::io::stdout())?;
        eprintln!("[serve] stdin closed: answered {n} requests");
    }
    let stats = server.stats();
    for st in &stats {
        eprintln!(
            "[serve] {}: fp {} gen {} queued {}/{}{}",
            st.model,
            fp_short(&st.fingerprint),
            st.generation,
            st.queued,
            st.capacity,
            if st.draining { " (draining)" } else { "" }
        );
    }
    print_trace_stats(&stats);
    if let Some((path, rec)) = &recorder {
        rec.flush();
        eprintln!("[serve] recorded {} request(s) to {path}", rec.records());
    }
    server.shutdown();
    Ok(())
}

/// Re-issue a recorded RFC 0006 traffic trace against a freshly built
/// registry at `--speed` times the recorded pace, preserving relative
/// arrival offsets, then report end-to-end and per-stage latency.
fn cmd_replay(cfg: &Config, ra: &ReplayArgs) -> Result<()> {
    use efqat::serve::{replay, ServeCfg, Server};

    let records = replay::load_trace(&ra.trace)?;
    if records.is_empty() {
        bail!("trace {} has no records to replay", ra.trace);
    }
    let speed = ra.speed.unwrap_or(1.0);
    let scfg = ServeCfg::from_config(cfg)?;
    let registry = build_registry(cfg, &ra.models, ra.default_model.as_deref())?;
    let server = Server::start(registry, scfg)?;
    eprintln!(
        "[replay] {} record(s) from {} at {speed}x (adaptive={})",
        records.len(),
        ra.trace,
        scfg.batch.adaptive
    );
    let report = replay::replay(&server, &records, speed)?;
    println!(
        "[replay] {} replies in {:.1} ms ({} overloaded retried), \
         latency p50/p95/p99 {:.3}/{:.3}/{:.3} ms",
        report.replies.len(),
        report.wall.as_secs_f64() * 1e3,
        report.retries,
        report.lat_pct(0.50),
        report.lat_pct(0.95),
        report.lat_pct(0.99)
    );
    print_trace_stats(&server.stats());
    server.shutdown();
    Ok(())
}

/// Scan the artifacts directory and (re)write the schema-versioned bundle
/// manifest (RFC 0001) that the PJRT backend verifies against.
fn cmd_bundle(cfg: &Config, note: Option<String>) -> Result<()> {
    let dir = artifacts_dir(cfg);
    let mut prov = BTreeMap::new();
    prov.insert("builder".to_string(), format!("efqat bundle v{}", env!("CARGO_PKG_VERSION")));
    if let Some(note) = note {
        prov.insert("note".to_string(), note);
    }
    let bundle = Bundle::scan(&dir, prov)?;
    if bundle.entries.is_empty() {
        bail!(
            "no *.manifest.json artifacts found in {} — run `make artifacts` first",
            dir.display()
        );
    }
    let path = Bundle::manifest_path(&dir);
    bundle.save(&path)?;
    println!(
        "[bundle] wrote {} ({} entries, schema v{}, hash {})",
        path.display(),
        bundle.entries.len(),
        efqat::bundle::SCHEMA_VERSION,
        &bundle.bundle_hash()[..12]
    );
    Ok(())
}

fn cmd_info(cfg: &Config) -> Result<()> {
    let dir = artifacts_dir(cfg);
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .filter_map(|e| e.ok())
        .filter_map(|e| {
            e.file_name()
                .to_string_lossy()
                .strip_suffix(".manifest.json")
                .map(str::to_string)
        })
        .collect();
    names.sort();
    println!("{} artifacts in {}:", names.len(), dir.display());
    for n in &names {
        let m = efqat::model::Manifest::load(&dir.join(format!("{n}.manifest.json")))?;
        println!(
            "  {n:<40} kind={:<6} bits=w{}a{} batch={} inputs={} outputs={}",
            m.kind,
            m.w_bits,
            m.a_bits,
            m.batch_size,
            m.inputs.len(),
            m.outputs.len()
        );
    }
    let bundle_path = Bundle::manifest_path(&dir);
    if bundle_path.exists() {
        let bundle = Bundle::load(&bundle_path)?;
        match bundle.verify_all(&dir) {
            Ok(()) => println!(
                "bundle: OK — {} entries, schema v{}, hash {}",
                bundle.entries.len(),
                efqat::bundle::SCHEMA_VERSION,
                &bundle.bundle_hash()[..12]
            ),
            Err(e) => println!("bundle: STALE — {e}"),
        }
    } else {
        println!("bundle: none (run `efqat bundle` to inventory this directory)");
    }
    Ok(())
}
