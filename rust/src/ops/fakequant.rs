//! Vectorized fake-quant ops (paper Eq. 1–4) with STE/LSQ gradients.
//!
//! The scalar formulas live in [`crate::quant`] and are shared with PTQ
//! calibration so both layers agree bit-for-bit; these kernels apply them
//! over whole tensors / gathered rows and add the backward rules of
//! `python/compile/quantization.py` (`fq_weight_bwd` / `fq_act_bwd`):
//! STE pass-through inside the clip range, LSQ scale gradients, LSQ+
//! zero-point gradients outside it.
//!
//! Every kernel has an `_into` form writing caller-provided slices (fed
//! from a [`crate::exec::Workspace`] on the hot paths) and a thin
//! allocating wrapper with the historical signature.

use crate::quant::{fq_asym, fq_sym, qrange_asym, qrange_sym};

/// Per-row symmetric weight fake-quant (Eq. 3): `ŵ = clip(round(w/s))·s`,
/// into `out` (same length as `w`, fully overwritten).
pub fn fq_weight_rows_into(w: &[f32], s: &[f32], row_size: usize, bits: u32, out: &mut [f32]) {
    debug_assert_eq!(w.len(), s.len() * row_size);
    debug_assert_eq!(out.len(), w.len());
    for (r, &sr) in s.iter().enumerate() {
        for i in 0..row_size {
            out[r * row_size + i] = fq_sym(w[r * row_size + i], sr, bits);
        }
    }
}

/// Allocating wrapper over [`fq_weight_rows_into`].
pub fn fq_weight_rows(w: &[f32], s: &[f32], row_size: usize, bits: u32) -> Vec<f32> {
    let mut out = vec![0.0; w.len()];
    fq_weight_rows_into(w, s, row_size, bits, &mut out);
    out
}

/// Per-tensor asymmetric activation fake-quant (Eq. 1), into `out`
/// (same length as `x`, fully overwritten).
pub fn fq_act_tensor_into(x: &[f32], s: f32, z: f32, bits: u32, out: &mut [f32]) {
    debug_assert_eq!(out.len(), x.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o = fq_asym(v, s, z, bits);
    }
}

/// Allocating wrapper over [`fq_act_tensor_into`].
pub fn fq_act_tensor(x: &[f32], s: f32, z: f32, bits: u32) -> Vec<f32> {
    let mut out = vec![0.0; x.len()];
    fq_act_tensor_into(x, s, z, bits, &mut out);
    out
}

/// STE/LSQ backward of the weight quantizer for the given (already
/// row-restricted) rows, into `dw` (`w_rows.len()`) and `ds`
/// (`s.len()`), both fully overwritten; mirrors
/// `python/compile/quantization.py::fq_weight_bwd`.
pub fn fq_weight_bwd_rows_into(
    w_rows: &[f32],
    s: &[f32],
    dwhat: &[f32],
    row_size: usize,
    bits: u32,
    dw: &mut [f32],
    ds: &mut [f32],
) {
    let (qmin, qmax) = qrange_sym(bits);
    let (qmin, qmax) = (qmin as f32, qmax as f32);
    debug_assert_eq!(dw.len(), w_rows.len());
    debug_assert_eq!(ds.len(), s.len());
    for (r, &sr) in s.iter().enumerate() {
        let mut dsr = 0.0f32;
        for i in 0..row_size {
            let idx = r * row_size + i;
            let v = w_rows[idx] / sr;
            let q = v.round().clamp(qmin, qmax);
            if v >= qmin && v <= qmax {
                dw[idx] = dwhat[idx]; // STE pass-through inside the clip range
                dsr += dwhat[idx] * (q - v); // LSQ: ∂ŵ/∂s = q - v
            } else {
                dw[idx] = 0.0;
                dsr += dwhat[idx] * q; // clipped: boundary code
            }
        }
        ds[r] = dsr;
    }
}

/// Allocating wrapper over [`fq_weight_bwd_rows_into`].
pub fn fq_weight_bwd_rows(
    w_rows: &[f32],
    s: &[f32],
    dwhat: &[f32],
    row_size: usize,
    bits: u32,
) -> (Vec<f32>, Vec<f32>) {
    let mut dw = vec![0.0; w_rows.len()];
    let mut ds = vec![0.0; s.len()];
    fq_weight_bwd_rows_into(w_rows, s, dwhat, row_size, bits, &mut dw, &mut ds);
    (dw, ds)
}

/// STE/LSQ+ backward of the activation quantizer, into `dx` (fully
/// overwritten).  Returns `(ds, dz)`; mirrors
/// `python/compile/quantization.py::fq_act_bwd`.
pub fn fq_act_bwd_tensor_into(
    x: &[f32],
    s: f32,
    z: f32,
    dxhat: &[f32],
    bits: u32,
    dx: &mut [f32],
) -> (f32, f32) {
    let (qmin, qmax) = qrange_asym(bits);
    let (qmin, qmax) = (qmin as f32, qmax as f32);
    let zr = z.round();
    debug_assert_eq!(dx.len(), x.len());
    let (mut ds, mut dz) = (0f32, 0f32);
    for i in 0..x.len() {
        let v = x[i] / s;
        let c = (v.round() + zr).clamp(qmin, qmax);
        // LSQ+ convention: the pass-through mask uses the continuous code
        if v + zr >= qmin && v + zr <= qmax {
            dx[i] = dxhat[i];
            ds += dxhat[i] * ((c - zr) - v);
        } else {
            dx[i] = 0.0;
            ds += dxhat[i] * (c - zr);
            dz += dxhat[i] * (-s);
        }
    }
    (ds, dz)
}

/// Allocating wrapper over [`fq_act_bwd_tensor_into`].
pub fn fq_act_bwd_tensor(
    x: &[f32],
    s: f32,
    z: f32,
    dxhat: &[f32],
    bits: u32,
) -> (Vec<f32>, f32, f32) {
    let mut dx = vec![0.0; x.len()];
    let (ds, dz) = fq_act_bwd_tensor_into(x, s, z, dxhat, bits, &mut dx);
    (dx, ds, dz)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant;
    use crate::testing::forall;

    #[test]
    fn prop_fq_weight_rows_matches_scalar_fq_sym() {
        forall(200, |r| {
            let rows = 1 + r.below(6);
            let rs = 1 + r.below(8);
            let bits = if r.uniform() < 0.5 { 4 } else { 8 };
            let mut rng = r.split(11);
            let w = rng.normal_vec(rows * rs, 1.0);
            let s: Vec<f32> = (0..rows).map(|_| r.uniform_in(1e-3, 0.2)).collect();
            let out = fq_weight_rows(&w, &s, rs, bits);
            for row in 0..rows {
                for i in 0..rs {
                    let want = quant::fq_sym(w[row * rs + i], s[row], bits);
                    assert_eq!(out[row * rs + i], want);
                }
            }
        });
    }

    #[test]
    fn prop_fq_act_tensor_matches_scalar_fq_asym() {
        forall(200, |r| {
            let n = 1 + r.below(32);
            let s = r.uniform_in(1e-3, 0.1);
            let z = r.uniform_in(0.0, 255.0).round();
            let mut rng = r.split(12);
            let x = rng.normal_vec(n, 2.0);
            let out = fq_act_tensor(&x, s, z, 8);
            for i in 0..n {
                assert_eq!(out[i], quant::fq_asym(x[i], s, z, 8));
            }
        });
    }

    #[test]
    fn into_variants_overwrite_dirty_buffers() {
        let w = [0.05f32, -0.31, 100.0];
        let s = [0.1f32];
        let g = [2.0f32, 1.5, 1.0];
        let mut dw = vec![9.0f32; 3];
        let mut ds = vec![9.0f32; 1];
        fq_weight_bwd_rows_into(&w, &s, &g, 3, 8, &mut dw, &mut ds);
        let (dw2, ds2) = fq_weight_bwd_rows(&w, &s, &g, 3, 8);
        assert_eq!((dw, ds), (dw2, ds2));
        let x = [0.5f32, 100.0];
        let mut dx = vec![-4.0f32; 2];
        let (ds, dz) = fq_act_bwd_tensor_into(&x, 0.1, 10.0, &[3.0, 1.0], 8, &mut dx);
        let (dx2, ds2, dz2) = fq_act_bwd_tensor(&x, 0.1, 10.0, &[3.0, 1.0], 8);
        assert_eq!((dx, ds, dz), (dx2, ds2, dz2));
        let mut fq = vec![7.0f32; 2];
        fq_act_tensor_into(&x, 0.1, 10.0, 8, &mut fq);
        assert_eq!(fq, fq_act_tensor(&x, 0.1, 10.0, 8));
        let mut fw = vec![7.0f32; 3];
        fq_weight_rows_into(&w, &s, 3, 8, &mut fw);
        assert_eq!(fw, fq_weight_rows(&w, &s, 3, 8));
    }

    #[test]
    fn fq_weight_bwd_ste_rules() {
        // in range: dw passes through, ds = (q - v)·g
        let (dw, ds) = fq_weight_bwd_rows(&[0.05], &[0.1], &[2.0], 1, 8);
        assert_eq!(dw, vec![2.0]);
        // v = 0.5 → q = round(0.5) = 1 (f32::round is away-from-zero)
        // → ds = (1 - 0.5)·2 = 1
        assert!((ds[0] - 1.0).abs() < 1e-6, "{}", ds[0]);
        // clipped: dw = 0, ds = boundary code · g
        let (dw, ds) = fq_weight_bwd_rows(&[100.0], &[0.1], &[1.0], 1, 8);
        assert_eq!(dw, vec![0.0]);
        assert!((ds[0] - 127.0).abs() < 1e-6);
    }

    #[test]
    fn fq_act_bwd_ste_rules() {
        // in range: dx passes through, dz = 0
        let (dx, _ds, dz) = fq_act_bwd_tensor(&[0.5], 0.1, 10.0, &[3.0], 8);
        assert_eq!(dx, vec![3.0]);
        assert_eq!(dz, 0.0);
        // clipped high: dx = 0, dz = -s·g
        let (dx, _ds, dz) = fq_act_bwd_tensor(&[100.0], 0.1, 10.0, &[1.0], 8);
        assert_eq!(dx, vec![0.0]);
        assert!((dz + 0.1).abs() < 1e-7);
    }
}
