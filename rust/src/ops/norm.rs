//! LayerNorm over the trailing feature axis — mirrors
//! `python/compile/layers.py::ln_fwd` / `ln_bwd` (ε = 1e-5).

pub const LN_EPS: f32 = 1e-5;

/// Normalize each of `rows` length-`d` rows.  Returns `(y, xhat, inv)`
/// where `xhat`/`inv` are the residual cache for [`layernorm_bwd`]
/// (`inv` is one `1/σ` per row).
pub fn layernorm_fwd(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(x.len(), rows * d);
    let mut y = vec![0.0f32; rows * d];
    let mut xhat = vec![0.0f32; rows * d];
    let mut inv = vec![0.0f32; rows];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mu = xr.iter().sum::<f32>() / d as f32;
        let var = xr.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let iv = 1.0 / (var + LN_EPS).sqrt();
        inv[r] = iv;
        for i in 0..d {
            let h = (xr[i] - mu) * iv;
            xhat[r * d + i] = h;
            y[r * d + i] = gamma[i] * h + beta[i];
        }
    }
    (y, xhat, inv)
}

/// Backward of [`layernorm_fwd`].  Returns `(dx, dgamma, dbeta)`.
pub fn layernorm_bwd(
    dy: &[f32],
    xhat: &[f32],
    inv: &[f32],
    gamma: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(dy.len(), rows * d);
    let mut dx = vec![0.0f32; rows * d];
    let mut dgamma = vec![0.0f32; d];
    let mut dbeta = vec![0.0f32; d];
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let xr = &xhat[r * d..(r + 1) * d];
        let mut m1 = 0.0f32; // mean of dxhat
        let mut m2 = 0.0f32; // mean of dxhat·xhat
        for i in 0..d {
            dgamma[i] += dyr[i] * xr[i];
            dbeta[i] += dyr[i];
            let dh = dyr[i] * gamma[i];
            m1 += dh;
            m2 += dh * xr[i];
        }
        m1 /= d as f32;
        m2 /= d as f32;
        for i in 0..d {
            let dh = dyr[i] * gamma[i];
            dx[r * d + i] = inv[r] * (dh - m1 - xr[i] * m2);
        }
    }
    (dx, dgamma, dbeta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn forward_normalizes_rows() {
        let mut rng = Pcg64::new(1);
        let (rows, d) = (5, 8);
        let x = rng.normal_vec(rows * d, 3.0);
        let gamma = vec![1.0; d];
        let beta = vec![0.0; d];
        let (y, _, _) = layernorm_fwd(&x, &gamma, &beta, rows, d);
        for r in 0..rows {
            let yr = &y[r * d..(r + 1) * d];
            let mu = yr.iter().sum::<f32>() / d as f32;
            let var = yr.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
            assert!(mu.abs() < 1e-5, "row {r} mean {mu}");
            assert!((var - 1.0).abs() < 1e-3, "row {r} var {var}");
        }
    }

    #[test]
    fn backward_matches_finite_difference() {
        let mut rng = Pcg64::new(2);
        let (rows, d) = (2, 6);
        let x = rng.normal_vec(rows * d, 1.5);
        let gamma = rng.normal_vec(d, 0.5);
        let beta = rng.normal_vec(d, 0.5);
        let dout = rng.normal_vec(rows * d, 1.0);
        let loss = |xv: &[f32], gv: &[f32], bv: &[f32]| -> f32 {
            let (y, _, _) = layernorm_fwd(xv, gv, bv, rows, d);
            y.iter().zip(&dout).map(|(a, b)| a * b).sum()
        };
        let (_, xhat, inv) = layernorm_fwd(&x, &gamma, &beta, rows, d);
        let (dx, dgamma, dbeta) = layernorm_bwd(&dout, &xhat, &inv, &gamma, rows, d);
        let eps = 1e-2;
        for i in [0usize, 3, 7, 11] {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = (loss(&xp, &gamma, &beta) - loss(&xm, &gamma, &beta)) / (2.0 * eps);
            assert!((dx[i] - num).abs() < 2e-2, "dx[{i}]: {} vs {num}", dx[i]);
        }
        for i in 0..d {
            let mut gp = gamma.clone();
            gp[i] += eps;
            let mut gm = gamma.clone();
            gm[i] -= eps;
            let num = (loss(&x, &gp, &beta) - loss(&x, &gm, &beta)) / (2.0 * eps);
            assert!((dgamma[i] - num).abs() < 2e-2, "dgamma[{i}]: {} vs {num}", dgamma[i]);
            let mut bp = beta.clone();
            bp[i] += eps;
            let mut bm = beta.clone();
            bm[i] -= eps;
            let num = (loss(&x, &gamma, &bp) - loss(&x, &gamma, &bm)) / (2.0 * eps);
            assert!((dbeta[i] - num).abs() < 2e-2, "dbeta[{i}]: {} vs {num}", dbeta[i]);
        }
    }
}
