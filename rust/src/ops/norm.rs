//! LayerNorm over the trailing feature axis — mirrors
//! `python/compile/layers.py::ln_fwd` / `ln_bwd` (ε = 1e-5).
//!
//! Both kernels have `_into` forms writing caller-provided slices (the
//! planned executors feed them from a [`crate::exec::Workspace`]) plus
//! thin allocating wrappers.

pub const LN_EPS: f32 = 1e-5;

/// Normalize each of `rows` length-`d` rows, into `y` plus the residual
/// caches `xhat` (`rows·d`) and `inv` (`rows`, one `1/σ` per row) for
/// [`layernorm_bwd`].  All three outputs are fully overwritten.
#[allow(clippy::too_many_arguments)] // a norm ABI: operand, params, dims, outputs
pub fn layernorm_fwd_into(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    rows: usize,
    d: usize,
    y: &mut [f32],
    xhat: &mut [f32],
    inv: &mut [f32],
) {
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(y.len(), rows * d);
    debug_assert_eq!(xhat.len(), rows * d);
    debug_assert_eq!(inv.len(), rows);
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mu = xr.iter().sum::<f32>() / d as f32;
        let var = xr.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let iv = 1.0 / (var + LN_EPS).sqrt();
        inv[r] = iv;
        for i in 0..d {
            let h = (xr[i] - mu) * iv;
            xhat[r * d + i] = h;
            y[r * d + i] = gamma[i] * h + beta[i];
        }
    }
}

/// Allocating wrapper over [`layernorm_fwd_into`]; returns
/// `(y, xhat, inv)`.
pub fn layernorm_fwd(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0f32; rows * d];
    let mut xhat = vec![0.0f32; rows * d];
    let mut inv = vec![0.0f32; rows];
    layernorm_fwd_into(x, gamma, beta, rows, d, &mut y, &mut xhat, &mut inv);
    (y, xhat, inv)
}

/// Backward of [`layernorm_fwd`], into `dx` / `dgamma` / `dbeta` (all
/// fully overwritten; `dgamma`/`dbeta` are zeroed first, then
/// row-accumulated).
#[allow(clippy::too_many_arguments)] // a VJP ABI: cotangent, caches, param, dims, outputs
pub fn layernorm_bwd_into(
    dy: &[f32],
    xhat: &[f32],
    inv: &[f32],
    gamma: &[f32],
    rows: usize,
    d: usize,
    dx: &mut [f32],
    dgamma: &mut [f32],
    dbeta: &mut [f32],
) {
    debug_assert_eq!(dy.len(), rows * d);
    debug_assert_eq!(dx.len(), rows * d);
    debug_assert_eq!(dgamma.len(), d);
    debug_assert_eq!(dbeta.len(), d);
    dgamma.fill(0.0);
    dbeta.fill(0.0);
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let xr = &xhat[r * d..(r + 1) * d];
        let mut m1 = 0.0f32; // mean of dxhat
        let mut m2 = 0.0f32; // mean of dxhat·xhat
        for i in 0..d {
            dgamma[i] += dyr[i] * xr[i];
            dbeta[i] += dyr[i];
            let dh = dyr[i] * gamma[i];
            m1 += dh;
            m2 += dh * xr[i];
        }
        m1 /= d as f32;
        m2 /= d as f32;
        for i in 0..d {
            let dh = dyr[i] * gamma[i];
            dx[r * d + i] = inv[r] * (dh - m1 - xr[i] * m2);
        }
    }
}

/// Allocating wrapper over [`layernorm_bwd_into`]; returns
/// `(dx, dgamma, dbeta)`.
pub fn layernorm_bwd(
    dy: &[f32],
    xhat: &[f32],
    inv: &[f32],
    gamma: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; rows * d];
    let mut dgamma = vec![0.0f32; d];
    let mut dbeta = vec![0.0f32; d];
    layernorm_bwd_into(dy, xhat, inv, gamma, rows, d, &mut dx, &mut dgamma, &mut dbeta);
    (dx, dgamma, dbeta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn forward_normalizes_rows() {
        let mut rng = Pcg64::new(1);
        let (rows, d) = (5, 8);
        let x = rng.normal_vec(rows * d, 3.0);
        let gamma = vec![1.0; d];
        let beta = vec![0.0; d];
        let (y, _, _) = layernorm_fwd(&x, &gamma, &beta, rows, d);
        for r in 0..rows {
            let yr = &y[r * d..(r + 1) * d];
            let mu = yr.iter().sum::<f32>() / d as f32;
            let var = yr.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
            assert!(mu.abs() < 1e-5, "row {r} mean {mu}");
            assert!((var - 1.0).abs() < 1e-3, "row {r} var {var}");
        }
    }

    #[test]
    fn into_variants_overwrite_dirty_buffers() {
        let mut rng = Pcg64::new(4);
        let (rows, d) = (3, 4);
        let x = rng.normal_vec(rows * d, 1.0);
        let gamma = rng.normal_vec(d, 0.5);
        let beta = rng.normal_vec(d, 0.5);
        let dout = rng.normal_vec(rows * d, 1.0);
        let (y, xhat, inv) = layernorm_fwd(&x, &gamma, &beta, rows, d);
        let (mut y2, mut xh2, mut iv2) =
            (vec![9.0; rows * d], vec![9.0; rows * d], vec![9.0; rows]);
        layernorm_fwd_into(&x, &gamma, &beta, rows, d, &mut y2, &mut xh2, &mut iv2);
        assert_eq!((&y, &xhat, &inv), (&y2, &xh2, &iv2));
        let want = layernorm_bwd(&dout, &xhat, &inv, &gamma, rows, d);
        let (mut dx, mut dg, mut db) = (vec![9.0; rows * d], vec![9.0; d], vec![9.0; d]);
        layernorm_bwd_into(&dout, &xhat, &inv, &gamma, rows, d, &mut dx, &mut dg, &mut db);
        assert_eq!(want, (dx, dg, db));
    }

    #[test]
    fn backward_matches_finite_difference() {
        let mut rng = Pcg64::new(2);
        let (rows, d) = (2, 6);
        let x = rng.normal_vec(rows * d, 1.5);
        let gamma = rng.normal_vec(d, 0.5);
        let beta = rng.normal_vec(d, 0.5);
        let dout = rng.normal_vec(rows * d, 1.0);
        let loss = |xv: &[f32], gv: &[f32], bv: &[f32]| -> f32 {
            let (y, _, _) = layernorm_fwd(xv, gv, bv, rows, d);
            y.iter().zip(&dout).map(|(a, b)| a * b).sum()
        };
        let (_, xhat, inv) = layernorm_fwd(&x, &gamma, &beta, rows, d);
        let (dx, dgamma, dbeta) = layernorm_bwd(&dout, &xhat, &inv, &gamma, rows, d);
        let eps = 1e-2;
        for i in [0usize, 3, 7, 11] {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = (loss(&xp, &gamma, &beta) - loss(&xm, &gamma, &beta)) / (2.0 * eps);
            assert!((dx[i] - num).abs() < 2e-2, "dx[{i}]: {} vs {num}", dx[i]);
        }
        for i in 0..d {
            let mut gp = gamma.clone();
            gp[i] += eps;
            let mut gm = gamma.clone();
            gm[i] -= eps;
            let num = (loss(&x, &gp, &beta) - loss(&x, &gm, &beta)) / (2.0 * eps);
            assert!((dgamma[i] - num).abs() < 2e-2, "dgamma[{i}]: {} vs {num}", dgamma[i]);
            let mut bp = beta.clone();
            bp[i] += eps;
            let mut bm = beta.clone();
            bm[i] -= eps;
            let num = (loss(&x, &gamma, &bp) - loss(&x, &gamma, &bm)) / (2.0 * eps);
            assert!((dbeta[i] - num).abs() < 2e-2, "dbeta[{i}]: {} vs {num}", dbeta[i]);
        }
    }
}
