//! SIMD micro-kernels for the int8 serving GEMM *and* the f32 training
//! GEMMs, behind runtime CPU dispatch.
//!
//! **Int8 family.**  The hot loop of
//! [`crate::ops::qmatmul::qlinear_fwd_into`] (and the im2col-fed
//! [`crate::ops::qconv`], which funnels into it) is a block dot product
//! over `u8` activation codes × `i8` weight codes.  This module owns
//! that inner loop as a table of interchangeable kernels:
//!
//! | kernel         | arch            | lanes | technique |
//! |----------------|-----------------|-------|-----------|
//! | `scalar`       | any             | 1     | the reference loop — the bit-exactness oracle |
//! | `avx2`         | x86_64 + avx2   | 16    | `cvtepu8`/`cvtepi8` widen → `madd_epi16` → i32 lanes |
//! | `neon-mlal`    | aarch64         | 8     | `vmovl` widen → `vmlal_s16` → i32 lanes |
//! | `neon-dotprod` | aarch64 + dotprod | 16  | `sdot` over `x−128` plus a `128·Σw` reconstruction |
//!
//! Every int8 kernel computes the *exact* integer sum — no saturating
//! intermediates (the `_mm256_maddubs_epi16` i16 path would clip at
//! `2·255·127 > i16::MAX`, so no kernel uses it) and i32 lane
//! accumulation that is exact up to the
//! [`crate::ops::qmatmul::I32_EXACT_MAX_K`] contraction bound enforced
//! at lowering time.  Integer addition is associative, so every kernel
//! returns the same i32 as the scalar oracle bit-for-bit, and therefore
//! the same f32 logits after the per-channel rescale —
//! `tests/simd_parity.rs` holds each kernel to that standard over an
//! adversarial shape/value grid.
//!
//! **F32 family.**  The four f32 GEMM contractions in
//! [`crate::ops::matmul`] (`linear_fwd_into`, `matmul_dy_w_into`,
//! `matmul_dyt_x_into`, `partial_dw_into`) — the train/eval hot path,
//! inherited by the im2col conv and the attention projections — draw
//! their inner loops from a parallel table of [`F32GemmKernel`]s, each
//! providing a block `dot` (forward) and a fused `axpy` (the three
//! backward contractions):
//!
//! | kernel     | arch              | lanes | technique |
//! |------------|-------------------|-------|-----------|
//! | `scalar`   | any               | 1     | the reference loops, retained verbatim |
//! | `avx2-fma` | x86_64 + avx2+fma | 8     | `_mm256_fmadd_ps`, two accumulator chains |
//! | `neon-fma` | aarch64           | 4     | `vfmaq_f32`, two accumulator chains |
//!
//! **F32 determinism contract.**  Unlike the int8 family, the f32
//! kernels are *not* bit-identical to each other: FMA contracts the
//! multiply-add into one rounding, and the vector dot reassociates the
//! sum into per-lane partials.  Cross-kernel results are
//! tolerance-equal (gradient-check scale, ≤ 1e-5 — held to that bound
//! by `tests/simd_parity.rs`), while **each kernel individually is
//! deterministic**: fixed accumulation order, no data-dependent
//! shortcuts.  Every bit-identity contract in the repo — data-parallel
//! training at any worker count, workspace reuse, serve replay —
//! therefore holds *per kernel choice*, and is tested that way.
//!
//! Dispatch is resolved once per process (like `EFQAT_THREADS`): the
//! registries probe `is_x86_feature_detected!` /
//! `is_aarch64_feature_detected!` at first use, and the single
//! `EFQAT_SIMD` environment variable picks the entry in *both* tables —
//! `auto` (default: fastest available), `off` (the scalar oracle;
//! `scalar` is accepted too), `avx2`, or `neon`.  A value naming a
//! kernel this CPU cannot run falls back to `off`, and garbage falls
//! back to `auto`, mirroring the defensive `EFQAT_THREADS` parse.
//! Tests and benches that need to compare kernels *within* one process
//! bypass the env with [`force`] (int8) / [`force_f32`] (f32):
//!
//! ```
//! use efqat::ops::simd;
//!
//! simd::force(Some(0)); // index 0 is always the scalar oracle
//! assert_eq!(simd::active().name, "scalar");
//! let y = efqat::ops::qmatmul::qlinear_fwd(&[1, 2], &[3, 4], &[7], 0, &[1.0], None, 1, 2, 1);
//! assert_eq!(y, vec![11.0]);
//! simd::force(None); // back to EFQAT_SIMD / auto dispatch
//!
//! simd::force_f32(Some(0)); // f32 table leads with the same oracle
//! assert_eq!(simd::active_f32().name, "scalar");
//! simd::force_f32(None);
//! ```
//!
//! Kernels are plain `fn` pointers over borrowed slices: calling one
//! allocates nothing, so the zero-allocation contracts for both the
//! serving path and the train step (`tests/workspace_alloc.rs`) hold
//! under every dispatch choice.

#![warn(missing_docs)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

#[cfg(target_arch = "aarch64")]
mod aarch64;
mod scalar;
#[cfg(target_arch = "x86_64")]
mod x86;

/// A block dot product over equal-length code slices:
/// `Σ_i x[i]·w[i]` with exact i32 accumulation.
pub type DotFn = fn(&[u8], &[i8]) -> i32;

/// One entry of the int8 GEMM kernel table.
#[derive(Clone, Copy)]
pub struct QGemmKernel {
    /// Stable kernel name (`scalar`, `avx2`, `neon-mlal`, …) — what
    /// `EFQAT_SIMD` matches against and what diagnostics print.
    pub name: &'static str,
    /// SIMD lane width in code elements (1 for the scalar oracle).
    /// The parity suite derives its adversarial `k` grid from this.
    pub lanes: usize,
    /// The block dot product consumed by
    /// [`crate::ops::qmatmul::qlinear_fwd_into`].
    pub dot: DotFn,
}

/// A block dot product over equal-length f32 slices: `Σ_i x[i]·w[i]`.
/// Deterministic per kernel; tolerance-equal across kernels (FMA).
pub type DotF32Fn = fn(&[f32], &[f32]) -> f32;

/// Fused scale-accumulate over equal-length f32 slices:
/// `y[i] += a·x[i]` for every `i`.  The backward contractions
/// ([`crate::ops::matmul::matmul_dy_w_into`] and friends) are built
/// from this row primitive.
pub type AxpyF32Fn = fn(f32, &[f32], &mut [f32]);

/// One entry of the f32 GEMM kernel table.
#[derive(Clone, Copy)]
pub struct F32GemmKernel {
    /// Stable kernel name (`scalar`, `avx2-fma`, `neon-fma`) — matched
    /// by `EFQAT_SIMD` family prefix and printed by diagnostics.
    pub name: &'static str,
    /// SIMD lane width in f32 elements (1 for the scalar oracle).
    pub lanes: usize,
    /// Block dot product — the forward GEMM inner loop.
    pub dot: DotF32Fn,
    /// Fused `y += a·x` — the backward GEMM inner loop.
    pub axpy: AxpyF32Fn,
}

/// Sentinel for "no forced kernel" in [`FORCED`] / [`FORCED_F32`].
const UNFORCED: usize = usize::MAX;

/// Test/bench override for the int8 table, set through [`force`].
static FORCED: AtomicUsize = AtomicUsize::new(UNFORCED);

/// Test/bench override for the f32 table, set through [`force_f32`].
/// Separate from [`FORCED`]: the two tables differ in length on most
/// CPUs, so one index cannot safely address both.
static FORCED_F32: AtomicUsize = AtomicUsize::new(UNFORCED);

/// The kernels this CPU can run, probed once per process.  Index 0 is
/// always the scalar oracle; entries are ordered slowest → fastest, so
/// `auto` dispatch is the last entry.
pub fn kernels() -> &'static [QGemmKernel] {
    static REGISTRY: OnceLock<Vec<QGemmKernel>> = OnceLock::new();
    REGISTRY
        .get_or_init(|| {
            let mut v = vec![scalar::KERNEL];
            #[cfg(target_arch = "x86_64")]
            if std::arch::is_x86_feature_detected!("avx2") {
                v.push(x86::AVX2);
            }
            #[cfg(target_arch = "aarch64")]
            {
                if std::arch::is_aarch64_feature_detected!("neon") {
                    v.push(aarch64::NEON_MLAL);
                }
                if std::arch::is_aarch64_feature_detected!("dotprod") {
                    v.push(aarch64::NEON_DOTPROD);
                }
            }
            v
        })
        .as_slice()
}

/// The f32 kernels this CPU can run, probed once per process.  Index 0
/// is always the scalar oracle; entries are ordered slowest → fastest,
/// so `auto` dispatch is the last entry.  Separate table from
/// [`kernels`]: the int8 and f32 families have different feature
/// requirements (`avx2-fma` also needs `fma`).
pub fn kernels_f32() -> &'static [F32GemmKernel] {
    static REGISTRY: OnceLock<Vec<F32GemmKernel>> = OnceLock::new();
    REGISTRY
        .get_or_init(|| {
            let mut v = vec![scalar::KERNEL_F32];
            #[cfg(target_arch = "x86_64")]
            if std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
            {
                v.push(x86::AVX2_FMA);
            }
            #[cfg(target_arch = "aarch64")]
            if std::arch::is_aarch64_feature_detected!("neon") {
                v.push(aarch64::NEON_FMA);
            }
            v
        })
        .as_slice()
}

/// Resolve an `EFQAT_SIMD` value against a kernel-name table (index
/// into it).  Shared by the int8 and f32 registries — family prefixes
/// (`avx2`, `neon`) match `avx2-fma` / `neon-mlal` / `neon-dotprod`
/// alike.  Pure so the selection rules are unit-testable anywhere.
fn parse_choice(v: Option<&str>, names: &[&str]) -> usize {
    let auto = names.len() - 1;
    let family = |prefix: &str| names.iter().rposition(|n| n.starts_with(prefix)).unwrap_or(0);
    match v.map(str::trim) {
        Some(s) if s.eq_ignore_ascii_case("off") || s.eq_ignore_ascii_case("scalar") => 0,
        Some(s) if s.eq_ignore_ascii_case("avx2") => family("avx2"),
        Some(s) if s.eq_ignore_ascii_case("neon") => family("neon"),
        // unset / "auto" / garbage all mean auto, like EFQAT_THREADS
        _ => auto,
    }
}

/// The `EFQAT_SIMD`-selected int8 kernel index, resolved once per
/// process.
fn env_choice() -> usize {
    static IDX: OnceLock<usize> = OnceLock::new();
    *IDX.get_or_init(|| {
        let names: Vec<&str> = kernels().iter().map(|k| k.name).collect();
        parse_choice(std::env::var("EFQAT_SIMD").ok().as_deref(), &names)
    })
}

/// The `EFQAT_SIMD`-selected f32 kernel index, resolved once per
/// process against the f32 table (its length differs from the int8
/// one, so the indices are not interchangeable).
fn env_choice_f32() -> usize {
    static IDX: OnceLock<usize> = OnceLock::new();
    *IDX.get_or_init(|| {
        let names: Vec<&str> = kernels_f32().iter().map(|k| k.name).collect();
        parse_choice(std::env::var("EFQAT_SIMD").ok().as_deref(), &names)
    })
}

/// The kernel the int8 GEMM dispatches to right now: the [`force`]d
/// entry if one is set, else the `EFQAT_SIMD`/auto choice.
pub fn active() -> &'static QGemmKernel {
    let ks = kernels();
    let f = FORCED.load(Ordering::SeqCst);
    let i = if f < ks.len() { f } else { env_choice() };
    &ks[i]
}

/// The kernel the f32 GEMMs dispatch to right now: the [`force_f32`]d
/// entry if one is set, else the `EFQAT_SIMD`/auto choice.  Resolved
/// once per GEMM call, outside the worker threads, so a concurrent
/// re-force cannot split one GEMM across kernels.
pub fn active_f32() -> &'static F32GemmKernel {
    let ks = kernels_f32();
    let f = FORCED_F32.load(Ordering::SeqCst);
    let i = if f < ks.len() { f } else { env_choice_f32() };
    &ks[i]
}

/// Force dispatch to [`kernels`]`()[idx]` (process-wide), or restore
/// the `EFQAT_SIMD`/auto choice with `None`.  For tests and benches
/// that compare kernels within one process — e.g. the differential
/// oracle suite forces index 0 (always the scalar reference) and each
/// detected SIMD kernel in turn.  Panics on an out-of-range index: only
/// kernels this CPU was probed to support can ever run.
pub fn force(idx: Option<usize>) {
    let v = match idx {
        Some(i) => {
            assert!(i < kernels().len(), "simd::force({i}): only {} kernels", kernels().len());
            i
        }
        None => UNFORCED,
    };
    FORCED.store(v, Ordering::SeqCst);
}

/// Force f32 dispatch to [`kernels_f32`]`()[idx]` (process-wide), or
/// restore the `EFQAT_SIMD`/auto choice with `None`.  Mirrors [`force`]
/// for the f32 table; panics on an out-of-range index.
pub fn force_f32(idx: Option<usize>) {
    let v = match idx {
        Some(i) => {
            let n = kernels_f32().len();
            assert!(i < n, "simd::force_f32({i}): only {n} kernels");
            i
        }
        None => UNFORCED,
    };
    FORCED_F32.store(v, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_always_leads_with_the_scalar_oracle() {
        let ks = kernels();
        assert!(!ks.is_empty());
        assert_eq!(ks[0].name, "scalar");
        assert_eq!(ks[0].lanes, 1);
        let mut names: Vec<_> = ks.iter().map(|k| k.name).collect();
        names.dedup();
        assert_eq!(names.len(), ks.len(), "duplicate kernel names: {names:?}");
    }

    #[test]
    fn f32_registry_always_leads_with_the_scalar_oracle() {
        let ks = kernels_f32();
        assert!(!ks.is_empty());
        assert_eq!(ks[0].name, "scalar");
        assert_eq!(ks[0].lanes, 1);
        let mut names: Vec<_> = ks.iter().map(|k| k.name).collect();
        names.dedup();
        assert_eq!(names.len(), ks.len(), "duplicate f32 kernel names: {names:?}");
    }

    #[test]
    fn env_values_select_the_documented_kernels() {
        let x86 = ["scalar", "avx2"];
        assert_eq!(parse_choice(Some("off"), &x86), 0);
        assert_eq!(parse_choice(Some("scalar"), &x86), 0);
        assert_eq!(parse_choice(Some("avx2"), &x86), 1);
        assert_eq!(parse_choice(Some("auto"), &x86), 1);
        assert_eq!(parse_choice(None, &x86), 1);
        // an unavailable family falls back to the scalar oracle
        assert_eq!(parse_choice(Some("neon"), &x86), 0);
        // garbage means auto, mirroring the EFQAT_THREADS parse
        assert_eq!(parse_choice(Some("avx512"), &x86), 1);
        assert_eq!(parse_choice(Some(""), &x86), 1);

        // "neon" picks the best neon kernel the CPU offers
        let arm = ["scalar", "neon-mlal", "neon-dotprod"];
        assert_eq!(parse_choice(Some("neon"), &arm), 2);
        assert_eq!(parse_choice(Some("auto"), &arm), 2);
        assert_eq!(parse_choice(Some("avx2"), &arm), 0);
        let arm_old = ["scalar", "neon-mlal"];
        assert_eq!(parse_choice(Some("neon"), &arm_old), 1);

        // the same parse drives the f32 table: family prefixes match
        // the -fma suffixed names
        let f32_x86 = ["scalar", "avx2-fma"];
        assert_eq!(parse_choice(Some("avx2"), &f32_x86), 1);
        assert_eq!(parse_choice(Some("off"), &f32_x86), 0);
        assert_eq!(parse_choice(None, &f32_x86), 1);
        let f32_arm = ["scalar", "neon-fma"];
        assert_eq!(parse_choice(Some("neon"), &f32_arm), 1);
        assert_eq!(parse_choice(Some("avx2"), &f32_arm), 0);
    }

    #[test]
    fn every_registered_kernel_matches_the_oracle_on_smoke_shapes() {
        // the full adversarial grid lives in tests/simd_parity.rs; this
        // in-crate smoke check keeps `cargo test --lib` self-contained
        let ks = kernels();
        let mut rng = crate::rng::Pcg64::new(0x51_3d);
        for k in ks {
            for n in [0usize, 1, 7, 16, 33, 512] {
                let x: Vec<u8> = (0..n).map(|_| (rng.below(256)) as u8).collect();
                let w: Vec<i8> = (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
                assert_eq!((k.dot)(&x, &w), (ks[0].dot)(&x, &w), "{} n={n}", k.name);
            }
        }
    }

    #[test]
    fn every_f32_kernel_is_tolerance_equal_to_the_oracle_on_smoke_shapes() {
        let ks = kernels_f32();
        let mut rng = crate::rng::Pcg64::new(0x7_f32);
        for k in ks {
            for n in [0usize, 1, 3, 7, 8, 9, 16, 33, 512] {
                let x = rng.normal_vec(n, 1.0);
                let w = rng.normal_vec(n, 1.0);
                let got = (k.dot)(&x, &w);
                let want = (ks[0].dot)(&x, &w);
                let scale = 1.0f32.max(want.abs());
                assert!(
                    (got - want).abs() <= 1e-5 * scale,
                    "{} dot n={n}: {got} vs {want}",
                    k.name
                );
                let mut ya = rng.normal_vec(n, 1.0);
                let mut yb = ya.clone();
                (k.axpy)(0.37, &x, &mut ya);
                (ks[0].axpy)(0.37, &x, &mut yb);
                for i in 0..n {
                    let scale = 1.0f32.max(yb[i].abs());
                    assert!(
                        (ya[i] - yb[i]).abs() <= 1e-5 * scale,
                        "{} axpy n={n} i={i}: {} vs {}",
                        k.name,
                        ya[i],
                        yb[i]
                    );
                }
            }
        }
    }

    #[test]
    fn f32_kernels_are_individually_deterministic() {
        let ks = kernels_f32();
        let mut rng = crate::rng::Pcg64::new(0xde7);
        let x = rng.normal_vec(259, 1.0);
        let w = rng.normal_vec(259, 1.0);
        for k in ks {
            let a = (k.dot)(&x, &w);
            for _ in 0..8 {
                assert_eq!(a.to_bits(), (k.dot)(&x, &w).to_bits(), "{} dot wobbled", k.name);
            }
            let mut y0 = rng.normal_vec(259, 1.0);
            let mut y1 = y0.clone();
            (k.axpy)(-1.25, &x, &mut y0);
            (k.axpy)(-1.25, &x, &mut y1);
            for i in 0..y0.len() {
                assert_eq!(y0[i].to_bits(), y1[i].to_bits(), "{} axpy wobbled at {i}", k.name);
            }
        }
    }
}
