//! SIMD micro-kernels for the int8 serving GEMM, behind runtime CPU
//! dispatch.
//!
//! The hot loop of [`crate::ops::qmatmul::qlinear_fwd_into`] (and the
//! im2col-fed [`crate::ops::qconv`], which funnels into it) is a block
//! dot product over `u8` activation codes × `i8` weight codes.  This
//! module owns that inner loop as a table of interchangeable kernels:
//!
//! | kernel         | arch            | lanes | technique |
//! |----------------|-----------------|-------|-----------|
//! | `scalar`       | any             | 1     | the reference loop — the bit-exactness oracle |
//! | `avx2`         | x86_64 + avx2   | 16    | `cvtepu8`/`cvtepi8` widen → `madd_epi16` → i32 lanes |
//! | `neon-mlal`    | aarch64         | 8     | `vmovl` widen → `vmlal_s16` → i32 lanes |
//! | `neon-dotprod` | aarch64 + dotprod | 16  | `sdot` over `x−128` plus a `128·Σw` reconstruction |
//!
//! Every kernel computes the *exact* integer sum — no saturating
//! intermediates (the `_mm256_maddubs_epi16` i16 path would clip at
//! `2·255·127 > i16::MAX`, so no kernel uses it) and i32 lane
//! accumulation that is exact up to the
//! [`crate::ops::qmatmul::I32_EXACT_MAX_K`] contraction bound enforced
//! at lowering time.  Integer addition is associative, so every kernel
//! returns the same i32 as the scalar oracle bit-for-bit, and therefore
//! the same f32 logits after the per-channel rescale —
//! `tests/simd_parity.rs` holds each kernel to that standard over an
//! adversarial shape/value grid.
//!
//! Dispatch is resolved once per process (like `EFQAT_THREADS`): the
//! registry probes `is_x86_feature_detected!` /
//! `is_aarch64_feature_detected!` at first use, and the `EFQAT_SIMD`
//! environment variable picks the entry — `auto` (default: fastest
//! available), `off` (the scalar oracle; `scalar` is accepted too),
//! `avx2`, or `neon`.  A value naming a kernel this CPU cannot run
//! falls back to `off`, and garbage falls back to `auto`, mirroring the
//! defensive `EFQAT_THREADS` parse.  Tests and benches that need to
//! compare kernels *within* one process bypass the env with [`force`]:
//!
//! ```
//! use efqat::ops::simd;
//!
//! simd::force(Some(0)); // index 0 is always the scalar oracle
//! assert_eq!(simd::active().name, "scalar");
//! let y = efqat::ops::qmatmul::qlinear_fwd(&[1, 2], &[3, 4], &[7], 0, &[1.0], None, 1, 2, 1);
//! assert_eq!(y, vec![11.0]);
//! simd::force(None); // back to EFQAT_SIMD / auto dispatch
//! ```
//!
//! Kernels are plain `fn` pointers over borrowed slices: calling one
//! allocates nothing, so the serving path's zero-allocation contract
//! (`tests/workspace_alloc.rs`) holds under every dispatch choice.

#![warn(missing_docs)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

#[cfg(target_arch = "aarch64")]
mod aarch64;
mod scalar;
#[cfg(target_arch = "x86_64")]
mod x86;

/// A block dot product over equal-length code slices:
/// `Σ_i x[i]·w[i]` with exact i32 accumulation.
pub type DotFn = fn(&[u8], &[i8]) -> i32;

/// One entry of the int8 GEMM kernel table.
#[derive(Clone, Copy)]
pub struct QGemmKernel {
    /// Stable kernel name (`scalar`, `avx2`, `neon-mlal`, …) — what
    /// `EFQAT_SIMD` matches against and what diagnostics print.
    pub name: &'static str,
    /// SIMD lane width in code elements (1 for the scalar oracle).
    /// The parity suite derives its adversarial `k` grid from this.
    pub lanes: usize,
    /// The block dot product consumed by
    /// [`crate::ops::qmatmul::qlinear_fwd_into`].
    pub dot: DotFn,
}

/// Sentinel for "no forced kernel" in [`FORCED`].
const UNFORCED: usize = usize::MAX;

/// Test/bench override, set through [`force`].
static FORCED: AtomicUsize = AtomicUsize::new(UNFORCED);

/// The kernels this CPU can run, probed once per process.  Index 0 is
/// always the scalar oracle; entries are ordered slowest → fastest, so
/// `auto` dispatch is the last entry.
pub fn kernels() -> &'static [QGemmKernel] {
    static REGISTRY: OnceLock<Vec<QGemmKernel>> = OnceLock::new();
    REGISTRY
        .get_or_init(|| {
            let mut v = vec![scalar::KERNEL];
            #[cfg(target_arch = "x86_64")]
            if std::arch::is_x86_feature_detected!("avx2") {
                v.push(x86::AVX2);
            }
            #[cfg(target_arch = "aarch64")]
            {
                if std::arch::is_aarch64_feature_detected!("neon") {
                    v.push(aarch64::NEON_MLAL);
                }
                if std::arch::is_aarch64_feature_detected!("dotprod") {
                    v.push(aarch64::NEON_DOTPROD);
                }
            }
            v
        })
        .as_slice()
}

/// Resolve an `EFQAT_SIMD` value against a kernel table (index into
/// it).  Pure so the selection rules are unit-testable on any machine.
fn parse_choice(v: Option<&str>, ks: &[QGemmKernel]) -> usize {
    let auto = ks.len() - 1;
    let family = |prefix: &str| ks.iter().rposition(|k| k.name.starts_with(prefix)).unwrap_or(0);
    match v.map(str::trim) {
        Some(s) if s.eq_ignore_ascii_case("off") || s.eq_ignore_ascii_case("scalar") => 0,
        Some(s) if s.eq_ignore_ascii_case("avx2") => family("avx2"),
        Some(s) if s.eq_ignore_ascii_case("neon") => family("neon"),
        // unset / "auto" / garbage all mean auto, like EFQAT_THREADS
        _ => auto,
    }
}

/// The `EFQAT_SIMD`-selected kernel index, resolved once per process.
fn env_choice() -> usize {
    static IDX: OnceLock<usize> = OnceLock::new();
    *IDX.get_or_init(|| parse_choice(std::env::var("EFQAT_SIMD").ok().as_deref(), kernels()))
}

/// The kernel the int8 GEMM dispatches to right now: the [`force`]d
/// entry if one is set, else the `EFQAT_SIMD`/auto choice.
pub fn active() -> &'static QGemmKernel {
    let ks = kernels();
    let f = FORCED.load(Ordering::SeqCst);
    let i = if f < ks.len() { f } else { env_choice() };
    &ks[i]
}

/// Force dispatch to [`kernels`]`()[idx]` (process-wide), or restore
/// the `EFQAT_SIMD`/auto choice with `None`.  For tests and benches
/// that compare kernels within one process — e.g. the differential
/// oracle suite forces index 0 (always the scalar reference) and each
/// detected SIMD kernel in turn.  Panics on an out-of-range index: only
/// kernels this CPU was probed to support can ever run.
pub fn force(idx: Option<usize>) {
    let v = match idx {
        Some(i) => {
            assert!(i < kernels().len(), "simd::force({i}): only {} kernels", kernels().len());
            i
        }
        None => UNFORCED,
    };
    FORCED.store(v, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake(names: &[&'static str]) -> Vec<QGemmKernel> {
        fn nop(_: &[u8], _: &[i8]) -> i32 {
            0
        }
        names.iter().map(|&n| QGemmKernel { name: n, lanes: 1, dot: nop }).collect()
    }

    #[test]
    fn registry_always_leads_with_the_scalar_oracle() {
        let ks = kernels();
        assert!(!ks.is_empty());
        assert_eq!(ks[0].name, "scalar");
        assert_eq!(ks[0].lanes, 1);
        let mut names: Vec<_> = ks.iter().map(|k| k.name).collect();
        names.dedup();
        assert_eq!(names.len(), ks.len(), "duplicate kernel names: {names:?}");
    }

    #[test]
    fn env_values_select_the_documented_kernels() {
        let x86 = fake(&["scalar", "avx2"]);
        assert_eq!(parse_choice(Some("off"), &x86), 0);
        assert_eq!(parse_choice(Some("scalar"), &x86), 0);
        assert_eq!(parse_choice(Some("avx2"), &x86), 1);
        assert_eq!(parse_choice(Some("auto"), &x86), 1);
        assert_eq!(parse_choice(None, &x86), 1);
        // an unavailable family falls back to the scalar oracle
        assert_eq!(parse_choice(Some("neon"), &x86), 0);
        // garbage means auto, mirroring the EFQAT_THREADS parse
        assert_eq!(parse_choice(Some("avx512"), &x86), 1);
        assert_eq!(parse_choice(Some(""), &x86), 1);

        // "neon" picks the best neon kernel the CPU offers
        let arm = fake(&["scalar", "neon-mlal", "neon-dotprod"]);
        assert_eq!(parse_choice(Some("neon"), &arm), 2);
        assert_eq!(parse_choice(Some("auto"), &arm), 2);
        assert_eq!(parse_choice(Some("avx2"), &arm), 0);
        let arm_old = fake(&["scalar", "neon-mlal"]);
        assert_eq!(parse_choice(Some("neon"), &arm_old), 1);
    }

    #[test]
    fn every_registered_kernel_matches_the_oracle_on_smoke_shapes() {
        // the full adversarial grid lives in tests/simd_parity.rs; this
        // in-crate smoke check keeps `cargo test --lib` self-contained
        let ks = kernels();
        let mut rng = crate::rng::Pcg64::new(0x51_3d);
        for k in ks {
            for n in [0usize, 1, 7, 16, 33, 512] {
                let x: Vec<u8> = (0..n).map(|_| (rng.below(256)) as u8).collect();
                let w: Vec<i8> = (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
                assert_eq!((k.dot)(&x, &w), (ks[0].dot)(&x, &w), "{} n={n}", k.name);
            }
        }
    }
}
