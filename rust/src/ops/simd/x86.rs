//! AVX2 `u8×i8→i32` block dot for x86_64.
//!
//! The classic int8 instruction here is `_mm256_maddubs_epi16` (u8×i8
//! pairs summed into i16 lanes), but its i16 intermediate *saturates*:
//! a pair can reach `2·255·127 = 64770 > i16::MAX`, silently clipping —
//! which would break the bit-exactness contract against the scalar
//! oracle.  So this kernel widens first and multiplies second:
//!
//! ```text
//! 16 u8 ──cvtepu8──► 16 i16 (zero-extended, 0..255)
//! 16 i8 ──cvtepi8──► 16 i16 (sign-extended, −128..127)
//!        ──madd_epi16──► 8 i32 lanes (a0·b0 + a1·b1, max 2·255·127 ≪ 2³¹)
//! ```
//!
//! Every intermediate holds the exact product, i32 lane accumulation is
//! exact for `k ≤` [`crate::ops::qmatmul::I32_EXACT_MAX_K`] (enforced at
//! lowering time), and integer addition is associative — so the result
//! equals the scalar oracle bit-for-bit.  The `k % 16` tail runs the
//! scalar loop.

use crate::ops::simd::QGemmKernel;

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// The AVX2 kernel — registered only when
/// `is_x86_feature_detected!("avx2")` holds.
pub(super) const AVX2: QGemmKernel = QGemmKernel { name: "avx2", lanes: 16, dot };

fn dot(x: &[u8], w: &[i8]) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    // SAFETY: this kernel is only reachable through the dispatch
    // registry, which registers it after `is_x86_feature_detected!`
    // confirmed AVX2 at startup.
    unsafe { dot_impl(x, w) }
}

#[target_feature(enable = "avx2")]
unsafe fn dot_impl(x: &[u8], w: &[i8]) -> i32 {
    let n = x.len();
    let (xp, wp) = (x.as_ptr(), w.as_ptr());
    // two independent accumulator chains hide the madd/add latency
    let mut acc0 = _mm256_setzero_si256();
    let mut acc1 = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 32 <= n {
        let x0 = _mm256_cvtepu8_epi16(_mm_loadu_si128(xp.add(i).cast()));
        let w0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(wp.add(i).cast()));
        acc0 = _mm256_add_epi32(acc0, _mm256_madd_epi16(x0, w0));
        let x1 = _mm256_cvtepu8_epi16(_mm_loadu_si128(xp.add(i + 16).cast()));
        let w1 = _mm256_cvtepi8_epi16(_mm_loadu_si128(wp.add(i + 16).cast()));
        acc1 = _mm256_add_epi32(acc1, _mm256_madd_epi16(x1, w1));
        i += 32;
    }
    if i + 16 <= n {
        let x0 = _mm256_cvtepu8_epi16(_mm_loadu_si128(xp.add(i).cast()));
        let w0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(wp.add(i).cast()));
        acc0 = _mm256_add_epi32(acc0, _mm256_madd_epi16(x0, w0));
        i += 16;
    }
    let acc = _mm256_add_epi32(acc0, acc1);
    let q = _mm_add_epi32(_mm256_castsi256_si128(acc), _mm256_extracti128_si256::<1>(acc));
    let q = _mm_add_epi32(q, _mm_unpackhi_epi64(q, q));
    let q = _mm_add_epi32(q, _mm_shuffle_epi32::<1>(q));
    let mut a = _mm_cvtsi128_si32(q);
    while i < n {
        a += x[i] as i32 * w[i] as i32;
        i += 1;
    }
    a
}
