//! AVX2 `u8×i8→i32` and AVX2+FMA `f32` block kernels for x86_64.
//!
//! The classic int8 instruction here is `_mm256_maddubs_epi16` (u8×i8
//! pairs summed into i16 lanes), but its i16 intermediate *saturates*:
//! a pair can reach `2·255·127 = 64770 > i16::MAX`, silently clipping —
//! which would break the bit-exactness contract against the scalar
//! oracle.  So this kernel widens first and multiplies second:
//!
//! ```text
//! 16 u8 ──cvtepu8──► 16 i16 (zero-extended, 0..255)
//! 16 i8 ──cvtepi8──► 16 i16 (sign-extended, −128..127)
//!        ──madd_epi16──► 8 i32 lanes (a0·b0 + a1·b1, max 2·255·127 ≪ 2³¹)
//! ```
//!
//! Every intermediate holds the exact product, i32 lane accumulation is
//! exact for `k ≤` [`crate::ops::qmatmul::I32_EXACT_MAX_K`] (enforced at
//! lowering time), and integer addition is associative — so the result
//! equals the scalar oracle bit-for-bit.  The `k % 16` tail runs the
//! scalar loop.
//!
//! The f32 kernel (`avx2-fma`) vectorizes the training GEMM inner
//! loops with `_mm256_fmadd_ps`: the dot accumulates two independent
//! 8-lane chains (16 elements per iteration, hiding FMA latency) with
//! a fixed horizontal reduction at the end, and the axpy fuses
//! `y += a·x` lane-wise.  FMA keeps the full-precision product before
//! the add, so results are tolerance-equal — not bit-equal — to the
//! scalar oracle; the accumulation order is fixed, so the kernel is
//! individually deterministic (the f32 family contract in
//! [`crate::ops::simd`]).  Tails run the scalar loops.

use crate::ops::simd::{F32GemmKernel, QGemmKernel};

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// The AVX2 kernel — registered only when
/// `is_x86_feature_detected!("avx2")` holds.
pub(super) const AVX2: QGemmKernel = QGemmKernel { name: "avx2", lanes: 16, dot };

/// The AVX2+FMA f32 kernel — registered only when both
/// `is_x86_feature_detected!("avx2")` and `…("fma")` hold.
pub(super) const AVX2_FMA: F32GemmKernel =
    F32GemmKernel { name: "avx2-fma", lanes: 8, dot: dot_f32, axpy: axpy_f32 };

fn dot(x: &[u8], w: &[i8]) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    // SAFETY: this kernel is only reachable through the dispatch
    // registry, which registers it after `is_x86_feature_detected!`
    // confirmed AVX2 at startup.
    unsafe { dot_impl(x, w) }
}

#[target_feature(enable = "avx2")]
unsafe fn dot_impl(x: &[u8], w: &[i8]) -> i32 {
    let n = x.len();
    let (xp, wp) = (x.as_ptr(), w.as_ptr());
    // two independent accumulator chains hide the madd/add latency
    let mut acc0 = _mm256_setzero_si256();
    let mut acc1 = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 32 <= n {
        let x0 = _mm256_cvtepu8_epi16(_mm_loadu_si128(xp.add(i).cast()));
        let w0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(wp.add(i).cast()));
        acc0 = _mm256_add_epi32(acc0, _mm256_madd_epi16(x0, w0));
        let x1 = _mm256_cvtepu8_epi16(_mm_loadu_si128(xp.add(i + 16).cast()));
        let w1 = _mm256_cvtepi8_epi16(_mm_loadu_si128(wp.add(i + 16).cast()));
        acc1 = _mm256_add_epi32(acc1, _mm256_madd_epi16(x1, w1));
        i += 32;
    }
    if i + 16 <= n {
        let x0 = _mm256_cvtepu8_epi16(_mm_loadu_si128(xp.add(i).cast()));
        let w0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(wp.add(i).cast()));
        acc0 = _mm256_add_epi32(acc0, _mm256_madd_epi16(x0, w0));
        i += 16;
    }
    let acc = _mm256_add_epi32(acc0, acc1);
    let q = _mm_add_epi32(_mm256_castsi256_si128(acc), _mm256_extracti128_si256::<1>(acc));
    let q = _mm_add_epi32(q, _mm_unpackhi_epi64(q, q));
    let q = _mm_add_epi32(q, _mm_shuffle_epi32::<1>(q));
    let mut a = _mm_cvtsi128_si32(q);
    while i < n {
        a += x[i] as i32 * w[i] as i32;
        i += 1;
    }
    a
}

fn dot_f32(x: &[f32], w: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), w.len());
    // SAFETY: only reachable through the dispatch registry, which
    // registers this kernel after `is_x86_feature_detected!` confirmed
    // AVX2 and FMA at startup.
    unsafe { dot_f32_impl(x, w) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn dot_f32_impl(x: &[f32], w: &[f32]) -> f32 {
    let n = x.len();
    let (xp, wp) = (x.as_ptr(), w.as_ptr());
    // two independent accumulator chains hide the 4-cycle FMA latency
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(wp.add(i)), acc0);
        acc1 =
            _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i + 8)), _mm256_loadu_ps(wp.add(i + 8)), acc1);
        i += 16;
    }
    if i + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(wp.add(i)), acc0);
        i += 8;
    }
    // fixed-order horizontal reduction: 8 lanes → 4 → 2 → 1
    let acc = _mm256_add_ps(acc0, acc1);
    let q = _mm_add_ps(_mm256_castps256_ps128(acc), _mm256_extractf128_ps::<1>(acc));
    let q = _mm_add_ps(q, _mm_movehl_ps(q, q));
    let q = _mm_add_ss(q, _mm_shuffle_ps::<1>(q, q));
    let mut a = _mm_cvtss_f32(q);
    while i < n {
        a += x[i] * w[i];
        i += 1;
    }
    a
}

fn axpy_f32(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    // SAFETY: as above — registry-gated on AVX2+FMA detection.
    unsafe { axpy_f32_impl(a, x, y) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_f32_impl(a: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len();
    let (xp, yp) = (x.as_ptr(), y.as_mut_ptr());
    let av = _mm256_set1_ps(a);
    let mut i = 0usize;
    while i + 8 <= n {
        let yv = _mm256_fmadd_ps(av, _mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
        _mm256_storeu_ps(yp.add(i), yv);
        i += 8;
    }
    while i < n {
        y[i] += a * x[i];
        i += 1;
    }
}
