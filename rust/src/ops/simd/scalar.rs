//! The scalar block dots — the oracles both kernel families are tested
//! against.
//!
//! The int8 `dot` is the exact inner loop the int8 GEMM ran before the
//! SIMD dispatch layer existed, retained verbatim: every SIMD kernel in
//! this module tree is tested against it (`tests/simd_parity.rs`) and
//! must return the *same i32*, not merely a close one.  Integer
//! addition is associative, so any kernel that computes the
//! full-precision products and accumulates them in (at least) i32 lanes
//! agrees with this loop bit-for-bit regardless of summation order.
//!
//! The f32 `dot_f32` / `axpy_f32` pair is likewise the exact loop the
//! f32 GEMMs in [`crate::ops::matmul`] ran before dispatch — strictly
//! sequential accumulation, one rounding per multiply and per add — so
//! forcing the scalar f32 kernel reproduces the pre-dispatch training
//! results bit-for-bit.  The vector f32 kernels are only
//! tolerance-equal to these loops (FMA contraction + lane
//! reassociation), but each is individually deterministic; see the
//! family contract in [`crate::ops::simd`].

use crate::ops::simd::{F32GemmKernel, QGemmKernel};

/// The scalar reference kernel — always registered, always index 0 of
/// [`crate::ops::simd::kernels`].
pub(super) const KERNEL: QGemmKernel = QGemmKernel { name: "scalar", lanes: 1, dot };

/// The scalar f32 reference kernel — always registered, always index 0
/// of [`crate::ops::simd::kernels_f32`].
pub(super) const KERNEL_F32: F32GemmKernel =
    F32GemmKernel { name: "scalar", lanes: 1, dot: dot_f32, axpy: axpy_f32 };

/// `Σ_i x[i]·w[i]` over equal-length code slices, in plain i32.
fn dot(x: &[u8], w: &[i8]) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    let mut a = 0i32;
    for i in 0..x.len() {
        a += x[i] as i32 * w[i] as i32;
    }
    a
}

/// `Σ_i x[i]·w[i]` over equal-length f32 slices, strictly sequential.
fn dot_f32(x: &[f32], w: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), w.len());
    let mut a = 0.0f32;
    for i in 0..x.len() {
        a += x[i] * w[i];
    }
    a
}

/// `y[i] += a·x[i]`, element-wise, with separate multiply and add
/// roundings (no FMA) — the pre-dispatch backward inner loop verbatim.
fn axpy_f32(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += a * x[i];
    }
}
