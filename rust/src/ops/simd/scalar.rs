//! The scalar `u8×i8→i32` block dot — the bit-exactness oracle.
//!
//! This is the exact inner loop the int8 GEMM ran before the SIMD
//! dispatch layer existed, retained verbatim: every SIMD kernel in this
//! module tree is tested against it (`tests/simd_parity.rs`) and must
//! return the *same i32*, not merely a close one.  Integer addition is
//! associative, so any kernel that computes the full-precision products
//! and accumulates them in (at least) i32 lanes agrees with this loop
//! bit-for-bit regardless of summation order.

use crate::ops::simd::QGemmKernel;

/// The scalar reference kernel — always registered, always index 0 of
/// [`crate::ops::simd::kernels`].
pub(super) const KERNEL: QGemmKernel = QGemmKernel { name: "scalar", lanes: 1, dot };

/// `Σ_i x[i]·w[i]` over equal-length code slices, in plain i32.
fn dot(x: &[u8], w: &[i8]) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    let mut a = 0i32;
    for i in 0..x.len() {
        a += x[i] as i32 * w[i] as i32;
    }
    a
}
