//! NEON kernels for aarch64: `u8×i8→i32` block dots (a baseline
//! widening multiply-accumulate kernel plus an SDOT kernel on `dotprod`
//! CPUs) and an FMA `f32` kernel for the training GEMMs.
//!
//! **`neon-mlal`** mirrors the AVX2 widen-then-multiply shape with core
//! NEON only (available on every aarch64 CPU): `vmovl_u8` zero-extends
//! the activation codes to i16 (0..255 fits), `vmovl_s8` sign-extends
//! the weight codes, and `vmlal_s16` accumulates the exact i16×i16
//! products into i32 lanes.
//!
//! **`neon-dotprod`** uses the ARMv8.2 `sdot` instruction, which only
//! exists in same-signed u8×u8 / i8×i8 forms (the mixed-sign `usdot`
//! needs the rarer `i8mm` extension).  Signs are reconciled by shifting
//! the activation domain: `x ^ 0x80` reinterpreted as i8 equals
//! `x − 128`, so
//!
//! ```text
//! Σ x·w = Σ (x−128)·w + 128·Σ w
//! ```
//!
//! with `Σ w` accumulated in the same loop by a second `sdot` against a
//! ones vector.  Both terms stay inside i32 for
//! `k ≤` [`crate::ops::qmatmul::I32_EXACT_MAX_K`] (`|Σ(x−128)·w| ≤
//! 128·127·k` and `|128·Σw| ≤ 128·127·k`, whose sum is the exact
//! `|Σ x·w| ≤ 255·127·k` bound), so the reconstruction is exact and
//! bit-identical to the scalar oracle.  Tails (`k % lane`) run the
//! scalar loop in the raw domain.
//!
//! **`neon-fma`** vectorizes the f32 training GEMM inner loops with
//! `vfmaq_f32`: the dot runs two independent 4-lane accumulator chains
//! (8 elements per iteration) with a fixed `vaddvq_f32` reduction, and
//! the axpy fuses `y += a·x` lane-wise.  FMA contraction makes the f32
//! kernel tolerance-equal — not bit-equal — to the scalar oracle, with
//! a fixed accumulation order so it is individually deterministic (the
//! f32 family contract in [`crate::ops::simd`]).  Tails run the scalar
//! loops.

use crate::ops::simd::{F32GemmKernel, QGemmKernel};

#[cfg(target_arch = "aarch64")]
use std::arch::aarch64::*;

/// Core-NEON widening kernel — registered on every aarch64 CPU.
pub(super) const NEON_MLAL: QGemmKernel =
    QGemmKernel { name: "neon-mlal", lanes: 8, dot: dot_mlal };

/// SDOT kernel — registered only when
/// `is_aarch64_feature_detected!("dotprod")` holds.
pub(super) const NEON_DOTPROD: QGemmKernel =
    QGemmKernel { name: "neon-dotprod", lanes: 16, dot: dot_dotprod };

/// Core-NEON FMA f32 kernel — registered on every aarch64 CPU.
pub(super) const NEON_FMA: F32GemmKernel =
    F32GemmKernel { name: "neon-fma", lanes: 4, dot: dot_f32, axpy: axpy_f32 };

fn dot_mlal(x: &[u8], w: &[i8]) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    // SAFETY: only reachable through the dispatch registry, which
    // registers this kernel after `is_aarch64_feature_detected!("neon")`.
    unsafe { dot_mlal_impl(x, w) }
}

#[target_feature(enable = "neon")]
unsafe fn dot_mlal_impl(x: &[u8], w: &[i8]) -> i32 {
    let n = x.len();
    let mut acc0 = vdupq_n_s32(0);
    let mut acc1 = vdupq_n_s32(0);
    let mut i = 0usize;
    while i + 8 <= n {
        let x16 = vreinterpretq_s16_u16(vmovl_u8(vld1_u8(x.as_ptr().add(i))));
        let w16 = vmovl_s8(vld1_s8(w.as_ptr().add(i)));
        acc0 = vmlal_s16(acc0, vget_low_s16(x16), vget_low_s16(w16));
        acc1 = vmlal_s16(acc1, vget_high_s16(x16), vget_high_s16(w16));
        i += 8;
    }
    let mut a = vaddvq_s32(vaddq_s32(acc0, acc1));
    while i < n {
        a += x[i] as i32 * w[i] as i32;
        i += 1;
    }
    a
}

fn dot_dotprod(x: &[u8], w: &[i8]) -> i32 {
    debug_assert_eq!(x.len(), w.len());
    // SAFETY: only reachable through the dispatch registry, which
    // registers this kernel after
    // `is_aarch64_feature_detected!("dotprod")`.
    unsafe { dot_dotprod_impl(x, w) }
}

#[target_feature(enable = "neon,dotprod")]
unsafe fn dot_dotprod_impl(x: &[u8], w: &[i8]) -> i32 {
    let n = x.len();
    let off = vdupq_n_u8(0x80);
    let ones = vdupq_n_s8(1);
    let mut acc = vdupq_n_s32(0); // Σ (x−128)·w
    let mut wsum = vdupq_n_s32(0); // Σ w over the vectorized prefix
    let mut i = 0usize;
    while i + 16 <= n {
        let xv = vld1q_u8(x.as_ptr().add(i));
        let wv = vld1q_s8(w.as_ptr().add(i));
        let xs = vreinterpretq_s8_u8(veorq_u8(xv, off));
        acc = vdotq_s32(acc, xs, wv);
        wsum = vdotq_s32(wsum, ones, wv);
        i += 16;
    }
    let mut a = vaddvq_s32(acc) + 128 * vaddvq_s32(wsum);
    while i < n {
        a += x[i] as i32 * w[i] as i32;
        i += 1;
    }
    a
}

fn dot_f32(x: &[f32], w: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), w.len());
    // SAFETY: only reachable through the dispatch registry, which
    // registers this kernel after `is_aarch64_feature_detected!("neon")`.
    unsafe { dot_f32_impl(x, w) }
}

#[target_feature(enable = "neon")]
unsafe fn dot_f32_impl(x: &[f32], w: &[f32]) -> f32 {
    let n = x.len();
    // two independent accumulator chains hide the FMA latency
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 8 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(x.as_ptr().add(i)), vld1q_f32(w.as_ptr().add(i)));
        acc1 = vfmaq_f32(acc1, vld1q_f32(x.as_ptr().add(i + 4)), vld1q_f32(w.as_ptr().add(i + 4)));
        i += 8;
    }
    if i + 4 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(x.as_ptr().add(i)), vld1q_f32(w.as_ptr().add(i)));
        i += 4;
    }
    let mut a = vaddvq_f32(vaddq_f32(acc0, acc1));
    while i < n {
        a += x[i] * w[i];
        i += 1;
    }
    a
}

fn axpy_f32(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    // SAFETY: as above — registry-gated on NEON detection.
    unsafe { axpy_f32_impl(a, x, y) }
}

#[target_feature(enable = "neon")]
unsafe fn axpy_f32_impl(a: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len();
    let av = vdupq_n_f32(a);
    let mut i = 0usize;
    while i + 4 <= n {
        let yv = vfmaq_f32(vld1q_f32(y.as_ptr().add(i)), av, vld1q_f32(x.as_ptr().add(i)));
        vst1q_f32(y.as_mut_ptr().add(i), yv);
        i += 4;
    }
    while i < n {
        y[i] += a * x[i];
        i += 1;
    }
}
