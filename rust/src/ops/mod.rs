//! Shared op library: paired forward/VJP kernels for the native backend.
//!
//! Every op here mirrors its oracle in `python/compile/kernels/ref.py` /
//! `python/compile/layers.py` — same math, same conventions (weights are
//! `[C_out, ...]` row-major, activations carry the batch in the leading
//! dim) — so the rust graph executor ([`crate::graph`]) and the AOT
//! artifacts agree bit-for-bit wherever both exist.  The ops are plain
//! functions over `&[f32]` slices: the layer-graph IR owns shapes and
//! residual caches, the ops own the math.
//!
//! Every kernel comes in two forms: an `_into` variant writing
//! caller-provided output slices — the planned executors
//! ([`crate::graph`], [`crate::lower`]) feed these from a
//! [`crate::exec::Workspace`], so steady-state execution performs no
//! heap allocation — and a thin allocating wrapper with the historical
//! signature for tests and cold paths.  `_into` kernels fully overwrite
//! their outputs (zeroing first where the algorithm accumulates), so
//! recycled buffers are always safe.
//!
//! * [`matmul`] — cache-blocked, `std::thread`-parallel GEMM variants:
//!   the linear forward, both backward matmuls (Eq. 5), and the paper's
//!   partial `dW` (Fig. 1 right) that only materializes unfrozen rows.
//! * [`conv`] — im2col/col2im so conv2d forward and both gradients reuse
//!   the matmul kernels (and therefore the same partial-`dW` path), plus
//!   2×2 average pooling.
//! * [`fakequant`] — vectorized Eq. 1–4 fake-quant with STE/LSQ
//!   gradients, shared with PTQ via the scalar formulas in
//!   [`crate::quant`].
//! * [`qmatmul`] / [`qconv`] — the *serving* kernels: `u8×i8→i32`
//!   GEMM with per-channel f32 rescale and its im2col conv lowering,
//!   executing the codes the fake-quant ops merely simulate (see
//!   [`crate::lower`] for the graph-level lowering pass).
//! * [`simd`] — runtime-dispatched SIMD micro-kernels (AVX2 / NEON)
//!   for the int8 GEMM's inner block dot, with the scalar loop kept as
//!   the bit-exactness oracle and an `EFQAT_SIMD` override.
//! * [`norm`] — LayerNorm over the trailing feature axis.
//! * [`attention`] — scaled-dot-product attention (optionally causal)
//!   over head-merged `[B, T, D]` layouts.
//! * [`loss`] — mean softmax cross-entropy with fused dlogits.
//! * [`elementwise`] — ReLU and the (fp32, non-freezable) embedding
//!   lookup.

pub mod attention;
pub mod conv;
pub mod elementwise;
pub mod fakequant;
pub mod loss;
pub mod matmul;
pub mod norm;
pub mod qconv;
pub mod qmatmul;
pub mod simd;
