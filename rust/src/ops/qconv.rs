//! Int8 conv2d for the serving path: im2col over *codes*, then the
//! [`crate::ops::qmatmul`] integer GEMM.
//!
//! Identical lowering shape to the float path ([`crate::ops::conv`]) —
//! a conv is a GEMM over unfolded patches — with one integer-domain
//! subtlety: float im2col pads with `0.0`, and the dequantized value
//! `0.0` corresponds to the *zero-point code* `Z_x`, not to code 0.  So
//! the code-domain patch matrix pads with `Z_x`, which makes the padded
//! positions contribute `(Z_x − Z_x)·qw = 0` after the zero-point
//! correction, exactly like the float reference.

#![warn(missing_docs)]

use crate::ops::conv::{im2col_with, im2col_with_into, ConvDims};
use crate::ops::qmatmul::{qlinear_fwd, qlinear_fwd_into};

/// Unfold u8 activation codes `[B, C_in, H, H]` into the patch matrix
/// `[M, C_in·k·k]` written into `cols`, padding out-of-bounds taps with
/// `pad_code` (the activation zero point).  One traversal with the
/// float path ([`crate::ops::conv::im2col`]) — only the element type
/// and the pad value differ.
pub fn im2col_codes_into(qx: &[u8], d: &ConvDims, pad_code: u8, cols: &mut [u8]) {
    im2col_with_into(qx, d, pad_code, cols);
}

/// Allocating wrapper over [`im2col_codes_into`].
pub fn im2col_codes(qx: &[u8], d: &ConvDims, pad_code: u8) -> Vec<u8> {
    im2col_with(qx, d, pad_code)
}

/// Int8 conv2d forward over codes: `[B, C_in, H, H]` u8 codes → f32
/// NCHW output `[B, C_out, H_out, H_out]` into `y` (fully
/// overwritten), dequantized by the per-channel `scale[o] = S_x·S_w[o]`
/// like the linear path.  The caller provides the unfold scratch
/// `cols` (`rows·patch` u8), the GEMM-layout scratch `y2`
/// (`rows·c_out` f32), and the per-worker accumulator `acc`
/// ([`crate::ops::qmatmul::qlinear_scratch_len`] i32) — all fed from a
/// [`crate::exec::Workspace`] on the serving hot path.
#[allow(clippy::too_many_arguments)] // a conv ABI: operands, correction, dims, out, scratch
pub fn qconv_fwd_into(
    qx: &[u8],
    qw: &[i8],
    wsum: &[i32],
    zx: i32,
    scale: &[f32],
    d: &ConvDims,
    y: &mut [f32],
    cols: &mut [u8],
    y2: &mut [f32],
    acc: &mut [i32],
) {
    im2col_codes_into(qx, d, zx as u8, cols);
    qlinear_fwd_into(cols, qw, wsum, zx, scale, None, d.rows(), d.patch(), d.c_out, y2, acc);
    crate::ops::conv::rows_to_nchw_into(y2, d, y);
}

/// Allocating wrapper over [`qconv_fwd_into`].
pub fn qconv_fwd(
    qx: &[u8],
    qw: &[i8],
    wsum: &[i32],
    zx: i32,
    scale: &[f32],
    d: &ConvDims,
) -> Vec<f32> {
    let cols = im2col_codes(qx, d, zx as u8);
    let y2 = qlinear_fwd(&cols, qw, wsum, zx, scale, None, d.rows(), d.patch(), d.c_out);
    crate::ops::conv::rows_to_nchw(&y2, d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::conv::{im2col, rows_to_nchw};
    use crate::ops::fakequant::{fq_act_tensor, fq_weight_rows};
    use crate::ops::matmul::linear_fwd;
    use crate::ops::qmatmul::{quantize_acts, quantize_weight_rows};
    use crate::testing::{forall, synth_row_scales, wsum_rows};

    #[test]
    fn prop_qconv_matches_fakequant_reference() {
        forall(40, |r| {
            let d = ConvDims {
                batch: 1 + r.below(3),
                c_in: 1 + r.below(3),
                hw: 4 + 2 * r.below(3),
                c_out: 1 + r.below(4),
                k: 3,
                stride: 1,
                pad: 1,
            };
            let mut rng = r.split(31);
            let x = rng.normal_vec(d.batch * d.c_in * d.hw * d.hw, 2.0);
            let w = rng.normal_vec(d.c_out * d.patch(), 1.0);
            let sx = r.uniform_in(1e-2, 0.1);
            let zx = r.uniform_in(20.0, 230.0).round();
            let sw = synth_row_scales(&w, d.c_out, d.patch(), 8);

            // float reference: fake-quant, im2col over dequantized values
            let xh = fq_act_tensor(&x, sx, zx, 8);
            let wh = fq_weight_rows(&w, &sw, d.patch(), 8);
            let cols = im2col(&xh, &d);
            let y2 = linear_fwd(&cols, &wh, None, d.rows(), d.patch(), d.c_out);
            let want = rows_to_nchw(&y2, &d);

            // integer path, including the zero-point padding rule
            let (qw, wsum) = quantize_weight_rows(&w, &sw, d.patch(), 8);
            let qx = quantize_acts(&x, sx, zx, 8);
            let scale: Vec<f32> = sw.iter().map(|&s| s * sx).collect();
            let got = qconv_fwd(&qx, &qw, &wsum, zx as i32, &scale, &d);

            for i in 0..got.len() {
                let tol = 1e-3 * want[i].abs().max(1.0);
                assert!((got[i] - want[i]).abs() <= tol, "[{i}] {} vs {}", got[i], want[i]);
            }
        });
    }

    #[test]
    fn padding_contributes_exactly_zero() {
        // a constant input at the zero-point code with non-trivial
        // weights: every output must be exactly 0 — the padded taps and
        // the interior taps alike cancel against the correction term
        let d = ConvDims { batch: 1, c_in: 1, hw: 4, c_out: 2, k: 3, stride: 1, pad: 1 };
        let zx = 77i32;
        let qx = vec![zx as u8; 16];
        let qw: Vec<i8> = (0..2 * 9).map(|i| (i as i8) - 9).collect();
        let wsum = wsum_rows(&qw, 2);
        let y = qconv_fwd(&qx, &qw, &wsum, zx, &[0.01, 0.02], &d);
        assert!(y.iter().all(|&v| v == 0.0), "{y:?}");
    }
}
