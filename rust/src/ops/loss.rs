//! Mean softmax cross-entropy with fused gradient — mirrors
//! `python/compile/layers.py::ce_loss_fwd` / `ce_loss_bwd`.
//!
//! Works row-wise, so classifiers (`rows = B`) and per-token LM heads
//! (`rows = B·T`) share one kernel; the mean (and the `1/rows` gradient
//! scale) is over *rows*, matching the AOT step functions.

use crate::error::{bail, Result};
use crate::tensor::argmax;

/// `loss = mean_r [lse(logits_r) - logits_r[label_r]]`, writing the
/// exact mean-loss gradient (`(softmax - onehot)/rows`) into `dlogits`
/// (fully overwritten; same length as `logits`).
///
/// Returns `(loss, correct_rows)`; the gradient is computed in the same
/// pass so forward-only callers pay nothing extra of consequence.
/// Labels outside `[0, classes)` are a descriptive error, never an index
/// panic.
pub fn softmax_xent_into(
    logits: &[f32],
    labels: &[i32],
    rows: usize,
    classes: usize,
    dlogits: &mut [f32],
) -> Result<(f32, usize)> {
    debug_assert_eq!(logits.len(), rows * classes);
    debug_assert_eq!(dlogits.len(), logits.len());
    if labels.len() != rows {
        bail!("softmax_xent: {} labels for {} logit rows", labels.len(), rows);
    }
    let mut loss = 0f32;
    let mut correct = 0usize;
    for r in 0..rows {
        let row = &logits[r * classes..(r + 1) * classes];
        let y = labels[r];
        if y < 0 || y as usize >= classes {
            bail!("label {y} out of range [0, {classes})");
        }
        let y = y as usize;
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let sum: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
        let lse = sum.ln() + mx;
        loss += lse - row[y];
        if argmax(row) == y {
            correct += 1;
        }
        for c in 0..classes {
            let p = (row[c] - lse).exp();
            let onehot = if c == y { 1.0 } else { 0.0 };
            dlogits[r * classes + c] = (p - onehot) / rows as f32;
        }
    }
    Ok((loss / rows as f32, correct))
}

/// Allocating wrapper over [`softmax_xent_into`]; returns
/// `(loss, correct_rows, dlogits)`.
pub fn softmax_xent(
    logits: &[f32],
    labels: &[i32],
    rows: usize,
    classes: usize,
) -> Result<(f32, usize, Vec<f32>)> {
    let mut dlogits = vec![0f32; rows * classes];
    let (loss, correct) = softmax_xent_into(logits, labels, rows, classes, &mut dlogits)?;
    Ok((loss, correct, dlogits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn uniform_logits_give_log_classes() {
        let (loss, _, _) = softmax_xent(&[0.0; 8], &[3, 1], 2, 4).unwrap();
        assert!((loss - (4f32).ln()).abs() < 1e-6);
    }

    #[test]
    fn into_variant_overwrites_dirty_buffers() {
        let logits = [0.3f32, -1.0, 0.7, 2.0, 0.0, -0.5];
        let labels = [2, 0];
        let (l1, c1, d1) = softmax_xent(&logits, &labels, 2, 3).unwrap();
        let mut d2 = vec![42.0f32; 6];
        let (l2, c2) = softmax_xent_into(&logits, &labels, 2, 3, &mut d2).unwrap();
        assert_eq!((l1, c1), (l2, c2));
        assert_eq!(d1, d2);
    }

    #[test]
    fn gradient_rows_sum_to_zero_and_match_fd() {
        let mut rng = Pcg64::new(9);
        let (rows, classes) = (3, 5);
        let logits = rng.normal_vec(rows * classes, 1.5);
        let labels = vec![0, 2, 4];
        let (_, _, d) = softmax_xent(&logits, &labels, rows, classes).unwrap();
        for r in 0..rows {
            let s: f32 = d[r * classes..(r + 1) * classes].iter().sum();
            assert!(s.abs() < 1e-6, "row {r} grad sum {s}");
        }
        let eps = 1e-3;
        for i in 0..rows * classes {
            let mut lp = logits.clone();
            lp[i] += eps;
            let mut lm = logits.clone();
            lm[i] -= eps;
            let (fp, _, _) = softmax_xent(&lp, &labels, rows, classes).unwrap();
            let (fm, _, _) = softmax_xent(&lm, &labels, rows, classes).unwrap();
            let num = (fp - fm) / (2.0 * eps);
            assert!((d[i] - num).abs() < 1e-3, "d[{i}]: {} vs {num}", d[i]);
        }
    }

    #[test]
    fn counts_correct_rows_and_rejects_bad_labels() {
        let logits = [0.0, 3.0, 0.1, 0.0]; // argmax 1, argmax 0
        let (_, correct, _) = softmax_xent(&logits, &[1, 1], 2, 2).unwrap();
        assert_eq!(correct, 1);
        assert!(softmax_xent(&logits, &[2, 0], 2, 2).is_err());
        assert!(softmax_xent(&logits, &[-1, 0], 2, 2).is_err());
    }
}
