//! Integer GEMM for the int8 serving path — the hot kernel of the
//! lowered inference engine ([`crate::lower`]).
//!
//! The fake-quant forward computes `ŷ = x̂·ŵᵀ` over *dequantized* f32
//! values; algebraically the same contraction over the integer codes is
//!
//! ```text
//! y[b,o] = S_x·S_w[o] · ( Σ_i qx[b,i]·qw[o,i]  −  Z_x·Σ_i qw[o,i] ) + bias[o]
//! ```
//!
//! so serving needs one `u8×i8→i32` GEMM, a per-channel column-sum of the
//! weight codes (precomputed once at lowering time), and a per-channel
//! f32 rescale.  Codes come from [`crate::quant::code_sym`] /
//! [`crate::quant::code_asym`] — the *same* round+clip the fake-quant
//! simulation uses — so the integer engine reproduces the float
//! reference's logits up to rescale rounding (≤ 1e-3 per logit, see
//! `tests/int8_parity.rs`).
//!
//! The kernel is cache-blocked over the contraction dim and
//! `std::thread`-parallel over output rows via the same row splitter
//! as the f32 GEMMs in [`crate::ops::matmul`] (the scratch-carrying
//! variant: each worker's i32 accumulator row comes from the caller,
//! so the serving hot path never allocates inside a thread): each thread
//! owns a disjoint output chunk, i32 accumulation is exact, so results
//! are bit-deterministic regardless of thread count.  Determinism is
//! also per-*row*: each output element reduces over `k` in a fixed block
//! order independent of the batch dimension, so serving an example in a
//! micro-batch of 64 ([`crate::serve`]) yields the same bits as serving
//! it alone.
//!
//! The innermost block dot product — `u8` codes × `i8` codes over one
//! `KC` slab — is dispatched through [`crate::ops::simd`]: CPU features
//! are probed once per process and the fastest exact kernel (AVX2 or
//! NEON) replaces the scalar loop, which stays registered as the
//! reference.  Every kernel computes the same exact integer sum, so the
//! bit-determinism guarantees above hold under any `EFQAT_SIMD` choice.

#![warn(missing_docs)]

use crate::ops::matmul::{par_rows_scratch, planned_threads};
use crate::quant::{code_asym, code_sym};

/// Contraction-dim block.  i8 operands are 4× denser than f32, so a
/// larger block than the f32 GEMM's still fits the same L1 budget.
const KC: usize = 512;

/// Largest contraction dim for which i32 accumulation of `u8×i8`
/// products is exact: `⌊(2³¹−1)/(255·127)⌋ = 66311`.  Every kernel in
/// [`crate::ops::simd`] (and the zero-point `Σw` reconstruction inside
/// the `sdot` kernel) is overflow-free up to this bound;
/// [`crate::lower`] rejects graphs whose contractions exceed it, so
/// serving never reaches the overflowing regime.
pub const I32_EXACT_MAX_K: usize = i32::MAX as usize / (255 * 127);

/// Quantize weight rows to their symmetric signed codes (Eq. 3) and
/// return `(codes, per-row code sums)` — the column-sum term of the
/// zero-point correction, computed once per model at lowering time.
pub fn quantize_weight_rows(
    w: &[f32],
    s: &[f32],
    row_size: usize,
    bits: u32,
) -> (Vec<i8>, Vec<i32>) {
    debug_assert_eq!(w.len(), s.len() * row_size);
    debug_assert!(bits <= 8, "int8 engine: weight codes must fit i8");
    let mut qw = vec![0i8; w.len()];
    let mut wsum = vec![0i32; s.len()];
    for (r, &sr) in s.iter().enumerate() {
        let mut acc = 0i32;
        for i in 0..row_size {
            let c = code_sym(w[r * row_size + i], sr, bits);
            qw[r * row_size + i] = c as i8;
            acc += c;
        }
        wsum[r] = acc;
    }
    (qw, wsum)
}

/// Quantize an activation tensor to its asymmetric unsigned codes
/// (Eq. 1) — the layer-boundary quantization of the serving path —
/// into `q` (fully overwritten; fed from a [`crate::exec::Workspace`]
/// on the serving hot path).
pub fn quantize_acts_into(x: &[f32], s: f32, z: f32, bits: u32, q: &mut [u8]) {
    debug_assert!(bits <= 8, "int8 engine: activation codes must fit u8");
    debug_assert_eq!(q.len(), x.len());
    for (o, &v) in q.iter_mut().zip(x) {
        *o = code_asym(v, s, z, bits) as u8;
    }
}

/// Allocating wrapper over [`quantize_acts_into`].
pub fn quantize_acts(x: &[f32], s: f32, z: f32, bits: u32) -> Vec<u8> {
    let mut q = vec![0u8; x.len()];
    quantize_acts_into(x, s, z, bits, &mut q);
    q
}

/// Per-worker accumulator scratch (in `i32` elements) that
/// [`qlinear_fwd_into`] needs for an `[m,k]×[n,k]` GEMM — one length-`n`
/// row per planned worker thread.
pub fn qlinear_scratch_len(m: usize, k: usize, n: usize) -> usize {
    planned_threads(m, k * n).max(1) * n
}

/// `y[b,o] = scale[o]·(Σ_i qx[b,i]·qw[o,i] − zx·wsum[o]) (+ bias[o])`
/// — qx: `[m,k]` u8 codes, qw: `[n,k]` i8 codes, `scale[o] = S_x·S_w[o]`,
/// into `y` (`[m,n]`, fully overwritten).  `acc` is per-worker
/// accumulator scratch of at least [`qlinear_scratch_len`]`(m, k, n)`
/// elements, so the threaded hot path performs no allocation at all.
///
/// i32 accumulation is exact for `k ≤` [`I32_EXACT_MAX_K`] (≈ 66k —
/// far above any repro model; [`crate::lower`] rejects larger
/// contractions, and this function debug-asserts the same bound), and
/// the zero-point correction is applied in i64 before the single f32
/// rescale per output element.  The block dot product runs on whichever
/// [`crate::ops::simd`] kernel is dispatched — all kernels are
/// bit-identical, so the output does not depend on the choice.
#[allow(clippy::too_many_arguments)] // a GEMM ABI: operands, correction, rescale, dims
pub fn qlinear_fwd_into(
    qx: &[u8],
    qw: &[i8],
    wsum: &[i32],
    zx: i32,
    scale: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
    y: &mut [f32],
    acc_scratch: &mut [i32],
) {
    debug_assert_eq!(qx.len(), m * k);
    debug_assert_eq!(qw.len(), n * k);
    debug_assert_eq!(wsum.len(), n);
    debug_assert_eq!(scale.len(), n);
    debug_assert_eq!(y.len(), m * n);
    debug_assert!(k <= I32_EXACT_MAX_K, "k={k} exceeds the exact-i32 bound {I32_EXACT_MAX_K}");
    // resolve dispatch once per GEMM, outside the worker threads
    let dot = crate::ops::simd::active().dot;
    par_rows_scratch(y, m, n, k * n, acc_scratch, n, |r0, rows, acc| {
        for (ri, yr) in rows.chunks_mut(n).enumerate() {
            let xr = &qx[(r0 + ri) * k..(r0 + ri + 1) * k];
            acc.fill(0);
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + KC).min(k);
                let xb = &xr[k0..k1];
                for (o, ao) in acc.iter_mut().enumerate() {
                    *ao += dot(xb, &qw[o * k + k0..o * k + k1]);
                }
                k0 = k1;
            }
            for (o, yo) in yr.iter_mut().enumerate() {
                let corrected = acc[o] as i64 - zx as i64 * wsum[o] as i64;
                let mut v = scale[o] * corrected as f32;
                if let Some(b) = bias {
                    v += b[o];
                }
                *yo = v;
            }
        }
    });
}

/// Allocating wrapper over [`qlinear_fwd_into`].
#[allow(clippy::too_many_arguments)] // a GEMM ABI: operands, correction, rescale, dims
pub fn qlinear_fwd(
    qx: &[u8],
    qw: &[i8],
    wsum: &[i32],
    zx: i32,
    scale: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    let mut y = vec![0.0f32; m * n];
    let mut acc = vec![0i32; qlinear_scratch_len(m, k, n)];
    qlinear_fwd_into(qx, qw, wsum, zx, scale, bias, m, k, n, &mut y, &mut acc);
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::fakequant::{fq_act_tensor, fq_weight_rows};
    use crate::ops::matmul::linear_fwd;
    use crate::testing::{forall, rand_act_codes, rand_weight_codes, synth_row_scales, wsum_rows};

    /// The acceptance-level identity: the integer GEMM over codes must
    /// match the f32 GEMM over the dequantized fake-quant values.
    #[test]
    fn prop_qlinear_matches_fakequant_reference() {
        forall(100, |r| {
            let (m, k, n) = (1 + r.below(6), 1 + r.below(200), 1 + r.below(8));
            let bits = if r.uniform() < 0.5 { 4 } else { 8 };
            let mut rng = r.split(21);
            let x = rng.normal_vec(m * k, 2.0);
            let w = rng.normal_vec(n * k, 1.0);
            let b = rng.normal_vec(n, 0.5);
            let sx = r.uniform_in(1e-2, 0.1);
            let zx = r.uniform_in(0.0, 200.0).round();
            let sw = synth_row_scales(&w, n, k, bits);

            // float reference: fake-quant then dense f32 GEMM
            let xh = fq_act_tensor(&x, sx, zx, bits);
            let wh = fq_weight_rows(&w, &sw, k, bits);
            let want = linear_fwd(&xh, &wh, Some(&b), m, k, n);

            // integer path
            let (qw, wsum) = quantize_weight_rows(&w, &sw, k, bits);
            let qx = quantize_acts(&x, sx, zx, bits);
            let scale: Vec<f32> = sw.iter().map(|&s| s * sx).collect();
            let got = qlinear_fwd(&qx, &qw, &wsum, zx as i32, &scale, Some(&b), m, k, n);

            for i in 0..m * n {
                let tol = 1e-3 * want[i].abs().max(1.0);
                assert!(
                    (got[i] - want[i]).abs() <= tol,
                    "[{i}] int8 {} vs float {}",
                    got[i],
                    want[i]
                );
            }
        });
    }

    #[test]
    fn weight_codes_and_sums_are_consistent() {
        let w = [0.1, -0.2, 0.3, 1.27, -1.27, 0.0];
        let s = [0.01, 0.01];
        let (qw, wsum) = quantize_weight_rows(&w, &s, 3, 8);
        assert_eq!(qw, vec![10, -20, 30, 127, -127, 0]);
        assert_eq!(wsum, vec![20, 0]);
    }

    #[test]
    fn act_codes_clamp_to_u8_range() {
        let q = quantize_acts(&[-100.0, 0.0, 100.0], 0.05, 128.0, 8);
        assert_eq!(q, vec![0, 128, 255]);
    }

    #[test]
    fn empty_gemm_does_not_panic() {
        assert!(qlinear_fwd(&[], &[], &[], 0, &[], None, 0, 4, 0).is_empty());
    }

    #[test]
    fn large_shapes_parallelize_deterministically() {
        // cross the threading threshold: i32 accumulation is exact, so
        // the parallel result must equal a naive single-pass sum exactly
        let (m, k, n) = (64, 300, 48);
        let mut rng = crate::rng::Pcg64::new(9);
        let qx = rand_act_codes(&mut rng, m * k);
        let qw = rand_weight_codes(&mut rng, n * k);
        let wsum = wsum_rows(&qw, n);
        let scale = vec![1e-4f32; n];
        let got = qlinear_fwd(&qx, &qw, &wsum, 128, &scale, None, m, k, n);
        for b in 0..m {
            for o in 0..n {
                let acc: i64 = (0..k)
                    .map(|i| (qx[b * k + i] as i64 - 128) * qw[o * k + i] as i64)
                    .sum();
                let want = 1e-4f32 * acc as f32;
                assert_eq!(got[b * n + o], want, "({b},{o})");
            }
        }
    }
}
