//! Conv2d via im2col/col2im, plus 2×2 average pooling.
//!
//! Lowering the convolution to a patch matrix means the forward and both
//! gradients are the *same* GEMM kernels the linear layers use
//! ([`crate::ops::matmul`]) — including the paper's partial `dW`: a
//! conv's output channels are matmul rows after im2col, so gathering
//! unfrozen channels (`partial_dw`) works untouched.  This mirrors
//! `python/compile/layers.py::qconv_*`, which reach the same contraction
//! through `lax.conv_general_dilated`.
//!
//! Layouts match the python side: activations NCHW, weights OIHW
//! (`[C_out, C_in, k, k]`, row-major — a weight row is one output
//! channel's `C_in·k·k` patch, exactly the freezable-site convention).

/// Static geometry of one conv2d site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvDims {
    pub batch: usize,
    pub c_in: usize,
    /// Input height == width (square feature maps only — all repro
    /// models use square inputs).
    pub hw: usize,
    pub c_out: usize,
    /// Kernel side length.
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvDims {
    /// Output spatial side length.
    pub fn hw_out(&self) -> usize {
        (self.hw + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Patch size = `C_in·k·k` — the contraction dim / weight row size.
    pub fn patch(&self) -> usize {
        self.c_in * self.k * self.k
    }

    /// im2col row count `M = B·H_out·W_out`.
    pub fn rows(&self) -> usize {
        self.batch * self.hw_out() * self.hw_out()
    }
}

/// Unfold `x` `[B, C_in, H, H]` into the patch matrix `[M, C_in·k·k]`
/// written into `cols` (fully overwritten), filling out-of-bounds taps
/// with `pad`.  Generic over the element so the f32 training path
/// ([`im2col`], pad `0.0`) and the int8 serving path
/// ([`crate::ops::qconv::im2col_codes`], pad = zero-point code) share
/// one traversal — the stride/pad index math is parity-critical and
/// must never fork.
pub fn im2col_with_into<T: Copy>(x: &[T], d: &ConvDims, pad: T, cols: &mut [T]) {
    let (ho, p, hw) = (d.hw_out(), d.patch(), d.hw);
    debug_assert_eq!(x.len(), d.batch * d.c_in * hw * hw);
    debug_assert_eq!(cols.len(), d.rows() * p);
    cols.fill(pad);
    let mut r = 0;
    for n in 0..d.batch {
        for oy in 0..ho {
            for ox in 0..ho {
                let col = &mut cols[r * p..(r + 1) * p];
                let mut c = 0;
                for ci in 0..d.c_in {
                    let plane = &x[(n * d.c_in + ci) * hw * hw..(n * d.c_in + ci + 1) * hw * hw];
                    for ky in 0..d.k {
                        let iy = (oy * d.stride + ky) as isize - d.pad as isize;
                        for kx in 0..d.k {
                            let ix = (ox * d.stride + kx) as isize - d.pad as isize;
                            if iy >= 0 && (iy as usize) < hw && ix >= 0 && (ix as usize) < hw {
                                col[c] = plane[iy as usize * hw + ix as usize];
                            }
                            c += 1;
                        }
                    }
                }
                r += 1;
            }
        }
    }
}

/// Allocating form of [`im2col_with_into`].
pub fn im2col_with<T: Copy>(x: &[T], d: &ConvDims, pad: T) -> Vec<T> {
    let mut cols = vec![pad; d.rows() * d.patch()];
    im2col_with_into(x, d, pad, &mut cols);
    cols
}

/// Unfold f32 activations into the patch matrix (zero padding), into
/// `cols` (fully overwritten).
pub fn im2col_into(x: &[f32], d: &ConvDims, cols: &mut [f32]) {
    im2col_with_into(x, d, 0.0, cols);
}

/// Allocating wrapper over [`im2col_into`].
pub fn im2col(x: &[f32], d: &ConvDims) -> Vec<f32> {
    im2col_with(x, d, 0.0)
}

/// Fold a patch-matrix gradient `[M, C_in·k·k]` back onto the input
/// layout `[B, C_in, H, H]` (scatter-add — patches overlap), into `dx`
/// (zeroed first, so recycled buffers are safe).
pub fn col2im_into(dcols: &[f32], d: &ConvDims, dx: &mut [f32]) {
    let (ho, p, hw) = (d.hw_out(), d.patch(), d.hw);
    debug_assert_eq!(dcols.len(), d.rows() * p);
    debug_assert_eq!(dx.len(), d.batch * d.c_in * hw * hw);
    dx.fill(0.0);
    let mut r = 0;
    for n in 0..d.batch {
        for oy in 0..ho {
            for ox in 0..ho {
                let col = &dcols[r * p..(r + 1) * p];
                let mut c = 0;
                for ci in 0..d.c_in {
                    let base = (n * d.c_in + ci) * hw * hw;
                    for ky in 0..d.k {
                        let iy = (oy * d.stride + ky) as isize - d.pad as isize;
                        for kx in 0..d.k {
                            let ix = (ox * d.stride + kx) as isize - d.pad as isize;
                            if iy >= 0 && (iy as usize) < hw && ix >= 0 && (ix as usize) < hw {
                                dx[base + iy as usize * hw + ix as usize] += col[c];
                            }
                            c += 1;
                        }
                    }
                }
                r += 1;
            }
        }
    }
}

/// Allocating wrapper over [`col2im_into`].
pub fn col2im(dcols: &[f32], d: &ConvDims) -> Vec<f32> {
    let mut dx = vec![0.0f32; d.batch * d.c_in * d.hw * d.hw];
    col2im_into(dcols, d, &mut dx);
    dx
}

/// Rearrange the GEMM output `[M, C_out]` (M = B·H_out·W_out) into NCHW
/// `[B, C_out, H_out, W_out]`, into `y` (fully overwritten — every
/// output position is assigned exactly once).
pub fn rows_to_nchw_into(y2: &[f32], d: &ConvDims, y: &mut [f32]) {
    let ho = d.hw_out();
    debug_assert_eq!(y2.len(), d.rows() * d.c_out);
    debug_assert_eq!(y.len(), y2.len());
    for n in 0..d.batch {
        for s in 0..ho * ho {
            let row = &y2[(n * ho * ho + s) * d.c_out..(n * ho * ho + s + 1) * d.c_out];
            for (o, &v) in row.iter().enumerate() {
                y[(n * d.c_out + o) * ho * ho + s] = v;
            }
        }
    }
}

/// Allocating wrapper over [`rows_to_nchw_into`].
pub fn rows_to_nchw(y2: &[f32], d: &ConvDims) -> Vec<f32> {
    let mut y = vec![0.0f32; y2.len()];
    rows_to_nchw_into(y2, d, &mut y);
    y
}

/// Inverse of [`rows_to_nchw`]: NCHW gradient → GEMM row layout, into
/// `dy2` (fully overwritten).
pub fn nchw_to_rows_into(dy: &[f32], d: &ConvDims, dy2: &mut [f32]) {
    let ho = d.hw_out();
    debug_assert_eq!(dy.len(), d.rows() * d.c_out);
    debug_assert_eq!(dy2.len(), dy.len());
    for n in 0..d.batch {
        for o in 0..d.c_out {
            let plane = &dy[(n * d.c_out + o) * ho * ho..(n * d.c_out + o + 1) * ho * ho];
            for (s, &v) in plane.iter().enumerate() {
                dy2[(n * ho * ho + s) * d.c_out + o] = v;
            }
        }
    }
}

/// Allocating wrapper over [`nchw_to_rows_into`].
pub fn nchw_to_rows(dy: &[f32], d: &ConvDims) -> Vec<f32> {
    let mut dy2 = vec![0.0f32; dy.len()];
    nchw_to_rows_into(dy, d, &mut dy2);
    dy2
}

/// 2×2 average pool, stride 2.  `x`: `[B, C, H, H]`, `H` even; output
/// into `y` (`[B, C, H/2, H/2]`, fully overwritten).
pub fn avgpool2_fwd_into(x: &[f32], batch: usize, c: usize, hw: usize, y: &mut [f32]) {
    debug_assert_eq!(hw % 2, 0, "avgpool2 needs an even spatial size");
    let ho = hw / 2;
    debug_assert_eq!(y.len(), batch * c * ho * ho);
    for nc in 0..batch * c {
        let plane = &x[nc * hw * hw..(nc + 1) * hw * hw];
        let out = &mut y[nc * ho * ho..(nc + 1) * ho * ho];
        for oy in 0..ho {
            for ox in 0..ho {
                let (iy, ix) = (oy * 2, ox * 2);
                out[oy * ho + ox] = 0.25
                    * (plane[iy * hw + ix]
                        + plane[iy * hw + ix + 1]
                        + plane[(iy + 1) * hw + ix]
                        + plane[(iy + 1) * hw + ix + 1]);
            }
        }
    }
}

/// Allocating wrapper over [`avgpool2_fwd_into`].
pub fn avgpool2_fwd(x: &[f32], batch: usize, c: usize, hw: usize) -> Vec<f32> {
    let ho = hw / 2;
    let mut y = vec![0.0f32; batch * c * ho * ho];
    avgpool2_fwd_into(x, batch, c, hw, &mut y);
    y
}

/// Backward of [`avgpool2_fwd`]: spread each output gradient evenly over
/// its 2×2 window, into `dx` (fully overwritten — every input position
/// belongs to exactly one window, so each is assigned exactly once).
pub fn avgpool2_bwd_into(dy: &[f32], batch: usize, c: usize, hw: usize, dx: &mut [f32]) {
    let ho = hw / 2;
    debug_assert_eq!(dy.len(), batch * c * ho * ho);
    debug_assert_eq!(dx.len(), batch * c * hw * hw);
    for nc in 0..batch * c {
        let gout = &dy[nc * ho * ho..(nc + 1) * ho * ho];
        let gin = &mut dx[nc * hw * hw..(nc + 1) * hw * hw];
        for oy in 0..ho {
            for ox in 0..ho {
                let g = 0.25 * gout[oy * ho + ox];
                let (iy, ix) = (oy * 2, ox * 2);
                gin[iy * hw + ix] = g;
                gin[iy * hw + ix + 1] = g;
                gin[(iy + 1) * hw + ix] = g;
                gin[(iy + 1) * hw + ix + 1] = g;
            }
        }
    }
}

/// Allocating wrapper over [`avgpool2_bwd_into`].
pub fn avgpool2_bwd(dy: &[f32], batch: usize, c: usize, hw: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; batch * c * hw * hw];
    avgpool2_bwd_into(dy, batch, c, hw, &mut dx);
    dx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::matmul::{linear_fwd, matmul_dy_w};
    use crate::testing::forall;

    fn naive_conv(x: &[f32], w: &[f32], d: &ConvDims) -> Vec<f32> {
        let (ho, hw) = (d.hw_out(), d.hw);
        let mut y = vec![0.0f32; d.batch * d.c_out * ho * ho];
        for n in 0..d.batch {
            for o in 0..d.c_out {
                for oy in 0..ho {
                    for ox in 0..ho {
                        let mut acc = 0.0;
                        for ci in 0..d.c_in {
                            for ky in 0..d.k {
                                for kx in 0..d.k {
                                    let iy = (oy * d.stride + ky) as isize - d.pad as isize;
                                    let ix = (ox * d.stride + kx) as isize - d.pad as isize;
                                    let inside = iy >= 0
                                        && (iy as usize) < hw
                                        && ix >= 0
                                        && (ix as usize) < hw;
                                    if inside {
                                        let xi = x[((n * d.c_in + ci) * hw + iy as usize) * hw
                                            + ix as usize];
                                        let wi = w[((o * d.c_in + ci) * d.k + ky) * d.k + kx];
                                        acc += xi * wi;
                                    }
                                }
                            }
                        }
                        y[((n * d.c_out + o) * ho + oy) * ho + ox] = acc;
                    }
                }
            }
        }
        y
    }

    #[test]
    fn prop_im2col_gemm_matches_naive_conv() {
        forall(60, |r| {
            let d = ConvDims {
                batch: 1 + r.below(3),
                c_in: 1 + r.below(3),
                hw: 4 + 2 * r.below(3),
                c_out: 1 + r.below(4),
                k: 3,
                stride: 1,
                pad: 1,
            };
            let mut rng = r.split(5);
            let x = rng.normal_vec(d.batch * d.c_in * d.hw * d.hw, 1.0);
            let w = rng.normal_vec(d.c_out * d.patch(), 1.0);
            let cols = im2col(&x, &d);
            let y2 = linear_fwd(&cols, &w, None, d.rows(), d.patch(), d.c_out);
            let got = rows_to_nchw(&y2, &d);
            let want = naive_conv(&x, &w, &d);
            for i in 0..got.len() {
                assert!((got[i] - want[i]).abs() < 1e-4, "{i}: {} vs {}", got[i], want[i]);
            }
        });
    }

    #[test]
    fn prop_col2im_is_im2col_transpose() {
        // ⟨im2col(x), c⟩ == ⟨x, col2im(c)⟩ — the adjoint identity that
        // makes the conv input-gradient exact
        forall(60, |r| {
            let d = ConvDims {
                batch: 1 + r.below(2),
                c_in: 1 + r.below(3),
                hw: 4 + 2 * r.below(2),
                c_out: 1,
                k: 3,
                stride: 1,
                pad: 1,
            };
            let mut rng = r.split(6);
            let x = rng.normal_vec(d.batch * d.c_in * d.hw * d.hw, 1.0);
            let c = rng.normal_vec(d.rows() * d.patch(), 1.0);
            let lhs: f32 = im2col(&x, &d).iter().zip(&c).map(|(a, b)| a * b).sum();
            let rhs: f32 = x.iter().zip(col2im(&c, &d)).map(|(a, b)| a * b).sum();
            assert!((lhs - rhs).abs() < 1e-2 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
        });
    }

    #[test]
    fn conv_dx_matches_finite_difference() {
        let d = ConvDims { batch: 1, c_in: 2, hw: 4, c_out: 3, k: 3, stride: 1, pad: 1 };
        let mut rng = crate::rng::Pcg64::new(11);
        let x = rng.normal_vec(d.batch * d.c_in * d.hw * d.hw, 1.0);
        let w = rng.normal_vec(d.c_out * d.patch(), 0.5);
        let dout = rng.normal_vec(d.rows() * d.c_out, 1.0); // NCHW layout
        let loss = |xv: &[f32]| -> f32 {
            let cols = im2col(xv, &d);
            let y2 = linear_fwd(&cols, &w, None, d.rows(), d.patch(), d.c_out);
            rows_to_nchw(&y2, &d).iter().zip(&dout).map(|(a, b)| a * b).sum()
        };
        let dy2 = nchw_to_rows(&dout, &d);
        let dcols = matmul_dy_w(&dy2, &w, d.rows(), d.c_out, d.patch());
        let dx = col2im(&dcols, &d);
        // the map is linear in x, so a large step costs no curvature
        // error and drowns f32 cancellation noise
        let eps = 1e-2;
        for i in [0usize, 5, 13, 31] {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = (loss(&xp) - loss(&xm)) / (2.0 * eps);
            assert!((dx[i] - num).abs() < 1e-2, "dx[{i}]: {} vs {num}", dx[i]);
        }
    }

    #[test]
    fn nchw_row_layout_round_trips() {
        let d = ConvDims { batch: 2, c_in: 1, hw: 4, c_out: 3, k: 3, stride: 1, pad: 1 };
        let n = d.rows() * d.c_out;
        let y2: Vec<f32> = (0..n).map(|i| i as f32).collect();
        assert_eq!(nchw_to_rows(&rows_to_nchw(&y2, &d), &d), y2);
    }

    #[test]
    fn avgpool_round_trip_conserves_gradient_mass() {
        let (b, c, hw) = (2, 3, 6);
        let mut rng = crate::rng::Pcg64::new(3);
        let x = rng.normal_vec(b * c * hw * hw, 1.0);
        let y = avgpool2_fwd(&x, b, c, hw);
        assert_eq!(y.len(), b * c * 9);
        // mean of means equals global mean
        let mx: f32 = x.iter().sum::<f32>() / x.len() as f32;
        let my: f32 = y.iter().sum::<f32>() / y.len() as f32;
        assert!((mx - my).abs() < 1e-5);
        let dy = vec![1.0f32; y.len()];
        let dx = avgpool2_bwd(&dy, b, c, hw);
        // each input contributes 1/4 of one output
        assert!(dx.iter().all(|&g| (g - 0.25).abs() < 1e-7));
    }

    #[test]
    fn into_variants_overwrite_dirty_buffers() {
        // recycled workspace buffers carry residue; every into-kernel
        // must produce the same bits as its allocating wrapper anyway
        let d = ConvDims { batch: 2, c_in: 2, hw: 4, c_out: 3, k: 3, stride: 1, pad: 1 };
        let mut rng = crate::rng::Pcg64::new(21);
        let x = rng.normal_vec(d.batch * d.c_in * d.hw * d.hw, 1.0);
        let mut cols = vec![5.0f32; d.rows() * d.patch()];
        im2col_into(&x, &d, &mut cols);
        assert_eq!(cols, im2col(&x, &d));
        let mut dx = vec![5.0f32; x.len()];
        col2im_into(&cols, &d, &mut dx);
        assert_eq!(dx, col2im(&cols, &d));
        let y2 = rng.normal_vec(d.rows() * d.c_out, 1.0);
        let mut y = vec![5.0f32; y2.len()];
        rows_to_nchw_into(&y2, &d, &mut y);
        assert_eq!(y, rows_to_nchw(&y2, &d));
        let mut back = vec![5.0f32; y2.len()];
        nchw_to_rows_into(&y, &d, &mut back);
        assert_eq!(back, y2);
        let (b, c, hw) = (1, 2, 4);
        let px = rng.normal_vec(b * c * hw * hw, 1.0);
        let mut py = vec![5.0f32; b * c * 4];
        avgpool2_fwd_into(&px, b, c, hw, &mut py);
        assert_eq!(py, avgpool2_fwd(&px, b, c, hw));
        let mut pdx = vec![5.0f32; px.len()];
        avgpool2_bwd_into(&py, b, c, hw, &mut pdx);
        assert_eq!(pdx, avgpool2_bwd(&py, b, c, hw));
    }
}
