//! Scaled-dot-product attention over head-merged `[B, T, D]` layouts —
//! mirrors `python/compile/models/transformer_common.py::mha_fwd/bwd`
//! without materializing the `[B, H, T, d_h]` transposes: head `h` of
//! token `t` lives at `data[(n·T + t)·D + h·d_h ..]`, so the einsums
//! become strided dot products over that slice.
//!
//! The Q/K/V/O *projections* are not part of these ops — they are
//! ordinary quantized-linear sites owned by the graph executor (that is
//! what makes their output channels freezable like any other layer).

/// Geometry of one attention op.
#[derive(Clone, Copy, Debug)]
pub struct AttnDims {
    pub batch: usize,
    /// Sequence length.
    pub t: usize,
    /// Model width; must be divisible by `heads`.
    pub d: usize,
    pub heads: usize,
}

impl AttnDims {
    pub fn d_head(&self) -> usize {
        self.d / self.heads
    }

    fn scale(&self) -> f32 {
        1.0 / (self.d_head() as f32).sqrt()
    }
}

/// Forward: `out = softmax(Q·Kᵀ/√d_h [causal-masked]) · V`, into
/// caller-provided buffers (fed from a [`crate::exec::Workspace`] on
/// the hot paths).
///
/// `q`/`k`/`v`/`out` are `[B, T, D]` head-merged; the probability
/// tensor `p` is `[B, H, T, T]` (the backward cache).  `out` and `p`
/// are fully overwritten; `scores` is a length-`T` scratch row.  Causal
/// masking zeroes the probabilities above the diagonal, so the backward
/// needs no explicit mask.
#[allow(clippy::too_many_arguments)] // an attention ABI: operands, dims, outputs, scratch
pub fn sdpa_fwd_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dm: &AttnDims,
    causal: bool,
    out: &mut [f32],
    p: &mut [f32],
    scores: &mut [f32],
) {
    let (b, t, d, h) = (dm.batch, dm.t, dm.d, dm.heads);
    let dh = dm.d_head();
    let alpha = dm.scale();
    debug_assert_eq!(q.len(), b * t * d);
    debug_assert_eq!(out.len(), b * t * d);
    debug_assert_eq!(p.len(), b * h * t * t);
    debug_assert_eq!(scores.len(), t);
    out.fill(0.0);
    p.fill(0.0);
    let at = |n: usize, i: usize, hd: usize| (n * t + i) * d + hd * dh;
    for n in 0..b {
        for hd in 0..h {
            for i in 0..t {
                let jmax = if causal { i + 1 } else { t };
                let qr = &q[at(n, i, hd)..at(n, i, hd) + dh];
                let mut mx = f32::NEG_INFINITY;
                for (j, sc) in scores.iter_mut().enumerate().take(jmax) {
                    let kr = &k[at(n, j, hd)..at(n, j, hd) + dh];
                    let mut acc = 0.0f32;
                    for c in 0..dh {
                        acc += qr[c] * kr[c];
                    }
                    *sc = acc * alpha;
                    mx = mx.max(*sc);
                }
                let mut sum = 0.0f32;
                for sc in scores.iter_mut().take(jmax) {
                    *sc = (*sc - mx).exp();
                    sum += *sc;
                }
                let prow = &mut p[((n * h + hd) * t + i) * t..((n * h + hd) * t + i + 1) * t];
                for j in 0..jmax {
                    prow[j] = scores[j] / sum;
                }
                let orow = &mut out[at(n, i, hd)..at(n, i, hd) + dh];
                for (j, &pj) in prow.iter().enumerate().take(jmax) {
                    if pj == 0.0 {
                        continue;
                    }
                    let vr = &v[at(n, j, hd)..at(n, j, hd) + dh];
                    for c in 0..dh {
                        orow[c] += pj * vr[c];
                    }
                }
            }
        }
    }
}

/// Allocating wrapper over [`sdpa_fwd_into`]; returns `(out, p)`.
pub fn sdpa_fwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dm: &AttnDims,
    causal: bool,
) -> (Vec<f32>, Vec<f32>) {
    let (b, t, d, h) = (dm.batch, dm.t, dm.d, dm.heads);
    let mut out = vec![0.0f32; b * t * d];
    let mut p = vec![0.0f32; b * h * t * t];
    let mut scores = vec![0.0f32; t];
    sdpa_fwd_into(q, k, v, dm, causal, &mut out, &mut p, &mut scores);
    (out, p)
}

/// Backward of [`sdpa_fwd_into`], into `dq`/`dk`/`dv` (head-merged
/// `[B, T, D]`, fully overwritten — zeroed first, so recycled buffers
/// are safe).  `p` is the cached probability tensor; masked positions
/// carry `p = 0` and therefore contribute no gradient.  `dp` is a
/// length-`T` scratch row.
#[allow(clippy::too_many_arguments)] // a VJP ABI: cotangent, operands, cache, dims, outputs
pub fn sdpa_bwd_into(
    dout: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    p: &[f32],
    dm: &AttnDims,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
    dp: &mut [f32],
) {
    let (b, t, d, h) = (dm.batch, dm.t, dm.d, dm.heads);
    let dh = dm.d_head();
    let alpha = dm.scale();
    debug_assert_eq!(dq.len(), b * t * d);
    debug_assert_eq!(dk.len(), b * t * d);
    debug_assert_eq!(dv.len(), b * t * d);
    debug_assert_eq!(dp.len(), t);
    dq.fill(0.0);
    dk.fill(0.0);
    dv.fill(0.0);
    let at = |n: usize, i: usize, hd: usize| (n * t + i) * d + hd * dh;
    for n in 0..b {
        for hd in 0..h {
            for i in 0..t {
                let dor = &dout[at(n, i, hd)..at(n, i, hd) + dh];
                let prow = &p[((n * h + hd) * t + i) * t..((n * h + hd) * t + i + 1) * t];
                // dp[j] = ⟨dout_i, v_j⟩ ; dv_j += p_ij · dout_i
                for j in 0..t {
                    if prow[j] == 0.0 {
                        dp[j] = 0.0;
                        continue;
                    }
                    let vr = &v[at(n, j, hd)..at(n, j, hd) + dh];
                    let dvr = &mut dv[at(n, j, hd)..at(n, j, hd) + dh];
                    let mut acc = 0.0f32;
                    for c in 0..dh {
                        acc += dor[c] * vr[c];
                        dvr[c] += prow[j] * dor[c];
                    }
                    dp[j] = acc;
                }
                // softmax backward: ds = p ⊙ (dp - ⟨dp, p⟩), then ·α
                let dot: f32 = dp.iter().zip(prow).map(|(a, b)| a * b).sum();
                let qr = &q[at(n, i, hd)..at(n, i, hd) + dh];
                let dqr_base = at(n, i, hd);
                for j in 0..t {
                    let ds = prow[j] * (dp[j] - dot) * alpha;
                    if ds == 0.0 {
                        continue;
                    }
                    let kr = &k[at(n, j, hd)..at(n, j, hd) + dh];
                    let dkr = &mut dk[at(n, j, hd)..at(n, j, hd) + dh];
                    for c in 0..dh {
                        dq[dqr_base + c] += ds * kr[c];
                        dkr[c] += ds * qr[c];
                    }
                }
            }
        }
    }
}

/// Allocating wrapper over [`sdpa_bwd_into`]; returns `(dq, dk, dv)`.
pub fn sdpa_bwd(
    dout: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    p: &[f32],
    dm: &AttnDims,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let n = dm.batch * dm.t * dm.d;
    let mut dq = vec![0.0f32; n];
    let mut dk = vec![0.0f32; n];
    let mut dv = vec![0.0f32; n];
    let mut dp = vec![0.0f32; dm.t];
    sdpa_bwd_into(dout, q, k, v, p, dm, &mut dq, &mut dk, &mut dv, &mut dp);
    (dq, dk, dv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn probabilities_are_rowwise_softmax() {
        let dm = AttnDims { batch: 2, t: 4, d: 6, heads: 2 };
        let mut rng = Pcg64::new(1);
        let q = rng.normal_vec(2 * 4 * 6, 1.0);
        let k = rng.normal_vec(2 * 4 * 6, 1.0);
        let v = rng.normal_vec(2 * 4 * 6, 1.0);
        for causal in [false, true] {
            let (_, p) = sdpa_fwd(&q, &k, &v, &dm, causal);
            for (ri, row) in p.chunks(4).enumerate() {
                let sum: f32 = row.iter().sum();
                assert!((sum - 1.0).abs() < 1e-5, "row {ri} sums to {sum}");
                if causal {
                    let i = ri % 4;
                    for (j, &pj) in row.iter().enumerate() {
                        assert!(j <= i || pj == 0.0, "causal leak at ({i},{j})");
                    }
                }
            }
        }
    }

    #[test]
    fn backward_matches_finite_difference() {
        let dm = AttnDims { batch: 1, t: 3, d: 4, heads: 2 };
        let n = dm.batch * dm.t * dm.d;
        let mut rng = Pcg64::new(5);
        let q = rng.normal_vec(n, 0.8);
        let k = rng.normal_vec(n, 0.8);
        let v = rng.normal_vec(n, 0.8);
        let dout = rng.normal_vec(n, 1.0);
        for causal in [false, true] {
            let loss = |qv: &[f32], kv: &[f32], vv: &[f32]| -> f32 {
                let (o, _) = sdpa_fwd(qv, kv, vv, &dm, causal);
                o.iter().zip(&dout).map(|(a, b)| a * b).sum()
            };
            let (_, p) = sdpa_fwd(&q, &k, &v, &dm, causal);
            let grads = sdpa_bwd(&dout, &q, &k, &v, &p, &dm);
            let analytic = [&grads.0, &grads.1, &grads.2];
            let eps = 1e-3;
            for i in 0..n {
                for (which, name) in ["dq", "dk", "dv"].iter().enumerate() {
                    let perturbed = |delta: f32| -> f32 {
                        let mut qv = q.clone();
                        let mut kv = k.clone();
                        let mut vv = v.clone();
                        match which {
                            0 => qv[i] += delta,
                            1 => kv[i] += delta,
                            _ => vv[i] += delta,
                        }
                        loss(&qv, &kv, &vv)
                    };
                    let num = (perturbed(eps) - perturbed(-eps)) / (2.0 * eps);
                    let got = analytic[which][i];
                    assert!(
                        (got - num).abs() < 5e-3,
                        "{name}[{i}]: {got} vs {num} (causal {causal})"
                    );
                }
            }
        }
    }
}
