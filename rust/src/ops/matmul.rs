//! GEMM kernels for the native backend — the hot path of every step.
//!
//! Three matmuls cover the whole linear-layer VJP (paper Eq. 5):
//!
//! * [`linear_fwd`]   `y[b,o]  = Σ_i x[b,i]·w[o,i] (+ bias[o])`
//! * [`matmul_dy_w`]  `dx[b,i] = Σ_o dy[b,o]·w[o,i]`  (always dense)
//! * [`matmul_dyt_x`] `dw[o,i] = Σ_b dy[b,o]·x[b,i]`  (full weight grad)
//! * [`partial_dw`]   the paper's Fig. 1 (right): only the gathered
//!   unfrozen rows of `dw` are ever materialized.
//!
//! Each kernel comes in two forms: an `_into` variant that writes a
//! caller-provided `&mut [f32]` (the planned executors feed these from a
//! [`crate::exec::Workspace`], so the steady state never touches the
//! allocator) and a thin allocating wrapper with the historical
//! signature for tests and cold paths.
//!
//! All kernels are cache-blocked over the contraction dim (`KC`) and
//! split their *output rows* across `std::thread` workers when the work
//! exceeds `PAR_MIN_FLOPS` — each thread owns a disjoint `&mut` chunk
//! of the output, so results are deterministic regardless of thread
//! count (no atomic accumulation, no reduction-order wobble).  The
//! worker count follows `std::thread::available_parallelism()` unless
//! the `EFQAT_THREADS` environment variable overrides it (read once per
//! process; benches and CI set it for reproducible numbers across
//! machines).
//!
//! The innermost loops — the forward block dot and the backward fused
//! `y += a·x` — come from the runtime-dispatched f32 SIMD registry
//! ([`crate::ops::simd::active_f32`], governed by `EFQAT_SIMD` like the
//! int8 serving GEMM).  The kernel is resolved **once per GEMM call,
//! before the row split**, so every worker thread of one GEMM runs the
//! same kernel even if a test re-forces dispatch concurrently.  The
//! scalar entry reproduces the pre-dispatch loops bit-for-bit; the
//! vector entries are tolerance-equal (FMA) but individually
//! deterministic — see the family contract in [`crate::ops::simd`].
//!
//! The process-wide ceiling can additionally be lowered *per calling
//! thread* via [`set_thread_cap`]: the data-parallel trainer splits
//! `EFQAT_THREADS` across its shard workers so `W` concurrent shards do
//! not oversubscribe the machine (each worker caps its own GEMMs at
//! `EFQAT_THREADS / W`).  The cap is thread-local, so a capped shard
//! worker never perturbs GEMMs issued from other threads, and it only
//! ever changes *how many* workers split the rows — never the result
//! (disjoint output rows are deterministic at any worker count).

use std::cell::Cell;
use std::sync::OnceLock;
use std::thread;

/// Contraction-dim block: 128 f32 ≈ half a 1 KiB L1 line budget per
/// operand row, small enough that `x` and `w` blocks stay resident.
const KC: usize = 128;

/// Minimum fused-multiply-adds before spawning threads pays for itself.
const PAR_MIN_FLOPS: usize = 1 << 18;

/// Parse an `EFQAT_THREADS` value; `None`/empty/zero/garbage means "no
/// override".
fn parse_threads(v: Option<String>) -> Option<usize> {
    v.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&n| n > 0)
}

/// Hardware (or `EFQAT_THREADS`-overridden) worker ceiling, resolved
/// once per process — `available_parallelism` is a syscall and the env
/// lookup allocates, neither belongs in a per-GEMM path.
fn hw_threads() -> usize {
    static CEILING: OnceLock<usize> = OnceLock::new();
    *CEILING.get_or_init(|| {
        parse_threads(std::env::var("EFQAT_THREADS").ok())
            .unwrap_or_else(|| thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    })
}

thread_local! {
    /// Per-thread ceiling override; 0 means "no override" (use the
    /// process-wide [`hw_threads`] ceiling).
    static THREAD_CAP: Cell<usize> = const { Cell::new(0) };
}

/// Cap the GEMM worker count for kernels issued *from the calling
/// thread*; `0` clears the cap.  Spawned shard workers set this once per
/// step so concurrent shards share the machine instead of each claiming
/// the full `EFQAT_THREADS` ceiling.
pub fn set_thread_cap(cap: usize) {
    THREAD_CAP.with(|c| c.set(cap));
}

/// The calling thread's current GEMM worker cap (0 = uncapped).
pub fn thread_cap() -> usize {
    THREAD_CAP.with(|c| c.get())
}

/// The process-wide worker ceiling (`EFQAT_THREADS` or the hardware
/// parallelism) — what a per-thread cap divides across shard workers.
pub fn total_threads() -> usize {
    hw_threads()
}

fn thread_count(rows: usize, flops_per_row: usize) -> usize {
    if rows == 0 {
        return 1;
    }
    let ceiling = match thread_cap() {
        0 => hw_threads(),
        cap => cap.min(hw_threads()),
    };
    let by_work = (rows.saturating_mul(flops_per_row) / PAR_MIN_FLOPS).max(1);
    ceiling.min(by_work).min(rows)
}

/// The worker count [`par_rows`] / [`par_rows_scratch`] would use for
/// this shape — callers sizing per-worker scratch from a workspace need
/// the same answer the splitter will compute.
pub(crate) fn planned_threads(rows: usize, flops_per_row: usize) -> usize {
    thread_count(rows, flops_per_row)
}

/// Run `body(first_row, rows_chunk)` over `out` split row-wise across
/// threads.  `out` must hold `rows * row_elems` values.  Generic over the
/// output element so the f32 GEMMs here and the int8 serving kernels
/// ([`crate::ops::qmatmul`]) share one deterministic work-splitting rule.
pub(crate) fn par_rows<T, F>(
    out: &mut [T],
    rows: usize,
    row_elems: usize,
    flops_per_row: usize,
    body: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if out.is_empty() || row_elems == 0 {
        return;
    }
    let nt = thread_count(rows, flops_per_row);
    if nt <= 1 {
        body(0, out);
        return;
    }
    let chunk = rows.div_ceil(nt);
    thread::scope(|s| {
        for (ci, chunk_rows) in out.chunks_mut(chunk * row_elems).enumerate() {
            let body = &body;
            s.spawn(move || body(ci * chunk, chunk_rows));
        }
    });
}

/// [`par_rows`] with per-worker scratch: `scratch` is pre-split into
/// `scratch_per`-element chunks, one per worker, so kernels that need a
/// private accumulator (the int8 GEMM) can draw it from a workspace
/// instead of allocating inside every spawned thread.  `scratch` must
/// hold at least `planned_threads(rows, flops_per_row) * scratch_per`
/// elements.
pub(crate) fn par_rows_scratch<T, S, F>(
    out: &mut [T],
    rows: usize,
    row_elems: usize,
    flops_per_row: usize,
    scratch: &mut [S],
    scratch_per: usize,
    body: F,
) where
    T: Send,
    S: Send,
    F: Fn(usize, &mut [T], &mut [S]) + Sync,
{
    if out.is_empty() || row_elems == 0 {
        return;
    }
    let nt = thread_count(rows, flops_per_row);
    debug_assert!(scratch.len() >= nt * scratch_per, "scratch under-sized for {nt} workers");
    if nt <= 1 {
        body(0, out, &mut scratch[..scratch_per]);
        return;
    }
    let chunk = rows.div_ceil(nt);
    thread::scope(|s| {
        let chunks = out.chunks_mut(chunk * row_elems);
        for ((ci, chunk_rows), sc) in chunks.enumerate().zip(scratch.chunks_mut(scratch_per)) {
            let body = &body;
            s.spawn(move || body(ci * chunk, chunk_rows, sc));
        }
    });
}

/// `y[b,o] = Σ_i x[b,i]·w[o,i] (+ bias[o])` — x: `[m,k]`, w: `[n,k]`,
/// into caller-provided `y` (`[m,n]`, fully overwritten).
pub fn linear_fwd_into(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
    y: &mut [f32],
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), n * k);
    debug_assert_eq!(y.len(), m * n);
    // resolve the dispatched kernel once, outside the worker threads
    let kf = crate::ops::simd::active_f32();
    par_rows(y, m, n, k * n, |r0, rows| {
        for (ri, yr) in rows.chunks_mut(n).enumerate() {
            let xr = &x[(r0 + ri) * k..(r0 + ri + 1) * k];
            match bias {
                Some(b) => yr.copy_from_slice(b),
                None => yr.fill(0.0),
            }
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + KC).min(k);
                let xb = &xr[k0..k1];
                for (o, yo) in yr.iter_mut().enumerate() {
                    *yo += (kf.dot)(xb, &w[o * k + k0..o * k + k1]);
                }
                k0 = k1;
            }
        }
    });
}

/// Allocating wrapper over [`linear_fwd_into`].
pub fn linear_fwd(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    let mut y = vec![0.0f32; m * n];
    linear_fwd_into(x, w, bias, m, k, n, &mut y);
    y
}

/// `dx[b,i] = Σ_o dy[b,o]·w[o,i]` — the full input gradient (always
/// computed dense, like QAT: Eq. 5's first matmul), into `dx` (`[m,k]`,
/// fully overwritten).
pub fn matmul_dy_w_into(dy: &[f32], w: &[f32], m: usize, n: usize, k: usize, dx: &mut [f32]) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), n * k);
    debug_assert_eq!(dx.len(), m * k);
    let kf = crate::ops::simd::active_f32();
    par_rows(dx, m, k, n * k, |r0, rows| {
        for (ri, dxr) in rows.chunks_mut(k).enumerate() {
            dxr.fill(0.0);
            let dyr = &dy[(r0 + ri) * n..(r0 + ri + 1) * n];
            for (o, &g) in dyr.iter().enumerate() {
                // relu-gated rows are mostly zero — skip them before the
                // kernel call, identically under every dispatch choice
                if g == 0.0 {
                    continue;
                }
                (kf.axpy)(g, &w[o * k..(o + 1) * k], dxr);
            }
        }
    });
}

/// Allocating wrapper over [`matmul_dy_w_into`].
pub fn matmul_dy_w(dy: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; m * k];
    matmul_dy_w_into(dy, w, m, n, k, &mut dx);
    dx
}

/// `dw[o,i] = Σ_b dy[b,o]·x[b,i]` — the full weight gradient, into `dw`
/// (`[n,k]`, fully overwritten).
pub fn matmul_dyt_x_into(dy: &[f32], x: &[f32], m: usize, n: usize, k: usize, dw: &mut [f32]) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dw.len(), n * k);
    let kf = crate::ops::simd::active_f32();
    par_rows(dw, n, k, m * k, |o0, rows| {
        rows.fill(0.0);
        for b in 0..m {
            let xr = &x[b * k..(b + 1) * k];
            for (oi, dwr) in rows.chunks_mut(k).enumerate() {
                let g = dy[b * n + o0 + oi];
                if g == 0.0 {
                    continue;
                }
                (kf.axpy)(g, xr, dwr);
            }
        }
    });
}

/// Allocating wrapper over [`matmul_dyt_x_into`].
pub fn matmul_dyt_x(dy: &[f32], x: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut dw = vec![0.0f32; n * k];
    matmul_dyt_x_into(dy, x, m, n, k, &mut dw);
    dw
}

/// Partial weight gradient (paper Fig. 1 right, mirrors
/// `kernels/ref.py::partial_dw_ref`): `dw[r,i] = Σ_b dy[b,idx[r]]·x[b,i]`
/// — only the `idx.len()` unfrozen rows are ever materialized, into `dw`
/// (`[idx.len(),k]`, fully overwritten).
pub fn partial_dw_into(
    dy: &[f32],
    x: &[f32],
    idx: &[usize],
    m: usize,
    n: usize,
    k: usize,
    dw: &mut [f32],
) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dw.len(), idx.len() * k);
    let kf = crate::ops::simd::active_f32();
    par_rows(dw, idx.len(), k, m * k, |r0, rows| {
        rows.fill(0.0);
        for b in 0..m {
            let xr = &x[b * k..(b + 1) * k];
            for (ri, dwr) in rows.chunks_mut(k).enumerate() {
                let g = dy[b * n + idx[r0 + ri]];
                if g == 0.0 {
                    continue;
                }
                (kf.axpy)(g, xr, dwr);
            }
        }
    });
}

/// Allocating wrapper over [`partial_dw_into`].
pub fn partial_dw(dy: &[f32], x: &[f32], idx: &[usize], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut dw = vec![0.0f32; idx.len() * k];
    partial_dw_into(dy, x, idx, m, n, k, &mut dw);
    dw
}

/// `db[o] = Σ_b dy[b,o]` — the bias gradient (column sum), into `db`
/// (`[n]`, fully overwritten).
pub fn col_sum_into(dy: &[f32], m: usize, n: usize, db: &mut [f32]) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(db.len(), n);
    db.fill(0.0);
    for b in 0..m {
        let dyr = &dy[b * n..(b + 1) * n];
        for o in 0..n {
            db[o] += dyr[o];
        }
    }
}

/// Allocating wrapper over [`col_sum_into`].
pub fn col_sum(dy: &[f32], m: usize, n: usize) -> Vec<f32> {
    let mut db = vec![0.0f32; n];
    col_sum_into(dy, m, n, &mut db);
    db
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    fn naive_fwd(
        x: &[f32],
        w: &[f32],
        bias: Option<&[f32]>,
        m: usize,
        k: usize,
        n: usize,
    ) -> Vec<f32> {
        let mut y = vec![0.0; m * n];
        for b in 0..m {
            for o in 0..n {
                let mut acc = bias.map_or(0.0, |bv| bv[o]);
                for i in 0..k {
                    acc += x[b * k + i] * w[o * k + i];
                }
                y[b * n + o] = acc;
            }
        }
        y
    }

    #[test]
    fn prop_linear_fwd_matches_naive() {
        forall(100, |r| {
            let (m, k, n) = (1 + r.below(5), 1 + r.below(200), 1 + r.below(6));
            let mut rng = r.split(1);
            let x = rng.normal_vec(m * k, 1.0);
            let w = rng.normal_vec(n * k, 1.0);
            let b = rng.normal_vec(n, 1.0);
            let got = linear_fwd(&x, &w, Some(&b), m, k, n);
            let want = naive_fwd(&x, &w, Some(&b), m, k, n);
            for i in 0..m * n {
                assert!((got[i] - want[i]).abs() < 1e-4, "{}: {} vs {}", i, got[i], want[i]);
            }
        });
    }

    #[test]
    fn into_variants_overwrite_dirty_buffers() {
        // the planned executors hand these kernels recycled buffers: any
        // residue from a previous step must be overwritten, not summed
        let (m, k, n) = (3, 5, 4);
        let mut rng = crate::rng::Pcg64::new(17);
        let x = rng.normal_vec(m * k, 1.0);
        let w = rng.normal_vec(n * k, 1.0);
        let dy = rng.normal_vec(m * n, 1.0);
        let mut y = vec![99.0f32; m * n];
        linear_fwd_into(&x, &w, None, m, k, n, &mut y);
        assert_eq!(y, linear_fwd(&x, &w, None, m, k, n));
        let mut dx = vec![-7.0f32; m * k];
        matmul_dy_w_into(&dy, &w, m, n, k, &mut dx);
        assert_eq!(dx, matmul_dy_w(&dy, &w, m, n, k));
        let mut dw = vec![3.0f32; n * k];
        matmul_dyt_x_into(&dy, &x, m, n, k, &mut dw);
        assert_eq!(dw, matmul_dyt_x(&dy, &x, m, n, k));
        let idx = [2usize, 0];
        let mut dp = vec![8.0f32; idx.len() * k];
        partial_dw_into(&dy, &x, &idx, m, n, k, &mut dp);
        assert_eq!(dp, partial_dw(&dy, &x, &idx, m, n, k));
        let mut db = vec![5.0f32; n];
        col_sum_into(&dy, m, n, &mut db);
        assert_eq!(db, col_sum(&dy, m, n));
    }

    #[test]
    fn prop_backward_matmuls_match_naive() {
        forall(100, |r| {
            let (m, k, n) = (1 + r.below(6), 1 + r.below(150), 1 + r.below(8));
            let mut rng = r.split(2);
            let dy = rng.normal_vec(m * n, 1.0);
            let x = rng.normal_vec(m * k, 1.0);
            let w = rng.normal_vec(n * k, 1.0);
            let dx = matmul_dy_w(&dy, &w, m, n, k);
            let dw = matmul_dyt_x(&dy, &x, m, n, k);
            for b in 0..m {
                for i in 0..k {
                    let want: f32 = (0..n).map(|o| dy[b * n + o] * w[o * k + i]).sum();
                    assert!((dx[b * k + i] - want).abs() < 1e-4);
                }
            }
            for o in 0..n {
                for i in 0..k {
                    let want: f32 = (0..m).map(|b| dy[b * n + o] * x[b * k + i]).sum();
                    assert!((dw[o * k + i] - want).abs() < 1e-4);
                }
            }
        });
    }

    #[test]
    fn prop_partial_dw_is_gathered_full_dw() {
        forall(100, |r| {
            let (m, k, n) = (2 + r.below(4), 1 + r.below(40), 2 + r.below(10));
            let mut rng = r.split(3);
            let dy = rng.normal_vec(m * n, 1.0);
            let x = rng.normal_vec(m * k, 1.0);
            let nk = 1 + r.below(n);
            let idx = {
                let mut rng2 = r.split(4);
                rng2.choice(n, nk)
            };
            let full = matmul_dyt_x(&dy, &x, m, n, k);
            let part = partial_dw(&dy, &x, &idx, m, n, k);
            for (ri, &o) in idx.iter().enumerate() {
                for i in 0..k {
                    let a = full[o * k + i];
                    let b = part[ri * k + i];
                    assert!((a - b).abs() < 1e-5, "row {o}: {a} vs {b}");
                }
            }
        });
    }

    #[test]
    fn col_sum_is_bias_grad() {
        let dy = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // [2, 3]
        assert_eq!(col_sum(&dy, 2, 3), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn large_shapes_parallelize_consistently() {
        // big enough to cross PAR_MIN_FLOPS: result must equal the naive
        // single-thread answer exactly (disjoint output rows — no
        // reduction-order dependence)
        let (m, k, n) = (64, 300, 48);
        let mut rng = crate::rng::Pcg64::new(7);
        let x = rng.normal_vec(m * k, 1.0);
        let w = rng.normal_vec(n * k, 1.0);
        let got = linear_fwd(&x, &w, None, m, k, n);
        let want = naive_fwd(&x, &w, None, m, k, n);
        for i in 0..m * n {
            assert!((got[i] - want[i]).abs() < 1e-3, "{i}");
        }
    }

    #[test]
    fn empty_inputs_do_not_panic() {
        assert!(linear_fwd(&[], &[], None, 0, 4, 0).is_empty());
        assert!(partial_dw(&[], &[], &[], 0, 0, 4).is_empty());
    }

    #[test]
    fn thread_override_parses_defensively() {
        assert_eq!(parse_threads(Some("4".into())), Some(4));
        assert_eq!(parse_threads(Some(" 2 ".into())), Some(2));
        // zero / garbage / unset all mean "no override"
        assert_eq!(parse_threads(Some("0".into())), None);
        assert_eq!(parse_threads(Some("lots".into())), None);
        assert_eq!(parse_threads(None), None);
    }

    #[test]
    fn per_thread_cap_bounds_the_split_and_clears() {
        // far above PAR_MIN_FLOPS so only the ceiling binds
        let uncapped = planned_threads(64, 1 << 20);
        set_thread_cap(1);
        assert_eq!(planned_threads(64, 1 << 20), 1);
        set_thread_cap(2);
        assert!(planned_threads(64, 1 << 20) <= 2);
        set_thread_cap(usize::MAX);
        assert_eq!(planned_threads(64, 1 << 20), uncapped, "cap never raises the ceiling");
        set_thread_cap(0);
        assert_eq!(planned_threads(64, 1 << 20), uncapped);
    }

    #[test]
    fn cap_is_thread_local() {
        set_thread_cap(0);
        let uncapped = planned_threads(64, 1 << 20);
        std::thread::scope(|s| {
            s.spawn(|| {
                set_thread_cap(1);
                assert_eq!(planned_threads(64, 1 << 20), 1);
            });
        });
        // the spawned worker's cap must not leak to this thread
        assert_eq!(planned_threads(64, 1 << 20), uncapped);
    }

    #[test]
    fn capped_gemm_matches_uncapped_bitwise() {
        // the cap changes the row split only — outputs are disjoint, so
        // the result is identical at any worker count
        let (m, k, n) = (64, 300, 48);
        let mut rng = crate::rng::Pcg64::new(11);
        let x = rng.normal_vec(m * k, 1.0);
        let w = rng.normal_vec(n * k, 1.0);
        let full = linear_fwd(&x, &w, None, m, k, n);
        set_thread_cap(1);
        let capped = linear_fwd(&x, &w, None, m, k, n);
        set_thread_cap(0);
        assert_eq!(full, capped);
    }

    #[test]
    fn scratch_splitter_matches_plain_splitter() {
        // par_rows_scratch must partition rows exactly like par_rows and
        // hand every worker a private scratch chunk
        let (rows, re) = (10usize, 3usize);
        let mut out = vec![0u32; rows * re];
        let nt = planned_threads(rows, 1 << 20);
        let mut scratch = vec![0u8; nt.max(1) * 2];
        par_rows_scratch(&mut out, rows, re, 1 << 20, &mut scratch, 2, |r0, chunk, sc| {
            assert_eq!(sc.len(), 2);
            for (ri, row) in chunk.chunks_mut(re).enumerate() {
                row.fill((r0 + ri) as u32);
            }
        });
        for r in 0..rows {
            assert!(out[r * re..(r + 1) * re].iter().all(|&v| v == r as u32), "row {r}");
        }
    }
}
