//! ReLU and the embedding lookup — the two "everything else" ops of the
//! native model families (`python/compile/layers.py::relu_*` /
//! `embedding_*`).  Embeddings are fp32 and non-freezable: per the
//! paper's transformer setup they train during FP pretraining only, so
//! their backward exists but is never row-gated.
//!
//! Like the rest of the op library, each kernel has an `_into` form
//! writing caller-provided slices plus a thin allocating wrapper.

/// `y = max(x, 0)`, into `y` (fully overwritten).
pub fn relu_fwd_into(x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (o, &v) in y.iter_mut().zip(x) {
        *o = v.max(0.0);
    }
}

/// Allocating wrapper over [`relu_fwd_into`].
pub fn relu_fwd(x: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0; x.len()];
    relu_fwd_into(x, &mut y);
    y
}

/// ReLU backward against the cached *pre-activation*, into `dx` (fully
/// overwritten).
pub fn relu_bwd_into(dy: &[f32], pre: &[f32], dx: &mut [f32]) {
    debug_assert_eq!(dy.len(), pre.len());
    debug_assert_eq!(dy.len(), dx.len());
    for i in 0..dy.len() {
        dx[i] = if pre[i] > 0.0 { dy[i] } else { 0.0 };
    }
}

/// Allocating wrapper over [`relu_bwd_into`].
pub fn relu_bwd(dy: &[f32], pre: &[f32]) -> Vec<f32> {
    let mut dx = vec![0.0; dy.len()];
    relu_bwd_into(dy, pre, &mut dx);
    dx
}

/// Token + learned-position embedding: `y[n,t] = tok[ids[n,t]] + pos[t]`,
/// into `y` (`[B·T, D]`, fully overwritten).
///
/// `tok`: `[V, D]`, `pos`: `[T, D]`, `ids`: `[B·T]`.
pub fn embed_fwd_into(tok: &[f32], pos: &[f32], ids: &[i32], t: usize, d: usize, y: &mut [f32]) {
    debug_assert_eq!(y.len(), ids.len() * d);
    for (r, &id) in ids.iter().enumerate() {
        let tr = &tok[id as usize * d..(id as usize + 1) * d];
        let pr = &pos[(r % t) * d..(r % t + 1) * d];
        let yr = &mut y[r * d..(r + 1) * d];
        for c in 0..d {
            yr[c] = tr[c] + pr[c];
        }
    }
}

/// Allocating wrapper over [`embed_fwd_into`].
pub fn embed_fwd(tok: &[f32], pos: &[f32], ids: &[i32], t: usize, d: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; ids.len() * d];
    embed_fwd_into(tok, pos, ids, t, d, &mut y);
    y
}

/// Backward of [`embed_fwd`]: scatter-add into `dtok` (`[V, D]`) and
/// reduce over the batch into `dpos` (`[T, D]`); both outputs are
/// zeroed first, so recycled buffers are safe.
pub fn embed_bwd_into(
    dy: &[f32],
    ids: &[i32],
    t: usize,
    d: usize,
    dtok: &mut [f32],
    dpos: &mut [f32],
) {
    debug_assert_eq!(dy.len(), ids.len() * d);
    debug_assert_eq!(dpos.len(), t * d);
    dtok.fill(0.0);
    dpos.fill(0.0);
    for (r, &id) in ids.iter().enumerate() {
        let gr = &dy[r * d..(r + 1) * d];
        let tr = &mut dtok[id as usize * d..(id as usize + 1) * d];
        for c in 0..d {
            tr[c] += gr[c];
        }
        let pr = &mut dpos[(r % t) * d..(r % t + 1) * d];
        for c in 0..d {
            pr[c] += gr[c];
        }
    }
}

/// Allocating wrapper over [`embed_bwd_into`].
pub fn embed_bwd(
    dy: &[f32],
    ids: &[i32],
    vocab: usize,
    t: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut dtok = vec![0.0f32; vocab * d];
    let mut dpos = vec![0.0f32; t * d];
    embed_bwd_into(dy, ids, t, d, &mut dtok, &mut dpos);
    (dtok, dpos)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_gates_on_preactivation() {
        let pre = [-1.0, 0.0, 2.0];
        assert_eq!(relu_fwd(&pre), vec![0.0, 0.0, 2.0]);
        assert_eq!(relu_bwd(&[1.0, 1.0, 1.0], &pre), vec![0.0, 0.0, 1.0]);
        // recycled buffers are fully overwritten
        let mut y = vec![42.0f32; 3];
        relu_fwd_into(&pre, &mut y);
        assert_eq!(y, vec![0.0, 0.0, 2.0]);
        let mut dx = vec![42.0f32; 3];
        relu_bwd_into(&[1.0, 1.0, 1.0], &pre, &mut dx);
        assert_eq!(dx, vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn embed_looks_up_and_scatters_back() {
        let (v, t, d) = (4, 2, 3);
        let tok: Vec<f32> = (0..v * d).map(|i| i as f32).collect();
        let pos: Vec<f32> = (0..t * d).map(|i| i as f32 * 0.1).collect();
        // batch of 2 sequences of length 2
        let ids = [2, 0, 2, 3];
        let y = embed_fwd(&tok, &pos, &ids, t, d);
        assert_eq!(y.len(), 4 * d);
        // y[0] = tok[2] + pos[0]
        assert!((y[0] - (6.0 + 0.0)).abs() < 1e-6);
        // y row 3 = tok[3] + pos[1]
        assert!((y[3 * d] - (9.0 + 0.3)).abs() < 1e-6);

        let dy = vec![1.0f32; 4 * d];
        let (dtok, dpos) = embed_bwd(&dy, &ids, v, t, d);
        // token 2 appears twice, token 1 never
        assert_eq!(dtok[2 * d], 2.0);
        assert_eq!(dtok[d], 0.0);
        // each position row sums the batch (2 sequences)
        assert!(dpos.iter().all(|&g| (g - 2.0).abs() < 1e-6));
        // the into-variant zeroes recycled buffers before scattering
        let mut dtok2 = vec![5.0f32; v * d];
        let mut dpos2 = vec![5.0f32; t * d];
        embed_bwd_into(&dy, &ids, t, d, &mut dtok2, &mut dpos2);
        assert_eq!((dtok, dpos), (dtok2, dpos2));
    }
}
