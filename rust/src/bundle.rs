//! Schema-versioned artifact-bundle manifests (`artifacts/manifest.json`).
//!
//! The per-artifact `<name>.manifest.json` files describe one step
//! function's ABI; this module adds the layer above them: a single
//! `manifest.json` at the root of the artifacts directory that inventories
//! every artifact with per-file SHA-256 checksums, byte sizes, and build
//! provenance, under an explicit `schema_version`.  The design follows the
//! program-bundle manifests of the related repos (artcode RFC 0005,
//! raster's "Program Bundle and Manifests") and is specified in
//! `docs/rfcs/0001-artifact-manifest.md`.
//!
//! Loading a bundle with an unknown `schema_version`, a missing entry, or
//! a checksum mismatch produces a descriptive [`crate::error::Error`] —
//! never a panic and never a silent fallback — so a stale or corrupted
//! artifacts directory is caught before a multi-minute training run
//! starts.  Bundles are written by `efqat bundle` (or `make artifacts`)
//! via [`Bundle::scan`] + [`Bundle::save`].

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{anyhow, bail, Context, Result};
use crate::json::Json;

/// The bundle schema this build reads and writes.  Readers must reject
/// any other major version loudly (RFC 0001 §Versioning).
pub const SCHEMA_VERSION: u64 = 1;

/// One checksummed file belonging to a bundle entry.
#[derive(Clone, Debug, PartialEq)]
pub struct FileRef {
    /// Path relative to the artifacts directory.
    pub path: String,
    /// Lowercase hex SHA-256 of the file contents.
    pub sha256: String,
    /// File size in bytes (fast pre-check before hashing).
    pub bytes: u64,
}

/// One artifact: a step-function manifest plus (for compiled backends)
/// its HLO text.  `files` is keyed by role: `"manifest"` is always
/// present; `"hlo"` is present for PJRT-compiled artifacts and absent for
/// entries the native backend synthesizes.
#[derive(Clone, Debug, PartialEq)]
pub struct BundleEntry {
    /// Artifact name, e.g. `resnet8_w8a8_train_r25`.
    pub name: String,
    /// Step kind from the per-artifact manifest: `train` | `fwd` | `calib`.
    pub kind: String,
    /// Role → file reference (`"manifest"`, `"hlo"`).
    pub files: BTreeMap<String, FileRef>,
}

/// The top-level, schema-versioned artifact inventory.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Bundle {
    /// Free-form provenance (`builder`, `jax`, `created`, …) recorded at
    /// build time; informational only, never validated.
    pub provenance: BTreeMap<String, String>,
    /// Artifacts in name order.
    pub entries: Vec<BundleEntry>,
}

impl Bundle {
    /// Canonical bundle path inside an artifacts directory.
    pub fn manifest_path(dir: &Path) -> PathBuf {
        dir.join("manifest.json")
    }

    /// Load and schema-check `manifest.json`.
    pub fn load(path: &Path) -> Result<Bundle> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading bundle manifest {}", path.display()))?;
        Self::parse(&src).with_context(|| format!("parsing bundle manifest {}", path.display()))
    }

    /// Parse from JSON text, rejecting unsupported schema versions with a
    /// descriptive error.
    pub fn parse(src: &str) -> Result<Bundle> {
        let j = Json::parse(src)?;
        let raw = j.get("schema_version")?.num()?;
        if raw.fract() != 0.0 || raw < 0.0 {
            bail!("malformed bundle schema_version {raw:?} (must be a non-negative integer)");
        }
        let ver = raw as u64;
        if ver != SCHEMA_VERSION {
            bail!(
                "unsupported bundle schema_version {ver} (this build supports {SCHEMA_VERSION}); \
                 re-run `make artifacts` with a matching toolchain"
            );
        }
        let mut provenance = BTreeMap::new();
        if let Some(p) = j.opt("provenance") {
            if let Json::Obj(m) = p {
                for (k, v) in m {
                    provenance.insert(k.clone(), v.str().unwrap_or("").to_string());
                }
            }
        }
        let entries = j
            .get("entries")?
            .arr()?
            .iter()
            .map(|e| {
                let mut files = BTreeMap::new();
                if let Json::Obj(m) = e.get("files")? {
                    for (role, f) in m {
                        files.insert(
                            role.clone(),
                            FileRef {
                                path: f.get("path")?.str()?.to_string(),
                                sha256: f.get("sha256")?.str()?.to_string(),
                                bytes: f.get("bytes")?.num()? as u64,
                            },
                        );
                    }
                } else {
                    bail!("entry files is not an object");
                }
                Ok(BundleEntry {
                    name: e.get("name")?.str()?.to_string(),
                    kind: e.get("kind")?.str()?.to_string(),
                    files,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Bundle { provenance, entries })
    }

    /// Serialize to the canonical JSON form ([`crate::json::Json::render`]).
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("schema_version".to_string(), Json::Num(SCHEMA_VERSION as f64));
        root.insert("bundle_hash".to_string(), Json::Str(self.bundle_hash()));
        root.insert(
            "provenance".to_string(),
            Json::Obj(
                self.provenance
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                    .collect(),
            ),
        );
        let entries = self
            .entries
            .iter()
            .map(|e| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(e.name.clone()));
                m.insert("kind".to_string(), Json::Str(e.kind.clone()));
                let files = e
                    .files
                    .iter()
                    .map(|(role, f)| {
                        let mut fm = BTreeMap::new();
                        fm.insert("path".to_string(), Json::Str(f.path.clone()));
                        fm.insert("sha256".to_string(), Json::Str(f.sha256.clone()));
                        fm.insert("bytes".to_string(), Json::Num(f.bytes as f64));
                        (role.clone(), Json::Obj(fm))
                    })
                    .collect();
                m.insert("files".to_string(), Json::Obj(files));
                Json::Obj(m)
            })
            .collect();
        root.insert("entries".to_string(), Json::Arr(entries));
        Json::Obj(root)
    }

    /// Write `manifest.json` (creating parent directories as needed).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().render())
            .with_context(|| format!("writing bundle manifest {}", path.display()))?;
        Ok(())
    }

    /// Look up an entry by artifact name.
    pub fn entry(&self, name: &str) -> Result<&BundleEntry> {
        self.entries.iter().find(|e| e.name == name).ok_or_else(|| {
            anyhow!(
                "artifact {name:?} is not listed in the bundle manifest \
                 ({} entries); the artifacts directory is stale — re-run `make artifacts`",
                self.entries.len()
            )
        })
    }

    /// Verify every file of one entry against its recorded size + SHA-256.
    pub fn verify_entry(&self, dir: &Path, name: &str) -> Result<()> {
        let entry = self.entry(name)?;
        for (role, f) in &entry.files {
            let path = dir.join(&f.path);
            let data = std::fs::read(&path).with_context(|| {
                format!(
                    "artifact {name}: {role} file {} listed in manifest.json is unreadable",
                    path.display()
                )
            })?;
            if data.len() as u64 != f.bytes {
                bail!(
                    "artifact {name}: {} is {} bytes, manifest.json records {} — \
                     artifacts and manifest are out of sync, re-run `make artifacts`",
                    f.path,
                    data.len(),
                    f.bytes
                );
            }
            let got = sha256_hex(&data);
            if got != f.sha256 {
                // .get() so a corrupted (non-ASCII) recorded hash can't
                // panic the error path it is being reported on
                let want = f.sha256.get(..12).unwrap_or(&f.sha256);
                bail!(
                    "artifact {name}: {} checksum mismatch (manifest {want}…, disk {}…) — \
                     artifacts and manifest are out of sync, re-run `make artifacts`",
                    f.path,
                    &got[..12]
                );
            }
        }
        Ok(())
    }

    /// Verify every entry ([`Bundle::verify_entry`]) in the bundle.
    pub fn verify_all(&self, dir: &Path) -> Result<()> {
        for e in &self.entries {
            self.verify_entry(dir, &e.name)?;
        }
        Ok(())
    }

    /// Content hash over the sorted (name, file, sha256) triples — a
    /// single value that changes iff any artifact changes.
    pub fn bundle_hash(&self) -> String {
        let mut acc = String::new();
        for e in &self.entries {
            for (role, f) in &e.files {
                acc.push_str(&e.name);
                acc.push(':');
                acc.push_str(role);
                acc.push(':');
                acc.push_str(&f.sha256);
                acc.push('\n');
            }
        }
        sha256_hex(acc.as_bytes())
    }

    /// Build a bundle by scanning an artifacts directory for
    /// `<name>.manifest.json` (+ optional `<name>.hlo.txt`) pairs,
    /// hashing each file and reading the step kind from the per-artifact
    /// manifest.
    pub fn scan(dir: &Path, provenance: BTreeMap<String, String>) -> Result<Bundle> {
        let mut names: Vec<String> = std::fs::read_dir(dir)
            .with_context(|| format!("scanning artifacts directory {}", dir.display()))?
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                e.file_name()
                    .to_string_lossy()
                    .strip_suffix(".manifest.json")
                    .map(str::to_string)
            })
            .collect();
        names.sort();
        let mut entries = Vec::with_capacity(names.len());
        for name in names {
            let man_rel = format!("{name}.manifest.json");
            let man = crate::model::Manifest::load(&dir.join(&man_rel))?;
            let mut files = BTreeMap::new();
            files.insert("manifest".to_string(), file_ref(dir, &man_rel)?);
            let hlo_rel = format!("{name}.hlo.txt");
            if dir.join(&hlo_rel).exists() {
                files.insert("hlo".to_string(), file_ref(dir, &hlo_rel)?);
            }
            entries.push(BundleEntry { name, kind: man.kind, files });
        }
        Ok(Bundle { provenance, entries })
    }
}

/// Canonical checkpoint fingerprint for the serving registry (RFC 0005).
///
/// * A directory (or an explicit path to a `manifest.json`) is
///   fingerprinted as its RFC 0001 bundle: [`Bundle::bundle_hash`], the
///   digest over every artifact's recorded SHA-256 — so two directories
///   with identical artifact content agree, byte-for-byte.
/// * Any other regular file (e.g. a raw `*.ckpt` written by the
///   pipeline) is fingerprinted as the SHA-256 of its contents.
///
/// Lowercase hex either way; this is what `efqat serve` installs
/// engines under and what response `fp` fields abbreviate.
pub fn fingerprint(path: &Path) -> Result<String> {
    let meta = std::fs::metadata(path)
        .with_context(|| format!("fingerprinting checkpoint {}", path.display()))?;
    if meta.is_dir() {
        return Ok(Bundle::load(&Bundle::manifest_path(path))?.bundle_hash());
    }
    if path.file_name().is_some_and(|n| n == "manifest.json") {
        return Ok(Bundle::load(path)?.bundle_hash());
    }
    let data = std::fs::read(path)
        .with_context(|| format!("fingerprinting checkpoint {}", path.display()))?;
    Ok(sha256_hex(&data))
}

fn file_ref(dir: &Path, rel: &str) -> Result<FileRef> {
    let data = std::fs::read(dir.join(rel))
        .with_context(|| format!("reading {rel} for checksumming"))?;
    Ok(FileRef { path: rel.to_string(), sha256: sha256_hex(&data), bytes: data.len() as u64 })
}

// ---------------------------------------------------------------------------
// SHA-256 (FIPS 180-4) — no crypto crates offline; checksums only, not
// security-critical.
// ---------------------------------------------------------------------------

const SHA256_K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// SHA-256 digest as lowercase hex.
pub fn sha256_hex(data: &[u8]) -> String {
    let mut h: [u32; 8] = [
        0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
        0x5be0cd19,
    ];
    let bitlen = (data.len() as u64).wrapping_mul(8);
    let mut msg = data.to_vec();
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bitlen.to_be_bytes());
    for chunk in msg.chunks_exact(64) {
        let mut w = [0u32; 64];
        for i in 0..16 {
            let b = [chunk[4 * i], chunk[4 * i + 1], chunk[4 * i + 2], chunk[4 * i + 3]];
            w[i] = u32::from_be_bytes(b);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16].wrapping_add(s0).wrapping_add(w[i - 7]).wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh] = h;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = hh
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(SHA256_K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
        h[4] = h[4].wrapping_add(e);
        h[5] = h[5].wrapping_add(f);
        h[6] = h[6].wrapping_add(g);
        h[7] = h[7].wrapping_add(hh);
    }
    h.iter().map(|x| format!("{x:08x}")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const TOY_MANIFEST: &str = r#"{
      "name": "toy_calib", "model": "toy", "kind": "calib",
      "w_bits": 0, "a_bits": 0, "batch_size": 4,
      "params": [], "states": [], "wsites": [],
      "inputs": [], "outputs": []
    }"#;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("efqat_bundle_{tag}"));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn sha256_known_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        // multi-block message (> 64 bytes)
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn scan_save_load_verify_round_trip() {
        let dir = tmp("rt");
        std::fs::write(dir.join("toy_calib.manifest.json"), TOY_MANIFEST).unwrap();
        std::fs::write(dir.join("toy_calib.hlo.txt"), "HloModule toy").unwrap();
        let mut prov = BTreeMap::new();
        prov.insert("builder".to_string(), "test".to_string());
        let bundle = Bundle::scan(&dir, prov).unwrap();
        assert_eq!(bundle.entries.len(), 1);
        assert_eq!(bundle.entries[0].kind, "calib");
        assert!(bundle.entries[0].files.contains_key("hlo"));

        let path = Bundle::manifest_path(&dir);
        bundle.save(&path).unwrap();
        let loaded = Bundle::load(&path).unwrap();
        assert_eq!(loaded, bundle);
        loaded.verify_all(&dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_file_fails_checksum_with_descriptive_error() {
        let dir = tmp("corrupt");
        std::fs::write(dir.join("toy_calib.manifest.json"), TOY_MANIFEST).unwrap();
        std::fs::write(dir.join("toy_calib.hlo.txt"), "HloModule toy").unwrap();
        let bundle = Bundle::scan(&dir, BTreeMap::new()).unwrap();
        // same length, different content → size check passes, hash fails
        std::fs::write(dir.join("toy_calib.hlo.txt"), "HloModule t0y").unwrap();
        let err = bundle.verify_entry(&dir, "toy_calib").unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
        assert!(err.contains("toy_calib"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_schema_version_rejected() {
        let src = r#"{"schema_version": 999, "entries": []}"#;
        let err = Bundle::parse(src).unwrap_err().to_string();
        assert!(err.contains("schema_version 999"), "{err}");
        assert!(err.contains("supports 1"), "{err}");
        // fractional/negative versions don't silently truncate to 1
        assert!(Bundle::parse(r#"{"schema_version": 1.5, "entries": []}"#).is_err());
        assert!(Bundle::parse(r#"{"schema_version": -1, "entries": []}"#).is_err());
    }

    #[test]
    fn malformed_manifest_is_an_error_not_a_panic() {
        assert!(Bundle::parse("{ not json").is_err());
        assert!(Bundle::parse(r#"{"entries": []}"#).is_err()); // missing schema_version
        let dir = tmp("missing");
        let err = Bundle::load(&Bundle::manifest_path(&dir)).unwrap_err().to_string();
        assert!(err.contains("manifest.json"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_entry_and_missing_file_are_descriptive() {
        let dir = tmp("entries");
        std::fs::write(dir.join("toy_calib.manifest.json"), TOY_MANIFEST).unwrap();
        let bundle = Bundle::scan(&dir, BTreeMap::new()).unwrap();
        let err = bundle.entry("nope_fwd").unwrap_err().to_string();
        assert!(err.contains("nope_fwd"), "{err}");
        std::fs::remove_file(dir.join("toy_calib.manifest.json")).unwrap();
        let err = bundle.verify_entry(&dir, "toy_calib").unwrap_err().to_string();
        assert!(err.contains("unreadable"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fingerprint_hashes_files_and_resolves_bundles() {
        let dir = tmp("fp");
        let ckpt = dir.join("model.ckpt");
        std::fs::write(&ckpt, b"weights").unwrap();
        assert_eq!(fingerprint(&ckpt).unwrap(), sha256_hex(b"weights"));

        std::fs::write(dir.join("toy_calib.manifest.json"), TOY_MANIFEST).unwrap();
        let bundle = Bundle::scan(&dir, BTreeMap::new()).unwrap();
        bundle.save(&Bundle::manifest_path(&dir)).unwrap();
        // directory and explicit manifest.json agree: both are the
        // bundle hash, not the hash of the manifest file's bytes
        assert_eq!(fingerprint(&dir).unwrap(), bundle.bundle_hash());
        assert_eq!(fingerprint(&Bundle::manifest_path(&dir)).unwrap(), bundle.bundle_hash());

        let err = fingerprint(&dir.join("ghost.ckpt")).unwrap_err().to_string();
        assert!(err.contains("fingerprinting"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bundle_hash_tracks_content() {
        let mut b1 = Bundle::default();
        b1.entries.push(BundleEntry {
            name: "a".into(),
            kind: "fwd".into(),
            files: BTreeMap::from([(
                "manifest".to_string(),
                FileRef { path: "a.manifest.json".into(), sha256: "00".into(), bytes: 2 },
            )]),
        });
        let mut b2 = b1.clone();
        assert_eq!(b1.bundle_hash(), b2.bundle_hash());
        b2.entries[0].files.get_mut("manifest").unwrap().sha256 = "ff".into();
        assert_ne!(b1.bundle_hash(), b2.bundle_hash());
    }
}
