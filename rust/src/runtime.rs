//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! Follows the /opt/xla-example/load_hlo pattern: HLO *text* is the
//! interchange format (xla_extension 0.5.1 rejects jax≥0.5 serialized
//! protos), `HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile` → `execute`.
//!
//! A [`Step`] couples a compiled executable with its [`Manifest`]; inputs
//! are packed host-tensors in manifest order, outputs are unpacked into a
//! name → [`Value`] map.  [`StepCache`] memoizes compilation per artifact.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::model::{Dtype, IoSpec, Manifest};
use crate::tensor::{ITensor, Tensor};

/// A host value crossing the runtime boundary.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(ITensor),
}

impl Value {
    pub fn f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn i32(&self) -> Result<&ITensor> {
        match self {
            Value::I32(t) => Ok(t),
            _ => bail!("expected i32 value"),
        }
    }

    /// First element of an f32 value (for [1]-shaped scalars).
    pub fn scalar(&self) -> Result<f32> {
        Ok(self.f32()?.data[0])
    }
}

pub struct Runtime {
    pub client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, artifacts_dir: artifacts_dir.to_path_buf() })
    }

    /// Load + compile one artifact by name (e.g. "resnet20_w8a8_train_r25").
    pub fn load(&self, name: &str) -> Result<Step> {
        let hlo = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let man = self.artifacts_dir.join(format!("{name}.manifest.json"));
        let manifest = Manifest::load(&man)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&hlo)
            .map_err(|e| anyhow!("parsing {}: {e:?}", hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        Ok(Step { manifest, exe, compile_time: t0.elapsed() })
    }
}

pub struct Step {
    pub manifest: Manifest,
    exe: xla::PjRtLoadedExecutable,
    pub compile_time: Duration,
}

/// Pack a host f32 tensor into an XLA literal of the given shape.
pub fn literal_f32(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(&t.data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape {:?}: {e:?}", t.shape))
}

pub fn literal_i32(t: &ITensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(&t.data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape {:?}: {e:?}", t.shape))
}

impl Step {
    pub fn name(&self) -> &str {
        &self.manifest.name
    }

    /// Execute with literals packed in manifest input order.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Outputs> {
        let (out, _) = self.execute_timed(inputs)?;
        Ok(out)
    }

    /// Execute and report device wall-time (the paper's backward-runtime
    /// measurements in Table 5 time exactly this call).
    pub fn execute_timed(&self, inputs: &[xla::Literal]) -> Result<(Outputs, Duration)> {
        if inputs.len() != self.manifest.inputs.len() {
            bail!(
                "{}: {} inputs supplied, manifest wants {}",
                self.manifest.name,
                inputs.len(),
                self.manifest.inputs.len()
            );
        }
        let t0 = Instant::now();
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.manifest.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let dt = t0.elapsed();
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        if parts.len() != self.manifest.outputs.len() {
            bail!(
                "{}: {} outputs returned, manifest declares {}",
                self.manifest.name,
                parts.len(),
                self.manifest.outputs.len()
            );
        }
        let mut map = BTreeMap::new();
        for (spec, lit) in self.manifest.outputs.iter().zip(parts) {
            map.insert(spec.name.clone(), unpack(spec, lit)?);
        }
        Ok((Outputs { map }, dt))
    }
}

fn unpack(spec: &IoSpec, lit: xla::Literal) -> Result<Value> {
    match spec.dtype {
        Dtype::F32 => {
            let data = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("{}: to_vec f32: {e:?}", spec.name))?;
            Ok(Value::F32(Tensor::new(spec.shape.clone(), data)?))
        }
        Dtype::I32 => {
            let data = lit
                .to_vec::<i32>()
                .map_err(|e| anyhow!("{}: to_vec i32: {e:?}", spec.name))?;
            Ok(Value::I32(ITensor::new(spec.shape.clone(), data)?))
        }
    }
}

/// Named outputs of one step execution.
#[derive(Debug)]
pub struct Outputs {
    pub map: BTreeMap<String, Value>,
}

impl Outputs {
    pub fn get(&self, name: &str) -> Result<&Value> {
        self.map.get(name).ok_or_else(|| anyhow!("missing output {name:?}"))
    }

    pub fn loss(&self) -> Result<f32> {
        self.get("loss")?.scalar()
    }

    pub fn correct(&self) -> Result<i32> {
        Ok(self.get("correct")?.i32()?.data[0])
    }
}

/// Lazily-compiled, memoized steps keyed by artifact name.
pub struct StepCache {
    runtime: Rc<Runtime>,
    cache: RefCell<BTreeMap<String, Rc<Step>>>,
}

impl StepCache {
    pub fn new(runtime: Rc<Runtime>) -> StepCache {
        StepCache { runtime, cache: RefCell::new(BTreeMap::new()) }
    }

    pub fn get(&self, name: &str) -> Result<Rc<Step>> {
        if let Some(s) = self.cache.borrow().get(name) {
            return Ok(s.clone());
        }
        let step = Rc::new(
            self.runtime
                .load(name)
                .with_context(|| format!("loading artifact {name} (run `make artifacts`?)"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), step.clone());
        Ok(step)
    }
}
