//! Data-parallel sharding: deterministic batch splitting, a fixed-order
//! pairwise tree reduction, and the frozen-aware sparse gradient
//! exchange (RFC 0004).
//!
//! Bit-exactness at any worker count is the design invariant everything
//! here serves.  A batch is always split into a *fixed* number of
//! virtual shards `S` chosen from the batch size alone
//! ([`ShardPlan::new`]), never from the worker count `W` — workers pick
//! shards round-robin (worker `w` runs shards `w, w+W, …`), so raising
//! `W` changes who computes a shard but never how the batch is grouped.
//! Shard results are indexed by shard id and combined after all workers
//! join, in the fixed pairwise order of [`tree_reduce`]; f32 addition is
//! not associative, so a fixed grouping *and* a fixed combination order
//! are both load-bearing.
//!
//! The exchange itself is frozen-aware ([`GradExchange`]): ratio
//! artifacts emit only the unfrozen channel rows of `dW`/`dS_w`
//! (frozen rows are never materialized), so the reduced payload already
//! shrinks with (1−r); LWPN artifacts emit dense grads but flag-frozen
//! sites are skipped — never summed or copied — because the optimizer
//! discards them anyway.  [`ExchangeStats`] reports both the bytes
//! actually combined and the dense-equivalent bytes so the shrink is
//! observable in metrics and benches.

use std::collections::BTreeMap;

use crate::backend::Value;
use crate::data::Batch;
use crate::error::{anyhow, bail, Result};
use crate::freeze::Selection;
use crate::model::Manifest;
use crate::rng::Pcg64;
use crate::tensor::{ITensor, Tensor};

/// Most virtual shards a batch is split into.  Small enough that the
/// per-shard batch stays GEMM-friendly, large enough that `W ∈ {1,2,4}`
/// all divide the shard count for the repo's batch sizes (16 and 8).
pub const MAX_VIRTUAL_SHARDS: usize = 4;

/// How one training batch is split, independently of the worker count.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Virtual shard count `S`: the largest divisor of the batch size
    /// that is ≤ [`MAX_VIRTUAL_SHARDS`].  Fixed per artifact, never a
    /// function of `W`.
    pub shards: usize,
    /// Examples per shard (`batch_size / shards`).
    pub shard_bs: usize,
    /// Base seed the per-shard RNG streams derive from.
    pub seed: u64,
}

impl ShardPlan {
    pub fn new(batch_size: usize, seed: u64) -> ShardPlan {
        let b = batch_size.max(1);
        let mut s = MAX_VIRTUAL_SHARDS.min(b);
        while b % s != 0 {
            s -= 1;
        }
        ShardPlan { shards: s, shard_bs: b / s, seed }
    }

    /// Deterministic per-shard RNG stream, keyed by shard id (not by the
    /// worker that happens to run it), so stochastic layers would draw
    /// identical values at any `W`.
    pub fn shard_rng(&self, shard: usize) -> Pcg64 {
        Pcg64::new(self.seed ^ 0x05a4d_5eed).split(shard as u64)
    }
}

/// Split `batch` into `shards` equal row-ranges, writing into `out`.
/// The first call builds the shard batches; later calls refresh the same
/// buffers in place (`copy_from_slice`), so the steady-state train loop
/// allocates nothing here.
pub fn split_batch_into(batch: &Batch, shards: usize, out: &mut Vec<Batch>) -> Result<()> {
    let b = batch.count;
    if shards == 0 || b == 0 || b % shards != 0 {
        bail!("shard split: batch of {b} examples does not divide into {shards} shards");
    }
    let per = b / shards;
    if out.len() != shards {
        out.clear();
        for s in 0..shards {
            let mut f32s = BTreeMap::new();
            for (name, t) in &batch.f32s {
                f32s.insert(name.clone(), rows_f32(name, t, b, s * per, per)?);
            }
            let mut i32s = BTreeMap::new();
            for (name, t) in &batch.i32s {
                i32s.insert(name.clone(), rows_i32(name, t, b, s * per, per)?);
            }
            out.push(Batch { f32s, i32s, count: per });
        }
        return Ok(());
    }
    for (s, shard) in out.iter_mut().enumerate() {
        for (name, t) in &batch.f32s {
            let epe = elems_per_example(name, t.shape.first().copied(), t.data.len(), b)?;
            let src = &t.data[s * per * epe..(s + 1) * per * epe];
            let dst = shard
                .f32s
                .get_mut(name)
                .ok_or_else(|| anyhow!("shard split: batch gained f32 tensor {name:?}"))?;
            if dst.data.len() != src.len() {
                bail!("shard split: tensor {name:?} changed size between steps");
            }
            dst.data.copy_from_slice(src);
        }
        for (name, t) in &batch.i32s {
            let epe = elems_per_example(name, t.shape.first().copied(), t.data.len(), b)?;
            let src = &t.data[s * per * epe..(s + 1) * per * epe];
            let dst = shard
                .i32s
                .get_mut(name)
                .ok_or_else(|| anyhow!("shard split: batch gained i32 tensor {name:?}"))?;
            if dst.data.len() != src.len() {
                bail!("shard split: tensor {name:?} changed size between steps");
            }
            dst.data.copy_from_slice(src);
        }
        shard.count = per;
    }
    Ok(())
}

fn elems_per_example(name: &str, lead: Option<usize>, len: usize, b: usize) -> Result<usize> {
    if lead != Some(b) {
        bail!("shard split: tensor {name:?} leading dim {lead:?} != batch count {b}");
    }
    Ok(len / b)
}

fn rows_f32(name: &str, t: &Tensor, b: usize, start: usize, n: usize) -> Result<Tensor> {
    let epe = elems_per_example(name, t.shape.first().copied(), t.data.len(), b)?;
    let mut shape = t.shape.clone();
    shape[0] = n;
    Tensor::new(shape, t.data[start * epe..(start + n) * epe].to_vec())
}

fn rows_i32(name: &str, t: &ITensor, b: usize, start: usize, n: usize) -> Result<ITensor> {
    let epe = elems_per_example(name, t.shape.first().copied(), t.data.len(), b)?;
    let mut shape = t.shape.clone();
    shape[0] = n;
    Ok(ITensor { shape, data: t.data[start * epe..(start + n) * epe].to_vec() })
}

/// Fixed-order pairwise tree reduction over `n` slots: `combine(i, j)`
/// must fold slot `j` into slot `i` (`j > i` always).  The visit order
/// is a pure function of `n` — gap-doubling rounds `(0,1)(2,3)… then
/// (0,2)(4,6)… then (0,4)…` — so the combined f32 value is bit-identical
/// no matter which worker produced which slot, or when.
pub fn tree_reduce(n: usize, mut combine: impl FnMut(usize, usize)) {
    let mut gap = 1;
    while gap < n {
        let mut i = 0;
        while i + gap < n {
            combine(i, i + gap);
            i += 2 * gap;
        }
        gap *= 2;
    }
}

/// Run `shards` work items over `slots` worker contexts, returning the
/// results indexed by shard id.
///
/// Worker `w` of `nw = min(slots, shards)` processes shards
/// `w, w+nw, w+2nw, …` on its own OS thread with exclusive access to its
/// slot; results are keyed by shard id, so completion timing cannot
/// reorder them.  With one slot (or one shard) everything runs inline on
/// the calling thread — same shard ids, same results.  Errors are
/// reported in worker order (first failing worker wins), which keeps the
/// failure deterministic too.
pub fn run_sharded<W, R, F>(slots: &mut [W], shards: usize, f: F) -> Result<Vec<R>>
where
    W: Send,
    R: Send,
    F: Fn(&mut W, usize) -> Result<R> + Sync,
{
    if slots.is_empty() {
        bail!("run_sharded: no worker slots");
    }
    let nw = slots.len().min(shards).max(1);
    if nw <= 1 {
        let slot = &mut slots[0];
        return (0..shards).map(|s| f(slot, s)).collect();
    }
    let slotted: Vec<Option<R>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(nw);
        for (w, slot) in slots.iter_mut().take(nw).enumerate() {
            let f = &f;
            handles.push(scope.spawn(move || -> Result<Vec<(usize, R)>> {
                let mut got = Vec::new();
                let mut s = w;
                while s < shards {
                    got.push((s, f(slot, s)?));
                    s += nw;
                }
                Ok(got)
            }));
        }
        let mut out: Vec<Option<R>> = (0..shards).map(|_| None).collect();
        let mut first_err = None;
        for (w, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(Ok(rs)) => {
                    for (s, r) in rs {
                        out[s] = Some(r);
                    }
                }
                Ok(Err(e)) if first_err.is_none() => first_err = Some(e),
                Err(_) if first_err.is_none() => {
                    first_err = Some(anyhow!("run_sharded: worker {w} panicked"))
                }
                _ => {}
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    })?;
    let mut out = Vec::with_capacity(shards);
    for (s, r) in slotted.into_iter().enumerate() {
        out.push(r.ok_or_else(|| anyhow!("run_sharded: shard {s} produced no result"))?);
    }
    Ok(out)
}

/// How one output of a train artifact is combined across shards.
enum ExKind {
    /// f32 shard-mean (loss and every gradient): tree-sum, then scale the
    /// root by 1/S.  `gate_site`: wsite whose LWPN flag gates whether the
    /// optimizer will consume this grad at all — flag-frozen sites are
    /// skipped entirely.
    Mean { gate_site: Option<usize> },
    /// i32 count (the `correct` metric): tree-sum, no scaling.
    Count,
}

struct ExOp {
    /// Position in the manifest output vector.
    pos: usize,
    kind: ExKind,
    /// f32/i32 elements actually shipped per shard pair.
    elems: usize,
    /// Elements a dense (freeze-unaware) exchange would ship: the full
    /// `c_out`-row tensor for partial `dW`/`dS_w`, `elems` otherwise.
    dense_elems: usize,
}

/// Per-step byte accounting of one [`GradExchange::reduce`] call.  Bytes
/// count each pairwise combine of the tree (`S−1` combines per reduced
/// buffer), the quantity a wire all-reduce would move.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExchangeStats {
    /// Bytes actually summed (active slices only).
    pub active_bytes: u64,
    /// Bytes a dense exchange of the same step would have summed.
    pub dense_bytes: u64,
}

/// The frozen-aware exchange plan for one train manifest: which outputs
/// reduce, how, and what the dense-equivalent payload would be.
pub struct GradExchange {
    ops: Vec<ExOp>,
}

impl GradExchange {
    /// Build the exchange plan from the manifest's output specs.
    pub fn plan(man: &Manifest) -> Result<GradExchange> {
        let site_pos = |name: &str| man.wsites.iter().position(|s| s.name == name);
        let mut ops = Vec::with_capacity(man.outputs.len());
        for (pos, spec) in man.outputs.iter().enumerate() {
            let elems = spec.elems();
            let (kind, dense_elems) = match spec.role.as_str() {
                "loss" => (ExKind::Mean { gate_site: None }, elems),
                "metric" => (ExKind::Count, elems),
                "grad" => {
                    let of = spec
                        .of
                        .as_deref()
                        .ok_or_else(|| anyhow!("grad output {:?} without 'of'", spec.name))?;
                    if let Some(site) = of.strip_prefix("sw:") {
                        // dS_w ships k of c_out rows for ratio artifacts
                        let si = site_pos(site)
                            .ok_or_else(|| anyhow!("grad {:?}: unknown wsite {site:?}", spec.name))?;
                        let dense = man.wsites[si].c_out;
                        (ExKind::Mean { gate_site: Some(si) }, dense)
                    } else if of.starts_with("sx:") || of.starts_with("zx:") {
                        (ExKind::Mean { gate_site: None }, elems)
                    } else if let Some(si) = site_pos(of) {
                        // partial dW: [k, rest] of a [c_out, rest] site
                        let k = spec.shape.first().copied().unwrap_or(1).max(1);
                        let dense = elems / k * man.wsites[si].c_out;
                        (ExKind::Mean { gate_site: Some(si) }, dense)
                    } else {
                        // bias / norm grads: always dense, always applied
                        (ExKind::Mean { gate_site: None }, elems)
                    }
                }
                "state" => bail!(
                    "data-parallel training cannot exchange state output {:?} \
                     (running statistics do not tree-reduce)",
                    spec.name
                ),
                other => bail!("output {:?}: unknown role {other:?}", spec.name),
            };
            ops.push(ExOp { pos, kind, elems, dense_elems });
        }
        Ok(GradExchange { ops })
    }

    /// Combine per-shard output vectors into full-batch values in
    /// `outs[0]`, in the fixed [`tree_reduce`] order.  Shard outputs are
    /// shard-means (the loss kernel scales by 1/rows), so f32 buffers
    /// tree-sum then scale by `1/S`; the `correct` count sums as-is.
    /// LWPN flag-frozen weight/scale grads are skipped — not summed, not
    /// copied — and only their dense-equivalent bytes are recorded.
    pub fn reduce(&self, outs: &mut [Vec<Value>], sel: Option<&Selection>) -> Result<ExchangeStats> {
        let n = outs.len();
        if n == 0 {
            bail!("gradient exchange: no shard outputs");
        }
        let inv = 1.0 / n as f32;
        let pair_bytes = |elems: usize| (elems * 4 * (n - 1)) as u64;
        let mut stats = ExchangeStats::default();
        for op in &self.ops {
            for (s, o) in outs.iter().enumerate() {
                let got = o.get(op.pos).map(|v| v.dtype());
                let want = outs[0][op.pos].dtype();
                if o.len() != outs[0].len() || got != Some(want) {
                    bail!("gradient exchange: shard {s} output {} diverges from shard 0", op.pos);
                }
            }
            stats.dense_bytes += pair_bytes(op.dense_elems);
            match op.kind {
                ExKind::Mean { gate_site } => {
                    if let (Some(si), Some(sel)) = (gate_site, sel) {
                        let flag_frozen = sel.flags.get(si).is_some_and(|&f| !f)
                            && sel.channels.get(si).map_or(true, |c| c.is_empty());
                        if flag_frozen {
                            continue; // optimizer discards this grad; never ship it
                        }
                    }
                    stats.active_bytes += pair_bytes(op.elems);
                    tree_reduce(n, |i, j| {
                        let (lo, hi) = outs.split_at_mut(j);
                        if let (Value::F32(dst), Value::F32(src)) =
                            (&mut lo[i][op.pos], &hi[0][op.pos])
                        {
                            for (d, s) in dst.data.iter_mut().zip(&src.data) {
                                *d += *s;
                            }
                        }
                    });
                    if let Value::F32(t) = &mut outs[0][op.pos] {
                        for v in &mut t.data {
                            *v *= inv;
                        }
                    }
                }
                ExKind::Count => {
                    stats.active_bytes += pair_bytes(op.elems);
                    tree_reduce(n, |i, j| {
                        let (lo, hi) = outs.split_at_mut(j);
                        if let (Value::I32(dst), Value::I32(src)) =
                            (&mut lo[i][op.pos], &hi[0][op.pos])
                        {
                            for (d, s) in dst.data.iter_mut().zip(&src.data) {
                                *d += *s;
                            }
                        }
                    });
                }
            }
        }
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_plan_is_a_function_of_batch_size_only() {
        assert_eq!(ShardPlan::new(16, 0).shards, 4);
        assert_eq!(ShardPlan::new(16, 0).shard_bs, 4);
        assert_eq!(ShardPlan::new(8, 0).shards, 4);
        assert_eq!(ShardPlan::new(8, 0).shard_bs, 2);
        assert_eq!(ShardPlan::new(6, 0).shards, 3);
        assert_eq!(ShardPlan::new(5, 0).shards, 1); // prime > 4: no split
        assert_eq!(ShardPlan::new(1, 0).shards, 1);
    }

    #[test]
    fn shard_rng_streams_keyed_by_shard_id() {
        let plan = ShardPlan::new(16, 7);
        let a: Vec<f32> = {
            let mut r = plan.shard_rng(2);
            (0..4).map(|_| r.uniform()).collect()
        };
        let b: Vec<f32> = {
            let mut r = plan.shard_rng(2);
            (0..4).map(|_| r.uniform()).collect()
        };
        let c: Vec<f32> = {
            let mut r = plan.shard_rng(3);
            (0..4).map(|_| r.uniform()).collect()
        };
        assert_eq!(a, b, "same shard id must replay the same stream");
        assert_ne!(a, c, "different shard ids must diverge");
    }

    #[test]
    fn tree_reduce_order_is_fixed() {
        let order_of = |n: usize| {
            let mut order = Vec::new();
            tree_reduce(n, |i, j| order.push((i, j)));
            order
        };
        assert_eq!(order_of(1), vec![]);
        assert_eq!(order_of(2), vec![(0, 1)]);
        assert_eq!(order_of(4), vec![(0, 1), (2, 3), (0, 2)]);
        assert_eq!(order_of(5), vec![(0, 1), (2, 3), (0, 2), (0, 4)]);
        assert_eq!(order_of(8), vec![(0, 1), (2, 3), (4, 5), (6, 7), (0, 2), (4, 6), (0, 4)]);
    }

    #[test]
    fn split_refreshes_in_place_without_realloc() {
        let mk = |base: f32| {
            let mut f32s = BTreeMap::new();
            f32s.insert(
                "x".to_string(),
                Tensor::new(vec![4, 3], (0..12).map(|i| base + i as f32).collect()).unwrap(),
            );
            let mut i32s = BTreeMap::new();
            i32s.insert("y".to_string(), ITensor { shape: vec![4], data: vec![1, 2, 3, 4] });
            Batch { f32s, i32s, count: 4 }
        };
        let mut out = Vec::new();
        split_batch_into(&mk(0.0), 2, &mut out).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].count, 2);
        assert_eq!(out[0].f32s["x"].shape, vec![2, 3]);
        assert_eq!(out[1].f32s["x"].data, vec![6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
        assert_eq!(out[1].i32s["y"].data, vec![3, 4]);
        let ptr = out[0].f32s["x"].data.as_ptr();
        split_batch_into(&mk(100.0), 2, &mut out).unwrap();
        assert_eq!(out[0].f32s["x"].data[0], 100.0);
        assert_eq!(out[0].f32s["x"].data.as_ptr(), ptr, "refresh must reuse the buffer");
    }

    #[test]
    fn split_rejects_indivisible_batches() {
        let b = Batch { f32s: BTreeMap::new(), i32s: BTreeMap::new(), count: 5 };
        assert!(split_batch_into(&b, 2, &mut Vec::new()).is_err());
    }

    #[test]
    fn run_sharded_results_are_shard_ordered_under_adversarial_timing() {
        // Workers finish in inverted order (shard 0's worker sleeps the
        // longest); results must still come back keyed by shard id, and
        // identically at every worker count.
        let run = |workers: usize| -> Vec<usize> {
            let mut slots: Vec<usize> = (0..workers).collect();
            run_sharded(&mut slots, 4, |_slot, s| {
                std::thread::sleep(std::time::Duration::from_millis(5 * (4 - s as u64)));
                Ok(s * 10)
            })
            .unwrap()
        };
        let w1 = run(1);
        assert_eq!(w1, vec![0, 10, 20, 30]);
        assert_eq!(run(2), w1);
        assert_eq!(run(4), w1);
    }

    #[test]
    fn run_sharded_reports_first_worker_error_deterministically() {
        for workers in [1usize, 2, 4] {
            let mut slots: Vec<usize> = (0..workers).collect();
            let err = run_sharded(&mut slots, 4, |_slot, s| -> Result<()> {
                // delay so later shards fail before earlier ones race in
                std::thread::sleep(std::time::Duration::from_millis(3 * (4 - s as u64)));
                bail!("shard {s} failed")
            })
            .unwrap_err();
            // worker 0 owns shard 0 at every W, and worker order decides
            assert_eq!(err.to_string(), "shard 0 failed", "W={workers}");
        }
    }

    #[test]
    fn reduce_matches_sequential_fixed_order_reference() {
        // 3 "shards" of a hand-built manifest-free plan: exercise the
        // Mean and Count paths against an explicit (((s0+s1)+s2)·⅓) with
        // the tree's own grouping for n=3: (0,1) then (0,2).
        let plan = GradExchange {
            ops: vec![
                ExOp { pos: 0, kind: ExKind::Mean { gate_site: None }, elems: 2, dense_elems: 2 },
                ExOp { pos: 1, kind: ExKind::Count, elems: 1, dense_elems: 1 },
            ],
        };
        let shard = |a: f32, b: f32, c: i32| {
            vec![
                Value::F32(Tensor::new(vec![2], vec![a, b]).unwrap()),
                Value::I32(ITensor { shape: vec![1], data: vec![c] }),
            ]
        };
        let mut outs = vec![shard(1.0, 2.0, 3), shard(0.5, -1.0, 2), shard(0.25, 4.0, 1)];
        let stats = plan.reduce(&mut outs, None).unwrap();
        let third = 1.0f32 / 3.0;
        assert_eq!(outs[0][0].f32().unwrap().data, vec![
            ((1.0f32 + 0.5) + 0.25) * third,
            ((2.0f32 + -1.0) + 4.0) * third,
        ]);
        assert_eq!(outs[0][1].i32().unwrap().data, vec![6]);
        // 2 f32 elems × 4 bytes × 2 combines + 1 i32 × 4 × 2
        assert_eq!(stats, ExchangeStats { active_bytes: 24, dense_bytes: 24 });
    }

    #[test]
    fn reduce_skips_lwpn_flag_frozen_sites() {
        let plan = GradExchange {
            ops: vec![
                ExOp { pos: 0, kind: ExKind::Mean { gate_site: Some(0) }, elems: 4, dense_elems: 4 },
                ExOp { pos: 1, kind: ExKind::Mean { gate_site: Some(1) }, elems: 4, dense_elems: 4 },
            ],
        };
        let shard = || {
            vec![
                Value::F32(Tensor::new(vec![4], vec![1.0; 4]).unwrap()),
                Value::F32(Tensor::new(vec![4], vec![1.0; 4]).unwrap()),
            ]
        };
        let mut outs = vec![shard(), shard()];
        // LWPN shape: empty channel lists, per-site flags
        let sel = Selection { channels: vec![Vec::new(), Vec::new()], flags: vec![true, false] };
        let stats = plan.reduce(&mut outs, Some(&sel)).unwrap();
        assert_eq!(outs[0][0].f32().unwrap().data, vec![1.0; 4], "active site reduces to mean");
        assert_eq!(outs[0][1].f32().unwrap().data, vec![1.0; 4], "frozen site left untouched");
        assert_eq!(outs[1][1].f32().unwrap().data, vec![1.0; 4]);
        assert_eq!(stats.active_bytes, 16, "only the unfrozen site ships");
        assert_eq!(stats.dense_bytes, 32);

        // indexed (CWPL/CWPN) selections set all flags true: never gated
        let sel = Selection { channels: vec![vec![0], vec![1]], flags: vec![true, true] };
        let mut outs = vec![shard(), shard()];
        let stats = plan.reduce(&mut outs, Some(&sel)).unwrap();
        assert_eq!(stats.active_bytes, 32);
    }
}
