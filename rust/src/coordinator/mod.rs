//! The coordinator: the paper's Algorithm 1 as a rust training system.
//!
//! Pipeline (all phases driven from here, python never runs):
//!   1. [`trainer::pretrain_fp`]   — train the FP baseline checkpoint
//!      (and FP+1) with the `<model>_fp_train` artifact.
//!   2. [`ptq::calibrate`]         — MinMax PTQ over a calibration set
//!      (Eq. 2/4), producing the initial [`crate::model::QParamStore`].
//!   3. [`trainer::EfqatTrainer`]  — one EfQAT epoch: forward+backward on
//!      the ratio/LWPN artifact, Top-K channel selection every `f`
//!      samples, row-masked SGD on unfrozen channels, Adam on the
//!      quantization parameters.
//!   4. [`eval::evaluate`]         — accuracy / span-F1 / perplexity.
//!
//! Every phase talks to the execution layer through the
//! [`crate::backend::Backend`] seam, so the same coordinator code drives
//! the native CPU reference executor and the PJRT artifact runtime.

pub mod binder;
pub mod pipeline;
pub mod eval;
pub mod metrics;
pub mod ptq;
pub mod shard;
pub mod tasks;
pub mod trainer;

pub use binder::bind_inputs;
pub use eval::{evaluate, evaluate_int8, example_inputs, EvalResult};
pub use ptq::calibrate;
pub use trainer::{pretrain_fp, DataParallelTrainer, EfqatTrainer, TrainCfg};

use std::path::Path;
use std::rc::Rc;

use crate::backend::{self, Backend, BackendKind, StepCache};
use crate::cfg::Config;
use crate::error::Result;

/// Shared backend + loaded-step cache for one process.
pub struct Session {
    pub backend: Rc<dyn Backend>,
    pub steps: StepCache,
}

impl Session {
    /// Open a session on the default backend ([`BackendKind::Native`]).
    pub fn new(artifacts_dir: &Path) -> Result<Session> {
        Self::with_backend(BackendKind::default(), artifacts_dir)
    }

    /// Open a session on an explicitly selected backend.
    pub fn with_backend(kind: BackendKind, artifacts_dir: &Path) -> Result<Session> {
        let backend = backend::create(kind, artifacts_dir)?;
        Ok(Session { steps: StepCache::new(backend.clone()), backend })
    }

    /// Open a session from config keys: `backend` (default `native`) and
    /// `artifacts` (default `artifacts`) — what the CLI's `--backend` /
    /// `--artifacts` flags map to.
    pub fn from_cfg(cfg: &Config) -> Result<Session> {
        let kind = BackendKind::parse(&cfg.str("backend", "native"))?;
        Self::with_backend(kind, &pipeline::artifacts_dir(cfg))
    }
}
