//! The coordinator: the paper's Algorithm 1 as a rust training system.
//!
//! Pipeline (all phases driven from here, python never runs):
//!   1. [`trainer::pretrain_fp`]   — train the FP baseline checkpoint
//!      (and FP+1) with the `<model>_fp_train` artifact.
//!   2. [`ptq::calibrate`]         — MinMax PTQ over a calibration set
//!      (Eq. 2/4), producing the initial [`crate::model::QParamStore`].
//!   3. [`trainer::EfqatTrainer`]  — one EfQAT epoch: forward+backward on
//!      the ratio/LWPN artifact, Top-K channel selection every `f`
//!      samples, row-masked SGD on unfrozen channels, Adam on the
//!      quantization parameters.
//!   4. [`eval::evaluate`]         — accuracy / span-F1 / perplexity.

pub mod binder;
pub mod pipeline;
pub mod eval;
pub mod metrics;
pub mod ptq;
pub mod tasks;
pub mod trainer;

pub use binder::bind_inputs;
pub use eval::{evaluate, EvalResult};
pub use ptq::calibrate;
pub use trainer::{pretrain_fp, EfqatTrainer, TrainCfg};

use std::path::Path;
use std::rc::Rc;

use anyhow::Result;

use crate::runtime::{Runtime, StepCache};

/// Shared runtime + compiled-step cache for one process.
pub struct Session {
    pub runtime: Rc<Runtime>,
    pub steps: StepCache,
}

impl Session {
    pub fn new(artifacts_dir: &Path) -> Result<Session> {
        let runtime = Rc::new(Runtime::new(artifacts_dir)?);
        Ok(Session { steps: StepCache::new(runtime.clone()), runtime })
    }
}
