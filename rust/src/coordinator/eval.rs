//! Evaluation: accuracy (CNNs), span exact-match + token-F1 (QA),
//! loss/perplexity (LM) — the metrics of the paper's Tables 3/4, over
//! either the fake-quant float reference ([`evaluate`]) or the lowered
//! int8 serving engine ([`evaluate_int8`]).

use crate::backend::{Step, Value};
use crate::data::{squad::span_f1, Batch, Loader};
use crate::error::{anyhow, bail, Result};
use crate::exec::Workspace;
use crate::graph::InputKind;
use crate::lower::QuantizedGraph;
use crate::model::{ParamStore, QParamStore, StateStore};
use crate::ops::loss::softmax_xent_into;
use crate::tensor::{argmax, ITensor, Tensor};

use super::binder::{BindCtx, Binder};

#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    pub loss: f32,
    /// top-1 accuracy (CNNs) or exact-match rate (QA) or token accuracy (LM)
    pub accuracy: f32,
    /// token-overlap F1 × 100 (QA models only)
    pub f1: Option<f32>,
    pub n: usize,
}

impl EvalResult {
    /// The paper's headline number for this task: accuracy% or F1.
    pub fn headline(&self) -> f32 {
        self.f1.unwrap_or(self.accuracy * 100.0)
    }

    pub fn perplexity(&self) -> f32 {
        self.loss.exp()
    }
}

/// Run the fwd artifact over the loader.  Handles wrap-padded final
/// batches by scoring only the first `batch.count` examples host-side.
/// One workspace and one persistent input binding serve every batch, so
/// the loop stops generating allocator traffic after the first batch.
pub fn evaluate(
    fwd: &Step,
    params: &ParamStore,
    qparams: Option<&QParamStore>,
    states: &StateStore,
    loader: &mut Loader,
) -> Result<EvalResult> {
    let man = &fwd.manifest;
    let is_qa = man.outputs.iter().any(|o| o.name == "logits")
        && man.inputs.iter().any(|i| i.name == "y_start");
    loader.reset();
    let (mut loss_sum, mut correct, mut f1_sum, mut n) = (0f64, 0usize, 0f64, 0usize);
    let mut batches = 0usize;
    let mut ws = Workspace::new();
    let mut binder = Binder::new();
    let loss_i = man.out_pos("loss")?;
    let logits_i = man.out_pos("logits")?;
    while let Some(batch) = loader.next_batch() {
        let ctx = BindCtx { params, qparams, states, batch: &batch, selection: None };
        let inputs = binder.bind(man, &ctx)?;
        let (outs, _dt) = fwd.execute_timed_ws(inputs, &mut ws)?;
        loss_sum += outs[loss_i].scalar()? as f64; // padded rows repeat real rows; negligible bias
        batches += 1;
        let logits = outs[logits_i].f32()?;
        if is_qa {
            let (em, f1) = score_spans(logits, &batch);
            correct += em;
            f1_sum += f1;
        } else {
            correct += score_top1(&logits.data, &logits.shape, &batch);
        }
        n += batch.count;
        ws.give_values(outs);
    }
    Ok(EvalResult {
        loss: (loss_sum / batches.max(1) as f64) as f32,
        accuracy: correct as f32 / n.max(1) as f32,
        f1: if is_qa { Some((f1_sum / n.max(1) as f64 * 100.0) as f32) } else { None },
        n,
    })
}

/// Run the lowered int8 engine over the loader — the *deployed*
/// arithmetic, not the fake-quant simulation.  Scoring and the padded
/// final-batch handling mirror [`evaluate`] exactly, so the two paths'
/// metrics are directly comparable (the parity tests assert identical
/// accuracy); loss is recomputed host-side from the int8 logits with the
/// same mean softmax cross-entropy the fwd artifacts emit.  Every batch
/// runs the planned forward over one reused workspace.
pub fn evaluate_int8(qg: &QuantizedGraph, loader: &mut Loader) -> Result<EvalResult> {
    loader.reset();
    let (mut loss_sum, mut correct, mut n) = (0f64, 0usize, 0usize);
    let mut batches = 0usize;
    let mut ws = Workspace::new();
    while let Some(mut batch) = loader.next_batch() {
        // move x out of the owned batch — no copy; only the labels are
        // read afterwards
        let x = match qg.input {
            InputKind::Image { .. } => Value::F32(
                batch.f32s.remove("x").ok_or_else(|| anyhow!("batch missing f32 \"x\""))?,
            ),
            InputKind::Tokens { .. } => Value::I32(
                batch.i32s.remove("x").ok_or_else(|| anyhow!("batch missing i32 \"x\""))?,
            ),
        };
        let b = x.shape().first().copied().unwrap_or(0);
        let logits = qg.forward_into(&x, &mut ws)?;
        let labels =
            &batch.i32s.get("y").ok_or_else(|| anyhow!("batch missing labels \"y\""))?.data;
        let rows = logits.len() / qg.classes;
        let mut dl = ws.take_f32(logits.len());
        let (loss, _rows_ok) = softmax_xent_into(&logits, labels, rows, qg.classes, &mut dl)
            .map_err(|e| anyhow!("{} int8 eval: {e}", qg.model))?;
        ws.give_f32(dl);
        loss_sum += loss as f64; // padded rows repeat real rows, like the float path
        batches += 1;
        let shape = qg.logits_dims(b);
        correct += score_top1(&logits, &shape, &batch);
        ws.give_f32(logits);
        n += batch.count;
    }
    Ok(EvalResult {
        loss: (loss_sum / batches.max(1) as f64) as f32,
        accuracy: correct as f32 / n.max(1) as f32,
        f1: None,
        n,
    })
}

/// Split one loader batch into per-example serving inputs — the request
/// granularity of [`crate::serve`] (images → f32 `[C, H, H]`, tokens →
/// i32 `[T]`, no batch dimension).  Only the `batch.count` real examples
/// are returned; wrap-padded rows are dropped, so feeding these through
/// the request path scores exactly the set [`evaluate_int8`] scores —
/// the serve parity tests and latency bench pull their traffic from the
/// same loaders as offline eval.
pub fn example_inputs(kind: InputKind, batch: &Batch) -> Result<Vec<Value>> {
    match kind {
        InputKind::Image { .. } => {
            let x = batch.f32s.get("x").ok_or_else(|| anyhow!("batch missing f32 \"x\""))?;
            let rows = *x.shape.first().unwrap_or(&0);
            if rows == 0 || batch.count > rows {
                bail!("batch has {} examples but x is {:?}", batch.count, x.shape);
            }
            let shape: Vec<usize> = x.shape[1..].to_vec();
            let per = x.data.len() / rows;
            Ok(x.data
                .chunks(per)
                .take(batch.count)
                .map(|c| Value::F32(Tensor { shape: shape.clone(), data: c.to_vec() }))
                .collect())
        }
        InputKind::Tokens { .. } => {
            let x = batch.i32s.get("x").ok_or_else(|| anyhow!("batch missing i32 \"x\""))?;
            let rows = *x.shape.first().unwrap_or(&0);
            if rows == 0 || batch.count > rows {
                bail!("batch has {} examples but x is {:?}", batch.count, x.shape);
            }
            let shape: Vec<usize> = x.shape[1..].to_vec();
            let per = x.data.len() / rows;
            Ok(x.data
                .chunks(per)
                .take(batch.count)
                .map(|c| Value::I32(ITensor { shape: shape.clone(), data: c.to_vec() }))
                .collect())
        }
    }
}

fn score_top1(logits: &[f32], shape: &[usize], batch: &Batch) -> usize {
    // logits [B, C] (CNNs) or [B, T, V] (LM: token accuracy)
    let labels = &batch.i32s["y"].data;
    if shape.len() == 2 {
        let c = shape[1];
        (0..batch.count)
            .filter(|&i| argmax(&logits[i * c..(i + 1) * c]) == labels[i] as usize)
            .count()
    } else {
        let (t, v) = (shape[1], shape[2]);
        let mut ok = 0;
        for i in 0..batch.count {
            for j in 0..t {
                let off = (i * t + j) * v;
                if argmax(&logits[off..off + v]) == labels[i * t + j] as usize {
                    ok += 1;
                }
            }
        }
        // report tokens as "examples" scaled back to sequences
        ok / t
    }
}

fn score_spans(logits: &crate::tensor::Tensor, batch: &Batch) -> (usize, f64) {
    // logits [B, T, 2]
    let t = logits.shape[1];
    let ys = &batch.i32s["y_start"].data;
    let ye = &batch.i32s["y_end"].data;
    let (mut em, mut f1) = (0usize, 0f64);
    for i in 0..batch.count {
        let mut s_best = (f32::NEG_INFINITY, 0usize);
        let mut e_best = (f32::NEG_INFINITY, 0usize);
        for j in 0..t {
            let s = logits.data[(i * t + j) * 2];
            let e = logits.data[(i * t + j) * 2 + 1];
            if s > s_best.0 {
                s_best = (s, j);
            }
            if e > e_best.0 {
                e_best = (e, j);
            }
        }
        let (ps, pe) = (s_best.1, e_best.1);
        if ps == ys[i] as usize && pe == ye[i] as usize {
            em += 1;
        }
        f1 += span_f1(ps, pe, ys[i] as usize, ye[i] as usize) as f64;
    }
    (em, f1)
}
