//! Task registry: maps each model to its dataset generators and default
//! scales (the repro-scale substitutes of DESIGN.md §3).  All sizes are
//! config-overridable (`data.*` keys).

use crate::cfg::Config;
use crate::error::{bail, Result};
use crate::data::{corpus, images, squad, Loader};
use crate::data::loader::Source;

pub struct Task {
    pub train: Loader,
    pub test: Loader,
    /// calibration split (paper: 512 samples)
    pub calib: Loader,
    pub calib_samples: usize,
}

/// Default dataset scales per model — chosen so a full Table-4-style grid
/// runs on a single CPU core in minutes (see EXPERIMENTS.md).
fn defaults(model: &str) -> (usize, usize, usize) {
    // (train_n, test_n, classes) — classes unused for seq tasks
    match model {
        "resnet8" => (1024, 512, 10),
        "resnet20" => (2048, 512, 10),
        "resnet11b" => (2048, 512, 100),
        "bert_tiny" => (2048, 512, 0),
        "gpt_mini" | "tiny_tf" => (0, 0, 0), // corpus-based, see below
        // native-backend models: small enough that a full pipeline is a
        // sub-second affair in `cargo test`
        "mlp" | "mlp_wide" | "convnet" => (512, 256, 10),
        _ => (1024, 512, 10),
    }
}

pub fn build_task(model: &str, batch_size: usize, cfg: &Config) -> Result<Task> {
    let seed = cfg.u64("data.seed", 0);
    let (dn, tn, classes) = defaults(model);
    let train_n = cfg.usize("data.train_n", dn);
    let test_n = cfg.usize("data.test_n", tn);
    let calib_samples = cfg.usize("data.calib_samples", 512);
    // ~75% FP ceiling: leaves room for the PTQ→QAT ordering
    let noise = cfg.f32("data.noise", 2.0);

    let (train_src, test_src) = match model {
        "resnet8" | "resnet20" | "resnet11b" | "mlp" | "mlp_wide" | "convnet" => {
            // the native manifests bake in 8×8 inputs; the PJRT conv
            // models keep the CIFAR-like 32×32 default
            let default_hw = if model.starts_with("mlp") || model == "convnet" { 8 } else { 32 };
            let hw = cfg.usize("data.hw", default_hw);
            // same task (prototypes), disjoint sample streams
            let tr = images::generate_split(train_n, classes, hw, noise, seed, seed);
            let te = images::generate_split(test_n, classes, hw, noise, seed, seed ^ 0x7e57);
            (Source::Images(tr), Source::Images(te))
        }
        "bert_tiny" => {
            let seq = cfg.usize("data.seq_len", 64);
            let vocab = cfg.usize("data.vocab", 1024);
            let tr = squad::generate(train_n, seq, vocab, seed);
            let te = squad::generate(test_n, seq, vocab, seed ^ 0x7e57);
            (Source::Squad(tr), Source::Squad(te))
        }
        "gpt_mini" | "tiny_tf" => {
            // tiny_tf's native manifests bake in seq 16 / vocab 64; the
            // PJRT gpt_mini keeps the larger LM defaults
            let tf = model == "tiny_tf";
            let seq = cfg.usize("data.seq_len", if tf { 16 } else { 128 });
            let vocab = cfg.usize("data.vocab", if tf { 64 } else { 512 });
            let train_tokens = cfg.usize("data.train_tokens", if tf { 8_192 } else { 300_000 });
            let test_tokens = cfg.usize("data.test_tokens", if tf { 2_048 } else { 40_000 });
            // same language, disjoint streams
            let tr = corpus::generate_split(train_tokens, vocab, seed, seed);
            let te = corpus::generate_split(test_tokens, vocab, seed, seed ^ 0x7e57);
            (
                Source::Lm { corpus: tr, seq_len: seq },
                Source::Lm { corpus: te, seq_len: seq },
            )
        }
        other => bail!("unknown model {other:?}"),
    };

    Ok(Task {
        train: Loader::new(train_src.clone(), batch_size, seed + 1, true, true),
        test: Loader::new(test_src, batch_size, seed + 2, false, false),
        calib: Loader::new(train_src, batch_size, seed + 3, true, true),
        calib_samples,
    })
}

/// Inference-only entry point: just the test split at an arbitrary
/// serving batch size.  The int8 eval path (`efqat eval --exec int8`)
/// goes through here — unlike training, serving is not bound to the
/// batch the manifests bake in.  (Implemented over [`build_task`]: the
/// discarded train/calib splits cost microseconds at repro scale; grow a
/// split-selective builder if a real dataset ever lands.)
pub fn test_loader(model: &str, batch_size: usize, cfg: &Config) -> Result<Loader> {
    Ok(build_task(model, batch_size, cfg)?.test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_every_model_task() {
        let cfg = Config::empty();
        for m in [
            "resnet8", "resnet20", "resnet11b", "bert_tiny", "gpt_mini", "mlp", "mlp_wide",
            "convnet", "tiny_tf",
        ] {
            let t = build_task(m, 8, &cfg).unwrap();
            assert!(t.train.n_batches() > 0, "{m}");
            assert!(t.test.n_batches() > 0, "{m}");
        }
    }

    #[test]
    fn tiny_tf_defaults_match_the_native_manifests() {
        let t = build_task("tiny_tf", 8, &Config::empty()).unwrap();
        let mut train = t.train;
        let b = train.next_batch().unwrap();
        assert_eq!(b.i32s["x"].shape, vec![8, 16]);
        assert_eq!(b.i32s["y"].shape, vec![8, 16]);
        let max = b.i32s["x"].data.iter().copied().max().unwrap();
        assert!(max < 64, "vocab overflow: {max}");
    }

    #[test]
    fn config_overrides_sizes() {
        let mut cfg = Config::empty();
        cfg.set("data.train_n", "64");
        let t = build_task("resnet8", 8, &cfg).unwrap();
        assert_eq!(t.train.n_batches(), 8);
    }

    #[test]
    fn unknown_model_rejected() {
        assert!(build_task("nope", 8, &Config::empty()).is_err());
    }

    #[test]
    fn test_loader_honors_serving_batch_sizes() {
        for bs in [1usize, 32] {
            let mut l = test_loader("mlp", bs, &Config::empty()).unwrap();
            let b = l.next_batch().unwrap();
            assert_eq!(b.f32s["x"].shape[0], bs);
        }
    }
}
