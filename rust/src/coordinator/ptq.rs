//! PTQ: MinMax calibration (the paper's PTQ baseline, §4).
//!
//! Runs the `<model>_calib` artifact (an FP forward with min/max taps at
//! every quantized activation site) over the calibration set — 512
//! samples in the paper and in our default configs — aggregates the
//! per-batch ranges in [`crate::quant::MinMaxObserver`]s, and derives
//! activation scales/zero-points (Eq. 2).  Weight scales come directly
//! from the weights (Eq. 4), per output channel.

use std::collections::BTreeMap;

use crate::backend::Step;
use crate::data::Loader;
use crate::error::Result;
use crate::model::{ParamStore, QParamStore, StateStore};
use crate::quant::MinMaxObserver;

use super::binder::{bind_inputs, BindCtx};

/// Calibrate activation qparams with the calib artifact and initialize
/// weight scales from the current parameters.
pub fn calibrate(
    calib_step: &Step,
    params: &ParamStore,
    states: &StateStore,
    loader: &mut Loader,
    max_samples: usize,
    bits_w: u32,
    bits_a: u32,
) -> Result<QParamStore> {
    let man = &calib_step.manifest;
    let mut observers: BTreeMap<String, MinMaxObserver> = BTreeMap::new();
    loader.reset();
    let mut seen = 0usize;
    while seen < max_samples {
        let Some(batch) = loader.next_batch() else { break };
        let ctx = BindCtx { params, qparams: None, states, batch: &batch, selection: None };
        let inputs = bind_inputs(man, &ctx)?;
        let out = calib_step.execute(&inputs)?;
        for spec in &man.outputs {
            if spec.role != "calib" {
                continue;
            }
            let mm = out.get(&spec.name)?.f32()?;
            let site = spec.of.clone().unwrap_or_default();
            observers.entry(site).or_default().observe(mm.data[0], mm.data[1]);
        }
        seen += batch.count;
    }

    let mut q = QParamStore::default();
    for (site, obs) in observers {
        q.act.insert(site, obs.qparams(bits_a));
    }
    q.init_weight_scales(man, params, bits_w);
    Ok(q)
}
