//! High-level pipeline: FP checkpoint → PTQ → EfQAT epoch → eval.
//!
//! Shared by the `efqat` CLI, the examples, and every bench that
//! regenerates a paper table — one code path, many entry points.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::cfg::Config;
use crate::error::{anyhow, Context, Result};
use crate::freeze::Mode;
use crate::harness::sparkline;
use crate::model::{load_checkpoint, save_checkpoint, ParamStore, QParamStore, StateStore};
use crate::quant::ActQParams;
use crate::tensor::Tensor;

use super::metrics::MetricsLog;
use super::tasks::build_task;
use super::trainer::{
    artifact_name, fwd_artifact_name, pretrain_fp, DataParallelTrainer, EfqatTrainer, TrainCfg,
};

pub use super::trainer::fwd_artifact_name as fwd_artifact_name_of;
use super::{calibrate, evaluate, Session};

pub fn artifacts_dir(cfg: &Config) -> PathBuf {
    PathBuf::from(cfg.str("artifacts", "artifacts"))
}

pub fn ckpt_dir(cfg: &Config) -> PathBuf {
    PathBuf::from(cfg.str("ckpt_dir", "ckpts"))
}

pub fn fp_ckpt_path(cfg: &Config, model: &str) -> PathBuf {
    ckpt_dir(cfg).join(format!("{model}_fp.ckpt"))
}

/// "w4a8" → (4, 8)
pub fn parse_bits(bits: &str) -> Result<(u32, u32)> {
    crate::quant::parse_bits_tag(bits)
        .ok_or_else(|| anyhow!("bad bits tag {bits:?} (want e.g. w4a8)"))
}

/// Paper-default hyper-parameters, config-overridable.
pub fn train_cfg(cfg: &Config, model: &str) -> TrainCfg {
    let default_lr = match model {
        "resnet11b" => 1e-3,
        _ => 1e-2,
    };
    TrainCfg {
        lr_w: cfg.f32("train.lr_w", default_lr),
        momentum: cfg.f32("train.momentum", 0.9),
        weight_decay: cfg.f32("train.weight_decay", 1e-4),
        lr_q: cfg.f32("train.lr_q", 1e-6),
        log_domain_scales: cfg.bool("train.log_scales", false),
        freq: cfg.usize("train.freq", 4096),
        ratio_override: None,
        seed: cfg.u64("train.seed", 0),
    }
}

/// Worker-thread count for data-parallel training: the `workers` config
/// key (CLI `--workers W`), else the `EFQAT_TRAIN_WORKERS` env var
/// (mirroring `EFQAT_THREADS`), else 0 — the single-trainer path.
/// Any value ≥ 1 selects [`DataParallelTrainer`]; results are
/// bit-identical across worker counts, so this is purely a throughput
/// knob.
pub fn train_workers(cfg: &Config) -> usize {
    if cfg.has("workers") {
        return cfg.usize("workers", 0);
    }
    std::env::var("EFQAT_TRAIN_WORKERS")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(0)
}

pub fn load_fp_checkpoint(cfg: &Config, model: &str) -> Result<(ParamStore, StateStore)> {
    let path = fp_ckpt_path(cfg, model);
    let ck = load_checkpoint(&path).with_context(|| {
        format!("loading FP checkpoint {} (run `efqat pretrain` first)", path.display())
    })?;
    Ok((
        ParamStore { map: ck.get("params").cloned().unwrap_or_default() },
        StateStore { map: ck.get("states").cloned().unwrap_or_default() },
    ))
}

/// Pretrain the FP baseline and save its checkpoint.  Returns the test
/// headline (paper Table 3 "FP" column).
pub fn run_pretrain(session: &Session, cfg: &Config, model: &str, epochs: usize) -> Result<f32> {
    let step = session.steps.get(&artifact_name(model, "fp", "fp", 100))?;
    let bs = step.manifest.batch_size;
    let mut task = build_task(model, bs, cfg)?;
    let mut params = ParamStore::init(&step.manifest, cfg.u64("train.seed", 0));
    let mut states = StateStore::init(&step.manifest);
    let tcfg = train_cfg(cfg, model);
    let log = pretrain_fp(&step, &mut params, &mut states, &mut task.train, epochs, &tcfg)?;
    let fwd = session.steps.get(&fwd_artifact_name(model, "fp"))?;
    let result = evaluate(&fwd, &params, None, &states, &mut task.test)?;
    println!(
        "[pretrain] {model}: train-loss {:.4} test-headline {:.2}  {}",
        log.mean_loss_tail(20),
        result.headline(),
        sparkline(&log.losses(), 50)
    );
    save_checkpoint(
        &fp_ckpt_path(cfg, model),
        &[("params", &params.map), ("states", &states.map)],
    )?;
    Ok(result.headline())
}

/// Everything one EfQAT run produces; reused by CLI, examples and benches.
#[derive(Clone, Debug)]
pub struct PipelineSummary {
    pub model: String,
    pub bits: String,
    pub mode: String,
    pub ratio: usize,
    pub ptq_headline: f32,
    pub efqat_headline: f32,
    /// artifact execution time over the epoch (paper Table 5's quantity)
    pub exec_seconds: f64,
    pub overhead_seconds: f64,
    /// data-parallel worker count (0 = single-trainer path)
    pub workers: usize,
    /// gradient-exchange payload shipped over the epoch (bytes; 0 when
    /// `workers` is 0)
    pub bytes_exchanged: u64,
    pub losses: Vec<f32>,
}

impl PipelineSummary {
    pub fn render(&self) -> String {
        let dp = if self.workers > 0 {
            format!(
                "\n  data-parallel: {} workers, {:.1} KiB exchanged",
                self.workers,
                self.bytes_exchanged as f64 / 1024.0
            )
        } else {
            String::new()
        };
        format!(
            "[efqat] {} {} mode={} ratio={}%\n  PTQ   headline {:.2}\n  EfQAT headline \
             {:.2}  ({:+.2})\n  step exec {:.2}s, coordinator overhead {:.2}s{}\n  loss {}",
            self.model,
            self.bits,
            self.mode,
            self.ratio,
            self.ptq_headline,
            self.efqat_headline,
            self.efqat_headline - self.ptq_headline,
            self.exec_seconds,
            self.overhead_seconds,
            dp,
            sparkline(&self.losses, 60),
        )
    }
}

/// The full Algorithm-1 pipeline for one (model, bits, mode, ratio) cell:
/// loads the FP checkpoint, calibrates PTQ, runs the EfQAT epoch(s), and
/// evaluates.  `mode` ∈ {cwpl, cwpn, lwpn, qat, r0}.
pub fn run_efqat_pipeline(
    session: &Session,
    cfg: &Config,
    model: &str,
    bits: &str,
    mode: &str,
    ratio: usize,
) -> Result<PipelineSummary> {
    let (params, states) = load_fp_checkpoint(cfg, model)?;
    let (w_bits, a_bits) = parse_bits(bits)?;

    // PTQ initialization (Algorithm 1: "Start from a PTQ model")
    let calib = session.steps.get(&format!("{model}_calib"))?;
    let mut task = build_task(model, calib.manifest.batch_size, cfg)?;
    let q =
        calibrate(&calib, &params, &states, &mut task.calib, task.calib_samples, w_bits, a_bits)?;
    let fwd = session.steps.get(&fwd_artifact_name(model, bits))?;
    let ptq_eval = evaluate(&fwd, &params, Some(&q), &states, &mut task.test)?;

    // EfQAT epoch
    let ratio_for_artifact = match mode {
        "qat" => 100,
        "r0" => 0,
        _ => ratio,
    };
    let art = artifact_name(model, bits, mode, ratio_for_artifact);
    let step = session.steps.get(&art)?;
    let mut tcfg = train_cfg(cfg, model);
    if mode == "lwpn" {
        tcfg.ratio_override = Some(ratio as f32 / 100.0);
    }
    let mut trainer = EfqatTrainer::new(step, params, q, states, Mode::parse(mode), tcfg)?;
    let epochs = cfg.usize("train.efqat_epochs", 1);
    let mut workers = train_workers(cfg);
    let mut log = MetricsLog::new(&art);
    let mut bytes_exchanged = 0u64;
    if workers > 0 {
        let mut dp = DataParallelTrainer::new(trainer, workers)?;
        for _ in 0..epochs {
            let l = dp.train_epoch(&mut task.train)?;
            for r in l.records {
                log.push(r);
            }
        }
        bytes_exchanged = dp.active_bytes;
        workers = dp.workers; // report the clamped count
        trainer = dp.into_inner();
    } else {
        for _ in 0..epochs {
            let l = trainer.train_epoch(&mut task.train)?;
            for r in l.records {
                log.push(r);
            }
        }
    }

    let result =
        evaluate(&fwd, &trainer.params, Some(&trainer.qparams), &trainer.states, &mut task.test)?;

    if cfg.bool("save_ckpt", true) {
        let qmap = qparams_to_tensors(&trainer.qparams);
        let out = ckpt_dir(cfg).join(format!("{model}_{bits}_{mode}{ratio}.ckpt"));
        save_checkpoint(
            &out,
            &[("params", &trainer.params.map), ("states", &trainer.states.map), ("qparams", &qmap)],
        )?;
    }

    Ok(PipelineSummary {
        model: model.to_string(),
        bits: bits.to_string(),
        mode: mode.to_string(),
        ratio,
        ptq_headline: ptq_eval.headline(),
        efqat_headline: result.headline(),
        exec_seconds: log.total_exec().as_secs_f64(),
        overhead_seconds: log.total_overhead().as_secs_f64(),
        workers,
        bytes_exchanged,
        losses: log.losses(),
    })
}

/// Make sure an FP checkpoint exists (pretraining if needed); idempotent.
pub fn ensure_fp_checkpoint(
    session: &Session,
    cfg: &Config,
    model: &str,
    epochs: usize,
) -> Result<()> {
    if fp_ckpt_path(cfg, model).exists() {
        return Ok(());
    }
    run_pretrain(session, cfg, model, epochs)?;
    Ok(())
}

pub fn qparams_to_tensors(q: &QParamStore) -> BTreeMap<String, Tensor> {
    let mut m = BTreeMap::new();
    for (k, v) in &q.sw {
        m.insert(format!("sw:{k}"), v.clone());
    }
    for (k, a) in &q.act {
        m.insert(format!("sx:{k}"), Tensor::scalar(a.scale));
        m.insert(format!("zx:{k}"), Tensor::scalar(a.zero_point));
    }
    m
}

pub fn qparams_from_tensors(m: &BTreeMap<String, Tensor>) -> QParamStore {
    let mut q = QParamStore::default();
    for (k, v) in m {
        if let Some(site) = k.strip_prefix("sw:") {
            q.sw.insert(site.to_string(), v.clone());
        } else if let Some(site) = k.strip_prefix("sx:") {
            q.act
                .entry(site.to_string())
                .or_insert(ActQParams { scale: 1.0, zero_point: 0.0 })
                .scale = v.data[0];
        } else if let Some(site) = k.strip_prefix("zx:") {
            q.act
                .entry(site.to_string())
                .or_insert(ActQParams { scale: 1.0, zero_point: 0.0 })
                .zero_point = v.data[0];
        }
    }
    q
}

/// Load a quantized checkpoint produced by [`run_efqat_pipeline`].
pub fn load_quant_checkpoint(path: &Path) -> Result<(ParamStore, StateStore, QParamStore)> {
    let ck = load_checkpoint(path)?;
    Ok((
        ParamStore { map: ck.get("params").cloned().unwrap_or_default() },
        StateStore { map: ck.get("states").cloned().unwrap_or_default() },
        ck.get("qparams").map(qparams_from_tensors).unwrap_or_default(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_parsing() {
        assert_eq!(parse_bits("w8a8").unwrap(), (8, 8));
        assert_eq!(parse_bits("w4a4").unwrap(), (4, 4));
        assert!(parse_bits("8a8").is_err());
        assert!(parse_bits("w8").is_err());
    }

    #[test]
    fn qparams_tensor_round_trip() {
        let mut q = QParamStore::default();
        q.sw.insert("fc.w".into(), Tensor::new(vec![2], vec![0.1, 0.2]).unwrap());
        q.act.insert("fc.w".into(), ActQParams { scale: 0.05, zero_point: 7.0 });
        let m = qparams_to_tensors(&q);
        let q2 = qparams_from_tensors(&m);
        assert_eq!(q2.sw["fc.w"].data, vec![0.1, 0.2]);
        assert_eq!(q2.act["fc.w"], ActQParams { scale: 0.05, zero_point: 7.0 });
    }
}
