//! Trainers: FP baseline pretraining and the EfQAT epoch (Algorithm 1).
//!
//! Everything here is manifest-driven and model-agnostic: the same loop
//! trains the 2-layer MLPs, the convnet, and the tiny_tf transformer on
//! the native graph executor (or any PJRT artifact) — grads are applied
//! by role (`weight` rows masked, `bias`/`norm`/`embed` dense, qparams
//! via Adam), never by model-specific name.
//!
//! The EfQAT step is exactly the paper's loop:
//!   1. forward + backward on the compiled step — the backward computes
//!      the full dX chain but only the unfrozen rows of dW/dS_w
//!      (ratio artifacts: gathered rows; LWPN artifact: flag-gated)
//!   2. "Optimizer Step": row-masked SGD(momentum) for the unfrozen weight
//!      channels, dense SGD for biases/norm params, Adam for quantization
//!      parameters (S_w rows of unfrozen channels; S_x/Z_x per site)
//!   3. BN running statistics threaded back into the state store
//!   4. every `f` samples: refresh importances of unfrozen channels and
//!      re-run Top-K selection (CWPL/CWPN/LWPN policies)

use std::rc::Rc;
use std::time::Instant;

use crate::backend::{Step, Value};
use crate::data::{Batch, Loader};
use crate::error::{anyhow, bail, Result};
use crate::exec::Workspace;
use crate::freeze::{site_k, FreezePolicy, Mode, Selection, Site};
use crate::graph::GraphStep;
use crate::model::{Manifest, ParamStore, QParamStore, StateStore};
use crate::ops::matmul;
use crate::optim::{Adam, SgdMomentum};
use crate::tensor::Tensor;

use super::binder::{BindCtx, Binder};
use super::metrics::{MetricsLog, StepRecord, StepTiming};
use super::shard::{run_sharded, split_batch_into, GradExchange, ShardPlan};

/// Hyper-parameters of one training phase (defaults follow the paper §4).
#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub lr_w: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// Adam LR for quantization parameters (paper: 1e-6 / 1e-7 per task)
    pub lr_q: f32,
    /// optimize ln(S) instead of S (Appendix A.2 ablation)
    pub log_domain_scales: bool,
    /// freezing frequency f in *samples* (paper §3.2)
    pub freq: usize,
    /// LWPN only: unfrozen-parameter budget (the lwpn artifact is shared
    /// across ratios — the budget lives in the policy, not the ABI)
    pub ratio_override: Option<f32>,
    pub seed: u64,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            lr_w: 1e-2,
            momentum: 0.9,
            weight_decay: 1e-4,
            lr_q: 1e-6,
            log_domain_scales: false,
            freq: 4096,
            ratio_override: None,
            seed: 0,
        }
    }
}

/// Map (model, bits, mode, ratio%) to the artifact name that serves it.
pub fn artifact_name(model: &str, bits: &str, mode: &str, ratio_pct: usize) -> String {
    match mode {
        "fp" => format!("{model}_fp_train"),
        "lwpn" => format!("{model}_{bits}_train_lwpn"),
        // qat == ratio 100; r0 == ratio 0 — all served by ratio artifacts
        _ => format!("{model}_{bits}_train_r{ratio_pct}"),
    }
}

pub fn fwd_artifact_name(model: &str, bits: &str) -> String {
    if bits == "fp" {
        format!("{model}_fp_fwd")
    } else {
        format!("{model}_{bits}_fwd")
    }
}

/// Label rows per example: 1 for classifiers, the sequence length for
/// per-token LM graphs (`y: [B, T]`).  The step's `correct` output counts
/// label rows, so [`crate::coordinator::metrics::StepRecord`] must use
/// the same units for its denominator or train accuracy leaves `[0, 1]`.
fn label_rows_per_example(man: &Manifest) -> usize {
    man.inputs
        .iter()
        .find(|i| i.name == "y")
        .map(|y| (y.elems() / man.batch_size.max(1)).max(1))
        .unwrap_or(1)
}

/// FP baseline pretraining (the paper's FP / FP+1 checkpoints): dense SGD
/// over every parameter with the `<model>_fp_train` artifact.
pub fn pretrain_fp(
    step: &Step,
    params: &mut ParamStore,
    states: &mut StateStore,
    loader: &mut Loader,
    epochs: usize,
    cfg: &TrainCfg,
) -> Result<MetricsLog> {
    let man = &step.manifest;
    if man.sel_mode != "fp" {
        bail!("{} is not an FP train artifact", man.name);
    }
    let mut sgd = SgdMomentum::new(cfg.lr_w, cfg.momentum, cfg.weight_decay);
    let mut log = MetricsLog::new(&format!("pretrain:{}", man.model));
    let mut step_no = 0usize;
    // one workspace + one persistent binding across all epochs/steps —
    // the steady-state loop performs no per-step heap allocation
    let mut ws = Workspace::new();
    let mut binder = Binder::new();
    let loss_i = man.out_pos("loss")?;
    let correct_i = man.out_pos("correct")?;
    for _ in 0..epochs {
        loader.reset();
        while let Some(batch) = loader.next_batch() {
            let mut timing = StepTiming::default();
            let t0 = Instant::now();
            let ctx = BindCtx { params, qparams: None, states, batch: &batch, selection: None };
            let inputs = binder.bind(man, &ctx)?;
            timing.bind = t0.elapsed();
            let (outs, dt) = step.execute_timed_ws(inputs, &mut ws)?;
            timing.exec = dt;

            let t2 = Instant::now();
            for (spec, val) in man.outputs.iter().zip(&outs) {
                match spec.role.as_str() {
                    "grad" => {
                        let of = spec.of.as_deref().unwrap();
                        sgd.apply_full(of, params.get_mut(of)?, &val.f32()?.data);
                    }
                    "state" => {
                        let of = spec.of.as_deref().unwrap();
                        *states.map.get_mut(of).unwrap() = val.f32()?.clone();
                    }
                    _ => {}
                }
            }
            timing.optim = t2.elapsed();
            let rec = StepRecord {
                step: step_no,
                loss: outs[loss_i].scalar()?,
                correct: outs[correct_i].i32()?.data[0],
                batch: batch.count * label_rows_per_example(man),
                active_frac: 1.0,
                bytes_exchanged: 0,
                bwd_layers_skipped: 0,
                timing,
            };
            ws.give_values(outs);
            log.push(rec);
            step_no += 1;
        }
    }
    Ok(log)
}

/// How the weight-gradient selection works for a given train artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SelKind {
    /// full dW everywhere (QAT baseline, ratio=100)
    Full,
    /// no dW at all (ratio=0)
    None,
    /// per-site index vectors (EfQAT-CWPL / CWPN)
    Indexed,
    /// per-site flags (EfQAT-LWPN)
    Flagged,
}

fn sel_kind(man: &Manifest) -> SelKind {
    if man.sel_mode == "lwpn" {
        SelKind::Flagged
    } else if man.inputs.iter().any(|i| i.role == "index") {
        SelKind::Indexed
    } else if man.ratio <= 0.0 {
        SelKind::None
    } else {
        SelKind::Full
    }
}

/// The "Optimizer Step" of Algorithm 1, applied to one step's output
/// vector: row-masked SGD(momentum) for unfrozen weight channels, dense
/// SGD for biases/norm params, Adam for quantization parameters, and BN
/// running statistics threaded back into the state store.
///
/// Shared by [`EfqatTrainer`] and [`DataParallelTrainer`]: the reduced
/// shard-0 output vector of the gradient exchange is ABI-identical to a
/// full-batch output vector, so both paths converge here.
#[allow(clippy::too_many_arguments)]
fn apply_train_outputs(
    man: &Manifest,
    outs: &[Value],
    sel: SelKind,
    selection: Option<&Selection>,
    sgd: &mut SgdMomentum,
    adam: &mut Adam,
    params: &mut ParamStore,
    qparams: &mut QParamStore,
    states: &mut StateStore,
) -> Result<()> {
    let kind_of = |name: &str| -> &str {
        man.params
            .iter()
            .find(|p| p.name == name)
            .map(|p| p.kind.as_str())
            .unwrap_or("")
    };
    let site_index = |name: &str| man.wsites.iter().position(|s| s.name == name);
    for (spec, val) in man.outputs.iter().zip(outs) {
        match spec.role.as_str() {
            "grad" => {
                let of = spec.of.as_deref().unwrap();
                let g = val.f32()?;
                if let Some(site) = of.strip_prefix("sw:") {
                    // per-row weight scales: only unfrozen channels update
                    let sw = qparams.sw.get_mut(site).unwrap();
                    match (sel, selection) {
                        (SelKind::Indexed, Some(sel)) => {
                            let si = site_index(site).unwrap();
                            adam.apply_rows(of, &mut sw.data, &g.data, &sel.channels[si]);
                        }
                        (SelKind::Flagged, Some(sel)) => {
                            let si = site_index(site).unwrap();
                            if sel.flags[si] {
                                adam.apply_full(of, &mut sw.data, &g.data);
                            }
                        }
                        _ => adam.apply_full(of, &mut sw.data, &g.data),
                    }
                } else if let Some(site) = of.strip_prefix("sx:") {
                    let act = qparams.act.get_mut(site).unwrap();
                    adam.apply_scalar(of, &mut act.scale, g.data[0]);
                } else if let Some(site) = of.strip_prefix("zx:") {
                    let act = qparams.act.get_mut(site).unwrap();
                    // zero points are plain parameters (never log-domain)
                    let mut zp = act.zero_point;
                    let saved = adam.log_domain;
                    adam.log_domain = false;
                    adam.apply_scalar(of, &mut zp, g.data[0]);
                    adam.log_domain = saved;
                    act.zero_point = zp;
                } else if kind_of(of) == "weight" {
                    match (sel, selection) {
                        (SelKind::Indexed, Some(sel)) => {
                            let si = site_index(of).unwrap();
                            sgd.apply_rows(of, params.get_mut(of)?, &g.data, &sel.channels[si]);
                        }
                        (SelKind::Flagged, Some(sel)) => {
                            let si = site_index(of).unwrap();
                            if sel.flags[si] {
                                sgd.apply_full(of, params.get_mut(of)?, &g.data);
                            }
                        }
                        _ => sgd.apply_full(of, params.get_mut(of)?, &g.data),
                    }
                } else {
                    // biases / norm params: always updated (paper §4)
                    sgd.apply_full(of, params.get_mut(of)?, &g.data);
                }
            }
            "state" => {
                let of = spec.of.as_deref().unwrap();
                *states.map.get_mut(of).unwrap() = val.f32()?.clone();
            }
            _ => {}
        }
    }
    Ok(())
}

/// One EfQAT (or QAT) training phase over a quantized model.
pub struct EfqatTrainer {
    pub step: Rc<Step>,
    pub params: ParamStore,
    pub qparams: QParamStore,
    pub states: StateStore,
    pub cfg: TrainCfg,
    pub policy: Option<FreezePolicy>,
    sel: SelKind,
    sgd: SgdMomentum,
    adam: Adam,
    step_no: usize,
    /// One execution workspace reused across all epochs/steps.
    ws: Workspace,
    /// Persistent input binding, refreshed in place each step.
    binder: Binder,
    /// Positions of the `loss` / `correct` outputs, resolved once.
    loss_i: usize,
    correct_i: usize,
}

impl EfqatTrainer {
    pub fn new(
        step: Rc<Step>,
        params: ParamStore,
        qparams: QParamStore,
        states: StateStore,
        mode: Option<Mode>,
        cfg: TrainCfg,
    ) -> Result<EfqatTrainer> {
        let man = &step.manifest;
        let sel = sel_kind(man);
        let policy = match sel {
            SelKind::Indexed | SelKind::Flagged => {
                let mode = mode.ok_or_else(|| anyhow!("freezing mode required for {}", man.name))?;
                if sel == SelKind::Flagged && mode != Mode::Lwpn {
                    bail!("artifact {} is LWPN but mode is {mode:?}", man.name);
                }
                let sites: Vec<Site> = man
                    .wsites
                    .iter()
                    .map(|s| Site {
                        name: s.name.clone(),
                        c_out: s.c_out,
                        k: site_k(s.c_out, man.ratio),
                        size: s.size,
                    })
                    .collect();
                // cross-check static slot counts against the artifact ABI
                for inp in man.inputs.iter().filter(|i| i.role == "index") {
                    let of = inp.of.as_deref().unwrap_or("");
                    let site = sites.iter().find(|s| s.name == of).unwrap();
                    if site.k != inp.shape[0] {
                        bail!("site {of}: k mismatch rust {} vs artifact {}", site.k, inp.shape[0]);
                    }
                }
                let weights: Vec<&Tensor> =
                    sites.iter().map(|s| params.get(&s.name).unwrap()).collect();
                // indexed artifacts bake k into the ABI — the ratio cannot be
                // overridden there; the shared LWPN artifact can.
                let ratio = match (sel, cfg.ratio_override) {
                    (SelKind::Flagged, Some(r)) => r,
                    _ => man.ratio,
                };
                Some(FreezePolicy::new(mode, ratio, cfg.freq, sites.clone(), &weights))
            }
            _ => None,
        };
        let sgd = SgdMomentum::new(cfg.lr_w, cfg.momentum, cfg.weight_decay);
        let adam = Adam::new(cfg.lr_q).log_domain(cfg.log_domain_scales);
        let loss_i = man.out_pos("loss")?;
        let correct_i = man.out_pos("correct")?;
        Ok(EfqatTrainer {
            step,
            params,
            qparams,
            states,
            cfg,
            policy,
            sel,
            sgd,
            adam,
            step_no: 0,
            ws: Workspace::new(),
            binder: Binder::new(),
            loss_i,
            correct_i,
        })
    }

    /// One training step on one batch.  Returns the step record.
    ///
    /// The hot loop is allocation-free in the steady state: the step
    /// (an `Rc`) is cloned instead of its manifest, the freeze
    /// selection is borrowed instead of cloned, inputs are refreshed in
    /// place by the persistent [`Binder`], the executor draws every
    /// buffer from the trainer's [`Workspace`], and the positional
    /// outputs are recycled back into it after the optimizer consumes
    /// them.
    pub fn train_step(&mut self, batch: &crate::data::Batch) -> Result<StepRecord> {
        let step = Rc::clone(&self.step);
        let man = &step.manifest;
        let mut timing = StepTiming::default();
        let selection = self.policy.as_ref().map(|p| p.selection());

        let t0 = Instant::now();
        let ctx = BindCtx {
            params: &self.params,
            qparams: Some(&self.qparams),
            states: &self.states,
            batch,
            selection,
        };
        let inputs = self.binder.bind(man, &ctx)?;
        timing.bind = t0.elapsed();

        let (outs, dt) = step.execute_timed_ws(inputs, &mut self.ws)?;
        timing.exec = dt;

        // ---- Optimizer Step (Algorithm 1) --------------------------------
        let t2 = Instant::now();
        apply_train_outputs(
            man,
            &outs,
            self.sel,
            selection,
            &mut self.sgd,
            &mut self.adam,
            &mut self.params,
            &mut self.qparams,
            &mut self.states,
        )?;
        timing.optim = t2.elapsed();

        let loss = outs[self.loss_i].scalar()?;
        let correct = outs[self.correct_i].i32()?.data[0];
        let active_frac = match (&self.policy, self.sel) {
            (Some(p), _) => p.unfrozen_fraction(),
            (None, SelKind::None) => 0.0,
            _ => 1.0,
        };
        // sites below the truncation boundary the executor just used —
        // computed before the refresh below moves the selection
        let bwd_layers_skipped = match &self.policy {
            Some(p) if crate::graph::backward_truncation_enabled() => {
                p.selection().lowest_active_layer(&p.sites).unwrap_or(0)
            }
            _ => 0,
        };
        self.ws.give_values(outs);

        // ---- freezing-frequency bookkeeping -------------------------------
        let t3 = Instant::now();
        if let Some(policy) = &mut self.policy {
            if policy.will_refresh(batch.count) {
                let weights: Vec<&Tensor> = policy
                    .sites
                    .iter()
                    .map(|s| self.params.get(&s.name).unwrap())
                    .collect();
                policy.observe_samples(batch.count, &weights);
            } else {
                policy.observe_samples(batch.count, &[]);
            }
        }
        timing.freeze = t3.elapsed();

        let rec = StepRecord {
            step: self.step_no,
            loss,
            correct,
            batch: batch.count * label_rows_per_example(man),
            active_frac,
            bytes_exchanged: 0,
            bwd_layers_skipped,
            timing,
        };
        self.step_no += 1;
        Ok(rec)
    }

    /// Combined bit-exact digest of the SGD and Adam optimizer state —
    /// the data-parallel equivalence suite compares training runs with
    /// this without exposing the private moment buffers.
    pub fn optimizer_digest(&self) -> u64 {
        self.sgd.state_digest() ^ self.adam.state_digest().rotate_left(1)
    }

    /// One full epoch (the paper applies exactly one EfQAT epoch).
    pub fn train_epoch(&mut self, loader: &mut Loader) -> Result<MetricsLog> {
        let mut log = MetricsLog::new(&format!("efqat:{}", self.step.manifest.name));
        loader.reset();
        while let Some(batch) = loader.next_batch() {
            let rec = self.train_step(&batch)?;
            log.push(rec);
        }
        Ok(log)
    }
}

/// One data-parallel worker's private execution context: a shard-batch
/// [`GraphStep`] clone plus its own workspace and input binding (the
/// graph executor is `Send` but not `Sync`, so each worker owns one).
struct WorkerSlot {
    step: GraphStep,
    ws: Workspace,
    binder: Binder,
}

/// Data-parallel EfQAT training (`efqat train --workers W`).
///
/// Wraps an [`EfqatTrainer`] (which keeps owning every piece of host
/// state — params, qparams, states, optimizers, freeze policy) and adds
/// `W` worker slots.  Each batch is split into the *fixed* virtual-shard
/// grid of [`ShardPlan`] — a function of the batch size, never of `W` —
/// and workers run forward + frozen-aware partial backward on their
/// shards round-robin with a capped GEMM thread budget
/// (`EFQAT_THREADS / W`).  The [`GradExchange`] then tree-reduces only
/// the active gradient slices into shard 0, in a fixed pairwise order,
/// before the ordinary optimizer step runs.  Final weights, optimizer
/// state and metrics are bit-identical at any `W`
/// (`rust/tests/data_parallel.rs` enforces this for W ∈ {1, 2, 4}).
pub struct DataParallelTrainer {
    /// The wrapped single trainer; all host state lives here.
    pub inner: EfqatTrainer,
    /// Actual worker count (requested, clamped to the shard count).
    pub workers: usize,
    /// Cumulative exchange payload actually shipped (bytes).
    pub active_bytes: u64,
    /// Cumulative dense-equivalent payload (bytes) — the shrink baseline.
    pub dense_bytes: u64,
    plan: ShardPlan,
    exchange: GradExchange,
    slots: Vec<WorkerSlot>,
    /// Shard batches, refreshed in place each step.
    shard_batches: Vec<Batch>,
    /// Per-worker GEMM thread budget (`EFQAT_THREADS / W`, at least 1).
    gemm_threads: usize,
}

impl DataParallelTrainer {
    /// Wrap `inner` with `workers` worker slots.  Only native-backend
    /// steps can be sharded (the worker steps are synthesized from the
    /// model's graph declaration at the shard batch size).
    pub fn new(inner: EfqatTrainer, workers: usize) -> Result<DataParallelTrainer> {
        let man = &inner.step.manifest;
        let plan = ShardPlan::new(man.batch_size, inner.cfg.seed);
        let exchange = GradExchange::plan(man)?;
        let w = workers.clamp(1, plan.shards);
        let mut slots = Vec::with_capacity(w);
        for _ in 0..w {
            slots.push(WorkerSlot {
                step: crate::backend::native::shard_step(&man.name, plan.shard_bs)?,
                ws: Workspace::new(),
                binder: Binder::new(),
            });
        }
        let gemm_threads = (matmul::total_threads() / w).max(1);
        Ok(DataParallelTrainer {
            inner,
            workers: w,
            active_bytes: 0,
            dense_bytes: 0,
            plan,
            exchange,
            slots,
            shard_batches: Vec::new(),
            gemm_threads,
        })
    }

    /// The sharding layout (for benches and diagnostics).
    pub fn shard_plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// One data-parallel training step: split → shard forward/backward →
    /// sparse tree-reduce → optimizer scatter → freeze bookkeeping.
    pub fn train_step(&mut self, batch: &Batch) -> Result<StepRecord> {
        let mut timing = StepTiming::default();
        let t0 = Instant::now();
        split_batch_into(batch, self.plan.shards, &mut self.shard_batches)?;
        timing.bind = t0.elapsed();

        let selection = self.inner.policy.as_ref().map(|p| p.selection());
        let shards = self.plan.shards;
        let gemm = self.gemm_threads;
        let params = &self.inner.params;
        let qparams = &self.inner.qparams;
        let states = &self.inner.states;
        let shard_batches = &self.shard_batches;
        let t1 = Instant::now();
        let mut outs = run_sharded(&mut self.slots, shards, |slot, s| {
            // split EFQAT_THREADS across workers; the cap is thread-local,
            // so set it on whichever thread ended up running this shard
            matmul::set_thread_cap(gemm);
            let WorkerSlot { step, ws, binder } = slot;
            let ctx = BindCtx {
                params,
                qparams: Some(qparams),
                states,
                batch: &shard_batches[s],
                selection,
            };
            let inputs = binder.bind(&step.man, &ctx)?;
            step.execute_ws(inputs, ws)
        })?;
        // the W=1 path runs the closure on this thread; clear the cap so
        // eval/serve GEMMs after training see the full budget again
        matmul::set_thread_cap(0);
        timing.exec = t1.elapsed();

        // ---- sparse gradient exchange ------------------------------------
        let t2 = Instant::now();
        let stats = self.exchange.reduce(&mut outs, selection)?;
        timing.exchange = t2.elapsed();
        self.active_bytes += stats.active_bytes;
        self.dense_bytes += stats.dense_bytes;

        // ---- Optimizer Step on the reduced shard-0 vector ----------------
        let t3 = Instant::now();
        apply_train_outputs(
            &self.slots[0].step.man,
            &outs[0],
            self.inner.sel,
            selection,
            &mut self.inner.sgd,
            &mut self.inner.adam,
            &mut self.inner.params,
            &mut self.inner.qparams,
            &mut self.inner.states,
        )?;
        timing.optim = t3.elapsed();

        let loss = outs[0][self.inner.loss_i].scalar()?;
        let correct = outs[0][self.inner.correct_i].i32()?.data[0];
        let active_frac = match (&self.inner.policy, self.inner.sel) {
            (Some(p), _) => p.unfrozen_fraction(),
            (None, SelKind::None) => 0.0,
            _ => 1.0,
        };
        // every shard binds the same flags, so the truncation boundary
        // (and this metric) is identical across workers
        let bwd_layers_skipped = match &self.inner.policy {
            Some(p) if crate::graph::backward_truncation_enabled() => {
                p.selection().lowest_active_layer(&p.sites).unwrap_or(0)
            }
            _ => 0,
        };
        // recycle each shard's buffers into the workspace of the worker
        // that produced them (shard s ran on worker s mod nw)
        let nw = self.slots.len().min(shards).max(1);
        for (s, o) in outs.into_iter().enumerate() {
            self.slots[s % nw].ws.give_values(o);
        }

        // ---- freezing-frequency bookkeeping ------------------------------
        let t4 = Instant::now();
        if let Some(policy) = &mut self.inner.policy {
            if policy.will_refresh(batch.count) {
                let weights: Vec<&Tensor> = policy
                    .sites
                    .iter()
                    .map(|s| self.inner.params.get(&s.name).unwrap())
                    .collect();
                policy.observe_samples(batch.count, &weights);
            } else {
                policy.observe_samples(batch.count, &[]);
            }
        }
        timing.freeze = t4.elapsed();

        let rec = StepRecord {
            step: self.inner.step_no,
            loss,
            correct,
            batch: batch.count * label_rows_per_example(&self.inner.step.manifest),
            active_frac,
            bytes_exchanged: stats.active_bytes,
            bwd_layers_skipped,
            timing,
        };
        self.inner.step_no += 1;
        Ok(rec)
    }

    /// One full epoch, mirroring [`EfqatTrainer::train_epoch`].
    pub fn train_epoch(&mut self, loader: &mut Loader) -> Result<MetricsLog> {
        let label = format!("efqat-dp{}:{}", self.workers, self.inner.step.manifest.name);
        let mut log = MetricsLog::new(&label);
        loader.reset();
        while let Some(batch) = loader.next_batch() {
            let rec = self.train_step(&batch)?;
            log.push(rec);
        }
        Ok(log)
    }

    /// Unwrap back into the single trainer (all host state lives there;
    /// the worker slots are discarded).
    pub fn into_inner(self) -> EfqatTrainer {
        self.inner
    }

    /// See [`EfqatTrainer::optimizer_digest`].
    pub fn optimizer_digest(&self) -> u64 {
        self.inner.optimizer_digest()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names() {
        assert_eq!(artifact_name("resnet20", "w4a8", "cwpn", 25), "resnet20_w4a8_train_r25");
        assert_eq!(artifact_name("resnet20", "w4a8", "qat", 100), "resnet20_w4a8_train_r100");
        assert_eq!(artifact_name("resnet20", "w4a8", "lwpn", 25), "resnet20_w4a8_train_lwpn");
        assert_eq!(artifact_name("bert_tiny", "w8a8", "fp", 100), "bert_tiny_fp_train");
        assert_eq!(fwd_artifact_name("bert_tiny", "fp"), "bert_tiny_fp_fwd");
        assert_eq!(fwd_artifact_name("bert_tiny", "w8a8"), "bert_tiny_w8a8_fwd");
    }
}
