//! Training metrics: per-step records, timing breakdown, CSV logging.

use std::io::Write;
use std::path::Path;
use std::time::Duration;

/// Per-step timing breakdown of the coordinator loop.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTiming {
    /// literal packing + host→device
    pub bind: Duration,
    /// artifact execution (fwd + bwd on the device)
    pub exec: Duration,
    /// host optimizer (SGD rows / Adam qparams)
    pub optim: Duration,
    /// cross-shard gradient exchange (data-parallel training only)
    pub exchange: Duration,
    /// importance refresh + Top-K reselection
    pub freeze: Duration,
}

impl StepTiming {
    pub fn total(&self) -> Duration {
        self.bind + self.exec + self.optim + self.exchange + self.freeze
    }
}

#[derive(Clone, Debug, Default)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub correct: i32,
    pub batch: usize,
    /// fraction of network weights receiving gradients this step
    /// ([`crate::freeze::Selection::active_fraction`]; 1.0 for dense)
    pub active_frac: f32,
    /// gradient-exchange payload actually shipped this step (bytes; 0 on
    /// the single-trainer path)
    pub bytes_exchanged: u64,
    /// freezable sites below the backward-truncation boundary this step
    /// ([`crate::freeze::Selection::lowest_active_layer`]) — dX
    /// propagation skipped for the layers owning them; 0 when the
    /// truncation is off or nothing is frozen from the bottom
    pub bwd_layers_skipped: usize,
    pub timing: StepTiming,
}

/// Accumulates step records; prints progress and dumps CSV.
#[derive(Default)]
pub struct MetricsLog {
    pub records: Vec<StepRecord>,
    pub label: String,
}

impl MetricsLog {
    pub fn new(label: &str) -> MetricsLog {
        MetricsLog { records: Vec::new(), label: label.to_string() }
    }

    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    pub fn losses(&self) -> Vec<f32> {
        self.records.iter().map(|r| r.loss).collect()
    }

    pub fn mean_loss_tail(&self, k: usize) -> f32 {
        let tail: Vec<f32> = self.records.iter().rev().take(k).map(|r| r.loss).collect();
        tail.iter().sum::<f32>() / tail.len().max(1) as f32
    }

    pub fn train_accuracy(&self) -> f32 {
        let c: i64 = self.records.iter().map(|r| r.correct as i64).sum();
        let n: usize = self.records.iter().map(|r| r.batch).sum();
        c as f32 / n.max(1) as f32
    }

    /// Sum of artifact execution time — the quantity Table 5 reports
    /// (the paper's "backward runtime ... over the total training steps").
    pub fn total_exec(&self) -> Duration {
        self.records.iter().map(|r| r.timing.exec).sum()
    }

    pub fn total_overhead(&self) -> Duration {
        self.records
            .iter()
            .map(|r| r.timing.bind + r.timing.optim + r.timing.exchange + r.timing.freeze)
            .sum()
    }

    /// Total gradient-exchange payload over the epoch (bytes).
    pub fn total_bytes_exchanged(&self) -> u64 {
        self.records.iter().map(|r| r.bytes_exchanged).sum()
    }

    /// Mean active-weight fraction over the epoch.
    pub fn mean_active_frac(&self) -> f32 {
        let s: f32 = self.records.iter().map(|r| r.active_frac).sum();
        s / self.records.len().max(1) as f32
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(
            f,
            "step,loss,correct,batch,active_frac,bytes_exchanged,bwd_layers_skipped,bind_us,\
             exec_us,optim_us,exchange_us,freeze_us"
        )?;
        for r in &self.records {
            writeln!(
                f,
                "{},{},{},{},{},{},{},{},{},{},{},{}",
                r.step,
                r.loss,
                r.correct,
                r.batch,
                r.active_frac,
                r.bytes_exchanged,
                r.bwd_layers_skipped,
                r.timing.bind.as_micros(),
                r.timing.exec.as_micros(),
                r.timing.optim.as_micros(),
                r.timing.exchange.as_micros(),
                r.timing.freeze.as_micros()
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, loss: f32) -> StepRecord {
        StepRecord {
            step,
            loss,
            correct: 4,
            batch: 8,
            active_frac: 0.25,
            bytes_exchanged: 64,
            bwd_layers_skipped: 1,
            timing: StepTiming {
                bind: Duration::from_micros(10),
                exec: Duration::from_micros(100),
                optim: Duration::from_micros(5),
                exchange: Duration::from_micros(2),
                freeze: Duration::from_micros(1),
            },
        }
    }

    #[test]
    fn aggregates() {
        let mut m = MetricsLog::new("t");
        m.push(rec(0, 2.0));
        m.push(rec(1, 1.0));
        assert_eq!(m.losses(), vec![2.0, 1.0]);
        assert_eq!(m.mean_loss_tail(1), 1.0);
        assert_eq!(m.train_accuracy(), 0.5);
        assert_eq!(m.total_exec(), Duration::from_micros(200));
        assert_eq!(m.total_overhead(), Duration::from_micros(36));
        assert_eq!(m.total_bytes_exchanged(), 128);
        assert!((m.mean_active_frac() - 0.25).abs() < 1e-6);
    }

    #[test]
    fn csv_written() {
        let mut m = MetricsLog::new("t");
        m.push(rec(0, 2.0));
        let dir = std::env::temp_dir().join("efqat_metrics_test");
        let p = dir.join("m.csv");
        m.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("step,loss"));
        assert!(s.contains("bwd_layers_skipped"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
