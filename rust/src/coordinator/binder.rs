//! Manifest-driven input binding: turn host stores + a batch + the
//! current freeze selection into the exact input vector an artifact
//! wants, as backend-agnostic [`Value`]s packed in manifest order.

use crate::backend::Value;
use crate::data::Batch;
use crate::error::{anyhow, bail, Result};
use crate::freeze::Selection;
use crate::model::{Dtype, Manifest, ParamStore, QParamStore, StateStore};
use crate::tensor::{ITensor, Tensor};

/// Everything an artifact input can refer to.
pub struct BindCtx<'a> {
    pub params: &'a ParamStore,
    pub qparams: Option<&'a QParamStore>,
    pub states: &'a StateStore,
    pub batch: &'a Batch,
    /// freeze selection (ratio/LWPN train artifacts only)
    pub selection: Option<&'a Selection>,
}

/// Pack host values in manifest input order.
///
/// Note: values are cloned into owned [`Value`]s — one copy per input
/// per step.  That keeps the backend seam lifetime-free; if profiling
/// ever shows the copies on a hot path, the seam-preserving fix is
/// `Value` holding `Rc<Tensor>` rather than borrowing here.
pub fn bind_inputs(man: &Manifest, ctx: &BindCtx) -> Result<Vec<Value>> {
    let site_pos = |of: &Option<String>| -> Result<usize> {
        let name = of.as_deref().ok_or_else(|| anyhow!("selector input without 'of'"))?;
        man.wsites
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("unknown wsite {name:?}"))
    };
    let mut out = Vec::with_capacity(man.inputs.len());
    for spec in &man.inputs {
        let val = match spec.role.as_str() {
            "param" => Value::F32(ctx.params.get(&spec.name)?.clone()),
            "qparam_sw" => {
                let q = ctx.qparams.ok_or_else(|| anyhow!("artifact wants qparams"))?;
                let of = spec.of.as_deref().unwrap_or("");
                let sw = q.sw.get(of).ok_or_else(|| anyhow!("missing sw for {of:?}"))?;
                Value::F32(sw.clone())
            }
            "qparam_sx" | "qparam_zx" => {
                let q = ctx.qparams.ok_or_else(|| anyhow!("artifact wants qparams"))?;
                let of = spec.of.as_deref().unwrap_or("");
                let act = q.act.get(of).ok_or_else(|| anyhow!("missing act qparams for {of:?}"))?;
                let v = if spec.role == "qparam_sx" { act.scale } else { act.zero_point };
                Value::F32(Tensor::scalar(v))
            }
            "state" => Value::F32(ctx.states.get(&spec.name)?.clone()),
            "data" => match spec.dtype {
                Dtype::F32 => Value::F32(
                    ctx.batch
                        .f32s
                        .get(&spec.name)
                        .ok_or_else(|| anyhow!("batch missing f32 {:?}", spec.name))?
                        .clone(),
                ),
                Dtype::I32 => Value::I32(
                    ctx.batch
                        .i32s
                        .get(&spec.name)
                        .ok_or_else(|| anyhow!("batch missing i32 {:?}", spec.name))?
                        .clone(),
                ),
            },
            "index" => {
                let sel = ctx.selection.ok_or_else(|| anyhow!("artifact wants a selection"))?;
                let si = site_pos(&spec.of)?;
                let ids = &sel.channels[si];
                if ids.len() != spec.shape[0] {
                    bail!(
                        "site {:?}: selection has {} channels, artifact slot is {}",
                        spec.of, ids.len(), spec.shape[0]
                    );
                }
                let data: Vec<i32> = ids.iter().map(|&c| c as i32).collect();
                Value::I32(ITensor { shape: spec.shape.clone(), data })
            }
            "flag" => {
                let sel = ctx.selection.ok_or_else(|| anyhow!("artifact wants a selection"))?;
                let si = site_pos(&spec.of)?;
                Value::I32(ITensor { shape: vec![1], data: vec![sel.flags[si] as i32] })
            }
            other => bail!("unknown input role {other:?} ({})", spec.name),
        };
        out.push(val);
    }
    Ok(out)
}
