//! Manifest-driven input binding: turn host stores + a batch + the
//! current freeze selection into the exact input vector an artifact
//! wants, as backend-agnostic [`Value`]s packed in manifest order.

use crate::backend::Value;
use crate::data::Batch;
use crate::error::{anyhow, bail, Result};
use crate::freeze::Selection;
use crate::model::{Dtype, Manifest, ParamStore, QParamStore, StateStore};
use crate::tensor::{ITensor, Tensor};

/// Everything an artifact input can refer to.
pub struct BindCtx<'a> {
    pub params: &'a ParamStore,
    pub qparams: Option<&'a QParamStore>,
    pub states: &'a StateStore,
    pub batch: &'a Batch,
    /// freeze selection (ratio/LWPN train artifacts only)
    pub selection: Option<&'a Selection>,
}

/// Pack host values in manifest input order.
///
/// Note: values are cloned into owned [`Value`]s — one allocation plus
/// one copy per input per step.  That keeps the backend seam
/// lifetime-free; hot loops should hold a [`Binder`] instead, which
/// pays the allocations once and then refreshes the same buffers in
/// place every step.
pub fn bind_inputs(man: &Manifest, ctx: &BindCtx) -> Result<Vec<Value>> {
    let site_pos = |of: &Option<String>| -> Result<usize> {
        let name = of.as_deref().ok_or_else(|| anyhow!("selector input without 'of'"))?;
        man.wsites
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("unknown wsite {name:?}"))
    };
    let mut out = Vec::with_capacity(man.inputs.len());
    for spec in &man.inputs {
        let val = match spec.role.as_str() {
            "param" => Value::F32(ctx.params.get(&spec.name)?.clone()),
            "qparam_sw" => {
                let q = ctx.qparams.ok_or_else(|| anyhow!("artifact wants qparams"))?;
                let of = spec.of.as_deref().unwrap_or("");
                let sw = q.sw.get(of).ok_or_else(|| anyhow!("missing sw for {of:?}"))?;
                Value::F32(sw.clone())
            }
            "qparam_sx" | "qparam_zx" => {
                let q = ctx.qparams.ok_or_else(|| anyhow!("artifact wants qparams"))?;
                let of = spec.of.as_deref().unwrap_or("");
                let act = q.act.get(of).ok_or_else(|| anyhow!("missing act qparams for {of:?}"))?;
                let v = if spec.role == "qparam_sx" { act.scale } else { act.zero_point };
                Value::F32(Tensor::scalar(v))
            }
            "state" => Value::F32(ctx.states.get(&spec.name)?.clone()),
            "data" => match spec.dtype {
                Dtype::F32 => Value::F32(
                    ctx.batch
                        .f32s
                        .get(&spec.name)
                        .ok_or_else(|| anyhow!("batch missing f32 {:?}", spec.name))?
                        .clone(),
                ),
                Dtype::I32 => Value::I32(
                    ctx.batch
                        .i32s
                        .get(&spec.name)
                        .ok_or_else(|| anyhow!("batch missing i32 {:?}", spec.name))?
                        .clone(),
                ),
            },
            "index" => {
                let sel = ctx.selection.ok_or_else(|| anyhow!("artifact wants a selection"))?;
                let si = site_pos(&spec.of)?;
                let ids = &sel.channels[si];
                if ids.len() != spec.shape[0] {
                    bail!(
                        "site {:?}: selection has {} channels, artifact slot is {}",
                        spec.of, ids.len(), spec.shape[0]
                    );
                }
                let data: Vec<i32> = ids.iter().map(|&c| c as i32).collect();
                Value::I32(ITensor { shape: spec.shape.clone(), data })
            }
            "flag" => {
                let sel = ctx.selection.ok_or_else(|| anyhow!("artifact wants a selection"))?;
                let si = site_pos(&spec.of)?;
                Value::I32(ITensor { shape: vec![1], data: vec![sel.flags[si] as i32] })
            }
            other => bail!("unknown input role {other:?} ({})", spec.name),
        };
        out.push(val);
    }
    Ok(out)
}

/// Persistent input binding for hot loops: the first [`Binder::bind`]
/// builds the owned input vector via [`bind_inputs`]; every later call
/// refreshes the same buffers in place (`copy_from_slice` — no heap
/// allocation), so a training epoch's bind phase stops generating
/// allocator traffic after the first step.  One binder serves one
/// manifest; shapes are fixed by the artifact ABI, so in-place refresh
/// is always size-exact (a drifting store is a descriptive error).
#[derive(Default)]
pub struct Binder {
    vals: Vec<Value>,
}

impl Binder {
    /// A binder with no bound inputs yet.
    pub fn new() -> Binder {
        Binder::default()
    }

    /// Bind (first call) or refresh (steady state) the input vector for
    /// `man` from `ctx`, returning it in manifest order.
    pub fn bind(&mut self, man: &Manifest, ctx: &BindCtx) -> Result<&[Value]> {
        if self.vals.is_empty() {
            self.vals = bind_inputs(man, ctx)?;
            return Ok(&self.vals);
        }
        if self.vals.len() != man.inputs.len() {
            bail!("binder: bound {} inputs, manifest wants {}", self.vals.len(), man.inputs.len());
        }
        let site_pos = |of: &Option<String>| -> Result<usize> {
            let name = of.as_deref().ok_or_else(|| anyhow!("selector input without 'of'"))?;
            man.wsites
                .iter()
                .position(|s| s.name == name)
                .ok_or_else(|| anyhow!("unknown wsite {name:?}"))
        };
        for (spec, slot) in man.inputs.iter().zip(self.vals.iter_mut()) {
            match spec.role.as_str() {
                "param" => refresh_f32(spec, slot, &ctx.params.get(&spec.name)?.data)?,
                "qparam_sw" => {
                    let q = ctx.qparams.ok_or_else(|| anyhow!("artifact wants qparams"))?;
                    let of = spec.of.as_deref().unwrap_or("");
                    let sw = q.sw.get(of).ok_or_else(|| anyhow!("missing sw for {of:?}"))?;
                    refresh_f32(spec, slot, &sw.data)?;
                }
                "qparam_sx" | "qparam_zx" => {
                    let q = ctx.qparams.ok_or_else(|| anyhow!("artifact wants qparams"))?;
                    let of = spec.of.as_deref().unwrap_or("");
                    let act =
                        q.act.get(of).ok_or_else(|| anyhow!("missing act qparams for {of:?}"))?;
                    let v = if spec.role == "qparam_sx" { act.scale } else { act.zero_point };
                    refresh_f32(spec, slot, &[v])?;
                }
                "state" => refresh_f32(spec, slot, &ctx.states.get(&spec.name)?.data)?,
                "data" => match spec.dtype {
                    Dtype::F32 => {
                        let t = ctx
                            .batch
                            .f32s
                            .get(&spec.name)
                            .ok_or_else(|| anyhow!("batch missing f32 {:?}", spec.name))?;
                        refresh_f32(spec, slot, &t.data)?;
                    }
                    Dtype::I32 => {
                        let t = ctx
                            .batch
                            .i32s
                            .get(&spec.name)
                            .ok_or_else(|| anyhow!("batch missing i32 {:?}", spec.name))?;
                        refresh_i32(spec, slot, &t.data)?;
                    }
                },
                "index" => {
                    let sel = ctx.selection.ok_or_else(|| anyhow!("artifact wants a selection"))?;
                    let ids = &sel.channels[site_pos(&spec.of)?];
                    if ids.len() != spec.shape[0] {
                        bail!(
                            "site {:?}: selection has {} channels, artifact slot is {}",
                            spec.of,
                            ids.len(),
                            spec.shape[0]
                        );
                    }
                    match slot {
                        Value::I32(t) => {
                            if t.data.len() != ids.len() {
                                bail!("binder: input {:?} changed size", spec.name);
                            }
                            for (dst, &c) in t.data.iter_mut().zip(ids) {
                                *dst = c as i32;
                            }
                        }
                        Value::F32(_) => bail!("binder: input {:?} changed dtype", spec.name),
                    }
                }
                "flag" => {
                    let sel = ctx.selection.ok_or_else(|| anyhow!("artifact wants a selection"))?;
                    let flag = sel.flags[site_pos(&spec.of)?] as i32;
                    match slot {
                        Value::I32(t) => t.data[0] = flag,
                        Value::F32(_) => bail!("binder: input {:?} changed dtype", spec.name),
                    }
                }
                other => bail!("unknown input role {other:?} ({})", spec.name),
            }
        }
        Ok(&self.vals)
    }
}

fn refresh_f32(spec: &crate::model::IoSpec, slot: &mut Value, src: &[f32]) -> Result<()> {
    match slot {
        Value::F32(t) => {
            if t.data.len() != src.len() {
                bail!("binder: input {:?} changed size", spec.name);
            }
            t.data.copy_from_slice(src);
            Ok(())
        }
        Value::I32(_) => bail!("binder: input {:?} changed dtype", spec.name),
    }
}

fn refresh_i32(spec: &crate::model::IoSpec, slot: &mut Value, src: &[i32]) -> Result<()> {
    match slot {
        Value::I32(t) => {
            if t.data.len() != src.len() {
                bail!("binder: input {:?} changed size", spec.name);
            }
            t.data.copy_from_slice(src);
            Ok(())
        }
        Value::F32(_) => bail!("binder: input {:?} changed dtype", spec.name),
    }
}
