//! Manifest-driven literal binding: turn host stores + a batch + the
//! current freeze selection into the exact input vector an artifact wants.

use anyhow::{anyhow, bail, Result};

use crate::freeze::Selection;
use crate::model::{Dtype, Manifest, ParamStore, QParamStore, StateStore};
use crate::runtime::{literal_f32, literal_i32};
use crate::data::Batch;
use crate::tensor::{ITensor, Tensor};

/// Everything an artifact input can refer to.
pub struct BindCtx<'a> {
    pub params: &'a ParamStore,
    pub qparams: Option<&'a QParamStore>,
    pub states: &'a StateStore,
    pub batch: &'a Batch,
    /// freeze selection (ratio/LWPN train artifacts only)
    pub selection: Option<&'a Selection>,
}

/// Pack literals in manifest input order.
pub fn bind_inputs(man: &Manifest, ctx: &BindCtx) -> Result<Vec<xla::Literal>> {
    let site_pos = |of: &Option<String>| -> Result<usize> {
        let name = of.as_deref().ok_or_else(|| anyhow!("selector input without 'of'"))?;
        man.wsites
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("unknown wsite {name:?}"))
    };
    let mut out = Vec::with_capacity(man.inputs.len());
    for spec in &man.inputs {
        let lit = match spec.role.as_str() {
            "param" => literal_f32(ctx.params.get(&spec.name)?)?,
            "qparam_sw" => {
                let q = ctx.qparams.ok_or_else(|| anyhow!("artifact wants qparams"))?;
                let of = spec.of.as_deref().unwrap_or("");
                let sw = q.sw.get(of).ok_or_else(|| anyhow!("missing sw for {of:?}"))?;
                literal_f32(sw)?
            }
            "qparam_sx" | "qparam_zx" => {
                let q = ctx.qparams.ok_or_else(|| anyhow!("artifact wants qparams"))?;
                let of = spec.of.as_deref().unwrap_or("");
                let act = q.act.get(of).ok_or_else(|| anyhow!("missing act qparams for {of:?}"))?;
                let v = if spec.role == "qparam_sx" { act.scale } else { act.zero_point };
                literal_f32(&Tensor::scalar(v))?
            }
            "state" => literal_f32(ctx.states.get(&spec.name)?)?,
            "data" => match spec.dtype {
                Dtype::F32 => literal_f32(
                    ctx.batch
                        .f32s
                        .get(&spec.name)
                        .ok_or_else(|| anyhow!("batch missing f32 {:?}", spec.name))?,
                )?,
                Dtype::I32 => literal_i32(
                    ctx.batch
                        .i32s
                        .get(&spec.name)
                        .ok_or_else(|| anyhow!("batch missing i32 {:?}", spec.name))?,
                )?,
            },
            "index" => {
                let sel = ctx.selection.ok_or_else(|| anyhow!("artifact wants a selection"))?;
                let si = site_pos(&spec.of)?;
                let ids = &sel.channels[si];
                if ids.len() != spec.shape[0] {
                    bail!(
                        "site {:?}: selection has {} channels, artifact slot is {}",
                        spec.of, ids.len(), spec.shape[0]
                    );
                }
                let data: Vec<i32> = ids.iter().map(|&c| c as i32).collect();
                literal_i32(&ITensor { shape: spec.shape.clone(), data })?
            }
            "flag" => {
                let sel = ctx.selection.ok_or_else(|| anyhow!("artifact wants a selection"))?;
                let si = site_pos(&spec.of)?;
                literal_i32(&ITensor { shape: vec![1], data: vec![sel.flags[si] as i32] })?
            }
            other => bail!("unknown input role {other:?} ({})", spec.name),
        };
        out.push(lit);
    }
    Ok(out)
}
