//! Channel/layer freezing — the heart of EfQAT (paper §3.2, Table 2).
//!
//! * importance metric: I_B = mean |w| per output channel (Eq. 6)
//! * three selection policies:
//!     CWPL  channel-wise per-layer   — top-⌈r·C_out⌉ channels in each layer
//!     CWPN  channel-wise per-network — channels ranked globally; each
//!           layer's static gradient slots are filled by global rank first,
//!           then local rank (AOT artifacts fix the per-layer slot count —
//!           see DESIGN.md §3 substitutions)
//!     LWPN  layer-wise per-network   — whole layers freeze; greedy by
//!           layer importance under the global weight budget r·|W|
//! * freezing frequency: importances of the *unfrozen* channels are
//!   recomputed every `f` training samples (paper §3.2 "Freezing
//!   Frequency"); frozen channels keep their stale importance and keep
//!   competing, exactly as in the paper.

use crate::tensor::{topk, Tensor};

/// The paper's three freezing policies (Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Channel-wise per-layer: top-k channels inside every layer.
    Cwpl,
    /// Channel-wise per-network: channels ranked globally.
    Cwpn,
    /// Layer-wise per-network: whole layers freeze under a weight budget.
    Lwpn,
}

impl Mode {
    /// Parse a CLI mode name (`cwpl` / `cwpn` / `lwpn`, case-insensitive);
    /// `qat` / `r0` are not modes — they run without a policy.
    pub fn parse(s: &str) -> Option<Mode> {
        match s.to_ascii_lowercase().as_str() {
            "cwpl" => Some(Mode::Cwpl),
            "cwpn" => Some(Mode::Cwpn),
            "lwpn" => Some(Mode::Lwpn),
            _ => None,
        }
    }
}

/// One freezable weight site (a conv's output channels / a linear's rows).
#[derive(Clone, Debug)]
pub struct Site {
    /// Parameter name of the site's weight tensor.
    pub name: String,
    /// Output-channel count (the leading weight dimension).
    pub c_out: usize,
    /// gradient slots in the ratio artifacts: k = max(1, ⌊r·C_out⌋)
    pub k: usize,
    /// total parameter count of the site (LWPN budgeting)
    pub size: usize,
}

/// Current selection: which channels (or layers) are unfrozen.
#[derive(Clone, Debug)]
pub struct Selection {
    /// per site: unfrozen channel ids, length = site.k (CWPL/CWPN)
    pub channels: Vec<Vec<usize>>,
    /// per site: unfrozen flag (LWPN)
    pub flags: Vec<bool>,
}

impl Selection {
    /// Active (gradient-receiving) output channels of one site: the
    /// channel list's length for the channel-wise policies, all of
    /// `c_out` or none for the flag-gated LWPN policy.
    pub fn active_count(&self, si: usize, site: &Site) -> usize {
        match self.channels.get(si) {
            Some(ch) if !ch.is_empty() => ch.len(),
            _ if self.flags.get(si).copied().unwrap_or(false) => site.c_out,
            _ => 0,
        }
    }

    /// Per-site active-channel counts, in site order — what the gradient
    /// exchange ships and what the train metrics log.
    pub fn active_counts(&self, sites: &[Site]) -> Vec<usize> {
        sites.iter().enumerate().map(|(si, s)| self.active_count(si, s)).collect()
    }

    /// The lowest site index holding any active (gradient-receiving)
    /// channel — the frozen-prefix backward-truncation boundary: the
    /// executor stops propagating dX below the layer owning this site
    /// ([`crate::graph`]), so the sites before it measure skipped
    /// backward compute.  `None` when every site is frozen (the
    /// executor then runs the full backward defensively).  Recomputed
    /// from the live selection, so each freeze refresh moves it.
    pub fn lowest_active_layer(&self, sites: &[Site]) -> Option<usize> {
        sites.iter().enumerate().find(|&(si, s)| self.active_count(si, s) > 0).map(|(si, _)| si)
    }

    /// Fraction of freezable-site weights currently receiving gradients
    /// (weighted by parameter count, so a wide unfrozen site counts for
    /// more than a narrow one).  This is the observable the exchange
    /// payload shrinks with: bytes-on-the-wire ∝ active_fraction.
    pub fn active_fraction(&self, sites: &[Site]) -> f32 {
        let total: usize = sites.iter().map(|s| s.size).sum();
        let active: usize = sites
            .iter()
            .enumerate()
            .map(|(si, s)| self.active_count(si, s) * s.size / s.c_out.max(1))
            .sum();
        active as f32 / total.max(1) as f32
    }
}

/// Stateful selection policy: tracks per-channel importances (Eq. 6) and
/// re-runs Top-K selection every `freq` training samples (paper §3.2).
pub struct FreezePolicy {
    /// Which of the paper's three policies drives selection.
    pub mode: Mode,
    /// Unfrozen fraction `r` (CWPL/CWPN: per-layer slots; LWPN: weight budget).
    pub ratio: f32,
    /// recompute importances every `freq` samples (paper's f)
    pub freq: usize,
    /// The freezable weight sites, in manifest order.
    pub sites: Vec<Site>,
    importance: Vec<Vec<f32>>,
    selection: Selection,
    samples_since_update: usize,
    /// number of importance refreshes performed (exposed for tests/metrics)
    pub updates: usize,
}

impl FreezePolicy {
    /// Build a policy, seed importances from the current weights (Eq. 6),
    /// and run the initial selection.
    pub fn new(mode: Mode, ratio: f32, freq: usize, sites: Vec<Site>, weights: &[&Tensor]) -> Self {
        assert_eq!(sites.len(), weights.len());
        let importance: Vec<Vec<f32>> = weights.iter().map(|w| w.row_abs_mean()).collect();
        let mut p = FreezePolicy {
            mode,
            ratio,
            freq,
            sites,
            importance,
            selection: Selection { channels: Vec::new(), flags: Vec::new() },
            samples_since_update: 0,
            updates: 0,
        };
        p.reselect();
        p
    }

    /// The current selection (bound to the artifact each step).
    pub fn selection(&self) -> &Selection {
        &self.selection
    }

    /// Current per-channel importances of one site (Eq. 6; frozen
    /// channels keep their stale value, as in the paper).
    pub fn importance(&self, site: usize) -> &[f32] {
        &self.importance[site]
    }

    /// Whether observing `n` more samples would trigger a refresh —
    /// hot loops use this to skip gathering weight references (and the
    /// allocation that entails) on the steps between refreshes.
    pub fn will_refresh(&self, n: usize) -> bool {
        self.samples_since_update + n >= self.freq.max(1)
    }

    /// Advance the sample counter; when `f` samples have passed, refresh the
    /// importance of the currently-unfrozen channels and reselect.
    /// Returns true if a refresh happened.
    pub fn observe_samples(&mut self, n: usize, weights: &[&Tensor]) -> bool {
        self.samples_since_update += n;
        if self.samples_since_update < self.freq.max(1) {
            return false;
        }
        self.samples_since_update = 0;
        self.refresh(weights);
        true
    }

    /// Paper §3.2: iterate over the *unfrozen* channels only, update their
    /// importance, then re-run selection.
    pub fn refresh(&mut self, weights: &[&Tensor]) {
        match self.mode {
            Mode::Lwpn => {
                for (si, unfrozen) in self.selection.flags.clone().iter().enumerate() {
                    if *unfrozen {
                        self.importance[si] = weights[si].row_abs_mean();
                    }
                }
            }
            _ => {
                for (si, chans) in self.selection.channels.clone().iter().enumerate() {
                    let rs = weights[si].row_size() as f32;
                    for &c in chans {
                        let imp = weights[si].row(c).iter().map(|x| x.abs()).sum::<f32>() / rs;
                        self.importance[si][c] = imp;
                    }
                }
            }
        }
        self.reselect();
        self.updates += 1;
    }

    fn reselect(&mut self) {
        self.selection = match self.mode {
            Mode::Cwpl => self.select_cwpl(),
            Mode::Cwpn => self.select_cwpn(),
            Mode::Lwpn => self.select_lwpn(),
        };
    }

    fn select_cwpl(&self) -> Selection {
        let channels = self
            .sites
            .iter()
            .zip(&self.importance)
            .map(|(site, imp)| topk(imp, site.k))
            .collect();
        Selection { channels, flags: vec![true; self.sites.len()] }
    }

    /// Global ranking, filled into each site's static slot budget; leftover
    /// slots of under-subscribed sites are topped up by local rank.
    fn select_cwpn(&self) -> Selection {
        let mut ranked: Vec<(usize, usize, f32)> = Vec::new(); // (site, ch, imp)
        for (si, imp) in self.importance.iter().enumerate() {
            for (ci, &v) in imp.iter().enumerate() {
                ranked.push((si, ci, v));
            }
        }
        ranked.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap_or(std::cmp::Ordering::Equal));
        let mut channels: Vec<Vec<usize>> = vec![Vec::new(); self.sites.len()];
        for (si, ci, _) in ranked {
            if channels[si].len() < self.sites[si].k {
                channels[si].push(ci);
            }
        }
        Selection { channels, flags: vec![true; self.sites.len()] }
    }

    /// Greedy layer selection by mean layer importance, under the global
    /// parameter budget r·Σ|site|; always unfreezes at least one layer for
    /// r > 0.
    fn select_lwpn(&self) -> Selection {
        let total: usize = self.sites.iter().map(|s| s.size).sum();
        let budget = (self.ratio as f64 * total as f64) as usize;
        let mut order: Vec<usize> = (0..self.sites.len()).collect();
        let layer_imp: Vec<f32> = self
            .importance
            .iter()
            .map(|imp| imp.iter().sum::<f32>() / imp.len().max(1) as f32)
            .collect();
        order.sort_by(|&a, &b| {
            layer_imp[b].partial_cmp(&layer_imp[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut flags = vec![false; self.sites.len()];
        let mut used = 0usize;
        for si in order {
            if self.ratio <= 0.0 {
                break;
            }
            if used == 0 || used + self.sites[si].size <= budget {
                flags[si] = true;
                used += self.sites[si].size;
            }
        }
        Selection { channels: vec![Vec::new(); self.sites.len()], flags }
    }

    /// Fraction of network weights currently receiving gradients
    /// (delegates to [`Selection::active_fraction`] over this policy's
    /// sites).
    pub fn unfrozen_fraction(&self) -> f32 {
        self.selection.active_fraction(&self.sites)
    }
}

/// Static slot count per site (must mirror python/compile/step.py::site_k).
pub fn site_k(c_out: usize, ratio: f32) -> usize {
    if ratio >= 1.0 {
        c_out
    } else {
        ((ratio * c_out as f32) as usize).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing::forall;

    fn mk_weights(rng: &mut Pcg64, dims: &[(usize, usize)]) -> Vec<Tensor> {
        dims.iter()
            .map(|&(r, c)| Tensor::new(vec![r, c], rng.normal_vec(r * c, 1.0)).unwrap())
            .collect()
    }

    fn mk_sites(dims: &[(usize, usize)], ratio: f32) -> Vec<Site> {
        dims.iter()
            .enumerate()
            .map(|(i, &(r, c))| Site {
                name: format!("w{i}"),
                c_out: r,
                k: site_k(r, ratio),
                size: r * c,
            })
            .collect()
    }

    #[test]
    fn site_k_matches_python_rule() {
        assert_eq!(site_k(16, 0.05), 1); // max(1, floor(0.8))
        assert_eq!(site_k(64, 0.25), 16);
        assert_eq!(site_k(64, 1.0), 64);
        assert_eq!(site_k(10, 0.999), 9);
    }

    #[test]
    fn cwpl_selects_top_channels_per_layer() {
        let w = Tensor::new(vec![4, 2], vec![0.1, 0.1, 9., 9., 0.2, 0.2, 5., 5.]).unwrap();
        let sites = mk_sites(&[(4, 2)], 0.5);
        let p = FreezePolicy::new(Mode::Cwpl, 0.5, 100, sites, &[&w]);
        assert_eq!(p.selection().channels[0], vec![1, 3]);
    }

    #[test]
    fn cwpn_global_rank_ties_break_by_site_then_channel_order() {
        // all importances identical → the stable global sort must keep
        // (site, channel) push order, so each site's slots fill with its
        // lowest channel ids — deterministic across runs and platforms
        let w0 = Tensor::new(vec![4, 2], vec![1.0; 8]).unwrap();
        let w1 = Tensor::new(vec![6, 2], vec![1.0; 12]).unwrap();
        let sites = mk_sites(&[(4, 2), (6, 2)], 0.5);
        let p = FreezePolicy::new(Mode::Cwpn, 0.5, 100, sites, &[&w0, &w1]);
        assert_eq!(p.selection().channels[0], vec![0, 1]);
        assert_eq!(p.selection().channels[1], vec![0, 1, 2]);
    }

    #[test]
    fn lwpn_budget_boundaries_r0_and_r1() {
        let w0 = Tensor::new(vec![2, 4], vec![5.0; 8]).unwrap();
        let w1 = Tensor::new(vec![2, 4], vec![0.1; 8]).unwrap();
        // r = 0: nothing unfreezes (the greedy "always one layer"
        // guarantee only applies for r > 0)
        let sites = mk_sites(&[(2, 4), (2, 4)], 0.0);
        let p = FreezePolicy::new(Mode::Lwpn, 0.0, 100, sites, &[&w0, &w1]);
        assert_eq!(p.selection().flags, vec![false, false]);
        assert!((p.unfrozen_fraction() - 0.0).abs() < 1e-7);
        // r = 1: the whole network fits the budget
        let sites = mk_sites(&[(2, 4), (2, 4)], 1.0);
        let p = FreezePolicy::new(Mode::Lwpn, 1.0, 100, sites, &[&w0, &w1]);
        assert_eq!(p.selection().flags, vec![true, true]);
        assert!((p.unfrozen_fraction() - 1.0).abs() < 1e-7);
    }

    #[test]
    fn stale_importance_competes_across_refresh_boundary() {
        // paper §3.2: a frozen channel keeps its stale importance and
        // keeps competing.  Channel 2 freezes at step 0 with importance
        // 3; after the unfrozen channels decay below 3 over TWO refresh
        // boundaries, its stale value must win a slot back — and the
        // refreshed (lower) importances of the previously-unfrozen
        // channels must persist.
        let mut w = Tensor::new(vec![4, 1], vec![10.0, 5.0, 3.0, 0.1]).unwrap();
        let sites = mk_sites(&[(4, 1)], 0.5);
        let mut p = FreezePolicy::new(Mode::Cwpl, 0.5, 1, sites, &[&w]);
        assert_eq!(p.selection().channels[0], vec![0, 1]);
        // first refresh: unfrozen 0/1 decay but stay above the stale 3
        w.data[0] = 9.0;
        w.data[1] = 4.0;
        p.refresh(&[&w]);
        assert_eq!(p.selection().channels[0], vec![0, 1]);
        // second refresh: channel 1 decays below the frozen channel 2's
        // stale importance → 2 re-enters on its stale value
        w.data[1] = 2.0;
        p.refresh(&[&w]);
        assert_eq!(p.selection().channels[0], vec![0, 2]);
        assert_eq!(p.importance(0)[1], 2.0, "refreshed importance must persist");
        assert_eq!(p.importance(0)[2], 3.0, "frozen channel keeps its stale importance");
        assert_eq!(p.updates, 2);
    }

    #[test]
    fn cwpn_prefers_globally_important_channels() {
        // site 0 channels dwarf site 1's, so site 0's slots fill from the
        // global top while site 1 still gets its guaranteed k slots
        let w0 = Tensor::new(vec![2, 2], vec![10., 10., 8., 8.]).unwrap();
        let w1 = Tensor::new(vec![4, 2], vec![1., 1., 3., 3., 2., 2., 0.5, 0.5]).unwrap();
        let sites = mk_sites(&[(2, 2), (4, 2)], 0.5);
        let p = FreezePolicy::new(Mode::Cwpn, 0.5, 100, sites, &[&w0, &w1]);
        assert_eq!(p.selection().channels[0], vec![0]);
        assert_eq!(p.selection().channels[1], vec![1, 2]);
    }

    #[test]
    fn lwpn_respects_budget_and_importance() {
        let w0 = Tensor::new(vec![2, 4], vec![5.0; 8]).unwrap(); // important, 8 params
        let w1 = Tensor::new(vec![2, 4], vec![0.1; 8]).unwrap();
        let sites = mk_sites(&[(2, 4), (2, 4)], 0.5);
        let p = FreezePolicy::new(Mode::Lwpn, 0.5, 100, sites, &[&w0, &w1]);
        assert_eq!(p.selection().flags, vec![true, false]);
    }

    #[test]
    fn lwpn_always_unfreezes_one_layer() {
        let w0 = Tensor::new(vec![2, 4], vec![5.0; 8]).unwrap();
        let w1 = Tensor::new(vec![2, 4], vec![0.1; 8]).unwrap();
        let sites = mk_sites(&[(2, 4), (2, 4)], 0.05);
        let p = FreezePolicy::new(Mode::Lwpn, 0.05, 100, sites, &[&w0, &w1]);
        assert_eq!(p.selection().flags.iter().filter(|&&f| f).count(), 1);
    }

    #[test]
    fn freezing_frequency_counts_samples() {
        let mut rng = Pcg64::new(0);
        let ws = mk_weights(&mut rng, &[(8, 4)]);
        let refs: Vec<&Tensor> = ws.iter().collect();
        let mut p = FreezePolicy::new(Mode::Cwpl, 0.5, 100, mk_sites(&[(8, 4)], 0.5), &refs);
        assert!(!p.observe_samples(64, &refs));
        assert!(p.observe_samples(64, &refs)); // 128 >= 100 -> refresh
        assert_eq!(p.updates, 1);
        assert!(!p.observe_samples(32, &refs)); // counter reset
    }

    #[test]
    fn refresh_tracks_weight_changes_of_unfrozen_channels() {
        let mut w = Tensor::new(vec![4, 2], vec![4., 4., 3., 3., 2., 2., 1., 1.]).unwrap();
        let sites = mk_sites(&[(4, 2)], 0.5);
        let mut p = FreezePolicy::new(Mode::Cwpl, 0.5, 1, sites, &[&w]);
        assert_eq!(p.selection().channels[0], vec![0, 1]);
        // unfrozen channel 1 decays below frozen channel 2's stale value
        w.row_mut(1).copy_from_slice(&[0.1, 0.1]);
        p.refresh(&[&w]);
        assert_eq!(p.selection().channels[0], vec![0, 2]);
    }

    #[test]
    fn active_counts_and_fraction_cover_both_selection_shapes() {
        let sites = mk_sites(&[(4, 2), (8, 2)], 0.5);
        // channel-wise: counts are the per-site list lengths
        let sel = Selection { channels: vec![vec![1, 3], vec![0, 2, 4, 6]], flags: vec![true; 2] };
        assert_eq!(sel.active_counts(&sites), vec![2, 4]);
        assert!((sel.active_fraction(&sites) - 0.5).abs() < 1e-7);
        // flag-gated (LWPN): counts are all-of-c_out or zero
        let sel = Selection { channels: vec![Vec::new(), Vec::new()], flags: vec![true, false] };
        assert_eq!(sel.active_counts(&sites), vec![4, 0]);
        // site 0 holds 8 of the 24 weights
        assert!((sel.active_fraction(&sites) - 8.0 / 24.0).abs() < 1e-7);
    }

    #[test]
    fn lowest_active_layer_is_none_when_everything_is_frozen() {
        let sites = mk_sites(&[(4, 2), (8, 2)], 0.5);
        let sel = Selection { channels: vec![Vec::new(), Vec::new()], flags: vec![false, false] };
        assert_eq!(sel.lowest_active_layer(&sites), None);
    }

    #[test]
    fn lowest_active_layer_is_zero_when_everything_is_active() {
        let sites = mk_sites(&[(4, 2), (8, 2)], 0.5);
        // channel-wise shape (CWPL/CWPN)
        let sel = Selection { channels: vec![vec![1], vec![0, 2]], flags: vec![true, true] };
        assert_eq!(sel.lowest_active_layer(&sites), Some(0));
        // flag-gated shape (LWPN)
        let sel = Selection { channels: vec![Vec::new(), Vec::new()], flags: vec![true, true] };
        assert_eq!(sel.lowest_active_layer(&sites), Some(0));
    }

    #[test]
    fn lowest_active_layer_moves_with_the_freeze_refresh() {
        // LWPN over two equal-size sites at r=0.5: only the more
        // important one unfreezes.  Site 0 wins at first; after its
        // weights decay below site 1's, a refresh must move the
        // truncation boundary from layer 0 to layer 1.
        let mut w0 = Tensor::new(vec![2, 4], vec![5.0; 8]).unwrap();
        let w1 = Tensor::new(vec![2, 4], vec![1.0; 8]).unwrap();
        let sites = mk_sites(&[(2, 4), (2, 4)], 0.5);
        let mut p = FreezePolicy::new(Mode::Lwpn, 0.5, 1, sites, &[&w0, &w1]);
        assert_eq!(p.selection().lowest_active_layer(&p.sites), Some(0));
        for v in w0.data.iter_mut() {
            *v = 0.1;
        }
        p.refresh(&[&w0, &w1]);
        assert_eq!(p.selection().flags, vec![false, true]);
        assert_eq!(p.selection().lowest_active_layer(&p.sites), Some(1));
    }

    #[test]
    fn prop_policy_fraction_equals_selection_fraction() {
        forall(100, |r| {
            let n_sites = 1 + r.below(4);
            let dims: Vec<(usize, usize)> =
                (0..n_sites).map(|_| (1 + r.below(16), 1 + r.below(8))).collect();
            let mut rng = r.split(9);
            let ws = mk_weights(&mut rng, &dims);
            let refs: Vec<&Tensor> = ws.iter().collect();
            let ratio = r.uniform_in(0.01, 0.99);
            for mode in [Mode::Cwpl, Mode::Cwpn, Mode::Lwpn] {
                let p = FreezePolicy::new(mode, ratio, 100, mk_sites(&dims, ratio), &refs);
                let f = p.unfrozen_fraction();
                assert_eq!(f, p.selection().active_fraction(&p.sites));
                assert!((0.0..=1.0).contains(&f), "{mode:?}: fraction {f} out of range");
            }
        });
    }

    #[test]
    fn prop_selection_invariants() {
        forall(200, |r| {
            let n_sites = 1 + r.below(4);
            let dims: Vec<(usize, usize)> =
                (0..n_sites).map(|_| (1 + r.below(32), 1 + r.below(8))).collect();
            let mut rng = r.split(1);
            let ws = mk_weights(&mut rng, &dims);
            let refs: Vec<&Tensor> = ws.iter().collect();
            let ratio = r.uniform_in(0.01, 0.99);
            for mode in [Mode::Cwpl, Mode::Cwpn, Mode::Lwpn] {
                let p = FreezePolicy::new(mode, ratio, 100, mk_sites(&dims, ratio), &refs);
                let sel = p.selection();
                match mode {
                    Mode::Lwpn => {
                        assert!(sel.flags.iter().any(|&f| f));
                        let total: usize = dims.iter().map(|(a, b)| a * b).sum();
                        let used: usize = dims
                            .iter()
                            .zip(&sel.flags)
                            .filter(|(_, &f)| f)
                            .map(|((a, b), _)| a * b)
                            .sum();
                        // greedy guarantees: either within budget, or a
                        // single (guaranteed) layer that alone exceeds it
                        let largest = dims.iter().map(|(a, b)| a * b).max().unwrap();
                        let budget = (ratio as f64 * total as f64) as usize;
                        assert!(
                            used <= budget.max(largest),
                            "budget exceeded: {used} of {total} at r={ratio}"
                        );
                    }
                    _ => {
                        for (si, ch) in sel.channels.iter().enumerate() {
                            // exactly k slots, all distinct, all in range
                            assert_eq!(ch.len(), site_k(dims[si].0, ratio));
                            let mut s = ch.clone();
                            s.sort();
                            s.dedup();
                            assert_eq!(s.len(), ch.len(), "duplicate channels");
                            assert!(ch.iter().all(|&c| c < dims[si].0));
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn prop_cwpl_selects_max_importance_channels() {
        forall(100, |r| {
            let rows = 2 + r.below(20);
            let mut rng = r.split(2);
            let w = Tensor::new(vec![rows, 3], rng.normal_vec(rows * 3, 1.0)).unwrap();
            let ratio = r.uniform_in(0.05, 0.95);
            let sites = mk_sites(&[(rows, 3)], ratio);
            let p = FreezePolicy::new(Mode::Cwpl, ratio, 100, sites, &[&w]);
            let imp = w.row_abs_mean();
            let sel = &p.selection().channels[0];
            let worst_sel = sel.iter().map(|&c| imp[c]).fold(f32::INFINITY, f32::min);
            for (c, &v) in imp.iter().enumerate() {
                if !sel.contains(&c) {
                    assert!(v <= worst_sel + 1e-6);
                }
            }
        });
    }
}
