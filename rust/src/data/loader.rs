//! Shuffled mini-batch loader over any synthetic dataset.
//!
//! Batches carry named tensors matching the artifact manifest's `data`
//! inputs (`x`, `y`, `y_start`, `y_end`), plus the *true* example count so
//! evaluation can wrap-pad the final partial batch (artifacts have a
//! static batch dimension) without biasing metrics.

use std::collections::BTreeMap;

use crate::rng::Pcg64;
use crate::tensor::{ITensor, Tensor};

use super::corpus::Corpus;
use super::images::ImageDataset;
use super::squad::SquadDataset;

#[derive(Clone)]
pub enum Source {
    Images(ImageDataset),
    Squad(SquadDataset),
    Lm { corpus: Corpus, seq_len: usize },
}

impl Source {
    pub fn len(&self) -> usize {
        match self {
            Source::Images(d) => d.n,
            Source::Squad(d) => d.n,
            Source::Lm { corpus, seq_len } => corpus.max_offset(*seq_len) / *seq_len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One packed mini-batch.  `count` ≤ batch_size is the number of real
/// (non-padding) examples.
#[derive(Clone, Debug)]
pub struct Batch {
    pub f32s: BTreeMap<String, Tensor>,
    pub i32s: BTreeMap<String, ITensor>,
    pub count: usize,
}

pub struct Loader {
    pub source: Source,
    pub batch_size: usize,
    indices: Vec<usize>,
    pos: usize,
    rng: Pcg64,
    shuffle: bool,
    drop_last: bool,
}

impl Loader {
    pub fn new(
        source: Source,
        batch_size: usize,
        seed: u64,
        shuffle: bool,
        drop_last: bool,
    ) -> Loader {
        let mut l = Loader {
            indices: (0..source.len()).collect(),
            source,
            batch_size,
            pos: 0,
            rng: Pcg64::new(seed ^ 0x10ade8),
            shuffle,
            drop_last,
        };
        l.reset();
        l
    }

    /// Start a new epoch (reshuffles if enabled).
    pub fn reset(&mut self) {
        self.pos = 0;
        if self.shuffle {
            self.rng.shuffle(&mut self.indices);
        }
    }

    pub fn n_batches(&self) -> usize {
        if self.drop_last {
            self.indices.len() / self.batch_size
        } else {
            self.indices.len().div_ceil(self.batch_size)
        }
    }

    pub fn next_batch(&mut self) -> Option<Batch> {
        let remaining = self.indices.len().saturating_sub(self.pos);
        if remaining == 0 || (self.drop_last && remaining < self.batch_size) {
            return None;
        }
        let count = remaining.min(self.batch_size);
        // wrap-pad the final partial batch
        let ids: Vec<usize> = (0..self.batch_size)
            .map(|i| self.indices[(self.pos + i) % self.indices.len().max(1)])
            .collect();
        self.pos += count;
        Some(self.pack(&ids, count))
    }

    fn pack(&self, ids: &[usize], count: usize) -> Batch {
        let b = self.batch_size;
        let mut f32s = BTreeMap::new();
        let mut i32s = BTreeMap::new();
        match &self.source {
            Source::Images(d) => {
                let s = d.sample_size();
                let mut x = Vec::with_capacity(b * s);
                let mut y = Vec::with_capacity(b);
                for &i in ids {
                    x.extend_from_slice(d.image(i));
                    y.push(d.labels[i]);
                }
                f32s.insert(
                    "x".to_string(),
                    Tensor { shape: vec![b, d.channels, d.hw, d.hw], data: x },
                );
                i32s.insert("y".to_string(), ITensor { shape: vec![b], data: y });
            }
            Source::Squad(d) => {
                let mut x = Vec::with_capacity(b * d.seq_len);
                let (mut ys, mut ye) = (Vec::with_capacity(b), Vec::with_capacity(b));
                for &i in ids {
                    x.extend_from_slice(d.seq(i));
                    ys.push(d.starts[i]);
                    ye.push(d.ends[i]);
                }
                i32s.insert("x".to_string(), ITensor { shape: vec![b, d.seq_len], data: x });
                i32s.insert("y_start".to_string(), ITensor { shape: vec![b], data: ys });
                i32s.insert("y_end".to_string(), ITensor { shape: vec![b], data: ye });
            }
            Source::Lm { corpus, seq_len } => {
                let t = *seq_len;
                let mut x = Vec::with_capacity(b * t);
                let mut y = Vec::with_capacity(b * t);
                for &i in ids {
                    let (xs, ys) = corpus.window(i * t, t);
                    x.extend_from_slice(xs);
                    y.extend_from_slice(ys);
                }
                i32s.insert("x".to_string(), ITensor { shape: vec![b, t], data: x });
                i32s.insert("y".to_string(), ITensor { shape: vec![b, t], data: y });
            }
        }
        Batch { f32s, i32s, count }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{corpus, images, squad};

    #[test]
    fn epoch_covers_every_sample_once() {
        let ds = images::generate(50, 10, 4, 0.1, 1);
        let mut l = Loader::new(Source::Images(ds), 8, 0, true, true);
        let mut seen = 0;
        while let Some(b) = l.next_batch() {
            assert_eq!(b.count, 8);
            seen += b.count;
        }
        assert_eq!(seen, 48); // drop_last
        assert_eq!(l.n_batches(), 6);
    }

    #[test]
    fn eval_pads_final_batch() {
        let ds = images::generate(10, 10, 4, 0.1, 1);
        let mut l = Loader::new(Source::Images(ds), 8, 0, false, false);
        let b1 = l.next_batch().unwrap();
        assert_eq!(b1.count, 8);
        let b2 = l.next_batch().unwrap();
        assert_eq!(b2.count, 2);
        assert_eq!(b2.f32s["x"].shape, vec![8, 3, 4, 4]); // padded to full shape
        assert!(l.next_batch().is_none());
    }

    #[test]
    fn squad_batch_shapes() {
        let ds = squad::generate(20, 32, 256, 2);
        let mut l = Loader::new(Source::Squad(ds), 4, 0, true, true);
        let b = l.next_batch().unwrap();
        assert_eq!(b.i32s["x"].shape, vec![4, 32]);
        assert_eq!(b.i32s["y_start"].shape, vec![4]);
        assert_eq!(b.i32s["y_end"].shape, vec![4]);
    }

    #[test]
    fn lm_windows_are_shifted_targets() {
        let c = corpus::generate(10_000, 64, 3);
        let mut l = Loader::new(Source::Lm { corpus: c, seq_len: 16 }, 2, 0, false, true);
        let b = l.next_batch().unwrap();
        let x = &b.i32s["x"].data;
        let y = &b.i32s["y"].data;
        assert_eq!(&x[1..16], &y[..15]);
    }

    #[test]
    fn shuffle_changes_order_but_not_multiset() {
        let ds = images::generate(30, 10, 4, 0.1, 7);
        let labels = ds.labels.clone();
        let mut l = Loader::new(Source::Images(ds), 30, 11, true, true);
        let b = l.next_batch().unwrap();
        let mut got = b.i32s["y"].data.clone();
        assert_ne!(got, labels, "shuffle did nothing");
        got.sort();
        let mut want = labels;
        want.sort();
        assert_eq!(got, want);
    }
}
