//! Class-conditioned synthetic image dataset (CIFAR / ImageNet stand-in).
//!
//! Each class owns a deterministic "prototype" built from a few random 2-D
//! sinusoidal gratings plus a colored blob; a sample is its class
//! prototype under a random translation, per-sample gain, and additive
//! Gaussian noise.  This keeps the Bayes error low but non-zero, so the
//! FP → PTQ → EfQAT → QAT accuracy ordering of the paper is measurable,
//! while exercising exactly the conv/BN/pooling code paths of CIFAR-10.

use crate::rng::Pcg64;

#[derive(Clone)]
pub struct ImageDataset {
    pub n: usize,
    pub channels: usize,
    pub hw: usize,
    pub classes: usize,
    /// flattened [n, channels, hw, hw]
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
}

struct ClassProto {
    freq: [(f32, f32, f32); 3], // (fx, fy, phase) per channel
    blob: (f32, f32, f32),      // (cx, cy, radius)
    color: [f32; 3],
}

fn protos(classes: usize, hw: usize, seed: u64) -> Vec<ClassProto> {
    let mut rng = Pcg64::new(seed ^ 0xC1A55);
    (0..classes)
        .map(|_| ClassProto {
            freq: [
                (rng.uniform_in(0.5, 3.0), rng.uniform_in(0.5, 3.0), rng.uniform_in(0.0, 6.28)),
                (rng.uniform_in(0.5, 3.0), rng.uniform_in(0.5, 3.0), rng.uniform_in(0.0, 6.28)),
                (rng.uniform_in(0.5, 3.0), rng.uniform_in(0.5, 3.0), rng.uniform_in(0.0, 6.28)),
            ],
            blob: (
                rng.uniform_in(0.2, 0.8) * hw as f32,
                rng.uniform_in(0.2, 0.8) * hw as f32,
                rng.uniform_in(0.15, 0.3) * hw as f32,
            ),
            color: [
                rng.uniform_in(-1.0, 1.0),
                rng.uniform_in(-1.0, 1.0),
                rng.uniform_in(-1.0, 1.0),
            ],
        })
        .collect()
}

/// Generate `n` samples over `classes` classes at `hw`×`hw`, 3 channels.
/// `noise` ≈ 2.0 gives ~70-80% ceilings for ResNet-20-class models.
///
/// `seed` fixes the class *prototypes* (the task definition) and
/// `sample_seed` the per-sample randomness — train/test splits share the
/// task seed and differ only in the sample seed.
pub fn generate_split(
    n: usize,
    classes: usize,
    hw: usize,
    noise: f32,
    seed: u64,
    sample_seed: u64,
) -> ImageDataset {
    let channels = 3usize;
    let protos = protos(classes, hw, seed);
    let mut rng = Pcg64::new(sample_seed);
    let mut images = vec![0f32; n * channels * hw * hw];
    let mut labels = vec![0i32; n];
    let tau = std::f32::consts::TAU;
    for i in 0..n {
        let cls = i % classes; // balanced
        labels[i] = cls as i32;
        let p = &protos[cls];
        let dx = rng.uniform_in(-3.0, 3.0);
        let dy = rng.uniform_in(-3.0, 3.0);
        let gain = rng.uniform_in(0.7, 1.3);
        let base = i * channels * hw * hw;
        for c in 0..channels {
            let (fx, fy, ph) = p.freq[c];
            for y in 0..hw {
                for x in 0..hw {
                    let xf = (x as f32 + dx) / hw as f32;
                    let yf = (y as f32 + dy) / hw as f32;
                    let grating = (tau * (fx * xf + fy * yf) + ph).sin();
                    let bx = x as f32 + dx - p.blob.0;
                    let by = y as f32 + dy - p.blob.1;
                    let gauss = (-(bx * bx + by * by) / (2.0 * p.blob.2 * p.blob.2)).exp();
                    let blob = p.color[c] * gauss;
                    let v = gain * (0.6 * grating + blob) + noise * rng.normal();
                    images[base + c * hw * hw + y * hw + x] = v;
                }
            }
        }
    }
    ImageDataset { n, channels, hw, classes, images, labels }
}

/// Same task + sample seed (tests / prototype extraction).
pub fn generate(n: usize, classes: usize, hw: usize, noise: f32, seed: u64) -> ImageDataset {
    generate_split(n, classes, hw, noise, seed, seed)
}

impl ImageDataset {
    pub fn sample_size(&self) -> usize {
        self.channels * self.hw * self.hw
    }

    pub fn image(&self, i: usize) -> &[f32] {
        let s = self.sample_size();
        &self.images[i * s..(i + 1) * s]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_balanced() {
        let a = generate(40, 10, 8, 0.5, 1);
        let b = generate(40, 10, 8, 0.5, 1);
        assert_eq!(a.images, b.images);
        for c in 0..10 {
            assert_eq!(a.labels.iter().filter(|&&l| l == c).count(), 4);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate(10, 10, 8, 0.5, 1);
        let b = generate(10, 10, 8, 0.5, 2);
        assert_ne!(a.images, b.images);
    }

    #[test]
    fn classes_are_separable_by_prototype_correlation() {
        // nearest-prototype classification on noiseless prototypes should
        // beat chance by a wide margin -> the task is learnable
        let ds = generate(200, 10, 16, 0.4, 3);
        let clean = generate(10, 10, 16, 0.0, 3); // one clean sample per class
        let mut correct = 0;
        for i in 0..ds.n {
            let img = ds.image(i);
            let mut best = (f32::NEG_INFINITY, 0usize);
            for c in 0..10 {
                let proto = clean.image(c);
                debug_assert_eq!(clean.labels[c] as usize, c);
                let dot: f32 = img.iter().zip(proto).map(|(a, b)| a * b).sum();
                if dot > best.0 {
                    best = (dot, c);
                }
            }
            if best.1 == ds.labels[i] as usize {
                correct += 1;
            }
        }
        // 5x chance — CNNs do much better
        assert!(correct > 100, "nearest-proto acc too low: {correct}/200");
    }

    #[test]
    fn values_bounded() {
        let ds = generate(50, 10, 8, 0.5, 4);
        assert!(ds.images.iter().all(|x| x.abs() < 12.0));
    }
}
