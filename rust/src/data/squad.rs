//! Span-extraction QA dataset (SQuAD v1.1 stand-in, DESIGN.md §3).
//!
//! Layout of each sequence (length T, vocab V):
//!   pos 0:  CLS
//!   pos 1:  the *query* token q (a random content token)
//!   pos 2:  the *length* token encoding the answer length L ∈ 1..=4
//!   pos 3+: random context tokens, with the answer planted: the token at
//!           the answer start equals q, followed by L-1 "payload" tokens.
//!
//! A model must attend from the query position to the matching context
//! token — the same retrieval structure extractive QA rewards — and emit
//! (start, end).  Metrics: exact match and token-overlap F1 (the paper's
//! SQuAD metric), see [`span_f1`].

use crate::rng::Pcg64;

pub const CLS: i32 = 0;
pub const LEN_BASE: i32 = 1; // tokens 1..=4 encode answer length
pub const CONTENT_BASE: i32 = 8;

#[derive(Clone)]
pub struct SquadDataset {
    pub n: usize,
    pub seq_len: usize,
    pub vocab: usize,
    /// flattened [n, seq_len]
    pub tokens: Vec<i32>,
    pub starts: Vec<i32>,
    pub ends: Vec<i32>,
}

pub fn generate(n: usize, seq_len: usize, vocab: usize, seed: u64) -> SquadDataset {
    assert!(vocab > CONTENT_BASE as usize + 8);
    let mut rng = Pcg64::new(seed ^ 0x50AD);
    let mut tokens = vec![0i32; n * seq_len];
    let mut starts = vec![0i32; n];
    let mut ends = vec![0i32; n];
    let content = |r: &mut Pcg64| CONTENT_BASE + r.below(vocab - CONTENT_BASE as usize) as i32;
    for i in 0..n {
        let t = &mut tokens[i * seq_len..(i + 1) * seq_len];
        let q = content(&mut rng);
        let len = 1 + rng.below(4); // answer length 1..=4
        let start = 3 + rng.below(seq_len - 3 - len);
        t[0] = CLS;
        t[1] = q;
        t[2] = LEN_BASE + (len as i32 - 1);
        for j in 3..seq_len {
            let mut tok = content(&mut rng);
            // the query token must appear exactly once in the context
            while tok == q {
                tok = content(&mut rng);
            }
            t[j] = tok;
        }
        t[start] = q;
        starts[i] = start as i32;
        ends[i] = (start + len - 1) as i32;
    }
    SquadDataset { n, seq_len, vocab, tokens, starts, ends }
}

impl SquadDataset {
    pub fn seq(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.seq_len..(i + 1) * self.seq_len]
    }
}

/// Token-overlap F1 between a predicted span and the gold span — the
/// SQuAD metric the paper reports for BERT.
pub fn span_f1(pred_start: usize, pred_end: usize, gold_start: usize, gold_end: usize) -> f32 {
    let (ps, pe) = if pred_end < pred_start {
        (pred_start, pred_start)
    } else {
        (pred_start, pred_end)
    };
    let overlap = {
        let lo = ps.max(gold_start);
        let hi = pe.min(gold_end);
        (hi + 1).saturating_sub(lo)
    };
    if overlap == 0 {
        return 0.0;
    }
    let pred_len = pe - ps + 1;
    let gold_len = gold_end - gold_start + 1;
    let p = overlap as f32 / pred_len as f32;
    let r = overlap as f32 / gold_len as f32;
    2.0 * p * r / (p + r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    #[test]
    fn answers_are_recoverable_by_needle_search() {
        let ds = generate(100, 32, 256, 5);
        for i in 0..ds.n {
            let t = ds.seq(i);
            let q = t[1];
            let len = (t[2] - LEN_BASE + 1) as usize;
            // the only context occurrence of q is the answer start
            let found: Vec<usize> = (3..32).filter(|&j| t[j] == q).collect();
            assert_eq!(found.len(), 1, "sample {i}");
            assert_eq!(found[0], ds.starts[i] as usize);
            assert_eq!(ds.ends[i] as usize, found[0] + len - 1);
        }
    }

    #[test]
    fn f1_exact_match_is_one() {
        assert_eq!(span_f1(5, 7, 5, 7), 1.0);
    }

    #[test]
    fn f1_disjoint_is_zero() {
        assert_eq!(span_f1(1, 2, 5, 7), 0.0);
    }

    #[test]
    fn f1_partial_overlap() {
        // pred [5,6], gold [6,7]: overlap 1, p=0.5, r=0.5 -> f1=0.5
        assert!((span_f1(5, 6, 6, 7) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn prop_f1_bounds_and_symmetry() {
        forall(500, |r| {
            let gs = r.below(20);
            let ge = gs + r.below(4);
            let ps = r.below(20);
            let pe = ps + r.below(4);
            let f = span_f1(ps, pe, gs, ge);
            assert!((0.0..=1.0).contains(&f));
            // overlap metric is symmetric in pred/gold
            let g = span_f1(gs, ge, ps, pe);
            assert!((f - g).abs() < 1e-6);
        });
    }
}
