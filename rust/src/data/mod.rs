//! Synthetic datasets standing in for the paper's benchmarks
//! (DESIGN.md §3 substitutions — no CIFAR/ImageNet/SQuAD on this testbed):
//!
//! * [`images`]  — class-conditioned structured images (CIFAR-10 /
//!   ImageNet-100 stand-ins) learnable by the same ResNets.
//! * [`squad`]   — span-extraction QA with needle-pattern answers
//!   (SQuAD stand-in, evaluated with token-overlap F1 like the paper).
//! * [`corpus`]  — a tiny Markov LM corpus for the end-to-end example.
//! * [`loader`]  — shuffled mini-batch iteration over any of the above.

pub mod corpus;
pub mod images;
pub mod loader;
pub mod squad;

pub use loader::{Batch, Loader};
