//! Tiny synthetic LM corpus for the end-to-end example.
//!
//! Text is emitted by a seeded order-2 Markov chain over a `vocab`-token
//! alphabet whose transition table is sparse (each bigram allows ~4
//! continuations).  The corpus therefore has ~2 bits/token of irreducible
//! entropy — a GPT-mini reaches substantially lower loss than the
//! ~log(vocab) of a unigram model, which makes the loss curve of the
//! e2e driver meaningful.

use crate::rng::Pcg64;

#[derive(Clone)]
pub struct Corpus {
    pub vocab: usize,
    pub tokens: Vec<i32>,
}

/// `seed` fixes the language (the Markov transition structure);
/// `stream_seed` the emitted token stream — train/test corpora share the
/// language and differ only in the stream.
pub fn generate_split(n_tokens: usize, vocab: usize, seed: u64, stream_seed: u64) -> Corpus {
    let branch = 4usize;
    let mut rng = Pcg64::new(stream_seed ^ 0xC0405);
    // continuation table: (prev2, prev1) -> `branch` allowed next tokens,
    // materialized lazily via hashing so the table costs no memory
    let next = |a: i32, b: i32, r: &mut Pcg64| -> i32 {
        let h = (a as u64)
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add((b as u64).wrapping_mul(0xbf58476d1ce4e5b9))
            .wrapping_add(seed);
        let pick = r.below(branch) as u64;
        let mixed = (h ^ pick.wrapping_mul(0x94d049bb133111eb)).wrapping_mul(0xff51afd7ed558ccd);
        (mixed % vocab as u64) as i32
    };
    let mut tokens = Vec::with_capacity(n_tokens);
    let (mut a, mut b) = (0i32, 1i32);
    let _ = &seed;
    for _ in 0..n_tokens {
        let t = next(a, b, &mut rng);
        tokens.push(t);
        a = b;
        b = t;
    }
    Corpus { vocab, tokens }
}

pub fn generate(n_tokens: usize, vocab: usize, seed: u64) -> Corpus {
    generate_split(n_tokens, vocab, seed, seed)
}

impl Corpus {
    /// Sample a (context, next-token-targets) window pair: x = tokens[o..o+T],
    /// y = tokens[o+1..o+T+1].
    pub fn window(&self, offset: usize, seq_len: usize) -> (&[i32], &[i32]) {
        (
            &self.tokens[offset..offset + seq_len],
            &self.tokens[offset + 1..offset + seq_len + 1],
        )
    }

    pub fn max_offset(&self, seq_len: usize) -> usize {
        self.tokens.len() - seq_len - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(generate(1000, 64, 9).tokens, generate(1000, 64, 9).tokens);
    }

    #[test]
    fn tokens_in_vocab() {
        let c = generate(5000, 64, 1);
        assert!(c.tokens.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn low_entropy_bigram_structure() {
        // given (a, b), the continuation distribution must be concentrated
        // on ~branch tokens (not uniform over the vocab)
        let c = generate(200_000, 64, 2);
        use std::collections::BTreeMap;
        let mut seen: BTreeMap<(i32, i32), std::collections::BTreeSet<i32>> = BTreeMap::new();
        for w in c.tokens.windows(3) {
            seen.entry((w[0], w[1])).or_default().insert(w[2]);
        }
        let avg: f32 = seen.values().map(|s| s.len() as f32).sum::<f32>() / seen.len() as f32;
        assert!(avg < 8.0, "avg continuations {avg} — too close to uniform");
    }

    #[test]
    fn window_shifted_by_one() {
        let c = generate(100, 16, 3);
        let (x, y) = c.window(10, 8);
        assert_eq!(&x[1..], &y[..7]);
    }
}
