//! Optimizers — the "Optimizer Step" of the paper's Algorithm 1, run
//! host-side by the coordinator.
//!
//! * [`SgdMomentum`] updates network parameters.  For EfQAT it supports
//!   **row-masked updates**: `apply_rows` touches only the unfrozen output
//!   channels, with per-row momentum buffers (frozen rows keep their
//!   momentum untouched, exactly like masking the gradient in the paper's
//!   PyTorch implementation).
//! * [`Adam`] updates quantization parameters (the paper "always uses Adam
//!   to update the quantization parameters"), optionally in the log domain
//!   for scales (Appendix A.2, TQT-style) — the `table7` ablation.

use std::collections::BTreeMap;

use crate::tensor::Tensor;

/// FNV-1a over a byte stream — tiny helper for the optimizer-state
/// digests below (bit-exact comparisons across training runs without
/// exposing the private moment buffers).
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf29ce484222325)
    }

    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    fn update_f32s(&mut self, xs: &[f32]) {
        for &x in xs {
            self.update(&x.to_bits().to_le_bytes());
        }
    }
}

/// SGD with momentum and decoupled weight decay (PyTorch semantics:
/// v = μv + g + λw;  w -= lr·v).
pub struct SgdMomentum {
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    velocity: BTreeMap<String, Tensor>,
}

impl SgdMomentum {
    pub fn new(lr: f32, momentum: f32, weight_decay: f32) -> Self {
        SgdMomentum { lr, momentum, weight_decay, velocity: BTreeMap::new() }
    }

    /// Momentum buffer for `name`, created on first use.  Lookup is by
    /// `&str` (no key allocation) so the steady-state step allocates
    /// nothing.
    fn velocity(&mut self, name: &str, shape: &[usize]) -> &mut Tensor {
        if !self.velocity.contains_key(name) {
            self.velocity.insert(name.to_string(), Tensor::zeros(shape));
        }
        self.velocity.get_mut(name).expect("just inserted")
    }

    /// Dense update of a whole parameter tensor.
    pub fn apply_full(&mut self, name: &str, param: &mut Tensor, grad: &[f32]) {
        assert_eq!(param.len(), grad.len(), "{name}: grad size mismatch");
        let v = self.velocity(name, &param.shape);
        for i in 0..grad.len() {
            let g = grad[i] + self.weight_decay * param.data[i];
            v.data[i] = self.momentum * v.data[i] + g;
            param.data[i] -= self.lr * v.data[i];
        }
    }

    /// Bit-exact digest of the momentum state (buffer names + f32 bit
    /// patterns) — lets tests assert two training runs left the
    /// optimizer in an identical state without exposing the buffers.
    pub fn state_digest(&self) -> u64 {
        let mut h = Fnv::new();
        for (name, v) in &self.velocity {
            h.update(name.as_bytes());
            h.update_f32s(&v.data);
        }
        h.0
    }

    /// Row-sparse update: `grad_rows` holds `idx.len()` rows of gradient
    /// (the EfQAT partial dW); only those rows of the parameter (and its
    /// momentum buffer) are touched.
    pub fn apply_rows(&mut self, name: &str, param: &mut Tensor, grad_rows: &[f32], idx: &[usize]) {
        let rs = param.row_size();
        assert_eq!(grad_rows.len(), idx.len() * rs, "{name}: partial grad size mismatch");
        let v = self.velocity(name, &param.shape);
        for (gi, &r) in idx.iter().enumerate() {
            let g = &grad_rows[gi * rs..(gi + 1) * rs];
            let pv = &mut v.data[r * rs..(r + 1) * rs];
            let pw = &mut param.data[r * rs..(r + 1) * rs];
            for i in 0..rs {
                let gg = g[i] + self.weight_decay * pw[i];
                pv[i] = self.momentum * pv[i] + gg;
                pw[i] -= self.lr * pv[i];
            }
        }
    }
}

/// Adam (Kingma & Ba).  Optional log-domain mode for positive scales:
/// the update is applied to ln(s), i.e. s ← exp(ln(s) - lr·m̂/(√v̂+ε)),
/// which keeps scales positive (Appendix A.2).
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub log_domain: bool,
    m: BTreeMap<String, Vec<f32>>,
    v: BTreeMap<String, Vec<f32>>,
    t: BTreeMap<String, u64>,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            log_domain: false,
            m: BTreeMap::new(),
            v: BTreeMap::new(),
            t: BTreeMap::new(),
        }
    }

    pub fn log_domain(mut self, on: bool) -> Self {
        self.log_domain = on;
        self
    }

    /// Adam update over the given (index, grad) pairs.  Takes any
    /// iterator (no `Vec` is built) and looks state up by `&str`, so
    /// steady-state calls perform no heap allocation.
    fn apply_indices<I>(&mut self, name: &str, param: &mut [f32], grads: I)
    where
        I: IntoIterator<Item = (usize, f32)>,
    {
        let n = param.len();
        let (b1, b2, eps, lr, logd) = (self.beta1, self.beta2, self.eps, self.lr, self.log_domain);
        if !self.m.contains_key(name) {
            self.m.insert(name.to_string(), vec![0.0; n]);
            self.v.insert(name.to_string(), vec![0.0; n]);
            self.t.insert(name.to_string(), 0);
        }
        let m = self.m.get_mut(name).expect("just inserted");
        let v = self.v.get_mut(name).expect("just inserted");
        let t = self.t.get_mut(name).expect("just inserted");
        *t += 1;
        let bc1 = 1.0 - b1.powi(*t as i32);
        let bc2 = 1.0 - b2.powi(*t as i32);
        for (i, g0) in grads {
            // chain rule into the log domain: d/d ln(s) = s · d/ds
            let g = if logd { g0 * param[i] } else { g0 };
            m[i] = b1 * m[i] + (1.0 - b1) * g;
            v[i] = b2 * v[i] + (1.0 - b2) * g * g;
            let mh = m[i] / bc1;
            let vh = v[i] / bc2;
            let step = lr * mh / (vh.sqrt() + eps);
            if logd {
                param[i] = (param[i].ln() - step).exp();
            } else {
                param[i] -= step;
            }
        }
    }

    pub fn apply_full(&mut self, name: &str, param: &mut [f32], grad: &[f32]) {
        self.apply_indices(name, param, grad.iter().copied().enumerate());
    }

    /// Sparse update for per-row weight scales: only the unfrozen rows of
    /// S_w are updated ("we update the quantization parameters of a channel
    /// only if we update the weights of that channel").
    pub fn apply_rows(&mut self, name: &str, param: &mut [f32], grad_rows: &[f32], idx: &[usize]) {
        assert_eq!(grad_rows.len(), idx.len());
        self.apply_indices(name, param, idx.iter().copied().zip(grad_rows.iter().copied()));
    }

    pub fn apply_scalar(&mut self, name: &str, param: &mut f32, grad: f32) {
        let mut p = [*param];
        self.apply_indices(name, &mut p, [(0usize, grad)]);
        *param = p[0];
    }

    /// Bit-exact digest of the Adam state (m/v moment bit patterns and
    /// per-buffer step counts) — see [`SgdMomentum::state_digest`].
    pub fn state_digest(&self) -> u64 {
        let mut h = Fnv::new();
        for (name, m) in &self.m {
            h.update(name.as_bytes());
            h.update_f32s(m);
        }
        for v in self.v.values() {
            h.update_f32s(v);
        }
        for t in self.t.values() {
            h.update(&t.to_le_bytes());
        }
        h.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    #[test]
    fn sgd_plain_step() {
        let mut opt = SgdMomentum::new(0.1, 0.0, 0.0);
        let mut p = Tensor::new(vec![2], vec![1.0, 2.0]).unwrap();
        opt.apply_full("p", &mut p, &[1.0, -1.0]);
        assert_eq!(p.data, vec![0.9, 2.1]);
    }

    #[test]
    fn sgd_momentum_accumulates() {
        let mut opt = SgdMomentum::new(1.0, 0.9, 0.0);
        let mut p = Tensor::new(vec![1], vec![0.0]).unwrap();
        opt.apply_full("p", &mut p, &[1.0]); // v=1, p=-1
        opt.apply_full("p", &mut p, &[1.0]); // v=1.9, p=-2.9
        assert!((p.data[0] + 2.9).abs() < 1e-6);
    }

    #[test]
    fn sgd_weight_decay_matches_pytorch() {
        let mut opt = SgdMomentum::new(0.1, 0.0, 0.1);
        let mut p = Tensor::new(vec![1], vec![2.0]).unwrap();
        opt.apply_full("p", &mut p, &[0.0]);
        assert!((p.data[0] - (2.0 - 0.1 * 0.2)).abs() < 1e-7);
    }

    #[test]
    fn sgd_rows_touch_only_selected() {
        let mut opt = SgdMomentum::new(0.5, 0.9, 0.0);
        let mut p = Tensor::new(vec![3, 2], vec![1.0; 6]).unwrap();
        opt.apply_rows("p", &mut p, &[1.0, 1.0], &[1]);
        assert_eq!(p.row(0), &[1.0, 1.0]);
        assert_eq!(p.row(1), &[0.5, 0.5]);
        assert_eq!(p.row(2), &[1.0, 1.0]);
    }

    #[test]
    fn sgd_rows_equals_full_on_selected_rows() {
        // property: a masked update == dense update restricted to the rows
        forall(100, |r| {
            let rows = 2 + r.below(8);
            let cols = 1 + r.below(6);
            let mut rng = r.split(3);
            let init = rng.normal_vec(rows * cols, 1.0);
            let grad = rng.normal_vec(rows * cols, 1.0);
            let k = 1 + r.below(rows);
            let idx = {
                let mut rng2 = r.split(4);
                rng2.choice(rows, k)
            };
            let mut dense = Tensor::new(vec![rows, cols], init.clone()).unwrap();
            let mut sparse = Tensor::new(vec![rows, cols], init.clone()).unwrap();
            let mut o1 = SgdMomentum::new(0.1, 0.9, 0.01);
            let mut o2 = SgdMomentum::new(0.1, 0.9, 0.01);
            for _ in 0..3 {
                o1.apply_full("p", &mut dense, &grad);
                let gr: Vec<f32> = idx
                    .iter()
                    .flat_map(|&r0| grad[r0 * cols..(r0 + 1) * cols].to_vec())
                    .collect();
                o2.apply_rows("p", &mut sparse, &gr, &idx);
            }
            for &r0 in &idx {
                for c in 0..cols {
                    let a = dense.data[r0 * cols + c];
                    let b = sparse.data[r0 * cols + c];
                    assert!((a - b).abs() < 1e-5, "row {r0} col {c}: {a} vs {b}");
                }
            }
        });
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let mut opt = Adam::new(0.1);
        let mut p = [5.0f32];
        for _ in 0..500 {
            let g = 2.0 * (p[0] - 1.0);
            opt.apply_full("p", &mut p, &[g]);
        }
        assert!((p[0] - 1.0).abs() < 1e-2, "{}", p[0]);
    }

    #[test]
    fn adam_log_domain_keeps_scales_positive() {
        let mut opt = Adam::new(0.5).log_domain(true);
        let mut s = [0.01f32];
        for _ in 0..200 {
            opt.apply_scalar("s", &mut s[0], 10.0); // huge pushes downward
            assert!(s[0] > 0.0, "scale went non-positive: {}", s[0]);
        }
    }

    #[test]
    fn adam_raw_can_go_negative_log_cannot() {
        // the instability Appendix A.2 talks about
        let mut raw = Adam::new(0.5);
        let mut s = 0.01f32;
        for _ in 0..10 {
            raw.apply_scalar("s", &mut s, 10.0);
        }
        assert!(s < 0.0);
    }

    #[test]
    fn state_digests_deterministic_and_state_sensitive() {
        let step = |o: &mut SgdMomentum| {
            let mut p = Tensor::new(vec![2], vec![1.0, 2.0]).unwrap();
            o.apply_full("p", &mut p, &[1.0, -1.0]);
        };
        let mut a = SgdMomentum::new(0.1, 0.9, 0.0);
        let mut b = SgdMomentum::new(0.1, 0.9, 0.0);
        step(&mut a);
        step(&mut b);
        assert_eq!(a.state_digest(), b.state_digest());
        step(&mut b); // one extra step must change the digest
        assert_ne!(a.state_digest(), b.state_digest());

        let mut x = Adam::new(0.1);
        let mut y = Adam::new(0.1);
        let (mut s1, mut s2) = (1.0f32, 1.0f32);
        x.apply_scalar("s", &mut s1, 0.5);
        y.apply_scalar("s", &mut s2, 0.5);
        assert_eq!(x.state_digest(), y.state_digest());
        y.apply_scalar("s", &mut s2, 0.5);
        assert_ne!(x.state_digest(), y.state_digest());
    }

    #[test]
    fn adam_sparse_rows_update_independently() {
        let mut opt = Adam::new(0.1);
        let mut p = vec![1.0f32; 4];
        opt.apply_rows("sw", &mut p, &[1.0], &[2]);
        assert_eq!(p[0], 1.0);
        assert!(p[2] < 1.0);
    }
}
