//! Minimal JSON parser + writer for artifact and bundle manifests (serde
//! is unavailable offline).
//!
//! Supports the full JSON grammar the manifests use: objects, arrays,
//! strings (with escapes), numbers, booleans, null.  Not streaming, not
//! zero-copy — manifests are a few hundred KiB at most.  [`Json::render`]
//! serializes back to pretty-printed text with stable (sorted) object key
//! order, so bundle manifests are byte-stable across rebuilds.

use std::collections::BTreeMap;

use crate::error::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn usize(&self) -> Result<usize> {
        Ok(self.num()? as usize)
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn shape(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|j| j.usize()).collect()
    }

    /// Serialize to pretty-printed JSON (2-space indent, sorted keys).
    /// `Json::parse(&j.render())` round-trips for every value this module
    /// can represent.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write_into(&mut out, 0);
        out.push('\n');
        out
    }

    /// Serialize to compact single-line JSON (no whitespace, sorted
    /// keys) — the line format of the JSONL serve protocol (RFC 0002),
    /// where one value must be one `\n`-terminated line.  Round-trips
    /// through [`Json::parse`] exactly like [`Json::render`].
    pub fn render_min(&self) -> String {
        let mut out = String::new();
        self.write_min(&mut out);
        out
    }

    fn write_min(&self, out: &mut String) {
        match self {
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_min(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_min(out);
                }
                out.push('}');
            }
            // scalars render identically in both modes
            other => other.write_into(out, 0),
        }
    }

    fn write_into(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no inf/NaN; parse() rejects them, so a
                    // hand-constructed non-finite renders as null rather
                    // than emitting unparseable output
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    // integers render without a trailing ".0" so
                    // hashes/sizes stay readable and stable
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                    item.write_into(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_into(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.ws();
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            let found = self.b[self.i] as char;
            bail!("expected {:?} at byte {}, found {found:?}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        self.ws();
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.eat(b':')?;
            m.insert(k, self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| anyhow!("unterminated string"))?;
            if c == b'"' {
                self.i += 1;
                return Ok(s);
            }
            if c == b'\\' {
                self.i += 1;
                let e = *self.b.get(self.i).ok_or_else(|| anyhow!("bad escape"))?;
                self.i += 1;
                match e {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        let hex = self
                            .b
                            .get(self.i..self.i + 4)
                            .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                        let cp = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                        self.i += 4;
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => bail!("bad escape \\{}", e as char),
                }
            } else {
                // the source is &str, so any multi-byte UTF-8 sequence is
                // valid — copy the whole sequence, not one byte at a time
                let start = self.i;
                self.i += 1;
                while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                    self.i += 1;
                }
                s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        self.ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = txt.parse()?;
        if !n.is_finite() {
            bail!("number out of range at byte {start}: {txt:?}");
        }
        Ok(Json::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let src = r#"{
          "name": "resnet8_w8a8_train_r25",
          "ratio": 0.25, "w_bits": 8,
          "inputs": [{"name": "x", "shape": [32, 3, 32, 32], "dtype": "f32"}],
          "flag": true, "nothing": null
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("name").unwrap().str().unwrap(), "resnet8_w8a8_train_r25");
        assert_eq!(j.get("ratio").unwrap().num().unwrap(), 0.25);
        let ins = j.get("inputs").unwrap().arr().unwrap();
        assert_eq!(ins[0].get("shape").unwrap().shape().unwrap(), vec![32, 3, 32, 32]);
        assert_eq!(j.get("flag").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("nothing").unwrap(), &Json::Null);
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.str().unwrap(), "a\nb\t\"c\" A");
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e2").unwrap().num().unwrap(), -150.0);
        assert_eq!(Json::parse("42").unwrap().usize().unwrap(), 42);
        // overflow-to-infinity is rejected, keeping render() output
        // parseable for everything parse() accepts
        assert!(Json::parse("1e999").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn nested_round_trip() {
        let j = Json::parse(r#"[[1,2],[3,[4,{"k":[5]}]]]"#).unwrap();
        let outer = j.arr().unwrap();
        assert_eq!(outer.len(), 2);
    }

    #[test]
    fn render_round_trips() {
        let src = r#"{
          "name": "bundle", "schema_version": 1, "ratio": 0.25,
          "entries": [{"sha256": "ab\"c", "bytes": 123}],
          "none": null, "ok": true, "empty_arr": [], "empty_obj": {}
        }"#;
        let j = Json::parse(src).unwrap();
        let rendered = j.render();
        let j2 = Json::parse(&rendered).unwrap();
        assert_eq!(j, j2);
        // integers render without a decimal point
        assert!(rendered.contains("\"schema_version\": 1"));
        assert!(rendered.contains("\"ratio\": 0.25"));
    }

    #[test]
    fn render_min_is_single_line_and_round_trips() {
        let src = r#"{"id": "r1", "logits": [1.5, -2, 0.25], "n": 3, "ok": true}"#;
        let j = Json::parse(src).unwrap();
        let line = j.render_min();
        assert!(!line.contains('\n') && !line.contains(' '), "{line:?}");
        assert_eq!(line, r#"{"id":"r1","logits":[1.5,-2,0.25],"n":3,"ok":true}"#);
        assert_eq!(Json::parse(&line).unwrap(), j);
        assert_eq!(Json::Arr(vec![]).render_min(), "[]");
        assert_eq!(Json::Obj(BTreeMap::new()).render_min(), "{}");
    }

    #[test]
    fn render_escapes_strings() {
        let j = Json::Str("a\nb\"c\\d".to_string());
        let r = j.render();
        assert_eq!(Json::parse(&r).unwrap(), j);
    }

    #[test]
    fn non_ascii_strings_round_trip() {
        let j = Json::parse(r#""café ☕ Größe""#).unwrap();
        assert_eq!(j.str().unwrap(), "café ☕ Größe");
        assert_eq!(Json::parse(&j.render()).unwrap(), j);
    }

    #[test]
    fn truncated_unicode_escape_is_an_error_not_a_panic() {
        assert!(Json::parse(r#""ab\u12"#).is_err());
        assert!(Json::parse(r#""ab\u"#).is_err());
    }
}
