//! Minimal JSON parser for artifact manifests (serde is unavailable offline).
//!
//! Supports the full JSON grammar the manifests use: objects, arrays,
//! strings (with escapes), numbers, booleans, null.  Not streaming, not
//! zero-copy — manifests are a few hundred KiB at most.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn usize(&self) -> Result<usize> {
        Ok(self.num()? as usize)
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn shape(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|j| j.usize()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.ws();
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.b[self.i] as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        self.ws();
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.eat(b':')?;
            m.insert(k, self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| anyhow!("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| anyhow!("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => s.push(c as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        self.ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let src = r#"{
          "name": "resnet8_w8a8_train_r25",
          "ratio": 0.25, "w_bits": 8,
          "inputs": [{"name": "x", "shape": [32, 3, 32, 32], "dtype": "f32"}],
          "flag": true, "nothing": null
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("name").unwrap().str().unwrap(), "resnet8_w8a8_train_r25");
        assert_eq!(j.get("ratio").unwrap().num().unwrap(), 0.25);
        let ins = j.get("inputs").unwrap().arr().unwrap();
        assert_eq!(ins[0].get("shape").unwrap().shape().unwrap(), vec![32, 3, 32, 32]);
        assert_eq!(j.get("flag").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("nothing").unwrap(), &Json::Null);
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.str().unwrap(), "a\nb\t\"c\" A");
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e2").unwrap().num().unwrap(), -150.0);
        assert_eq!(Json::parse("42").unwrap().usize().unwrap(), 42);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn nested_round_trip() {
        let j = Json::parse(r#"[[1,2],[3,[4,{"k":[5]}]]]"#).unwrap();
        let outer = j.arr().unwrap();
        assert_eq!(outer.len(), 2);
    }
}
