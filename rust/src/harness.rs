//! Benchmark harness: timing statistics + paper-format table printing
//! (criterion is unavailable offline; benches use `harness = false`).
//!
//! Every `benches/*.rs` regenerates one of the paper's tables/figures and
//! appends machine-readable CSV rows to `bench_out/` alongside the pretty
//! console table.

use std::io::Write;
use std::path::Path;
use std::time::Instant;

/// Summary statistics over repeated timed runs (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn from(samples: &[f64]) -> Stats {
        if samples.is_empty() {
            return Stats::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            max: samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

/// Time `f` for `warmup + iters` runs, keeping the last `iters`.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<f64> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    Stats::from(&samples)
}

/// Fixed-width console table, paper style.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line: usize = widths.iter().sum::<usize>() + 3 * ncol + 1;
        println!("\n=== {} ===", self.title);
        println!("{}", "-".repeat(line));
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, w) in widths.iter().enumerate() {
                let c = cells.get(i).map(String::as_str).unwrap_or("");
                s.push_str(&format!(" {c:>w$} |", w = w));
            }
            s
        };
        println!("{}", fmt_row(&self.header));
        println!("{}", "-".repeat(line));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        println!("{}", "-".repeat(line));
    }

    /// Append rows as CSV (with header if the file is new).
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let new = !path.exists();
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        if new {
            writeln!(f, "{}", self.header.join(","))?;
        }
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// Render an ASCII sparkline for loss curves / figure-style output.
pub fn sparkline(values: &[f32], width: usize) -> String {
    if values.is_empty() {
        return String::new();
    }
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let stride = (values.len() as f32 / width.max(1) as f32).max(1.0);
    let pick: Vec<f32> = (0..values.len().min(width))
        .map(|i| values[(i as f32 * stride) as usize % values.len()])
        .collect();
    let lo = pick.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = pick.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let span = (hi - lo).max(1e-9);
    pick.iter()
        .map(|v| BARS[(((v - lo) / span) * 7.0).round() as usize])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn table_renders_and_writes_csv() {
        let mut t = Table::new("Table 5", &["model", "mode", "time"]);
        t.row(&["resnet20".into(), "CWPN".into(), "3.46".into()]);
        t.print();
        let dir = std::env::temp_dir().join("efqat_tbl_test");
        let p = dir.join("t.csv");
        std::fs::remove_file(&p).ok();
        t.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("model,mode,time\n"));
        assert!(s.contains("resnet20,CWPN,3.46"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sparkline_monotone() {
        let s = sparkline(&[0.0, 1.0, 2.0, 3.0], 4);
        assert_eq!(s.chars().count(), 4);
    }
}
