//! Experiment configuration: a TOML-subset parser + typed accessors.
//!
//! Supports the subset the experiment configs use: `[section]` headers,
//! `key = value` with strings, numbers, booleans and flat arrays, `#`
//! comments.  Values are addressed as "section.key"; CLI `--key value`
//! pairs override file values, so every experiment is reproducible from
//! `configs/*.toml` + the command line.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{anyhow, bail, Context, Result};

#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn empty() -> Config {
        Config::default()
    }

    pub fn load(path: &Path) -> Result<Config> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Config::parse(&src)
    }

    pub fn parse(src: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: malformed section {line:?}", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, unquote(v.trim()));
        }
        Ok(Config { values })
    }

    /// Apply `--key value` CLI overrides (highest precedence).
    pub fn override_with(&mut self, pairs: &BTreeMap<String, String>) {
        for (k, v) in pairs {
            self.values.insert(k.clone(), v.clone());
        }
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn has(&self, key: &str) -> bool {
        self.values.contains_key(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.values.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn req_str(&self, key: &str) -> Result<String> {
        self.values.get(key).cloned().ok_or_else(|| anyhow!("missing config key {key:?}"))
    }

    pub fn f32(&self, key: &str, default: f32) -> f32 {
        self.values.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.values.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.values.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.values
            .get(key)
            .map(|v| matches!(v.as_str(), "true" | "1" | "yes"))
            .unwrap_or(default)
    }

    /// Comma/array list of strings: `a = ["x", "y"]` or `a = x,y`.
    pub fn list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.values.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => {
                let inner = v.trim().trim_start_matches('[').trim_end_matches(']');
                inner
                    .split(',')
                    .map(|s| unquote(s.trim()))
                    .filter(|s| !s.is_empty())
                    .collect()
            }
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> String {
    let v = v.trim();
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        v[1..v.len() - 1].to_string()
    } else {
        v.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let cfg = Config::parse(
            r#"
            # experiment
            name = "table4"
            [train]
            lr = 1e-3          # comment after value
            epochs = 2
            modes = ["cwpl", "cwpn"]
            log = true
            "#,
        )
        .unwrap();
        assert_eq!(cfg.str("name", ""), "table4");
        assert_eq!(cfg.f32("train.lr", 0.0), 1e-3);
        assert_eq!(cfg.usize("train.epochs", 0), 2);
        assert_eq!(cfg.list("train.modes", &[]), vec!["cwpl", "cwpn"]);
        assert!(cfg.bool("train.log", false));
        assert_eq!(cfg.usize("train.missing", 7), 7);
    }

    #[test]
    fn overrides_take_precedence() {
        let mut cfg = Config::parse("a = 1").unwrap();
        let mut over = BTreeMap::new();
        over.insert("a".to_string(), "2".to_string());
        cfg.override_with(&over);
        assert_eq!(cfg.usize("a", 0), 2);
    }

    #[test]
    fn hash_inside_string_kept() {
        let cfg = Config::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(cfg.str("tag", ""), "a#b");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[oops").is_err());
        assert!(Config::parse("novalue").is_err());
    }
}
