//! EfQAT — Efficient Quantization-Aware Training (Ashkboos et al., 2024).
//!
//! Layer-3 coordinator of the three-layer reproduction:
//!
//! * [`runtime`] loads AOT-compiled HLO artifacts (JAX/Pallas, built once by
//!   `make artifacts`) onto a PJRT client and executes them — python is
//!   never on the training path.
//! * [`coordinator`] implements the paper's Algorithm 1: PTQ initialization,
//!   the EfQAT epoch with channel/layer freezing, and the optimizer step.
//! * [`freeze`] implements the importance metric (Eq. 6) and the three
//!   freezing policies (CWPL / CWPN / LWPN, Table 2).
//! * [`quant`] mirrors the quantization math (Eq. 1–4) host-side for PTQ
//!   calibration and unit-testing against the L1 kernels.
//! * [`data`] generates the synthetic datasets standing in for CIFAR-10 /
//!   ImageNet / SQuAD (DESIGN.md §3) and a tiny LM corpus.
//!
//! Offline-build note: only the crates vendored with the `xla` crate are
//! available, so [`cli`], [`cfg`], [`json`], [`rng`], [`harness`] and
//! [`testing`] provide the small subset of clap/serde/rand/criterion/
//! proptest functionality this project needs.

pub mod cfg;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod freeze;
pub mod harness;
pub mod json;
pub mod model;
pub mod optim;
pub mod quant;
pub mod rng;
pub mod runtime;
pub mod tensor;
pub mod testing;
