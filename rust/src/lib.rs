//! EfQAT — Efficient Quantization-Aware Training (Ashkboos et al., 2024).
//!
//! Layer-3 coordinator of the three-layer reproduction (see
//! `docs/ARCHITECTURE.md` for the full design):
//!
//! * [`backend`] abstracts "execute a compiled step function" behind a
//!   [`backend::Backend`] trait with two implementations: the pure-rust
//!   [`backend::native`] CPU reference executor (zero dependencies — the
//!   default, and what `cargo test` exercises end-to-end) and the
//!   feature-gated [`backend::pjrt`] runtime for AOT-compiled HLO
//!   artifacts (JAX/Pallas, built once by `make artifacts`).
//! * [`graph`] is the layer-graph IR behind the native backend: each
//!   native model is one declarative `Vec<Layer>` from which manifests
//!   are synthesized and forward/backward/calibration run generically —
//!   the frozen-channel-aware partial backward (paper Fig. 1 right) is
//!   implemented once there and inherited by every layer type.
//! * [`ops`] is the shared kernel library the graph executes through:
//!   cache-blocked threaded matmul, im2col conv2d, layernorm, attention,
//!   softmax cross-entropy and the Eq. 1–4 fake-quant ops with STE/LSQ
//!   gradients, each mirroring `python/compile/kernels/ref.py` — plus
//!   the `u8×i8→i32` serving kernels ([`ops::qmatmul`], [`ops::qconv`])
//!   whose inner block dot runs on runtime-dispatched SIMD micro-kernels
//!   ([`ops::simd`]: AVX2 / NEON, scalar oracle, `EFQAT_SIMD` override).
//! * [`lower`] is the float-train → int8-serve boundary: it compiles a
//!   trained graph + calibrated qparams into a [`lower::QuantizedGraph`]
//!   of true integer kernels (weights frozen to per-channel i8 once,
//!   activations quantized at layer boundaries) for forward-only batched
//!   inference — the deployed arithmetic `--exec int8` evaluates and
//!   `benches/serve_throughput.rs` measures.
//! * [`exec`] is the execution workspace behind every hot path: a typed
//!   free-list arena ([`exec::Workspace`]) the planned executors (graph
//!   train/eval steps, the lowered serving forward) draw every
//!   activation, cache, gradient, and scratch buffer from, so the steady
//!   state performs zero heap allocations per training step and per
//!   serve request (RFC `docs/rfcs/0003-exec-plan.md`).
//! * [`serve`] is the concurrent serving runtime above the lowering
//!   boundary (`efqat serve`): a multi-model registry
//!   ([`serve::Registry`], RFC `docs/rfcs/0005-serving-registry.md`)
//!   keyed by (model, checkpoint fingerprint), giving every model its
//!   own bounded intake queue, dynamic micro-batcher (flush on
//!   `max_batch` or a `max_wait` deadline), and worker pool — with
//!   zero-downtime checkpoint hot swap and per-model admission control.
//!   Requests route by model name as JSONL over stdin or TCP
//!   (RFC `docs/rfcs/0002-serve-protocol.md`, v2) and each answer is
//!   bit-identical to a batch-of-1 forward on the engine its reply
//!   names.
//! * [`bundle`] defines the schema-versioned artifact bundle manifest
//!   (`manifest.json`, RFC `docs/rfcs/0001-artifact-manifest.md`) with
//!   per-file SHA-256 checksums, so stale or corrupt artifacts fail
//!   loudly before training starts.
//! * [`coordinator`] implements the paper's Algorithm 1: PTQ
//!   initialization, the EfQAT epoch with channel/layer freezing, and the
//!   optimizer step.  `--workers W` shards each batch across worker
//!   threads with a frozen-aware sparse gradient exchange
//!   ([`coordinator::shard`], RFC `docs/rfcs/0004-gradient-exchange.md`)
//!   that is bit-identical at any worker count.
//! * [`freeze`] implements the importance metric (Eq. 6) and the three
//!   freezing policies (CWPL / CWPN / LWPN, Table 2).
//! * [`quant`] mirrors the quantization math (Eq. 1–4) host-side for PTQ
//!   calibration and unit-testing against the L1 kernels.
//! * [`data`] generates the synthetic datasets standing in for CIFAR-10 /
//!   ImageNet / SQuAD (DESIGN.md §3) and a tiny LM corpus.
//!
//! Offline-build note: the default build has no external dependencies at
//! all, so [`cli`], [`cfg`], [`json`], [`rng`], [`harness`], [`testing`]
//! and [`error`] provide the small subset of clap/serde/rand/criterion/
//! proptest/anyhow functionality this project needs.

pub mod backend;
pub mod bundle;
pub mod cfg;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod exec;
pub mod freeze;
pub mod graph;
pub mod harness;
pub mod json;
pub mod lower;
pub mod model;
pub mod ops;
pub mod optim;
pub mod quant;
pub mod rng;
pub mod serve;
pub mod tensor;
pub mod testing;
